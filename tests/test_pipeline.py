"""Multi-buffered DMA pipeline kernels: bit-identical across pipeline
depths (num_stages 1/2/3), to the classic grid kernels, and to the jnp
oracles — including odd/prime grid sizes where blocks shrink."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import pipeline as P
from repro.kernels.stream import ops, ref

KEY = jax.random.key(7)


def _streams(rows, dtype=jnp.float32):
    n = rows * 128
    return [jax.random.normal(jax.random.fold_in(KEY, i), (n,), dtype)
            for i in range(4)]


ROWS = [512, 64, 33, 7]          # even, block-sized, odd, prime
STAGES = [1, 2, 3]
S, T = 1.7, -0.3


def _all_outputs(rows, ns):
    a, b, c, d = _streams(rows)
    n = rows * 128
    kw = dict(interpret=True, num_stages=ns)
    return {
        "copy": np.asarray(ops.copy(b, **kw)),
        "store": np.asarray(ops.store(S, (n,), jnp.float32, **kw)),
        "update": np.asarray(ops.update(S, a, **kw)),
        "striad": np.asarray(ops.striad(S, b, c, **kw)),
        "schoenauer": np.asarray(ops.schoenauer(b, c, d, **kw)),
        "triad_update": np.asarray(ops.triad_update(S, T, b, c, **kw)),
        "load": np.asarray(ops.load(a, **kw)),
        "ddot": np.asarray(ops.ddot(a, b, **kw)),
    }


@pytest.mark.parametrize("rows", ROWS)
def test_bit_identical_across_num_stages(rows):
    """Pipeline depth must not change a single bit of any kernel output
    (the reduction accumulates in chunk order regardless of depth)."""
    base = _all_outputs(rows, 1)
    for ns in STAGES[1:]:
        outs = _all_outputs(rows, ns)
        for k in outs:
            assert np.array_equal(outs[k], base[k]), (rows, ns, k)


@pytest.mark.parametrize("rows", ROWS)
@pytest.mark.parametrize("ns", STAGES)
def test_bit_identical_to_grid_kernels(rows, ns):
    """DMA pipeline == classic one-block-per-grid-step Pallas kernels."""
    a, b, c, d = _streams(rows)
    n = rows * 128
    kw = dict(interpret=True, num_stages=ns)
    legacy = dict(interpret=True)
    assert np.array_equal(np.asarray(ops.copy(b, **kw)),
                          np.asarray(ops.copy(b, **legacy)))
    assert np.array_equal(
        np.asarray(ops.store(S, (n,), jnp.float32, **kw)),
        np.asarray(ops.store(S, (n,), jnp.float32, **legacy)))
    assert np.array_equal(np.asarray(ops.update(S, a, **kw)),
                          np.asarray(ops.update(S, a, **legacy)))
    assert np.array_equal(np.asarray(ops.striad(S, b, c, **kw)),
                          np.asarray(ops.striad(S, b, c, **legacy)))
    assert np.array_equal(np.asarray(ops.schoenauer(b, c, d, **kw)),
                          np.asarray(ops.schoenauer(b, c, d, **legacy)))


@pytest.mark.parametrize("rows", [512, 33])
def test_elementwise_match_ref_oracles(rows):
    """Elementwise pipeline kernels equal the jnp oracles bit-for-bit
    (identical per-element arithmetic; reductions get tolerances since
    summation order legitimately differs from a whole-array jnp.sum)."""
    a, b, c, d = _streams(rows)
    n = rows * 128
    kw = dict(interpret=True, num_stages=2)
    assert np.array_equal(np.asarray(ops.copy(b, **kw)),
                          np.asarray(ref.copy(b)))
    assert np.array_equal(np.asarray(ops.store(S, (n,), jnp.float32, **kw)),
                          np.asarray(ref.store(S, (n,), jnp.float32)))
    assert np.array_equal(np.asarray(ops.update(S, a, **kw)),
                          np.asarray(ref.update(S, a)))
    np.testing.assert_allclose(np.asarray(ops.striad(S, b, c, **kw)),
                               np.asarray(ref.striad(S, b, c)),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ops.schoenauer(b, c, d, **kw)),
                               np.asarray(ref.schoenauer(b, c, d)),
                               rtol=1e-6, atol=1e-6)
    atol = 1e-3 * n**0.5
    np.testing.assert_allclose(float(ops.load(a, **kw)),
                               float(ref.load(a)), rtol=1e-4, atol=atol)
    np.testing.assert_allclose(float(ops.ddot(a, b, **kw)),
                               float(ref.ddot(a, b)), rtol=1e-4, atol=atol)


def test_fused_chain_matches_composition():
    a, b, c, d = _streams(64)
    fused = np.asarray(ops.triad_update(S, T, b, c, interpret=True))
    chained = np.asarray(ops.triad_update_unfused(S, T, b, c,
                                                  interpret=True))
    np.testing.assert_allclose(fused, chained, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        fused, np.asarray(ref.update(T, ref.striad(S, b, c))),
        rtol=1e-6, atol=1e-6)


def test_fused_chain_stream_counts():
    unfused, fused = P.triad_update_chain_streams()
    assert (unfused, fused) == (5, 3)


def test_bf16_pipeline():
    rows = 64
    b = jax.random.normal(jax.random.fold_in(KEY, 9), (rows * 128,),
                          jnp.bfloat16)
    c = jax.random.normal(jax.random.fold_in(KEY, 10), (rows * 128,),
                          jnp.bfloat16)
    got = ops.striad(S, b, c, interpret=True, num_stages=3)
    legacy = ops.striad(S, b, c, interpret=True)
    assert np.array_equal(np.asarray(got, np.float32),
                          np.asarray(legacy, np.float32))


def test_num_stages_capped_by_chunks():
    """num_stages larger than the chunk count degrades gracefully."""
    b = jax.random.normal(KEY, (2 * 128,), jnp.float32)
    got = ops.copy(b, interpret=True, num_stages=3, block_rows=2)
    assert np.array_equal(np.asarray(got), np.asarray(b))


def test_pipeline_config_vmem_budget():
    cfg = P.PipelineConfig(num_stages=3, block_rows=64)
    assert cfg.vmem_bytes(n_streams=4) == 3 * 4 * 64 * 128 * 4


# ---------------------------------------------------------------------------
# overlap calibration (tpu_ecm glue)
# ---------------------------------------------------------------------------


def test_overlap_coefficient_inversion():
    from repro.core.tpu_ecm import measured_overlap, overlap_coefficient

    # fully serialized: measured = t_comp + t_x -> f = 1
    assert overlap_coefficient(3.0, 1.0, 2.0) == pytest.approx(1.0)
    # fully hidden (transfer-bound): measured = t_x -> smallest f
    assert overlap_coefficient(2.0, 1.0, 2.0) == pytest.approx(0.5)
    # compute-bound and hidden: f = 0
    assert overlap_coefficient(1.0, 1.0, 0.5) == pytest.approx(0.0)
    # serial vs pipelined pair: hiding t_x fully -> f = 0
    assert measured_overlap(3.0, 1.0, 2.0) == pytest.approx(0.0)
    assert measured_overlap(3.0, 3.0, 2.0) == pytest.approx(1.0)
    assert measured_overlap(3.0, 2.0, 2.0) == pytest.approx(0.5)


def test_with_measured_overlap():
    from repro.core.tpu_ecm import TPUStepECM, with_measured_overlap

    step = TPUStepECM(name="t", t_comp=1.0, t_hbm=2.0, t_ici=0.0)
    cal = with_measured_overlap(step, t_serial_s=3.0, t_pipelined_s=2.0)
    assert cal.exposed_hbm_fraction == pytest.approx(0.5)
    assert cal.t_ecm == pytest.approx(2.0)      # max(1, 1) + 1
