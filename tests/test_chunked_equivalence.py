"""Chunked-parallel formulations vs sequential references (the trainable
fast paths must be semantically identical to the recurrences they replace).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import _chunked_attn, _dense_attn
from repro.models.mamba2 import Mamba2Config, _ssd_chunked, mamba2_layer
from repro.models.xlstm import _mlstm_chunked, _mlstm_core


def _rand(key, *shape):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


@given(st.integers(1, 3), st.integers(2, 24), st.integers(1, 2),
       st.sampled_from([4, 8]), st.sampled_from([3, 8]))
@settings(max_examples=10, deadline=None)
def test_mlstm_chunked_matches_sequential(b, s, h, p, chunk):
    q, k, v = (_rand(i, b, s, h, p) for i in range(3))
    i_raw = _rand(3, b, s, h) * 2
    f_raw = _rand(4, b, s, h) * 2 + 1
    ref, (c0, n0, m0) = _mlstm_core(q, k, v, i_raw, f_raw)
    got, (c1, n1, m1) = _mlstm_chunked(q, k, v, i_raw, f_raw, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m0),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c0),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_chunked_with_carry_state():
    b, s, h, p = 2, 12, 2, 4
    q, k, v = (_rand(i, b, s, h, p) for i in range(3))
    i_raw, f_raw = _rand(3, b, s, h), _rand(4, b, s, h) + 1
    # run the first 8 steps, carry, then the last 4 — must equal one pass
    ref, _ = _mlstm_core(q, k, v, i_raw, f_raw)
    _, st8 = _mlstm_chunked(q[:, :8], k[:, :8], v[:, :8], i_raw[:, :8],
                            f_raw[:, :8], chunk=4)
    tail, _ = _mlstm_chunked(q[:, 8:], k[:, 8:], v[:, 8:], i_raw[:, 8:],
                             f_raw[:, 8:], state=st8, chunk=4)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(ref[:, 8:]),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------


def _ssd_reference(cfg, x, bmat, cmat, dt, a_log):
    """Naive per-step recurrence h_t = a_t h_{t-1} + dt_t B_t x_t."""
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hpg = h // g
    a = np.exp(np.asarray(a_log, np.float64))
    hst = np.zeros((b, h, n, p))
    ys = np.zeros((b, s, h, p))
    xf = np.asarray(x, np.float64)
    bf = np.repeat(np.asarray(bmat, np.float64), hpg, 2)
    cf = np.repeat(np.asarray(cmat, np.float64), hpg, 2)
    dtf = np.asarray(dt, np.float64)
    for t in range(s):
        at = np.exp(-dtf[:, t][:, :, None, None] * a[None, :, None, None])
        contrib = (dtf[:, t][:, :, None, None]
                   * bf[:, t][:, :, :, None] * xf[:, t][:, :, None, :])
        hst = at * hst + contrib
        ys[:, t] = np.einsum("bhn,bhnp->bhp", cf[:, t], hst)
    return ys


@pytest.mark.parametrize("s,chunk", [(8, 4), (12, 5), (16, 16), (7, 3)])
def test_ssd_chunked_matches_recurrence(s, chunk):
    cfg = Mamba2Config(d_model=8, d_state=4, head_dim=4, chunk=chunk)
    b, h, p, g, n = 2, 4, 4, 1, 4
    x = _rand(0, b, s, h, p)
    bmat = _rand(1, b, s, g, n)
    cmat = _rand(2, b, s, g, n)
    dt = jax.nn.softplus(_rand(3, b, s, h))
    a_log = jnp.zeros((h,))
    y, _ = _ssd_chunked(cfg, x, bmat, cmat, dt, a_log)
    want = _ssd_reference(cfg, x, bmat, cmat, dt, a_log)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-3, atol=1e-3)


def test_mamba2_decode_matches_prefill():
    """Token-by-token decode must reproduce the chunked full-seq output."""
    cfg = Mamba2Config(d_model=16, d_state=4, head_dim=8, chunk=4)
    from repro.models.common import materialize
    from repro.models.mamba2 import mamba2_spec
    params = materialize(mamba2_spec(cfg), jax.random.key(0))
    u = _rand(9, 2, 10, 16)
    full = mamba2_layer(params, cfg, u)
    # decode one token at a time
    ssm = conv = None
    outs = []
    for t in range(10):
        o, (ssm, conv) = mamba2_layer(params, cfg, u[:, t:t + 1],
                                      ssm_state=ssm, conv_state=conv,
                                      return_state=True)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full, np.float32),
                               rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# chunked attention
# ---------------------------------------------------------------------------


@given(st.integers(1, 2), st.sampled_from([8, 16, 32]),
       st.sampled_from([(4, 2), (4, 4), (2, 1)]), st.sampled_from([4, 8, 16]),
       st.booleans())
@settings(max_examples=12, deadline=None)
def test_chunked_attention_matches_dense(b, s, heads, chunk, causal):
    h, kvh = heads
    d = 8
    q = _rand(0, b, s, h, d)
    k = _rand(1, b, s, kvh, d)
    v = _rand(2, b, s, kvh, d)
    got = _chunked_attn(q, k, v, causal=causal, chunk=chunk)
    kk = jnp.repeat(k, h // kvh, axis=2)
    vv = jnp.repeat(v, h // kvh, axis=2)
    want = _dense_attn(q, kk, vv, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
