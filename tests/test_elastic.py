"""Elastic re-meshing: ``shrink_mesh`` edge cases + ``remesh_state``
round-trips.

The axis-edge checks run in-process (a 1x1 mesh exists on any host);
the multi-device round-trip shells out with 8 fake devices — the
``XLA_FLAGS`` fake-device knob must be set before jax initializes, and
the main test process has long since imported jax (same pattern as
``test_dryrun.py``).  The round-trip is the property the serving
engine's device-loss fault leans on: shrink the mesh on the data axis,
reshard the state, and every element must come back bit-identical.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.train.elastic import shrink_mesh

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code, timeout=240):
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    return subprocess.run([sys.executable, "-c", code], env=env, cwd=ROOT,
                          capture_output=True, text=True, timeout=timeout)


def _mesh_1x1():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(dev, ("data", "model"))


def test_shrink_unknown_axis_raises():
    with pytest.raises(ValueError, match="no axis 'pod'"):
        shrink_mesh(_mesh_1x1(), "pod")


def test_shrink_size_one_axis_raises():
    with pytest.raises(ValueError, match="cannot shrink axis data"):
        shrink_mesh(_mesh_1x1(), "data")


def test_shrink_error_names_known_axes():
    with pytest.raises(ValueError, match="data.*model"):
        shrink_mesh(_mesh_1x1(), "nope")


_ROUNDTRIP = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax
import numpy as np
from jax.sharding import Mesh
from repro.dist.sharding import ShardingProfile, param_shardings
from repro.models.common import ParamSpec
from repro.train.elastic import remesh_state, shrink_mesh

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
spec = {
    "w": ParamSpec(shape=(16, 8), axes=("rows", "cols")),
    "kv": ParamSpec(shape=(8, 4, 4), axes=("pages", None, None)),
    "step": ParamSpec(shape=(), axes=()),
}
profile = ShardingProfile("t", rules={"rows": "data", "cols": "model",
                                      "pages": "data"})
rng = np.random.default_rng(0)
host = {
    "w": rng.standard_normal((16, 8)).astype(np.float32),
    "kv": rng.standard_normal((8, 4, 4)).astype(np.float32),
    "step": np.float32(17.0),
}
shardings = param_shardings(spec, mesh, profile)
flat_a, treedef = jax.tree.flatten(host)
flat_s = jax.tree.flatten(shardings, is_leaf=lambda x: hasattr(x, "spec"))[0]
state = jax.tree.unflatten(
    treedef, [jax.device_put(a, s) for a, s in zip(flat_a, flat_s)])

small = shrink_mesh(mesh, "data")
assert small.devices.shape == (2, 2), small.devices.shape
restate = remesh_state(state, spec, small, profile)

for key in host:
    got = np.asarray(restate[key])
    assert got.dtype == host[key].dtype, (key, got.dtype)
    assert np.array_equal(got, np.asarray(host[key])), key
    sh = restate[key].sharding
    assert set(sh.mesh.axis_names) == {"data", "model"}, key
    assert sh.mesh.devices.shape == (2, 2), (key, sh.mesh.devices.shape)

# shrink again down to data=1, then shrinking further must raise
tiny = shrink_mesh(small, "data")
state2 = remesh_state(restate, spec, tiny, profile)
assert np.array_equal(np.asarray(state2["w"]), host["w"])
try:
    shrink_mesh(tiny, "data")
except ValueError:
    print("ROUNDTRIP-OK")
else:
    raise AssertionError("expected ValueError at data=1")
"""


def test_remesh_roundtrip_bit_identical():
    r = _run(_ROUNDTRIP)
    assert "ROUNDTRIP-OK" in r.stdout, r.stdout + r.stderr
