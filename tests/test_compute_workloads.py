"""Compute-bound workload families: blocked matmul + flash attention.

Pinned here:

1. **Traffic law** — the blocked-GEMM layer conditions (``K/bn + K/bm``
   streamed panels vs ``K/N + K/M`` resident ones) and the attention KV
   reuse condition produce the hand-derived per-edge line counts, and
   they move with the *machine's* capacities.
2. **In-core routing** — contraction MACs (``UopMix.dot``) run on the FMA
   ports on CPUs (hitting exactly the SP FMA peak on Haswell), decompose
   into mul+add on the no-FMA Sandy Bridge, and retire at the MXU
   systolic rate on the tpu-v5e hierarchy view (``T_OL`` = flops /
   peak_f32 exactly).
3. **Eq. 1 from the non-saturated side** — both families are core-bound:
   the prediction equals ``T_OL`` at every residence level, and golden
   Haswell models are pinned bit-identical
   (``tests/golden_haswell_ecm.json``).
4. **Autotuners** — ``rank(..., objective="matmul"|"attention")``
   ranks through the generic workload path, and the chosen
   blockings drive the real Pallas kernels (interpret mode) to
   oracle-identical results.
5. **Bench-regression gate** — ``tools/check_bench.py --compare`` passes
   on identical artifacts, ignores wall-clock drift, and fails (exit 1)
   on injected model-prediction drift beyond ``--rtol``.
"""
import importlib.util
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    FLASH_ATTENTION_F32,
    HASWELL_EP,
    MACHINES,
    MATMUL_F32,
    SANDY_BRIDGE_EP,
    SKYLAKE_SP,
    TPU_V5E,
    TPU_V5E_HIERARCHY,
    AttentionWorkload,
    MatmulWorkload,
    get_machine,
    route_traffic,
    workload_ecm,
    workload_registry,
)
from repro.core.autotune import (
    attention_block_candidates,
    matmul_block_candidates,
    rank,
)

GOLDEN = json.loads(
    (Path(__file__).parent / "golden_haswell_ecm.json").read_text())

MM = MatmulWorkload(MATMUL_F32, m=4096, n=4096, k=4096)
ATT = AttentionWorkload(FLASH_ATTENTION_F32)


# ---------------------------------------------------------------------------
# 1. Traffic law
# ---------------------------------------------------------------------------


def test_matmul_streamed_panel_traffic():
    """Neither panel survives Haswell's L1/L2 at the default blocking:
    K/bn (A) + K/bm (B) lines per CL of C, plus the C store pair."""
    t = MM.traffic(HASWELL_EP)
    k, bm, bn = MM.k, MM.bm, MM.bn
    assert t.loads[0, 0] == k / bn + k / bm == 32.0
    assert t.loads[0, 1] == 32.0
    assert t.rfo[0] == 1.0 and t.evicts[0] == 1.0 and t.nt[0] == 0.0


def test_matmul_a_panel_layer_condition():
    """bm=512 makes the A panel (bm*K*4 B = 8 MB) fit the 17.5 MB L3
    (safety 2): A drops to K/N = 1 line at the memory edge while B still
    streams at K/bm."""
    w = MM.with_block((512, 1024, 512))
    t = w.traffic(HASWELL_EP)
    assert t.loads[0, 2] == MM.k / MM.n + MM.k / 512 == 9.0
    # bm=1024: the 16 MB panel no longer fits half the LLC slice
    t2 = MM.with_block((1024, 512, 512)).traffic(HASWELL_EP)
    assert t2.loads[0, 2] == MM.k / 512 + MM.k / 1024 == 12.0


def test_matmul_lc_moves_with_machine_capacities():
    """The same workload holds the A panel in SKX's big L2 slice only
    where the capacities allow: per-machine traffic, one code path."""
    small = MatmulWorkload(MATMUL_F32, m=512, n=512, k=512, bm=128, bn=128)
    hsw = small.traffic(HASWELL_EP)    # A panel 128*512*4 = 256 KiB
    skx = small.traffic(SKYLAKE_SP)    # SKX L2 = 1 MiB holds it (safety 2)
    assert hsw.loads[0, 1] == 512 / 128 + 512 / 128      # both streamed
    assert skx.loads[0, 1] == 512 / 512 + 512 / 128      # A resident in L2


def test_matmul_blocking_changes_mem_traffic_not_uops():
    u1, u2 = MM.uops(), MM.with_block((32, 32, 512)).uops()
    assert u1 == u2
    t1 = MM.traffic(HASWELL_EP).loads[0, 0]
    t2 = MM.with_block((32, 32, 512)).traffic(HASWELL_EP).loads[0, 0]
    assert t2 == 4096 / 32 * 2 > t1
    # the tiny A panel (32 rows) goes L3-resident: K/N + K/bm at the edge
    t2_mem = MM.with_block((32, 32, 512)).traffic(HASWELL_EP).loads[0, -1]
    assert t2_mem == 4096 / 4096 + 4096 / 32


def test_attention_kv_reuse_condition():
    """KV (2*4096*128*4 B = 4 MB) fits Haswell's L3 slice but not L1/L2:
    streamed 2*Sk_eff/bq lines above, cold 2*skv/sq lines below."""
    t = ATT.traffic(HASWELL_EP)
    sk_eff = ATT.skv * ATT.kv_fraction()
    assert t.loads[0, 0] == pytest.approx(1.0 + 2.0 * sk_eff / ATT.bq)
    assert t.loads[0, 2] == pytest.approx(1.0 + 2.0 * ATT.skv / ATT.sq)
    assert t.rfo[0] == 1.0 and t.evicts[0] == 1.0


def test_attention_causal_fraction():
    assert ATT.kv_fraction() == pytest.approx(0.5 + 512 / 8192)
    full = AttentionWorkload(FLASH_ATTENTION_F32, causal=False)
    assert full.kv_fraction() == 1.0
    # non-causal doubles the contractions (up to the block-diagonal term)
    assert full.uops().dot == pytest.approx(4.0 * full.skv)
    assert ATT.uops().dot < full.uops().dot


def test_attention_causal_fraction_matches_kernel_block_skip():
    """The Pallas kernel visits a tile unless the whole q block is above
    the diagonal (``qi*bq + bq - 1 < ki*bkv``): count the visited block
    pairs exactly and compare with the model's kv_fraction."""
    for bq, bkv in ((2048, 128), (128, 2048), (512, 512), (4096, 4096)):
        w = AttentionWorkload(FLASH_ATTENTION_F32, bq=bq, bkv=bkv)
        visited = sum(1
                      for qi in range(w.sq // bq)
                      for ki in range(w.skv // bkv)
                      if qi * bq + bq - 1 >= ki * bkv)
        total = (w.sq // bq) * (w.skv // bkv)
        assert w.kv_fraction() == pytest.approx(visited / total), (bq, bkv)


def test_attention_rescale_overhead_shrinks_with_kv_block():
    """The online-softmax rescale is the bkv knob: fewer KV passes, fewer
    acc *= alpha multiplies (causal factor held fixed here)."""
    small = AttentionWorkload(FLASH_ATTENTION_F32, causal=False, bkv=128)
    large = AttentionWorkload(FLASH_ATTENTION_F32, causal=False, bkv=2048)
    assert small.uops().mul > large.uops().mul
    assert small.uops().dot == large.uops().dot


def test_compute_families_route_through_hierarchy_semantics():
    """No-write-allocate routing applies to the families like any other
    workload: the C/O store pair becomes an NT stream on the TPU."""
    routed = route_traffic(TPU_V5E_HIERARCHY, MM.traffic(TPU_V5E_HIERARCHY))
    hbm_in = routed.load_lines[0, -1]
    hbm_out = routed.evict_lines[0, -1]
    assert hbm_out == 1.0                      # write-back turned NT stream
    assert hbm_in == 2.0                       # A + B resident in VMEM


# ---------------------------------------------------------------------------
# 2. In-core routing of contraction MACs
# ---------------------------------------------------------------------------


def test_matmul_hits_fma_peak_on_haswell():
    """T_OL = K cycles per CL of C = exactly the SP FMA peak (2 ports x
    8 f32 lanes x 2 flops); the register tile keeps loads non-binding
    (arXiv:1511.03639's Haswell DGEMM structure)."""
    e = workload_ecm(MM, HASWELL_EP)
    assert e.t_ol == MM.k
    assert e.t_nol < e.t_ol
    elems = HASWELL_EP.line_bytes // MATMUL_F32.elem_bytes
    flops_per_cl = elems * 2 * MM.k
    assert flops_per_cl / e.prediction("Mem") == pytest.approx(
        HASWELL_EP.flops_per_cycle_sp)


def test_dot_uops_decompose_on_no_fma_machine():
    """Sandy Bridge has no FMA units: each contraction MAC splits into a
    multiply and an add uop — T_OL doubles (add-port bound)."""
    hsw = workload_ecm(MM, HASWELL_EP)
    snb = workload_ecm(MM, SANDY_BRIDGE_EP)
    assert snb.t_ol == 2 * hsw.t_ol


def test_mxu_replaces_fma_ports_on_tpu():
    """On the tpu-v5e view the dot uops retire at the MXU systolic rate:
    T_OL equals flops / peak_f32 in core cycles, not the VPU rate."""
    e = workload_ecm(MM, "tpu-v5e")
    flops_per_row = 128 * 2 * MM.k
    want = flops_per_row / (TPU_V5E.peak_f32_flops / TPU_V5E.clock_hz)
    assert e.t_ol == pytest.approx(want)
    # the VPU rate would be ~100x slower for the same uop count
    vpu_cycles = MM.uops().dot / 8.0
    assert e.t_ol < vpu_cycles / 50


def test_attention_softmax_rides_the_vpu_on_tpu():
    """The QK/PV contractions hit the MXU but the online-softmax
    mul/add stay on the VPU — on the TPU the exp/rescale overhead, not
    the MACs, binds T_OL (the small-d flash-attention reality)."""
    e = workload_ecm(ATT, "tpu-v5e")
    u = ATT.uops()
    mxu = TPU_V5E_HIERARCHY.ports.mxu_vectors_per_cycle
    assert e.t_ol == pytest.approx(max(u.dot / mxu, (u.mul + u.add) / 8.0))
    assert (u.mul + u.add) / 8.0 > u.dot / mxu


# ---------------------------------------------------------------------------
# 3. Core-bound composition + golden pins
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workload", [MM, ATT], ids=["matmul", "attention"])
@pytest.mark.parametrize("machine", sorted(MACHINES))
def test_core_bound_on_every_machine(workload, machine):
    """T_OL hides the whole transfer chain at the registry sizes: the
    prediction equals T_core at every residence level — Eq. 1 exercised
    from the non-saturated side on the full zoo."""
    e = workload_ecm(workload, machine)
    assert e.t_ol > e.t_nol
    for p in e.predictions():
        assert p == pytest.approx(e.t_ol)


@pytest.mark.parametrize("key", sorted(GOLDEN["compute"]))
def test_compute_bit_equal_to_golden(key):
    rec = GOLDEN["compute"][key]
    name, dims, blk = key.split("@")
    block = tuple(int(x) for x in blk.removeprefix("blk").split(","))
    if name == "matmul":
        m, n, k = (int(x) for x in dims.split("x"))
        w = MatmulWorkload(MATMUL_F32, m=m, n=n, k=k).with_block(block)
    else:
        sq, rest = dims.split("x", 1)
        skv, d = rest.split("xd")
        w = AttentionWorkload(FLASH_ATTENTION_F32, sq=int(sq), skv=int(skv),
                              d=int(d)).with_block(block)
    mdl = workload_ecm(w, "haswell-ep")
    assert mdl.t_ol.hex() == rec["t_ol"]
    assert mdl.t_nol.hex() == rec["t_nol"]
    assert [t.hex() for t in mdl.transfers] == rec["transfers"]
    assert [p.hex() for p in mdl.predictions()] == rec["predictions"]


def test_registry_includes_compute_families():
    reg = workload_registry()
    assert {"matmul", "flash-attention"}.issubset(reg)
    assert len(reg) >= 14


# ---------------------------------------------------------------------------
# 4. Autotuners + Pallas kernel validation
# ---------------------------------------------------------------------------


def test_matmul_candidates_divide_dims():
    for bm, bn, bk in matmul_block_candidates(4096, 2048, 1024):
        assert 4096 % bm == 0 and 2048 % bn == 0 and 1024 % bk == 0


def test_rank_matmul_blocks_prefers_core_bound_tiles():
    ranked = rank((4096, 4096, 4096), "haswell-ep", objective="matmul")
    best, worst = ranked[0], ranked[-1]
    assert best["core_bound"] and best["t_ecm"] <= worst["t_ecm"]
    assert worst["block"][:2] == (32, 32) and not worst["core_bound"]
    assert best["mem_lines"] < worst["mem_lines"]
    # ties among core-bound candidates break toward the largest tile
    assert best["block"][:2] == (1024, 1024)


def test_rank_attention_blocks_fit_constraint():
    ranked = rank((4096, 4096, 128), "haswell-ep", objective="attention")
    fitting = [r["fits"] for r in ranked]
    # all fitting candidates rank before any non-fitting one
    assert fitting == sorted(fitting, reverse=True)
    assert ranked[0]["fits"]
    cap = max(get_machine("haswell-ep").capacities)
    assert ranked[0]["tile_bytes"] * 2 <= cap


def test_attention_candidates_divide_dims():
    for bq, bkv in attention_block_candidates(2048, 4096):
        assert 2048 % bq == 0 and 4096 % bkv == 0


def test_tuned_blocks_drive_pallas_matmul_to_oracle():
    """The tuner's pick is directly usable by the Pallas kernel and
    produces oracle-identical results in interpret mode."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.matmul import ops as mm_ops, ref as mm_ref

    dim = 256
    bm, bn, bk = mm_ops.tuned_blocks(dim, dim, dim)
    assert dim % bm == 0 and dim % bn == 0 and dim % bk == 0
    kx, ky = jax.random.split(jax.random.key(1))
    x = jax.random.normal(kx, (dim, dim), jnp.float32)
    y = jax.random.normal(ky, (dim, dim), jnp.float32)
    got = mm_ops.matmul(x, y, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(mm_ref.matmul(x, y)),
                               rtol=1e-5, atol=1e-5)


def test_tuned_blocks_drive_pallas_attention_to_oracle():
    import jax
    import jax.numpy as jnp

    from repro.kernels.attention import ops as att_ops, ref as att_ref

    sq = sk = 256
    d = 64
    bq, bkv = att_ops.tuned_blocks(sq, sk, d, machine="haswell-ep")
    assert sq % bq == 0 and sk % bkv == 0
    kq, kk, kv = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(kq, (1, sq, 1, d), jnp.float32)
    k = jax.random.normal(kk, (1, sk, 1, d), jnp.float32)
    v = jax.random.normal(kv, (1, sk, 1, d), jnp.float32)
    got = att_ops.flash_attention(q, k, v, causal=True, bq=bq, bk=bkv,
                                  interpret=True)
    flat = lambda t: t.transpose(0, 2, 1, 3).reshape(1, sq, d)
    want = att_ref.attention(flat(q), flat(k), flat(v), causal=True)
    want = want.reshape(1, 1, sq, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_matmul_workload_matches_kernel_blocking():
    from repro.kernels.matmul.ops import matmul_workload

    w = matmul_workload(512, 512, 512, bm=128, bn=128, bk=128)
    assert (w.bm, w.bn, w.bk) == (128, 128, 128)
    assert w.m == 512


# ---------------------------------------------------------------------------
# Simulator: the compute-bound path
# ---------------------------------------------------------------------------


def test_simulator_compute_bound_path():
    """Long-T_OL kernels sustain fma_sustained_eff of the light-speed
    rate at every residence level; short-T_OL kernels are untouched."""
    from repro.simcache import simulate_workloads_batch
    from repro.simcache.sim import DEFAULT_PARAMS, SimParams

    names, table = simulate_workloads_batch([MM], "haswell-ep")
    want = MM.k / DEFAULT_PARAMS.fma_sustained_eff
    np.testing.assert_allclose(table, want)

    # disabling the effect recovers the light-speed core bound (up to the
    # small L2/front-end penalties, < 1% at this T_OL)
    off = SimParams(fma_sustained_eff=1.0)
    _, table_off = simulate_workloads_batch([MM], "haswell-ep", params=off)
    assert np.all(table_off >= MM.k)
    assert np.all(table_off <= MM.k * 1.01)


def test_simulator_passes_through_prelowered_records():
    """The cycles-denominated FMA derate must not touch pre-lowered
    records whose times are in their own units (the TPU step model is
    microseconds per step): they simulate at the light-speed prediction."""
    from repro.core.tpu_ecm import TPUStepECM
    from repro.core.workload import lower, tpu_step_workload
    from repro.simcache import simulate_workloads_batch

    step = tpu_step_workload(
        TPUStepECM(name="big", t_comp=2e-4, t_hbm=5e-5, t_ici=0.0))
    _, table = simulate_workloads_batch([step], "tpu-v5e")
    want = lower(step, "tpu-v5e").batch.predictions()
    np.testing.assert_array_equal(table, want)


def test_simulator_streams_unaffected_by_compute_path():
    """The threshold keeps every Table I / stencil kernel identical to
    the pre-compute-path simulator (their T_OL <= 6 cycles)."""
    from repro.core import BENCHMARKS, StreamWorkload
    from repro.simcache import simulate_workloads_batch
    from repro.simcache.sim import SimParams

    ws = [StreamWorkload(s) for s in BENCHMARKS.values()]
    _, with_eff = simulate_workloads_batch(ws, "haswell-ep")
    _, without = simulate_workloads_batch(
        ws, "haswell-ep", params=SimParams(fma_sustained_eff=1.0))
    np.testing.assert_array_equal(with_eff, without)


# ---------------------------------------------------------------------------
# 5. The bench-regression gate
# ---------------------------------------------------------------------------


def _load_check_bench():
    path = Path(__file__).parent.parent / "tools" / "check_bench.py"
    spec = importlib.util.spec_from_file_location("check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


MINI_COMPUTE = {
    "schema": 2, "suite": "compute", "machine": "haswell-ep",
    "matmul": {
        "dims": [64, 64, 64],
        "ecm": {"levels": ["L1", "L2", "L3", "Mem"],
                "input_notation": "{64 || 43 | 1 | 2 | 3}",
                "predictions": [64.0, 64.0, 64.0, 64.0],
                "t_ol": 64.0, "t_nol": 43.0, "core_bound": True},
        "blocking": {"ranked": [{"block": [64, 64, 64], "t_ecm": 64.0,
                                 "core_bound": True, "mem_lines": 4.0,
                                 "speedup_vs_min_block": 1.0}],
                     "best": {"block": [64, 64, 64]}},
    },
    "attention": {
        "dims": [64, 64, 16], "causal": True,
        "ecm": {"levels": ["L1", "L2", "L3", "Mem"],
                "input_notation": "{a}", "predictions": [1.0, 2.0, 3.0, 4.0],
                "t_ol": 1.0, "t_nol": 0.5, "core_bound": False},
        "blocking": {"ranked": [{"block": [64, 64], "t_ecm": 4.0,
                                 "fits": True, "core_bound": False,
                                 "tile_bytes": 1024}],
                     "best": {"block": [64, 64]}},
    },
    "kernels": {
        "matmul": {"shape": [64, 64, 64], "block": [64, 64, 64],
                   "max_abs_err": 0.0, "matches_ref": True, "wall_s": 0.1},
        "attention": {"shape": [1, 64, 1, 16], "block": [64, 64],
                      "max_abs_err": 0.0, "matches_ref": True,
                      "wall_s": 0.1},
    },
}


def test_check_bench_gate_passes_and_fails_on_drift(tmp_path, capsys):
    cb = _load_check_bench()
    base = tmp_path / "base.json"
    base.write_text(json.dumps(MINI_COMPUTE))

    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps(MINI_COMPUTE))
    assert cb.main([str(fresh), "--compare", str(base)]) == 0

    # wall-clock drift is volatile: ignored at any magnitude
    noisy = json.loads(json.dumps(MINI_COMPUTE))
    noisy["kernels"]["matmul"]["wall_s"] *= 50
    fresh.write_text(json.dumps(noisy))
    assert cb.main([str(fresh), "--compare", str(base)]) == 0

    # >rtol model-prediction drift fails the gate
    drift = json.loads(json.dumps(MINI_COMPUTE))
    drift["matmul"]["ecm"]["predictions"][3] *= 1.2
    fresh.write_text(json.dumps(drift))
    assert cb.main([str(fresh), "--compare", str(base), "--rtol",
                    "0.05"]) == 1
    assert "predictions[3]" in capsys.readouterr().err

    # ...unless the tolerance allows it
    fresh.write_text(json.dumps(drift))
    assert cb.main([str(fresh), "--compare", str(base), "--rtol",
                    "0.5"]) == 0


def test_check_bench_gate_catches_missing_fields(tmp_path, capsys):
    cb = _load_check_bench()
    base = tmp_path / "base.json"
    base.write_text(json.dumps(MINI_COMPUTE))
    dropped = json.loads(json.dumps(MINI_COMPUTE))
    del dropped["matmul"]["blocking"]["ranked"][0]["mem_lines"]
    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps(dropped))
    assert cb.main([str(fresh), "--compare", str(base)]) == 1
    assert "mem_lines" in capsys.readouterr().err


def test_check_bench_validates_compute_schema(tmp_path):
    cb = _load_check_bench()
    good = tmp_path / "BENCH_compute.json"
    good.write_text(json.dumps(MINI_COMPUTE))
    assert cb.main([str(good)]) == 0
    broken = json.loads(json.dumps(MINI_COMPUTE))
    del broken["matmul"]["ecm"]["predictions"]
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(broken))
    assert cb.main([str(bad)]) == 1
