"""Vectorized ECMBatch path == scalar ECMModel path, everywhere it's used:
model construction, Eq. 1 predictions, the simulator table, sweeps,
scaling and the autotuner ranking."""
import numpy as np
import pytest

from repro.core import BENCHMARKS, ECMBatch, benchmark_batch, haswell_ecm
from repro.core.autotune import (
    WorkloadSpec,
    candidates,
    estimate,
    estimate_batch,
    rank,
)
from repro.core.kernel_spec import PAPER_TABLE1_INPUTS
from repro.core.ecm import ECMModel
from repro.core.saturation import ScalingModel, batch_curve, batch_saturation
from repro.simcache import (
    scaling_batch,
    simulate_level,
    simulate_scaling,
    simulate_working_set,
    sweep,
    sweep_batch,
)

ALL = sorted(BENCHMARKS)


# ---------------------------------------------------------------------------
# construction + Eq. 1
# ---------------------------------------------------------------------------


def test_batch_construction_matches_scalar_bitwise():
    batch = benchmark_batch(ALL)
    for i, name in enumerate(batch.names):
        scalar = haswell_ecm(name)
        assert tuple(batch.transfers[i]) == scalar.transfers, name
        assert float(batch.t_ol[i]) == scalar.t_ol
        assert float(batch.t_nol[i]) == scalar.t_nol


@pytest.mark.parametrize("name", ALL)
def test_batch_predictions_match_scalar_1e12(name):
    batch = benchmark_batch(ALL)
    i = batch.names.index(name)
    scalar = haswell_ecm(name)
    np.testing.assert_allclose(batch.predictions()[i], scalar.predictions(),
                               rtol=0, atol=1e-12)
    # and through the scalar view
    view = batch.scalar(i)
    assert view.predictions() == scalar.predictions()
    assert view.name == name


def test_from_models_roundtrip():
    models = [haswell_ecm(n) for n in ALL]
    batch = ECMBatch.from_models(models)
    for i, m in enumerate(models):
        assert batch.scalar(i).predictions() == m.predictions()


def test_batch_performance_matches_scalar():
    batch = benchmark_batch(ALL)
    perf = batch.performance(8.0, "Mem", clock_hz=2.3e9)
    for i, name in enumerate(batch.names):
        want = haswell_ecm(name).performance(8.0, "Mem", clock_hz=2.3e9)
        assert perf[i] == pytest.approx(want, rel=1e-12)


def test_batch_shape_validation():
    with pytest.raises(ValueError):
        ECMBatch(t_ol=[1.0], t_nol=[1.0], transfers=[[1.0, 2.0]],
                 levels=("L1", "L2"))


# ---------------------------------------------------------------------------
# simulator: scalar APIs are views over the batch path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL)
def test_sweep_matches_pointwise(name):
    sizes = [2.0**k * 1024 for k in range(4, 18)]
    curve = dict(sweep(name, sizes))
    _, surface = sweep_batch([name], sizes)
    for j, s_ in enumerate(sizes):
        assert surface[0, j] == pytest.approx(
            simulate_working_set(name, s_), rel=0, abs=1e-12)
        assert curve[s_] == pytest.approx(surface[0, j], rel=0, abs=1e-12)


def test_levels_batch_matches_levels():
    from repro.simcache import simulate_levels_batch

    names, table = simulate_levels_batch(ALL)
    for i, n in enumerate(names):
        for lv in range(4):
            assert table[i, lv] == simulate_level(n, lv), (n, lv)


def test_scaling_batch_matches_scalar():
    names, p = scaling_batch(["ddot", "striad"], 14)
    for i, n in enumerate(names):
        want = simulate_scaling(n, 14)
        np.testing.assert_allclose(p[i], want, rtol=0, atol=1e-6)


def test_batch_curve_matches_scaling_model():
    batch = benchmark_batch(ALL)
    curves = batch_curve(batch, 14, work_per_unit=8.0, clock_hz=2.3e9)
    sats = batch_saturation(batch)
    for i, name in enumerate(batch.names):
        sm = ScalingModel.from_ecm(haswell_ecm(name))
        np.testing.assert_allclose(
            curves[i], sm.curve(14, 8.0, 2.3e9), rtol=1e-12)
        assert sats[i] == sm.n_saturation


# ---------------------------------------------------------------------------
# autotuner: batch ranking == scalar estimates
# ---------------------------------------------------------------------------


def test_estimate_batch_matches_scalar():
    w = WorkloadSpec(n_params=2_000_000_000, d_model=2048, n_layers=24,
                     global_batch=256, seq_len=4096)
    cands = candidates(256, w)
    b = estimate_batch(w, cands)
    for i, c in enumerate(cands):
        e = estimate(w, c)
        assert b["t_comp"][i] == pytest.approx(e.t_comp, rel=1e-12)
        assert b["t_hbm"][i] == pytest.approx(e.t_hbm, rel=1e-12)
        assert b["t_coll"][i] == pytest.approx(e.t_coll, rel=1e-12)
        assert b["t_ecm"][i] == pytest.approx(e.t_ecm, rel=1e-12)
        assert bool(b["fits"][i]) == e.fits


def test_rank_is_sorted_and_consistent():
    w = WorkloadSpec(n_params=9_000_000_000, d_model=4096, n_layers=40,
                     global_batch=1024, seq_len=4096)
    ranked = rank(w, 1024)
    ts = [e.t_ecm for e in ranked]
    assert ts == sorted(ts)
    for e in ranked[:5]:
        want = estimate(w, e.config)
        assert e.t_ecm == pytest.approx(want.t_ecm, rel=1e-12)


# ---------------------------------------------------------------------------
# §VII-E NT-store accounting regression (satellite: l2_streams reconcile)
# ---------------------------------------------------------------------------


def test_striad_nt_accounting_matches_paper_inputs():
    """NT stores cross the L1<->L2 interface (LFB drain) and the memory
    edge, but bypass L2<->L3 — the builder must reproduce the paper's
    stated striad_nt input {1 || 3 | 4 | 4 | 15.6} (§VII-E)."""
    spec = BENCHMARKS["striad_nt"]
    assert spec.l1_evict_streams == 1            # NT store leaves L1
    assert spec.l2_streams == spec.load_streams  # ...but never crosses L2<->L3
    assert spec.mem_streams == 3                 # ...and lands in memory
    model = haswell_ecm("striad_nt")
    paper = ECMModel.parse(PAPER_TABLE1_INPUTS["striad_nt"])
    assert model.t_nol == pytest.approx(paper.t_nol, abs=0.15)
    for got, want in zip(model.transfers, paper.transfers):
        assert got == pytest.approx(want, abs=0.15)
    # batch builder agrees with the same accounting
    batch = benchmark_batch(["striad_nt"])
    np.testing.assert_allclose(batch.transfers[0], model.transfers,
                               rtol=0, atol=1e-12)
