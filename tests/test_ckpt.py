"""Checkpoint substrate: atomicity, roundtrip, pruning, async."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (
    AsyncCheckpointer,
    CheckpointManager,
    latest_step,
    restore_tree,
    save_tree,
)
from repro.ckpt.checkpoint import list_steps, prune


def _tree(x=1.0):
    return {"params": {"w": jnp.full((4, 3), x), "b": jnp.zeros((3,))},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    root = str(tmp_path)
    t = _tree(2.5)
    save_tree(root, 10, t, metadata={"loss": 0.5})
    got, meta = restore_tree(root, 10, t)
    assert meta["loss"] == 0.5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_staging_never_visible(tmp_path):
    root = str(tmp_path)
    save_tree(root, 1, _tree())
    # plant a stale staging dir (simulated crash mid-save)
    stale = os.path.join(root, "step_00000002.tmp-999")
    os.makedirs(stale)
    assert list_steps(root) == [1]          # staging invisible
    save_tree(root, 3, _tree())             # next save GCs it
    assert not os.path.exists(stale)
    assert latest_step(root) == 3


def test_prune_keeps_last(tmp_path):
    root = str(tmp_path)
    for s in (1, 2, 3, 4):
        save_tree(root, s, _tree(float(s)))
    prune(root, keep_last=2)
    assert list_steps(root) == [3, 4]


def test_manager_interval(tmp_path):
    m = CheckpointManager(str(tmp_path), interval=5, keep_last=2)
    for s in range(1, 12):
        m.maybe_save(s, _tree(float(s)))
    assert list_steps(str(tmp_path)) == [5, 10]
    s, tree, meta = m.restore_latest(_tree())
    assert s == 10


def test_restore_corrupt_manifest_raises(tmp_path):
    root = str(tmp_path)
    save_tree(root, 1, _tree())
    with open(os.path.join(root, "step_00000001", "manifest.json"), "w") as f:
        f.write("{")
    with pytest.raises(json.JSONDecodeError):
        restore_tree(root, 1, _tree())


def test_async_checkpointer(tmp_path):
    ac = AsyncCheckpointer(str(tmp_path), keep_last=2)
    for s in (1, 2, 3):
        ac.submit(s, _tree(float(s)), metadata={"s": s})
    ac.close()
    assert list_steps(str(tmp_path)) == [2, 3]
    got, meta = restore_tree(str(tmp_path), 3, _tree())
    assert meta["s"] == 3
    assert float(np.asarray(got["params"]["w"])[0, 0]) == 3.0
