"""Unit tests for HLO resource extraction and the TPU-ECM model."""
import pytest

from repro.core.hlo import (
    CollectiveOp,
    HLOResources,
    _shape_bytes,
    parse_collectives,
)
from repro.core.tpu_ecm import MeshSpec, TPUStepECM, from_resources, saturation_chips

HLO_SAMPLE = """\
HloModule jit_f, is_scheduled=true

%region_0.0.clone (x: f32[], y: f32[]) -> f32[] {
  ROOT %add = f32[] add(%x, %y)
}

ENTRY %main {
  %p0 = bf16[8,64]{1,0} parameter(0)
  %ag = bf16[8,512]{1,0} all-gather(%p0), channel_id=3, replica_groups=[2,8]<=[16], dimensions={1}
  %all-reduce = f32[1024]{0} all-reduce(%x), channel_id=1, replica_groups=[4,2]<=[8], use_global_device_ids=true, to_apply=%region_0.0.clone
  %rs = f32[256]{0} reduce-scatter(%y), channel_id=2, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%region_0.0.clone
  %a2a = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%a, %b), channel_id=4, replica_groups={{0,1}}
  %cp-start = bf16[32]{0} collective-permute-start(%z), channel_id=5, source_target_pairs={{0,1},{1,0}}
  %cp-done = bf16[32]{0} collective-permute-done(%cp-start)
  %ar2-start = f32[64]{0} all-reduce-start(%w), channel_id=6, replica_groups=[1,8]<=[8]
  %ar2-done = f32[64]{0} all-reduce-done(%ar2-start)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[1024]{0}") == 4096
    assert _shape_bytes("bf16[8,64]{1,0}") == 1024
    assert _shape_bytes("(f32[16,16]{1,0}, f32[16,16]{1,0})") == 2048
    assert _shape_bytes("f32[]") == 4
    assert _shape_bytes("pred[8]") == 8


def test_parse_collectives_kinds_and_groups():
    ops = parse_collectives(HLO_SAMPLE, n_devices=16)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-reduce",
                     "all-to-all", "collective-permute", "reduce-scatter"]
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.out_bytes == 8 * 512 * 2
    assert ag.group_size == 8            # replica_groups=[2,8]
    ar = [o for o in ops if o.kind == "all-reduce"]
    assert {o.group_size for o in ar} == {2, 8}
    rs = next(o for o in ops if o.kind == "reduce-scatter")
    assert rs.group_size == 4            # {{0,1,2,3},{4,5,6,7}}
    a2a = next(o for o in ops if o.kind == "all-to-all")
    assert a2a.out_bytes == 2048 and a2a.group_size == 2
    cp = next(o for o in ops if o.kind == "collective-permute")
    assert cp.out_bytes == 64            # counted once (start only)


def test_wire_bytes_ring_multipliers():
    ar = CollectiveOp("all-reduce", out_bytes=100.0, group_size=4)
    assert ar.wire_bytes_per_chip == pytest.approx(2 * 0.75 * 100)
    ag = CollectiveOp("all-gather", out_bytes=100.0, group_size=4)
    assert ag.wire_bytes_per_chip == pytest.approx(0.75 * 100)
    cp = CollectiveOp("collective-permute", out_bytes=100.0, group_size=2)
    assert cp.wire_bytes_per_chip == 100.0


def test_real_jax_lowering_collectives():
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    if len(jax.devices()) != 1:
        pytest.skip("expects the default single-device test env")
    mesh = jax.make_mesh((1,), ("data",))
    f = lambda x: jnp.sum(x * 2.0)
    s = NamedSharding(mesh, P("data"))
    lowered = jax.jit(f, in_shardings=s).lower(
        jax.ShapeDtypeStruct((64,), jnp.float32))
    compiled = lowered.compile()
    from repro.core.hlo import analyze
    res = analyze(compiled, lowered, n_devices=1)
    assert res.flops > 0
    assert res.bytes_accessed > 0


def test_tpu_ecm_terms_and_dominance():
    res = HLOResources(flops=1e12, bytes_accessed=1e9)
    res.collectives = [CollectiveOp("all-reduce", out_bytes=2e8, group_size=16)]
    mesh = MeshSpec(shape=(16, 16), axes=("data", "model"), dcn_axes=())
    step = from_resources(res, mesh, flops_are_global=False, name="t")
    assert step.t_comp == pytest.approx(1e12 / 197e12)
    assert step.t_hbm == pytest.approx(1e9 / 819e9)
    # all-reduce wire bytes: 2*(15/16)*2e8 = 3.75e8 over 50GB/s
    assert step.t_ici == pytest.approx(3.75e8 / 50e9)
    assert step.dominant == "collective"
    assert step.t_roofline == pytest.approx(max(step.t_comp, step.t_hbm, step.t_ici))
    assert step.t_ecm >= step.t_roofline


def test_tpu_ecm_overlap_bounds():
    step = TPUStepECM(name="x", t_comp=1.0, t_hbm=0.5, t_ici=0.4,
                      exposed_ici_fraction=1.0, exposed_hbm_fraction=0.0)
    assert step.t_ecm == pytest.approx(1.4)     # compute + exposed ici
    full = TPUStepECM(name="x", t_comp=1.0, t_hbm=0.5, t_ici=0.4,
                      exposed_ici_fraction=0.0, exposed_hbm_fraction=0.0)
    assert full.t_ecm == pytest.approx(1.0)     # roofline limit


def test_multipod_dcn_split():
    res = HLOResources(flops=0.0, bytes_accessed=0.0)
    # group spanning both pods (512 chips)
    res.collectives = [CollectiveOp("all-reduce", out_bytes=1e9, group_size=512)]
    mesh = MeshSpec(shape=(2, 16, 16), axes=("pod", "data", "model"))
    step = from_resources(res, mesh, flops_are_global=False)
    assert step.t_dcn > 0
    # pod-local group: no DCN traffic
    res.collectives = [CollectiveOp("all-reduce", out_bytes=1e9, group_size=256)]
    step2 = from_resources(res, mesh, flops_are_global=False)
    assert step2.t_dcn == 0


def test_saturation_chips():
    step = TPUStepECM(name="x", t_comp=8.0, t_hbm=1.0, t_ici=2.0)
    assert saturation_chips(step, "collective") >= 1
