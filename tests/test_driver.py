"""Fault-tolerant driver: checkpoint-restart, determinism, stragglers."""
import time

import pytest

from repro.configs import get_arch
from repro.configs.base import ShapeSpec
from repro.data.arch_data import ArchSyntheticDataset
from repro.dist.sharding import get_profile
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig
from repro.optim.schedule import constant
from repro.train.driver import InjectedFailure, Trainer, TrainerConfig


def _mk(tmp_path, total_steps, hooks=None, interval=5, lr=1e-3):
    arch = get_arch("internlm2-1.8b", smoke=True)
    mesh = make_host_mesh(model=1)
    profile = get_profile(arch.profile)
    shape = ShapeSpec("t", seq_len=16, global_batch=2, kind="train")
    data = ArchSyntheticDataset(arch, shape, seed=3)
    cfg = TrainerConfig(total_steps=total_steps, ckpt_dir=str(tmp_path),
                        ckpt_interval=interval, straggler_factor=5.0)
    return Trainer(arch, data, mesh, profile, AdamWConfig(),
                   constant(lr), cfg, hooks=hooks)


def test_checkpoint_restart_bit_identical(tmp_path):
    """Crash at step 12, restart, final state equals an uninterrupted run."""
    # uninterrupted reference
    ref = _mk(tmp_path / "ref", 20)
    ref_out = ref.run()

    def crash(trainer, step, state):
        raise InjectedFailure(f"injected at {step}")

    broken = _mk(tmp_path / "ft", 20, hooks={12: crash})
    with pytest.raises(InjectedFailure):
        broken.run()
    # restart: a FRESH trainer (new process in real life) resumes from ckpt 10
    resumed = _mk(tmp_path / "ft", 20)
    out = resumed.run()
    assert len(out["losses"]) == 10                 # resumed from step 10
    assert out["final_loss"] == pytest.approx(ref_out["final_loss"],
                                              rel=1e-5)


def test_straggler_detection(tmp_path):
    def slow(trainer, step, state):
        time.sleep(1.2)

    t = _mk(tmp_path, 14, hooks={10: slow})
    # hook sleeps before the step; fold the sleep into the step wall-time
    orig_batch = t.dataset.batch

    def batch_with_sleep(step):
        if step == 10:
            time.sleep(1.0)
        return orig_batch(step)

    t.dataset.batch = batch_with_sleep
    out = t.run()
    assert 10 in out["stragglers"], out["stragglers"]


def test_loss_decreases_over_run(tmp_path):
    # fresh random batches per step make single-point loss comparisons pure
    # noise (sigma ~0.15 per batch); compare 5-step window means at a lr
    # where the trend dominates within 30 steps.
    t = _mk(tmp_path, 30, lr=1e-2)
    out = t.run()
    first = sum(out["losses"][:5]) / 5
    last = sum(out["losses"][-5:]) / 5
    assert last < first, (first, last, out["losses"])
