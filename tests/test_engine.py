"""Request-path engine (``repro.core.engine``): the precompiled lowering
table, its fingerprint/invalidation contract, the vectorized Eq. 1 fast
path, and incremental re-ranking.

The contract pinned here:

1. **Bit-identity** — a table-served row is byte-for-byte the row the
   reference single-workload path produces, for every (workload, machine)
   pair in the registry, and the Table I goldens in
   ``tests/golden_haswell_ecm.json`` hold through the table path.
2. **Invalidation** — re-registering a machine (a published calibration
   update) or a workload drops exactly the affected rows; a post-update
   table row equals a cold rebuild.  Rows of other machines survive.
3. **Incremental re-ranking** — ``prior`` + dirty-set re-ranks are
   *identical* (``==``) to full re-ranks, and the serving
   ``BucketModel``'s EWMA re-calibration refreshes buckets with zero
   table traffic.
"""
import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import BENCHMARKS, HASWELL_EP, MACHINES, StreamWorkload
from repro.core import engine
from repro.core.machine import register_machine
from repro.core.workload import (
    WORKLOADS,
    lower_many,
    register_workload,
    workload_registry,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = json.loads(
    (Path(__file__).parent / "golden_haswell_ecm.json").read_text())


# ---------------------------------------------------------------------------
# 1. Bit-identity of the table fast path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mname", sorted(MACHINES))
def test_table_rows_bit_identical_to_cold_lowering(mname):
    ws = list(workload_registry().values())
    m = MACHINES[mname]
    with engine.cache_disabled():
        cold = lower_many(ws, m, table=False)
    warm = lower_many(ws, m)
    # canonical() is an exact structural form (arrays -> raw bytes), so
    # form equality is byte-for-byte equality of every field
    assert engine.canonical(warm) == engine.canonical(cold)


@pytest.mark.parametrize("name", sorted(GOLDEN["stream"]))
def test_stream_goldens_hold_through_table(name):
    rec = GOLDEN["stream"][name]
    w = StreamWorkload(BENCHMARKS[name])
    bw = HASWELL_EP.measured_bw[name]
    lowered = lower_many([w], HASWELL_EP, sustained_bw=bw)
    preds = lowered.batch.predictions()[0]
    assert [float(p).hex() for p in preds] == rec["predictions"]


def test_table_hit_is_a_hit_and_arrays_are_frozen():
    w = next(iter(workload_registry().values()))
    tab = engine.lowered_table()
    first = tab.get(w, HASWELL_EP)
    before = dict(tab.stats)
    again = tab.get(w, HASWELL_EP)
    assert tab.stats["hits"] == before["hits"] + 1
    assert again is first
    for arr in (again.batch.transfers, again.l1_uops, again.mem_cy_per_line):
        assert not arr.flags.writeable
        with pytest.raises(ValueError):
            arr[...] = 0.0


def test_eq1_fast_path_bit_identical_and_backends():
    from repro.core.ecm import eq1_predictions

    lowered = lower_many(list(workload_registry().values()), HASWELL_EP)
    b = lowered.batch
    ref = b.predictions()
    via_fn = eq1_predictions(b.t_ol, b.t_nol, b.transfers)
    assert via_fn.tobytes() == ref.tobytes()
    assert engine.eq1_backend("numpy") is eq1_predictions
    jx = engine.eq1_backend("jax")
    if jx is not eq1_predictions:          # jax present: numeric mirror
        np.testing.assert_allclose(
            jx(b.t_ol, b.t_nol, b.transfers), ref, rtol=1e-6)
    with pytest.raises(ValueError):
        engine.eq1_backend("torch")


# ---------------------------------------------------------------------------
# 2. Invalidation contract
# ---------------------------------------------------------------------------


def test_register_machine_invalidates_only_that_machine():
    tab = engine.lowered_table()
    tab.build()                            # all pairs resident
    rows_before = len(tab)
    ws = list(workload_registry().values())
    original = MACHINES["haswell-ep"]
    bumped = dataclasses.replace(
        original, measured_bw={k: v * 1.25
                               for k, v in original.measured_bw.items()})
    tok_before = engine.cache_token("haswell-ep")
    sb_row = tab.get(ws[0], MACHINES["sandy-bridge-ep"])
    inv_before = tab.stats["invalidated"]
    try:
        register_machine(bumped)
        assert engine.cache_token("haswell-ep") != tok_before
        # every haswell row dropped (>= the registry's worth; autotuners
        # may have parked extra same-machine rows), no other machine's
        dropped = tab.stats["invalidated"] - inv_before
        assert dropped >= len(ws)
        assert len(tab) == rows_before - dropped
        assert tab.get(ws[0], MACHINES["sandy-bridge-ep"]) is sb_row
        warm = lower_many(ws, bumped)
        with engine.cache_disabled():
            cold = lower_many(ws, bumped, table=False)
        assert engine.canonical(warm) == engine.canonical(cold)
        # and the update is visible: memory-level T_ECM moved
        with engine.cache_disabled():
            old = lower_many(ws, original, table=False)
        assert warm.batch.prediction(-1).tobytes() \
            != old.batch.prediction(-1).tobytes()
    finally:
        register_machine(original)


def test_register_workload_invalidates_only_that_row():
    spec = dataclasses.replace(BENCHMARKS["striad"],
                               name="striad_test_engine")
    w = StreamWorkload(spec)
    tab = engine.lowered_table()
    try:
        register_workload(w)
        warm = lower_many([w], HASWELL_EP)
        rows_with = len(tab)
        register_workload(w)               # re-register: row must drop
        assert len(tab) == rows_with - 1
        rebuilt = lower_many([w], HASWELL_EP)
        assert engine.canonical(rebuilt) == engine.canonical(warm)
    finally:
        del WORKLOADS[w.name]
        engine._on_registry_change(w)


def test_simulator_level_memo_tracks_registry_generation():
    from repro.simcache import EVAL_COUNTERS, reset_counters, sweep_batch

    sizes = list(np.geomspace(16 * 1024, 64 * 1024 * 1024, 64))
    sweep_batch(("ddot",), sizes)          # populate
    reset_counters()
    sweep_batch(("ddot",), sizes)
    assert EVAL_COUNTERS["levels_cache_hits"] > 0
    original = MACHINES["haswell-ep"]
    try:
        register_machine(dataclasses.replace(original))
        reset_counters()
        sweep_batch(("ddot",), sizes)      # generation moved: cold again
        assert EVAL_COUNTERS["levels_cache_hits"] == 0
    finally:
        register_machine(original)


# ---------------------------------------------------------------------------
# 3. Incremental re-ranking + the serving BucketModel
# ---------------------------------------------------------------------------


def test_incremental_rank_workloads_identical_to_full():
    from repro.core.autotune import rank

    ws = list(workload_registry().values())
    full = rank(ws, "haswell-ep")
    assert rank(ws, "haswell-ep", prior=full, dirty=None) == full
    assert rank(ws, "haswell-ep", prior=full,
                dirty=("striad", "ddot")) == full
    assert rank(ws, "haswell-ep", prior=full,
                dirty=(0, len(ws) - 1)) == full


def test_incremental_rank_attention_blocks_identical_to_full():
    from repro.core.autotune import rank

    dims = (4096, 4096, 128)
    full = rank(dims, objective="attention")
    assert rank(dims, objective="attention", prior=full, dirty=()) == full
    dirty = tuple(tuple(r["block"]) for r in full[:3])
    assert rank(dims, objective="attention", prior=full,
                dirty=dirty) == full
    with pytest.raises(ValueError):
        rank(dims, objective="attention", prior=full[1:], dirty=())


def test_bucket_recalibration_refreshes_with_zero_table_traffic():
    from repro.serve.engine import BucketModel

    bm = BucketModel()
    before_calib = bm._decode_entry(1024)
    tab = engine.lowered_table()
    stats = dict(tab.stats)
    new_mult = bm.recalibrate("decode", 1024, 1.25)
    after = bm._decode_entry(1024)
    # refresh went through the incremental path: no table get at all
    assert tab.stats["hits"] == stats["hits"]
    assert tab.stats["misses"] == stats["misses"]
    assert new_mult != 1.0
    assert after["best_bkv"] == before_calib["best_bkv"]


def test_machine_recalibration_rebuilds_buckets_cold():
    from repro.serve.engine import BucketModel

    bm = BucketModel()
    ent = bm._decode_entry(1024)
    original = MACHINES[bm.machine.name]
    bumped = dataclasses.replace(
        original, measured_bw={k: v * 2.0
                               for k, v in original.measured_bw.items()})
    try:
        register_machine(bumped)
        ent2 = bm._decode_entry(1024)
        assert ent2["cy_per_cl"] != ent["cy_per_cl"]
    finally:
        register_machine(original)
        bm._decode_entry(1024)             # restore must also refresh


def test_zoo_sweep_matches_direct_scaling():
    from repro.core.scaling import scale_workloads

    out = engine.zoo_sweep(machines=["haswell-ep"])
    got = out["machines"]["haswell-ep"]
    ws = list(workload_registry().values())
    with engine.cache_disabled():
        cs = scale_workloads(lower_many(ws, "haswell-ep", table=False),
                             "haswell-ep")
    assert got["performance"].tobytes() == cs.performance().tobytes()
    assert got["n_sat_chip"].tobytes() == cs.n_saturation_chip().tobytes()
    assert out["points"] > 0


# ---------------------------------------------------------------------------
# 4. The --floor gate in tools/check_bench.py
# ---------------------------------------------------------------------------


def _check_bench(*argv, timeout=120):
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_bench.py"),
         *argv], env=env, cwd=ROOT, capture_output=True, text=True,
        timeout=timeout)


@pytest.fixture(scope="module")
def engine_artifact(tmp_path_factory):
    payload = {
        "schema": 2, "suite": "engine", "machine": "haswell-ep",
        "table": {"n_workloads": 14, "n_machines": 5, "rows": 70,
                  "zoo_t_ecm_mem_total_cy": 40870.0},
        "cold_lower": {"rows": 70, "wall_s": 0.005, "rows_per_s": 14000.0},
        "warm_eval": {"points": 92880, "iters": 5, "wall_s": 0.002,
                      "points_per_s": 46440000.0},
        "zoo_sweep": {"points": 4102, "machines": 5, "iters": 20,
                      "wall_s": 0.002, "sweeps_per_s": 10000.0},
        "rerank": {"n_candidates": 25, "n_dirty": 2, "full_wall_s": 0.01,
                   "incremental_wall_s": 0.001, "speedup": 10.0,
                   "identical": True},
        "zoo": {"haswell-ep": {}},
    }
    path = tmp_path_factory.mktemp("bench") / "BENCH_engine.json"
    path.write_text(json.dumps(payload))
    return path


def test_engine_artifact_passes_schema_and_floors(engine_artifact):
    r = _check_bench(str(engine_artifact),
                     "--floor", "engine.warm_eval.points_per_s=14000000",
                     "--floor", "engine.zoo_sweep.sweeps_per_s=1000")
    assert r.returncode == 0, r.stderr


def test_floor_fails_below_bound(engine_artifact):
    r = _check_bench(str(engine_artifact),
                     "--floor", "engine.warm_eval.points_per_s=1e12")
    assert r.returncode == 1
    assert "below floor" in r.stderr


def test_floor_requires_matching_suite_and_valid_syntax(engine_artifact):
    r = _check_bench(str(engine_artifact),
                     "--floor", "serve.warm_eval.points_per_s=1")
    assert r.returncode == 1 and "no artifact for suite 'serve'" in r.stderr
    assert "suites present: engine" in r.stderr
    r = _check_bench(str(engine_artifact), "--floor", "engine.warm_eval")
    assert r.returncode == 1 and "expected" in r.stderr
    r = _check_bench(str(engine_artifact),
                     "--floor", "engine.rerank.identical=1")
    assert r.returncode == 1 and "not a number" in r.stderr
