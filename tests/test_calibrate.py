"""Calibration runner, versioned machine files, and the disk cache.

Covers the PR-10 acceptance contracts:

* machine dict/file round-trips are bit-identical for every zoo machine,
  and the checked-in ``src/repro/machines/*.json`` files are golden pins
  of the registry constants;
* recalibrating a zoo machine snaps every field back to the registered
  prior (the emitted file reproduces golden predictions exactly), while
  a synthetically perturbed backend is recovered field-by-field with
  ``snap_rtol=0``;
* a warm disk cache serves the calibration report byte-identically with
  zero re-fitting and zero re-measurement, invalidates on
  ``register_machine``, and rejects corrupted / foreign-schema files as
  misses rather than crashes;
* warm ``tuned_blocks`` picks restore from disk with zero re-lowering;
* ``tools/check_bench.py`` validates the calibrate BENCH payload and
  pins the max fit residual and the zero-warm-refit invariants.
"""
import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core import calibrate as cal
from repro.core import diskcache
from repro.core.machine import (
    MACHINE_SCHEMA_VERSION,
    MACHINES,
    ChipPower,
    get_machine,
    load_machine_file,
    machine_from_dict,
    machine_to_dict,
    register_machine,
    resolve_machine,
    save_machine_file,
    zoo_machine_file,
)

ROOT = Path(__file__).resolve().parent.parent


@pytest.fixture
def cache_dir(tmp_path):
    prev = diskcache.set_cache_dir(tmp_path)
    diskcache.reset_counters()
    cal.reset_counters()
    yield tmp_path
    diskcache.restore_cache_dir(prev)


# ---------------------------------------------------------------------------
# machine dict / file round-trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(MACHINES))
def test_machine_dict_roundtrip_bit_identical(name):
    m = MACHINES[name]
    d = machine_to_dict(m)
    assert machine_from_dict(d) == m
    # and through an actual JSON encode/decode (tuples -> lists -> back)
    assert machine_from_dict(json.loads(json.dumps(d))) == m


@pytest.mark.parametrize("name", sorted(MACHINES))
def test_zoo_machine_files_are_golden_pins(name):
    path = zoo_machine_file(name)
    assert path.is_file(), f"missing checked-in machine file {path}"
    doc = json.loads(path.read_text())
    assert doc["schema"] == MACHINE_SCHEMA_VERSION
    assert doc["kind"] == "ecm-machine"
    loaded, prov = load_machine_file(path, with_provenance=True)
    assert loaded == MACHINES[name]
    assert loaded.name == name
    assert isinstance(prov.get("aliases"), list)


def test_save_load_roundtrip_with_provenance(tmp_path):
    m = MACHINES["haswell-ep"]
    path = tmp_path / "hsw.json"
    save_machine_file(m, path, provenance={"note": "test", "x": 1})
    loaded, prov = load_machine_file(path, with_provenance=True)
    assert loaded == m
    assert prov == {"note": "test", "x": 1}
    # saving the loaded model again is byte-identical (canonical emit)
    path2 = tmp_path / "hsw2.json"
    save_machine_file(loaded, path2, provenance={"note": "test", "x": 1})
    assert path.read_bytes() == path2.read_bytes()


def test_machine_from_dict_rejects_unknown_field():
    d = machine_to_dict(MACHINES["haswell-ep"])
    d["not_a_field"] = 1
    with pytest.raises(ValueError, match="unknown"):
        machine_from_dict(d)


def test_machine_from_dict_rejects_foreign_schema():
    doc = {"schema": 99, "kind": "ecm-machine",
           "machine": machine_to_dict(MACHINES["haswell-ep"])}
    with pytest.raises(ValueError, match="schema"):
        machine_from_dict(doc)


def test_machine_from_dict_rejects_unknown_ports_kind():
    d = machine_to_dict(MACHINES["haswell-ep"])
    d["ports"]["kind"] = "alien"
    with pytest.raises(ValueError, match="alien"):
        machine_from_dict(d)


def test_resolve_machine_accepts_name_path_and_dict(tmp_path):
    # registry name: plain passthrough
    assert resolve_machine("haswell-ep") is get_machine("haswell-ep")
    # file path: loaded and registered under the file's machine name
    m = dataclasses.replace(MACHINES["haswell-ep"],
                            name="test-resolve-machine")
    path = tmp_path / "m.json"
    save_machine_file(m, path)
    try:
        loaded = resolve_machine(str(path))
        assert loaded == m
        assert get_machine("test-resolve-machine") == m
        # dict: coerced through machine_from_dict
        assert resolve_machine(machine_to_dict(m)) == m
    finally:
        MACHINES.pop("test-resolve-machine", None)


# ---------------------------------------------------------------------------
# calibration: zoo snap-back + synthetic recovery
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(MACHINES))
def test_calibrate_zoo_machine_snaps_to_prior(name):
    r = cal.calibrate(name, use_cache=False)
    assert r.machine == MACHINES[name]          # bit-identical adoption
    assert all(f.snapped for f in r.fits)
    assert r.residual_max() <= cal.MAX_FIT_RESIDUAL
    assert r.base == name and not r.from_cache
    assert len(r.measurement_hash) == 64


def test_calibrate_report_save_reproduces_prior(tmp_path):
    r = cal.calibrate("haswell-ep", use_cache=False)
    path = r.save(tmp_path / "hsw.json")
    loaded, prov = load_machine_file(path, with_provenance=True)
    assert loaded == MACHINES["haswell-ep"]
    assert prov["calibrated_from"] == "haswell-ep"
    assert prov["measurement_hash"] == r.measurement_hash
    assert prov["residual_max"] == r.residual_max()
    assert len(prov["fits"]) == len(r.fits)


def test_calibrate_synthetic_recovery():
    """A perturbed backend (the "real" machine differs from the prior) is
    recovered field-by-field with snapping disabled — the onboarding
    path for a machine whose constants are unknown."""
    base = MACHINES["haswell-ep"]
    bw = dict(base.measured_bw)
    bw["copy"] *= 1.2
    bw["ddot"] *= 0.85
    caps = list(base.capacities)
    caps[1] *= 2
    truth = dataclasses.replace(
        base, measured_bw=bw, capacities=tuple(caps),
        power=ChipPower(idle_watts=40.0, static_per_core=0.7,
                        dyn_lin=0.2, dyn_quad=3.1))
    r = cal.calibrate("haswell-ep", backend=cal.SimcacheBackend(truth),
                      snap_rtol=0.0, use_cache=False)
    by_field = {f.field: f for f in r.fits}
    assert by_field["measured_bw[copy]"].adopted == \
        pytest.approx(bw["copy"], rel=1e-9)
    assert by_field["measured_bw[ddot]"].adopted == \
        pytest.approx(bw["ddot"], rel=1e-9)
    assert by_field["capacities[1]"].adopted == \
        pytest.approx(caps[1], rel=1e-3)
    assert by_field["power.idle_watts"].adopted == \
        pytest.approx(40.0, rel=1e-6)
    assert by_field["power.dyn_quad"].adopted == \
        pytest.approx(3.1, rel=1e-6)
    # untouched fields still match the prior exactly
    assert by_field["measured_bw[load]"].adopted == \
        pytest.approx(base.measured_bw["load"], rel=1e-9)


def test_calibrate_tpu_falls_back_to_forward_inversion():
    r = cal.calibrate("tpu-v5e", use_cache=False)
    assert r.machine == MACHINES["tpu-v5e"]
    assert any(f.field == "tpu.exposed_hbm_fraction" for f in r.fits)
    assert all(f.snapped for f in r.fits)


# ---------------------------------------------------------------------------
# disk cache: warm identity, invalidation, rejection
# ---------------------------------------------------------------------------


def test_calibrate_warm_cache_zero_refits(cache_dir, tmp_path):
    cold = cal.calibrate("haswell-ep")
    assert not cold.from_cache and cal.CAL_COUNTERS["fits"] > 0
    diskcache.clear_memo()                      # force the on-disk path
    cal.reset_counters()
    warm = cal.calibrate("haswell-ep")
    assert warm.from_cache
    assert cal.CAL_COUNTERS["fits"] == 0
    assert cal.CAL_COUNTERS["measurements"] == 0
    assert cal.CAL_COUNTERS["cache_hits"] == 1
    assert warm.machine == cold.machine
    assert warm.measurement_hash == cold.measurement_hash
    assert warm.fits == cold.fits
    # the emitted machine files are byte-identical cold vs warm
    p1, p2 = tmp_path / "cold.json", tmp_path / "warm.json"
    cold.save(p1)
    warm.save(p2)
    assert p1.read_bytes() == p2.read_bytes()


def test_diskcache_roundtrip_preserves_tuples(cache_dir):
    value = {"block": (128, 256), "ok": True, "t": 1.5}
    diskcache.put("t", ("k", 1), value, machine="haswell-ep")
    diskcache.clear_memo()
    hit = diskcache.get("t", ("k", 1), machine="haswell-ep")
    assert hit == value
    assert isinstance(hit["block"], tuple)


def test_diskcache_invalidated_by_register_machine(cache_dir):
    original = MACHINES["haswell-ep"]
    diskcache.put("t", ("k",), {"v": 1}, machine=original)
    assert diskcache.get("t", ("k",), machine=original) == {"v": 1}
    bumped = dataclasses.replace(
        original, measured_bw={k: v * 1.25
                               for k, v in original.measured_bw.items()})
    inv_before = diskcache.COUNTERS["invalidations"]
    try:
        register_machine(bumped)
        # the registry hook cleared the in-memory memo...
        assert diskcache.COUNTERS["invalidations"] > inv_before
        # ...and the new content fingerprint never matches the old entry
        assert diskcache.get("t", ("k",), machine=bumped) is None
    finally:
        register_machine(original)
    # the original machine's entry is still served (content-addressed)
    assert diskcache.get("t", ("k",), machine=original) == {"v": 1}


def test_diskcache_rejects_corrupted_file(cache_dir):
    path = diskcache.put("t", ("k",), {"v": 1}, machine="haswell-ep")
    path.write_text("{not json")
    diskcache.clear_memo()
    rej = diskcache.COUNTERS["rejected"]
    assert diskcache.get("t", ("k",), machine="haswell-ep") is None
    assert diskcache.COUNTERS["rejected"] == rej + 1


def test_diskcache_rejects_foreign_schema(cache_dir):
    path = diskcache.put("t", ("k",), {"v": 1}, machine="haswell-ep")
    doc = json.loads(path.read_text())
    doc["schema"] = diskcache.CACHE_SCHEMA + 1
    path.write_text(json.dumps(doc))
    diskcache.clear_memo()
    rej = diskcache.COUNTERS["rejected"]
    assert diskcache.get("t", ("k",), machine="haswell-ep") is None
    assert diskcache.COUNTERS["rejected"] == rej + 1


def test_diskcache_disabled_is_inert(tmp_path):
    prev = diskcache.set_cache_dir(None)
    try:
        assert not diskcache.enabled()
        assert diskcache.put("t", ("k",), {"v": 1}) is None
        assert diskcache.get("t", ("k",)) is None
    finally:
        diskcache.restore_cache_dir(prev)


def test_machine_fingerprint_tracks_content():
    m = MACHINES["haswell-ep"]
    fp = diskcache.machine_fingerprint(m)
    assert fp == diskcache.machine_fingerprint("haswell-ep")
    assert fp == diskcache.machine_fingerprint(dataclasses.replace(m))
    bumped = dataclasses.replace(m, cores=m.cores + 1)
    assert diskcache.machine_fingerprint(bumped) != fp


def test_tuned_blocks_warm_restart_zero_relowering(cache_dir):
    from repro.core import engine
    from repro.kernels.matmul.ops import tuned_blocks

    cold = tuned_blocks(512, 512, 512, machine="tpu-v5e")
    diskcache.clear_memo()                      # simulate a process restart
    tab = engine.lowered_table()
    stats_before = dict(tab.stats)
    warm = tuned_blocks(512, 512, 512, machine="tpu-v5e")
    assert warm == cold and isinstance(warm, tuple)
    assert dict(tab.stats) == stats_before      # zero lowering activity


# ---------------------------------------------------------------------------
# bench artifact: schema + spec agreement
# ---------------------------------------------------------------------------


def _run_check_bench(*argv, timeout=180):
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_bench.py"),
         *argv], env=env, cwd=ROOT, capture_output=True, text=True,
        timeout=timeout)


@pytest.fixture(scope="module")
def calibrate_bench_payload():
    from benchmarks.run import calibrate_payload

    return calibrate_payload()


def test_calibrate_payload_passes_check_bench(tmp_path,
                                              calibrate_bench_payload):
    path = tmp_path / "BENCH_calibrate.json"
    path.write_text(json.dumps(calibrate_bench_payload))
    r = _run_check_bench(str(path))
    assert r.returncode == 0, r.stdout + r.stderr


def test_check_bench_pins_fit_residual(tmp_path, calibrate_bench_payload):
    payload = json.loads(json.dumps(calibrate_bench_payload))
    payload["fit"]["residual_max"] = 0.5        # way past the gate
    path = tmp_path / "BENCH_calibrate.json"
    path.write_text(json.dumps(payload))
    r = _run_check_bench(str(path))
    assert r.returncode == 1
    assert "exceeds the calibration gate" in r.stderr


def test_check_bench_pins_zero_warm_refits(tmp_path,
                                           calibrate_bench_payload):
    payload = json.loads(json.dumps(calibrate_bench_payload))
    payload["cache"]["warm_fits"] = 3           # a re-fit leaked through
    path = tmp_path / "BENCH_calibrate.json"
    path.write_text(json.dumps(payload))
    r = _run_check_bench(str(path))
    assert r.returncode == 1
    assert "must not re-fit" in r.stderr


def test_check_bench_residual_gate_matches_calibrate():
    """The stdlib-only checker pins the bound by value; it must track
    ``repro.core.calibrate.MAX_FIT_RESIDUAL``."""
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "check_bench", os.path.join(ROOT, "tools", "check_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert mod.MAX_CALIBRATE_RESIDUAL == cal.MAX_FIT_RESIDUAL
    assert "calibrate" in mod.SUITES
    assert "calibrate" in mod.SPECS


def test_check_bench_floor_names_missing_suite(tmp_path,
                                               calibrate_bench_payload):
    """--floor against an absent suite must say which suite is missing
    and which suites were actually present (satellite: error clarity)."""
    path = tmp_path / "BENCH_calibrate.json"
    path.write_text(json.dumps(calibrate_bench_payload))
    r = _run_check_bench(str(path), "--floor", "engine.x.y=1")
    assert r.returncode == 1
    assert "no artifact for suite 'engine'" in r.stderr
    assert "suites present: calibrate" in r.stderr
    # an unknown suite name additionally gets the known-suite hint
    r2 = _run_check_bench(str(path), "--floor", "nosuch.x.y=1")
    assert r2.returncode == 1
    assert "not a known suite" in r2.stderr
