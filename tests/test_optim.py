"""Optimizer substrate: AdamW semantics + quantized moments (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    apply_updates,
    global_norm,
    opt_state_spec,
)
from repro.optim.schedule import constant, linear_warmup_cosine
from repro.models.common import ParamSpec, abstract


def _params():
    return {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]]),
            "b": jnp.zeros((2,))}


def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray(5.0)}
    cfg = AdamWConfig(weight_decay=0.0, grad_clip_norm=0.0)
    state = adamw_init(params, cfg)
    sched = constant(0.1)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw w^2
        upd, state, _ = adamw_update(grads, state, params, cfg, sched)
        params = apply_updates(params, upd)
    assert abs(float(params["w"])) < 0.5


@pytest.mark.parametrize("mdt", ["f32", "bf16", "int8"])
def test_moment_dtypes_agree_on_direction(mdt):
    params = _params()
    cfg = AdamWConfig(moment_dtype=mdt, weight_decay=0.0)
    state = adamw_init(params, cfg)
    grads = jax.tree.map(lambda p: jnp.ones_like(p), params)
    upd, state, _ = adamw_update(grads, state, params, cfg, constant(1e-2))
    for u in jax.tree.leaves(upd):
        assert np.all(np.asarray(u) < 0)        # positive grad -> negative step


def test_int8_moments_close_to_f32():
    params = {"w": jnp.linspace(-1, 1, 64).reshape(8, 8)}
    grads = {"w": jnp.ones((8, 8)) * 0.3}
    cfg32 = AdamWConfig(moment_dtype="f32", weight_decay=0.0)
    cfg8 = AdamWConfig(moment_dtype="int8", weight_decay=0.0)
    s32, s8 = adamw_init(params, cfg32), adamw_init(params, cfg8)
    p32 = p8 = params
    for _ in range(10):
        u32, s32, _ = adamw_update(grads, s32, p32, cfg32, constant(1e-2))
        u8, s8, _ = adamw_update(grads, s8, p8, cfg8, constant(1e-2))
        p32, p8 = apply_updates(p32, u32), apply_updates(p8, u8)
    np.testing.assert_allclose(np.asarray(p32["w"]), np.asarray(p8["w"]),
                               rtol=0.05, atol=5e-3)


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    cfg = AdamWConfig(grad_clip_norm=1.0, weight_decay=0.0)
    state = adamw_init(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(huge, state, params, cfg, constant(1.0))
    assert float(metrics["grad_norm"]) > 1e5     # reported pre-clip


def test_opt_state_spec_matches_init_structure():
    pspec = {"w": ParamSpec((8, 4), ("embed", "mlp")),
             "b": ParamSpec((4,), ("mlp",), init="zeros")}
    for mdt in ("f32", "bf16", "int8"):
        cfg = AdamWConfig(moment_dtype=mdt)
        params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), pspec,
                              is_leaf=lambda x: isinstance(x, ParamSpec))
        st_real = adamw_init(params, cfg)
        st_abs = abstract(opt_state_spec(pspec, cfg))
        real_flat = jax.tree.flatten(st_real)[1]
        abs_flat = jax.tree.flatten(st_abs)[1]
        assert str(real_flat) == str(abs_flat)
        for a, b in zip(jax.tree.leaves(st_real), jax.tree.leaves(st_abs)):
            assert a.shape == b.shape and a.dtype == b.dtype


@given(st.floats(1e-5, 1.0), st.integers(1, 50), st.integers(51, 500))
@settings(max_examples=20, deadline=None)
def test_schedule_properties(peak, warm, total):
    sched = linear_warmup_cosine(peak, warm, total)
    lrs = [float(sched(jnp.asarray(s))) for s in range(0, total, 7)]
    assert all(0 <= lr <= peak * (1 + 1e-6) for lr in lrs)
    # warmup is nondecreasing
    warm_lrs = [float(sched(jnp.asarray(s))) for s in range(warm)]
    assert all(b >= a - 1e-9 for a, b in zip(warm_lrs, warm_lrs[1:]))


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=8))
@settings(max_examples=30, deadline=None)
def test_global_norm_matches_numpy(xs):
    tree = {"x": jnp.asarray(xs, jnp.float32)}
    want = np.linalg.norm(np.asarray(xs, np.float32))
    got = float(global_norm(tree))
    assert got == pytest.approx(want, rel=1e-4, abs=1e-4)
