"""ECM-guided config selection: sanity of the analytic ranking."""

from repro.core.autotune import (
    CandidateConfig,
    WorkloadSpec,
    estimate,
    rank,
    recommend,
)


def _w(n_params=2e9, kind="train", batch=256):
    return WorkloadSpec(n_params=int(n_params), d_model=2048, n_layers=24,
                        global_batch=batch, seq_len=4096, kind=kind)


def test_recommend_is_feasible_and_best():
    w = _w()
    ranked = rank(w, 256)
    best = recommend(w, 256)
    assert best.summary() == ranked[0].summary()
    assert best.fits
    assert all(ranked[0].t_ecm <= e.t_ecm for e in ranked)


def test_small_model_prefers_data_parallelism():
    """A 125M model should want little/no tensor parallelism."""
    w = WorkloadSpec(n_params=125_000_000, d_model=768, n_layers=12,
                     global_batch=256, seq_len=4096)
    best = recommend(w, 256)
    assert best.config.model <= 2, best.summary()


def test_huge_model_wants_model_sharding():
    """At 111B the per-microbatch ZeRO weight stream makes pure DP lose
    badly to TP+FSDP (the estimator reproduces the qwen1.5-110b profile
    choice)."""
    w = WorkloadSpec(n_params=111_000_000_000, d_model=8192, n_layers=80,
                     global_batch=256, seq_len=4096)
    best = recommend(w, 256)
    assert best.config.model >= 8, best.summary()
    assert best.fits
    pure_dp = estimate(w, CandidateConfig(data=256, model=1, accum=16))
    assert pure_dp.t_ecm > 2 * best.t_ecm


def test_decode_estimates_memory_bound():
    """One-token decode is HBM-dominated at any mesh (the §Roofline
    observation, reproduced analytically)."""
    w = _w(kind="decode", batch=128)
    for e in rank(w, 256)[:3]:
        assert e.t_hbm > e.t_comp


def test_more_chips_never_worse():
    w = _w(n_params=9e9)
    t256 = recommend(w, 256).t_ecm
    t64 = recommend(w, 64).t_ecm
    assert t256 <= t64 * 1.05
