"""Registry chip-scaling + energy engine (``repro.core.scaling``).

Four guarantees pinned here:

1. **Golden Fig. 10 / Figs. 5-6 values** — the Haswell saturation points
   (CoD vs non-CoD) and the energy/EDP grid minima computed through the
   new registry path are bit-identical to the pre-refactor
   ``saturation.py`` / ``energy.py`` numbers captured in
   ``tests/golden_haswell_ecm.json``.
2. **Core-bound regression** — workloads whose shared-bottleneck term is
   zero (the compute-bound families at cache-resident sizes) report
   ``n_S = cores`` and scale linearly instead of raising
   ``ZeroDivisionError``.
3. **One engine, any machine** — the cross-zoo saturation table covers
   every registered workload on every registered machine, and
   ``rank(..., objective="edp")`` ranks the (workload x frequency x cores)
   surface under all three objectives.
4. **TPU Eq. 2 analogue** — ICI collective wire bytes act as the
   shared-bottleneck term of multi-chip data-parallel scaling.
"""
import importlib.util
import json
import math
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    get_machine,
    haswell_ecm,
    machine_names,
    saturation_table,
    scale_workloads,
    tpu_dp_scaling,
    workload_registry,
)
from repro.core.autotune import rank
from repro.core.ecm import ECMBatch, ECMModel
from repro.core.energy import FrequencyScaledECM, best_config, energy_grid
from repro.core.hlo import CollectiveOp, HLOResources
from repro.core.machine import HASWELL_CHIP_BW_NONCOD, ChipPower
from repro.core.saturation import (
    ScalingModel,
    batch_curve,
    batch_saturation,
)
from repro.core.scaling import fill_domains, frequency_scale
from repro.core.workload import StreamWorkload
from repro.core.kernel_spec import BENCHMARKS

GOLDEN = json.loads(
    (Path(__file__).parent / "golden_haswell_ecm.json").read_text())["scaling"]

FREQS = GOLDEN["freqs_ghz"]
WORK = float.fromhex(GOLDEN["work_units"])
FIG10 = ("ddot", "striad", "schoenauer")


# ---------------------------------------------------------------------------
# 1. Golden pins: Fig. 10 saturation + Figs. 5/6 energy minima
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def hsw_scaling():
    reg = workload_registry()
    return scale_workloads(list(reg.values()), "haswell-ep")


@pytest.mark.parametrize("kernel", FIG10)
def test_fig10_cod_saturation_pinned(hsw_scaling, kernel):
    """Registry CoD path: per-domain and per-chip Eq. 2 points, plus the
    cycle terms they derive from, bit-equal to the golden capture."""
    cs = hsw_scaling
    rec = GOLDEN["fig10"][kernel]
    i = cs.names.index(kernel)
    fi = int(np.argmin(np.abs(cs.f_ghz - cs.machine.nominal_ghz)))
    assert int(cs.n_saturation()[i, fi]) == rec["n_sat_domain"]
    assert int(cs.n_saturation_chip()[i, fi]) == rec["n_sat_chip"]
    assert float(cs.t_single[i, fi]).hex() == rec["t_single_cy"]
    assert float(cs.bottleneck[i, fi]).hex() == rec["bottleneck_cy"]


@pytest.mark.parametrize("kernel", FIG10)
def test_fig10_noncod_saturation_pinned(kernel):
    """Non-CoD mode (one big domain at the measured chip bandwidth)."""
    m = get_machine("haswell-ep")
    cs = scale_workloads(
        [StreamWorkload(BENCHMARKS[kernel])], m,
        sustained_bw=HASWELL_CHIP_BW_NONCOD[kernel],
        cores_per_domain=m.cores, n_domains=1)
    fi = int(np.argmin(np.abs(cs.f_ghz - m.nominal_ghz)))
    assert (int(cs.n_saturation()[0, fi])
            == GOLDEN["fig10"][kernel]["n_sat_noncod"])


@pytest.mark.parametrize("label,coupled", [("uncoupled", False),
                                           ("coupled", True)])
def test_energy_minima_bit_equal_to_pre_refactor(label, coupled):
    """The deprecated one-model view reproduces the pre-refactor grids
    exactly (it is now a thin wrapper over the batched engine)."""
    rec = GOLDEN["energy_one_domain"][label]
    fecm = FrequencyScaledECM(haswell_ecm("striad"), f_nominal_ghz=2.3,
                              bw_freq_coupled=coupled)
    g = energy_grid(fecm, ChipPower(), n_cores_max=14,
                    f_ghz_list=FREQS, total_work_units=WORK)
    f_e, n_e, e = best_config(g["energy_J"], FREQS)
    f_d, n_d, d = best_config(g["edp_Js"], FREQS)
    assert [f_e, n_e, float(e).hex()] == rec["best_energy"]
    assert [f_d, n_d, float(d).hex()] == rec["best_edp"]
    assert [float(x).hex() for x in g["energy_J"][0]] == rec["energy_row_1p2"]


def test_registry_one_domain_override_matches_deprecated_view():
    """scale_workloads with the one-domain topology override produces the
    same energy surface as the deprecated ``energy_grid`` — bit-identical,
    the acceptance bar of the refactor."""
    fecm = FrequencyScaledECM(haswell_ecm("striad"), f_nominal_ghz=2.3)
    g_old = energy_grid(fecm, ChipPower(), n_cores_max=14,
                        f_ghz_list=FREQS, total_work_units=WORK)
    cs = scale_workloads([workload_registry()["striad"]], "haswell-ep",
                         f_ghz=FREQS, cores_per_domain=14, n_domains=1)
    g_new = cs.energy(WORK)
    for k in ("energy_J", "edp_Js", "runtime_s"):
        assert np.array_equal(np.asarray(g_old[k]), g_new[k][0]), k


def test_registry_cod_energy_minima_pinned():
    """The domain-aware registry path (CoD: cores fill 7-core domains)
    has its own — pinned — optimum."""
    cs = scale_workloads([workload_registry()["striad"]], "haswell-ep")
    be = cs.best(WORK, objective="energy")[0]
    bd = cs.best(WORK, objective="edp")[0]
    rec = GOLDEN["energy_registry_cod"]
    assert [be["f_ghz"], be["n_cores"],
            float(be["energy_J"]).hex()] == rec["best_energy"]
    assert [bd["f_ghz"], bd["n_cores"],
            float(bd["edp_Js"]).hex()] == rec["best_edp"]


def test_frequency_scale_matches_scalar_rule():
    """Vectorized DVFS == the scalar FrequencyScaledECM rule, per point."""
    ecm = haswell_ecm("striad")
    batch = frequency_scale(ECMBatch.from_models([ecm]), FREQS,
                            f_nominal_ghz=2.3, bw_freq_coupled=True)
    for fi, f in enumerate(FREQS):
        scalar = FrequencyScaledECM(ecm, f_nominal_ghz=2.3,
                                    bw_freq_coupled=True).at_frequency(f)
        got = batch.scalar((0, fi))
        assert got.transfers == scalar.transfers
        assert got.t_ol == scalar.t_ol and got.t_nol == scalar.t_nol


# ---------------------------------------------------------------------------
# 2. Core-bound regression: zero bottleneck must not divide
# ---------------------------------------------------------------------------


def _core_bound_ecm():
    # in-core time dominates and the memory edge transfers nothing: the
    # cache-resident compute-bound shape
    return ECMModel(t_ol=64.0, t_nol=8.0, transfers=(2.0, 4.0, 0.0),
                    name="resident")


def test_scalar_scaling_model_core_bound_no_zero_division():
    m = ScalingModel.from_ecm(_core_bound_ecm(), cores=14)
    assert m.core_bound
    assert m.n_saturation == 14          # linear to the full chip
    # P(n) = n * P(1), exactly — no bandwidth ceiling anywhere
    p1 = m.performance(1)
    for n in (2, 7, 14):
        assert m.performance(n) == pytest.approx(n * p1)
    assert len(m.curve(14)) == 14


def test_scalar_scaling_model_core_bound_without_core_count():
    # unknown chip size: degrade to 1 (never 0, never a crash)
    assert ScalingModel.from_ecm(_core_bound_ecm()).n_saturation == 1


def test_batch_saturation_core_bound():
    batch = ECMBatch.from_models([_core_bound_ecm(), haswell_ecm("striad")])
    n = batch_saturation(batch, cores=14)
    assert n[0] == 14                    # core-bound: the full chip
    assert 1 <= n[1] < 14                # bandwidth-bound: Eq. 2
    # and the curve stays linear for the core-bound element
    p = batch_curve(batch, 14)
    assert p[0, -1] == pytest.approx(14 * p[0, 0])
    assert p[1, -1] < 14 * p[1, 0]


def test_registry_matmul_is_core_bound_full_chip(hsw_scaling):
    cs = hsw_scaling
    fi = int(np.argmin(np.abs(cs.f_ghz - cs.machine.nominal_ghz)))
    for name in ("matmul", "flash-attention"):
        i = cs.names.index(name)
        assert bool(cs.core_bound()[i, fi])
        assert int(cs.n_saturation_chip()[i, fi]) == cs.cores
        perf = cs.performance()[i, fi]
        assert perf[-1] == pytest.approx(cs.cores * perf[0])


def test_overlap_dominated_but_bandwidth_limited_not_core_bound():
    """A workload whose T_OL hides the whole transfer chain can still
    saturate the bus when its Eq. 2 point fits inside a domain:
    ``core_bound`` / ``n_saturation`` must agree with the
    ``performance()`` cap (regression: the flag used to claim linear
    scaling while the surface plateaued at 2 cores)."""
    from repro.core.machine import HASWELL_EP
    from repro.core.scaling import ChipScaling

    cs = ChipScaling(machine=HASWELL_EP, names=("ovl",),
                     f_ghz=np.asarray([2.3]),
                     t_single=np.asarray([[40.0]]),
                     bottleneck=np.asarray([[20.0]]),
                     t_ol=np.asarray([40.0]),
                     cores_per_domain=7, n_domains=2)
    assert not bool(cs.core_bound()[0, 0])
    assert int(cs.n_saturation()[0, 0]) == 2          # ceil(40/20)
    p = cs.performance()[0, 0]
    assert p[1] == pytest.approx(1 / 20)              # domain saturated...
    assert p[6] == pytest.approx(p[1])                # ...stays flat
    assert p[13] == pytest.approx(2 * p[1])           # second domain


def test_fill_domains_topology():
    # 2 domains x 7 cores, saturation at 2x single-core performance
    p = fill_domains(1.0, 2.0, 14, 7, 2)
    assert p[0] == 1.0 and p[1] == 2.0 and p[6] == 2.0   # domain 0 caps
    assert p[7] == 3.0 and p[8] == 4.0                   # domain 1 fills
    assert p[-1] == 4.0                                  # both saturated
    # non-CoD: one pool with the aggregate bandwidth
    q = fill_domains(1.0, 2.0, 14, 7, 2, fill_domains_first=False)
    assert q[3] == 4.0 and q[-1] == 4.0
    # no shared bottleneck: linear everywhere
    lin = fill_domains(1.0, np.inf, 14, 7, 2)
    assert list(lin) == list(range(1, 15))


# ---------------------------------------------------------------------------
# 3. Cross-zoo table + operating-point ranking
# ---------------------------------------------------------------------------


def test_saturation_table_covers_every_machine_and_workload():
    table = saturation_table()
    names = set(workload_registry())
    assert set(table) == set(machine_names())
    for mname, rows in table.items():
        m = get_machine(mname)
        assert set(rows) == names
        for w, rec in rows.items():
            assert 1 <= rec["n_sat_domain"] <= rec["n_sat_chip"] <= m.cores
        # compute-bound families never hit the shared bottleneck anywhere
        for w in ("matmul", "flash-attention"):
            assert rows[w]["core_bound"]
            assert rows[w]["n_sat_chip"] == m.cores


def test_rank_operating_points_objectives():
    ws = [workload_registry()[k] for k in FIG10]
    for objective, key in (("energy", "energy_J"), ("edp", "edp_Js"),
                           ("performance", "runtime_s")):
        pts = rank(ws, "haswell-ep", objective=objective,
                   total_work_units=WORK)
        assert len(pts) == 3 * len(FREQS) * 14
        values = [p["value"] for p in pts]
        assert values == sorted(values)
        assert all(p["value"] == p[key] for p in pts)
    top = rank(ws, "haswell-ep", objective="edp", total_work_units=WORK,
               top=5)
    assert len(top) == 5


def test_rank_unknown_objective():
    with pytest.raises(ValueError, match="unknown objective"):
        rank([workload_registry()["striad"]], "haswell-ep",
             objective="speed")


def test_machine_power_calibration_present():
    """Every registered machine carries §III-D calibration: a power model
    and a (possibly degenerate) DVFS grid."""
    for name in machine_names():
        m = get_machine(name)
        assert isinstance(m.power, ChipPower)
        grid = m.frequency_grid()
        assert grid and all(f > 0 for f in grid)
        assert m.power.watts(1, grid[0]) > 0
        # array broadcasting (the batched engine's form)
        w = m.power.watts(np.arange(1, 4), np.asarray(grid[0]))
        assert w.shape == (3,) and np.all(np.diff(w) > 0)


# ---------------------------------------------------------------------------
# 4. TPU Eq. 2 analogue: ICI collectives as the shared bottleneck
# ---------------------------------------------------------------------------


def _resources(with_collective=True):
    res = HLOResources()
    res.flops = 6.0e18 / 1e3
    res.bytes_accessed = 4.0e12
    if with_collective:
        res.collectives = [CollectiveOp(kind="all-reduce",
                                        out_bytes=4.0e9, group_size=1)]
    return res


def test_tpu_dp_scaling_saturates_on_ici_floor():
    out = tpu_dp_scaling(_resources(), chip_counts=(1, 2, 4, 8, 16, 32))
    assert out["t_ici_floor_us"] > 0
    assert out["n_saturation"] is not None and out["n_saturation"] >= 1
    # speedup grows monotonically but sub-linearly once the floor bites
    assert all(b > a for a, b in zip(out["speedup"], out["speedup"][1:]))
    eff = out["parallel_efficiency"]
    assert eff[0] == pytest.approx(1.0)
    assert all(b <= a + 1e-12 for a, b in zip(eff, eff[1:]))
    # the collective term approaches its ring floor from below
    assert out["t_ici_us"][-1] <= out["t_ici_floor_us"] + 1e-9


def test_tpu_dp_scaling_no_collectives_is_core_bound_case():
    out = tpu_dp_scaling(_resources(with_collective=False),
                         chip_counts=(1, 2, 4))
    assert out["n_saturation"] is None
    assert out["speedup"][-1] == pytest.approx(4.0)


def test_tpu_dp_scaling_fully_hidden_ici_never_saturates():
    """exposed_ici_fraction=0 hides the collective entirely: scaling is
    linear, so no finite saturation chip count must be reported."""
    out = tpu_dp_scaling(_resources(), chip_counts=(1, 2, 4),
                         exposed_ici_fraction=0.0)
    assert out["n_saturation"] is None


# ---------------------------------------------------------------------------
# 5. check_bench: the scaling suite schema + strict unknown suites
# ---------------------------------------------------------------------------


def _load_check_bench():
    path = Path(__file__).parent.parent / "tools" / "check_bench.py"
    spec = importlib.util.spec_from_file_location("check_bench_scaling",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _scaling_artifact():
    from benchmarks.run import scaling_payload

    return scaling_payload("haswell-ep")


@pytest.fixture(scope="module")
def scaling_artifact():
    return _scaling_artifact()


def test_check_bench_accepts_scaling_artifact(tmp_path, scaling_artifact):
    cb = _load_check_bench()
    p = tmp_path / "BENCH_scaling.json"
    p.write_text(json.dumps(scaling_artifact))
    assert cb.check_file(p) == []


def test_check_bench_rejects_unrecognized_suite(tmp_path):
    """An unknown suite name is a hard failure, never a silent pass."""
    cb = _load_check_bench()
    p = tmp_path / "BENCH_mystery.json"
    p.write_text(json.dumps({"schema": 2, "suite": "mystery",
                             "machine": "haswell-ep"}))
    problems = cb.check_file(p)
    assert problems and "unrecognized suite" in problems[0]
    assert cb.main([str(p)]) == 1


def test_check_bench_compare_rejects_suite_mismatch(tmp_path,
                                                    scaling_artifact):
    cb = _load_check_bench()
    new = tmp_path / "BENCH_scaling.json"
    new.write_text(json.dumps(scaling_artifact))
    base = tmp_path / "BENCH_tpu.json"
    base.write_text(json.dumps({"schema": 2, "suite": "tpu",
                                "machine": "tpu-v5e",
                                "pipeline": {"kernels": {}}, "zoo": {}}))
    problems = cb.compare_files(new, base, rtol=0.05)
    assert problems and "suite mismatch" in problems[0]


def test_check_bench_gate_catches_saturation_drift(tmp_path,
                                                   scaling_artifact):
    cb = _load_check_bench()
    base = tmp_path / "base.json"
    base.write_text(json.dumps(scaling_artifact))
    drifted = json.loads(json.dumps(scaling_artifact))
    drifted["saturation"]["workloads"]["striad"]["n_sat_chip"] += 2
    new = tmp_path / "BENCH_scaling.json"
    new.write_text(json.dumps(drifted))
    problems = cb.compare_files(new, base, rtol=0.05)
    assert any("n_sat_chip" in p for p in problems)
    # identical artifacts are clean
    assert cb.compare_files(base, base, rtol=0.05) == []


def test_scaling_payload_deterministic(scaling_artifact):
    """The artifact the CI gate diffs carries no wall-clock fields: two
    builds in one process are byte-identical."""
    a = json.dumps(scaling_artifact, sort_keys=True)
    b = json.dumps(_scaling_artifact(), sort_keys=True)
    assert a == b


def test_dp_saturation_consistent_with_floor():
    """n_S follows the Eq. 2 form against the exposed ICI floor."""
    out = tpu_dp_scaling(_resources(), chip_counts=(1,))
    t1 = out["t_step_us"][0]
    floor = out["t_ici_floor_us"]
    from repro.core.machine import TPU_V5E

    expected = max(1, math.ceil(
        t1 / (TPU_V5E.exposed_ici_fraction * floor)))
    assert out["n_saturation"] == expected
