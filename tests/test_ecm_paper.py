"""Paper-faithfulness tests: reproduce Table I and §V/§VII numbers exactly.

These tests pin the ECM core to the paper's own published values; they are
the reproduction baseline everything else builds on.
"""

import pytest

from repro.core import (
    HASWELL_EP,
    PAPER_TABLE1_INPUTS,
    PAPER_TABLE1_MEASUREMENTS,
    PAPER_TABLE1_PREDICTIONS,
    ECMModel,
    ScalingModel,
    haswell_ecm,
    parse_prediction,
)

#: Display rounding used by the paper is 1 decimal; the paper itself rounds
#: intermediates (e.g. 6.2 cy/CL -> 12.5 for two lines), so allow 0.15 cy.
TOL = 0.15


@pytest.mark.parametrize("name", sorted(PAPER_TABLE1_PREDICTIONS))
def test_table1_predictions(name):
    """ECM predictions match Table I (and §VII-E for the NT variants)."""
    model = haswell_ecm(name)
    expected = PAPER_TABLE1_PREDICTIONS[name]
    got = model.predictions()
    assert len(got) == len(expected)
    for g, e in zip(got, expected):
        assert g == pytest.approx(e, abs=TOL), (
            f"{name}: predicted {model.prediction_notation()} "
            f"vs paper {expected}"
        )


@pytest.mark.parametrize("name", sorted(PAPER_TABLE1_INPUTS))
def test_table1_model_inputs(name):
    """The §IV-C construction recipe reproduces the paper's stated inputs.

    Exception (documented in DESIGN.md §8): the paper states T_OL=2 for
    `update` via a pairing argument; the port model gives 1 cy (two AVX muls
    on ports 0/1).  Predictions are identical at every level.
    """
    model = haswell_ecm(name)
    paper = ECMModel.parse(PAPER_TABLE1_INPUTS[name])
    assert model.t_nol == pytest.approx(paper.t_nol, abs=TOL)
    for g, e in zip(model.transfers, paper.transfers):
        assert g == pytest.approx(e, abs=TOL)
    if name != "update":
        assert model.t_ol == pytest.approx(paper.t_ol, abs=TOL)
    else:
        assert model.predictions() == pytest.approx(
            paper.predictions(), abs=TOL)


def test_notation_roundtrip():
    m = haswell_ecm("ddot")
    s = m.notation()
    p = ECMModel.parse(s)
    assert p.predictions() == pytest.approx(m.predictions(), abs=0.05)


def test_prediction_notation_format():
    m = haswell_ecm("load")
    assert m.prediction_notation() == "{2 ] 2 ] 4 ] 8.5}"
    assert parse_prediction("{2 ] 2 ] 4 ] 8.5}") == (2, 2, 4, 8.5)


def test_eq1_overlap_rule():
    """Worked example from §IV-A: {2 || 4 | 4 | 9} -> L2 = max(2, 4+4) = 8."""
    m = ECMModel(t_ol=2, t_nol=4, transfers=(4, 9), levels=("L1", "L2", "L3"))
    assert m.prediction("L1") == 4
    assert m.prediction("L2") == 8
    assert m.prediction("L3") == 17


def test_schoenauer_agu_optimization():
    """§VII-C: using the port-7 simple AGU + LEA trick, the eight addressing
    operations complete in three instead of four cycles."""
    naive = haswell_ecm("schoenauer")
    opt = haswell_ecm("schoenauer", optimized_agu=True)
    assert naive.t_nol == 4
    assert opt.t_nol == 3
    assert opt.prediction("L1") == 3


def test_nt_store_speedups_match_paper():
    """§VII-E: ECM-inferred speedups of exactly 1.42x (stream) / 1.32x
    (Schönauer) from non-temporal stores — beyond the roofline 1.33x/1.25x."""
    st, st_nt = haswell_ecm("striad"), haswell_ecm("striad_nt")
    sc, sc_nt = haswell_ecm("schoenauer"), haswell_ecm("schoenauer_nt")
    sp_st = st.prediction("Mem") / st_nt.prediction("Mem")
    sp_sc = sc.prediction("Mem") / sc_nt.prediction("Mem")
    assert sp_st == pytest.approx(1.42, abs=0.01)
    assert sp_sc == pytest.approx(1.32, abs=0.01)
    # naive roofline (stream-count ratio) underpredicts
    assert 4 / 3 < sp_st
    assert 5 / 4 < sp_sc


def test_measurement_error_bands():
    """Model error vs the paper's measured values stays inside Table I's
    reported error column (max 33%, on copy/L2)."""
    for name, meas in PAPER_TABLE1_MEASUREMENTS.items():
        model = haswell_ecm(name)
        for lvl, (g, m) in enumerate(zip(model.predictions(), meas)):
            err = abs(g - m) / m
            assert err <= 0.34, f"{name} level {lvl}: error {err:.0%}"


def test_saturation_point_eq2():
    """Eq. 2 on the ddot model: n_S = ceil(17.1 / 9.1) = 2 per memory domain
    (the light-speed bound; measured saturation in Fig. 10 is later)."""
    scal = ScalingModel.from_ecm(haswell_ecm("ddot"))
    assert scal.n_saturation == 2
    # per-domain saturated performance: 8 updates per CL / T_L3Mem cycles
    mups = scal.performance(7, work_per_unit=8, clock_hz=HASWELL_EP.clock_hz)
    # paper Fig. 10: one domain saturates slightly above 2000 MUp/s
    assert mups == pytest.approx(2.02e9, rel=0.02)


def test_scaling_monotone_and_saturating():
    scal = ScalingModel.from_ecm(haswell_ecm("striad"))
    curve = scal.curve(14)
    assert all(b >= a - 1e-12 for a, b in zip(curve, curve[1:]))
    assert curve[-1] == pytest.approx(curve[scal.n_saturation - 1], rel=1e-9)
