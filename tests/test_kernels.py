"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes, block sizes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.attention import ops as att_ops, ref as att_ref
from repro.kernels.matmul import ops as mm_ops, ref as mm_ref
from repro.kernels.stream import ops as st_ops, ref as st_ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
           dict(rtol=1e-4, atol=1e-5)


def _arr(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype=dtype)


STREAM_SIZES = [1024, 8192, 1024 * 33]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("n", STREAM_SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("block_rows", [8, 64])
def test_stream_elementwise_kernels(n, dtype, block_rows):
    if (n // 128) % block_rows:
        pytest.skip("rows not divisible by block")
    a, b, c, d = (_arr((n,), dtype) for _ in range(4))
    s = 1.5
    tol = _tol(dtype)
    np.testing.assert_allclose(
        st_ops.copy(b, block_rows=block_rows, interpret=True), st_ref.copy(b), **tol)
    np.testing.assert_allclose(
        np.asarray(st_ops.store(s, (n,), dtype, block_rows=block_rows,
                                interpret=True), dtype=np.float32),
        np.asarray(st_ref.store(s, (n,), dtype), dtype=np.float32), **tol)
    np.testing.assert_allclose(
        np.asarray(st_ops.update(s, a, block_rows=block_rows, interpret=True),
                   dtype=np.float32),
        np.asarray(st_ref.update(s, a), dtype=np.float32), **tol)
    np.testing.assert_allclose(
        np.asarray(st_ops.striad(s, b, c, block_rows=block_rows,
                                 interpret=True), dtype=np.float32),
        np.asarray(st_ref.striad(s, b, c), dtype=np.float32), **tol)
    np.testing.assert_allclose(
        np.asarray(st_ops.schoenauer(b, c, d, block_rows=block_rows,
                                     interpret=True), dtype=np.float32),
        np.asarray(st_ref.schoenauer(b, c, d), dtype=np.float32), **tol)


@pytest.mark.parametrize("n", STREAM_SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_stream_reduction_kernels(n, dtype):
    a, b = _arr((n,), dtype), _arr((n,), dtype)
    # sums of ~N(0,1) cancel towards 0, so a pure rtol is meaningless:
    # scale atol with sqrt(n) (the expected magnitude of the sum).
    atol = 1e-2 * n ** 0.5 if dtype == jnp.bfloat16 else 1e-3 * n ** 0.5
    got = st_ops.load(a, interpret=True)
    want = st_ref.load(a)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-3, atol=atol)
    got = st_ops.ddot(a, b, interpret=True)
    want = st_ref.ddot(a, b)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-3, atol=atol)


@pytest.mark.parametrize("shape", [(256, 256, 256), (512, 384, 640),
                                   (128, 128, 1024)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_matmul_kernel(shape, dtype):
    m, n, k = shape
    x, y = _arr((m, k), dtype), _arr((k, n), dtype)
    got = mm_ops.matmul(x, y, bm=128, bn=128, bk=128, interpret=True)
    want = mm_ref.matmul(x, y)
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want, dtype=np.float32),
        **(_tol(dtype) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-3)))


@pytest.mark.parametrize("dims", [(1, 256, 256, 4, 2, 64),
                                  (2, 512, 512, 8, 8, 64),
                                  (2, 256, 256, 8, 1, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(dims, causal):
    b, sq, sk, h, hkv, d = dims
    q = _arr((b, sq, h, d), jnp.float32)
    k = _arr((b, sk, hkv, d), jnp.float32)
    v = _arr((b, sk, hkv, d), jnp.float32)
    got = att_ops.flash_attention(q, k, v, causal=causal, bq=128, bk=128,
                                  interpret=True)
    rep = h // hkv
    kk = jnp.repeat(k, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vv = jnp.repeat(v, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    qq = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    want = att_ref.attention(qq, kk, vv, causal=causal)
    want = want.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_decode_shape():
    """Decode: q_len 1 against a long cache, non-causal."""
    q = _arr((2, 1, 8, 64), jnp.float32)
    # pad q_len to a block-multiple is the wrapper's caller's job in decode;
    # here we use bq=1 directly.
    k = _arr((2, 1024, 2, 64), jnp.float32)
    v = _arr((2, 1024, 2, 64), jnp.float32)
    got = att_ops.flash_attention(q, k, v, causal=False, bq=1, bk=256,
                                  interpret=True)
    qq = q.transpose(0, 2, 1, 3).reshape(16, 1, 64)
    kk = jnp.repeat(k, 4, 2).transpose(0, 2, 1, 3).reshape(16, 1024, 64)
    vv = jnp.repeat(v, 4, 2).transpose(0, 2, 1, 3).reshape(16, 1024, 64)
    want = att_ref.attention(qq, kk, vv, causal=False).reshape(2, 8, 1, 64)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want.transpose(0, 2, 1, 3)),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# property-based: kernels == oracle on arbitrary data (fixed shapes)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), s=st.floats(-4, 4, allow_nan=False))
def test_striad_property(seed, s):
    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.normal(size=(2048,)), dtype=jnp.float32)
    c = jnp.asarray(rng.normal(size=(2048,)), dtype=jnp.float32)
    got = st_ops.striad(s, b, c, interpret=True, block_rows=8)
    np.testing.assert_allclose(got, st_ref.striad(s, b, c), rtol=1e-4,
                               atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ddot_property(seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(4096,)), dtype=jnp.float32)
    b = jnp.asarray(rng.normal(size=(4096,)), dtype=jnp.float32)
    got = float(st_ops.ddot(a, b, interpret=True))
    np.testing.assert_allclose(got, float(st_ref.ddot(a, b)), rtol=1e-4)
