"""Simulator ("measurement" oracle) vs the paper's measured values."""
import pytest

from repro.core import (
    BENCHMARKS,
    HASWELL_EP,
    PAPER_TABLE1_MEASUREMENTS,
    haswell_ecm,
)
from repro.simcache import (
    simulate_level,
    simulate_scaling,
    simulate_working_set,
    sweep,
)


@pytest.mark.parametrize("name", sorted(PAPER_TABLE1_MEASUREMENTS))
def test_simulator_matches_paper_measurements(name):
    meas = PAPER_TABLE1_MEASUREMENTS[name]
    for lv in range(4):
        sim = simulate_level(name, lv)
        assert sim == pytest.approx(meas[lv], rel=0.12), (
            f"{name} level {lv}: sim {sim:.2f} vs paper {meas[lv]}"
        )


@pytest.mark.parametrize("name", sorted(PAPER_TABLE1_MEASUREMENTS))
def test_simulator_error_within_paper_error_band(name):
    """Model-vs-simulator error stays within Table I's model-vs-hardware
    error band (max 33%) — the simulator is a plausible hardware stand-in."""
    model = haswell_ecm(name)
    for lv in range(4):
        sim = simulate_level(name, lv)
        err = abs(model.prediction(lv) - sim) / sim
        assert err <= 0.35


def test_levels_are_monotone():
    for name in BENCHMARKS:
        vals = [simulate_level(name, lv) for lv in range(4)]
        assert vals == sorted(vals), name


def test_working_set_residence():
    tiny = simulate_working_set("ddot", 8 * 1024)
    huge = simulate_working_set("ddot", 512 * 1024 * 1024)
    assert tiny == pytest.approx(simulate_level("ddot", 0), rel=1e-6)
    assert huge == pytest.approx(simulate_level("ddot", 3), rel=0.02)


def test_sweep_monotone_nondecreasing():
    sizes = [2.0**k * 1024 for k in range(3, 18)]
    curve = sweep("striad", sizes)
    ys = [y for _, y in curve]
    assert all(b >= a - 1e-9 for a, b in zip(ys, ys[1:]))


def test_scaling_saturates_at_domain_bandwidth():
    """Fig. 10: ddot saturates slightly above 2000 MUp/s per memory domain,
    slightly above 4000 MUp/s per chip (both domains)."""
    curve = simulate_scaling("ddot", 14)
    spec = BENCHMARKS["ddot"]
    bpu = spec.mem_streams * 64 / 8            # 16 B per update
    p_domain = HASWELL_EP.measured_bw["ddot"] / bpu
    assert curve[-1] == pytest.approx(2 * p_domain, rel=1e-6)
    assert 3.9e9 < curve[-1] < 4.2e9
    # measured-style saturation is later than the light-speed Eq. 2 point
    assert curve[3] == pytest.approx(min(4 * curve[0], p_domain), rel=1e-6)


def test_cod_vs_noncod_same_peak():
    """Fig. 10: peak performance of CoD and non-CoD modes is nearly equal."""
    cod = simulate_scaling("striad", 14, fill_domains_first=True)
    noncod = simulate_scaling("striad", 14, fill_domains_first=False)
    assert cod[-1] == pytest.approx(noncod[-1], rel=0.05)
