"""Energy/EDP model (paper §III-D, Figs. 5/6): structural claims pinned."""
import pytest

from repro.core import haswell_ecm
from repro.core.energy import FrequencyScaledECM, best_config, energy_grid
from repro.core.machine import ChipPower

FREQS = [1.2, 1.6, 2.0, 2.3, 2.7, 3.0]
WORK = 10e9 / 3 / 64        # 10 GB striad dataset, CLs of the A array


def _grids(coupled: bool):
    fecm = FrequencyScaledECM(haswell_ecm("striad"), f_nominal_ghz=2.3,
                              bw_freq_coupled=coupled)
    return energy_grid(fecm, ChipPower(), n_cores_max=14,
                       f_ghz_list=FREQS, total_work_units=WORK)


def test_race_to_idle_not_optimal():
    """Max frequency + all cores is never the energy optimum."""
    g = _grids(False)
    f, n, _ = best_config(g["energy_J"], FREQS)
    assert (f, n) != (FREQS[-1], 14)


def test_haswell_energy_optimum_at_lowest_frequency():
    """BW frequency-independent => lowest frequency minimises energy."""
    g = _grids(False)
    f, _, _ = best_config(g["energy_J"], FREQS)
    assert f == FREQS[0]


def test_coupled_uarch_needs_higher_frequency():
    """SNB/IVB-style coupling pushes the optima to higher frequencies."""
    f_h, _, _ = best_config(_grids(False)["edp_Js"], FREQS)
    f_s, _, _ = best_config(_grids(True)["edp_Js"], FREQS)
    assert f_s > f_h


def test_haswell_beats_coupled_on_energy_and_edp():
    """Paper: 12-23% energy, 35-55% EDP improvement over SNB/IVB."""
    gh, gs = _grids(False), _grids(True)
    e_ratio = best_config(gs["energy_J"], FREQS)[2] / \
        best_config(gh["energy_J"], FREQS)[2]
    d_ratio = best_config(gs["edp_Js"], FREQS)[2] / \
        best_config(gh["edp_Js"], FREQS)[2]
    assert 1.05 < e_ratio < 1.35
    assert 1.15 < d_ratio < 1.65


def test_saturation_plateau():
    """Beyond bandwidth saturation, extra cores only add energy (Fig. 5)."""
    g = _grids(False)
    row = g["energy_J"][0]                     # 1.2 GHz
    t_row = g["runtime_s"][0]
    # runtime stops improving after some core count...
    assert t_row[13] == pytest.approx(t_row[7], rel=0.01)
    # ...while energy keeps growing
    assert row[13] > row[7]
