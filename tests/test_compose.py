"""Whole-model ECM composition: the config zoo as step-time predictions.

The deliverable of ``repro.core.compose`` is a *prediction claimed to
decompose and to match measurement*, so these tests pin it from every
side: golden Haswell step times (bit-exact hex floats) for a dense LM,
an MoE and a Mamba2 hybrid; finite/positive + decode-vs-prefill +
breakdown-sums-to-total invariants for every config x every registry
machine; bit-identity of a composed single-op model with the direct
``workload_batch`` lowering; monotonicity in layer count and hidden
size; no behavior drift when the serving engine's ``BucketModel`` is
sourced from composition; the dry-run ``--predict`` table (including
the previously-silent skipped cells); and the ``BENCH_compose.json``
schema/regression contract in ``tools/check_bench.py``.
"""
import json
import math
import os
import subprocess
import sys
from dataclasses import replace
from functools import lru_cache
from pathlib import Path

import pytest

from repro.configs import ARCH_NAMES, SHAPES, get_arch
from repro.core import compose
from repro.core.compose import (
    attention_op,
    compose_cycles,
    compose_ops,
    matmul_op,
    model_ops,
    overlap_alpha,
    predict_step,
)
from repro.core.machine import get_machine, machine_names
from repro.core.workload import (
    FLASH_ATTENTION_F32,
    MATMUL_F32,
    AttentionWorkload,
    MatmulWorkload,
    workload_batch,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

GOLDEN = json.loads(
    (Path(__file__).parent / "golden_haswell_ecm.json").read_text())

MACHINES = machine_names()
SEQ = 4096


@lru_cache(maxsize=None)
def _sp(name: str, machine: str) -> compose.StepPrediction:
    return predict_step(name, machine, batch=1, seq_len=SEQ, context=SEQ)


# ---------------------------------------------------------------------------
# 1. Invariants: every config x every machine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("machine", MACHINES)
@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prediction_finite_positive_and_decomposable(arch, machine):
    sp = _sp(arch, machine)
    assert sp.ops, "composition produced no ops"
    for ph in compose.PHASES:
        cy = sp.cycles(ph)
        assert math.isfinite(cy) and cy > 0, (ph, cy)
        assert sp.seconds(ph) == cy / sp.clock_hz
        assert sp.flops(ph) > 0 and sp.hbm_bytes(ph) > 0
        assert sp.dominant_op(ph)
    for o in sp.ops:
        assert math.isfinite(o.cycles) and o.cycles > 0, o.name
        assert o.cy_per_unit > 0 and o.units > 0 and o.count > 0, o.name


@pytest.mark.parametrize("machine", MACHINES)
@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_step_not_above_prefill_at_equal_context(arch, machine):
    sp = _sp(arch, machine)
    assert sp.cycles("decode") <= sp.cycles("prefill")


@pytest.mark.parametrize("machine", MACHINES)
@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_per_op_breakdown_sums_to_total_under_overlap_rule(arch, machine):
    """The phase total is exactly the machine's overlap rule applied to
    the per-op terms — nothing is lost or double-counted between the
    breakdown and the headline number."""
    sp = _sp(arch, machine)
    assert sp.alpha == overlap_alpha(machine)
    for ph in compose.PHASES:
        ops = sp.phase_ops(ph)
        recombined = compose_cycles([o.t_ol_cy for o in ops],
                                    [o.t_rest_cy for o in ops],
                                    [o.cycles for o in ops], sp.alpha)
        assert sp.cycles(ph) == recombined
        # per-layer groups partition the per-op serial cycles
        assert sum(sp.per_layer(ph).values()) == pytest.approx(
            sum(o.cycles for o in ops))
        if sp.alpha == 1.0:     # CPU rule: the serial sum *is* the total
            assert sp.cycles(ph) == pytest.approx(
                sum(o.cycles for o in ops))
        if sp.alpha == 0.0:     # TPU rule: Eq. 1 over the summed terms
            assert sp.cycles(ph) == pytest.approx(
                max(sum(o.t_ol_cy for o in ops),
                    sum(o.t_rest_cy for o in ops)))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_composed_flops_track_param_count_accounting(arch):
    """The op walk is validated against the *independent* parameter-tree
    accounting: composed prefill FLOPs must live in a calibrated band
    around 2 * n_active_params per token (embedding and the seq-quadratic
    attention term make the families sit on either side of exactly 2N;
    the upper edge is whisper-base, whose attention dominates its tiny
    parameter count at this sequence length)."""
    a = get_arch(arch)
    sp = _sp(arch, "tpu-v5e")
    ratio = sp.flops("prefill") / (2.0 * a.n_active_params * SEQ)
    assert 0.6 <= ratio <= 1.75, ratio


# ---------------------------------------------------------------------------
# 2. Golden Haswell pins (dense LM / MoE / Mamba2)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", sorted(GOLDEN["compose"]))
def test_composed_step_bit_equal_to_golden(arch):
    rec = GOLDEN["compose"][arch]
    sp = _sp(arch, "haswell-ep")
    assert sp.cycles("prefill").hex() == rec["prefill_cy"]
    assert sp.cycles("decode").hex() == rec["decode_cy"]
    assert len(sp.ops) == rec["n_ops"]


def test_golden_covers_dense_moe_and_mamba():
    pinned = set(GOLDEN["compose"])
    assert "internlm2-1.8b" in pinned          # dense LM
    assert "granite-moe-1b-a400m" in pinned    # MoE
    assert "zamba2-1.2b" in pinned             # Mamba2 hybrid


# ---------------------------------------------------------------------------
# 3. Property tests: single-op degeneration + monotonicity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("machine", MACHINES)
def test_single_op_composition_bit_identical_to_workload_batch(machine):
    """A one-op model *is* its workload: the composed per-unit time must
    equal the direct ``workload_batch`` lowering bit-for-bit, and the
    step total must be exactly (count x units x per-unit) under either
    overlap rule."""
    m = get_machine(machine)
    cases = [
        (matmul_op("mm", "l", "prefill", m=2048, n=2048, k=2048, count=7),
         MatmulWorkload(MATMUL_F32, m=2048, n=2048, k=2048)),
        (attention_op("att", "l", "decode", sq=1, skv=4096, d=128,
                      bq=1, bkv=512, count=32, causal=False),
         AttentionWorkload(FLASH_ATTENTION_F32, sq=1, skv=4096, d=128,
                           bq=1, bkv=512, causal=False)),
    ]
    for op, direct in cases:
        sp = compose_ops([op], machine)
        ref = float(workload_batch([direct], machine).predictions()[0, -1])
        rec = sp.ops[0]
        assert rec.cy_per_unit == ref                      # bit-identical
        scale = rec.count * op.units(m.line_bytes)
        assert sp.cycles(op.phase) == pytest.approx(ref * scale, rel=1e-12)


@pytest.mark.parametrize("machine", ["haswell-ep", "tpu-v5e"])
@pytest.mark.parametrize("knob", ["n_layers", "d_model"])
def test_composition_monotone_in_layers_and_hidden_size(machine, knob):
    cfg = get_arch("internlm2-1.8b").cfg
    big = replace(cfg, **{knob: 2 * getattr(cfg, knob)})
    for ph in compose.PHASES:
        small_cy = compose_ops(
            model_ops(cfg, ph, batch=1, seq_len=512), machine).cycles(ph)
        big_cy = compose_ops(
            model_ops(big, ph, batch=1, seq_len=512), machine).cycles(ph)
        assert big_cy > small_cy, (knob, ph)


def test_scale_model_feeds_eq2_engine():
    """A whole config's step runs through the same Eq. 2 machinery as a
    single kernel: memory-bound decode saturates a handful of cores,
    and the aggregate's single-core time is the pipelined composition
    of the op walk."""
    from repro.core.scaling import scale_model

    from repro.core.compose import model_lowered

    cs = scale_model("internlm2-1.8b", "haswell-ep", phase="decode",
                     batch=1, seq_len=SEQ)
    n_sat = int(cs.n_saturation()[0, -1])
    assert 1 <= n_sat <= cs.cores_per_domain
    assert not bool(cs.core_bound()[0, -1])     # decode GEMVs stream HBM

    lowered = model_lowered("internlm2-1.8b", "haswell-ep",
                            phase="decode", batch=1, seq_len=SEQ)
    sp = _sp("internlm2-1.8b", "haswell-ep")
    ops = sp.phase_ops("decode")
    pipelined = max(sum(o.t_ol_cy for o in ops),
                    sum(o.t_rest_cy for o in ops))
    assert float(lowered.batch.predictions()[0, -1]) == pytest.approx(
        pipelined, rel=1e-9)


# ---------------------------------------------------------------------------
# 4. Serving: composition-backed BucketModel, zero behavior drift
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("machine", ["tpu-v5e", "haswell-ep"])
def test_bucket_model_compose_source_bit_identical(machine):
    from repro.serve.engine import BucketModel

    direct = BucketModel(machine)
    composed = BucketModel(machine, source="compose")
    assert composed.source == "compose"
    for cb in (130, 1000, 3000):
        assert composed.decode_cy_per_token(cb) \
            == direct.decode_cy_per_token(cb)
        assert composed.prefill_cy(cb) == direct.prefill_cy(cb)


def test_bucket_model_rejects_unknown_source():
    from repro.serve.engine import BucketModel

    with pytest.raises(ValueError, match="source"):
        BucketModel("tpu-v5e", source="magic")


def test_compose_backed_engine_reproduces_pinned_recovery_sequence():
    """The PR-6 device-loss trajectory, byte-for-byte, with the brain's
    predictions sourced from whole-model composition — same requeues,
    same steps, same final device count."""
    from repro.serve import (
        EngineConfig,
        FaultInjector,
        ServeEngine,
        TraceConfig,
        fault_plan,
        synthetic_trace,
    )
    from repro.serve.policy import DegradationPolicy

    trace_cfg = TraceConfig(mean_interarrival_s=0.001)
    engine = ServeEngine(EngineConfig(seed=0, bucket_source="compose"),
                         degrade=DegradationPolicy(step_budget_s=0.001))
    summary = engine.run(synthetic_trace(trace_cfg, seed=0),
                         FaultInjector(fault_plan("device_loss")))
    seq = [(e["event"], e.get("rid"), e["step"])
           for e in engine.events("device_loss", "requeue", "fail")]
    assert seq == [("device_loss", None, 72),
                   ("requeue", 3, 72), ("requeue", 4, 72),
                   ("requeue", 7, 72), ("requeue", 8, 72)]
    assert summary["lost"] == 0
    assert summary["n_devices_final"] == 2

    baseline = ServeEngine(EngineConfig(seed=0),
                           degrade=DegradationPolicy(step_budget_s=0.001))
    base_summary = baseline.run(synthetic_trace(trace_cfg, seed=0),
                                FaultInjector(fault_plan("device_loss")))
    assert engine.log == baseline.log
    assert summary == base_summary


# ---------------------------------------------------------------------------
# 5. Dry-run: --predict table + surfaced skips
# ---------------------------------------------------------------------------


def _dryrun_mod():
    # importing repro.launch.dryrun pulls in jax with a forced device
    # count; the skip path and the predict table never touch devices
    from repro.launch import dryrun
    return dryrun


def test_run_cell_surfaces_skipped_cells(tmp_path, capsys):
    dryrun = _dryrun_mod()
    rec = dryrun.run_cell("internlm2-1.8b", "long_500k", multi_pod=False,
                          out=str(tmp_path))
    assert rec["status"] == "skipped"
    assert rec["reason"]
    out = capsys.readouterr().out
    assert "SKIPPED" in out and rec["reason"] in out


def test_predict_table_keeps_skipped_rows_and_flags_agreement(tmp_path):
    dryrun = _dryrun_mod()
    skipped = dryrun.run_cell("internlm2-1.8b", "long_500k",
                              multi_pod=False, out=str(tmp_path),
                              verbose=False)
    shape = SHAPES["decode_32k"]
    n_chips = 256
    pred = dryrun.composed_step_s("internlm2-1.8b", shape, n_chips)
    lo, hi = compose.DRYRUN_TOLERANCE
    ok_rec = {"arch": "internlm2-1.8b", "shape": "decode_32k",
              "mesh": "16x16", "status": "ok",
              "ecm": {"t_ecm_s": pred / (0.5 * (lo + hi))}}
    err_rec = {"arch": "glm4-9b", "shape": "train_4k", "mesh": "2x16x16",
               "status": "error", "error": "RESOURCE_EXHAUSTED: boom"}
    rows = dryrun.predict_table([skipped, ok_rec, err_rec])
    assert len(rows) == 3

    by_status = {r["status"]: r for r in rows}
    assert by_status["skipped"]["reason"] == skipped["reason"]
    assert by_status["error"]["reason"] == "RESOURCE_EXHAUSTED: boom"
    ok_row = by_status["ok"]
    assert ok_row["predicted_s"] == pytest.approx(pred)
    assert ok_row["agrees"] is True
    # a simulated time far outside the band must flip the flag
    bad = dict(ok_rec, ecm={"t_ecm_s": pred / (10 * hi)})
    assert dryrun.predict_table([bad])[0]["agrees"] is False

    table = dryrun.format_predict_table(rows)
    assert "SKIPPED" in table and "ERROR" in table
    assert skipped["reason"] in table


# ---------------------------------------------------------------------------
# 6. BENCH_compose.json: schema + regression-gate contract
# ---------------------------------------------------------------------------


def _check_bench(*argv, timeout=120):
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_bench.py"),
         *argv], env=env, cwd=ROOT, capture_output=True, text=True,
        timeout=timeout)


@pytest.fixture(scope="module")
def compose_artifact(tmp_path_factory):
    from benchmarks.run import compose_payload

    path = tmp_path_factory.mktemp("bench") / "BENCH_compose.json"
    path.write_text(json.dumps(compose_payload()))
    return path


def test_compose_payload_passes_check_bench(compose_artifact):
    r = _check_bench(str(compose_artifact))
    assert r.returncode == 0, r.stdout + r.stderr


def test_check_bench_rejects_decode_above_prefill(compose_artifact,
                                                  tmp_path):
    payload = json.loads(compose_artifact.read_text())
    name = next(iter(payload["models"]))
    payload["models"][name]["decode"]["predicted_cy"] = \
        2 * payload["models"][name]["prefill"]["predicted_cy"]
    path = tmp_path / "BENCH_compose.json"
    path.write_text(json.dumps(payload))
    r = _check_bench(str(path))
    assert r.returncode == 1
    assert "decode predicted_cy exceeds prefill" in r.stderr


def test_check_bench_gates_deterministic_compose_fields(compose_artifact,
                                                        tmp_path):
    # identical artifacts pass the gate; a drifted prediction fails it
    r = _check_bench(str(compose_artifact), "--compare",
                     str(compose_artifact))
    assert r.returncode == 0, r.stdout + r.stderr

    payload = json.loads(compose_artifact.read_text())
    name = next(iter(payload["models"]))
    payload["models"][name]["decode"]["predicted_cy"] *= 0.5
    drifted = tmp_path / "BENCH_compose.json"
    drifted.write_text(json.dumps(payload))
    r = _check_bench(str(drifted), "--compare", str(compose_artifact))
    assert r.returncode == 1
    assert "predicted_cy" in r.stderr


def test_check_bench_rejects_cross_suite_compare(compose_artifact):
    r = _check_bench(str(compose_artifact), "--compare",
                     os.path.join(ROOT, "BENCH_serve.json"))
    assert r.returncode == 1
    assert "suite mismatch" in r.stderr


def test_committed_compose_baseline_matches_model():
    """The committed ``BENCH_compose.json`` carries the *current* model's
    deterministic predictions (same gate CI applies on every PR)."""
    base = json.loads(
        (Path(ROOT) / "BENCH_compose.json").read_text())
    assert base["suite"] == "compose"
    for name, entry in base["models"].items():
        sp = _sp(name, base["machine"])
        assert entry["decode"]["predicted_cy"] == pytest.approx(
            sp.cycles("decode"), rel=1e-9), name
        assert entry["prefill"]["predicted_cy"] == pytest.approx(
            sp.cycles("prefill"), rel=1e-9), name
