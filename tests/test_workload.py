"""Unified workload/machine registry: round-trip, bit-equality pins and
hierarchy-routing semantics.

Three guarantees of the refactor are pinned here:

1. **Bit-equality on Haswell** — every Table I stream kernel and both
   stencils (several layer-condition regimes) produce *bit-identical*
   ECM models through the unified engine, against golden values captured
   from the pre-refactor builders (``tests/golden_haswell_ecm.json``).
2. **Registry round-trip** — every registered workload builds a valid
   model on every registered machine through the same single code path.
3. **Hierarchy routing** — the Skylake-SP victim L3 and the TPU's
   no-write-allocate hierarchy change the routed per-level *line counts*
   of the same logical workload (not merely the bandwidth numbers).
"""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    BENCHMARKS,
    HASWELL_EP,
    JACOBI2D,
    MACHINES,
    SKYLAKE_SP,
    TPU_V5E_HIERARCHY,
    TRIAD_UPDATE,
    StencilWorkload,
    StreamWorkload,
    fuse_chain,
    get_machine,
    haswell_ecm,
    machine_names,
    route_traffic,
    stencil_ecm,
    workload_batch,
    workload_ecm,
    workload_registry,
)
from repro.core.autotune import rank

GOLDEN = json.loads(
    (Path(__file__).parent / "golden_haswell_ecm.json").read_text())

STENCIL_CASES = {
    "jacobi2d": [(512,), (1024,), (8192,)],
    "jacobi3d": [(20, 20), (100, 100), (100, 500), (480, 480)],
}


# ---------------------------------------------------------------------------
# 1. Haswell predictions pinned bit-equal to the pre-refactor builders
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(GOLDEN["stream"]))
def test_stream_bit_equal_to_pre_refactor(name):
    rec = GOLDEN["stream"][name]
    m = haswell_ecm(name)
    assert m.t_ol.hex() == rec["t_ol"]
    assert m.t_nol.hex() == rec["t_nol"]
    assert [t.hex() for t in m.transfers] == rec["transfers"]
    assert [p.hex() for p in m.predictions()] == rec["predictions"]


@pytest.mark.parametrize("name,widths", [
    (n, w) for n, ws in STENCIL_CASES.items() for w in ws])
def test_stencil_bit_equal_to_pre_refactor(name, widths):
    key = "%s@%s" % (name, ",".join(map(str, widths)))
    rec = GOLDEN["stencil"][key]
    m = stencil_ecm(name, widths=widths)
    assert m.t_ol.hex() == rec["t_ol"]
    assert m.t_nol.hex() == rec["t_nol"]
    assert [t.hex() for t in m.transfers] == rec["transfers"]
    assert [p.hex() for p in m.predictions()] == rec["predictions"]


def test_blocked_stencil_bit_equal_to_pre_refactor():
    rec = GOLDEN["stencil"]["jacobi2d@8192@blk256"]
    m = stencil_ecm("jacobi2d", widths=(8192,), block=(256,))
    assert [p.hex() for p in m.predictions()] == rec["predictions"]


def test_engine_view_equals_spec_view_bitwise():
    """workload_ecm(StreamWorkload(spec)) == spec.ecm == batch element."""
    for name, spec in BENCHMARKS.items():
        bw = HASWELL_EP.measured_bw[name]
        via_engine = workload_ecm(StreamWorkload(spec), HASWELL_EP,
                                  sustained_bw=bw)
        via_spec = spec.ecm(HASWELL_EP, bw)
        assert via_engine.transfers == via_spec.transfers
        assert via_engine.t_ol == via_spec.t_ol
        assert via_engine.t_nol == via_spec.t_nol


# ---------------------------------------------------------------------------
# 2. Registry round-trip: every workload x every machine
# ---------------------------------------------------------------------------


def test_registry_is_populated():
    reg = workload_registry()
    assert set(BENCHMARKS).issubset(reg)
    assert {"triad_update", "jacobi2d", "jacobi3d",
            "matmul", "flash-attention"}.issubset(reg)
    assert {"haswell-ep", "sandy-bridge-ep", "broadwell-ep", "skylake-sp",
            "tpu-v5e"}.issubset(machine_names())
    # >= 3 machines beyond the original pair, incl. a non-inclusive LLC
    assert len(MACHINES) >= 5
    assert any(m.victim_l3 for m in MACHINES.values())


@pytest.mark.parametrize("machine", sorted(MACHINES))
def test_every_workload_builds_on_every_machine(machine):
    """The acceptance-criterion grid: one code path, valid shapes
    everywhere."""
    m = get_machine(machine)
    ws = list(workload_registry().values())
    batch = workload_batch(ws, m)
    levels = m.level_names()
    assert batch.levels == levels
    assert batch.transfers.shape[-1] == len(levels) - 1
    assert np.all(batch.transfers >= 0)
    assert np.all(batch.t_ol >= 0) and np.all(batch.t_nol >= 0)
    preds = batch.predictions()
    assert preds.shape == (len(batch), len(levels))
    # Eq. 1: predictions are monotone over levels and >= T_core
    assert np.all(np.diff(preds, axis=-1) >= -1e-12)
    assert np.all(preds[..., 0] >= batch.t_core - 1e-12)
    # every scalar view round-trips through ECMModel validation
    for i in range(len(batch)):
        sm = batch.scalar(i)
        assert len(sm.levels) == len(sm.transfers) + 1


@pytest.mark.parametrize("machine", sorted(set(MACHINES) - {"tpu-v5e"}))
def test_generic_simulator_covers_every_cpu_machine(machine):
    """The unified simulator consumes any lowered workload with no
    family-specific code."""
    from repro.simcache import simulate_workloads_batch

    names, table = simulate_workloads_batch(
        list(workload_registry().values()), machine)
    assert table.shape == (len(names), 4)
    assert np.all(table > 0)
    assert np.all(np.diff(table, axis=-1) >= -1e-9)


def test_no_per_family_branches_in_consumers():
    """The refactor's contract: simcache/sim.py and core/autotune.py
    contain no isinstance/per-family dispatch."""
    import repro.core.autotune as autotune
    import repro.simcache.sim as sim

    for mod in (sim, autotune):
        src = Path(mod.__file__).read_text()
        assert "isinstance(" not in src, mod.__name__


# ---------------------------------------------------------------------------
# 3. Hierarchy routing: victim L3 and no-write-allocate
# ---------------------------------------------------------------------------


def test_skylake_victim_l3_traffic_differs_from_inclusive():
    """Same logical workload, different per-level line counts: the SKX
    LLC edge carries victims outward and nothing inward."""
    w = StreamWorkload(BENCHMARKS["copy"])       # 1 load + 1 RFO + 1 WB
    hsw = route_traffic(HASWELL_EP, w.traffic(HASWELL_EP))
    skx = route_traffic(SKYLAKE_SP, w.traffic(SKYLAKE_SP))
    llc = len(HASWELL_EP.levels) - 1             # the L2<->L3 edge index
    # inclusive: loads + RFO inward, write-back outward
    assert hsw.load_lines[0, llc] == 2.0
    assert hsw.evict_lines[0, llc] == 1.0
    # victim: nothing inward; clean victim (the load) + dirty WB outward
    assert skx.load_lines[0, llc] == 0.0
    assert skx.evict_lines[0, llc] == 2.0
    # the memory edge is unchanged (same lines must cross to DRAM)
    assert skx.load_lines[0, -1] == hsw.load_lines[0, -1]
    assert skx.evict_lines[0, -1] == hsw.evict_lines[0, -1]


def test_skylake_stencil_lc_uses_its_own_capacities():
    """SKX's 1 MiB L2 holds layer conditions an HSW 256 KiB L2 breaks."""
    width = 8192                                  # 3 rows x 8 B = 192 KiB
    hsw = StencilWorkload(JACOBI2D, widths=(width,)).traffic(HASWELL_EP)
    skx = StencilWorkload(JACOBI2D, widths=(width,)).traffic(SKYLAKE_SP)
    assert hsw.loads[0, 1] == 3.0                 # broken in HSW L2
    assert skx.loads[0, 1] == 1.0                 # held in SKX L2


def test_tpu_no_write_allocate_routing():
    """Software-managed hierarchy: RFO vanishes, stores are NT streams —
    the paper's §VII-E store behaviour as a machine property."""
    w = StreamWorkload(BENCHMARKS["copy"])
    routed = route_traffic(TPU_V5E_HIERARCHY, w.traffic(TPU_V5E_HIERARCHY))
    # VREG<->VMEM edge: 1 load in, 1 NT store out (no RFO anywhere)
    assert routed.load_lines[0, 0] == 1.0
    assert routed.evict_lines[0, 0] == 1.0
    # HBM edge: 2 lines total, vs 3 on a write-allocate machine
    hsw = route_traffic(HASWELL_EP, w.traffic(HASWELL_EP))
    assert float(routed.mem_lines()[0]) == 2.0
    assert float(hsw.mem_lines()[0]) == 3.0


def test_nt_speedup_is_free_on_tpu():
    """striad and striad_nt collapse to the same model on the TPU (every
    store is already non-temporal)."""
    st = workload_ecm(StreamWorkload(BENCHMARKS["striad"]), "tpu-v5e")
    nt = workload_ecm(StreamWorkload(BENCHMARKS["striad_nt"]), "tpu-v5e")
    assert st.predictions() == nt.predictions()


# ---------------------------------------------------------------------------
# Calibration dedupe: the registry is the single source
# ---------------------------------------------------------------------------


def test_deprecated_bw_aliases_point_at_machine_calibration():
    import repro.core as core

    with pytest.warns(DeprecationWarning):
        hsw_bw = core.HASWELL_MEASURED_BW
    with pytest.warns(DeprecationWarning):
        stencil_bw = core.STENCIL_MEASURED_BW
    with pytest.warns(DeprecationWarning):
        caps = core.HASWELL_CAPACITIES
    for k, v in hsw_bw.items():
        assert HASWELL_EP.measured_bw[k] == v
    for k, v in stencil_bw.items():
        assert HASWELL_EP.measured_bw[k] == v
    assert caps == HASWELL_EP.capacities


def test_bw_lookup_chain():
    assert HASWELL_EP.sustained_bw("striad") == 27.1e9
    assert HASWELL_EP.sustained_bw("no-such-kernel", "_stream") == 27e9
    with pytest.raises(KeyError):
        HASWELL_EP.sustained_bw("no-such-kernel")
    assert HASWELL_EP.sustained_bw("no-such", default=1.0) == 1.0


def test_machine_aliases_resolve():
    assert get_machine("hsw") is HASWELL_EP
    assert get_machine("haswell-ep-2695v3") is HASWELL_EP
    assert get_machine(HASWELL_EP) is HASWELL_EP
    with pytest.raises(KeyError):
        get_machine("pentium-pro")


# ---------------------------------------------------------------------------
# Fused chains + generic ranking
# ---------------------------------------------------------------------------


def test_fused_chain_elides_intermediate_streams():
    assert TRIAD_UPDATE.loads_explicit == 2      # B, C
    assert TRIAD_UPDATE.stores == 1              # A only; T stays resident
    assert TRIAD_UPDATE.rfo == 1
    assert TRIAD_UPDATE.mem_streams == 4
    unfused = (BENCHMARKS["striad"].mem_streams
               + BENCHMARKS["update"].mem_streams)
    assert unfused == 6                          # striad 4 + update 2
    # ECM stream counting: fused chain beats the two-launch composition
    fused = TRIAD_UPDATE.ecm(HASWELL_EP,
                             HASWELL_EP.sustained_bw("triad_update"))
    st = haswell_ecm("striad")
    up = haswell_ecm("update")
    assert fused.prediction("Mem") < st.prediction("Mem") + up.prediction("Mem")


def test_fuse_chain_validates():
    with pytest.raises(ValueError):
        fuse_chain("bad", (BENCHMARKS["load"], BENCHMARKS["load"]),
                   internal=2)
    with pytest.raises(ValueError):   # NT intermediate cannot stay resident
        fuse_chain("bad_nt", (BENCHMARKS["striad_nt"], BENCHMARKS["update"]),
                   internal=1)


def test_fuse_chain_rfo_follows_the_arrays():
    """RFO accounting per fused link: copy∘copy collapses to a plain copy
    (1 load + 1 RFO + 1 WB), and the in-place `update` stage's store
    gains an RFO when its covering load is elided (triad_update)."""
    cc = fuse_chain("copy2", (BENCHMARKS["copy"], BENCHMARKS["copy"]),
                    internal=1)
    assert (cc.loads_explicit, cc.rfo, cc.stores) == (1, 1, 1)
    assert cc.mem_streams == BENCHMARKS["copy"].mem_streams == 3
    assert TRIAD_UPDATE.rfo == 1      # striad's T-RFO gone, A's RFO gained


def test_lower_many_rejects_mixed_hierarchies():
    from repro.core.tpu_ecm import TPUStepECM

    step = TPUStepECM(name="step", t_comp=1e-3, t_hbm=2e-3, t_ici=5e-4)
    with pytest.raises(ValueError, match="different hierarchies"):
        rank([StreamWorkload(BENCHMARKS["ddot"]),
              step.as_workload()], "haswell-ep")


def test_registry_seeding_survives_early_user_registration():
    """A user workload registered before first registry access must not
    suppress the shipped entries."""
    import repro.core.workload as wl

    saved, saved_flag = dict(wl.WORKLOADS), wl._REGISTRY_SEEDED
    try:
        wl.WORKLOADS.clear()
        wl._REGISTRY_SEEDED = False
        wl.register_workload(StreamWorkload(BENCHMARKS["ddot"]))
        reg = workload_registry()
        assert "striad" in reg and "jacobi2d" in reg
        assert len(reg) >= 12
    finally:
        wl.WORKLOADS.clear()
        wl.WORKLOADS.update(saved)
        wl._REGISTRY_SEEDED = saved_flag


def test_unknown_registry_names_raise_keyerror():
    from repro.simcache import simulate_level, simulate_stencil_level

    with pytest.raises(KeyError, match="jacobi2"):
        rank("jacobi2", widths=(8192,))
    with pytest.raises(KeyError, match="ddott"):
        simulate_level("ddott", 0)
    with pytest.raises(KeyError, match="jacobi2"):
        simulate_stencil_level("jacobi2", 0, widths=(512,))


def test_stencil_simulation_uses_machine_capacities_by_default():
    """SKX's 1 MiB L2 must drive the layer conditions (and residence)
    when simulating on skylake-sp — not Haswell's 256 KiB."""
    from repro.simcache import simulate_stencil_levels_batch

    width = 8192                      # holds in SKX L2, breaks HSW L2
    skx = simulate_stencil_levels_batch(
        "jacobi2d", np.array([[float(width)]]), machine="skylake-sp")
    hsw = simulate_stencil_levels_batch(
        "jacobi2d", np.array([[float(width)]]), machine="haswell-ep")
    assert not np.allclose(skx, hsw)
    # and the SKX table matches an explicit SKX-capacity evaluation
    from repro.simcache import machine_caches
    explicit = simulate_stencil_levels_batch(
        "jacobi2d", np.array([[float(width)]]), machine="skylake-sp",
        caches=machine_caches("skylake-sp"))
    np.testing.assert_array_equal(skx, explicit)


def test_rank_workloads_mixed_families_one_path():
    """Streams, a stencil and the fused chain ranked in one pass on one
    machine — and the order is the Mem-level prediction order."""
    ws = [StreamWorkload(BENCHMARKS["ddot"]),
          StreamWorkload(TRIAD_UPDATE),
          StencilWorkload(JACOBI2D, widths=(8192,))]
    for machine in ("haswell-ep", "skylake-sp"):
        ranked = rank(ws, machine)
        ts = [r["t_ecm"] for r in ranked]
        assert ts == sorted(ts)
        assert ranked[0]["name"] == "ddot"


def test_rank_workloads_accepts_prelowered_tpu_step():
    from repro.core.tpu_ecm import TPUStepECM

    step = TPUStepECM(name="step", t_comp=1e-3, t_hbm=2e-3, t_ici=5e-4)
    ranked = rank([step.as_workload()], "tpu-v5e")
    assert ranked[0]["name"] == "step"
    assert ranked[0]["t_ecm"] > 0


def test_tpu_overlap_calibration_lives_on_machine():
    from repro.core import TPU_V5E
    from repro.core.hlo import HLOResources
    from repro.core.tpu_ecm import MeshSpec, from_resources

    res = HLOResources(flops=1e12, bytes_accessed=1e9, collectives=())
    step = from_resources(res, MeshSpec(shape=(4,), axes=("data",)))
    assert step.exposed_hbm_fraction == TPU_V5E.exposed_hbm_fraction
    assert step.exposed_ici_fraction == TPU_V5E.exposed_ici_fraction
