"""Layer-condition analysis pinned to the hand-derived values of
Stengel et al., arXiv:1410.5010 §III (2D 5-point Jacobi, double precision),
plus batch-vs-scalar equivalence of the LC-aware ECM construction."""
import numpy as np
import pytest

from repro.core import (
    HASWELL_EP,
    JACOBI2D,
    JACOBI3D,
    StencilSpec,
    misses_batch,
    stencil_block_batch,
    stencil_ecm,
)
from repro.core.autotune import rank, stencil_block_candidates

L1, L2, L3 = HASWELL_EP.capacities


# ---------------------------------------------------------------------------
# 1410.5010 §III hand-derived traffic for the 2D 5-point stencil
# ---------------------------------------------------------------------------


def test_lc_held_edge_traffic_is_3_lines():
    """LC satisfied: only the leading row misses -> 1 load + 1 RFO + 1 WB
    = 3 CLs per CL of work = 24 B/LUP (the paper's §III value)."""
    misses = JACOBI2D.load_misses(L1, (512,))
    assert misses == 1
    lines = misses + JACOBI2D.rfo_streams + JACOBI2D.wb_streams
    assert lines == 3
    bytes_per_lup = lines * 64 / JACOBI2D.elems_per_line(64)
    assert bytes_per_lup == 24.0


def test_lc_broken_edge_traffic_is_5_lines():
    """LC violated: all 2r+1 = 3 rows miss -> 3 loads + RFO + WB = 5 CLs
    per CL of work = 40 B/LUP."""
    misses = JACOBI2D.load_misses(L1, (4096,))
    assert misses == 3 == JACOBI2D.row_streams
    lines = misses + JACOBI2D.rfo_streams + JACOBI2D.wb_streams
    assert lines == 5
    assert lines * 64 / 8 == 40.0


def test_lc_threshold_exact():
    """The L1 break sits exactly at 3*N*8*safety = 32 KiB -> N = 682."""
    assert JACOBI2D.load_misses(L1, (682,)) == 1
    assert JACOBI2D.load_misses(L1, (683,)) == 3


@pytest.mark.parametrize("width,expected", [
    (512, (1, 1, 1)),       # LC holds everywhere
    (1024, (3, 1, 1)),      # broken in L1 only
    (8192, (3, 3, 1)),      # broken in L1 and L2
    (2 ** 21, (3, 3, 3)),   # broken everywhere (3 rows > L3/2)
])
def test_misses_per_level_2d(width, expected):
    assert JACOBI2D.misses_per_level((width,)) == expected


def test_blocking_restores_layer_condition():
    """Spatial blocking caps the effective width: a 256-wide block makes
    an 8192-wide problem L1-resident again (1410.5010 §V)."""
    assert JACOBI2D.misses_per_level((8192,)) == (3, 3, 1)
    assert JACOBI2D.misses_per_level((8192,), block=(256,)) == (1, 1, 1)


# ---------------------------------------------------------------------------
# 3D 7-point: the {1, 3, 5} miss hierarchy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("widths,l1_misses", [
    ((20, 20), 1),      # 3 layers fit in L1: leading stream only
    ((100, 100), 3),    # layers broken, 5 rows fit: one per layer
    ((100, 500), 5),    # neither: all 4r+1 row streams miss
])
def test_misses_3d_hierarchy(widths, l1_misses):
    assert JACOBI3D.load_misses(L1, widths) == l1_misses


def test_3d_row_streams():
    assert JACOBI3D.row_streams == 5
    assert StencilSpec(name="r2", dim=3, radius=2).row_streams == 9


# ---------------------------------------------------------------------------
# LC-aware ECM construction
# ---------------------------------------------------------------------------


def test_stencil_ecm_levels_and_monotonicity():
    m = stencil_ecm("jacobi2d", widths=(8192,))
    assert m.levels == HASWELL_EP.level_names()
    preds = m.predictions()
    assert all(b >= a for a, b in zip(preds, preds[1:]))


def test_lc_changes_model_inputs_not_just_residence():
    """The broken-LC model has strictly larger transfer terms on the
    broken edges and a strictly larger Mem prediction."""
    held = stencil_ecm("jacobi2d", widths=(512,))
    broken = stencil_ecm("jacobi2d", widths=(8192,))
    assert broken.transfers[0] > held.transfers[0]        # L1<->L2 edge
    assert broken.prediction("Mem") > held.prediction("Mem")
    assert broken.t_ol == held.t_ol                       # in-core unchanged
    assert broken.t_nol == held.t_nol


def test_block_batch_agrees_with_scalar():
    """stencil_block_batch == per-candidate StencilSpec.ecm, exactly."""
    widths, bw = (8192,), 24.1e9
    blocks = [(64,), (512,), (1024,), (8192,)]
    batch = stencil_block_batch(JACOBI2D, widths, blocks, sustained_bw=bw)
    for i, b in enumerate(blocks):
        scalar = JACOBI2D.ecm(HASWELL_EP, bw, widths=widths, block=b)
        np.testing.assert_allclose(batch.scalar(i).predictions(),
                                   scalar.predictions(), rtol=0, atol=0)


def test_misses_batch_matches_scalar():
    widths = np.array([64, 682, 683, 5461, 5462, 2 ** 21], float)
    tab = misses_batch(JACOBI2D, widths)
    for i, w in enumerate(widths):
        assert tuple(tab[i]) == JACOBI2D.misses_per_level((int(w),))


# ---------------------------------------------------------------------------
# Autotuner integration
# ---------------------------------------------------------------------------


def test_rank_stencil_blocks_prefers_lc_restoring_block():
    ranked = rank("jacobi2d", widths=(8192,))
    assert ranked[0]["misses_l1"] == 1
    assert ranked[0]["t_ecm"] <= ranked[-1]["t_ecm"]
    ts = [r["t_ecm"] for r in ranked]
    assert ts == sorted(ts)
    unblocked = next(r for r in ranked if r["block"] == (8192,))
    assert ranked[0]["speedup_vs_unblocked"] == pytest.approx(
        unblocked["t_ecm"] / ranked[0]["t_ecm"])
    assert ranked[0]["speedup_vs_unblocked"] > 1.1


def test_block_candidates_cover_problem():
    cands = stencil_block_candidates((8192,))
    assert cands[0] == (16,)
    assert cands[-1] == (8192,)
    cands3 = stencil_block_candidates((400, 400))
    assert all(c[0] == 400 for c in cands3)   # only inner dim tiled


# ---------------------------------------------------------------------------
# Simulator ("measured") side
# ---------------------------------------------------------------------------


def test_sweep_batch_regimes_and_lc_divergence():
    """The acceptance-criterion property: >= 3 residence regimes, with
    layer-condition-driven predictions differing between them."""
    from repro.simcache import stencil_sweep_batch

    r = stencil_sweep_batch("jacobi2d", [32, 64, 512, 1024, 2048, 8192])
    regimes = set(int(x) for x in r["regime"])
    assert {0, 3}.issubset(regimes) and len(regimes) >= 3
    # LC breaks between N=512 and N=1024 change the *model*, not just the
    # residence blend: the per-level prediction tables differ.
    assert not np.allclose(r["predicted_levels"][2],
                           r["predicted_levels"][3])
    # measured tracks predicted within the simulator's calibration band
    err = np.abs(r["measured"] / r["predicted"] - 1)
    assert float(err.max()) < 0.2


def test_simulate_stencil_scalar_view():
    from repro.simcache import (
        simulate_stencil_level,
        simulate_stencil_levels_batch,
    )

    tab = simulate_stencil_levels_batch("jacobi2d", np.array([[1024.0]]))
    for lv in range(4):
        assert simulate_stencil_level("jacobi2d", lv, widths=(1024,)) \
            == pytest.approx(float(tab[0, lv]), abs=0)
