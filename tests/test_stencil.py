"""Stencil Pallas kernels: bit-identical to the jnp oracles at every
pipeline depth (num_stages None/1/2/3), including odd/prime sizes where
the halo pipeline's block fit shrinks, plus halo-contract errors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import pipeline as P
from repro.kernels.stencil import kernel as K
from repro.kernels.stencil import ops, ref

KEY = jax.random.key(11)
STAGES = [None, 1, 2, 3]

SHAPES_2D = [(24, 33), (40, 128), (23, 17)]      # even, lane-wide, prime
SHAPES_3D = [(12, 10, 17), (7, 9, 11)]           # even, prime


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("ns", STAGES)
def test_jacobi2d_bit_identical_to_ref(shape, ns):
    a = jax.random.normal(jax.random.fold_in(KEY, shape[0]), shape,
                          jnp.float32)
    got = np.asarray(ops.jacobi2d(a, num_stages=ns, interpret=True))
    want = np.asarray(ref.jacobi2d(a))
    assert np.array_equal(got, want), (shape, ns)


@pytest.mark.parametrize("shape", SHAPES_3D)
@pytest.mark.parametrize("ns", STAGES)
def test_jacobi3d_bit_identical_to_ref(shape, ns):
    a = jax.random.normal(jax.random.fold_in(KEY, shape[0]), shape,
                          jnp.float32)
    got = np.asarray(ops.jacobi3d(a, num_stages=ns, interpret=True))
    want = np.asarray(ref.jacobi3d(a))
    assert np.array_equal(got, want), (shape, ns)


def test_jacobi2d_bit_identical_across_depths_nonzero_c0():
    a = jax.random.normal(jax.random.fold_in(KEY, 5), (40, 56), jnp.float32)
    kw = dict(c0=0.5, c1=0.125, interpret=True)
    base = np.asarray(ops.jacobi2d(a, num_stages=1, **kw))
    for ns in (None, 2, 3):
        got = np.asarray(ops.jacobi2d(a, num_stages=ns, **kw))
        assert np.array_equal(got, base), ns
    assert np.array_equal(base, np.asarray(ref.jacobi2d(a, 0.5, 0.125)))


def test_jacobi2d_bf16():
    a = jax.random.normal(jax.random.fold_in(KEY, 6), (32, 48), jnp.bfloat16)
    got = ops.jacobi2d(a, num_stages=2, interpret=True)
    want = ref.jacobi2d(a)
    assert got.dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(got, np.float32),
                          np.asarray(want, np.float32))


def test_boundary_is_dirichlet_copy():
    a = jax.random.normal(jax.random.fold_in(KEY, 7), (16, 20), jnp.float32)
    out = np.asarray(ops.jacobi2d(a, num_stages=2, interpret=True))
    an = np.asarray(a)
    for sl in (np.s_[0, :], np.s_[-1, :], np.s_[:, 0], np.s_[:, -1]):
        assert np.array_equal(out[sl], an[sl])


def test_fixed_point_constant_field():
    """With c0 + 4*c1 = 1 a constant field is a fixed point of the sweep."""
    a = jnp.full((24, 40), 3.25, jnp.float32)
    out = np.asarray(ops.jacobi2d(a, c0=0.0, c1=0.25, num_stages=3,
                                  interpret=True))
    assert np.array_equal(out, np.asarray(a))


def test_num_stages_exceeding_chunks_degrades_gracefully():
    a = jax.random.normal(jax.random.fold_in(KEY, 8), (8, 12), jnp.float32)
    got = np.asarray(ops.jacobi2d(a, num_stages=5, block_rows=4,
                                  interpret=True))
    assert np.array_equal(got, np.asarray(ref.jacobi2d(a)))


def test_halo_pipeline_rejects_unpadded_input():
    with pytest.raises(ValueError, match="padded input"):
        P.halo_pipeline_call(lambda t, g0: t, out_shape=(8, 4),
                             in_shape=(8, 6), dtype=jnp.float32, halo=1)


def test_five_point_block_matches_ref_interior():
    """The shared tile compute (used by both execution paths) equals the
    oracle on an interior tile with a traced-style offset."""
    a = jax.random.normal(jax.random.fold_in(KEY, 9), (20, 15), jnp.float32)
    p = jnp.pad(a, 1)
    tile = p[4:4 + 6, :]          # padded rows for output rows 4..7
    got = K.five_point_block(tile, 4, H=20, W=15, c0=0.0, c1=0.25)
    want = ref.jacobi2d(a)[4:8]
    assert np.array_equal(np.asarray(got), np.asarray(want))
