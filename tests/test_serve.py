"""Fault-tolerant serving engine: determinism, zero-lost accounting,
pinned recovery sequences, model-traceable decisions.

The engine runs on a virtual clock with seeded jitter, so a (trace,
config, fault plan, seed) tuple is a *name* for one exact trajectory —
these tests pin the recovery sequences byte-for-byte (which request
bounced, at which step, in which order) instead of asserting loose
"eventually recovers" properties.  The configs mirror
``benchmarks/serve_bench.py`` so the committed ``BENCH_serve.json``
baseline and the pins here guard the same trajectories.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.serve import (
    EngineConfig,
    FaultInjector,
    RequestState,
    RetryPolicy,
    ServeEngine,
    TraceConfig,
    fault_plan,
    slo_class,
    synthetic_trace,
)
from repro.serve.faults import FaultPlan, KVCorrupt
from repro.serve.policy import SLO_CLASSES, DegradationPolicy
from repro.serve.trace import Request

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: the bench configuration (same trajectories as BENCH_serve.json)
TRACE = TraceConfig(mean_interarrival_s=0.001)
DEGRADE = DegradationPolicy(step_budget_s=0.001)


def _bench_run(plan_name, **cfg_kw):
    engine = ServeEngine(EngineConfig(**cfg_kw), degrade=DEGRADE)
    summary = engine.run(synthetic_trace(TRACE, seed=0),
                         FaultInjector(fault_plan(plan_name)))
    return engine, summary


# ---------------------------------------------------------------------------
# trace + engine determinism
# ---------------------------------------------------------------------------


def test_trace_is_seed_deterministic():
    a = synthetic_trace(TRACE, seed=3)
    b = synthetic_trace(TRACE, seed=3)
    c = synthetic_trace(TRACE, seed=4)
    assert [(r.arrival_s, r.prompt_len, r.gen_len, r.slo.name)
            for r in a] == \
           [(r.arrival_s, r.prompt_len, r.gen_len, r.slo.name) for r in b]
    assert [r.arrival_s for r in a] != [r.arrival_s for r in c]
    assert all(a[i].arrival_s <= a[i + 1].arrival_s
               for i in range(len(a) - 1))


def test_engine_replay_is_bit_identical():
    e1, s1 = _bench_run("device_loss")
    e2, s2 = _bench_run("device_loss")
    assert e1.log == e2.log
    assert s1 == s2
    assert [(st.step, st.predicted_s, st.measured_s) for st in e1.steps] \
        == [(st.step, st.predicted_s, st.measured_s) for st in e2.steps]


# ---------------------------------------------------------------------------
# zero-lost accounting under every fault class
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("plan", ["none", "device_loss", "slow_step",
                                  "kv_corruption"])
def test_no_request_is_ever_lost(plan):
    engine, summary = _bench_run(plan)
    assert summary["lost"] == 0
    assert summary["completed"] == TRACE.n_requests
    for r in engine.requests:
        assert r.terminal, (r.rid, r.state)
        assert r.finish_s is not None


def test_fault_free_run_is_clean():
    engine, summary = _bench_run("none")
    assert summary["recovery"] == {"requeued": 0, "retried": 0,
                                   "recovered": 0}
    assert not engine.events("requeue", "fail", "device_loss",
                             "kv_corrupt", "recalibrate")
    assert summary["step_pred_measured"]["max_ratio"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# pinned recovery sequences (one per fault class)
# ---------------------------------------------------------------------------


def test_device_loss_recovery_sequence_pinned():
    engine, summary = _bench_run("device_loss")
    seq = [(e["event"], e.get("rid"), e["step"])
           for e in engine.events("device_loss", "requeue", "fail")]
    # half the devices vanish at step 72; the four requests whose KV
    # pages lived on the lost slice bounce, re-prefill, and complete
    assert seq == [("device_loss", None, 72),
                   ("requeue", 3, 72), ("requeue", 4, 72),
                   ("requeue", 7, 72), ("requeue", 8, 72)]
    loss = engine.events("device_loss")[0]
    assert loss["n_devices_before"] == 4 and loss["n_devices_after"] == 2
    assert summary["n_devices_final"] == 2
    assert summary["recovery"] == {"requeued": 4, "retried": 4,
                                   "recovered": 4}
    for rid in (3, 4, 7, 8):
        assert engine.requests[rid].state is RequestState.DONE


def test_kv_corruption_drop_and_retry_sequence_pinned():
    engine, summary = _bench_run("kv_corruption")
    seq = [(e["event"], e["rid"], e["step"])
           for e in engine.events("kv_corrupt", "requeue", "fail")]
    assert seq == [("kv_corrupt", 1, 67), ("requeue", 1, 67),
                   ("kv_corrupt", 2, 81), ("requeue", 2, 81)]
    # a dropped page forces a cold re-prefill: the victims were
    # re-admitted (admit count exceeds the request count)
    assert summary["events"]["admit"] == TRACE.n_requests + 2
    assert summary["recovery"]["recovered"] == 2
    for e in engine.events("requeue"):
        assert e["reason"] == "corrupted KV page"
        assert e["backoff_s"] > 0
        assert e["eligible_s"] > e["t"]


def test_slow_window_triggers_recalibration():
    engine, summary = _bench_run("slow_step")
    recals = engine.events("recalibrate")
    assert recals, "measured >> predicted must re-calibrate the buckets"
    first = recals[0]
    # first divergence is detected inside the injected window [60, 70)
    assert 60 <= first["step"] <= 70
    assert first["ratio"] == pytest.approx(4.0)
    assert first["calibration"] > 1.0
    # calibrated buckets feed later admission decisions
    assert summary["calibration"], "calibration table must be exported"
    assert summary["step_pred_measured"]["max_ratio"] == pytest.approx(4.0)
    assert summary["lost"] == 0


# ---------------------------------------------------------------------------
# retry bounds + degradation traceability
# ---------------------------------------------------------------------------


def test_retry_budget_exhaustion_fails_terminally():
    # corrupt the same slot every step: the victim must hit FAILED
    # (terminal + accounted), never loop forever or vanish
    plan = FaultPlan(name="hammer", kv_corruptions=tuple(
        KVCorrupt(step=s, slot=0) for s in range(0, 400)))
    engine = ServeEngine(EngineConfig(seed=0),
                         retry=RetryPolicy(max_retries=2), degrade=DEGRADE)
    summary = engine.run(synthetic_trace(TRACE, seed=0),
                         FaultInjector(plan))
    assert summary["lost"] == 0
    fails = engine.events("fail")
    assert fails
    for e in fails:
        assert "retries exhausted" in e["reason"]
        assert engine.requests[e["rid"]].state is RequestState.FAILED
        assert engine.requests[e["rid"]].retries == 3  # max_retries + 1


def test_every_degradation_is_traceable_to_a_prediction():
    engine, summary = _bench_run("none")
    transitions = engine.events("degrade", "restore")
    assert transitions, "the heavy trace must exercise the ladder"
    assert summary["degrade_max_level"] >= 1
    for e in transitions:
        # each transition carries the ECM prediction that triggered it
        assert "predicted_step_s" in e and "step_budget_s" in e
        if e["event"] == "degrade":
            assert e["predicted_step_s"] > e["step_budget_s"]
        else:
            assert e["predicted_step_s"] < 0.5 * e["step_budget_s"]


def test_admission_decisions_carry_predictions():
    engine, _ = _bench_run("none")
    admits = engine.events("admit")
    assert len(admits) == TRACE.n_requests
    for e in admits:
        assert e["predicted_finish_s"] <= e["deadline_s"]
        assert e["ctx_bucket"] in (128, 256, 512, 1024, 2048, 4096)


def test_hopeless_deadline_is_rejected_with_prediction():
    # deadline far below even a solo ECM-predicted finish -> reject
    impossible = slo_class("interactive").__class__(
        "impossible", priority=0, base_budget_s=1e-9,
        per_token_budget_s=0.0)
    req = Request(rid=0, arrival_s=0.0, prompt_len=2048, gen_len=128,
                  slo=impossible)
    ok = Request(rid=1, arrival_s=0.0, prompt_len=128, gen_len=16,
                 slo=SLO_CLASSES[2])
    engine = ServeEngine(EngineConfig(seed=0))
    summary = engine.run([req, ok])
    assert req.state is RequestState.SHED
    assert ok.state is RequestState.DONE
    assert summary["lost"] == 0
    rejects = engine.events("reject")
    assert len(rejects) == 1
    assert rejects[0]["predicted_finish_s"] > rejects[0]["deadline_s"]


# ---------------------------------------------------------------------------
# bench artifact: schema + spec agreement
# ---------------------------------------------------------------------------


def test_serve_payload_passes_check_bench(tmp_path):
    from benchmarks.run import serve_payload

    path = tmp_path / "BENCH_serve.json"
    path.write_text(json.dumps(serve_payload()))
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_bench.py"),
         str(path)], env=env, cwd=ROOT, capture_output=True, text=True,
        timeout=120)
    assert r.returncode == 0, r.stdout + r.stderr


def test_check_bench_rejects_lost_requests(tmp_path):
    from benchmarks.run import serve_payload

    payload = serve_payload()
    payload["classes"]["none"]["lost"] = 1  # a vanished request
    path = tmp_path / "BENCH_serve.json"
    path.write_text(json.dumps(payload))
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_bench.py"),
         str(path)], env=env, cwd=ROOT, capture_output=True, text=True,
        timeout=120)
    assert r.returncode == 1
    assert "lost requests must be 0" in r.stderr


# ---------------------------------------------------------------------------
# real-mesh device loss: elastic reshard keeps the KV store bit-identical
# ---------------------------------------------------------------------------


_RESHARD = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax
import numpy as np
from jax.sharding import Mesh
from repro.serve import EngineConfig, ServeEngine
from repro.serve.faults import DeviceLoss, apply_device_loss

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
engine = ServeEngine(EngineConfig(n_devices=4))
store = engine.attach_kv_store(mesh, n_pages=16, page_tokens=4)
before = {k: np.asarray(v).copy() for k, v in store.items()}

apply_device_loss(engine, DeviceLoss(step=0, axis="data"))

ev = engine.events("device_loss")[0]
assert ev["resharded"] is True, ev
assert ev["n_devices_before"] == 4 and ev["n_devices_after"] == 2, ev
assert engine.mesh.devices.shape == (2, 2), engine.mesh.devices.shape
for k, v in engine.kv_store.items():
    assert np.array_equal(np.asarray(v), before[k]), k
    assert v.sharding.mesh.devices.shape == (2, 2), k

# second loss: data axis 2 -> 1; a third must fail loudly upstream
apply_device_loss(engine, DeviceLoss(step=1, axis="data"))
assert engine.mesh.devices.shape == (1, 2)
for k, v in engine.kv_store.items():
    assert np.array_equal(np.asarray(v), before[k]), k
print('RESHARD-OK')
"""


def test_device_loss_reshards_kv_store_bit_identical():
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    r = subprocess.run([sys.executable, "-c", _RESHARD], env=env, cwd=ROOT,
                       capture_output=True, text=True, timeout=240)
    assert "RESHARD-OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# loop safety: a hung serve loop fails fast instead of spinning
# ---------------------------------------------------------------------------


def test_max_steps_guard_raises():
    engine = ServeEngine(EngineConfig(max_steps=3, seed=0))
    with pytest.raises(RuntimeError, match="max_steps"):
        engine.run(synthetic_trace(TraceConfig(n_requests=8), seed=0))
