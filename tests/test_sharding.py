"""Logical-axis sharding: rule resolution, divisibility fallbacks, remesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.sharding import (
    PROFILES,
    ShardingProfile,
    _axis_sizes,
    get_profile,
    logical_to_pspec,
    param_shardings,
    tp_dp,
)
from repro.models.common import ParamSpec
from repro.train.elastic import remesh_state, shrink_mesh


def _mesh(shape=(2, 2), axes=("data", "model")):
    devs = np.array(jax.devices()[:1] * int(np.prod(shape))).reshape(shape)
    return Mesh(devs, axes)


def test_logical_to_pspec_basic():
    rules = {"embed": None, "mlp": "model", "batch": ("data",)}
    ps = logical_to_pspec(("embed", "mlp"), rules)
    assert ps == P(None, "model")


def test_duplicate_mesh_axis_deduped():
    rules = {"embed": "model", "mlp": "model"}
    ps = logical_to_pspec(("embed", "mlp"), rules)
    assert ps == P("model", None)


def test_divisibility_fallback_replicates():
    mesh = _mesh((1, 2))
    rules = {"heads": "model"}
    ps = logical_to_pspec(("heads",), rules, (3,), mesh)   # 3 % 2 != 0
    assert ps == P(None)
    ps2 = logical_to_pspec(("heads",), rules, (4,), mesh)
    assert ps2 == P("model")


def test_ensure_model_axis_fallback():
    mesh = _mesh((1, 2))
    prof = ShardingProfile("t", rules={"heads": "model"})
    spec = {"wq": ParamSpec((4096, 3, 256), ("embed", "heads", "head_dim"))}
    sh = param_shardings(spec, mesh, prof, ensure_model_axis=True,
                         min_elems=1 << 20)
    # heads=3 indivisible -> largest divisible dim (embed) gets model
    assert sh["wq"].spec == P("model", None, None)
    # but layers axes are never chosen
    spec2 = {"w": ParamSpec((2048, 4096), ("layers", "embed"))}
    sh2 = param_shardings(spec2, mesh, prof, ensure_model_axis=True,
                          min_elems=1 << 20)
    assert sh2["w"].spec == P(None, "model")


def test_profiles_construct_both_modes():
    for name, fn in PROFILES.items():
        for mp in (False, True):
            p = fn(mp)
            assert "batch" in p.activation_rules, name


def test_axis_sizes_two_pod_mesh():
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    assert _axis_sizes(mesh) == {"pod": 2, "data": 2, "model": 2}
    assert _axis_sizes(None) == {}


def test_multi_pod_batch_spans_pod_and_data():
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    prof = get_profile("tp_dp", multi_pod=True)
    assert prof.activation_rules["batch"] == ("pod", "data")
    ps = logical_to_pspec(("batch", "seq", "embed"),
                          prof.activation_rules, (8, 16, 32), mesh)
    assert ps == P(("pod", "data"), None, None)
    # an indivisible batch keeps the largest divisible axis prefix: the
    # 2-pod split survives while the per-pod data split is dropped
    ps2 = logical_to_pspec(("batch",), prof.activation_rules, (2,), mesh)
    assert ps2 == P("pod")


def test_param_shardings_two_pod_mesh():
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    spec = {
        "wq": ParamSpec((64, 8, 16), ("embed", "heads", "head_dim")),
        "emb": ParamSpec((128, 64), ("vocab", "embed")),
    }
    # weights never shard over the pod axis — DCN is gradient-sync only
    sh = param_shardings(spec, mesh, get_profile("tp_dp", multi_pod=True))
    assert sh["wq"].spec == P(None, "model", None)
    assert sh["emb"].spec == P("model", None)
    # FSDP puts embed over data (intra-pod), still never over pod
    sh_fsdp = param_shardings(spec, mesh,
                              get_profile("tp_fsdp", multi_pod=True))
    assert sh_fsdp["wq"].spec == P("data", "model", None)
    assert sh_fsdp["emb"].spec == P("model", "data")


def test_remesh_state_roundtrip():
    mesh = _mesh((1, 1))
    prof = tp_dp(False)
    spec = {"w": ParamSpec((8, 4), ("embed", "mlp"))}
    state = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    out = remesh_state(state, spec, mesh, prof)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(state["w"]))


def test_shrink_mesh():
    mesh = _mesh((2, 2))
    small = shrink_mesh(mesh, "data")
    assert dict(zip(small.axis_names, small.devices.shape)) == {
        "data": 1, "model": 2}
    with pytest.raises(ValueError):
        shrink_mesh(small, "data")
