"""Per-architecture smoke tests: reduced same-family configs, one forward/
train step + prefill/decode on CPU, asserting output shapes and no NaNs.

The FULL assigned configs are exercised only via the dry-run (ShapeDtype-
Struct lowering, no allocation) — see repro.launch.dryrun / tests/test_dryrun.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_arch
from repro.configs.base import ShapeSpec
from repro.models.common import materialize
from repro.optim import AdamWConfig
from repro.train.steps import init_state, make_train_step

SMOKE_TRAIN = ShapeSpec("smoke_train", seq_len=32, global_batch=2,
                        kind="train")
SMOKE_PREFILL = ShapeSpec("smoke_prefill", seq_len=32, global_batch=2,
                          kind="prefill")
SMOKE_DECODE = ShapeSpec("smoke_decode", seq_len=48, global_batch=2,
                         kind="decode")


def _jnp_batch(batch):
    return {k: jnp.asarray(v) for k, v in batch.items()}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_smoke(name):
    arch = get_arch(name, smoke=True)
    opt = AdamWConfig(weight_decay=0.0)
    state = init_state(arch, jax.random.key(0), opt)
    batch = _jnp_batch(arch.make_batch(SMOKE_TRAIN, seed=1))
    step = jax.jit(make_train_step(arch, opt))
    state2, metrics = step(state, batch)

    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{name}: non-finite loss {loss}"
    assert int(state2["step"]) == 1
    # vocab is tiny in smoke configs; loss should be near log(vocab_padded)
    vpad = arch.cfg.vocab_padded if hasattr(arch.cfg, "vocab_padded") else 512
    assert loss < np.log(vpad) + 2.0, (name, loss)
    # parameters actually moved
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(state2["params"])[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))
    # and stayed finite
    for leaf in jax.tree.leaves(state2["params"]):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_loss_decreases_smoke(name):
    """Three steps on the same structured batch should reduce the loss."""
    arch = get_arch(name, smoke=True)
    opt = AdamWConfig(weight_decay=0.0, grad_clip_norm=0.0)
    from repro.optim.schedule import constant
    state = init_state(arch, jax.random.key(0), opt)
    batch = _jnp_batch(arch.make_batch(SMOKE_TRAIN, seed=2))
    step = jax.jit(make_train_step(arch, opt, constant(3e-3)))
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], (name, losses)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_smoke(name):
    arch = get_arch(name, smoke=True)
    if not arch.has_decoder:
        pytest.skip("no decoder")
    params = materialize(arch.param_spec(), jax.random.key(0))
    batch = _jnp_batch(arch.make_batch(SMOKE_PREFILL, seed=3))
    max_len = SMOKE_DECODE.seq_len

    logits, cache = jax.jit(
        lambda p, b: arch.prefill(p, b, max_len=max_len))(params, batch)
    vpad = arch.cfg.vocab_padded
    assert logits.shape[0] == 2 and logits.shape[-1] == vpad
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    decode = jax.jit(lambda p, c, b: arch.decode(p, c, b))
    tok = jnp.argmax(logits[:, -1, : arch.cfg.vocab], axis=-1)[:, None]
    for _ in range(3):
        logits, cache = decode(params, cache, {"tokens": tok.astype(jnp.int32)})
        assert logits.shape == (2, 1, vpad)
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), name
        tok = jnp.argmax(logits[:, -1, : arch.cfg.vocab], axis=-1)[:, None]
    assert int(cache["length"]) == int(batch["tokens"].shape[1]
                                       + getattr(arch.cfg, "image_prefix", 0)
                                       ) + 3


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_batch_specs_cover_assigned_shapes(name):
    """Every runnable (arch x assigned shape) cell has well-formed abstract
    inputs (shape-only; no allocation)."""
    arch = get_arch(name)
    for shape, ok, reason in arch.cells():
        if not ok:
            assert reason
            continue
        abs_batch = arch.abstract_batch(shape)
        assert "tokens" in abs_batch
        for k, v in abs_batch.items():
            assert all(int(d) > 0 for d in v.shape), (name, shape.name, k)
