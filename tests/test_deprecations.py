"""Deprecation shims: actionable warnings, and a source guard that the
repo itself has fully migrated off them.

The PR-3/PR-7/PR-8 compatibility shims (``HASWELL_MEASURED_BW``,
``STENCIL_MEASURED_BW``, ``HASWELL_CAPACITIES``, ``PowerModel``, and the
five ``rank_*`` wrappers) are graduating toward removal: every warning
now names the exact replacement call, and no in-repo code may import or
reference them outside the modules that define the shims and the tests
that pin them.
"""
import re
import warnings
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

#: the package __init__ lazily forwards the constant aliases (so the
#: warning fires in the owning submodule); it is shim plumbing, not a
#: caller, and is the only other file allowed to spell the names
_FORWARDER = "src/repro/core/__init__.py"

#: deprecated name -> modules that own the shim (the only allowed source
#: references outside tests)
DEPRECATED = {
    "HASWELL_MEASURED_BW": {"src/repro/core/machine.py", _FORWARDER},
    "HASWELL_CAPACITIES": {"src/repro/core/layer_condition.py", _FORWARDER},
    "STENCIL_MEASURED_BW": {"src/repro/core/layer_condition.py",
                            _FORWARDER},
    "PowerModel": {"src/repro/core/energy.py", _FORWARDER},
    "rank_workloads": {"src/repro/core/autotune.py"},
    "rank_operating_points": {"src/repro/core/autotune.py"},
    "rank_stencil_blocks": {"src/repro/core/autotune.py"},
    "rank_matmul_blocks": {"src/repro/core/autotune.py"},
    "rank_attention_blocks": {"src/repro/core/autotune.py"},
}


def test_no_in_repo_caller_uses_deprecated_names():
    """Grep the shipped source tree (src/ + benchmarks/ + examples/ +
    launch entry points) for the deprecated names; only each shim's own
    defining module may mention its name."""
    offenders = []
    scan_roots = ("src/repro", "benchmarks", "examples")
    for root in scan_roots:
        for path in sorted((ROOT / root).rglob("*.py")):
            rel = path.relative_to(ROOT).as_posix()
            text = path.read_text()
            for name, owners in DEPRECATED.items():
                if rel in owners:
                    continue
                if re.search(rf"\b{name}\b", text):
                    offenders.append(f"{rel}: {name}")
    assert not offenders, (
        "deprecated names referenced outside their shim modules "
        f"(migrate per the DeprecationWarning hint): {offenders}")


@pytest.mark.parametrize("name,module", [
    ("HASWELL_MEASURED_BW", "repro.core.machine"),
    ("HASWELL_CAPACITIES", "repro.core.layer_condition"),
    ("STENCIL_MEASURED_BW", "repro.core.layer_condition"),
    ("PowerModel", "repro.core.energy"),
])
def test_constant_shims_warn_with_migration_hint(name, module):
    import importlib

    mod = importlib.import_module(module)
    with pytest.warns(DeprecationWarning,
                      match=rf"{name} is deprecated and scheduled for "
                            rf"removal; migrate"):
        getattr(mod, name)


@pytest.mark.parametrize("name", [
    "rank_workloads", "rank_operating_points", "rank_stencil_blocks",
    "rank_matmul_blocks", "rank_attention_blocks",
])
def test_ranker_shims_warn_and_name_replacement(name):
    from repro.core import autotune

    fn = autotune.__getattr__(name)
    assert callable(fn)
    # the warning fires on *call* and points at the unified rank() API
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        try:
            fn()
        except TypeError:
            pass                                # bad args; warning already out
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert dep, f"{name} did not emit a DeprecationWarning"
    msg = str(dep[0].message)
    assert "deprecated and scheduled for removal" in msg
    assert "migrate to repro.core.autotune.rank" in msg


def test_unknown_attribute_still_raises():
    from repro.core import autotune, energy, machine

    for mod in (autotune, energy, machine):
        with pytest.raises(AttributeError):
            mod.__getattr__("definitely_not_a_symbol")
