"""Shared test config: `slow` marker + a hypothesis fallback.

The container may not ship `hypothesis`; the property tests degrade to a
seeded mini-runner (a handful of deterministic random examples per test)
instead of failing at collection.  With the real package installed the
stub is inert.
"""
import importlib.util
import random
import sys
import types


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running integration tests (deselect with "
        "-m 'not slow')")


if importlib.util.find_spec("hypothesis") is None:
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def _floats(min_value=-1e9, max_value=1e9, allow_nan=True, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _integers(min_value=0, max_value=1 << 31, **_kw):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[rng.randrange(len(items))])

    def _lists(elems, min_size=0, max_size=None, **_kw):
        hi = max_size if max_size is not None else min_size + 8

        def draw(rng):
            n = rng.randint(min_size, hi)
            return [elems.example(rng) for _ in range(n)]

        return _Strategy(draw)

    _st = types.ModuleType("hypothesis.strategies")
    _st.floats = _floats
    _st.integers = _integers
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.lists = _lists

    _MAX_EXAMPLES = [5]

    def _settings(max_examples=5, **_kw):
        def deco(fn):
            fn._max_examples = min(max_examples, 10)
            return fn
        return deco

    def _given(*arg_st, **kw_st):
        def deco(fn):
            inner = fn

            def wrapper(*args, **kwargs):
                rng = random.Random(f"stub:{inner.__name__}")
                n = getattr(wrapper, "_max_examples", _MAX_EXAMPLES[0])
                for _ in range(n):
                    drawn = [s.example(rng) for s in arg_st]
                    drawn_kw = {k: s.example(rng) for k, s in kw_st.items()}
                    inner(*args, *drawn, **kwargs, **drawn_kw)

            wrapper.__name__ = inner.__name__
            wrapper.__doc__ = inner.__doc__
            # allow @settings above or below @given
            if hasattr(inner, "_max_examples"):
                wrapper._max_examples = inner._max_examples
            return wrapper
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.strategies = _st
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.__stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
