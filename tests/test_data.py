"""Data pipeline: determinism, restartability, file datasets, arch batches."""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ShapeSpec
from repro.data import DataConfig, SyntheticLMDataset, TokenFileDataset
from repro.data.arch_data import ArchSyntheticDataset


CFG = DataConfig(global_batch=4, seq_len=32, vocab=128, seed=5)


def test_batches_deterministic_per_step():
    a, b = SyntheticLMDataset(CFG), SyntheticLMDataset(CFG)
    for step in (0, 3, 1000, 123456):
        ba, bb = a.batch(step), b.batch(step)
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
        np.testing.assert_array_equal(ba["labels"], bb["labels"])


def test_batches_differ_across_steps_and_seeds():
    d = SyntheticLMDataset(CFG)
    assert not np.array_equal(d.batch(0)["tokens"], d.batch(1)["tokens"])
    d2 = SyntheticLMDataset(DataConfig(**{**CFG.__dict__, "seed": 6}))
    assert not np.array_equal(d.batch(0)["tokens"], d2.batch(0)["tokens"])


def test_labels_are_next_tokens():
    b = SyntheticLMDataset(CFG).batch(0)
    # label[t] continues token stream: label[:-1] == tokens[1:]
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_structure_learnable():
    """With structure=0.8, even->odd transitions follow the grammar."""
    d = SyntheticLMDataset(CFG)
    hits = total = 0
    for step in range(5):
        b = d.batch(step)
        succ = d._succ
        even, odd = b["tokens"][:, 0:-1:2], b["tokens"][:, 1::2]
        n = min(even.shape[1], odd.shape[1])
        hits += np.sum(succ[even[:, :n]] == odd[:, :n])
        total += even[:, :n].size
    assert hits / total > 0.6


def test_token_file_dataset(tmp_path):
    path = str(tmp_path / "tokens.bin")
    data = np.arange(2000, dtype=np.uint16) % 128
    data.tofile(path)
    cfg = DataConfig(global_batch=2, seq_len=64, vocab=128, seed=1)
    ds = TokenFileDataset(path, cfg)
    b0 = ds.batch(0)
    assert b0["tokens"].shape == (2, 64)
    np.testing.assert_array_equal(b0["labels"][:, :-1], b0["tokens"][:, 1:])
    # deterministic across instances
    np.testing.assert_array_equal(
        TokenFileDataset(path, cfg).batch(3)["tokens"], ds.batch(3)["tokens"])


@pytest.mark.parametrize("name", ["whisper-base", "pixtral-12b"])
def test_arch_dataset_fills_extra_inputs(name):
    arch = get_arch(name, smoke=True)
    shape = ShapeSpec("t", seq_len=32, global_batch=2, kind="train")
    ds = ArchSyntheticDataset(arch, shape, seed=0)
    b = ds.batch(0)
    spec = arch.batch_spec(shape)
    assert set(b) == set(spec)
    for k, s in spec.items():
        assert b[k].shape == s.shape, (k, b[k].shape, s.shape)
    np.testing.assert_array_equal(b["tokens"], ds.batch(0)["tokens"])
