"""Decode path == full forward: prefill + token-by-token decode must
reproduce the teacher-forced logits (exercises the KV cache, the GQA
grouped einsums and the cache-length masking)."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.common import materialize


@pytest.mark.parametrize("name", ["internlm2-1.8b", "glm4-9b",
                                  "qwen1.5-110b", "granite-moe-1b-a400m"])
def test_lm_decode_matches_full_forward(name):
    from repro.models import lm

    arch = get_arch(name, smoke=True)
    cfg = arch.cfg
    params = materialize(arch.param_spec(), jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)

    h, _ = lm.hidden_states(params, cfg, tokens)
    full = np.asarray(lm.logits_fn(params, cfg, h), np.float32)

    logits, cache = lm.prefill(params, cfg, {"tokens": tokens[:, :8]},
                               max_len=16)
    np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                               full[:, 7], rtol=6e-2, atol=6e-2)
    for t in range(8, 12):
        logits, cache = lm.decode_step(params, cfg, cache,
                                       {"tokens": tokens[:, t:t + 1]})
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   full[:, t], rtol=6e-2, atol=6e-2,
                                   err_msg=f"{name} step {t}")


def test_whisper_decode_matches_teacher_forced():
    from repro.models import whisper

    arch = get_arch("whisper-base", smoke=True)
    cfg = arch.cfg
    params = materialize(arch.param_spec(), jax.random.key(0))
    frames = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.1
    tokens = jax.random.randint(jax.random.key(2), (2, 10), 0, cfg.vocab)

    enc = whisper.encode(params, cfg, frames)
    h = whisper.decode_train(params, cfg, tokens, enc)
    full = np.asarray(whisper._logits(params, cfg, h), np.float32)

    logits, cache = whisper.prefill(
        params, cfg, {"frames": frames, "tokens": tokens[:, :6]}, max_len=12)
    np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                               full[:, 5], rtol=6e-2, atol=6e-2)
    for t in range(6, 10):
        logits, cache = whisper.decode_step(params, cfg, cache,
                                            {"tokens": tokens[:, t:t + 1]})
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   full[:, t], rtol=6e-2, atol=6e-2,
                                   err_msg=f"step {t}")
