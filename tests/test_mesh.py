"""Multi-chip parallelism model (``repro.core.mesh``), the unified
``autotune.rank`` facade, the sharding-profile registry and the ring
wire-byte arithmetic the collective terms are built from."""
import warnings

import pytest

from repro.core import autotune
from repro.core.autotune import rank
from repro.core.hlo import CollectiveOp, HLOResources
from repro.core.mesh import (
    MeshPlan,
    dp_scaling,
    plan_candidates,
    plan_collectives,
    rank_meshes,
)
from repro.core.scaling import tpu_dp_scaling
from repro.dist.sharding import (
    PROFILES,
    ShardingProfile,
    get_profile,
    profile_names,
    register_profile,
)

MESH_KW = dict(batch=8, seq_len=2048)


# ---------------------------------------------------------------------------
# 1. Ring wire bytes per chip (the collective-term primitive)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,expected", [
    ("all-gather", 768.0),           # (4-1)/4 * 1024
    ("reduce-scatter", 768.0),       # same ring traffic as AG
    ("all-to-all", 768.0),           # each chip keeps 1/4
    ("all-reduce", 1536.0),          # RS + AG: 2 * (4-1)/4 * 1024
    ("collective-permute", 1024.0),  # point-to-point: full buffer
])
def test_wire_bytes_per_chip_ring_multipliers(kind, expected):
    op = CollectiveOp(kind=kind, out_bytes=1024.0, group_size=4)
    assert op.wire_bytes_per_chip == expected


def test_wire_bytes_per_chip_degenerate_groups():
    # group of 1: the ring fraction vanishes for the sharded collectives
    assert CollectiveOp("all-gather", 1024.0, 1).wire_bytes_per_chip == 0.0
    assert CollectiveOp("all-reduce", 1024.0, 1).wire_bytes_per_chip == 0.0
    # ...but a permute still moves the whole buffer
    assert CollectiveOp("collective-permute", 1024.0,
                        1).wire_bytes_per_chip == 1024.0
    # group_size=0 is clamped, not a ZeroDivisionError
    assert CollectiveOp("all-gather", 1024.0, 0).wire_bytes_per_chip == 0.0


# ---------------------------------------------------------------------------
# 2. Pure-DP bit-identity: tpu_dp_scaling == mesh.dp_scaling
# ---------------------------------------------------------------------------


def _resources(with_collective=True):
    res = HLOResources()
    res.flops = 6.0e15
    res.bytes_accessed = 4.0e12
    if with_collective:
        res.collectives = [CollectiveOp(kind="all-reduce",
                                        out_bytes=4.0e9, group_size=1)]
    return res


def test_dp_scaling_bit_identical_to_legacy():
    """The refactor's no-drift contract: the legacy entry point routed
    through the generalized plan evaluator returns ``==``-identical
    output (same keys, same floats, no tolerance)."""
    assert tpu_dp_scaling(_resources()) == dp_scaling(_resources())
    assert tpu_dp_scaling(_resources(False)) == dp_scaling(_resources(False))
    legacy = tpu_dp_scaling(_resources(), chip_counts=(1, 4, 16),
                            exposed_ici_fraction=0.5)
    assert legacy == dp_scaling(_resources(), (1, 4, 16),
                                exposed_ici_fraction=0.5)


# ---------------------------------------------------------------------------
# 3. MeshPlan arithmetic + candidate enumeration
# ---------------------------------------------------------------------------


def test_mesh_plan_labels_and_bubble():
    p = MeshPlan(data=4, model=2)
    assert p.label == "dp4xtp2" and p.n_chips == 8
    assert p.bubble_fraction == 0.0 and p.pipeline_scale == 1.0
    pp = MeshPlan(data=4, pipe=2, microbatches=8)
    assert pp.label == "dp4xpp2"
    assert pp.bubble_fraction == pytest.approx((2 - 1) / (8 + 2 - 1))
    assert pp.pipeline_scale == pytest.approx((8 + 2 - 1) / 8)
    mp = MeshPlan(data=4, model=2, pods=2)
    assert mp.label == "2podxdp4xtp2" and mp.multi_pod
    assert mp.n_chips == 16 and mp.data_total == 8


def test_plan_candidates_cover_the_chip_count():
    plans = plan_candidates(8)
    assert plans and all(p.n_chips == 8 for p in plans)
    # pure-DP collapses the model-axis profiles to one representative
    # per FSDP class: no duplicate (data, model, pipe, profile) rows
    assert len({(p.data, p.model, p.pipe, p.pods, p.profile)
                for p in plans}) == len(plans)
    tp1 = [p for p in plans if p.model == 1 and p.pipe == 1]
    assert all(p.profile in ("tp_dp", "tp_fsdp") for p in tp1)


# ---------------------------------------------------------------------------
# 4. Analytic collective volumes per plan
# ---------------------------------------------------------------------------


def test_plan_collectives_tp_volume_shrinks_with_data():
    """Activation collectives are per data-shard: doubling the batch
    split halves the per-chip TP all-reduce volume, while the gradient
    sync (the Eq. 2 floor) does not shrink."""
    a = plan_collectives("internlm2-1.8b", MeshPlan(data=2, model=2),
                         **MESH_KW)
    b = plan_collectives("internlm2-1.8b", MeshPlan(data=4, model=2),
                         **MESH_KW)
    ar_a = sum(c.wire_bytes_per_chip for c in a.ici
               if c.kind == "all-reduce" and c not in a.floor)
    ar_b = sum(c.wire_bytes_per_chip for c in b.ici
               if c.kind == "all-reduce" and c not in b.floor)
    assert ar_b == pytest.approx(ar_a / 2)
    assert a.floor and b.floor
    assert b.floor_bytes == pytest.approx(a.floor_bytes)


def test_plan_collectives_moe_has_all_to_all():
    colls = plan_collectives("granite-moe-1b-a400m",
                             MeshPlan(data=2, model=4, profile="moe_ep"),
                             **MESH_KW)
    assert any(c.kind == "all-to-all" for c in colls.ici)


def test_plan_collectives_fsdp_gathers_raise_the_floor():
    dp = plan_collectives("internlm2-1.8b", MeshPlan(data=8), **MESH_KW)
    fsdp = plan_collectives("internlm2-1.8b",
                            MeshPlan(data=8, profile="tp_fsdp"), **MESH_KW)
    assert any(c.kind == "all-gather" for c in fsdp.floor)
    assert fsdp.floor_bytes > dp.floor_bytes


def test_plan_collectives_multi_pod_splits_fabrics():
    colls = plan_collectives("internlm2-1.8b",
                             MeshPlan(data=8, model=2, pods=2), **MESH_KW)
    assert colls.dcn, "2-pod gradient sync must put traffic on DCN"
    assert colls.ici


# ---------------------------------------------------------------------------
# 5. Golden-pinned joint winners (the BENCH_mesh.json contract)
# ---------------------------------------------------------------------------

#: (config, n_chips) -> (mesh label, profile, t_step_us)
GOLDEN_WINNERS = {
    ("internlm2-1.8b", 8): ("dp4xtp2", "tp_dp", 551013.8048099199),
    ("internlm2-1.8b", 16): ("dp8xtp2", "tp_dp", 292535.77664496),
    ("internlm2-1.8b", 64): ("dp16xtp4", "tp_dp", 90081.06574024621),
    ("glm4-9b", 8): ("dp4xtp2", "tp_fsdp", 2690374.523189349),
    ("glm4-9b", 16): ("dp8xtp2", "tp_fsdp", 1454920.7399946745),
    ("glm4-9b", 64): ("dp8xtp8", "tp_dp", 438609.01633047726),
    ("granite-moe-1b-a400m", 8): ("dp4xpp2", "tp_dp", 214018.8132070315),
    ("granite-moe-1b-a400m", 16): ("dp8xpp2", "tp_dp", 120376.12916351572),
    ("granite-moe-1b-a400m", 64): ("dp16xpp4", "tp_dp", 42181.72501329647),
}


@pytest.mark.parametrize("config", ["internlm2-1.8b", "glm4-9b",
                                    "granite-moe-1b-a400m"])
def test_golden_mesh_winners(config):
    for n in (8, 16, 64):
        rows = rank(config, "tpu-v5e", mesh=n, **MESH_KW)
        mesh_label, profile, t_step = GOLDEN_WINNERS[(config, n)]
        w = rows[0]
        assert (w["mesh"], w["profile"]) == (mesh_label, profile), (n, w)
        assert w["t_step_us"] == pytest.approx(t_step, rel=1e-9)
        assert w["fits_hbm"] and w["block"] is not None
        assert w["data"] * w["model"] * w["pipe"] * w.get("pods", 1) == n
        # fitting plans sort strictly before HBM-overflowing ones
        fits = [r["fits_hbm"] for r in rows]
        assert fits == sorted(fits, reverse=True)


def test_rank_meshes_decode_phase_and_top():
    rows = rank_meshes("internlm2-1.8b", 8, "tpu-v5e", batch=8,
                       seq_len=1, context=4096, phase="decode",
                       include_blocks=False, top=3)
    assert len(rows) == 3
    assert rows[0]["t_step_us"] <= rows[1]["t_step_us"]


# ---------------------------------------------------------------------------
# 6. The unified facade: dispatch, mesh kwarg forms, deprecation shims
# ---------------------------------------------------------------------------


def test_facade_mesh_int_equals_rank_meshes():
    via_facade = rank("internlm2-1.8b", "tpu-v5e", mesh=8,
                      include_blocks=False, **MESH_KW)
    direct = rank_meshes("internlm2-1.8b", 8, "tpu-v5e",
                         include_blocks=False, **MESH_KW)
    assert via_facade == direct


def test_facade_mesh_dict_form():
    a = rank("internlm2-1.8b", "tpu-v5e",
             mesh={"n_chips": 8, "include_blocks": False}, **MESH_KW)
    b = rank("internlm2-1.8b", "tpu-v5e", mesh=8, include_blocks=False,
             **MESH_KW)
    assert a == b


def test_facade_rejects_unknown_objective_and_stray_kwargs():
    with pytest.raises(ValueError, match="unknown objective"):
        rank([], "haswell-ep", objective="speed")
    with pytest.raises(TypeError, match="without mesh="):
        rank((4096, 4096, 4096), "haswell-ep", objective="matmul",
             include_blocks=False)


@pytest.mark.parametrize("name,call", [
    ("rank_matmul_blocks",
     lambda fn: fn((512, 512, 512), machine="haswell-ep")),
    ("rank_attention_blocks",
     lambda fn: fn((1024, 1024, 128), machine="haswell-ep")),
    ("rank_stencil_blocks",
     lambda fn: fn("jacobi2d", (8192,))),
])
def test_deprecated_wrappers_warn_and_match(name, call):
    with pytest.warns(DeprecationWarning, match=f"{name} is deprecated"):
        old = call(getattr(autotune, name))
    impl = getattr(autotune, f"_{name}")
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # the impl itself must not warn
        assert call(impl) == old


def test_deprecated_rank_workloads_matches_facade():
    from repro.core import BENCHMARKS
    from repro.core.workload import StreamWorkload

    ws = [StreamWorkload(BENCHMARKS[k]) for k in ("copy", "ddot", "striad")]
    with pytest.warns(DeprecationWarning):
        old = autotune.rank_workloads(ws, "haswell-ep")
    assert rank(ws, "haswell-ep") == old


def test_deprecated_rank_operating_points_matches_facade():
    from repro.core import BENCHMARKS
    from repro.core.workload import StreamWorkload

    ws = [StreamWorkload(BENCHMARKS["striad"])]
    with pytest.warns(DeprecationWarning):
        old = autotune.rank_operating_points(ws, "haswell-ep",
                                             objective="edp")
    assert rank(ws, "haswell-ep", objective="edp") == old


def test_unknown_autotune_attribute_still_raises():
    with pytest.raises(AttributeError):
        autotune.no_such_ranker


# ---------------------------------------------------------------------------
# 7. Sharding-profile registry
# ---------------------------------------------------------------------------


def test_get_profile_roundtrip_and_errors():
    for name in profile_names():
        p = get_profile(name)
        assert isinstance(p, ShardingProfile) and p.name == name
        assert PROFILES[name]() == p        # historical call shape intact
    with pytest.raises(KeyError, match="tp_dp"):
        get_profile("no_such_profile")


def test_get_profile_instance_passthrough():
    inst = get_profile("tp_dp", multi_pod=True)
    assert get_profile(inst) is inst
    assert "pod" in inst.activation_rules["batch"]


def test_register_profile_constructor_and_instance():
    @register_profile
    def zz_test_prof(multi_pod=False):
        base = get_profile("tp_dp", multi_pod=multi_pod)
        return ShardingProfile("zz_test_prof", rules=base.rules,
                               activation_rules=base.activation_rules)

    try:
        assert "zz_test_prof" in profile_names()
        assert get_profile("zz_test_prof").name == "zz_test_prof"
        inst = ShardingProfile("zz_inst", rules={},
                               activation_rules={"batch": ("data",)})
        register_profile(inst)
        assert get_profile("zz_inst") == inst
    finally:
        PROFILES.pop("zz_test_prof", None)
        PROFILES.pop("zz_inst", None)


# ---------------------------------------------------------------------------
# 8. Serving + launcher integration
# ---------------------------------------------------------------------------


def test_bucket_model_remesh_ranks_device_split():
    from repro.serve import EngineConfig, ServeEngine

    engine = ServeEngine(EngineConfig(n_devices=4))
    # the trivial all-DP plan is installed up front
    assert engine.buckets.mesh_plan == {"data": 4, "model": 1,
                                        "t_step_s": None, "ctx_bucket": None}
    plan = engine.buckets.remesh(2)
    assert plan["data"] * plan["model"] == 2
    assert plan["t_step_s"] > 0
    assert engine.buckets.mesh_plan is plan


def test_predict_table_carries_best_mesh():
    from repro.launch.dryrun import (
        SHAPES,
        composed_step_s,
        format_predict_table,
        predict_table,
    )

    pred = composed_step_s("internlm2-1.8b", SHAPES["decode_32k"], 256)
    rec = {"arch": "internlm2-1.8b", "shape": "decode_32k",
           "mesh": "16x16", "status": "ok", "ecm": {"t_ecm_s": pred}}
    rows = predict_table([rec])
    assert rows[0]["status"] == "ok" and "/" in rows[0]["best_mesh"]
    mesh_label, profile = rows[0]["best_mesh"].split("/")
    assert profile in profile_names()
    table = format_predict_table(rows)
    assert "best_mesh" in table and rows[0]["best_mesh"] in table
