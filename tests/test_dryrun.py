"""Multi-pod dry-run integration: real subprocess with 512 fake devices
(the env var must precede jax init, so these tests shell out), plus
grid-completeness checks over generated records."""
import glob
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=540):
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    return subprocess.run([sys.executable, *args], env=env, cwd=ROOT,
                          capture_output=True, text=True, timeout=timeout)


def test_production_meshes_build():
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512';"
        "from repro.launch.mesh import make_production_mesh;"
        "m=make_production_mesh();"
        "assert m.devices.shape==(16,16) and m.axis_names==('data','model');"
        "m2=make_production_mesh(multi_pod=True);"
        "assert m2.devices.shape==(2,16,16);"
        "assert m2.axis_names==('pod','data','model');"
        "print('MESH-OK')"
    )
    r = _run(["-c", code], timeout=120)
    assert "MESH-OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_dryrun_cell_both_meshes(tmp_path):
    """Lower+compile one full-size cell on the single-pod AND multi-pod
    meshes end-to-end through the CLI."""
    r = _run(["-m", "repro.launch.dryrun", "--arch", "whisper-base",
              "--shape", "decode_32k", "--both-meshes",
              "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    recs = [json.load(open(p)) for p in glob.glob(str(tmp_path / "*.json"))]
    assert {rec["mesh"] for rec in recs} == {"16x16", "2x16x16"}
    for rec in recs:
        assert rec["status"] == "ok", rec
        assert rec["fits_hbm"], rec["peak_bytes_per_chip"]
        assert rec["ecm"]["t_hbm_s"] > 0
        assert rec["cost"]["flops_per_chip"] > 0


GRID = glob.glob(os.path.join(ROOT, "results", "dryrun", "*.json"))


@pytest.mark.skipif(len(GRID) < 80, reason="grid not fully generated")
def test_grid_complete_and_healthy():
    recs = [json.load(open(p)) for p in GRID]
    assert len(recs) == 80                      # 10 archs x 4 shapes x 2 meshes
    by_status = {}
    for r in recs:
        by_status.setdefault(r["status"], []).append(r)
    assert set(by_status) <= {"ok", "skipped"}, {
        (r["arch"], r["shape"]): r.get("error") for r in
        by_status.get("error", [])}
    # exactly the documented skips: long_500k on the 8 full-attention archs
    skips = {(r["arch"], r["shape"]) for r in by_status["skipped"]}
    assert all(s == "long_500k" for _, s in skips)
    assert {a for a, _ in skips} == {
        "internlm2-1.8b", "qwen1.5-110b", "minitron-4b", "glm4-9b",
        "granite-moe-1b-a400m", "qwen3-moe-235b-a22b", "pixtral-12b",
        "whisper-base"}
    # every compiled record carries the roofline inputs
    for r in by_status["ok"]:
        assert r["cost"]["flops_per_chip"] > 0
        assert r["cost"]["bytes_per_chip"] > 0
        assert "wire_bytes_per_chip" in r["collectives"]
