"""Multi-buffered HBM->VMEM DMA pipeline (the ECM overlap engine).

This module is the *shared* pipeline engine for every kernel family —
stream ops, fused chains and the halo-carrying stencils all route through
it; their ``ops.py`` wrappers only choose a compute function and a
builder.  The ECM model's central claim (Eq. 1) is
``T = max(T_nOL + T_data, T_OL)``: in-core work can hide data transfers
when the hardware overlaps them.  The default one-block-per-grid-step
Pallas kernels leave that overlap to the implicit two-deep pallas_call
pipeline; this module makes it *explicit and tunable*: inputs and outputs
live in HBM (``memory_space=ANY``) and the kernel itself runs an
``emit_pipeline``-style software pipeline with ``num_stages`` VMEM
buffers per stream and per-slot DMA semaphores:

    warm-up:  start DMAs for chunks 0..num_stages-2
    steady:   start chunk ``i+num_stages-1`` | wait chunk ``i`` | compute |
              start the output DMA for chunk ``i``
    drain:    wait the last in-flight output DMAs

The pipeline contract, common to all three builders:

* **Block shapes.**  Work is chunked along axis 0.  The requested
  ``block_rows`` is shrunk by :func:`_fit_block` to the largest divisor of
  the array's rows, so odd/prime sizes stay exact; ``n_chunks = rows //
  block_rows``.  Streaming kernels use flat ``(rows, 128)`` layouts;
  :func:`halo_pipeline_call` accepts arbitrary trailing dims (2D/3D
  stencil tiles).
* **``num_stages`` semantics.**  VMEM buffers per stream = pipeline
  depth, capped at ``n_chunks``.  ``1`` is a fully serial
  fetch->compute->store loop (the *no-overlap* bound, T_nOL + T_data);
  ``>= 2`` overlaps the next chunk's HBM reads and the previous chunk's
  write-back with compute (the *full-overlap* bound, max(T_data, T_OL)).
  Depth is a pure performance knob: outputs are bit-identical across
  ``num_stages`` (reductions accumulate in chunk order regardless of
  depth) — enforced by ``tests/test_pipeline.py`` and
  ``tests/test_stencil.py``.
* **Halo handling.**  Stencil chunks need ``halo`` extra rows on both
  sides.  :func:`halo_pipeline_call` takes a *pre-padded* input (axis 0
  length ``rows + 2*halo``; the wrapper pads, so every chunk's fetch
  window ``[c*block_rows, c*block_rows + block_rows + 2*halo)`` is in
  bounds without clamping) and fetches overlapping windows while writing
  disjoint ``block_rows``-sized outputs.  The compute callback receives
  the fetched tile plus the chunk's global row offset so it can mask
  physical-boundary rows.

Measuring one kernel at ``num_stages=1`` and ``>=2`` and placing the
runtime between the two bounds yields the machine's overlap coefficient —
see ``repro.core.tpu_ecm.overlap_coefficient``.

Everything here runs bit-identically under ``interpret=True`` (CPU) and
lowers to Mosaic DMA on a real TPU backend.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


@dataclass(frozen=True)
class PipelineConfig:
    """Knobs of the software pipeline.

    ``num_stages``: VMEM buffers per stream (pipeline depth).  1 = serial
    (no overlap), 2 = double buffering, 3 = triple buffering.
    ``block_rows``: rows of 128 lanes per chunk; shrunk to the largest
    divisor of the array's rows so odd sizes stay exact.
    """

    num_stages: int = 2
    block_rows: int = 64

    def vmem_bytes(self, n_streams: int, elem_bytes: int = 4) -> int:
        return (self.num_stages * n_streams
                * self.block_rows * LANES * elem_bytes)


def _fit_block(n_rows: int, block_rows: int) -> int:
    """Largest divisor of ``n_rows`` that is <= the requested block."""
    b = max(1, min(block_rows, n_rows))
    while n_rows % b:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# kernel builders
# ---------------------------------------------------------------------------


def _map_pipeline_kernel(compute, n_scalars: int, n_in: int, *,
                         n_chunks: int, stages: int, block_rows: int,
                         dtype):
    """Elementwise-map pipeline: out[chunk] = compute(*scalars, *blocks)."""

    def kernel(*refs):
        scalar_refs = refs[:n_scalars]
        in_refs = refs[n_scalars:n_scalars + n_in]
        out_ref = refs[n_scalars + n_in]

        def body(in_scr, out_scr, in_sem, out_sem):
            def in_dma(slot, chunk, j):
                return pltpu.make_async_copy(
                    in_refs[j].at[pl.ds(chunk * block_rows, block_rows), :],
                    in_scr.at[j, slot],
                    in_sem.at[j, slot],
                )

            def out_dma(slot, chunk):
                return pltpu.make_async_copy(
                    out_scr.at[slot],
                    out_ref.at[pl.ds(chunk * block_rows, block_rows), :],
                    out_sem.at[slot],
                )

            for k in range(stages - 1):                      # warm-up
                for j in range(n_in):
                    in_dma(k, k, j).start()

            def loop(chunk, _):
                slot = jax.lax.rem(chunk, stages)
                ahead = chunk + stages - 1

                @pl.when(ahead < n_chunks)
                def _():
                    for j in range(n_in):
                        in_dma(jax.lax.rem(ahead, stages), ahead, j).start()

                for j in range(n_in):
                    in_dma(slot, chunk, j).wait()

                # slot's previous output DMA must land before we overwrite
                @pl.when(chunk >= stages)
                def _():
                    out_dma(slot, chunk - stages).wait()

                scalars = [r[0, 0] for r in scalar_refs]
                if n_in:
                    blocks = [in_scr[j, slot] for j in range(n_in)]
                    val = compute(*scalars, *blocks)
                else:       # generator kernels (store): no input streams
                    val = compute(*scalars, shape=(block_rows, LANES))
                out_scr[slot] = val.astype(dtype)
                out_dma(slot, chunk).start()
                return ()

            jax.lax.fori_loop(0, n_chunks, loop, ())

            for k in range(min(stages, n_chunks)):           # drain
                chunk = n_chunks - 1 - k
                out_dma(chunk % stages, chunk).wait()

        scratch = dict(
            in_scr=pltpu.VMEM((max(n_in, 1), stages, block_rows, LANES),
                              dtype),
            out_scr=pltpu.VMEM((stages, block_rows, LANES), dtype),
            in_sem=pltpu.SemaphoreType.DMA((max(n_in, 1), stages)),
            out_sem=pltpu.SemaphoreType.DMA((stages,)),
        )
        pl.run_scoped(body, **scratch)

    return kernel


def _reduce_pipeline_kernel(compute, n_in: int, *, n_chunks: int,
                            stages: int, block_rows: int, dtype, acc_dtype):
    """Reduction pipeline: out[0,0] = sum_chunks sum(compute(*blocks)).

    The accumulation order is chunk-sequential and independent of
    ``num_stages``, so results are bit-identical across pipeline depths.
    """

    def kernel(*refs):
        in_refs = refs[:n_in]
        out_ref = refs[n_in]

        def body(in_scr, in_sem):
            def in_dma(slot, chunk, j):
                return pltpu.make_async_copy(
                    in_refs[j].at[pl.ds(chunk * block_rows, block_rows), :],
                    in_scr.at[j, slot],
                    in_sem.at[j, slot],
                )

            for k in range(stages - 1):
                for j in range(n_in):
                    in_dma(k, k, j).start()

            def loop(chunk, acc):
                slot = jax.lax.rem(chunk, stages)
                ahead = chunk + stages - 1

                @pl.when(ahead < n_chunks)
                def _():
                    for j in range(n_in):
                        in_dma(jax.lax.rem(ahead, stages), ahead, j).start()

                for j in range(n_in):
                    in_dma(slot, chunk, j).wait()

                blocks = [in_scr[j, slot] for j in range(n_in)]
                return acc + jnp.sum(compute(*blocks).astype(acc_dtype))

            acc0 = jnp.zeros((), acc_dtype)
            out_ref[0, 0] = jax.lax.fori_loop(0, n_chunks, loop, acc0)

        pl.run_scoped(
            body,
            in_scr=pltpu.VMEM((n_in, stages, block_rows, LANES), dtype),
            in_sem=pltpu.SemaphoreType.DMA((n_in, stages)),
        )

    return kernel


# ---------------------------------------------------------------------------
# pallas_call builders
# ---------------------------------------------------------------------------


def _hbm_spec():
    return pl.BlockSpec(memory_space=pltpu.ANY)


def _smem_spec():
    return pl.BlockSpec(memory_space=pltpu.SMEM)


def map_pipeline_call(compute, n_scalars: int, n_in: int, *, x_shape, dtype,
                      num_stages: int = 2, block_rows: int = 64,
                      interpret: bool = False):
    """Build a pipelined elementwise-map ``pallas_call``.

    Inputs/outputs are full HBM-resident (rows, 128) arrays; scalars ride
    in SMEM as (1, 1) blocks.
    """
    rows = x_shape[0]
    block_rows = _fit_block(rows, block_rows)
    n_chunks = rows // block_rows
    stages = max(1, min(num_stages, n_chunks))
    kernel = _map_pipeline_kernel(
        compute, n_scalars, n_in, n_chunks=n_chunks, stages=stages,
        block_rows=block_rows, dtype=dtype)
    return pl.pallas_call(
        kernel,
        in_specs=[_smem_spec()] * n_scalars + [_hbm_spec()] * n_in,
        out_specs=_hbm_spec(),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), dtype),
        interpret=interpret,
    )


def reduce_pipeline_call(compute, n_in: int, *, x_shape, dtype,
                         num_stages: int = 2, block_rows: int = 64,
                         interpret: bool = False):
    """Build a pipelined reduction ``pallas_call`` -> (1, 1) accumulator."""
    rows = x_shape[0]
    block_rows = _fit_block(rows, block_rows)
    n_chunks = rows // block_rows
    stages = max(1, min(num_stages, n_chunks))
    acc_dtype = jnp.float32 if dtype in (jnp.bfloat16, jnp.float16) else dtype
    kernel = _reduce_pipeline_kernel(
        compute, n_in, n_chunks=n_chunks, stages=stages,
        block_rows=block_rows, dtype=dtype, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        in_specs=[_hbm_spec()] * n_in,
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((1, 1), acc_dtype),
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Halo pipeline (stencil kernels)
# ---------------------------------------------------------------------------


def _halo_pipeline_kernel(compute, *, n_chunks: int, stages: int,
                          block0: int, halo: int, in_rest: tuple,
                          out_rest: tuple, dtype):
    """Overlapping-fetch pipeline: chunk ``c`` fetches the padded rows
    ``[c*block0, c*block0 + block0 + 2*halo)`` and writes the disjoint
    output rows ``[c*block0, (c+1)*block0)``.

    ``compute(tile, g0)`` maps a ``(block0 + 2*halo, *in_rest)`` tile plus
    the chunk's global first output row to a ``(block0, *out_rest)``
    block.  Same warm-up/steady/drain schedule as the map pipeline;
    overlapping *reads* are safe (each input row may be fetched by up to
    two chunks) and writes never overlap.
    """
    fetch = block0 + 2 * halo
    in_tail = (slice(None),) * len(in_rest)
    out_tail = (slice(None),) * len(out_rest)

    def kernel(in_ref, out_ref):
        def body(in_scr, out_scr, in_sem, out_sem):
            def in_dma(slot, chunk):
                return pltpu.make_async_copy(
                    in_ref.at[(pl.ds(chunk * block0, fetch),) + in_tail],
                    in_scr.at[slot],
                    in_sem.at[slot],
                )

            def out_dma(slot, chunk):
                return pltpu.make_async_copy(
                    out_scr.at[slot],
                    out_ref.at[(pl.ds(chunk * block0, block0),) + out_tail],
                    out_sem.at[slot],
                )

            for k in range(stages - 1):                      # warm-up
                in_dma(k, k).start()

            def loop(chunk, _):
                slot = jax.lax.rem(chunk, stages)
                ahead = chunk + stages - 1

                @pl.when(ahead < n_chunks)
                def _():
                    in_dma(jax.lax.rem(ahead, stages), ahead).start()

                in_dma(slot, chunk).wait()

                @pl.when(chunk >= stages)
                def _():
                    out_dma(slot, chunk - stages).wait()

                out_scr[slot] = compute(in_scr[slot],
                                        chunk * block0).astype(dtype)
                out_dma(slot, chunk).start()
                return ()

            jax.lax.fori_loop(0, n_chunks, loop, ())

            for k in range(min(stages, n_chunks)):           # drain
                chunk = n_chunks - 1 - k
                out_dma(chunk % stages, chunk).wait()

        pl.run_scoped(
            body,
            in_scr=pltpu.VMEM((stages, fetch) + in_rest, dtype),
            out_scr=pltpu.VMEM((stages, block0) + out_rest, dtype),
            in_sem=pltpu.SemaphoreType.DMA((stages,)),
            out_sem=pltpu.SemaphoreType.DMA((stages,)),
        )

    return kernel


def halo_pipeline_call(compute, *, out_shape, in_shape, dtype, halo: int = 1,
                       num_stages: int = 2, block_rows: int = 8,
                       interpret: bool = False):
    """Build a pipelined halo-exchange ``pallas_call`` (stencil engine).

    ``in_shape`` is the *pre-padded* input: axis 0 must be
    ``out_shape[0] + 2*halo`` (trailing dims are free — the caller decides
    how much spatial padding the compute callback expects).  See the
    module docstring for the full pipeline contract.
    """
    rows = out_shape[0]
    if in_shape[0] != rows + 2 * halo:
        raise ValueError(
            f"padded input axis 0 must be rows + 2*halo = {rows + 2*halo}, "
            f"got {in_shape[0]}")
    block0 = _fit_block(rows, block_rows)
    n_chunks = rows // block0
    stages = max(1, min(num_stages, n_chunks))
    kernel = _halo_pipeline_kernel(
        compute, n_chunks=n_chunks, stages=stages, block0=block0, halo=halo,
        in_rest=tuple(in_shape[1:]), out_rest=tuple(out_shape[1:]),
        dtype=dtype)
    return pl.pallas_call(
        kernel,
        in_specs=[_hbm_spec()],
        out_specs=_hbm_spec(),
        out_shape=jax.ShapeDtypeStruct(tuple(out_shape), dtype),
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Fused multi-kernel chains
# ---------------------------------------------------------------------------
#
# Chaining two stream kernels through HBM costs the intermediate a full
# round trip (1 store + 1 load of every element).  Keeping it in VMEM
# drops those two streams, exactly as the ECM stream count predicts:
#
#   triad  A = B + s*C   {2 loads, 1 store}     5 streams total
#   update A = t*A       {1 load,  1 store}   (3 for triad + 2 for update)
#   fused  A = t*(B+s*C) {2 loads, 1 store}     3 streams total
#
# -> predicted memory-bound speedup 5/3 = 1.67x.


def fused_compute_triad_update(s, t, b, c):
    return t * (b + s * c)


def triad_update_chain_streams() -> tuple[int, int]:
    """(unfused, fused) HBM stream counts per element for triad->update."""
    return 5, 3
