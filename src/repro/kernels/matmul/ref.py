"""Pure-jnp oracle for the blocked matmul kernel."""
import jax.numpy as jnp


def matmul(x, y, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(out_dtype)
