"""MXU-tiled blocked matmul Pallas kernel.

The compute-bound counterpart of the streaming kernels: MXU-aligned
(multiples of 128) VMEM tiles, f32 accumulation in a VMEM scratch across the
sequential K grid dimension.  Used (a) as the compute microbenchmark for the
TPU-ECM model and (b) as an optional drop-in for dense layer contractions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_call(m: int, n: int, k: int, dtype, *,
                bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                bk: int = DEFAULT_BK, out_dtype=None, interpret: bool = False):
    """Build a pallas_call computing (m,k) @ (k,n) with VMEM tiling.

    Grid is (m/bm, n/bn, k/bk) with the K dimension innermost (sequential)
    so the f32 accumulator scratch persists across K steps.
    """
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    out_dtype = out_dtype or dtype
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )
