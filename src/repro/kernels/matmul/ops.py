"""Jitted wrapper for the blocked matmul Pallas kernel, plus the bridge
to the analytic side: :func:`matmul_workload` builds the
``repro.core.workload.MatmulWorkload`` matching this kernel's grid
blocking, and :func:`tuned_blocks` asks the ECM autotuner for the
``(bm, bn, bk)`` to pass back into :func:`matmul`."""
from __future__ import annotations

import functools

import jax

from . import kernel as K


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                              "interpret"))
def matmul(x, y, *, bm=K.DEFAULT_BM, bn=K.DEFAULT_BN, bk=K.DEFAULT_BK,
           out_dtype=None, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    (m, k), (k2, n) = x.shape, y.shape
    assert k == k2
    call = K.matmul_call(m, n, k, x.dtype, bm=bm, bn=bn, bk=bk,
                         out_dtype=out_dtype, interpret=interpret)
    return call(x, y)


def matmul_workload(m: int, n: int, k: int, *, bm=K.DEFAULT_BM,
                    bn=K.DEFAULT_BN, bk=K.DEFAULT_BK):
    """The analytic ECM workload of this kernel at a given blocking —
    lower it on any registry machine (``repro.core.workload_ecm``) or
    hand it to ``autotune.rank``."""
    from repro.core.workload import MATMUL_F32, MatmulWorkload

    return MatmulWorkload(MATMUL_F32, m=m, n=n, k=k,
                          bm=min(bm, m), bn=min(bn, n), bk=min(bk, k))


def tuned_blocks(m: int, n: int, k: int, *,
                 machine: str = "tpu-v5e") -> tuple[int, int, int]:
    """ECM-autotuned ``(bm, bn, bk)`` for :func:`matmul` on a registry
    machine (candidates are restricted to tilings the kernel accepts).

    With the on-disk cache enabled (``repro.core.diskcache``) the pick is
    persisted keyed by the machine's content fingerprint, so a warm
    restart skips the ranking entirely."""
    from repro.core import diskcache
    from repro.core.autotune import rank

    key = ("matmul-blocks", m, n, k)
    hit = diskcache.get("tuned-blocks", key, machine=machine)
    if hit is not None:
        return tuple(hit)
    block = tuple(rank((m, n, k), machine, objective="matmul")[0]["block"])
    diskcache.put("tuned-blocks", key, block, machine=machine)
    return block
