"""Jitted wrapper for the blocked matmul Pallas kernel."""
from __future__ import annotations

import functools

import jax

from . import kernel as K


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                              "interpret"))
def matmul(x, y, *, bm=K.DEFAULT_BM, bn=K.DEFAULT_BN, bk=K.DEFAULT_BK,
           out_dtype=None, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    (m, k), (k2, n) = x.shape, y.shape
    assert k == k2
    call = K.matmul_call(m, n, k, x.dtype, bm=bm, bn=bn, bk=bk,
                         out_dtype=out_dtype, interpret=interpret)
    return call(x, y)
