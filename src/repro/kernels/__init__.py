"""Pallas TPU kernels for the performance-critical compute layers.

Each kernel family ships three files (see EXAMPLE.md): ``kernel.py`` with the
``pl.pallas_call`` + explicit ``BlockSpec`` VMEM tiling, ``ops.py`` with the
jitted public wrapper, and ``ref.py`` with the pure-jnp oracle used by the
allclose test sweeps.

* ``stream``    -- the paper's Table I streaming microbenchmarks, TPU-native
* ``matmul``    -- MXU-tiled blocked matmul (compute microbenchmark)
* ``attention`` -- blockwise flash attention (VMEM-resident score tiles)
"""
from . import stream
from . import matmul
from . import attention
