"""Pallas TPU kernels for the performance-critical compute layers.

Each kernel family ships three files (walkthrough in
``docs/kernel-authoring.md``): ``kernel.py`` with the ``pl.pallas_call``
bodies and builders, ``ops.py`` with the jitted public wrapper, and
``ref.py`` with the pure-jnp oracle the test sweeps compare against.

* ``stream``    -- the paper's Table I streaming microbenchmarks, TPU-native
* ``stencil``   -- Jacobi 2D 5-point / 3D 7-point (layer-condition ECM,
  halo-aware DMA pipeline)
* ``matmul``    -- MXU-tiled blocked matmul (compute microbenchmark)
* ``attention`` -- blockwise flash attention (VMEM-resident score tiles)

The multi-buffered HBM->VMEM DMA engine the stream and stencil families
share lives in ``pipeline.py`` — see its docstring for the block-shape /
halo / ``num_stages`` contract.
"""
from . import stream
from . import stencil
from . import matmul
from . import attention
