"""Pure-jnp oracle: exact softmax attention with optional causal mask."""
from __future__ import annotations

import jax.numpy as jnp


def attention(q, k, v, *, causal=True, scale=None):
    """q: (BH, Sq, d); k, v: (BH, Sk, d) — GQA pre-expanded."""
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool), k=sk - sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
