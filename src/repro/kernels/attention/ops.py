"""Jitted wrappers for the flash attention kernel (GQA-aware), plus the
bridge to the analytic side: :func:`attention_workload` builds the
``repro.core.workload.AttentionWorkload`` matching this kernel's tiling,
and :func:`tuned_blocks` asks the ECM autotuner for the ``(bq, bk)`` to
pass back into :func:`flash_attention`."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as K


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, bq=K.DEFAULT_BQ, bk=K.DEFAULT_BK,
                    interpret=None):
    """q: (B, Sq, H, d); k, v: (B, Sk, Hkv, d).  Returns (B, Sq, H, d).

    GQA is handled by repeating KV heads to match Q heads before the fused
    (batch*heads) kernel grid.  Causal masking requires Sq == Sk (prefill);
    decode uses ``causal=False`` with a pre-masked/valid cache.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    if causal:
        assert sq == sk, "causal masking assumes aligned q/k positions"
    if hkv != h:
        assert h % hkv == 0
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    call = K.flash_attention_call(b * h, sq, sk, d, q.dtype, bq=bq, bk=bk,
                                  causal=causal, interpret=interpret)
    out = call(qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


def attention_workload(sq: int, sk: int, d: int, *, bq=K.DEFAULT_BQ,
                       bk=K.DEFAULT_BK, causal: bool = True):
    """The analytic ECM workload of this kernel at a given tiling (heads
    multiply the work; they do not change the per-line model)."""
    from repro.core.workload import FLASH_ATTENTION_F32, AttentionWorkload

    return AttentionWorkload(FLASH_ATTENTION_F32, sq=sq, skv=sk, d=d,
                             bq=min(bq, sq), bkv=min(bk, sk), causal=causal)


def tuned_blocks(sq: int, sk: int, d: int, *, causal: bool = True,
                 machine: str = "tpu-v5e") -> tuple[int, int]:
    """ECM-autotuned ``(bq, bk)`` for :func:`flash_attention` on a
    registry machine (candidates are tilings the kernel accepts).

    With the on-disk cache enabled (``repro.core.diskcache``) the pick is
    persisted keyed by the machine's content fingerprint, so a warm
    restart skips the ranking entirely."""
    from repro.core import diskcache
    from repro.core.autotune import rank

    key = ("attention-blocks", sq, sk, d, bool(causal))
    hit = diskcache.get("tuned-blocks", key, machine=machine)
    if hit is not None:
        return tuple(hit)
    block = tuple(rank((sq, sk, d), machine, objective="attention",
                       causal=causal)[0]["block"])
    diskcache.put("tuned-blocks", key, block, machine=machine)
    return block
