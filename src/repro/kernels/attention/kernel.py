"""Blockwise (flash-style) attention Pallas kernel for TPU.

Online-softmax attention computed over KV blocks with running (m, l, acc)
state in VMEM scratch — the standard memory-hierarchy-aware formulation,
which is exactly the paper's insight (decompose into compute + hierarchy
streams, keep the working set in the fast level) applied to attention:
instead of materialising the (Sq, Sk) score matrix in HBM, scores live in
VMEM one (bq, bk) tile at a time.

Supports causal masking (block-skipping for fully-masked tiles) and GQA via
the q-heads-per-kv-head index map.  f32 accumulation regardless of input
dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 n_kv: int, bq: int, bk: int, causal: bool, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _block():
        q = q_ref[0, ...].astype(jnp.float32) * scale      # (bq, d)
        k = k_ref[0, ...].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, ...].astype(jnp.float32)              # (bk, d)
        acc_ref[...] = alpha * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip tiles strictly above the diagonal
        @pl.when(qi * bq + bq - 1 >= ki * bk)
        def _maybe():
            _block()
    else:
        _block()

    @pl.when(ki == n_kv - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_call(
    batch_heads: int, sq: int, sk: int, d: int, dtype, *,
    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
    causal: bool = True, scale: float | None = None,
    interpret: bool = False,
):
    """Build a pallas_call for attention with fused heads: inputs are
    q (BH, Sq, d), k/v (BH, Sk, d) with GQA pre-expanded in the wrapper."""
    bq, bk = min(bq, sq), min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0
    scale = scale if scale is not None else d ** -0.5
    n_kv = sk // bk
    kern = functools.partial(
        _attn_kernel, n_kv=n_kv, bq=bq, bk=bk, causal=causal, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(batch_heads, 1, sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, _, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, _, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, _, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, _, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch_heads, sq, d), dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )
