"""Pallas TPU kernels for the paper's streaming microbenchmarks (§V).

TPU adaptation of the paper's Table I kernel set.  The cache line (64 B)
becomes a VMEM block (``BLOCK`` elements, a multiple of the 8x128 VPU tile);
the grid streams blocks HBM -> VMEM -> VREG, processes them on the VPU and
streams results back.  Because Pallas ``out_specs`` write whole blocks, the
output stream never reads its destination: the paper's *non-temporal store*
(§VII-E) is the structural default on TPU — the write-allocate/RFO variant
is modelled by ``*_inplace`` wrappers that alias input and output
(read-modify-write), see ``ops.py``.

Scalars arrive as (1, 1) SMEM-style blocks so they stay runtime values.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..pipeline import _fit_block

#: default block: 8 sublanes x 128 lanes x 8 rows = fits VMEM comfortably and
#: keeps the MXU/VPU tile alignment (multiples of (8, 128)).
BLOCK_ROWS = 64
BLOCK_COLS = 128


def _grid(n_rows: int, block_rows: int) -> tuple[int]:
    assert n_rows % block_rows == 0, (n_rows, block_rows)
    return (n_rows // block_rows,)


def _io_spec(block_rows: int):
    return pl.BlockSpec((block_rows, BLOCK_COLS), lambda i: (i, 0))


def _scalar_spec():
    return pl.BlockSpec((1, 1), lambda i: (0, 0))


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------


def _copy_kernel(b_ref, a_ref):
    a_ref[...] = b_ref[...]


def _store_kernel(s_ref, a_ref):
    a_ref[...] = jnp.full_like(a_ref, s_ref[0, 0])


def _update_kernel(s_ref, a_in_ref, a_ref):
    a_ref[...] = s_ref[0, 0] * a_in_ref[...]


def _striad_kernel(s_ref, b_ref, c_ref, a_ref):
    a_ref[...] = b_ref[...] + s_ref[0, 0] * c_ref[...]


def _schoenauer_kernel(b_ref, c_ref, d_ref, a_ref):
    a_ref[...] = b_ref[...] + c_ref[...] * d_ref[...]


def _load_kernel(a_ref, o_ref):
    """s += A[i] — block-level partial sums, reduced across the sequential
    grid into a single (1, 1) output."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0, 0] += jnp.sum(a_ref[...].astype(o_ref.dtype))


def _ddot_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0, 0] += jnp.sum((a_ref[...] * b_ref[...]).astype(o_ref.dtype))


# ---------------------------------------------------------------------------
# pallas_call builders
# ---------------------------------------------------------------------------


def _compiler_params(semantics: str, interpret: bool):
    """Declare grid-dimension semantics to Mosaic: ``parallel`` grid steps
    may be reordered/overlapped by the pipeliner, ``arbitrary`` ones are
    sequential (reductions).  Ignored (but accepted) in interpret mode."""
    if interpret:
        return None
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.TPUCompilerParams(dimension_semantics=(semantics,))


def _streaming_call(body, n_in: int, *, scalar_first: bool, interpret: bool,
                    block_rows: int, x_shape, dtype):
    rows = x_shape[0]
    block_rows = _fit_block(rows, block_rows)
    in_specs = ([_scalar_spec()] if scalar_first else []) + [
        _io_spec(block_rows) for _ in range(n_in)
    ]
    return pl.pallas_call(
        body,
        grid=_grid(rows, block_rows),
        in_specs=in_specs,
        out_specs=_io_spec(block_rows),
        out_shape=jax.ShapeDtypeStruct(x_shape, dtype),
        interpret=interpret,
        compiler_params=_compiler_params("parallel", interpret),
    )


def copy_call(x_shape, dtype, *, block_rows=BLOCK_ROWS, interpret=False):
    return _streaming_call(_copy_kernel, 1, scalar_first=False,
                           interpret=interpret, block_rows=block_rows,
                           x_shape=x_shape, dtype=dtype)


def store_call(x_shape, dtype, *, block_rows=BLOCK_ROWS, interpret=False):
    return _streaming_call(_store_kernel, 0, scalar_first=True,
                           interpret=interpret, block_rows=block_rows,
                           x_shape=x_shape, dtype=dtype)


def update_call(x_shape, dtype, *, block_rows=BLOCK_ROWS, interpret=False):
    return _streaming_call(_update_kernel, 1, scalar_first=True,
                           interpret=interpret, block_rows=block_rows,
                           x_shape=x_shape, dtype=dtype)


def striad_call(x_shape, dtype, *, block_rows=BLOCK_ROWS, interpret=False):
    return _streaming_call(_striad_kernel, 2, scalar_first=True,
                           interpret=interpret, block_rows=block_rows,
                           x_shape=x_shape, dtype=dtype)


def schoenauer_call(x_shape, dtype, *, block_rows=BLOCK_ROWS, interpret=False):
    return _streaming_call(_schoenauer_kernel, 3, scalar_first=False,
                           interpret=interpret, block_rows=block_rows,
                           x_shape=x_shape, dtype=dtype)


def _reduce_call(body, n_in, x_shape, dtype, *, block_rows, interpret):
    rows = x_shape[0]
    block_rows = _fit_block(rows, block_rows)
    acc_dtype = jnp.float32 if dtype in (jnp.bfloat16, jnp.float16) else dtype
    return pl.pallas_call(
        body,
        grid=_grid(rows, block_rows),
        in_specs=[_io_spec(block_rows) for _ in range(n_in)],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), acc_dtype),
        interpret=interpret,
        compiler_params=_compiler_params("arbitrary", interpret),
    )


def load_call(x_shape, dtype, *, block_rows=BLOCK_ROWS, interpret=False):
    return _reduce_call(_load_kernel, 1, x_shape, dtype,
                        block_rows=block_rows, interpret=interpret)


def ddot_call(x_shape, dtype, *, block_rows=BLOCK_ROWS, interpret=False):
    return _reduce_call(_ddot_kernel, 2, x_shape, dtype,
                        block_rows=block_rows, interpret=interpret)
