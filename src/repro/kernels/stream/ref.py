"""Pure-jnp oracles for the streaming kernels (Table I loop bodies)."""
from __future__ import annotations

import jax.numpy as jnp


def load(a):
    """s += A[i]"""
    return jnp.sum(a, dtype=jnp.float32 if a.dtype == jnp.bfloat16 else a.dtype)


def ddot(a, b):
    """s += A[i] * B[i]"""
    acc = jnp.float32 if a.dtype == jnp.bfloat16 else a.dtype
    return jnp.sum((a * b).astype(acc))


def store(s, shape, dtype):
    """A[i] = s"""
    return jnp.full(shape, s, dtype=dtype)


def update(s, a):
    """A[i] = s * A[i]"""
    return (s * a).astype(a.dtype)


def copy(b):
    """A[i] = B[i]"""
    return b


def striad(s, b, c):
    """A[i] = B[i] + s * C[i]"""
    return (b + s * c).astype(b.dtype)


def schoenauer(b, c, d):
    """A[i] = B[i] + C[i] * D[i]"""
    return (b + c * d).astype(b.dtype)
