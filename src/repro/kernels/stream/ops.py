"""Jitted public wrappers for the streaming Pallas kernels.

``interpret`` defaults to True on CPU backends (this container) so the
kernel bodies execute in Python for correctness validation; on a real TPU
backend the same code lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as K


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _as2d(x):
    """Reshape a flat stream to (rows, BLOCK_COLS)."""
    n = x.shape[0] if x.ndim == 1 else x.shape[0] * x.shape[1]
    rows = n // K.BLOCK_COLS
    return x.reshape(rows, K.BLOCK_COLS)


def _scal(s, dtype):
    return jnp.asarray(s, dtype=dtype).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def load(a, *, block_rows=K.BLOCK_ROWS, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    a2 = _as2d(a)
    out = K.load_call(a2.shape, a2.dtype, block_rows=block_rows,
                      interpret=interpret)(a2)
    return out[0, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ddot(a, b, *, block_rows=K.BLOCK_ROWS, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    a2, b2 = _as2d(a), _as2d(b)
    out = K.ddot_call(a2.shape, a2.dtype, block_rows=block_rows,
                      interpret=interpret)(a2, b2)
    return out[0, 0]


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "block_rows", "interpret"))
def store(s, shape, dtype, *, block_rows=K.BLOCK_ROWS, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    rows = (shape[0] * (shape[1] if len(shape) > 1 else 1)) // K.BLOCK_COLS
    out = K.store_call((rows, K.BLOCK_COLS), dtype, block_rows=block_rows,
                       interpret=interpret)(_scal(s, dtype))
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def update(s, a, *, block_rows=K.BLOCK_ROWS, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    a2 = _as2d(a)
    out = K.update_call(a2.shape, a2.dtype, block_rows=block_rows,
                        interpret=interpret)(_scal(s, a2.dtype), a2)
    return out.reshape(a.shape)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def copy(b, *, block_rows=K.BLOCK_ROWS, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    b2 = _as2d(b)
    out = K.copy_call(b2.shape, b2.dtype, block_rows=block_rows,
                      interpret=interpret)(b2)
    return out.reshape(b.shape)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def striad(s, b, c, *, block_rows=K.BLOCK_ROWS, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    b2, c2 = _as2d(b), _as2d(c)
    out = K.striad_call(b2.shape, b2.dtype, block_rows=block_rows,
                        interpret=interpret)(_scal(s, b2.dtype), b2, c2)
    return out.reshape(b.shape)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def schoenauer(b, c, d, *, block_rows=K.BLOCK_ROWS, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    b2, c2, d2 = _as2d(b), _as2d(c), _as2d(d)
    out = K.schoenauer_call(b2.shape, b2.dtype, block_rows=block_rows,
                            interpret=interpret)(b2, c2, d2)
    return out.reshape(b.shape)


# ---------------------------------------------------------------------------
# RFO-analogue variants (§VII-E inverted): force a read-modify-write of the
# output stream by aliasing it as an input, i.e. the "regular store" case of
# the paper.  Used by the fig12 TPU benchmark to contrast traffic.
# ---------------------------------------------------------------------------


@jax.jit
def striad_rmw(s, a, b, c):
    """A[i] = B[i] + s*C[i], but reading A first (write-allocate analogue)."""
    return (a * 0 + b + s * c).astype(a.dtype)
