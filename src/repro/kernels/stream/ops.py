"""Jitted public wrappers for the streaming Pallas kernels.

``interpret`` defaults to True on CPU backends (this container) so the
kernel bodies execute in Python for correctness validation; on a real TPU
backend the same code lowers to Mosaic.

Every op takes ``num_stages``: ``None`` uses the classic one-block-per-
grid-step kernels (the implicit pallas_call pipeline); an integer routes
through the shared multi-buffered DMA pipeline engine with that many VMEM
buffers per stream.  The pipeline contract — block-shape fitting,
``num_stages`` semantics (1 = serial / no overlap, 2 = double buffering,
...), bit-identity across depths, and the halo handling used by the
stencil family — is documented once, in :mod:`repro.kernels.pipeline`
where the engine lives; these wrappers only pick a compute function and
one of its builders (``map_pipeline_call`` for elementwise streams,
``reduce_pipeline_call`` for ``load``/``ddot``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import pipeline as P
from . import kernel as K


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _as2d(x):
    """Reshape a flat stream to (rows, BLOCK_COLS)."""
    n = x.shape[0] if x.ndim == 1 else x.shape[0] * x.shape[1]
    rows = n // K.BLOCK_COLS
    return x.reshape(rows, K.BLOCK_COLS)


def _scal(s, dtype):
    return jnp.asarray(s, dtype=dtype).reshape(1, 1)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "num_stages"))
def load(a, *, block_rows=K.BLOCK_ROWS, interpret=None, num_stages=None):
    interpret = _default_interpret() if interpret is None else interpret
    a2 = _as2d(a)
    if num_stages is not None:
        out = P.reduce_pipeline_call(
            lambda x: x, 1, x_shape=a2.shape, dtype=a2.dtype,
            num_stages=num_stages, block_rows=block_rows,
            interpret=interpret)(a2)
    else:
        out = K.load_call(a2.shape, a2.dtype, block_rows=block_rows,
                          interpret=interpret)(a2)
    return out[0, 0]


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "num_stages"))
def ddot(a, b, *, block_rows=K.BLOCK_ROWS, interpret=None, num_stages=None):
    interpret = _default_interpret() if interpret is None else interpret
    a2, b2 = _as2d(a), _as2d(b)
    if num_stages is not None:
        out = P.reduce_pipeline_call(
            lambda x, y: x * y, 2, x_shape=a2.shape, dtype=a2.dtype,
            num_stages=num_stages, block_rows=block_rows,
            interpret=interpret)(a2, b2)
    else:
        out = K.ddot_call(a2.shape, a2.dtype, block_rows=block_rows,
                          interpret=interpret)(a2, b2)
    return out[0, 0]


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "block_rows",
                                             "interpret", "num_stages"))
def store(s, shape, dtype, *, block_rows=K.BLOCK_ROWS, interpret=None,
          num_stages=None):
    interpret = _default_interpret() if interpret is None else interpret
    rows = (shape[0] * (shape[1] if len(shape) > 1 else 1)) // K.BLOCK_COLS
    if num_stages is not None:
        out = P.map_pipeline_call(
            lambda sv, *, shape: jnp.full(shape, sv, dtype), 1, 0,
            x_shape=(rows, K.BLOCK_COLS), dtype=dtype,
            num_stages=num_stages, block_rows=block_rows,
            interpret=interpret)(_scal(s, dtype))
    else:
        out = K.store_call((rows, K.BLOCK_COLS), dtype, block_rows=block_rows,
                           interpret=interpret)(_scal(s, dtype))
    return out.reshape(shape)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "num_stages"))
def update(s, a, *, block_rows=K.BLOCK_ROWS, interpret=None, num_stages=None):
    interpret = _default_interpret() if interpret is None else interpret
    a2 = _as2d(a)
    if num_stages is not None:
        out = P.map_pipeline_call(
            lambda sv, x: sv * x, 1, 1, x_shape=a2.shape, dtype=a2.dtype,
            num_stages=num_stages, block_rows=block_rows,
            interpret=interpret)(_scal(s, a2.dtype), a2)
    else:
        out = K.update_call(a2.shape, a2.dtype, block_rows=block_rows,
                            interpret=interpret)(_scal(s, a2.dtype), a2)
    return out.reshape(a.shape)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "num_stages"))
def copy(b, *, block_rows=K.BLOCK_ROWS, interpret=None, num_stages=None):
    interpret = _default_interpret() if interpret is None else interpret
    b2 = _as2d(b)
    if num_stages is not None:
        out = P.map_pipeline_call(
            lambda x: x, 0, 1, x_shape=b2.shape, dtype=b2.dtype,
            num_stages=num_stages, block_rows=block_rows,
            interpret=interpret)(b2)
    else:
        out = K.copy_call(b2.shape, b2.dtype, block_rows=block_rows,
                          interpret=interpret)(b2)
    return out.reshape(b.shape)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "num_stages"))
def striad(s, b, c, *, block_rows=K.BLOCK_ROWS, interpret=None,
           num_stages=None):
    interpret = _default_interpret() if interpret is None else interpret
    b2, c2 = _as2d(b), _as2d(c)
    if num_stages is not None:
        out = P.map_pipeline_call(
            lambda sv, x, y: x + sv * y, 1, 2, x_shape=b2.shape,
            dtype=b2.dtype, num_stages=num_stages, block_rows=block_rows,
            interpret=interpret)(_scal(s, b2.dtype), b2, c2)
    else:
        out = K.striad_call(b2.shape, b2.dtype, block_rows=block_rows,
                            interpret=interpret)(_scal(s, b2.dtype), b2, c2)
    return out.reshape(b.shape)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "num_stages"))
def schoenauer(b, c, d, *, block_rows=K.BLOCK_ROWS, interpret=None,
               num_stages=None):
    interpret = _default_interpret() if interpret is None else interpret
    b2, c2, d2 = _as2d(b), _as2d(c), _as2d(d)
    if num_stages is not None:
        out = P.map_pipeline_call(
            lambda x, y, z: x + y * z, 0, 3, x_shape=b2.shape,
            dtype=b2.dtype, num_stages=num_stages, block_rows=block_rows,
            interpret=interpret)(b2, c2, d2)
    else:
        out = K.schoenauer_call(b2.shape, b2.dtype, block_rows=block_rows,
                                interpret=interpret)(b2, c2, d2)
    return out.reshape(b.shape)


# ---------------------------------------------------------------------------
# Fused multi-kernel chains (intermediate stays in VMEM)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "num_stages"))
def triad_update(s, t, b, c, *, block_rows=K.BLOCK_ROWS, interpret=None,
                 num_stages=2):
    """Fused triad->update chain: ``A[i] = t * (B[i] + s*C[i])``.

    The triad result never round-trips through HBM: 3 streams instead of
    the 5 of ``update(t, striad(s, b, c))`` — the ECM stream count
    predicts the 5/3 memory-bound speedup (see ``pipeline.py``).
    """
    interpret = _default_interpret() if interpret is None else interpret
    b2, c2 = _as2d(b), _as2d(c)
    out = P.map_pipeline_call(
        P.fused_compute_triad_update, 2, 2, x_shape=b2.shape, dtype=b2.dtype,
        num_stages=num_stages, block_rows=block_rows, interpret=interpret,
    )(_scal(s, b2.dtype), _scal(t, b2.dtype), b2, c2)
    return out.reshape(b.shape)


def triad_update_unfused(s, t, b, c, *, block_rows=K.BLOCK_ROWS,
                         interpret=None, num_stages=2):
    """Reference chain through HBM: two kernel launches, 5 streams."""
    a = striad(s, b, c, block_rows=block_rows, interpret=interpret,
               num_stages=num_stages)
    return update(t, a, block_rows=block_rows, interpret=interpret,
                  num_stages=num_stages)


# ---------------------------------------------------------------------------
# RFO-analogue variants (§VII-E inverted): force a read-modify-write of the
# output stream by aliasing it as an input, i.e. the "regular store" case of
# the paper.  Used by the fig12 TPU benchmark to contrast traffic.
# ---------------------------------------------------------------------------


@jax.jit
def striad_rmw(s, a, b, c):
    """A[i] = B[i] + s*C[i], but reading A first (write-allocate analogue)."""
    return (a * 0 + b + s * c).astype(a.dtype)
