"""Pure-jnp oracles for the Jacobi stencil kernels.

Semantics (shared with ``kernel.py`` / ``ops.py``, bit-for-bit):

* interior: ``out = c0*a + c1*(sum of the 2*dim nearest neighbours)``,
  with the neighbour sum associated per axis, outermost axis first:
  2D ``(n+s) + (w+e)``, 3D ``((d+u) + (n+s)) + (w+e)``;
* physical boundary (any index at 0 or the last position of its axis):
  ``out = a`` (Dirichlet copy — the classic Jacobi sweep keeps boundary
  values fixed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _edge_mask(shape) -> jnp.ndarray:
    masks = []
    for ax, n in enumerate(shape):
        idx = jax.lax.broadcasted_iota(jnp.int32, shape, ax)
        masks.append((idx == 0) | (idx == n - 1))
    out = masks[0]
    for m in masks[1:]:
        out = out | m
    return out


def jacobi2d(a, c0=0.0, c1=0.25):
    """b[j,i] = c0*a[j,i] + c1*(a[j-1,i] + a[j+1,i] + a[j,i-1] + a[j,i+1])
    on the interior; b = a on the boundary."""
    p = jnp.pad(a, 1)
    val = c0 * a + c1 * ((p[:-2, 1:-1] + p[2:, 1:-1])
                         + (p[1:-1, :-2] + p[1:-1, 2:]))
    return jnp.where(_edge_mask(a.shape), a, val).astype(a.dtype)


def jacobi3d(a, c0=0.0, c1=1.0 / 6.0):
    """b[k,j,i] = c0*a[k,j,i] + c1*(sum of the 6 nearest neighbours) on the
    interior; b = a on the boundary."""
    p = jnp.pad(a, 1)
    val = c0 * a + c1 * (
        ((p[:-2, 1:-1, 1:-1] + p[2:, 1:-1, 1:-1])
         + (p[1:-1, :-2, 1:-1] + p[1:-1, 2:, 1:-1]))
        + (p[1:-1, 1:-1, :-2] + p[1:-1, 1:-1, 2:]))
    return jnp.where(_edge_mask(a.shape), a, val).astype(a.dtype)
