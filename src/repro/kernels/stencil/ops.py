"""Jitted public wrappers for the Jacobi stencil Pallas kernels.

``interpret`` defaults to True on CPU backends (this container); on a real
TPU backend the same code lowers to Mosaic.

``num_stages`` follows the stream-ops convention: ``None`` runs the
single-step whole-array kernel (validation baseline); an integer routes
through the halo-aware multi-buffered DMA pipeline
(:func:`repro.kernels.pipeline.halo_pipeline_call`) with that many VMEM
buffers per stream (1 = serial / no overlap, 2 = double buffering, ...).
Outputs are bit-identical across every ``num_stages`` setting and to the
``ref.py`` oracles — enforced by ``tests/test_stencil.py``.

The wrappers pad the input with one zero ring before the pallas_call so
every pipeline fetch is in bounds; the kernels mask physical-boundary
points back to the input value (Dirichlet copy), making the result
independent of the pad contents.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import pipeline as P
from . import kernel as K


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("c0", "c1", "num_stages",
                                             "block_rows", "interpret"))
def jacobi2d(a, *, c0: float = 0.0, c1: float = 0.25, num_stages=None,
             block_rows: int = K.BLOCK_ROWS, interpret=None):
    """2D 5-point Jacobi sweep: ``b = c0*a + c1*(N+S+W+E)`` interior,
    ``b = a`` on the boundary."""
    interpret = _default_interpret() if interpret is None else interpret
    H, W = a.shape
    p = jnp.pad(a, 1)
    if num_stages is None:
        return K.jacobi2d_call((H, W), a.dtype, c0=c0, c1=c1,
                               interpret=interpret)(p)
    compute = functools.partial(K.five_point_block, H=H, W=W, c0=c0, c1=c1)
    return P.halo_pipeline_call(
        compute, out_shape=(H, W), in_shape=p.shape, dtype=a.dtype, halo=1,
        num_stages=num_stages, block_rows=block_rows, interpret=interpret,
    )(p)


@functools.partial(jax.jit, static_argnames=("c0", "c1", "num_stages",
                                             "block_rows", "interpret"))
def jacobi3d(a, *, c0: float = 0.0, c1: float = 1.0 / 6.0, num_stages=None,
             block_rows: int = K.BLOCK_ROWS, interpret=None):
    """3D 7-point Jacobi sweep over (D, H, W); the pipeline chunks along
    the outermost (layer) axis with a one-layer halo."""
    interpret = _default_interpret() if interpret is None else interpret
    D, H, W = a.shape
    p = jnp.pad(a, 1)
    if num_stages is None:
        return K.jacobi3d_call((D, H, W), a.dtype, c0=c0, c1=c1,
                               interpret=interpret)(p)
    compute = functools.partial(K.seven_point_block, D=D, H=H, W=W,
                                c0=c0, c1=c1)
    return P.halo_pipeline_call(
        compute, out_shape=(D, H, W), in_shape=p.shape, dtype=a.dtype,
        halo=1, num_stages=num_stages, block_rows=block_rows,
        interpret=interpret,
    )(p)
