"""Pallas TPU kernels for the Jacobi stencils (2D 5-point, 3D 7-point).

Two execution paths share the tile compute functions below:

* ``jacobi2d_call`` / ``jacobi3d_call`` — single-step whole-array kernels
  (the ``num_stages=None`` baseline: the padded array lands in VMEM in
  one block, validation-sized problems only);
* the halo pipeline — ``ops.py`` routes ``num_stages=k`` through
  :func:`repro.kernels.pipeline.halo_pipeline_call`, which streams
  overlapping ``(block_rows + 2, ...)`` tiles of the padded array
  HBM->VMEM with ``k`` buffers and writes disjoint ``block_rows`` output
  chunks (see the pipeline-contract docstring there).

Inputs are pre-padded with one zero ring (``jnp.pad(a, 1)``) by the
``ops.py`` wrappers, so every tile fetch is in bounds without clamping;
the compute functions mask physical-boundary points back to the centre
value (Dirichlet copy), which makes the result independent of the pad
contents and bit-identical to ``ref.py``.

Shapes are unconstrained in interpret mode; on a Mosaic backend the
trailing dim is padded to the 128-lane tile by the compiler (stencil
widths are arbitrary, unlike the lane-aligned stream kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: default pipeline chunk: 8 rows (2D) / 8 layers (3D) per DMA.
BLOCK_ROWS = 8


# ---------------------------------------------------------------------------
# tile compute (shared by the whole-array kernels and the halo pipeline)
# ---------------------------------------------------------------------------


def five_point_block(tile, g0, *, H: int, W: int, c0: float, c1: float):
    """5-point stencil on a padded row tile.

    ``tile``: ``(n + 2, W + 2)`` slice of the padded array whose first row
    is padded row ``g0``; returns the ``(n, W)`` output rows ``g0 ..
    g0+n-1``.  ``g0`` may be traced (the pipeline's chunk offset).
    """
    n = tile.shape[0] - 2
    c = tile[1:1 + n, 1:W + 1]
    up = tile[0:n, 1:W + 1]
    dn = tile[2:2 + n, 1:W + 1]
    lf = tile[1:1 + n, 0:W]
    rt = tile[1:1 + n, 2:W + 2]
    val = c0 * c + c1 * ((up + dn) + (lf + rt))
    rows = g0 + jax.lax.broadcasted_iota(jnp.int32, (n, W), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, W), 1)
    edge = (rows == 0) | (rows == H - 1) | (cols == 0) | (cols == W - 1)
    return jnp.where(edge, c, val)


def seven_point_block(tile, g0, *, D: int, H: int, W: int,
                      c0: float, c1: float):
    """7-point stencil on a padded layer tile: ``(n + 2, H + 2, W + 2)``
    -> output layers ``g0 .. g0+n-1`` of shape ``(n, H, W)``."""
    n = tile.shape[0] - 2
    c = tile[1:1 + n, 1:H + 1, 1:W + 1]
    kd = tile[0:n, 1:H + 1, 1:W + 1]
    ku = tile[2:2 + n, 1:H + 1, 1:W + 1]
    jn_ = tile[1:1 + n, 0:H, 1:W + 1]
    js = tile[1:1 + n, 2:H + 2, 1:W + 1]
    iw = tile[1:1 + n, 1:H + 1, 0:W]
    ie = tile[1:1 + n, 1:H + 1, 2:W + 2]
    val = c0 * c + c1 * (((kd + ku) + (jn_ + js)) + (iw + ie))
    ks = g0 + jax.lax.broadcasted_iota(jnp.int32, (n, H, W), 0)
    js_i = jax.lax.broadcasted_iota(jnp.int32, (n, H, W), 1)
    is_i = jax.lax.broadcasted_iota(jnp.int32, (n, H, W), 2)
    edge = ((ks == 0) | (ks == D - 1) | (js_i == 0) | (js_i == H - 1)
            | (is_i == 0) | (is_i == W - 1))
    return jnp.where(edge, c, val)


# ---------------------------------------------------------------------------
# whole-array pallas_call builders (num_stages=None baseline)
# ---------------------------------------------------------------------------


def _jacobi2d_kernel(p_ref, o_ref, *, H, W, c0, c1):
    o_ref[...] = five_point_block(
        p_ref[...], 0, H=H, W=W, c0=c0, c1=c1).astype(o_ref.dtype)


def _jacobi3d_kernel(p_ref, o_ref, *, D, H, W, c0, c1):
    o_ref[...] = seven_point_block(
        p_ref[...], 0, D=D, H=H, W=W, c0=c0, c1=c1).astype(o_ref.dtype)


def jacobi2d_call(shape, dtype, *, c0: float, c1: float,
                  interpret: bool = False):
    """Single-step kernel over the whole padded array: (H+2, W+2) -> (H, W)."""
    H, W = shape
    return pl.pallas_call(
        functools.partial(_jacobi2d_kernel, H=H, W=W, c0=c0, c1=c1),
        out_shape=jax.ShapeDtypeStruct((H, W), dtype),
        interpret=interpret,
    )


def jacobi3d_call(shape, dtype, *, c0: float, c1: float,
                  interpret: bool = False):
    """Single-step kernel over the whole padded array: (D+2, H+2, W+2) ->
    (D, H, W)."""
    D, H, W = shape
    return pl.pallas_call(
        functools.partial(_jacobi3d_kernel, D=D, H=H, W=W, c0=c0, c1=c1),
        out_shape=jax.ShapeDtypeStruct((D, H, W), dtype),
        interpret=interpret,
    )
