"""Training/serving runtime: jitted steps, state, fault-tolerant driver."""
from .steps import (
    init_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    state_spec,
)
from .driver import Trainer, TrainerConfig
from .elastic import remesh_state

__all__ = [
    "init_state",
    "make_prefill_step",
    "make_serve_step",
    "make_train_step",
    "state_spec",
    "Trainer",
    "TrainerConfig",
    "remesh_state",
]
