"""Elastic scaling: re-mesh a live training state onto a different mesh.

When nodes are lost (or gained) the driver rebuilds the mesh from the
surviving devices, recomputes every sharding from the *logical* axis rules
(the same rules — the mesh is an input, not baked into the model, which is
the ECM paper's machine-model-as-input lesson applied to distribution), and
resharded the state with ``jax.device_put``.  The step function is then
re-jitted for the new mesh by the caller.

On a real cluster the surviving hosts coordinate through the checkpoint
store: if the state is unreachable (host died holding unreplicated shards)
the driver falls back to checkpoint-restart instead.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.dist.sharding import ShardingProfile, param_shardings


def remesh_state(state, state_spec_tree, new_mesh: Mesh,
                 profile: ShardingProfile):
    """Reshard ``state`` (array pytree) onto ``new_mesh``."""
    shardings = param_shardings(state_spec_tree, new_mesh, profile)
    flat_sh = jax.tree.flatten(shardings,
                               is_leaf=lambda x: hasattr(x, "spec"))[0]
    flat_st, treedef = jax.tree.flatten(state)
    assert len(flat_sh) == len(flat_st), (len(flat_sh), len(flat_st))
    out = [jax.device_put(x, s) for x, s in zip(flat_st, flat_sh)]
    return jax.tree.unflatten(treedef, out)


def shrink_mesh(mesh: Mesh, lost_fraction_axis: str = "data") -> Mesh:
    """Build the largest power-of-two sub-mesh after losing one slice of
    ``lost_fraction_axis`` (simulated node failure)."""
    names = mesh.axis_names
    shape = dict(zip(names, mesh.devices.shape))
    if lost_fraction_axis not in shape:
        raise ValueError(
            f"mesh has no axis {lost_fraction_axis!r} (axes: {names})")
    if shape[lost_fraction_axis] <= 1:
        raise ValueError(f"cannot shrink axis {lost_fraction_axis} below 1")
    shape[lost_fraction_axis] //= 2
    devs = mesh.devices
    idx = [slice(None)] * devs.ndim
    idx[names.index(lost_fraction_axis)] = slice(0, shape[lost_fraction_axis])
    return Mesh(devs[tuple(idx)], names)
