"""Jittable train / prefill / serve steps over the uniform ArchDef API.

The train state is a plain dict pytree (easy to checkpoint and shard):

    {"params": ..., "opt_state": {"mu", "nu", "count"}, "step": i32}

``make_train_step`` supports gradient accumulation via ``lax.scan`` over
microbatches (batch arrays reshaped to ``(accum, B/accum, ...)``) — the
standard memory-term reduction when the HBM roofline term dominates.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchDef
from repro.models.common import ParamSpec, materialize
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    apply_updates,
    opt_state_spec,
)
from repro.optim.schedule import Schedule


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------


def init_state(arch: ArchDef, key, opt_cfg: AdamWConfig) -> dict:
    params = materialize(arch.param_spec(), key)
    return {
        "params": params,
        "opt_state": adamw_init(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }


def state_spec(arch: ArchDef, opt_cfg: AdamWConfig) -> dict:
    pspec = arch.param_spec()
    return {
        "params": pspec,
        "opt_state": opt_state_spec(pspec, opt_cfg),
        "step": ParamSpec((), (), init="zeros", dtype=jnp.int32),
    }


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------


def _split_micro(batch: dict, accum: int) -> dict:
    def r(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape(accum, b // accum, *x.shape[1:])
    return jax.tree.map(r, batch)


def cast_params_for_compute(arch: ArchDef, params):
    """fp32-master / low-precision-compute: cast >=2-D float params to the
    arch compute dtype ONCE at step entry.  Hypothesis was that downstream
    FSDP/TP weight gathers would then move 2 B/param instead of 4; the
    dry-run measurement REFUTED it for the assigned shapes (GSPMD's chosen
    schedules were not weight-gather-bound; the extra cast copies cost
    ~3-5% HBM bytes) — kept as an opt-in knob, default off.  See
    EXPERIMENTS.md §Perf iteration log.  Grads still arrive in f32 through
    the cast's VJP (master-weight pattern)."""
    cdt = getattr(arch.cfg, "dtype", None)
    if cdt is None:
        return params

    def cast(p):
        if p.ndim >= 2 and p.dtype == jnp.float32:
            return p.astype(cdt)
        return p
    return jax.tree.map(cast, params)


def make_train_step(arch: ArchDef, opt_cfg: AdamWConfig,
                    schedule: Schedule | None = None, *, accum: int = 1,
                    cast_once: bool = False) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)``."""

    def loss_of(params, batch):
        p = cast_params_for_compute(arch, params) if cast_once else params
        return arch.loss(p, batch)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(state: dict, batch: dict):
        params = state["params"]
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = _split_micro(batch, accum)

            def mb(g_acc, mb_batch):
                (l, m), g = grad_fn(params, mb_batch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return g_acc, (l, m)

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, ms) = jax.lax.scan(mb, g0, micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(lambda x: jnp.mean(x), ms)

        updates, opt_state, om = adamw_update(
            grads, state["opt_state"], params, opt_cfg, schedule)
        new_params = apply_updates(params, updates)
        metrics = {**metrics, **om, "loss": loss}
        return (
            {"params": new_params, "opt_state": opt_state,
             "step": state["step"] + 1},
            metrics,
        )

    return train_step


def make_prefill_step(arch: ArchDef, *, max_len: int | None = None,
                      cast_once: bool = False) -> Callable:
    def prefill_step(params, batch):
        p = cast_params_for_compute(arch, params) if cast_once else params
        return arch.prefill(p, batch, max_len=max_len)
    return prefill_step


def make_serve_step(arch: ArchDef, *, cast_once: bool = False) -> Callable:
    """One batched decode step: ``serve_step(params, cache, batch)``."""
    def serve_step(params, cache, batch):
        p = cast_params_for_compute(arch, params) if cast_once else params
        return arch.decode(p, cache, batch)
    return serve_step


def make_eval_step(arch: ArchDef) -> Callable:
    def eval_step(params, batch):
        loss, metrics = arch.loss(params, batch)
        return metrics
    return eval_step
