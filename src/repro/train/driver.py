"""Fault-tolerant training driver.

Failure model (what actually happens on big fleets) and the response here:

* **Process crash / preemption** — training state lives in the newest
  atomic checkpoint (``repro.ckpt``); on restart the driver restores the
  latest step and the deterministic data pipeline resumes bit-identically
  (batches are a pure function of step).  Simulated in tests by raising
  ``InjectedFailure`` mid-run and re-running the driver.
* **Node loss (shrink)** — ``elastic=True`` lets the driver rebuild a
  smaller mesh (``shrink_mesh``), reshard the live state with
  ``remesh_state`` and re-jit the step; batch size per device grows, the
  global batch is preserved.
* **Stragglers** — synchronous SPMD steps run at the speed of the slowest
  participant.  The driver keeps a rolling median of step wall-times; a
  step slower than ``straggler_factor`` x median raises a straggler event:
  logged, counted, and (on a real cluster) the slow host is reported to
  the scheduler for re-meshing.  The detection logic is exercised in tests
  with an injected sleep.

The loop itself is deliberately boring: everything interesting is in the
recovery paths.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass
from typing import Callable

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs.base import ArchDef
from repro.data.pipeline import shard_batch
from repro.dist.sharding import (
    ShardingProfile,
    param_shardings,
    use_mesh_context,
)
from repro.optim import AdamWConfig
from repro.optim.schedule import Schedule
from .steps import init_state, make_train_step, state_spec


class InjectedFailure(RuntimeError):
    """Raised by test hooks to simulate a process crash."""


@dataclass
class TrainerConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_interval: int = 50
    keep_last: int = 3
    log_interval: int = 10
    accum: int = 1
    straggler_factor: float = 3.0
    straggler_window: int = 20
    seed: int = 0
    multi_pod: bool = False


@dataclass
class StepEvent:
    step: int
    loss: float
    wall_s: float
    straggler: bool = False


class Trainer:
    """Checkpoint-restart training loop over an ArchDef."""

    def __init__(self, arch: ArchDef, dataset, mesh, profile: ShardingProfile,
                 opt_cfg: AdamWConfig, schedule: Schedule,
                 cfg: TrainerConfig,
                 hooks: dict[int, Callable] | None = None):
        self.arch = arch
        self.dataset = dataset
        self.mesh = mesh
        self.profile = profile
        self.opt_cfg = opt_cfg
        self.schedule = schedule
        self.cfg = cfg
        self.hooks = hooks or {}
        self.ckpt = CheckpointManager(cfg.ckpt_dir,
                                      interval=cfg.ckpt_interval,
                                      keep_last=cfg.keep_last)
        self.events: list[StepEvent] = []
        self.straggler_events: list[int] = []
        self._spec = state_spec(arch, opt_cfg)

    # ------------------------------------------------------------------
    def _shardings(self):
        return param_shardings(self._spec, self.mesh, self.profile)

    def _init_or_restore(self):
        shardings = self._shardings()
        step0, state, _ = self.ckpt.restore_latest(
            jax.tree.map(lambda s: np.zeros(s.shape, s.dtype), self._spec,
                         is_leaf=lambda x: hasattr(x, "shape")
                         and hasattr(x, "init")),
            shardings=shardings)
        if state is not None:
            return int(step0), state
        key = jax.random.key(self.cfg.seed)
        with use_mesh_context(self.mesh, self.profile,
                              multi_pod=self.cfg.multi_pod):
            state = jax.jit(
                lambda k: init_state(self.arch, k, self.opt_cfg),
                out_shardings=shardings)(key)
        return 0, state

    def _batch_axes(self):
        return ("pod", "data") if self.cfg.multi_pod else ("data",)

    # ------------------------------------------------------------------
    def run(self) -> dict:
        cfg = self.cfg
        start, state = self._init_or_restore()
        step_fn = make_train_step(self.arch, self.opt_cfg, self.schedule,
                                  accum=cfg.accum)
        shardings = self._shardings()
        jit_step = jax.jit(step_fn, donate_argnums=(0,),
                           in_shardings=(shardings, None),
                           out_shardings=(shardings, None))
        window: list[float] = []
        losses = []
        with use_mesh_context(self.mesh, self.profile,
                              multi_pod=cfg.multi_pod):
            for step in range(start, cfg.total_steps):
                if step in self.hooks:
                    self.hooks[step](self, step, state)
                t0 = time.perf_counter()   # data time counts: a slow host
                batch = self.dataset.batch(step)   # stalls the sync step
                batch = shard_batch(batch, self.mesh, self._batch_axes())
                state, metrics = jit_step(state, batch)
                loss = float(metrics["loss"])
                wall = time.perf_counter() - t0
                straggler = False
                if len(window) >= 5:
                    med = statistics.median(window[-cfg.straggler_window:])
                    if wall > cfg.straggler_factor * med:
                        straggler = True
                        self.straggler_events.append(step)
                window.append(wall)
                losses.append(loss)
                self.events.append(StepEvent(step, loss, wall, straggler))
                self.ckpt.maybe_save(step + 1, state,
                                     metadata={"loss": loss})
        return {
            "final_step": cfg.total_steps,
            "final_loss": losses[-1] if losses else float("nan"),
            "losses": losses,
            "stragglers": self.straggler_events,
        }
