"""Whisper-style encoder-decoder transformer backbone (arXiv:2212.04356).

Per the brief, the conv/mel audio frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings (B, S_frames, d) directly — i.e. the
output the two-conv frontend would produce.  Everything downstream is real:
a bidirectional pre-LN encoder, a causal decoder with cross-attention, and
learned (sinusoidal for the encoder) position embeddings.

Decode shapes drive the decoder: ``prefill`` encodes the frames once and
caches cross-attention K/V per layer (computed from encoder output — the
standard inference factorization); ``decode_step`` grows the self-attention
KV cache one token at a time.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .attention import AttnConfig, attn_spec, attention, decode_attention
from .common import (
    ParamSpec,
    embed,
    gelu_mlp,
    gelu_mlp_spec,
    layernorm,
    layernorm_spec,
    masked_xent,
    shard_annotate,
    unembed,
)
from .lm import pad_vocab

NEG_INF = -1e30


@dataclass(frozen=True)
class WhisperConfig:
    name: str
    n_layers: int                  # encoder layers == decoder layers
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    max_frames: int = 32768        # stub-frontend frame positions
    max_text: int = 32768
    attn_impl: str = "chunked"
    attn_chunk: int = 1024
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    remat: str = "none"
    vocab_pad_multiple: int = 2048
    z_loss: float = 0.0

    @property
    def head_dim_(self) -> int:
        return self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab, self.vocab_pad_multiple)

    def attn_cfg(self, *, causal: bool, rope: bool = False) -> AttnConfig:
        return AttnConfig(d_model=self.d_model, n_heads=self.n_heads,
                          n_kv_heads=self.n_heads, head_dim=self.head_dim_,
                          causal=causal, rope_fraction=0.0,
                          impl=self.attn_impl, chunk_size=self.attn_chunk)


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------


def _stack(spec, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), ("layers", *s.axes), init=s.init,
                            scale=s.scale, dtype=s.dtype),
        spec, is_leaf=lambda x: isinstance(x, ParamSpec))


def whisper_spec(cfg: WhisperConfig) -> dict:
    enc_layer = {
        "ln_attn": layernorm_spec(cfg.d_model),
        "attn": attn_spec(cfg.attn_cfg(causal=False)),
        "ln_ffn": layernorm_spec(cfg.d_model),
        "mlp": gelu_mlp_spec(cfg.d_model, cfg.d_ff),
    }
    dec_layer = {
        "ln_self": layernorm_spec(cfg.d_model),
        "self_attn": attn_spec(cfg.attn_cfg(causal=True)),
        "ln_cross": layernorm_spec(cfg.d_model),
        "cross_attn": attn_spec(cfg.attn_cfg(causal=False)),
        "ln_ffn": layernorm_spec(cfg.d_model),
        "mlp": gelu_mlp_spec(cfg.d_model, cfg.d_ff),
    }
    return {
        "enc": {
            "layers": _stack(enc_layer, cfg.n_layers),
            "ln_f": layernorm_spec(cfg.d_model),
        },
        "dec": {
            # tied embedding/unembedding: init at 1/sqrt(d) so initial
            # logits are O(1) (std-1 init puts the tied logits at O(sqrt d))
            "embedding": ParamSpec((cfg.vocab_padded, cfg.d_model),
                                   ("vocab", "embed"),
                                   scale=cfg.d_model ** -0.5),
            "pos": ParamSpec((cfg.max_text, cfg.d_model), (None, "embed"),
                             scale=0.01),
            "layers": _stack(dec_layer, cfg.n_layers),
            "ln_f": layernorm_spec(cfg.d_model),
        },
    }


def _sinusoid(s: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos * jnp.exp(-math.log(10000.0) * dim / max(d // 2 - 1, 1))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def encode(params, cfg: WhisperConfig, frames):
    """frames: (B, S_f, d) stub frontend output -> encoder states."""
    h = frames.astype(cfg.dtype)
    h = h + _sinusoid(h.shape[1], cfg.d_model).astype(cfg.dtype)[None]
    h = shard_annotate(h, ("batch", None, "embed"))
    acfg = cfg.attn_cfg(causal=False)

    def body(hh, p_l):
        a, _ = attention(p_l["attn"], acfg,
                         layernorm(p_l["ln_attn"], hh, cfg.norm_eps))
        hh = hh + a
        hh = hh + gelu_mlp(p_l["mlp"],
                           layernorm(p_l["ln_ffn"], hh, cfg.norm_eps))
        return hh, None

    fn = jax.checkpoint(body) if cfg.remat != "none" else body
    h, _ = jax.lax.scan(fn, h, params["enc"]["layers"])
    return layernorm(params["enc"]["ln_f"], h, cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------


def _cross_attention(p, cfg: WhisperConfig, x, enc_k, enc_v):
    """x: (B, Sq, d) decoder states attending to cached encoder K/V.

    Chunked (online-softmax) by default: the dense (B,H,Sq,Sk) score tensor
    at train_4k would be GiBs per layer."""
    from .attention import _chunked_attn, _dense_attn

    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if cfg.attn_impl == "chunked" and q.shape[1] > 1:
        out = _chunked_attn(q, enc_k, enc_v, causal=False,
                            chunk=cfg.attn_chunk)
    else:
        out = _dense_attn(q, enc_k, enc_v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def _enc_kv(p_l, cfg: WhisperConfig, enc_out):
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p_l["cross_attn"]["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p_l["cross_attn"]["wv"].astype(dt))
    return k, v


def _dec_layer(p_l, cfg: WhisperConfig, h, enc_kv, *, self_cache=None,
               cache_len=None):
    acfg = cfg.attn_cfg(causal=True)
    x = layernorm(p_l["ln_self"], h, cfg.norm_eps)
    if self_cache is None:
        a, kv = attention(p_l["self_attn"], acfg, x)
        new_cache = kv
    else:
        ck, cv = self_cache
        a, ck, cv = decode_attention(p_l["self_attn"], acfg, x, ck, cv,
                                     cache_len)
        new_cache = (ck, cv)
    h = h + a
    x = layernorm(p_l["ln_cross"], h, cfg.norm_eps)
    h = h + _cross_attention(p_l["cross_attn"], cfg, x, *enc_kv)
    h = h + gelu_mlp(p_l["mlp"], layernorm(p_l["ln_ffn"], h, cfg.norm_eps))
    return h, new_cache


def decode_train(params, cfg: WhisperConfig, tokens, enc_out):
    """Teacher-forced decoder pass (training)."""
    b, s = tokens.shape
    h = embed(params["dec"]["embedding"], tokens).astype(cfg.dtype)
    h = h + params["dec"]["pos"][:s].astype(cfg.dtype)[None]
    h = shard_annotate(h, ("batch", None, "embed"))

    def body(hh, p_l):
        enc_kv = _enc_kv(p_l, cfg, enc_out)
        hh, _ = _dec_layer(p_l, cfg, hh, enc_kv)
        return hh, None

    fn = jax.checkpoint(body) if cfg.remat != "none" else body
    h, _ = jax.lax.scan(fn, h, params["dec"]["layers"])
    return layernorm(params["dec"]["ln_f"], h, cfg.norm_eps)


def loss_fn(params, cfg: WhisperConfig, batch):
    """batch: frames (B,S_f,d), tokens (B,S_t), labels, mask."""
    enc_out = encode(params, cfg, batch["frames"])
    h = decode_train(params, cfg, batch["tokens"], enc_out)
    logits = _logits(params, cfg, h)
    logits = shard_annotate(logits, ("batch", None, "vocab"))
    loss = masked_xent(logits, batch["labels"], batch.get("mask"),
                       vocab=cfg.vocab, vocab_padded=cfg.vocab_padded,
                       z_loss=cfg.z_loss)
    return loss, {"loss": loss, "aux_loss": 0.0}


def _logits(params, cfg: WhisperConfig, h):
    # tied unembedding (Whisper ties decoder embedding and output proj)
    return unembed(jnp.swapaxes(params["dec"]["embedding"], 0, 1), h)


# ---------------------------------------------------------------------------
# prefill / decode (inference)
# ---------------------------------------------------------------------------


def cache_spec(cfg: WhisperConfig, batch: int, max_len: int,
               n_frames: int | None = None) -> dict:
    h, hd = cfg.n_heads, cfg.head_dim_
    nf = n_frames or cfg.max_frames
    self_shape = (cfg.n_layers, batch, max_len, h, hd)
    cross_shape = (cfg.n_layers, batch, nf, h, hd)
    axes = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {
        "self_k": ParamSpec(self_shape, axes, init="zeros", dtype=cfg.dtype),
        "self_v": ParamSpec(self_shape, axes, init="zeros", dtype=cfg.dtype),
        "cross_k": ParamSpec(cross_shape, axes, init="zeros", dtype=cfg.dtype),
        "cross_v": ParamSpec(cross_shape, axes, init="zeros", dtype=cfg.dtype),
        "length": ParamSpec((), (), init="zeros", dtype=jnp.int32),
    }


def prefill(params, cfg: WhisperConfig, batch, *, max_len: int | None = None):
    """Encode frames, prefill the decoder on the prompt tokens; returns
    (last-token logits, cache)."""
    frames, tokens = batch["frames"], batch["tokens"]
    b, s = tokens.shape
    max_len = max_len or s
    enc_out = encode(params, cfg, frames)
    h = embed(params["dec"]["embedding"], tokens).astype(cfg.dtype)
    h = h + params["dec"]["pos"][:s].astype(cfg.dtype)[None]

    def body(hh, p_l):
        enc_kv = _enc_kv(p_l, cfg, enc_out)
        hh, (k, v) = _dec_layer(p_l, cfg, hh, enc_kv)
        return hh, (k.astype(cfg.dtype), v.astype(cfg.dtype),
                    enc_kv[0].astype(cfg.dtype), enc_kv[1].astype(cfg.dtype))

    h, (ks, vs, cks, cvs) = jax.lax.scan(body, h, params["dec"]["layers"])
    h = layernorm(params["dec"]["ln_f"], h, cfg.norm_eps)
    logits = _logits(params, cfg, h[:, -1:, :])
    pad = max_len - s
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"self_k": ks, "self_v": vs, "cross_k": cks, "cross_v": cvs,
             "length": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(params, cfg: WhisperConfig, cache, batch):
    """One-token decode with cached self + cross KV."""
    tokens = batch["tokens"]
    length = cache["length"]
    h = embed(params["dec"]["embedding"], tokens).astype(cfg.dtype)
    h = h + jnp.take(params["dec"]["pos"], length[None], axis=0
                     ).astype(cfg.dtype)[None]

    def body(hh, xs):
        p_l, ck, cv, xk, xv = xs
        hh, (ck, cv) = _dec_layer(p_l, cfg, hh, (xk, xv),
                                  self_cache=(ck, cv), cache_len=length)
        return hh, (ck, cv)

    h, (ks, vs) = jax.lax.scan(
        body, h, (params["dec"]["layers"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    h = layernorm(params["dec"]["ln_f"], h, cfg.norm_eps)
    logits = _logits(params, cfg, h)
    return logits, {"self_k": ks, "self_v": vs, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "length": length + 1}
