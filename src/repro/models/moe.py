"""Mixture-of-Experts FFN with top-k routing.

Three dispatch implementations, all sharing the same router/expert params:

* ``ref``       — dense all-experts reference (exact, no capacity drops);
  O(E * N * d * f) compute, so smoke tests / correctness only.
* ``scatter``   — global sort-based dispatch in pure pjit ops (argsort by
  expert id, capacity-bounded scatter into an (E, cap, d) buffer, grouped
  expert matmuls, scatter-combine).  GSPMD infers the communication.  This
  is the *baseline* the ECM analysis starts from.
* ``shard_map`` — explicit expert parallelism: tokens stay on their data
  shard (they are replicated across the ``model`` axis anyway), each model
  shard selects the assignments routed to its local experts, computes them,
  and the partial outputs are combined with a ``psum`` over ``model``.
  FSDP'd expert weights are all-gathered over ``data`` on entry.  This is
  the ECM-guided optimized path (see EXPERIMENTS.md §Perf).

Routing semantics are identical (same top-k, same renormalised weights);
``scatter``/``shard_map`` drop overflow beyond ``capacity_factor``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import ParamSpec, shard_annotate


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    impl: str = "scatter"          # ref | scatter | shard_map
    router_dtype: object = jnp.float32


def moe_spec(d_model: int, cfg: MoEConfig) -> dict:
    e, f = cfg.n_experts, cfg.d_ff
    return {
        "router": ParamSpec((d_model, e), ("embed", "experts_r")),
        "w_gate": ParamSpec((e, d_model, f), ("experts", "embed", "mlp")),
        "w_up": ParamSpec((e, d_model, f), ("experts", "embed", "mlp")),
        "w_down": ParamSpec((e, f, d_model), ("experts", "mlp", "embed")),
    }


def _route(p, cfg: MoEConfig, xf):
    """xf: (N, d) -> (weights (N,k), ids (N,k), aux load-balance loss)."""
    logits = (xf.astype(cfg.router_dtype)
              @ p["router"].astype(cfg.router_dtype))          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balance aux loss
    e = cfg.n_experts
    density = jnp.mean(jax.nn.one_hot(ids[:, 0], e), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * mean_probs)
    return weights.astype(xf.dtype), ids, aux


def _expert_ffn(w_gate, w_up, w_down, buf):
    """buf: (E, C, d) -> (E, C, d) through each expert's SwiGLU."""
    dt = buf.dtype
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(dt))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(dt))


def _capacity(n_tokens: int, cfg: MoEConfig, shards: int = 1) -> int:
    cap = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor
                        / cfg.n_experts))
    return max(8, ((cap + 127) // 128) * 128)


# ---------------------------------------------------------------------------
# ref: dense all-experts (exact; smoke/correctness only)
# ---------------------------------------------------------------------------


def moe_ffn_ref(p, cfg: MoEConfig, x):
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    weights, ids, aux = _route(p, cfg, xf)
    out = jnp.zeros_like(xf)
    for e in range(cfg.n_experts):
        w_e = jnp.sum(jnp.where(ids == e, weights, 0.0), axis=-1)   # (N,)
        h = _expert_ffn(p["w_gate"][e:e + 1], p["w_up"][e:e + 1],
                        p["w_down"][e:e + 1], xf[None])
        out = out + h[0] * w_e[:, None]
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# scatter: global sort-based dispatch (pure pjit baseline)
# ---------------------------------------------------------------------------


def moe_ffn_scatter(p, cfg: MoEConfig, x):
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    k, e = cfg.top_k, cfg.n_experts
    weights, ids, aux = _route(p, cfg, xf)

    cap = _capacity(n, cfg)
    flat_ids = ids.reshape(-1)                                  # (N*k,)
    sort_idx = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[sort_idx]
    token_of = sort_idx // k
    counts = jnp.zeros((e,), jnp.int32).at[flat_ids].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(n * k, dtype=jnp.int32) - starts[sorted_ids]
    valid = pos < cap
    slot = sorted_ids * cap + jnp.where(valid, pos, cap - 1)

    gathered = xf[token_of] * valid[:, None].astype(xf.dtype)
    buf = jnp.zeros((e * cap, d), xf.dtype).at[slot].add(
        gathered, mode="drop")
    buf = shard_annotate(buf.reshape(e, cap, d), ("experts", None, None))
    h = _expert_ffn(p["w_gate"], p["w_up"], p["w_down"], buf)
    h = shard_annotate(h, ("experts", None, None))

    rows = h.reshape(e * cap, d)[slot] * valid[:, None].astype(xf.dtype)
    inv = jnp.argsort(sort_idx)
    rows = rows[inv].reshape(n, k, d)
    out = jnp.sum(rows * weights[..., None], axis=1)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# shard_map: explicit expert parallelism (ECM-optimized path)
# ---------------------------------------------------------------------------


def moe_ffn_shard_map(p, cfg: MoEConfig, x, *, mesh, data_axes=("data",),
                      model_axis="model", fsdp_axis: str | None = None):
    """Expert-parallel MoE.  Tokens are data-sharded (replicated over
    ``model``); each model shard computes only its local experts and the
    partials are psum'd over ``model``.  Dispatch never leaves the device —
    the collective cost is one psum of the (local-batch, d) output plus the
    FSDP weight all-gather, instead of GSPMD's inferred scatter traffic."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    n_model = mesh.shape[model_axis]
    e = cfg.n_experts
    assert e % n_model == 0, (e, n_model)
    e_loc = e // n_model
    k = cfg.top_k

    def local(x_loc, router, w_gate, w_up, w_down):
        # gather FSDP'd expert weights (pod-local data axis), cast to the
        # compute dtype BEFORE the gather: the wire and the gathered HBM
        # copy cost 2 B/param instead of 4 (§Perf iteration log)
        if fsdp_axis is not None and mesh.shape[fsdp_axis] > 1:
            cdt = x_loc.dtype
            w_gate = jax.lax.all_gather(w_gate.astype(cdt), fsdp_axis,
                                        axis=1, tiled=True)
            w_up = jax.lax.all_gather(w_up.astype(cdt), fsdp_axis,
                                      axis=1, tiled=True)
            w_down = jax.lax.all_gather(w_down.astype(cdt), fsdp_axis,
                                        axis=1, tiled=True)
        bl, sl, d = x_loc.shape
        xf = x_loc.reshape(-1, d)
        n = xf.shape[0]
        weights, ids, aux = _route({"router": router}, cfg, xf)
        m = jax.lax.axis_index(model_axis)
        lo = m * e_loc
        local_mask = (ids >= lo) & (ids < lo + e_loc)           # (N, k)
        loc_ids = jnp.where(local_mask, ids - lo, e_loc)        # e_loc = trash
        flat_ids = loc_ids.reshape(-1)
        cap = _capacity(n, cfg)                                  # per expert
        sort_idx = jnp.argsort(flat_ids)
        sorted_ids = flat_ids[sort_idx]
        token_of = sort_idx // k
        counts = jnp.zeros((e_loc + 1,), jnp.int32).at[flat_ids].add(1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(n * k, dtype=jnp.int32) - starts[sorted_ids]
        valid = (pos < cap) & (sorted_ids < e_loc)
        slot = jnp.where(valid, sorted_ids * cap + pos, e_loc * cap)
        gathered = xf[token_of] * valid[:, None].astype(xf.dtype)
        buf = jnp.zeros((e_loc * cap + 1, d), xf.dtype).at[slot].add(gathered)
        h = _expert_ffn(w_gate, w_up, w_down,
                        buf[:-1].reshape(e_loc, cap, d))
        rows = h.reshape(e_loc * cap, d)
        rows = jnp.concatenate([rows, jnp.zeros((1, d), rows.dtype)], 0)[slot]
        w_sorted = (weights * local_mask.astype(weights.dtype)).reshape(-1)[sort_idx]
        contrib = rows * w_sorted[:, None]
        out = jnp.zeros((n, d), xf.dtype).at[token_of].add(contrib)
        out = jax.lax.psum(out, model_axis)
        aux = jax.lax.pmean(aux, (*data_axes, model_axis))
        return out.reshape(bl, sl, d), aux

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(data_axes, None, None),
                  P(None, None),
                  P(model_axis, fsdp_axis, None),
                  P(model_axis, fsdp_axis, None),
                  P(model_axis, fsdp_axis, None)),
        out_specs=(P(data_axes, None, None), P()),
        check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def moe_ffn(p, cfg: MoEConfig, x, *, mesh=None, data_axes=("data",),
            model_axis="model", fsdp_axis=None):
    if cfg.impl == "ref":
        return moe_ffn_ref(p, cfg, x)
    if cfg.impl == "shard_map":
        assert mesh is not None, "shard_map MoE needs a mesh"
        return moe_ffn_shard_map(p, cfg, x, mesh=mesh, data_axes=data_axes,
                                 model_axis=model_axis, fsdp_axis=fsdp_axis)
    return moe_ffn_scatter(p, cfg, x)
