"""Mamba2 (SSD — state-space duality) layer, chunked-parallel.

Implements the discrete selective SSM

    h_t = a_t * h_{t-1} + dt_t * B_t x_t        (per head, state size N)
    y_t = C_t . h_t + D * x_t

with a_t = exp(-dt_t * A_h), dt_t = softplus(dt_raw + bias), via the SSD
chunked algorithm: within-chunk attention-like scores with decay masks +
cross-chunk state recurrence (``lax.scan`` over chunks).  Training cost is
O(S * L) per head (L = chunk), decode is O(1) per token — which is why the
SSM archs run the ``long_500k`` cell.

Includes the depthwise causal conv frontend (kernel 4) on (x, B, C) and the
gated RMSNorm output stage, matching the reference Mamba2 block layout.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import ParamSpec, rmsnorm, shard_annotate


@dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_kernel: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def mamba2_spec(cfg: Mamba2Config) -> dict:
    d, di, g, n, h = (cfg.d_model, cfg.d_inner, cfg.n_groups, cfg.d_state,
                      cfg.n_heads)
    proj_out = 2 * di + 2 * g * n + h          # z, x, B, C, dt
    return {
        "in_proj": ParamSpec((d, proj_out), ("embed", "mamba_inner")),
        "conv_w": ParamSpec((cfg.conv_kernel, cfg.conv_dim),
                            (None, "mamba_inner"), scale=0.1),
        "conv_b": ParamSpec((cfg.conv_dim,), ("mamba_inner",), init="zeros"),
        "a_log": ParamSpec((h,), ("heads",), init="zeros"),
        "dt_bias": ParamSpec((h,), ("heads",), init="zeros"),
        "d_skip": ParamSpec((h,), ("heads",), init="ones"),
        "norm": ParamSpec((di,), ("mamba_inner",), init="ones"),
        "out_proj": ParamSpec((di, d), ("mamba_inner", "embed")),
    }


def _split_proj(cfg: Mamba2Config, zxbcdt):
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    bmat = zxbcdt[..., 2 * di:2 * di + g * n]
    cmat = zxbcdt[..., 2 * di + g * n:2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n:]
    return z, x, bmat, cmat, dt


def _causal_conv(w, b, x, *, state=None):
    """Depthwise causal conv along time.  x: (B, S, C); w: (K, C).

    If ``state`` (B, K-1, C) is given (decode), uses it as left context and
    returns the updated state."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None] for i in range(k))
    out = jax.nn.silu(out + b[None, None])
    new_state = xp[:, -(k - 1):, :]
    return out, new_state


def _ssd_chunked(cfg: Mamba2Config, x, bmat, cmat, dt, a_log, *, h0=None):
    """Chunked SSD.  x: (B,S,H,P); bmat/cmat: (B,S,G,N); dt: (B,S,H).

    Returns (y (B,S,H,P), h_final (B,H,N,P))."""
    bsz, s_orig, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hpg = h // g                                    # heads per group
    l = min(cfg.chunk, s_orig)
    # pad to a chunk multiple: padded steps have dt=0 (=> decay 1, no input)
    pad = (-s_orig) % l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // l

    a = jnp.exp(a_log.astype(jnp.float32))          # (H,) positive
    dtf = dt.astype(jnp.float32)
    la = -dtf * a[None, None]                       # log a_t  (B,S,H)

    # chunked views
    xc = x.reshape(bsz, nc, l, h, p)
    bc = bmat.reshape(bsz, nc, l, g, n)
    cc = cmat.reshape(bsz, nc, l, g, n)
    dtc = dtf.reshape(bsz, nc, l, h)
    lac = la.reshape(bsz, nc, l, h)

    def chunk_step(h_prev, inp):
        xk, bk, ck, dtk, lak = inp                  # (B,l,...) per chunk
        cum = jnp.cumsum(lak, axis=1)               # (B,l,H) inclusive
        # intra-chunk: decay(i,j) = exp(cum_i - cum_j), j <= i
        diff = cum[:, :, None, :] - cum[:, None, :, :]      # (B,l,l,H)
        ii = jnp.arange(l)
        mask = (ii[:, None] >= ii[None, :])[None, :, :, None]
        decay = jnp.where(mask, jnp.exp(diff), 0.0)
        # scores: C_i . B_j per group -> broadcast to heads
        cb = jnp.einsum("bign,bjgn->bijg", ck.astype(jnp.float32),
                        bk.astype(jnp.float32))     # (B,l,l,G)
        cb = jnp.repeat(cb, hpg, axis=3)            # (B,l,l,H)
        w_ij = cb * decay * dtk[:, None, :, :]      # dt_j weight
        y_intra = jnp.einsum("bijh,bjhp->bihp", w_ij, xk.astype(jnp.float32))
        # inter-chunk: y_i += exp(cum_i) C_i . h_prev
        cfull = jnp.repeat(ck.astype(jnp.float32), hpg, axis=2)  # (B,l,H,N)
        y_inter = jnp.einsum("bihn,bhnp->bihp", cfull, h_prev) \
            * jnp.exp(cum)[..., None]
        # state update: h_new = exp(cum_L) h_prev + sum_j exp(cum_L - cum_j) dt_j B_j x_j
        wj = jnp.exp(cum[:, -1:, :] - cum) * dtk    # (B,l,H)
        bfull = jnp.repeat(bk.astype(jnp.float32), hpg, axis=2)  # (B,l,H,N)
        h_new = jnp.einsum("blh,blhn,blhp->bhnp", wj, bfull,
                           xk.astype(jnp.float32))
        h_new = h_new + jnp.exp(cum[:, -1])[..., None, None] * h_prev
        return h_new, (y_intra + y_inter).astype(x.dtype)

    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    xs = (xc.transpose(1, 0, 2, 3, 4), bc.transpose(1, 0, 2, 3, 4),
          cc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
          lac.transpose(1, 0, 2, 3))
    # checkpoint each chunk: the (l, l, H) decay/score tiles are otherwise
    # all saved for backward -- O(S*l) f32 per layer instead of O(S)
    h_fin, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    return y[:, :s_orig], h_fin


def mamba2_layer(p, cfg: Mamba2Config, u, *, ssm_state=None, conv_state=None,
                 return_state: bool = False):
    """Full Mamba2 block.  u: (B, S, d_model).

    Train/prefill: ``ssm_state``/``conv_state`` None.  Decode: S == 1 and
    both states provided; returns (out, (ssm_state, conv_state))."""
    bsz, s, _ = u.shape
    dt_ = u.dtype
    zxbcdt = jnp.einsum("bsd,dk->bsk", u, p["in_proj"].astype(dt_))
    z, x, bmat, cmat, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([x, bmat, cmat], axis=-1)
    xbc, new_conv = _causal_conv(p["conv_w"].astype(dt_),
                                 p["conv_b"].astype(dt_), xbc,
                                 state=conv_state)
    di, g, n = cfg.d_inner, cfg.n_groups, cfg.d_state
    x = xbc[..., :di].reshape(bsz, s, cfg.n_heads, cfg.head_dim)
    bmat = xbc[..., di:di + g * n].reshape(bsz, s, g, n)
    cmat = xbc[..., di + g * n:].reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    x = shard_annotate(x, ("batch", None, "heads", None))

    if ssm_state is None and s > 1:
        y, h_fin = _ssd_chunked(cfg, x, bmat, cmat, dt, p["a_log"])
    else:
        # single-step (decode) recurrence
        h_prev = (jnp.zeros((bsz, cfg.n_heads, n, cfg.head_dim), jnp.float32)
                  if ssm_state is None else ssm_state)
        a = jnp.exp(p["a_log"].astype(jnp.float32))
        at = jnp.exp(-dt[:, 0] * a[None])                    # (B,H)
        hpg = cfg.n_heads // g
        bfull = jnp.repeat(bmat[:, 0].astype(jnp.float32), hpg, axis=1)
        cfull = jnp.repeat(cmat[:, 0].astype(jnp.float32), hpg, axis=1)
        contrib = jnp.einsum("bh,bhn,bhp->bhnp", dt[:, 0], bfull,
                             x[:, 0].astype(jnp.float32))
        h_fin = at[..., None, None] * h_prev + contrib
        y = jnp.einsum("bhn,bhnp->bhp", cfull, h_fin)[:, None]
        y = y.astype(dt_)

    y = y + (p["d_skip"].astype(jnp.float32)[None, None, :, None]
             * x.astype(jnp.float32)).astype(dt_)
    y = y.reshape(bsz, s, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dt_))
    if return_state:
        return out, (h_fin, new_conv)
    return out
