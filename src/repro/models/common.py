"""Shared model infrastructure: parameter specs, logical-axis sharding,
norms, RoPE, MLPs, embeddings and the LM loss.

Parameters are declared as trees of :class:`ParamSpec` (shape + logical axis
names + initializer).  The same spec tree materialises into (a) actual
arrays for smoke tests / examples, (b) ``ShapeDtypeStruct`` stand-ins for
the dry-run, and (c) ``PartitionSpec`` trees via the mesh's logical-axis
rules (``repro.dist.sharding``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter: shape, logical axes, initializer."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | scaled
    scale: float | None = None    # stddev override
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_array(key, spec: ParamSpec, dtype=None):
    dtype = dtype or spec.dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    std = spec.scale
    if std is None:
        # fan-in scaled normal over the last-but-one dim by convention
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape) * std).astype(dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def materialize(spec_tree, key, dtype=None):
    """Spec tree -> array tree (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_array(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def abstract(spec_tree, dtype=None):
    """Spec tree -> ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype),
        spec_tree, is_leaf=is_spec)


def axes_tree(spec_tree):
    """Spec tree -> logical-axes tree (same structure, tuples as leaves)."""
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(int(math.prod(s.shape)) for s in leaves)


# ---------------------------------------------------------------------------
# Logical-axis activation annotation (rules installed by repro.dist)
# ---------------------------------------------------------------------------

_ACTIVATION_RULES: dict[str, Any] | None = None


def set_activation_rules(rules: dict[str, Any] | None):
    global _ACTIVATION_RULES
    _ACTIVATION_RULES = rules


def shard_annotate(x, axes: tuple[str | None, ...]):
    """Attach a sharding constraint if logical rules are installed.

    Divisibility-aware: an axis whose dimension does not divide by the mesh
    axes it maps to is left unsharded — uneven shardings make GSPMD pad and
    replicate (observed: 24 q-heads annotated onto a 16-way axis cost GiBs
    of padded full-size copies in the minitron-4b dry-run).
    """
    if _ACTIVATION_RULES is None:
        return x
    from jax.sharding import PartitionSpec as P

    assignment = [_ACTIVATION_RULES.get(a) if a else None for a in axes]
    try:
        from repro.dist.sharding import current_mesh
        mesh = current_mesh()
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            checked = []
            for dim, a in zip(x.shape, assignment):
                if a is None:
                    checked.append(None)
                    continue
                group = a if isinstance(a, tuple) else (a,)
                # largest prefix of the group that divides the dim (matches
                # dist.sharding.logical_to_pspec)
                chosen = None
                for k in range(len(group), 0, -1):
                    n = 1
                    for g in group[:k]:
                        n *= sizes.get(g, 1)
                    if n and dim % n == 0:
                        chosen = group[:k] if k > 1 else group[0]
                        break
                checked.append(chosen)
            assignment = checked
        return jax.lax.with_sharding_constraint(x, P(*assignment))
    except (KeyError, RuntimeError, TypeError, ValueError):
        return x  # rules reference axes this mesh lacks: skip annotation


# ---------------------------------------------------------------------------
# Differentiable optimization barrier
# ---------------------------------------------------------------------------


@jax.custom_vjp
def grad_barrier(x):
    """``jax.lax.optimization_barrier`` with a gradient rule (the primitive
    has none on this jax version).  The barrier is applied on both the
    forward and the cotangent so XLA cannot hoist converts out of the
    scan/backward loop in either direction."""
    return jax.lax.optimization_barrier(x)


def _grad_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _grad_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


grad_barrier.defvjp(_grad_barrier_fwd, _grad_barrier_bwd)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


def rmsnorm(w, x, eps: float = 1e-6):
    """RMSNorm with f32 *statistics* but no materialized f32 copy of x.

    The sum-of-squares accumulates in f32 (``preferred_element_type``); the
    per-row rsqrt scale is applied in the compute dtype.  Keeping the
    (B, S, d) tensor out of f32 matters structurally: a full ``x.astype
    (f32)`` inside a scanned layer makes XLA save/convert the whole
    per-layer carry stack in f32 in the backward pass (2x the remat
    memory, observed on the dry-run).
    """
    dt = x.dtype
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)[..., None]
    var = ss / x.shape[-1]
    scale = jax.lax.rsqrt(var + eps).astype(dt)
    return w.astype(dt) * (x * scale)


def layernorm_spec(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), init="ones"),
            "bias": ParamSpec((d,), ("embed",), init="zeros")}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (p["scale"] * (xf - mu) * jax.lax.rsqrt(var + eps)
            + p["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (with partial-dim support for GLM4)
# ---------------------------------------------------------------------------


def rope_angles(positions, head_dim: int, *, theta: float = 10000.0,
                fraction: float = 1.0):
    """Return (cos, sin) of shape (..., rot_dim/2) for given positions."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions[..., None].astype(jnp.float32) * inv      # (..., rot/2)
    return jnp.cos(ang), jnp.sin(ang), rot


def apply_rope(x, cos, sin, rot: int):
    """x: (B, S, H, D); rotate the first ``rot`` dims pairwise."""
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    r1 = x1 * c - x2 * s
    r2 = x2 * c + x1 * s
    xr = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([xr, xp], axis=-1) if rot < x.shape[-1] else xr


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_spec(d: int, d_ff: int) -> dict:
    return {
        "w_gate": ParamSpec((d, d_ff), ("embed", "mlp")),
        "w_up": ParamSpec((d, d_ff), ("embed", "mlp")),
        "w_down": ParamSpec((d_ff, d), ("mlp", "embed")),
    }


def swiglu(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = shard_annotate(h, ("batch", None, "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


def gelu_mlp_spec(d: int, d_ff: int) -> dict:
    return {
        "w_in": ParamSpec((d, d_ff), ("embed", "mlp")),
        "b_in": ParamSpec((d_ff,), ("mlp",), init="zeros"),
        "w_out": ParamSpec((d_ff, d), ("mlp", "embed")),
        "b_out": ParamSpec((d,), ("embed",), init="zeros"),
    }


def gelu_mlp(p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(x.dtype)) + p["b_in"].astype(x.dtype)
    h = jax.nn.gelu(h)
    h = shard_annotate(h, ("batch", None, "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(x.dtype)) + p["b_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------


def embedding_spec(vocab: int, d: int) -> ParamSpec:
    return ParamSpec((vocab, d), ("vocab", "embed"), scale=1.0)


def embed(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed_spec(d: int, vocab: int) -> ParamSpec:
    return ParamSpec((d, vocab), ("embed", "vocab"))


def unembed(w, x):
    return jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))


def masked_xent(logits, labels, mask=None, *, vocab: int,
                vocab_padded: int | None = None, z_loss: float = 0.0):
    """Stable masked cross entropy with padded-vocab masking (f32 math)."""
    vpad = vocab_padded or vocab
    lf = logits.astype(jnp.float32)
    if vpad != vocab:
        pad_mask = jnp.arange(vpad) >= vocab
        lf = jnp.where(pad_mask[None, None, :], jnp.asarray(-1e30, jnp.float32), lf)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    per_tok = lse - ll
    if z_loss:
        per_tok = per_tok + z_loss * lse**2
    if mask is None:
        return jnp.mean(per_tok)
    maskf = mask.astype(jnp.float32)
    return jnp.sum(per_tok * maskf) / jnp.maximum(jnp.sum(maskf), 1.0)


def softmax_xent(logits, labels, *, z_loss: float = 0.0):
    """Stable per-token cross entropy, mean over tokens (f32 math).

    ``z_loss`` adds the standard log-normalizer regulariser (used at scale
    to keep logits bounded)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse**2
    return jnp.mean(loss)
