"""xLSTM language model: a stack of mLSTM blocks with sLSTM blocks at
configurable depths (Beck et al. 2024), pre-LN residual layout.

The assigned xlstm-125m config has ``d_ff = 0``: feed-forward capacity
lives inside the blocks (mLSTM 2x up-projection, sLSTM 4/3 gated post-MLP),
matching the reference implementation.

Layers are heterogeneous (two different param structures), so the stack is
a Python loop rather than ``lax.scan`` — at 12 layers the HLO stays small.
Decode carries per-layer recurrent states (matrix memory for mLSTM, scalar
cell for sLSTM): O(1) per token, so this arch runs ``long_500k``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .common import (
    ParamSpec,
    embed,
    embedding_spec,
    masked_xent,
    rmsnorm,
    rmsnorm_spec,
    shard_annotate,
    unembed,
    unembed_spec,
)
from .lm import pad_vocab
from .xlstm import (
    XLSTMConfig,
    mlstm_block,
    mlstm_spec,
    slstm_block,
    slstm_spec,
)


@dataclass(frozen=True)
class XLSTMLMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    vocab: int
    slstm_at: tuple[int, ...] = (3, 7)
    chunk: int = 256
    mlstm_impl: str = "chunked"
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    remat: str = "none"
    vocab_pad_multiple: int = 2048
    z_loss: float = 0.0

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab, self.vocab_pad_multiple)

    @property
    def block_cfg(self) -> XLSTMConfig:
        return XLSTMConfig(d_model=self.d_model, n_heads=self.n_heads,
                           chunk=self.chunk, mlstm_impl=self.mlstm_impl)

    def is_slstm(self, i: int) -> bool:
        return i in self.slstm_at


def xlstm_lm_spec(cfg: XLSTMLMConfig) -> dict:
    layers = {}
    for i in range(cfg.n_layers):
        kind = "slstm" if cfg.is_slstm(i) else "mlstm"
        block = (slstm_spec if cfg.is_slstm(i) else mlstm_spec)(cfg.block_cfg)
        layers[f"layer_{i}"] = {"ln": rmsnorm_spec(cfg.d_model), kind: block}
    return {
        "embedding": embedding_spec(cfg.vocab_padded, cfg.d_model),
        "layers": layers,
        "ln_f": rmsnorm_spec(cfg.d_model),
        "unembed": unembed_spec(cfg.d_model, cfg.vocab_padded),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block(p_l, cfg: XLSTMLMConfig, i: int, h, *, state=None,
           return_state=False):
    bc = cfg.block_cfg
    x = rmsnorm(p_l["ln"], h, cfg.norm_eps)
    if cfg.is_slstm(i):
        out = slstm_block(p_l["slstm"], bc, x, state=state,
                          return_state=return_state)
    else:
        out = mlstm_block(p_l["mlstm"], bc, x, state=state,
                          return_state=return_state)
    if return_state:
        o, st = out
        return h + o, st
    return h + out


def hidden_states(params, cfg: XLSTMLMConfig, tokens):
    h = embed(params["embedding"], tokens).astype(cfg.dtype)
    h = shard_annotate(h, ("batch", None, "embed"))
    for i in range(cfg.n_layers):
        fn = lambda hh, p_l, i=i: _block(p_l, cfg, i, hh)
        if cfg.remat != "none":
            fn = jax.checkpoint(fn)
        h = fn(h, params["layers"][f"layer_{i}"])
    return rmsnorm(params["ln_f"], h, cfg.norm_eps)


def loss_fn(params, cfg: XLSTMLMConfig, batch):
    h = hidden_states(params, cfg, batch["tokens"])
    logits = unembed(params["unembed"], h)
    logits = shard_annotate(logits, ("batch", None, "vocab"))
    loss = masked_xent(logits, batch["labels"], batch.get("mask"),
                       vocab=cfg.vocab, vocab_padded=cfg.vocab_padded,
                       z_loss=cfg.z_loss)
    return loss, {"loss": loss, "aux_loss": 0.0}


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------


def cache_spec(cfg: XLSTMLMConfig, batch: int, max_len: int) -> dict:
    """Recurrent decode state (max_len is irrelevant: O(1) state)."""
    bc = cfg.block_cfg
    out: dict = {}
    for i in range(cfg.n_layers):
        if cfg.is_slstm(i):
            h, hd = bc.n_heads, bc.s_head_dim
            out[f"layer_{i}"] = {
                "c": ParamSpec((batch, h, hd), ("batch", "heads", None),
                               init="zeros", dtype=jnp.float32),
                "n": ParamSpec((batch, h, hd), ("batch", "heads", None),
                               init="ones", dtype=jnp.float32),
                "hid": ParamSpec((batch, h, hd), ("batch", "heads", None),
                                 init="zeros", dtype=jnp.float32),
                "m": ParamSpec((batch, h, hd), ("batch", "heads", None),
                               init="zeros", dtype=jnp.float32),
            }
        else:
            h, p = bc.n_heads, bc.head_dim
            out[f"layer_{i}"] = {
                "c": ParamSpec((batch, h, p, p), ("batch", "heads", None, None),
                               init="zeros", dtype=jnp.float32),
                "n": ParamSpec((batch, h, p), ("batch", "heads", None),
                               init="zeros", dtype=jnp.float32),
                "m": ParamSpec((batch, h), ("batch", "heads"),
                               init="zeros", dtype=jnp.float32),
            }
    out["length"] = ParamSpec((), (), init="zeros", dtype=jnp.int32)
    return out


def _state_tuple(cfg: XLSTMLMConfig, i: int, entry: dict | None):
    if entry is None:
        return None
    if cfg.is_slstm(i):
        return (entry["c"], entry["n"], entry["hid"], entry["m"])
    return (entry["c"], entry["n"], entry["m"])


def _state_dict(cfg: XLSTMLMConfig, i: int, st) -> dict:
    if cfg.is_slstm(i):
        c, n, hid, m = st
        return {"c": c, "n": n, "hid": hid, "m": m}
    c, n, m = st
    return {"c": c, "n": n, "m": m}


def _run_with_state(params, cfg: XLSTMLMConfig, tokens, cache):
    h = embed(params["embedding"], tokens).astype(cfg.dtype)
    new_cache: dict = {}
    for i in range(cfg.n_layers):
        key = f"layer_{i}"
        st = _state_tuple(cfg, i, cache.get(key) if cache else None)
        h, st = _block(params["layers"][key], cfg, i, h, state=st,
                       return_state=True)
        new_cache[key] = _state_dict(cfg, i, st)
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    return h, new_cache


def prefill(params, cfg: XLSTMLMConfig, batch, *, max_len: int | None = None):
    tokens = batch["tokens"]
    h, cache = _run_with_state(params, cfg, tokens, None)
    logits = unembed(params["unembed"], h[:, -1:, :])
    cache["length"] = jnp.asarray(tokens.shape[1], jnp.int32)
    return logits, cache


def decode_step(params, cfg: XLSTMLMConfig, cache, batch):
    h, new_cache = _run_with_state(params, cfg, batch["tokens"], cache)
    logits = unembed(params["unembed"], h)
    new_cache["length"] = cache["length"] + 1
    return logits, new_cache
