"""GQA attention: dense, chunked (flash-style in pure XLA ops) and Pallas
implementations, plus KV-cache decode.

``impl`` selection:

* ``dense``   — materialises the (Sq, Sk) scores; fine for smoke tests and
  short sequences.
* ``chunked`` — online-softmax over KV chunks via ``lax.scan``: the flash
  attention *algorithm* expressed in XLA ops, so it compiles on any backend
  and keeps HBM traffic/score memory at O(S·chunk).  This is what the big
  dry-run configs use.
* ``flash``   — the Pallas kernel (``repro.kernels.attention``), TPU runtime.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamSpec, apply_rope, rope_angles, shard_annotate

NEG_INF = -1e30


@dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_fraction: float = 1.0
    rope_theta: float = 10000.0
    causal: bool = True
    impl: str = "dense"          # dense | chunked | flash
    chunk_size: int = 1024


def attn_spec(cfg: AttnConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), init="zeros")
        p["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    return p


def _qkv(p, cfg: AttnConfig, x, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.rope_fraction > 0:
        cos, sin, rot = rope_angles(positions, cfg.head_dim,
                                    theta=cfg.rope_theta,
                                    fraction=cfg.rope_fraction)
        # rope math in f32 (cos/sin), result back in the compute dtype so
        # the residual stream stays bf16 (scan carries are dtype-strict)
        q = apply_rope(q, cos, sin, rot).astype(dt)
        k = apply_rope(k, cos, sin, rot).astype(dt)
    q = shard_annotate(q, ("batch", None, "heads", None))
    k = shard_annotate(k, ("batch", None, "kv_heads", None))
    v = shard_annotate(v, ("batch", None, "kv_heads", None))
    return q, k, v


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.repeat(k, n_rep, axis=2)


def _dense_attn(q, k, v, *, causal: bool, q_offset=0):
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        rows = q_offset + jnp.arange(sq)[:, None]
        cols = jnp.arange(sk)[None, :]
        s = jnp.where(rows >= cols, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _chunked_attn(q, k, v, *, causal: bool, chunk: int):
    """Online-softmax over (q-block x kv-chunk) tiles: the flash algorithm
    expressed in XLA ops (double ``lax.scan``), GQA-aware (KV heads are
    never repeated — the q-group dim rides along in the einsums).

    Score tiles are (B, kvH, rep, cq, ck): O(chunk^2), never O(S^2).
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    cq = min(chunk, sq)
    ck = min(chunk, sk)
    assert sq % cq == 0 and sk % ck == 0, (sq, sk, chunk)
    nq, nk = sq // cq, sk // ck
    scale = 1.0 / math.sqrt(d)
    # keep q/k/v in the compute dtype; f32 appears only in score/accumulator
    # tiles (a full-sequence f32 copy would double the remat carry stack)
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(b, nq, cq, kvh, rep, d)
    qg = qg.transpose(1, 0, 2, 3, 4, 5)                     # (nq,b,cq,kvh,rep,d)
    kc = k.reshape(b, nk, ck, kvh, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, ck, kvh, d).transpose(1, 0, 2, 3, 4)

    def q_block(_, qin):
        qi, qb = qin                                         # qb: (b,cq,kvh,rep,d)
        rows = qi * cq + jnp.arange(cq)

        def kv_chunk(carry, kin):
            m, l, acc = carry
            ki, kb, vb = kin
            s = jnp.einsum("bqhrd,bkhd->bhrqk", qb, kb,
                           preferred_element_type=jnp.float32)
            if causal:
                cols = ki * ck + jnp.arange(ck)
                mask = (rows[:, None] >= cols[None, :])[None, None, None]
                s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhrqk,bkhd->bhrqd", p.astype(v.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((b, kvh, rep, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, rep, cq), jnp.float32)
        a0 = jnp.zeros((b, kvh, rep, cq, d), jnp.float32)
        # checkpoint each kv tile: the backward otherwise saves every
        # (cq, ck) score/prob tile — i.e. the full S^2 matrix in chunks.
        # Recomputing tiles keeps backward memory at O(S d), the flash-
        # attention profile.
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_chunk), (m0, l0, a0),
                                      (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]         # (b,kvh,rep,cq,d)
        return None, out.transpose(0, 3, 1, 2, 4)            # (b,cq,kvh,rep,d)

    _, blocks = jax.lax.scan(q_block, None, (jnp.arange(nq), qg))
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def attention(p, cfg: AttnConfig, x, *, positions=None):
    """Full-sequence attention (train / prefill). x: (B, S, d)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q, k, v = _qkv(p, cfg, x, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    if cfg.impl == "flash":
        from repro.kernels.attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=cfg.causal)
    elif cfg.impl == "chunked":
        out = _chunked_attn(q, k, v, causal=cfg.causal, chunk=cfg.chunk_size)
    else:
        out = _dense_attn(q, _repeat_kv(k, n_rep), _repeat_kv(v, n_rep),
                          causal=cfg.causal)
    out = shard_annotate(out, ("batch", None, "heads", None))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), (k, v)


def _seq_sharded_cache_update(cache, new, length):
    """Cache write that stays LOCAL under sequence sharding.

    A plain dynamic-update-slice at a runtime index on a seq-sharded cache
    makes GSPMD fall back to "involuntary full rematerialization" — it
    replicates the whole (B, S, kvH, hd) cache per layer (observed: the
    qwen1.5-110b decode_32k cell at 20.7 GiB/chip and ~56 GB of per-step
    HBM traffic).  Here each sequence shard checks whether ``length`` falls
    in its range and writes locally via ``shard_map``; every other shard is
    a no-op.
    """
    from jax.experimental.shard_map import shard_map
    from repro.dist.sharding import current_context

    ctx = current_context()
    mesh = ctx.mesh
    seq_ax = ctx.cache_seq_axis
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_batch = math.prod(sizes.get(a, 1) for a in ctx.data_axes)
    batch_spec = ctx.data_axes if cache.shape[0] % n_batch == 0 else None

    def local(c, n, ln):
        s_loc = c.shape[1]
        off = jax.lax.axis_index(seq_ax) * s_loc
        idx = ln - off

        def write(c):
            return jax.lax.dynamic_update_slice_in_dim(
                c, n.astype(c.dtype), jnp.clip(idx, 0, s_loc - 1), axis=1)

        return jax.lax.cond((idx >= 0) & (idx < s_loc), write, lambda c: c, c)

    P_ = P(batch_spec, seq_ax, None, None)
    return shard_map(local, mesh=mesh,
                     in_specs=(P_, P(batch_spec, None, None, None), P()),
                     out_specs=P_, check_rep=False)(cache, new, length)


def _update_cache(cache, new, length):
    from repro.dist.sharding import current_context
    ctx = current_context()
    if ctx.cache_seq_axis is not None and ctx.mesh is not None:
        return _seq_sharded_cache_update(cache, new, length)
    return jax.lax.dynamic_update_slice_in_dim(
        cache, new.astype(cache.dtype), length, axis=1)


def _flash_decode(q, cache_k, cache_v, k_new, v_new, cache_len, *,
                  n_rep: int, scale: float):
    """Sequence-parallel one-token decode attention via ``shard_map``.

    With the KV cache sequence-sharded (kv-heads indivisible by the model
    axis), GSPMD's pjit lowering all-gathers the full cache per layer per
    token (measured: 2 x 1.07 GB f32 gathers/layer on internlm2 decode_32k).
    Flash-decode keeps everything local: each seq shard updates its slice of
    the cache, computes local scores/max/sum/partial-out, and the softmax is
    completed with three tiny psums (max, denom, numerator).
    """
    from jax.experimental.shard_map import shard_map
    from repro.dist.sharding import current_context

    ctx = current_context()
    mesh = ctx.mesh
    seq_ax = ctx.cache_seq_axis
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_batch = math.prod(sizes.get(a, 1) for a in ctx.data_axes)
    bspec = ctx.data_axes if q.shape[0] % n_batch == 0 else None

    def local(q, ck, cv, kn, vn, ln):
        s_loc = ck.shape[1]
        off = jax.lax.axis_index(seq_ax) * s_loc
        idx = ln - off

        def write(c_n):
            c, n = c_n
            return jax.lax.dynamic_update_slice_in_dim(
                c, n.astype(c.dtype), jnp.clip(idx, 0, s_loc - 1), axis=1)

        inb = (idx >= 0) & (idx < s_loc)
        ck = jax.lax.cond(inb, write, lambda cn: cn[0], (ck, kn))
        cv = jax.lax.cond(inb, write, lambda cn: cn[0], (cv, vn))

        # GQA-aware: never repeat the KV cache (a jnp.repeat materializes
        # h/kvh extra copies of the dominant HBM stream)
        b, _, h, d = q.shape
        kvh = ck.shape[2]
        qg = q.reshape(b, kvh, n_rep, d)
        s = jnp.einsum("bkrd,bskd->bkrs", qg, ck,
                       preferred_element_type=jnp.float32) * scale
        cols = off + jnp.arange(s_loc)
        s = jnp.where((cols <= ln)[None, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)                          # (b,kvh,rep)
        m = jax.lax.pmax(m_loc, seq_ax)
        pr = jnp.exp(s - m[..., None])
        denom = jax.lax.psum(jnp.sum(pr, axis=-1), seq_ax)
        num = jnp.einsum("bkrs,bskd->bkrd", pr.astype(cv.dtype), cv,
                         preferred_element_type=jnp.float32)
        num = jax.lax.psum(num, seq_ax)
        out = (num / jnp.maximum(denom, 1e-30)[..., None]).reshape(
            b, 1, h, d)
        return out.astype(q.dtype), ck, cv

    Pc = P(bspec, seq_ax, None, None)
    Pq = P(bspec, None, None, None)
    return shard_map(local, mesh=mesh,
                     in_specs=(Pq, Pc, Pc, Pq, Pq, P()),
                     out_specs=(Pq, Pc, Pc),
                     check_rep=False)(q, cache_k, cache_v, k_new, v_new,
                                      cache_len)


def decode_attention(p, cfg: AttnConfig, x, cache_k, cache_v, cache_len):
    """One-token decode against a KV cache.

    x: (B, 1, d); cache_k/v: (B, S_max, kvH, hd); cache_len: () current
    length.  Returns (out (B,1,d), new_k, new_v).
    """
    from repro.dist.sharding import current_context

    b = x.shape[0]
    positions = jnp.broadcast_to(cache_len[None, None], (b, 1))
    q, k_new, v_new = _qkv(p, cfg, x, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    scale = 1.0 / math.sqrt(cfg.head_dim)

    ctx = current_context()
    if ctx.cache_seq_axis is not None and ctx.mesh is not None:
        out, cache_k, cache_v = _flash_decode(
            q, cache_k, cache_v, k_new, v_new, cache_len,
            n_rep=n_rep, scale=scale)
        return (jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)),
                cache_k, cache_v)

    cache_k = _update_cache(cache_k, k_new, cache_len)
    cache_v = _update_cache(cache_v, v_new, cache_len)
    s_max = cache_k.shape[1]
    # GQA-aware, f32 only in score/probability tiles: repeating or
    # upcasting the cache multiplies the dominant HBM stream of the step
    b_, _, h_, d_ = q.shape
    kvh = cache_k.shape[2]
    qg = q.reshape(b_, kvh, h_ // kvh, d_)
    s = jnp.einsum("bkrd,bskd->bkrs", qg, cache_k,
                   preferred_element_type=jnp.float32) * scale
    valid = (jnp.arange(s_max) <= cache_len)[None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    # probabilities stay f32 (matching _dense_attn): rounding them to the
    # cache dtype makes decode drift from the teacher-forced logits by
    # O(1e-1) within a few steps; only the CACHE stays in the low dtype
    out = jnp.einsum("bkrs,bskd->bkrd", pr, cache_v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b_, 1, h_, d_).astype(x.dtype)
    return (jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)),
            cache_k, cache_v)
