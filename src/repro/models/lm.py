"""Decoder-only transformer LM family.

Covers internlm2-1.8b, qwen1.5-110b, minitron-4b, glm4-9b (dense, GQA,
optional QKV bias / partial RoPE), granite-moe / qwen3-moe (MoE FFN via
``repro.models.moe``) and pixtral-12b (multimodal: precomputed patch
embeddings prepended to the token stream — the vision frontend is a stub
input per the brief).

Layers are scanned (``lax.scan`` over parameters stacked on a leading
"layers" axis) with configurable remat, so HLO size is O(1) in depth and
94-layer configs compile quickly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .attention import AttnConfig, attn_spec, attention, decode_attention
from .common import (
    ParamSpec,
    embed,
    embedding_spec,
    grad_barrier,
    rmsnorm,
    rmsnorm_spec,
    shard_annotate,
    swiglu,
    swiglu_spec,
    unembed,
    unembed_spec,
)
from .moe import MoEConfig, moe_ffn, moe_spec


def pad_vocab(vocab: int, multiple: int = 2048) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_fraction: float = 1.0
    rope_theta: float = 10000.0
    moe: MoEConfig | None = None
    attn_impl: str = "dense"           # dense | chunked | flash
    attn_chunk: int = 1024
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    remat: str = "none"                # none | full | dots
    scan_layers: bool = True
    image_prefix: int = 0              # pixtral: # of patch positions
    vocab_pad_multiple: int = 2048
    z_loss: float = 0.0

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab, self.vocab_pad_multiple)

    @property
    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim_,
            qkv_bias=self.qkv_bias, rope_fraction=self.rope_fraction,
            rope_theta=self.rope_theta, impl=self.attn_impl,
            chunk_size=self.attn_chunk)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def _layer_spec(cfg: LMConfig) -> dict:
    spec = {
        "ln_attn": rmsnorm_spec(cfg.d_model),
        "attn": attn_spec(cfg.attn_cfg),
        "ln_ffn": rmsnorm_spec(cfg.d_model),
    }
    if cfg.moe is not None:
        spec["moe"] = moe_spec(cfg.d_model, cfg.moe)
    else:
        spec["mlp"] = swiglu_spec(cfg.d_model, cfg.d_ff)
    return spec


def _stack_spec(spec, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), ("layers", *s.axes), init=s.init,
                            scale=s.scale, dtype=s.dtype),
        spec, is_leaf=lambda x: isinstance(x, ParamSpec))


def lm_spec(cfg: LMConfig) -> dict:
    layer = _layer_spec(cfg)
    return {
        "embedding": embedding_spec(cfg.vocab_padded, cfg.d_model),
        "layers": _stack_spec(layer, cfg.n_layers) if cfg.scan_layers
        else {f"layer_{i}": layer for i in range(cfg.n_layers)},
        "ln_f": rmsnorm_spec(cfg.d_model),
        "unembed": unembed_spec(cfg.d_model, cfg.vocab_padded),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _ffn(p_layer, cfg: LMConfig, h):
    if cfg.moe is not None:
        from repro.dist.sharding import current_context
        ctx = current_context()
        fsdp = None
        if (cfg.moe.impl == "shard_map" and ctx.profile is not None
                and ctx.profile.rules.get("embed") == "data"):
            fsdp = "data"
        out, aux = moe_ffn(p_layer["moe"], cfg.moe, h,
                           mesh=ctx.mesh, data_axes=ctx.data_axes,
                           fsdp_axis=fsdp)
        return out, aux
    return swiglu(p_layer["mlp"], h), 0.0


def _layer_body(cfg: LMConfig):
    def body(h, p_l):
        # barrier: stops XLA from hoisting the rmsnorm bf16->f32 convert of
        # the *entire* saved-carry stack out of the backward while-loop
        # (observed 2x carry-stack memory on the dry-run without it)
        h = grad_barrier(h)
        a, _ = attention(p_l["attn"], cfg.attn_cfg,
                         rmsnorm(p_l["ln_attn"], h, cfg.norm_eps))
        h = h + a
        f, aux = _ffn(p_l, cfg, rmsnorm(p_l["ln_ffn"], h, cfg.norm_eps))
        h = h + f
        h = shard_annotate(h, ("batch", "seq", "embed"))
        return h, aux
    return body


def _remat(fn, cfg: LMConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def hidden_states(params, cfg: LMConfig, tokens, *, extra_embeds=None):
    """Token (+ optional prefix) embeddings through all layers."""
    h = embed(params["embedding"], tokens).astype(cfg.dtype)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(cfg.dtype), h], axis=1)
    h = shard_annotate(h, ("batch", "seq", "embed"))
    body = _layer_body(cfg)
    if cfg.scan_layers:
        wrapped = _remat(body, cfg)
        h, aux = jax.lax.scan(wrapped, h, params["layers"])
        aux = jnp.sum(aux)
    else:
        aux = 0.0
        for i in range(cfg.n_layers):
            step = _remat(body, cfg)
            h, a = step(h, params["layers"][f"layer_{i}"])
            aux = aux + a
    return rmsnorm(params["ln_f"], h, cfg.norm_eps), aux


def logits_fn(params, cfg: LMConfig, h):
    logits = unembed(params["unembed"], h)
    logits = shard_annotate(logits, ("batch", None, "vocab"))
    return logits


def loss_fn(params, cfg: LMConfig, batch):
    """batch: tokens (B,S), labels (B,S), mask (B,S).  For VLM configs,
    ``patch_embeds`` (B,P,d) is prepended and labels cover the full
    (P + S_text) sequence."""
    h, aux = hidden_states(params, cfg, batch["tokens"],
                           extra_embeds=batch.get("patch_embeds"))
    logits = logits_fn(params, cfg, h)
    labels = batch["labels"]
    mask = batch.get("mask")
    loss = masked_xent(logits, labels, mask, cfg)
    loss = loss + 0.01 * aux
    return loss, {"loss": loss, "aux_loss": aux}


def masked_xent(logits, labels, mask, cfg: LMConfig):
    from .common import masked_xent as _mx
    return _mx(logits, labels, mask, vocab=cfg.vocab,
               vocab_padded=cfg.vocab_padded, z_loss=cfg.z_loss)


# ---------------------------------------------------------------------------
# prefill / decode (KV cache)
# ---------------------------------------------------------------------------


def cache_spec(cfg: LMConfig, batch: int, max_len: int) -> dict:
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_
    shape = (cfg.n_layers, batch, max_len, kvh, hd)
    axes = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {
        "k": ParamSpec(shape, axes, init="zeros", dtype=cfg.dtype),
        "v": ParamSpec(shape, axes, init="zeros", dtype=cfg.dtype),
        "length": ParamSpec((), (), init="zeros", dtype=jnp.int32),
    }


def prefill(params, cfg: LMConfig, batch, *, max_len: int | None = None):
    """Process the prompt, return (logits_last, cache).

    Uses the full-sequence path and collects per-layer K/V (right-padded to
    ``max_len`` for subsequent decode).  Only scanned layers are supported
    here (all assigned archs use scan).
    """
    assert cfg.scan_layers
    tokens = batch["tokens"]
    h = embed(params["embedding"], tokens).astype(cfg.dtype)
    if batch.get("patch_embeds") is not None:
        h = jnp.concatenate([batch["patch_embeds"].astype(cfg.dtype), h], 1)
    h = shard_annotate(h, ("batch", "seq", "embed"))

    def body(hh, p_l):
        a, (k, v) = attention(p_l["attn"], cfg.attn_cfg,
                              rmsnorm(p_l["ln_attn"], hh, cfg.norm_eps))
        hh = hh + a
        f, _ = _ffn(p_l, cfg, rmsnorm(p_l["ln_ffn"], hh, cfg.norm_eps))
        hh = hh + f
        hh = shard_annotate(hh, ("batch", "seq", "embed"))
        return hh, (k.astype(cfg.dtype), v.astype(cfg.dtype))

    h, (ks, vs) = jax.lax.scan(_remat(body, cfg), h, params["layers"])
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    logits = logits_fn(params, cfg, h[:, -1:, :])
    s = tokens.shape[1] + (batch["patch_embeds"].shape[1]
                           if batch.get("patch_embeds") is not None else 0)
    if max_len is not None and max_len > s:
        pad = ((0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0))
        ks = jnp.pad(ks, pad)
        vs = jnp.pad(vs, pad)
    cache = {"k": ks, "v": vs, "length": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(params, cfg: LMConfig, cache, batch):
    """One-token decode.  batch: tokens (B,1).  cache as in cache_spec.

    The full (L, B, S, kvh, hd) cache rides the layer scan as a *carry*
    (updated in place at the loop index) rather than as xs/ys: stacked ys
    cannot alias their input, which double-buffers the cache — measured
    +2x cache bytes of temp on the qwen1.5-110b decode_32k dry-run."""
    assert cfg.scan_layers
    tokens = batch["tokens"]
    h = embed(params["embedding"], tokens).astype(cfg.dtype)
    h = shard_annotate(h, ("batch", None, "embed"))
    length = cache["length"]

    def body(carry, xs):
        hh, kc, vc = carry
        p_l, i = xs
        ck = jax.lax.dynamic_index_in_dim(kc, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(vc, i, 0, keepdims=False)
        a, ck, cv = decode_attention(
            p_l["attn"], cfg.attn_cfg,
            rmsnorm(p_l["ln_attn"], hh, cfg.norm_eps), ck, cv, length)
        kc = jax.lax.dynamic_update_index_in_dim(kc, ck, i, 0)
        vc = jax.lax.dynamic_update_index_in_dim(vc, cv, i, 0)
        hh = hh + a
        f, _ = _ffn(p_l, cfg, rmsnorm(p_l["ln_ffn"], hh, cfg.norm_eps))
        hh = hh + f
        return (hh, kc, vc), None

    (h, ks, vs), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"]),
        (params["layers"], jnp.arange(cfg.n_layers)))
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    logits = logits_fn(params, cfg, h)
    return logits, {"k": ks, "v": vs, "length": length + 1}
