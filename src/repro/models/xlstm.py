"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, recurrent), per Beck et al. 2024 (arXiv:2405.04517).

mLSTM uses stabilized exponential gating with a matrix memory per head:

    m_t = max(logsig(f_t) + m_{t-1}, i_t)
    C_t = exp(logsig(f_t) + m_{t-1} - m_t) C_{t-1} + exp(i_t - m_t) v_t k_t^T
    n_t = exp(logsig(f_t) + m_{t-1} - m_t) n_{t-1} + exp(i_t - m_t) k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, exp(-m_t))

computed here with a ``lax.scan`` over time (the chunkwise-parallel variant
is an optimization documented in EXPERIMENTS.md).  sLSTM keeps a scalar
cell/normalizer pair per unit with block-diagonal (per-head) recurrent
weights and the same stabilizer; it is inherently sequential.

Both blocks follow the paper's pre-LN residual layout; the assigned
xlstm-125m config has d_ff=0, so feed-forward capacity lives inside the
blocks (mLSTM: x2 up-projection; sLSTM: 4/3 gated MLP after the cell),
as in the reference implementation.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import ParamSpec, shard_annotate


@dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int
    expand_m: int = 2            # mLSTM up-projection factor
    ff_factor: float = 4.0 / 3.0  # sLSTM post-MLP factor
    chunk: int = 256             # mLSTM chunkwise-parallel chunk length
    mlstm_impl: str = "chunked"  # chunked | scan (reference)

    @property
    def d_inner(self) -> int:
        return self.expand_m * self.d_model

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads

    @property
    def s_head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_ff_s(self) -> int:
        return int(self.d_model * self.ff_factor)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_spec(cfg: XLSTMConfig) -> dict:
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    return {
        "w_up": ParamSpec((d, di), ("embed", "mlp")),
        "w_z": ParamSpec((d, di), ("embed", "mlp")),
        "w_q": ParamSpec((di, di), ("mlp", "heads_qk")),
        "w_k": ParamSpec((di, di), ("mlp", "heads_qk")),
        "w_v": ParamSpec((di, di), ("mlp", "heads_qk")),
        "w_i": ParamSpec((di, h), ("mlp", "heads")),
        "w_f": ParamSpec((di, h), ("mlp", "heads")),
        "b_i": ParamSpec((h,), ("heads",), init="zeros"),
        "b_f": ParamSpec((h,), ("heads",), init="ones"),
        "w_down": ParamSpec((di, d), ("mlp", "embed")),
    }


def _mlstm_core(q, k, v, i_raw, f_raw, *, state=None):
    """q/k/v: (B,S,H,P); i_raw/f_raw: (B,S,H).  Returns (h, state).

    state = (C (B,H,P,P), n (B,H,P), m (B,H))."""
    b, s, h, p = q.shape
    scale = 1.0 / math.sqrt(p)
    lf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))          # (B,S,H)
    ir = i_raw.astype(jnp.float32)

    if state is None:
        c0 = jnp.zeros((b, h, p, p), jnp.float32)
        n0 = jnp.zeros((b, h, p), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, it, ft = inp                                # (B,H,P)...
        m_new = jnp.maximum(ft + m, it)
        a = jnp.exp(ft + m - m_new)[..., None]                  # (B,H,1)
        bgate = jnp.exp(it - m_new)[..., None]
        c = a[..., None] * c + bgate[..., None] * (
            vt[..., :, None] * kt[..., None, :])                # (B,H,P,P)
        n = a * n + bgate * kt
        qs = qt * scale
        num = jnp.einsum("bhvk,bhk->bhv", c, qs)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qs)),
                          jnp.exp(-m_new))[..., None]
        return (c, n, m_new), num / den

    xs = (q.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          ir.transpose(1, 0, 2), lf.transpose(1, 0, 2))
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    out = hs.transpose(1, 0, 2, 3).astype(q.dtype)              # (B,S,H,P)
    return out, (c, n, m)


def _mlstm_chunked(q, k, v, i_raw, f_raw, *, state=None, chunk: int = 256):
    """Chunkwise-parallel mLSTM: identical semantics to :func:`_mlstm_core`
    (same stabilized exponential gating) but O(S/L) sequential steps with
    (L, L) intra-chunk score matrices — the trainable formulation (mLSTM is
    linear attention with decay, so the SSD-style chunking applies).

    Derivation: with g_t = logsig(f_t), F_t = cumsum(g)_t and carry
    stabilizer m_prev, the sequential m_t equals
    ``max(F_t + cummax(i - F)_t, F_t + m_prev)`` and every term of C_t/n_t
    becomes a row of ``exp(F_t - F_j + i_j - m_t)`` scores.
    """
    b, s_orig, h, p = q.shape
    scale = 1.0 / math.sqrt(p)
    l = min(chunk, s_orig)
    pad = (-s_orig) % l
    if pad:
        # padded steps: f=+inf (decay 1 keeps state), i=-inf (no input)
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        f_raw = jnp.pad(f_raw, ((0, 0), (0, pad), (0, 0)),
                        constant_values=60.0)       # logsig(60) ~ 0
    s = s_orig + pad
    nc = s // l

    lf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    ir = i_raw.astype(jnp.float32)

    if state is None:
        c0 = jnp.zeros((b, h, p, p), jnp.float32)
        n0 = jnp.zeros((b, h, p), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state

    qc = (q.astype(jnp.float32) * scale).reshape(b, nc, l, h, p)
    kc = k.astype(jnp.float32).reshape(b, nc, l, h, p)
    vc = v.astype(jnp.float32).reshape(b, nc, l, h, p)
    ic = ir.reshape(b, nc, l, h)
    gc = lf.reshape(b, nc, l, h)

    ii = jnp.arange(l)
    tri = (ii[:, None] >= ii[None, :])[None, :, :, None]    # (1,L,L,1)

    def chunk_step(carry, inp):
        c_prev, n_prev, m_prev = carry
        qk, kk, vk, ik, gk = inp                       # (B,L,H,*) per chunk
        f_cum = jnp.cumsum(gk, axis=1)                 # F_t inclusive
        r = jax.lax.cummax(ik - f_cum, axis=1)         # cummax(i - F)
        m_t = f_cum + jnp.maximum(r, m_prev[:, None])  # (B,L,H)
        # intra scores: exp(F_t - F_j + i_j - m_t), j <= t
        logS = (f_cum[:, :, None, :] - f_cum[:, None, :, :]
                + ik[:, None, :, :] - m_t[:, :, None, :])
        sc = jnp.where(tri, jnp.exp(logS), 0.0)        # (B,L,L,H)
        # inter decay: exp(F_t + m_prev - m_t)
        inter = jnp.exp(f_cum + m_prev[:, None] - m_t)  # (B,L,H)
        kq = jnp.einsum("bjhp,bthp->btjh", kk, qk)      # k_j . q_t
        num = jnp.einsum("btjh,btjh,bjhp->bthp", sc, kq, vk)
        num = num + inter[..., None] * jnp.einsum("bhvp,bthp->bthv",
                                                  c_prev, qk)
        den = jnp.einsum("btjh,btjh->bth", sc, kq) \
            + inter * jnp.einsum("bhp,bthp->bth", n_prev, qk)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        hs = num / den
        # carry update at chunk end
        m_new = m_t[:, -1]
        dec_last = jnp.exp(f_cum[:, -1:, :] + m_prev[:, None] - m_t[:, -1:])
        w_j = jnp.exp(f_cum[:, -1:, :] - f_cum + ik - m_t[:, -1:])  # (B,L,H)
        c_new = dec_last[:, 0, :, None, None] * c_prev + jnp.einsum(
            "bjh,bjhv,bjhk->bhvk", w_j, vk, kk)
        n_new = dec_last[:, 0, :, None] * n_prev + jnp.einsum(
            "bjh,bjhp->bhp", w_j, kk)
        return (c_new, n_new, m_new), hs

    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), ic.transpose(1, 0, 2, 3),
          gc.transpose(1, 0, 2, 3))
    # checkpoint each chunk (see mamba2._ssd_chunked): keeps backward memory
    # at O(S) instead of saving every (L, L, H) score tile
    (c, n, m), hs = jax.lax.scan(jax.checkpoint(chunk_step), (c0, n0, m0), xs)
    out = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)[:, :s_orig]
    return out.astype(q.dtype), (c, n, m)


def mlstm_block(p, cfg: XLSTMConfig, u, *, state=None, return_state=False):
    b, s, d = u.shape
    dt = u.dtype
    x = jnp.einsum("bsd,dk->bsk", u, p["w_up"].astype(dt))
    z = jnp.einsum("bsd,dk->bsk", u, p["w_z"].astype(dt))
    h, hd = cfg.n_heads, cfg.head_dim
    q = jnp.einsum("bsk,kj->bsj", x, p["w_q"].astype(dt)).reshape(b, s, h, hd)
    k = jnp.einsum("bsk,kj->bsj", x, p["w_k"].astype(dt)).reshape(b, s, h, hd)
    v = jnp.einsum("bsk,kj->bsj", x, p["w_v"].astype(dt)).reshape(b, s, h, hd)
    i_raw = jnp.einsum("bsk,kh->bsh", x, p["w_i"].astype(dt)) + p["b_i"].astype(dt)
    f_raw = jnp.einsum("bsk,kh->bsh", x, p["w_f"].astype(dt)) + p["b_f"].astype(dt)
    q = shard_annotate(q, ("batch", None, "heads", None))
    if cfg.mlstm_impl == "chunked" and s > 1:
        core, new_state = _mlstm_chunked(q, k, v, i_raw, f_raw, state=state,
                                         chunk=cfg.chunk)
    else:
        core, new_state = _mlstm_core(q, k, v, i_raw, f_raw, state=state)
    core = core.reshape(b, s, cfg.d_inner)
    out = jnp.einsum("bsk,kd->bsd", core * jax.nn.silu(z),
                     p["w_down"].astype(dt))
    if return_state:
        return out, new_state
    return out


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_spec(cfg: XLSTMConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.s_head_dim
    gates = {}
    for g in ("z", "i", "f", "o"):
        gates[f"w_{g}"] = ParamSpec((d, d), ("embed", "heads_qk"))
        gates[f"r_{g}"] = ParamSpec((h, hd, hd), ("heads", None, None),
                                    scale=0.5 / math.sqrt(hd))
        gates[f"b_{g}"] = ParamSpec((d,), ("embed",),
                                    init="ones" if g == "f" else "zeros")
    return {
        **gates,
        "ff_up": ParamSpec((d, cfg.d_ff_s), ("embed", "mlp")),
        "ff_gate": ParamSpec((d, cfg.d_ff_s), ("embed", "mlp")),
        "ff_down": ParamSpec((cfg.d_ff_s, d), ("mlp", "embed")),
    }


def _slstm_core(p, cfg: XLSTMConfig, x, *, state=None):
    """x: (B,S,d).  Sequential scan with per-head recurrent weights."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.s_head_dim
    dt = x.dtype

    pre = {g: (jnp.einsum("bsd,dk->bsk", x, p[f"w_{g}"].astype(dt))
               + p[f"b_{g}"].astype(dt)).astype(jnp.float32)
           for g in ("z", "i", "f", "o")}

    if state is None:
        c0 = jnp.zeros((b, h, hd), jnp.float32)
        n0 = jnp.ones((b, h, hd), jnp.float32)
        hid0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.zeros((b, h, hd), jnp.float32)
    else:
        c0, n0, hid0, m0 = state

    rw = {g: p[f"r_{g}"].astype(jnp.float32) for g in ("z", "i", "f", "o")}

    def step(carry, inp):
        c, n, hid, m = carry
        zt, it, ft, ot = (v.reshape(b, h, hd) for v in inp)
        rec = {g: jnp.einsum("bhk,hkj->bhj", hid, rw[g])
               for g in ("z", "i", "f", "o")}
        zv = jnp.tanh(zt + rec["z"])
        ov = jax.nn.sigmoid(ot + rec["o"])
        ilog = it + rec["i"]
        flog = jax.nn.log_sigmoid(ft + rec["f"])
        m_new = jnp.maximum(flog + m, ilog)
        iv = jnp.exp(ilog - m_new)
        fv = jnp.exp(flog + m - m_new)
        c = fv * c + iv * zv
        n = fv * n + iv
        hid_new = ov * c / jnp.maximum(n, 1e-6)
        return (c, n, hid_new, m_new), hid_new

    xs = tuple(pre[g].transpose(1, 0, 2) for g in ("z", "i", "f", "o"))
    (c, n, hid, m), hs = jax.lax.scan(step, (c0, n0, hid0, m0), xs)
    out = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(dt)
    return out, (c, n, hid, m)


def slstm_block(p, cfg: XLSTMConfig, u, *, state=None, return_state=False):
    core, new_state = _slstm_core(p, cfg, u, state=state)
    # post gated MLP (factor 4/3)
    dt = u.dtype
    g = jnp.einsum("bsd,df->bsf", core, p["ff_gate"].astype(dt))
    up = jnp.einsum("bsd,df->bsf", core, p["ff_up"].astype(dt))
    out = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(g) * up,
                     p["ff_down"].astype(dt))
    if return_state:
        return out, new_state
    return out
