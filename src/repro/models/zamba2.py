"""Zamba2 hybrid LM: a Mamba2 backbone with one *shared* attention+MLP
block applied at evenly spaced depths (arXiv:2411.15242).

Faithfulness notes (DESIGN.md §6): the shared block's weights are reused at
every application; per-application specialization is a stacked per-use
RMSNorm gain + low-rank (LoRA) adapter on the attention output projection
(the reference model uses per-use LoRA on all shared projections; we keep
one site).  The reference concatenates the original embedding with the
hidden state at shared-block inputs; we use the standard residual stream.

Decode carries one SSM state + conv state per Mamba layer and one KV cache
per shared-block *application* — sub-quadratic in sequence length, which is
why this arch runs the ``long_500k`` cell.

Scan structure: ``lax.scan`` over the 38 stacked Mamba layers; a per-layer
boolean flag selects (``lax.cond``) whether the shared block fires before
the Mamba mixer, and a carried application counter indexes the shared KV
cache — HLO stays O(1) in depth.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .attention import AttnConfig, attn_spec, attention, decode_attention
from .common import (
    ParamSpec,
    embed,
    embedding_spec,
    masked_xent,
    rmsnorm,
    rmsnorm_spec,
    shard_annotate,
    swiglu,
    swiglu_spec,
    unembed,
    unembed_spec,
)
from .mamba2 import Mamba2Config, mamba2_layer, mamba2_spec
from .lm import pad_vocab


@dataclass(frozen=True)
class Zamba2Config:
    name: str
    n_layers: int                 # Mamba2 layers
    d_model: int
    n_heads: int                  # shared attention block heads
    n_kv_heads: int
    d_ff: int                     # shared block MLP
    vocab: int
    d_state: int = 64
    shared_every: int = 6         # apply shared block before layer i if i % shared_every == 0
    lora_rank: int = 64
    mamba_head_dim: int = 64
    mamba_chunk: int = 256
    attn_impl: str = "chunked"
    attn_chunk: int = 1024
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    remat: str = "none"
    vocab_pad_multiple: int = 2048
    z_loss: float = 0.0

    @property
    def n_shared(self) -> int:
        return (self.n_layers + self.shared_every - 1) // self.shared_every

    @property
    def head_dim_(self) -> int:
        return self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab, self.vocab_pad_multiple)

    @property
    def mamba_cfg(self) -> Mamba2Config:
        return Mamba2Config(d_model=self.d_model, d_state=self.d_state,
                            head_dim=self.mamba_head_dim,
                            chunk=self.mamba_chunk)

    @property
    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(d_model=self.d_model, n_heads=self.n_heads,
                          n_kv_heads=self.n_kv_heads, head_dim=self.head_dim_,
                          impl=self.attn_impl, chunk_size=self.attn_chunk)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def _stack(spec, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), ("layers", *s.axes), init=s.init,
                            scale=s.scale, dtype=s.dtype),
        spec, is_leaf=lambda x: isinstance(x, ParamSpec))


def zamba2_spec(cfg: Zamba2Config) -> dict:
    mamba_layer = {
        "ln": rmsnorm_spec(cfg.d_model),
        "mamba": mamba2_spec(cfg.mamba_cfg),
    }
    d, r, ns = cfg.d_model, cfg.lora_rank, cfg.n_shared
    return {
        "embedding": embedding_spec(cfg.vocab_padded, cfg.d_model),
        "layers": _stack(mamba_layer, cfg.n_layers),
        "shared": {
            "ln_attn": rmsnorm_spec(d),
            "attn": attn_spec(cfg.attn_cfg),
            "ln_ffn": rmsnorm_spec(d),
            "mlp": swiglu_spec(d, cfg.d_ff),
            # per-application specialization (stacked over applications)
            "use_gain": ParamSpec((ns, d), (None, "embed"), init="ones"),
            "lora_a": ParamSpec((ns, d, r), (None, "embed", None),
                                scale=0.01),
            "lora_b": ParamSpec((ns, r, d), (None, None, "embed"),
                                init="zeros"),
        },
        "ln_f": rmsnorm_spec(cfg.d_model),
        "unembed": unembed_spec(cfg.d_model, cfg.vocab_padded),
    }


def _shared_flags(cfg: Zamba2Config) -> jnp.ndarray:
    return (jnp.arange(cfg.n_layers) % cfg.shared_every == 0)


# ---------------------------------------------------------------------------
# forward (train / prefill path)
# ---------------------------------------------------------------------------


def _apply_shared(ps, cfg: Zamba2Config, h, app_idx, *, cache=None,
                  cache_len=None):
    """One application of the shared transformer block.  ``app_idx`` selects
    the per-use gain/LoRA.  Returns (h, (k, v)) — k/v for cache collection
    (train/prefill) or the updated cache slice (decode)."""
    gain = jax.lax.dynamic_index_in_dim(ps["use_gain"], app_idx, 0,
                                        keepdims=False)
    la = jax.lax.dynamic_index_in_dim(ps["lora_a"], app_idx, 0, keepdims=False)
    lb = jax.lax.dynamic_index_in_dim(ps["lora_b"], app_idx, 0, keepdims=False)
    x = rmsnorm(ps["ln_attn"], h, cfg.norm_eps) * gain.astype(h.dtype)
    if cache is None:
        a, (k, v) = attention(ps["attn"], cfg.attn_cfg, x)
        kv = (k, v)
    else:
        ck, cv = cache
        a, ck, cv = decode_attention(ps["attn"], cfg.attn_cfg, x, ck, cv,
                                     cache_len)
        kv = (ck, cv)
    a = a + jnp.einsum("bsd,dr,re->bse", x, la.astype(h.dtype),
                       lb.astype(h.dtype))
    h = h + a
    h = h + swiglu(ps["mlp"], rmsnorm(ps["ln_ffn"], h, cfg.norm_eps))
    return h, kv


def hidden_states(params, cfg: Zamba2Config, tokens, *, collect_kv=False):
    """Embeddings through the hybrid stack.

    Returns (h_final, aux=0, kv_stack)  where kv_stack is (n_layers, ...)
    with zeros at non-shared layers when ``collect_kv`` (prefill uses it).
    """
    b, s = tokens.shape
    h = embed(params["embedding"], tokens).astype(cfg.dtype)
    h = shard_annotate(h, ("batch", None, "embed"))
    flags = _shared_flags(cfg)
    ps = params["shared"]
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_

    def body(carry, xs):
        hh, app = carry
        p_l, flag = xs

        def with_shared(hh):
            out, (k, v) = _apply_shared(ps, cfg, hh, app)
            return out, (k.astype(cfg.dtype), v.astype(cfg.dtype))

        def without(hh):
            z = jnp.zeros((b, s, kvh, hd), cfg.dtype)
            return hh, (z, z)

        hh, kv = jax.lax.cond(flag, with_shared, without, hh)
        app = app + flag.astype(jnp.int32)
        hh = hh + mamba2_layer(p_l["mamba"], cfg.mamba_cfg,
                               rmsnorm(p_l["ln"], hh, cfg.norm_eps))
        hh = shard_annotate(hh, ("batch", None, "embed"))
        return (hh, app), (kv if collect_kv else 0.0)

    wrapped = body
    if cfg.remat != "none":
        wrapped = jax.checkpoint(body)
    (h, _), kvs = jax.lax.scan(wrapped, (h, jnp.asarray(0, jnp.int32)),
                               (params["layers"], flags))
    return rmsnorm(params["ln_f"], h, cfg.norm_eps), 0.0, kvs


def loss_fn(params, cfg: Zamba2Config, batch):
    h, aux, _ = hidden_states(params, cfg, batch["tokens"])
    logits = unembed(params["unembed"], h)
    logits = shard_annotate(logits, ("batch", None, "vocab"))
    loss = masked_xent(logits, batch["labels"], batch.get("mask"),
                       vocab=cfg.vocab, vocab_padded=cfg.vocab_padded,
                       z_loss=cfg.z_loss)
    return loss, {"loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------


def cache_spec(cfg: Zamba2Config, batch: int, max_len: int) -> dict:
    """Decode state: per-Mamba-layer SSM + conv states, per-application
    shared-attention KV (only n_shared caches, not n_layers)."""
    m = cfg.mamba_cfg
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_
    kv_shape = (cfg.n_shared, batch, max_len, kvh, hd)
    kv_axes = (None, "batch", "seq", "kv_heads", "head_dim")
    return {
        "ssm": ParamSpec((cfg.n_layers, batch, m.n_heads, m.d_state,
                          m.head_dim),
                         ("layers", "batch", "heads", None, None),
                         init="zeros", dtype=jnp.float32),
        "conv": ParamSpec((cfg.n_layers, batch, m.conv_kernel - 1, m.conv_dim),
                          ("layers", "batch", None, "mamba_inner"),
                          init="zeros", dtype=cfg.dtype),
        "k": ParamSpec(kv_shape, kv_axes, init="zeros", dtype=cfg.dtype),
        "v": ParamSpec(kv_shape, kv_axes, init="zeros", dtype=cfg.dtype),
        "length": ParamSpec((), (), init="zeros", dtype=jnp.int32),
    }


def prefill(params, cfg: Zamba2Config, batch, *, max_len: int | None = None):
    """Process the prompt; return (last-token logits, decode cache).

    The shared-application KV caches ride the layer scan as a *carry* of
    shape (n_shared, B, max_len, kvh, hd) written in place at the current
    application index — memory stays at cache size (never n_layers x)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_len = max_len or s
    h = embed(params["embedding"], tokens).astype(cfg.dtype)
    flags = _shared_flags(cfg)
    ps = params["shared"]
    kvh, hd = cfg.n_kv_heads, cfg.head_dim_

    k0 = jnp.zeros((cfg.n_shared, b, max_len, kvh, hd), cfg.dtype)
    v0 = jnp.zeros_like(k0)

    def body(carry, xs):
        hh, app, kc, vc = carry
        p_l, flag = xs

        def with_shared(args):
            hh, kc, vc = args
            out, (k, v) = _apply_shared(ps, cfg, hh, app)
            pad = max_len - s
            if pad > 0:
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kc = jax.lax.dynamic_update_index_in_dim(
                kc, k.astype(cfg.dtype), app, 0)
            vc = jax.lax.dynamic_update_index_in_dim(
                vc, v.astype(cfg.dtype), app, 0)
            return out, kc, vc

        hh, kc, vc = jax.lax.cond(flag, with_shared, lambda a: a,
                                  (hh, kc, vc))
        app = app + flag.astype(jnp.int32)
        mixed, (ssm, conv) = mamba2_layer(
            p_l["mamba"], cfg.mamba_cfg,
            rmsnorm(p_l["ln"], hh, cfg.norm_eps), return_state=True)
        hh = hh + mixed
        return (hh, app, kc, vc), (ssm, conv)

    (h, _, k, v), (ssms, convs) = jax.lax.scan(
        body, (h, jnp.asarray(0, jnp.int32), k0, v0),
        (params["layers"], flags))
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    logits = unembed(params["unembed"], h[:, -1:, :])
    cache = {"ssm": ssms, "conv": convs, "k": k, "v": v,
             "length": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(params, cfg: Zamba2Config, cache, batch):
    """One-token decode.  batch: tokens (B, 1).

    Shared KV caches are scan *carries* (updated in place at the current
    application index); Mamba states are scan xs/ys (one per layer)."""
    tokens = batch["tokens"]
    h = embed(params["embedding"], tokens).astype(cfg.dtype)
    flags = _shared_flags(cfg)
    ps = params["shared"]
    length = cache["length"]

    def body(carry, xs):
        hh, app, kc, vc = carry
        p_l, flag, ssm, conv = xs

        def with_shared(args):
            hh, kc, vc = args
            ck = jax.lax.dynamic_index_in_dim(kc, app, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(vc, app, 0, keepdims=False)
            out, (ck, cv) = _apply_shared(ps, cfg, hh, app, cache=(ck, cv),
                                          cache_len=length)
            kc = jax.lax.dynamic_update_index_in_dim(kc, ck, app, 0)
            vc = jax.lax.dynamic_update_index_in_dim(vc, cv, app, 0)
            return out, kc, vc

        hh, kc, vc = jax.lax.cond(flag, with_shared, lambda a: a,
                                  (hh, kc, vc))
        app = app + flag.astype(jnp.int32)
        mixed, (ssm, conv) = mamba2_layer(
            p_l["mamba"], cfg.mamba_cfg,
            rmsnorm(p_l["ln"], hh, cfg.norm_eps),
            ssm_state=ssm, conv_state=conv, return_state=True)
        hh = hh + mixed
        return (hh, app, kc, vc), (ssm, conv)

    (h, _, k, v), (ssms, convs) = jax.lax.scan(
        body, (h, jnp.asarray(0, jnp.int32), cache["k"], cache["v"]),
        (params["layers"], flags, cache["ssm"], cache["conv"]))
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    logits = unembed(params["unembed"], h)
    new_cache = {"ssm": ssms, "conv": convs, "k": k, "v": v,
                 "length": length + 1}
    return logits, new_cache
