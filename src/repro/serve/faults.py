"""Deterministic fault injection for the serving engine.

Three fault classes, mirroring what a real serving fleet sees:

* :class:`DeviceLoss` — half the devices on one mesh axis disappear at
  a given step.  The engine loses throughput capacity (its per-step
  devisor), overflow requests bounce back to the queue for
  re-admission, and — when a real jax mesh + KV page store is attached
  — the store is resharded onto the surviving sub-mesh through
  ``repro.train.elastic`` (values must survive bit-identically).
* :class:`SlowWindow` — a ``[start, stop)`` step window in which the
  measured step time is ``factor`` times the light-speed prediction
  (thermal throttling, a straggler host).  Measured >> predicted is
  exactly the signal the engine's re-calibration watches for, so a slow
  window must produce ``recalibrate`` events.
* :class:`KVCorrupt` — a KV page checksum fails after a step; the
  victim request's pages are dropped and the request retries from
  prefill under the bounded backoff policy.

A :class:`FaultPlan` is a frozen set of events; :class:`FaultInjector`
is the engine-facing accessor (plus the seed for backoff jitter — one
seed, one exact recovery sequence).  Everything is pure data: replaying
the same (trace, plan, seed) reproduces the identical log, which is how
``tests/test_serve.py`` pins recovery sequences.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class DeviceLoss:
    """Lose half of ``axis`` just before ``step`` executes."""

    step: int
    axis: str = "data"


@dataclass(frozen=True)
class SlowWindow:
    """Steps in ``[start, stop)`` run ``factor`` times slower than the
    light-speed prediction."""

    start: int
    stop: int
    factor: float = 4.0


@dataclass(frozen=True)
class KVCorrupt:
    """A KV page checksum fails after ``step``; ``slot`` picks the
    victim position within the running batch (mod batch size)."""

    step: int
    slot: int = 0


@dataclass(frozen=True)
class FaultPlan:
    """One named, reproducible fault scenario."""

    name: str = "none"
    device_losses: tuple[DeviceLoss, ...] = ()
    slow_windows: tuple[SlowWindow, ...] = ()
    kv_corruptions: tuple[KVCorrupt, ...] = ()

    @classmethod
    def none(cls) -> "FaultPlan":
        return cls(name="none")

    @classmethod
    def device_loss(cls, step: int = 72, axis: str = "data") -> "FaultPlan":
        return cls(name="device_loss",
                   device_losses=(DeviceLoss(step=step, axis=axis),))

    @classmethod
    def slow_steps(cls, start: int = 60, stop: int = 70,
                   factor: float = 4.0) -> "FaultPlan":
        return cls(name="slow_step",
                   slow_windows=(SlowWindow(start, stop, factor),))

    @classmethod
    def kv_corruption(cls, steps: tuple[int, ...] = (66, 80),
                      slot: int = 0) -> "FaultPlan":
        return cls(name="kv_corruption",
                   kv_corruptions=tuple(KVCorrupt(step=s, slot=slot)
                                        for s in steps))


#: the bench's fault matrix, one column per class
PRESETS: dict[str, FaultPlan] = {
    "none": FaultPlan.none(),
    "device_loss": FaultPlan.device_loss(),
    "slow_step": FaultPlan.slow_steps(),
    "kv_corruption": FaultPlan.kv_corruption(),
}


def fault_plan(name: str) -> FaultPlan:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown fault plan {name!r}; "
                       f"known: {sorted(PRESETS)}") from None


@dataclass
class FaultInjector:
    """Engine-facing view of a :class:`FaultPlan`."""

    plan: FaultPlan = field(default_factory=FaultPlan.none)

    def step_factor(self, step: int) -> float:
        """Multiplier on the measured time of ``step`` (slow windows
        compound, though presets never overlap)."""
        f = 1.0
        for w in self.plan.slow_windows:
            if w.start <= step < w.stop:
                f *= w.factor
        return f

    def device_losses(self, step: int) -> list[DeviceLoss]:
        return [ev for ev in self.plan.device_losses if ev.step == step]

    def corruptions(self, step: int) -> list[KVCorrupt]:
        return [ev for ev in self.plan.kv_corruptions if ev.step == step]


def apply_device_loss(engine, event: DeviceLoss) -> None:
    """Shrink the engine's capacity after ``event``.

    If the engine carries a real jax mesh with a data axis that can
    shrink, the loss goes through ``repro.train.elastic``: the mesh is
    halved on ``event.axis`` and the attached KV page store is
    resharded onto the survivors (logged with the shard counts).  In
    single-device environments (CI) the loss is logical: the engine's
    data-parallel device count is halved, which degrades every
    subsequent step-time prediction the same way.
    """
    before = engine.n_devices
    resharded = False
    mesh = getattr(engine, "mesh", None)
    if mesh is not None:
        shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        if shape.get(event.axis, 1) > 1:
            from repro.train.elastic import remesh_state, shrink_mesh

            new_mesh = shrink_mesh(mesh, event.axis)
            if engine.kv_store is not None:
                engine.kv_store = remesh_state(
                    engine.kv_store, engine.kv_spec, new_mesh,
                    engine.kv_profile)
            engine.mesh = new_mesh
            engine.n_devices = max(engine.n_devices // 2, 1)
            resharded = True
    if not resharded:
        engine.n_devices = max(engine.n_devices // 2, 1)
    engine._log("device_loss", axis=event.axis, n_devices_before=before,
                n_devices_after=engine.n_devices, resharded=resharded,
                predicted_slowdown=before / engine.n_devices)
