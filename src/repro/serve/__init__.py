"""Model-guided serving: continuous batching with ECM admission control.

The ECM model predicts step time from first principles, which makes it
usable *online*: this package puts the registry-lowered
``AttentionWorkload`` predictions inside a continuous-batching serving
loop as the scheduler's brain.  Admission control, degradation under
pressure and fault recovery are all decided against — and logged with —
the model's predicted step times.

Modules:

* :mod:`repro.serve.trace` — seedable synthetic heavy-traffic traces;
* :mod:`repro.serve.policy` — SLO classes, bounded retry with backoff,
  the degradation ladder;
* :mod:`repro.serve.engine` — the continuous-batching engine on a
  virtual clock, with per-(batch, context) bucket predictions and
  online re-calibration;
* :mod:`repro.serve.faults` — deterministic fault injection (device
  loss via ``repro.train.elastic``, slow steps, corrupted KV pages).
"""
from .engine import BucketModel, EngineConfig, ServeEngine, ServingModel
from .faults import (
    PRESETS,
    DeviceLoss,
    FaultInjector,
    FaultPlan,
    KVCorrupt,
    SlowWindow,
    fault_plan,
)
from .policy import (
    SLO_CLASSES,
    DegradationPolicy,
    RequestState,
    RetryPolicy,
    SLOClass,
    slo_class,
)
from .trace import Request, TraceConfig, synthetic_trace

__all__ = [
    "BucketModel", "EngineConfig", "ServeEngine", "ServingModel",
    "DeviceLoss", "FaultInjector", "FaultPlan", "KVCorrupt", "PRESETS",
    "SlowWindow", "fault_plan",
    "SLO_CLASSES", "DegradationPolicy", "RequestState", "RetryPolicy",
    "SLOClass", "slo_class",
    "Request", "TraceConfig", "synthetic_trace",
]
