"""The continuous-batching engine: ECM predictions drive scheduling.

The engine runs on a **virtual clock**: each iteration admits queued
requests, forms one decode step over the running batch (new admissions
piggyback their prefill onto the step, chunked-prefill style), predicts
the step time from the registry-lowered ``AttentionWorkload`` models,
then "executes" it by advancing the clock by the *measured* time (the
same light-speed prediction scaled by the configured hardware factor
and any injected faults).  Nothing reads a wall clock, so a (trace,
config, fault plan, seed) tuple reproduces the run bit-for-bit — which
is what lets the tests pin exact recovery sequences.

The model is the scheduler's brain in three places:

* **bucket predictions** — :class:`BucketModel` lowers a decode-regime
  attention workload (one query row streaming the whole KV: ``sq = bq
  = 1``, the bandwidth-bound case ECM predicts well) per power-of-two
  context bucket, with ``rank(..., objective="attention")`` picking the
  KV block size per bucket, and composes per-step time as the batch's
  summed
  per-request cycles over the data-parallel devices;
* **admission control** — a request is admitted only if its predicted
  finish (prefill + remaining decode steps at the would-be batch size)
  meets its deadline; hopeless requests are rejected *with the
  prediction logged*;
* **re-calibration** — when a measured step exceeds the prediction by
  more than ``recalib_threshold`` (an injected slow step, a degraded
  part), the involved buckets' calibration multipliers are pulled
  toward the measured ratio, and subsequent admission decisions use the
  calibrated times.

Degradation under pressure and fault handling are layered on by
:mod:`repro.serve.policy` and :mod:`repro.serve.faults`; the engine
logs every transition with the prediction that triggered it.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.autotune import rank
from repro.core.machine import MachineModel, get_machine
from repro.core.workload import AttentionSpec, AttentionWorkload, lower

from .policy import DegradationPolicy, RequestState, RetryPolicy
from .trace import Request


# ---------------------------------------------------------------------------
# The served model and the per-bucket ECM predictions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ServingModel:
    """First-order description of the served transformer's attention
    path (the decode bottleneck the ECM model predicts): head count,
    layer count, head dimension and KV dtype width."""

    heads: int = 8
    layers: int = 16
    d: int = 128
    elem_bytes: int = 4

    def o_lines_per_token(self, line_bytes: int = 64) -> float:
        """Cache lines of attention output per generated token across
        all heads and layers — the unit-of-work count that converts the
        per-CL ECM prediction into per-token cycles."""
        return (self.d * self.elem_bytes / line_bytes) \
            * self.heads * self.layers


def pow2_bucket(x: int, lo: int, hi: int) -> int:
    """Smallest power of two >= ``x``, clamped to ``[lo, hi]``."""
    b = lo
    while b < x and b < hi:
        b *= 2
    return b


class BucketModel:
    """Per-(kind, context-bucket) ECM step-time predictions + online
    calibration.

    Decode buckets lower ``AttentionWorkload(sq=1, bq=1, skv=bucket)``
    — one query row streaming the whole KV, ``causal=False`` (decode
    attends to everything already cached).  Prefill buckets lower the
    causal tiled workload at the bucket's square shape.  For each
    bucket ``rank(..., objective="attention")`` ranks the KV block
    candidates and
    the engine serves from the winner (degradation level 2 falls back
    to the smallest fitting candidate).  ``calib`` starts at 1.0 per
    bucket and is pulled toward measured/predicted by
    :meth:`recalibrate`.
    """

    def __init__(self, machine: "MachineModel | str" = "tpu-v5e",
                 model: ServingModel = ServingModel(), *,
                 min_ctx: int = 128, max_ctx: int = 16384,
                 bkv_candidates: tuple[int, ...] = (128, 256, 512,
                                                    1024, 2048),
                 source: str = "attention"):
        if source not in ("attention", "compose"):
            raise ValueError(f"unknown bucket source {source!r}: "
                             f"expected 'attention' or 'compose'")
        self.machine = get_machine(machine)
        self.model = model
        self.min_ctx = min_ctx
        self.max_ctx = max_ctx
        self.bkv_candidates = bkv_candidates
        #: "attention" scales the ranked per-CL prediction directly;
        #: "compose" routes the same workload through the whole-model
        #: composition engine (repro.core.compose) — the two agree
        #: bit-for-bit for this single-op model, which is exactly what
        #: lets the engine swap brains with zero behavior drift
        self.source = source
        self.spec = AttentionSpec(elem_bytes=model.elem_bytes)
        self.calib: dict[tuple[str, int], float] = {}
        self._decode: dict[int, dict] = {}
        self._prefill: dict[int, dict] = {}
        #: full candidate rankings per (kind, bucket), kept as the
        #: ``prior`` for incremental re-ranking; ``_dirty`` buckets are
        #: refreshed through it on next access (EWMA re-calibration moves
        #: no lowering input, so that refresh re-lowers nothing)
        self._rankings: dict[tuple[str, int], list[dict]] = {}
        self._dirty: set[tuple[str, int]] = set()
        self._model_token = None
        #: the ranked (data, model) device split; ``None`` until the
        #: engine installs one (trivially all-DP) or :meth:`remesh`
        #: re-ranks it after a device count change
        self.mesh_plan: dict | None = None

    # -- bucket construction ------------------------------------------------

    def ctx_bucket(self, ctx: int) -> int:
        return pow2_bucket(int(ctx), self.min_ctx, self.max_ctx)

    def _machine_token(self):
        """Fingerprint of the machine calibration this model's buckets
        were ranked against (tracking the registry: a re-registered
        machine under the same name is a published calibration update)."""
        from repro.core import engine as core_engine
        from repro.core.machine import MACHINES
        return core_engine.fingerprint(
            MACHINES.get(self.machine.name, self.machine))

    def _refresh_if_stale(self) -> None:
        tok = self._machine_token()
        if tok != self._model_token:
            if self._model_token is not None:
                # machine calibration changed: every bucket's lowering
                # inputs moved, so prior rankings are no longer valid
                # priors — full cold rebuild on next access
                from repro.core.machine import MACHINES
                self.machine = MACHINES.get(self.machine.name,
                                            self.machine)
                self._decode.clear()
                self._prefill.clear()
                self._rankings.clear()
                self._dirty.clear()
            self._model_token = tok

    def _ranking_cache_key(self, kind: str, cb: int, blocks) -> tuple:
        return ("bucket-rank", kind, cb, self.model.d, self.spec,
                tuple(blocks))

    def _cached_prior(self, kind: str, cb: int, blocks):
        """Ranking prior for a bucket: in-memory first, then the on-disk
        cache (``repro.core.diskcache``).  A disk hit seeds the PR-8
        incremental path — ``rank(..., prior=hit, dirty=())`` re-lowers
        nothing, so a warm restart skips straight to serving."""
        prior = self._rankings.get((kind, cb))
        if prior is not None:
            return prior
        from repro.core import diskcache
        hit = diskcache.get("bucket-rank",
                            self._ranking_cache_key(kind, cb, blocks),
                            machine=self.machine)
        if hit is not None:
            return [dict(r, block=tuple(r["block"])) for r in hit]
        return None

    def _persist_ranking(self, kind: str, cb: int, blocks, ranked) -> None:
        from repro.core import diskcache
        diskcache.put("bucket-rank",
                      self._ranking_cache_key(kind, cb, blocks),
                      ranked, machine=self.machine)

    def _decode_entry(self, cb: int) -> dict:
        self._refresh_if_stale()
        key = ("decode", cb)
        ent = self._decode.get(cb)
        if ent is None or key in self._dirty:
            blocks = [(1, bkv) for bkv in self.bkv_candidates if bkv <= cb] \
                or [(1, cb)]
            ranked = rank(
                (1, cb, self.model.d), self.machine, objective="attention",
                blocks=blocks, causal=False, spec=self.spec,
                prior=self._cached_prior("decode", cb, blocks), dirty=())
            self._rankings[key] = ranked
            self._persist_ranking("decode", cb, blocks, ranked)
            self._dirty.discard(key)
            fitting = [r for r in ranked if r["fits"]] or ranked
            by_bkv = {r["block"][1]: r["t_ecm"] for r in ranked}
            ent = {
                "best_bkv": fitting[0]["block"][1],
                "min_bkv": min(r["block"][1] for r in fitting),
                "cy_per_cl": by_bkv,
                "tile_bytes": {r["block"][1]: r["tile_bytes"]
                               for r in ranked},
            }
            self._decode[cb] = ent
        return ent

    def _prefill_entry(self, cb: int) -> dict:
        self._refresh_if_stale()
        key = ("prefill", cb)
        ent = self._prefill.get(cb)
        if ent is None or key in self._dirty:
            blocks = [(bq, bkv)
                      for bq in self.bkv_candidates if bq <= cb
                      for bkv in self.bkv_candidates if bkv <= cb] \
                or [(cb, cb)]
            ranked = rank(
                (cb, cb, self.model.d), self.machine, objective="attention",
                blocks=blocks, causal=True, spec=self.spec,
                prior=self._cached_prior("prefill", cb, blocks), dirty=())
            self._rankings[key] = ranked
            self._persist_ranking("prefill", cb, blocks, ranked)
            self._dirty.discard(key)
            fitting = [r for r in ranked if r["fits"]] or ranked
            best = fitting[0]
            ent = {"block": best["block"], "cy_per_cl": best["t_ecm"]}
            self._prefill[cb] = ent
        return ent

    def decode_block(self, ctx: int, *, smallest: bool = False) -> int:
        """The ranked KV block size for this context bucket (the
        degradation ladder's level-2 fallback picks the smallest)."""
        ent = self._decode_entry(self.ctx_bucket(ctx))
        return ent["min_bkv"] if smallest else ent["best_bkv"]

    def chosen_blocks(self) -> dict[int, dict]:
        """Every bucket built so far: ``{ctx_bucket: {"decode_bkv",
        "prefill_block"}}`` (the bench artifact pins these)."""
        out: dict[int, dict] = {}
        for cb, ent in sorted(self._decode.items()):
            out[cb] = {"decode_bkv": ent["best_bkv"]}
        for cb, ent in sorted(self._prefill.items()):
            out.setdefault(cb, {})["prefill_block"] = list(ent["block"])
        return out

    # -- predictions --------------------------------------------------------

    def _verify_attention_model(self, ctx_bucket, workload):
        # hook point for tests; lower() is the registry path already
        return lower(workload, self.machine)

    def _composed_cy(self, kind: str, cb: int, block, *,
                     out_tokens: int | None = None) -> float:
        """The composition-engine view of one bucket: the ranked
        attention workload as a whole-model op walk (heads x layers
        folded into the op count), composed under the machine's overlap
        rule.  For this single-op model the result is bit-identical to
        the direct per-CL product — the no-drift guarantee the serving
        tests pin."""
        from repro.core.compose import attention_op, compose_ops

        hl = self.model.heads * self.model.layers
        if kind == "decode":
            op = attention_op("serve.decode_attn", "serve", "decode",
                              sq=1, skv=cb, d=self.model.d, bq=1,
                              bkv=int(block), causal=False, count=hl,
                              spec=self.spec)
        else:
            bq, bkv = block
            op = attention_op("serve.prefill_attn", "serve", "prefill",
                              sq=cb, skv=cb, d=self.model.d, bq=int(bq),
                              bkv=int(bkv), causal=True, count=hl,
                              out_tokens=out_tokens, spec=self.spec)
        return compose_ops([op], self.machine, name="serving").cycles(kind)

    def decode_cy_per_token(self, ctx: int, *, smallest_block: bool = False,
                            calibrated: bool = True) -> float:
        """Predicted core cycles to decode one token at this context."""
        cb = self.ctx_bucket(ctx)
        ent = self._decode_entry(cb)
        bkv = ent["min_bkv"] if smallest_block else ent["best_bkv"]
        if self.source == "compose":
            cy = self._composed_cy("decode", cb, bkv)
        else:
            cy = ent["cy_per_cl"][bkv] * self.model.o_lines_per_token(
                self.machine.line_bytes)
        if calibrated:
            cy *= self.calib.get(("decode", cb), 1.0)
        return cy

    def prefill_cy(self, prompt_len: int, *, calibrated: bool = True
                   ) -> float:
        """Predicted core cycles to prefill a prompt (all layers/heads)."""
        cb = self.ctx_bucket(prompt_len)
        ent = self._prefill_entry(cb)
        if self.source == "compose":
            cy = self._composed_cy("prefill", cb, ent["block"],
                                   out_tokens=prompt_len)
        else:
            cy = ent["cy_per_cl"] * prompt_len \
                * self.model.o_lines_per_token(self.machine.line_bytes)
        if calibrated:
            cy *= self.calib.get(("prefill", cb), 1.0)
        return cy

    def seconds(self, cycles: float, n_devices: int = 1) -> float:
        """Cycles -> virtual seconds over ``n_devices`` data-parallel
        devices (requests partition across devices; the step ends when
        the slowest share does — modeled as an even split)."""
        return cycles / (self.machine.clock_hz * max(n_devices, 1))

    def remesh(self, n_devices: int, *, batch: int = 16) -> dict:
        """Re-rank the (data, model) split of the serving mesh for a new
        device count — the device-loss path.

        The same tradeoff :mod:`repro.core.mesh` prices for training, at
        serving granularity: tensor-parallel ``model`` ways shard the
        heads (cutting per-token decode latency by ``model``) but pay a
        ring all-reduce of the attention output over ICI every token,
        while data-parallel ways multiply throughput with no collective.
        Splits are ranked by predicted step seconds at an even ``batch``
        split over the data ways.  Only already-built decode buckets are
        consulted (falling back to ``min_ctx``), so re-ranking never
        grows the bucket tables the bench artifacts pin.
        """
        from repro.core.mesh import _tpu_chip

        n = max(int(n_devices), 1)
        cb = max(self._decode, default=self.min_ctx)
        cy = self.decode_cy_per_token(cb, calibrated=False)
        chip = _tpu_chip(self.machine)
        ici_bw = chip.ici_link_bytes_per_s * chip.ici_links_per_chip
        # row-parallel attention output: d_model activations per token
        # per layer, ring all-reduce moves 2*(m-1)/m of the payload
        ar_bytes = (2.0 * self.model.layers * self.model.heads
                    * self.model.d * self.model.elem_bytes)
        plans = []
        m_ways = 1
        while m_ways <= n:
            if n % m_ways == 0:
                data = n // m_ways
                t_tok = cy / (self.machine.clock_hz * m_ways)
                if m_ways > 1:
                    t_tok += ar_bytes * (m_ways - 1) / m_ways / ici_bw
                t_step = t_tok * -(-max(batch, 1) // data)
                plans.append({"data": data, "model": m_ways,
                              "t_step_s": t_step, "ctx_bucket": cb})
            m_ways *= 2
        plans.sort(key=lambda p: (p["t_step_s"], p["model"]))
        self.mesh_plan = plans[0]
        return self.mesh_plan

    # -- calibration --------------------------------------------------------

    def calibration(self, kind: str, ctx: int) -> float:
        return self.calib.get((kind, self.ctx_bucket(ctx)), 1.0)

    def recalibrate(self, kind: str, ctx: int, ratio: float,
                    alpha: float = 0.75) -> float:
        """Pull the bucket's multiplier toward ``measured/predicted``;
        returns the new value.  The bucket is marked dirty: its next
        access refreshes the ranking through the incremental path, which
        re-lowers nothing (the multiplier is applied after prediction, so
        no lowering input changed) — re-calibration never rebuilds the
        bucket tables."""
        key = (kind, self.ctx_bucket(ctx))
        old = self.calib.get(key, 1.0)
        new = (1.0 - alpha) * old + alpha * old * ratio
        self.calib[key] = new
        self._dirty.add(key)
        return new


# ---------------------------------------------------------------------------
# Engine configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EngineConfig:
    """Knobs of one engine instance (all deterministic)."""

    machine: str = "tpu-v5e"
    n_devices: int = 4
    max_batch: int = 16
    min_ctx: int = 128
    max_ctx: int = 16384
    #: true hardware time as a multiple of the light-speed prediction
    #: (1.0 = the model is exact; the fault harness perturbs per step)
    hw_factor: float = 1.0
    #: measured/predicted ratio beyond which a step triggers bucket
    #: re-calibration (either direction)
    recalib_threshold: float = 1.5
    recalib_alpha: float = 0.75
    #: slack multiplier on predicted finish vs deadline at admission
    admission_slack: float = 1.0
    max_steps: int = 100_000
    seed: int = 0
    bkv_candidates: tuple[int, ...] = (128, 256, 512, 1024, 2048)
    #: where BucketModel sources its predictions: "attention" (direct
    #: per-CL product) or "compose" (the whole-model composition engine)
    bucket_source: str = "attention"


@dataclass
class StepRecord:
    """One executed engine step (deterministic trajectory element)."""

    step: int
    t_start: float
    batch: int
    prefills: int
    predicted_s: float
    measured_s: float
    degrade_level: int
    n_devices: int
    buckets: tuple[int, ...] = ()

    @property
    def ratio(self) -> float:
        return self.measured_s / self.predicted_s if self.predicted_s else 1.0


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """Continuous batching on a virtual clock, scheduled by the ECM
    model.  See the module docstring for the loop structure; public
    results are ``log`` (the decision/event log), ``steps`` (per-step
    predicted vs measured) and :meth:`summary`."""

    def __init__(self, cfg: EngineConfig = EngineConfig(),
                 model: ServingModel = ServingModel(), *,
                 retry: RetryPolicy = RetryPolicy(),
                 degrade: DegradationPolicy = DegradationPolicy()):
        self.cfg = cfg
        self.model = model
        self.retry = retry
        self.degrade = degrade
        self.buckets = BucketModel(
            cfg.machine, model, min_ctx=cfg.min_ctx, max_ctx=cfg.max_ctx,
            bkv_candidates=cfg.bkv_candidates, source=cfg.bucket_source)
        # all-DP is the trivial split; device loss re-ranks via remesh()
        self.buckets.mesh_plan = {"data": cfg.n_devices, "model": 1,
                                  "t_step_s": None, "ctx_bucket": None}
        self.rng = np.random.default_rng(cfg.seed)
        self.now = 0.0
        self.step_idx = 0
        self.level = 0
        self.n_devices = cfg.n_devices
        self.log: list[dict] = []
        self.steps: list[StepRecord] = []
        self.requests: list[Request] = []
        # optional real-jax KV page store (resharded on device loss)
        self.mesh = None
        self.kv_store = None
        self.kv_spec = None
        self.kv_profile = None

    # -- logging ------------------------------------------------------------

    def _log(self, event: str, **fields) -> dict:
        rec = {"t": round(self.now, 9), "step": self.step_idx,
               "event": event, **fields}
        self.log.append(rec)
        return rec

    def events(self, *names: str) -> list[dict]:
        return [e for e in self.log if not names or e["event"] in names]

    # -- optional real KV store (exercised by the device-loss fault) --------

    def attach_kv_store(self, mesh, *, n_pages: int = 64,
                        page_tokens: int = 16):
        """Attach a real jax KV-page pytree sharded over ``mesh``'s
        ``data`` axis; the device-loss fault reshards it through
        ``repro.train.elastic`` (values must survive bit-identically)."""
        from repro.dist.sharding import ShardingProfile, param_shardings
        from repro.models.common import ParamSpec, is_spec

        import jax

        d = self.model.d
        spec = {"kv_pages": ParamSpec(shape=(n_pages, page_tokens, d),
                                      axes=("pages", None, None)),
                "page_table": ParamSpec(shape=(n_pages,),
                                        axes=("pages",), dtype=np.int32)}
        profile = ShardingProfile("kv_pages", rules={"pages": "data"})
        arrays = {
            "kv_pages": np.arange(n_pages * page_tokens * d,
                                  dtype=np.float32
                                  ).reshape(n_pages, page_tokens, d),
            "page_table": np.arange(n_pages, dtype=np.int32),
        }
        shardings = param_shardings(spec, mesh, profile)
        flat_a, treedef = jax.tree.flatten(arrays)
        flat_s = jax.tree.flatten(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))[0]
        self.kv_store = jax.tree.unflatten(
            treedef, [jax.device_put(a, s) for a, s in zip(flat_a, flat_s)])
        self.kv_spec = jax.tree.map(lambda s: s, spec, is_leaf=is_spec)
        self.kv_profile = profile
        self.mesh = mesh
        return self.kv_store

    # -- derived settings ---------------------------------------------------

    @property
    def effective_max_batch(self) -> int:
        return max(self.cfg.max_batch // (2 if self.level >= 1 else 1), 1)

    @property
    def smallest_blocks(self) -> bool:
        return self.level >= 2

    # -- predictions --------------------------------------------------------

    def _batch_cycles(self, running: list[Request],
                      prefills: list[Request], *, calibrated: bool) -> float:
        cy = sum(self.buckets.decode_cy_per_token(
            r.context_len, smallest_block=self.smallest_blocks,
            calibrated=calibrated) for r in running)
        cy += sum(self.buckets.prefill_cy(r.prompt_len,
                                          calibrated=calibrated)
                  for r in prefills)
        return cy

    def predict_step_s(self, running: list[Request],
                       prefills: list[Request] = (), *,
                       calibrated: bool = True,
                       n_devices: int | None = None) -> float:
        """The scheduler's core query: predicted next-step seconds."""
        return self.buckets.seconds(
            self._batch_cycles(list(running), list(prefills),
                               calibrated=calibrated),
            n_devices if n_devices is not None else self.n_devices)

    def predict_finish_s(self, req: Request, batch_size: int) -> float:
        """Predicted completion time if ``req`` were admitted into a
        batch of ``batch_size`` now: prefill (if KV is cold) plus the
        remaining decode steps, each at the batch's predicted step
        time (context frozen at admission — first-order, like the
        paper's stream counting)."""
        per_req = self.buckets.decode_cy_per_token(
            req.context_len, smallest_block=self.smallest_blocks)
        step_s = self.buckets.seconds(per_req * max(batch_size, 1),
                                      self.n_devices)
        prefill_s = 0.0
        if req.tokens_done == 0:
            prefill_s = self.buckets.seconds(
                self.buckets.prefill_cy(req.prompt_len), self.n_devices)
        return self.now + prefill_s + req.remaining_tokens * step_s

    # -- the loop -----------------------------------------------------------

    def run(self, requests: list[Request], faults=None) -> dict:
        """Serve ``requests`` to completion; returns :meth:`summary`.

        ``faults`` is a :class:`repro.serve.faults.FaultInjector` (or
        ``None``).  The loop ends when every request is terminal; it
        raises if ``cfg.max_steps`` is exceeded (a hung loop must fail,
        not stall)."""
        from .faults import apply_device_loss

        self.requests = list(requests)
        pending = sorted(self.requests, key=lambda r: (r.arrival_s, r.rid))
        queue: list[Request] = []
        running: list[Request] = []

        while pending or queue or running:
            if self.step_idx >= self.cfg.max_steps:
                raise RuntimeError(
                    f"serve loop exceeded max_steps={self.cfg.max_steps} "
                    f"({len(pending)} pending, {len(queue)} queued, "
                    f"{len(running)} running)")

            # 1. advance the clock when idle (to the next arrival or the
            #    earliest backoff-eligible queued request)
            if not running:
                times = [r.arrival_s for r in pending[:1]] \
                    + [r.eligible_s for r in queue]
                if times:
                    self.now = max(self.now, min(times))

            # 2. arrivals
            while pending and pending[0].arrival_s <= self.now:
                queue.append(pending.pop(0))

            # 3. deadline sweep: cancel queued requests that can no
            #    longer finish even solo (ECM-predicted floor)
            for r in list(queue):
                if self.predict_finish_s(r, 1) > r.deadline_s \
                        and self.now > r.arrival_s:
                    if self.predict_finish_s(r, 1) - r.deadline_s \
                            < self.buckets.seconds(
                                self.buckets.decode_cy_per_token(
                                    r.context_len), self.n_devices):
                        continue  # marginal: give admission a chance
                    r.state = RequestState.CANCELLED
                    r.finish_s = self.now
                    r.reason = "deadline unreachable"
                    queue.remove(r)
                    self._log("cancel", rid=r.rid,
                              predicted_finish_s=self.predict_finish_s(r, 1),
                              deadline_s=r.deadline_s)

            # 4. degradation ladder on the predicted next-step time
            pressure = self.predict_step_s(
                running if running else queue[: self.effective_max_batch])
            new_level = self.degrade.next_level(self.level, pressure)
            if new_level != self.level:
                self._log("degrade" if new_level > self.level else "restore",
                          level=new_level, from_level=self.level,
                          predicted_step_s=pressure,
                          step_budget_s=self.degrade.step_budget_s)
                self.level = new_level
            if self.level >= 3:
                self._shed_queue(queue)

            # 5. admission (priority, then deadline, then rid)
            prefills = self._admit(queue, running)

            if not running:
                if not pending and not queue:
                    break
                continue

            # 6. one continuous-batching step
            self._execute_step(running, prefills, queue, faults,
                               apply_device_loss)

        return self.summary()

    # -- loop pieces --------------------------------------------------------

    def _shed_queue(self, queue: list[Request]) -> None:
        """Level-3 action: shed the lowest-priority queued requests
        whose ECM-predicted finish misses their deadline."""
        for r in sorted(queue, key=lambda r: (-r.priority, r.rid)):
            predicted = self.predict_finish_s(r, self.effective_max_batch)
            if predicted * self.cfg.admission_slack > r.deadline_s:
                r.state = RequestState.SHED
                r.finish_s = self.now
                r.reason = "load shed"
                queue.remove(r)
                self._log("shed", rid=r.rid, priority=r.priority,
                          predicted_finish_s=predicted,
                          deadline_s=r.deadline_s)
                return  # one per step: pressure re-evaluated next round

    def _admit(self, queue: list[Request],
               running: list[Request]) -> list[Request]:
        prefills: list[Request] = []
        queue.sort(key=lambda r: (r.priority, r.deadline_s, r.rid))
        for r in list(queue):
            if len(running) >= self.effective_max_batch:
                break
            if r.eligible_s > self.now:
                continue  # backoff window still open
            if r.prompt_len + r.gen_len > self.cfg.max_ctx:
                r.state = RequestState.SHED
                r.finish_s = self.now
                r.reason = "context exceeds max_ctx"
                queue.remove(r)
                self._log("reject", rid=r.rid, reason=r.reason,
                          context=r.prompt_len + r.gen_len,
                          max_ctx=self.cfg.max_ctx)
                continue
            predicted = self.predict_finish_s(r, len(running) + 1)
            if predicted * self.cfg.admission_slack > r.deadline_s:
                # would blow the deadline at this batch size; if even a
                # solo run cannot make it, reject now (terminal,
                # logged) instead of queueing a hopeless request
                solo = self.predict_finish_s(r, 1)
                if solo * self.cfg.admission_slack > r.deadline_s:
                    r.state = RequestState.SHED
                    r.finish_s = self.now
                    r.reason = "deadline infeasible at admission"
                    queue.remove(r)
                    self._log("reject", rid=r.rid, reason=r.reason,
                              predicted_finish_s=solo,
                              deadline_s=r.deadline_s)
                continue
            queue.remove(r)
            r.state = RequestState.RUNNING
            r.admitted_s = self.now
            running.append(r)
            if r.tokens_done == 0:
                prefills.append(r)
            self._log("admit", rid=r.rid, batch=len(running),
                      predicted_finish_s=predicted, deadline_s=r.deadline_s,
                      ctx_bucket=self.buckets.ctx_bucket(r.context_len))
        return prefills

    def _execute_step(self, running: list[Request],
                      prefills: list[Request], queue: list[Request],
                      faults, apply_device_loss) -> None:
        cfg = self.cfg

        # fault: device loss lands before the step executes
        if faults is not None:
            for ev in faults.device_losses(self.step_idx):
                before = self.n_devices
                apply_device_loss(self, ev)
                self._bounce_lost_shard(running, queue, before,
                                        self.n_devices)
                self._requeue_overflow(running, queue, "device loss")
                # the surviving device count is a new machine shape:
                # re-rank the (data, model) split before the next step
                self.buckets.remesh(self.n_devices, batch=cfg.max_batch)

        predicted = self.predict_step_s(running, prefills)
        raw = self.predict_step_s(running, prefills, calibrated=False)
        factor = faults.step_factor(self.step_idx) if faults else 1.0
        measured = raw * cfg.hw_factor * factor

        bucket_set = tuple(sorted({self.buckets.ctx_bucket(r.context_len)
                                   for r in running}))
        self.steps.append(StepRecord(
            step=self.step_idx, t_start=self.now, batch=len(running),
            prefills=len(prefills), predicted_s=predicted,
            measured_s=measured, degrade_level=self.level,
            n_devices=self.n_devices, buckets=bucket_set))
        self.now += measured
        self.step_idx += 1

        # re-calibration: measured diverged from the calibrated
        # prediction beyond the threshold -> fold the ratio into every
        # bucket this step touched (the model must track the degraded
        # hardware before the next admission decision)
        ratio = measured / predicted if predicted > 0 else 1.0
        if ratio > cfg.recalib_threshold or ratio < 1.0 / cfg.recalib_threshold:
            for cb in bucket_set:
                new = self.buckets.recalibrate("decode", cb, ratio,
                                               cfg.recalib_alpha)
                self._log("recalibrate", kind="decode", ctx_bucket=cb,
                          predicted_s=predicted, measured_s=measured,
                          ratio=ratio, calibration=new)

        # token accounting + completions
        for r in list(running):
            r.tokens_done += 1
            if r.tokens_done >= r.gen_len:
                r.state = RequestState.DONE
                r.finish_s = self.now
                running.remove(r)
                self._log("complete", rid=r.rid,
                          latency_s=r.finish_s - r.arrival_s,
                          met_deadline=bool(r.finish_s <= r.deadline_s))

        # fault: corrupted KV page detected at step end -> drop the
        # request's pages and retry from prefill (bounded)
        if faults is not None:
            for ev in faults.corruptions(self.step_idx - 1):
                victim = self._pick_victim(running, ev)
                if victim is None:
                    continue
                self._log("kv_corrupt", rid=victim.rid,
                          ctx_bucket=self.buckets.ctx_bucket(
                              victim.context_len))
                self._bounce(victim, running, queue, "corrupted KV page")

    def _pick_victim(self, running: list[Request], ev) -> "Request | None":
        if not running:
            return None
        return running[ev.slot % len(running)]

    def _bounce_lost_shard(self, running: list[Request],
                           queue: list[Request], before: int,
                           after: int) -> None:
        """Re-admit the requests whose KV pages lived on the lost
        devices.  Pages round-robin over the data axis (request ``i``
        of the rid-sorted batch on device ``i mod n``), so losing the
        upper half of the axis loses the requests at positions with
        ``i mod before >= after`` — those re-prefill after re-admission
        (their pages are gone)."""
        if after >= before:
            return
        ordered = sorted(running, key=lambda r: r.rid)
        victims = [r for i, r in enumerate(ordered) if i % before >= after]
        for r in victims:
            self._bounce(r, running, queue, "device loss")

    def _requeue_overflow(self, running: list[Request],
                          queue: list[Request], why: str) -> None:
        """After capacity shrank (device loss), bounce the lowest-
        priority overflow back to the queue for re-admission."""
        running.sort(key=lambda r: (r.priority, r.deadline_s, r.rid))
        while len(running) > self.effective_max_batch:
            victim = running.pop()  # lowest priority, latest deadline
            self._bounce(victim, None, queue, why, drop_kv=False)

    def _bounce(self, req: Request, running: "list[Request] | None",
                queue: list[Request], why: str, *,
                drop_kv: bool = True) -> None:
        """Fault path re-admission: bounded retry with exponential
        backoff + jitter; KV drop forces a re-prefill."""
        if running is not None and req in running:
            running.remove(req)
        req.retries += 1
        req.requeues += 1
        if self.retry.exhausted(req.retries):
            req.state = RequestState.FAILED
            req.finish_s = self.now
            req.reason = f"retries exhausted after {why}"
            self._log("fail", rid=req.rid, reason=req.reason,
                      retries=req.retries)
            return
        if drop_kv:
            req.tokens_done = 0  # pages dropped: decode restarts cold
        backoff = self.retry.backoff_s(req.retries - 1, self.rng)
        req.state = RequestState.QUEUED
        req.eligible_s = self.now + backoff
        queue.append(req)
        self._log("requeue", rid=req.rid, reason=why, retries=req.retries,
                  backoff_s=backoff, eligible_s=req.eligible_s)

    # -- results ------------------------------------------------------------

    def summary(self) -> dict:
        """Deterministic run summary (virtual-clock throughput and
        latency, model accuracy, recovery accounting)."""
        reqs = self.requests
        done = [r for r in reqs if r.state is RequestState.DONE]
        lost = [r for r in reqs if not r.terminal]
        tokens = sum(r.tokens_done for r in reqs)
        t0 = min((r.arrival_s for r in reqs), default=0.0)
        makespan = max(self.now - t0, 1e-12)
        lat = sorted(r.finish_s - r.arrival_s for r in done)
        ratios = [s.ratio for s in self.steps]
        counts: dict[str, int] = {}
        for e in self.log:
            counts[e["event"]] = counts.get(e["event"], 0) + 1
        terminal: dict[str, int] = {}
        for r in reqs:
            terminal[r.state.value] = terminal.get(r.state.value, 0) + 1
        return {
            "requests": len(reqs),
            "completed": len(done),
            "lost": len(lost),
            "terminal": terminal,
            "tokens": int(tokens),
            "steps": len(self.steps),
            "makespan": float(makespan),
            "tok_rate": float(tokens / makespan),
            "latency_p50": float(np.percentile(lat, 50)) if lat else None,
            "latency_p99": float(np.percentile(lat, 99)) if lat else None,
            "deadline_hits": sum(1 for r in done
                                 if r.finish_s <= r.deadline_s),
            "step_pred_measured": {
                "mean_ratio": float(np.mean(ratios)) if ratios else 1.0,
                "max_ratio": float(np.max(ratios)) if ratios else 1.0,
            },
            "recovery": {
                "requeued": sum(r.requeues for r in reqs),
                "retried": sum(1 for r in reqs if r.retries),
                "recovered": sum(1 for r in done if r.retries),
            },
            "degrade_max_level": max(
                (s.degrade_level for s in self.steps), default=0),
            "events": counts,
            "n_devices_final": self.n_devices,
            "calibration": {f"{k}:{cb}": v
                            for (k, cb), v in sorted(self.buckets.calib.items())},
        }
