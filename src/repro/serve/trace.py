"""Synthetic heavy-traffic request traces (seedable, deterministic).

Arrivals are a Poisson process (exponential interarrivals); prompt and
generation lengths are drawn from small categorical mixes (the
(batch, context-length) bucket structure the engine schedules over);
each request carries an SLO class that fixes its priority and deadline.
Everything is drawn from one ``numpy`` generator, so a (config, seed)
pair names one exact trace — the fault-injection tests replay the same
trace under different fault plans and pin the recovery sequences.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .policy import SLO_CLASSES, RequestState, SLOClass


@dataclass
class Request:
    """One serving request plus its mutable lifecycle.

    The immutable half (lengths, SLO, deadline) comes from the trace;
    the mutable half is owned by the engine.  ``eligible_s`` is the
    earliest admission time (pushed forward by retry backoff);
    ``reason`` records why a terminal state was entered.
    """

    rid: int
    arrival_s: float
    prompt_len: int
    gen_len: int
    slo: SLOClass

    state: RequestState = RequestState.QUEUED
    tokens_done: int = 0
    retries: int = 0
    requeues: int = 0
    eligible_s: float = 0.0
    admitted_s: "float | None" = None
    finish_s: "float | None" = None
    reason: str = ""

    def __post_init__(self):
        if self.eligible_s < self.arrival_s:
            self.eligible_s = self.arrival_s

    @property
    def priority(self) -> int:
        return self.slo.priority

    @property
    def deadline_s(self) -> float:
        return self.slo.deadline_s(self.arrival_s, self.gen_len)

    @property
    def context_len(self) -> int:
        """Current KV length: prompt + decoded tokens."""
        return self.prompt_len + self.tokens_done

    @property
    def remaining_tokens(self) -> int:
        return self.gen_len - self.tokens_done

    @property
    def terminal(self) -> bool:
        from .policy import TERMINAL_STATES
        return self.state in TERMINAL_STATES


@dataclass(frozen=True)
class TraceConfig:
    """Shape of the synthetic traffic mix."""

    n_requests: int = 64
    mean_interarrival_s: float = 0.01
    prompt_lens: tuple[int, ...] = (128, 512, 2048)
    prompt_weights: tuple[float, ...] = (0.50, 0.35, 0.15)
    gen_lens: tuple[int, ...] = (16, 64, 128)
    gen_weights: tuple[float, ...] = (0.40, 0.40, 0.20)
    #: probability of each SLO class, aligned with ``SLO_CLASSES``
    slo_weights: tuple[float, ...] = (0.50, 0.30, 0.20)
    classes: tuple[SLOClass, ...] = field(default=SLO_CLASSES)


def _norm(w) -> np.ndarray:
    a = np.asarray(w, float)
    return a / a.sum()


def synthetic_trace(cfg: TraceConfig = TraceConfig(), *,
                    seed: int = 0) -> list[Request]:
    """Draw one deterministic trace: ``(cfg, seed)`` -> the exact same
    request list every time."""
    rng = np.random.default_rng(seed)
    n = cfg.n_requests
    arrivals = np.cumsum(rng.exponential(cfg.mean_interarrival_s, size=n))
    prompts = rng.choice(cfg.prompt_lens, size=n, p=_norm(cfg.prompt_weights))
    gens = rng.choice(cfg.gen_lens, size=n, p=_norm(cfg.gen_weights))
    slos = rng.choice(len(cfg.classes), size=n, p=_norm(cfg.slo_weights))
    return [
        Request(rid=i, arrival_s=float(arrivals[i]),
                prompt_len=int(prompts[i]), gen_len=int(gens[i]),
                slo=cfg.classes[int(slos[i])])
        for i in range(n)
    ]
