"""Request-lifecycle policy: SLO classes, bounded retry, degradation.

Everything here is *decision rules over ECM predictions* — none of it
looks at wall clocks or device state.  The engine feeds each rule the
model's predicted step/finish times and acts on the verdict, logging the
prediction that triggered it (so every scheduling decision is traceable
to a model output, see ``docs/serving.md``).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass


class RequestState(str, enum.Enum):
    """Lifecycle of one serving request.

    ``QUEUED -> RUNNING -> DONE`` is the happy path.  Faults bounce a
    request back to ``QUEUED`` (with a retry/backoff budget); admission
    control may end it early: ``SHED`` (load shedding / hopeless
    deadline at admission), ``CANCELLED`` (deadline blown while
    queued), ``FAILED`` (retry budget exhausted).  Every request ends
    in exactly one terminal state — a request that vanishes without one
    counts as *lost* (asserted zero by the bench and tests).
    """

    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    SHED = "shed"
    CANCELLED = "cancelled"
    FAILED = "failed"


TERMINAL_STATES = frozenset(
    {RequestState.DONE, RequestState.SHED, RequestState.CANCELLED,
     RequestState.FAILED})


# ---------------------------------------------------------------------------
# SLO classes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SLOClass:
    """One service class: a priority and a deadline budget.

    The deadline is ``arrival + base_budget_s + per_token_budget_s *
    gen_len`` — a base allowance for queueing + prefill plus a per-token
    decode allowance.  Priority 0 is the highest (admitted first, shed
    last).
    """

    name: str
    priority: int
    base_budget_s: float
    per_token_budget_s: float

    def deadline_s(self, arrival_s: float, gen_len: int) -> float:
        return arrival_s + self.base_budget_s \
            + self.per_token_budget_s * gen_len


#: the shipped service classes, tightest deadline first
SLO_CLASSES: tuple[SLOClass, ...] = (
    SLOClass("interactive", priority=0, base_budget_s=1.0,
             per_token_budget_s=0.05),
    SLOClass("standard", priority=1, base_budget_s=4.0,
             per_token_budget_s=0.10),
    SLOClass("batch", priority=2, base_budget_s=20.0,
             per_token_budget_s=0.50),
)


def slo_class(name: str) -> SLOClass:
    for c in SLO_CLASSES:
        if c.name == name:
            return c
    raise KeyError(f"unknown SLO class {name!r}; "
                   f"known: {[c.name for c in SLO_CLASSES]}")


# ---------------------------------------------------------------------------
# Bounded retry with exponential backoff + deterministic jitter
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Fault-triggered re-admission budget.

    A request bounced by a fault (corrupted KV page, device loss) is
    re-queued but only becomes *eligible* for admission again after
    ``backoff_base_s * backoff_mult**attempt`` plus jitter — the jitter
    is drawn from the engine's seeded generator, so recovery sequences
    are bit-reproducible while still de-synchronized.  After
    ``max_retries`` bounces the request is ``FAILED`` (terminal,
    accounted — never silently lost).
    """

    max_retries: int = 3
    backoff_base_s: float = 0.005
    backoff_mult: float = 2.0
    jitter_frac: float = 0.25

    def backoff_s(self, attempt: int, rng) -> float:
        base = self.backoff_base_s * self.backoff_mult ** max(attempt, 0)
        return base * (1.0 + self.jitter_frac * float(rng.random()))

    def exhausted(self, retries: int) -> bool:
        return retries > self.max_retries


# ---------------------------------------------------------------------------
# Graceful degradation ladder
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DegradationPolicy:
    """Pressure ladder driven by the ECM-predicted step time.

    The engine evaluates the predicted time of the *next* step (current
    batch at current settings) every iteration; when the prediction
    exceeds ``step_budget_s`` the ladder escalates one level, and when
    it falls back below ``restore_fraction * step_budget_s`` it
    de-escalates:

    =====  =====================================================
    level  effect
    =====  =====================================================
    0      normal operation
    1      max batch halved (shrinks the very term that blew the
           budget: predicted step time is the batch's summed
           per-request cycles)
    2      decode KV blocks fall back to the smallest ranked
           candidate (smaller resident tiles; the light-speed
           prediction ties, the working set shrinks)
    3      lowest-priority queued requests whose predicted finish
           misses their deadline are shed
    =====  =====================================================

    Every transition is logged with the predicted step time that
    triggered it.
    """

    step_budget_s: float = 0.02
    restore_fraction: float = 0.5
    max_level: int = 3

    def next_level(self, level: int, predicted_step_s: float) -> int:
        if predicted_step_s > self.step_budget_s:
            return min(level + 1, self.max_level)
        if predicted_step_s < self.restore_fraction * self.step_budget_s:
            return max(level - 1, 0)
        return level
