"""Atomic, manifest-driven checkpointing.

Layout (one directory per step)::

    <root>/step_00000420.tmp-<pid>/     # staging (invisible to restore)
        manifest.json                   # leaf paths, shapes, dtypes, metadata
        <leaf-path>.npy                 # one file per tree leaf
    <root>/step_00000420/               # os.replace'd into place (atomic)

Crash safety: a checkpoint is visible iff the final ``os.replace`` happened,
so a failure mid-save never corrupts the latest restorable state — the
restart driver (``repro.train.driver``) simply restores ``latest_step``.
Stale ``*.tmp-*`` staging dirs are garbage-collected on the next save.

Multi-host note: at >1 process each host writes only its addressable shards
(per-shard files keyed by process index) and manifests are written by
process 0; the single-process implementation here writes full arrays but
keeps the same manifest/atomic-rename protocol.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any

import jax
import numpy as np


_SEP = "/"


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        out.append((_SEP.join(keys), leaf))
    return out


def _leaf_filename(path: str) -> str:
    return path.replace(_SEP, "__") + ".npy"


def save_tree(root: str, step: int, tree, *, metadata: dict | None = None
              ) -> str:
    """Atomically save a pytree of arrays as ``<root>/step_<step>``."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    staging = f"{final}.tmp-{os.getpid()}"
    # GC stale staging dirs from crashed saves
    for d in os.listdir(root):
        if ".tmp-" in d:
            shutil.rmtree(os.path.join(root, d), ignore_errors=True)
    os.makedirs(staging, exist_ok=True)

    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for path, leaf in leaves:
        arr = np.asarray(leaf)
        fn = _leaf_filename(path)
        np.save(os.path.join(staging, fn), arr)
        manifest["leaves"][path] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(staging, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(staging, final)
    return final


def list_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for d in os.listdir(root):
        if d.startswith("step_") and ".tmp-" not in d and os.path.exists(
                os.path.join(root, d, "manifest.json")):
            out.append(int(d[len("step_"):]))
    return sorted(out)


def latest_step(root: str) -> int | None:
    steps = list_steps(root)
    return steps[-1] if steps else None


def restore_tree(root: str, step: int, like_tree, *, shardings=None):
    """Restore into the structure of ``like_tree`` (arrays or specs).

    ``shardings``: optional matching pytree of ``NamedSharding``; leaves are
    ``jax.device_put`` accordingly (each process would feed only its shard
    at multi-host scale).
    """
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    paths = [p for p, _ in _flatten_with_paths(like_tree)]
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for path, sh in zip(paths, shard_leaves):
        ent = manifest["leaves"][path]
        arr = np.load(os.path.join(d, ent["file"]))
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, manifest["metadata"]


def prune(root: str, keep_last: int) -> None:
    steps = list_steps(root)
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)


class CheckpointManager:
    """Synchronous manager: save every ``interval`` steps, keep the last N."""

    def __init__(self, root: str, *, interval: int = 100, keep_last: int = 3):
        self.root = root
        self.interval = interval
        self.keep_last = keep_last

    def maybe_save(self, step: int, tree, metadata: dict | None = None
                   ) -> str | None:
        if step % self.interval:
            return None
        path = save_tree(self.root, step, tree, metadata=metadata)
        prune(self.root, self.keep_last)
        return path

    def restore_latest(self, like_tree, shardings=None):
        s = latest_step(self.root)
        if s is None:
            return None, None, None
        tree, meta = restore_tree(self.root, s, like_tree,
                                  shardings=shardings)
        return s, tree, meta


class AsyncCheckpointer:
    """Background-thread checkpointing: the training loop hands off a
    host-transferred copy and keeps stepping (compute/IO overlap — the same
    overlap-of-contributions idea the ECM model formalizes, applied to the
    checkpoint stream)."""

    def __init__(self, root: str, *, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._err: Exception | None = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, meta = item
            try:
                save_tree(self.root, step, tree, metadata=meta)
                prune(self.root, self.keep_last)
            # noqa rationale: the worker must never die silently — any
            # write failure is captured and re-raised on submit/close
            except Exception as e:  # noqa: BLE001
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree, metadata: dict | None = None) -> None:
        if self._err:
            raise self._err
        host_tree = jax.tree.map(np.asarray, tree)   # D2H before enqueue
        self._q.put((step, host_tree, metadata))

    def wait(self) -> None:
        self._q.join()
        if self._err:
            raise self._err

    def close(self, timeout: float = 10.0) -> None:
        """Drain the queue and stop the worker.

        Raises ``RuntimeError`` if the worker is still alive after
        ``timeout`` seconds — a wedged writer (dead filesystem, stuck
        I/O) must be loud, not silently leaked as a daemon thread with
        a checkpoint possibly half-written.  Any error the worker
        recorded is surfaced too (chained when both happen).
        """
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            raise RuntimeError(
                f"checkpoint writer thread failed to stop within "
                f"{timeout:.0f}s; a write to {self.root!r} may be "
                f"wedged or half-finished") from self._err
        if self._err:
            raise self._err
