"""Checkpointing substrate: sharded, atomic, restartable."""
from .checkpoint import (
    AsyncCheckpointer,
    CheckpointManager,
    latest_step,
    restore_tree,
    save_tree,
)

__all__ = [
    "AsyncCheckpointer",
    "CheckpointManager",
    "latest_step",
    "restore_tree",
    "save_tree",
]
