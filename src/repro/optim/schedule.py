"""Learning-rate schedules as jittable step -> lr functions."""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant(lr: float) -> Schedule:
    def fn(step):
        return jnp.asarray(lr, jnp.float32)
    return fn


def cosine(peak_lr: float, total_steps: int, *, final_fraction: float = 0.1
           ) -> Schedule:
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(math.pi * t))
        return peak_lr * (final_fraction + (1 - final_fraction) * cos)
    return fn


def linear_warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                         *, final_fraction: float = 0.1) -> Schedule:
    decay = cosine(peak_lr, max(total_steps - warmup_steps, 1),
                   final_fraction=final_fraction)

    def fn(step):
        stepf = step.astype(jnp.float32)
        warm = peak_lr * stepf / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, decay(step - warmup_steps))
    return fn
