"""AdamW with global-norm clipping and optionally int8-quantized moments.

State layout per parameter leaf:

* ``f32``/``bf16`` moments: ``mu``/``nu`` arrays of the parameter's shape.
* ``int8`` moments: ``mu_q``/``nu_q`` int8 arrays + per-row ``f32`` absmax
  scales over the last axis (symmetric quantization).  The HBM cost of the
  moment streams drops from 8 B/param to ~2 B/param — this is the
  "moment-stream" optimization recorded in the TPU-ECM §Perf log.

All moment math happens in f32; quantization error only affects what is
*stored* between steps (same trade-off as 8-bit Adam, Dettmers et al.).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, is_spec
from .schedule import Schedule, constant


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    moment_dtype: str = "f32"          # f32 | bf16 | int8
    #: serialize per-leaf updates with optimization barriers so XLA reuses
    #: the f32 transient buffers across leaves instead of scheduling all
    #: leaves' mf/vf/update chains concurrently (observed ~5 concurrent
    #: 1.1 GiB chains on 94-layer stacked MoE weights in the dry-run)
    serialize_leaves: bool = True

    def validate(self) -> None:
        assert self.moment_dtype in ("f32", "bf16", "int8"), self.moment_dtype


# ---------------------------------------------------------------------------
# int8 moment quantization (symmetric, per-row over the last axis)
# ---------------------------------------------------------------------------


def _quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# state construction
# ---------------------------------------------------------------------------


def _moment_like(p, cfg: AdamWConfig):
    if cfg.moment_dtype == "int8":
        scale_shape = (*p.shape[:-1], 1) if p.ndim else ()
        return {
            "q": jnp.zeros(p.shape, jnp.int8),
            "scale": jnp.zeros(scale_shape, jnp.float32),
        }
    dt = jnp.bfloat16 if cfg.moment_dtype == "bf16" else jnp.float32
    return jnp.zeros(p.shape, dt)


def adamw_init(params, cfg: AdamWConfig) -> dict:
    cfg.validate()
    return {
        "mu": jax.tree.map(lambda p: _moment_like(p, cfg), params),
        "nu": jax.tree.map(lambda p: _moment_like(p, cfg), params),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_state_spec(param_spec_tree, cfg: AdamWConfig) -> dict:
    """Optimizer-state ParamSpec tree mirroring the parameter specs, so the
    sharding machinery can derive optimizer shardings (moments inherit the
    parameter's logical axes)."""
    cfg.validate()

    def moment_spec(s: ParamSpec):
        if cfg.moment_dtype == "int8":
            scale_shape = (*s.shape[:-1], 1) if s.shape else ()
            scale_axes = (*s.axes[:-1], None) if s.axes else ()
            return {
                "q": ParamSpec(s.shape, s.axes, init="zeros", dtype=jnp.int8),
                "scale": ParamSpec(scale_shape, scale_axes, init="zeros",
                                   dtype=jnp.float32),
            }
        dt = jnp.bfloat16 if cfg.moment_dtype == "bf16" else jnp.float32
        return ParamSpec(s.shape, s.axes, init="zeros", dtype=dt)

    return {
        "mu": jax.tree.map(moment_spec, param_spec_tree, is_leaf=is_spec),
        "nu": jax.tree.map(moment_spec, param_spec_tree, is_leaf=is_spec),
        "count": ParamSpec((), (), init="zeros", dtype=jnp.int32),
    }


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _load_moment(m, cfg: AdamWConfig):
    if cfg.moment_dtype == "int8":
        return _dequantize(m["q"], m["scale"])
    return m.astype(jnp.float32)


def _store_moment(x, cfg: AdamWConfig):
    if cfg.moment_dtype == "int8":
        q, scale = _quantize(x)
        return {"q": q, "scale": scale}
    dt = jnp.bfloat16 if cfg.moment_dtype == "bf16" else jnp.float32
    return x.astype(dt)


def adamw_update(grads, state: dict, params, cfg: AdamWConfig,
                 schedule: Schedule | None = None):
    """One AdamW step.  Returns ``(updates, new_state, metrics)``; apply with
    :func:`apply_updates`."""
    cfg.validate()
    schedule = schedule or constant(1e-3)
    count = state["count"] + 1
    lr = schedule(count)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip_norm else jnp.asarray(1.0, jnp.float32)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    mu_leaves, treedef = jax.tree.flatten(state["mu"],
                                          is_leaf=lambda x: isinstance(x, dict)
                                          and "q" in x)
    nu_leaves = treedef.flatten_up_to(state["nu"])
    g_leaves = treedef.flatten_up_to(grads)
    p_leaves = treedef.flatten_up_to(params)

    new_mu, new_nu, upd = [], [], []
    token = None
    for g, m, v, p in zip(g_leaves, mu_leaves, nu_leaves, p_leaves):
        gf = g.astype(jnp.float32) * clip
        if cfg.serialize_leaves and token is not None:
            gf, _ = jax.lax.optimization_barrier((gf, token))
        mf = b1 * _load_moment(m, cfg) + (1 - b1) * gf
        vf = b2 * _load_moment(v, cfg) + (1 - b2) * gf * gf
        mhat = mf / c1
        vhat = vf / c2
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            step_dir = step_dir + cfg.weight_decay * p.astype(jnp.float32)
        u = (-lr * step_dir).astype(p.dtype)
        upd.append(u)
        new_mu.append(_store_moment(mf, cfg))
        new_nu.append(_store_moment(vf, cfg))
        token = u.ravel()[:1] if u.ndim else u

    new_state = {
        "mu": jax.tree.unflatten(treedef, new_mu),
        "nu": jax.tree.unflatten(treedef, new_nu),
        "count": count,
    }
    updates = jax.tree.unflatten(treedef, upd)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return updates, new_state, metrics


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                      + u.astype(jnp.float32)).astype(p.dtype),
                        params, updates)
