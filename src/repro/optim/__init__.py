"""Optimizer substrate: AdamW with schedules, clipping and quantized moments.

Built from scratch in pure JAX (no optax dependency).  The optimizer state
is declared via ``ParamSpec`` trees like the models' parameters, so the same
logical-axis sharding machinery (``repro.dist.sharding``) derives the
optimizer-state shardings — moments inherit the parameter sharding (FSDP
shards optimizer state for free).

The int8-quantized moment option is one of the framework's beyond-paper
distributed-optimization tricks: it reduces the optimizer's HBM term in the
TPU-ECM model by 4x for the moment streams (EXPERIMENTS.md §Perf).
"""
from .adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    apply_updates,
    global_norm,
    opt_state_spec,
)
from .schedule import Schedule, constant, cosine, linear_warmup_cosine

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "apply_updates",
    "global_norm",
    "opt_state_spec",
    "Schedule",
    "constant",
    "cosine",
    "linear_warmup_cosine",
]
