"""Serving launcher: batched prefill + decode with a KV/state cache.

``python -m repro.launch.serve --arch <id> --batch 4 --prompt-len 16
--gen 8`` runs prefill on a synthetic prompt batch and decodes tokens,
reporting per-phase timings.  Smoke scale on CPU; the same entry point
targets the production mesh with ``--mesh single-pod``.

``--continuous`` runs the model-guided continuous-batching engine
(``repro.serve``) over a synthetic trace instead of a single static
batch: requests arrive, are admitted against their ECM-predicted finish
times, and the summary reports throughput/latency plus the full event
ledger.  Optionally combine with ``--faults <plan>`` to replay one of
the named fault scenarios.
"""
from __future__ import annotations

import argparse
import json
import time


def _continuous(args) -> int:
    """Trace-driven engine mode: pure virtual clock, no jax needed."""
    from repro.serve import (
        EngineConfig,
        FaultInjector,
        ServeEngine,
        TraceConfig,
        fault_plan,
        synthetic_trace,
    )

    engine = ServeEngine(EngineConfig(seed=args.seed))
    trace = synthetic_trace(
        TraceConfig(n_requests=args.requests), seed=args.seed)
    summary = engine.run(trace, FaultInjector(fault_plan(args.faults)))
    print(json.dumps(summary, indent=1, default=str))
    return 0 if summary["lost"] == 0 else 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--mesh", default="host",
                    choices=("host", "single-pod", "multi-pod"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="run the ECM-guided continuous-batching engine "
                         "over a synthetic trace (repro.serve)")
    ap.add_argument("--requests", type=int, default=64,
                    help="trace length for --continuous")
    ap.add_argument("--faults", default="none",
                    help="fault plan for --continuous "
                         "(none/device_loss/slow_step/kv_corruption)")
    args = ap.parse_args()

    if args.continuous:
        return _continuous(args)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import ARCH_NAMES, get_arch
    from repro.dist.sharding import get_profile, use_mesh_context
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.models.common import materialize

    if args.arch not in ARCH_NAMES:
        ap.error(f"--arch must be one of {ARCH_NAMES}")

    arch = get_arch(args.arch, smoke=args.smoke)
    if not arch.has_decoder:
        print(f"{arch.name}: encoder-only, nothing to serve")
        return 0
    multi_pod = args.mesh == "multi-pod"
    mesh = (make_host_mesh(model=1) if args.mesh == "host"
            else make_production_mesh(multi_pod=multi_pod))
    profile = get_profile(arch.profile, multi_pod=multi_pod)
    max_len = args.prompt_len + args.gen + 8

    from repro.configs.base import ShapeSpec
    shape = ShapeSpec("cli_prefill", seq_len=args.prompt_len,
                      global_batch=args.batch, kind="prefill")
    batch = {k: jnp.asarray(v)
             for k, v in arch.make_batch(shape, seed=args.seed).items()}

    with use_mesh_context(mesh, profile, multi_pod=multi_pod):
        params = materialize(arch.param_spec(), jax.random.key(args.seed))
        prefill = jax.jit(lambda p, b: arch.prefill(p, b, max_len=max_len))
        decode = jax.jit(arch.decode)

        t0 = time.perf_counter()
        logits, cache = prefill(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        toks = []
        tok = jnp.argmax(logits[:, -1, : arch.cfg.vocab], -1)[:, None]
        t0 = time.perf_counter()
        for _ in range(args.gen):
            logits, cache = decode(params, cache,
                                   {"tokens": tok.astype(jnp.int32)})
            tok = jnp.argmax(logits[:, -1, : arch.cfg.vocab], -1)[:, None]
            toks.append(np.asarray(tok[:, 0]))
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0

    # --gen 0 is a prefill-only run: no decode steps happened, so a
    # per-token decode time does not exist (it is null, not 0/0)
    print(json.dumps({
        "arch": arch.name,
        "prefill_s": round(t_prefill, 4),
        "decode_s_per_tok": (round(t_decode / args.gen, 4)
                             if args.gen > 0 else None),
        "tokens": np.stack(toks, 1).tolist() if toks else [],
    }, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
