"""One-command machine onboarding: measure, fit, emit a machine file.

The close of the measure->calibrate->predict loop (ROADMAP item 4)::

    python -m repro.launch.calibrate --machine-out /tmp/m.json

runs the microbenchmark sweeps against the default host machine, fits
every :class:`repro.core.machine.MachineModel` calibration field class
(see ``repro.core.calibrate``), prints the fit table, and writes a
versioned machine file with full provenance.  The emitted file is usable
everywhere a registry name is::

    python -m repro.launch.dryrun --all --predict --machine /tmp/m.json
    python benchmarks/run.py --suite stream --machine /tmp/m.json

With ``--cache-dir`` (or ``REPRO_CACHE_DIR``) the report persists in the
on-disk cache: a repeat run re-fits nothing.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.calibrate",
        description="Calibrate a machine from microbenchmark measurements "
                    "and emit a versioned machine file.")
    ap.add_argument("--machine", default="haswell-ep",
                    help="machine to calibrate: registry name/alias or a "
                         "machine-file path (default: haswell-ep)")
    ap.add_argument("--machine-out", metavar="PATH",
                    help="write the fitted machine file here")
    ap.add_argument("--snap-rtol", type=float, default=None,
                    help="snap-to-prior tolerance (default: "
                         "calibrate.SNAP_RTOL); fits within this relative "
                         "distance of the prior adopt it bit-identically")
    ap.add_argument("--no-snap", action="store_true",
                    help="adopt raw fits (snap_rtol=0): the new-machine "
                         "onboarding path")
    ap.add_argument("--cache-dir", metavar="DIR",
                    help="enable the on-disk calibration cache at DIR")
    ap.add_argument("--no-cache", action="store_true",
                    help="ignore the disk cache even when configured")
    ap.add_argument("--max-residual", type=float, default=None,
                    help="exit 1 if any field's fit residual exceeds this "
                         "(default: calibrate.MAX_FIT_RESIDUAL)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the fit table (summary line only)")
    args = ap.parse_args(argv)

    from repro.core import calibrate as cal
    from repro.core import diskcache
    from repro.core.machine import load_machine_file, resolve_machine

    if args.cache_dir:
        diskcache.set_cache_dir(args.cache_dir)
    snap_rtol = 0.0 if args.no_snap else (
        cal.SNAP_RTOL if args.snap_rtol is None else args.snap_rtol)
    machine = resolve_machine(args.machine)
    report = cal.calibrate(machine, snap_rtol=snap_rtol,
                           use_cache=not args.no_cache)

    if args.quiet:
        print(f"calibrated {report.base!r}: {len(report.fits)} fields, "
              f"max residual {report.residual_max():.5f}"
              + (" (cached)" if report.from_cache else ""))
    else:
        print(cal.format_report(report))

    if args.machine_out:
        path = report.save(args.machine_out)
        loaded = load_machine_file(path)
        tag = ("bit-identical to the registered prior"
               if loaded == machine else "updated calibration")
        assert loaded == report.machine, "machine file round-trip mismatch"
        print(f"wrote {path} ({tag})")

    bound = (cal.MAX_FIT_RESIDUAL if args.max_residual is None
             else args.max_residual)
    if report.residual_max() > bound:
        print(f"FAIL: max fit residual {report.residual_max():.5f} "
              f"exceeds the bound {bound:g}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
