"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it drives the *smoke-scale* config end-to-end with
the full production stack (sharded state, deterministic pipeline, fault-
tolerant driver, checkpointing).  On a real TPU fleet the same entry point
runs the full config: the mesh comes from ``--mesh`` and jax.distributed
initialization (one process per host) — everything else is identical.
"""
from __future__ import annotations

import argparse
import json
import os


from repro.configs import ARCH_NAMES, get_arch
from repro.configs.base import ShapeSpec
from repro.data.arch_data import ArchSyntheticDataset
from repro.dist.sharding import get_profile
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim import AdamWConfig
from repro.optim.schedule import linear_warmup_cosine
from repro.train.driver import Trainer, TrainerConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (CPU scale); --no-smoke for full")
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--mesh", default="host",
                    choices=("host", "single-pod", "multi-pod"))
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=25)
    ap.add_argument("--moment-dtype", default="f32",
                    choices=("f32", "bf16", "int8"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_arch(args.arch, smoke=args.smoke)
    if args.mesh == "host":
        mesh = make_host_mesh(model=1)
        multi_pod = False
    else:
        multi_pod = args.mesh == "multi-pod"
        mesh = make_production_mesh(multi_pod=multi_pod)
    profile = get_profile(arch.profile, multi_pod=multi_pod)

    shape = ShapeSpec("cli_train", seq_len=args.seq,
                      global_batch=args.batch, kind="train")
    data = ArchSyntheticDataset(arch, shape, seed=args.seed)
    opt = AdamWConfig(moment_dtype=args.moment_dtype)
    sched = linear_warmup_cosine(args.lr, args.steps // 10 + 1, args.steps)
    trainer = Trainer(
        arch, data, mesh, profile, opt, sched,
        TrainerConfig(total_steps=args.steps,
                      ckpt_dir=os.path.join(args.ckpt_dir, arch.name),
                      ckpt_interval=args.ckpt_interval,
                      accum=args.accum, seed=args.seed,
                      multi_pod=multi_pod))
    out = trainer.run()
    print(json.dumps({"arch": arch.name,
                      "steps": out["final_step"],
                      "first_loss": out["losses"][0],
                      "final_loss": out["final_loss"],
                      "stragglers": out["stragglers"]}, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
