import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract the ECM/roofline resource terms from the compiled
artifact.

This is how the distribution config is proven coherent without hardware:
``.lower().compile()`` must succeed on the 16x16 single-pod mesh AND the
2x16x16 multi-pod mesh for every runnable cell; ``memory_analysis()``
proves the per-chip footprint fits a v5e's 16 GB HBM, ``cost_analysis()``
+ HLO collective parsing feed EXPERIMENTS.md §Roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
        --shape train_4k [--multi-pod] [--out results/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # every cell

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` (resumable: cells
with an existing result are skipped unless --force).
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_NAMES, SHAPES, get_arch
from repro.configs.base import ArchDef, ShapeSpec
from repro.core import hlo as hlo_mod
from repro.core.tpu_ecm import MeshSpec, from_resources
from repro.dist.sharding import (
    ShardingProfile,
    get_profile,
    param_shardings,
    use_mesh_context,
)
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.models.common import abstract
from repro.optim import AdamWConfig
from repro.optim.schedule import linear_warmup_cosine
from repro.train.steps import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
    state_spec,
)


HBM_BYTES = 16 * 1024**3


# ---------------------------------------------------------------------------
# input construction
# ---------------------------------------------------------------------------


def input_specs(arch: ArchDef, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for the step's data inputs (no device
    allocation) — the dry-run's replacement for a real data pipeline."""
    return arch.abstract_batch(shape)


def _input_profile(arch: ArchDef, mesh, *, multi_pod: bool,
                   kv_divisible: bool,
                   batch_axes=None) -> ShardingProfile:
    batch_axes = batch_axes or (("pod", "data") if multi_pod else ("data",))
    rules = {
        "batch": batch_axes,
        "embed": None,
        "layers": None,
        "head_dim": None,
        # decode caches: shard kv heads over model when divisible, else
        # shard the sequence dim (SP) so 32k-500k caches fit per chip
        "kv_heads": "model" if kv_divisible else None,
        "seq": None if kv_divisible else "model",
        "heads": "model",
        "mamba_inner": "model",
    }
    return ShardingProfile(name="inputs", rules=rules)


def _kv_divisible(arch: ArchDef, mesh) -> bool:
    sizes = mesh_axis_sizes(mesh)
    kvh = getattr(arch.cfg, "n_kv_heads", None)
    if kvh is None:
        kvh = getattr(arch.cfg, "n_heads", 1)
    return kvh % sizes["model"] == 0


# ---------------------------------------------------------------------------
# lowering one cell
# ---------------------------------------------------------------------------


def lower_cell(arch: ArchDef, shape: ShapeSpec, *, multi_pod: bool,
               opt_cfg: AdamWConfig | None = None,
               profile_name: str | None = None,
               accum: int | None = None):
    """Lower + compile one (arch x shape x mesh) cell; returns
    (record dict, lowered, compiled)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    profile_name = profile_name or arch.profile
    profile = get_profile(profile_name, multi_pod=multi_pod)
    opt_cfg = opt_cfg or AdamWConfig(moment_dtype=arch.moment_dtype)
    kv_div = _kv_divisible(arch, mesh)
    in_prof = _input_profile(arch, mesh, multi_pod=multi_pod,
                             kv_divisible=kv_div,
                             batch_axes=profile.activation_rules.get("batch"))
    if shape.kind == "prefill":
        # sequence parallelism for the prefill residual stream: GSPMD turns
        # the per-layer TP all-reduces into reduce-scatter/all-gather pairs
        # on seq-sharded activations (half the wire bytes, 16x smaller
        # norm/residual working set per chip)
        import dataclasses as _dc
        profile = _dc.replace(
            profile,
            activation_rules={**profile.activation_rules, "seq": "model"})
    if shape.kind == "decode" and not kv_div:
        # flash-decode: with the KV cache sequence-sharded, q-heads must be
        # replicated over `model` or GSPMD all-gathers the whole cache per
        # layer per token (measured: 40 ms collective term on internlm2
        # decode_32k from exactly this)
        import dataclasses as _dc
        profile = _dc.replace(
            profile,
            activation_rules={**profile.activation_rules, "heads": None})

    batch_abs = input_specs(arch, shape)
    batch_sh = param_shardings(arch.batch_spec(shape), mesh, in_prof)
    pspec_tree = arch.param_spec()
    params_sh = param_shardings(pspec_tree, mesh, profile,
                                ensure_model_axis=True)
    params_abs = abstract(pspec_tree)

    accum = arch.train_accum if accum is None else accum
    if shape.kind == "train":
        # microbatches must stay divisible by the batch-sharding degree
        # (resolved from the profile's batch axes with prefix fallback)
        sizes = mesh_axis_sizes(mesh)
        group = profile.activation_rules.get("batch", ("data",))
        group = group if isinstance(group, tuple) else (group,)
        batch_shards = 1
        for k in range(len(group), 0, -1):
            n = 1
            for g in group[:k]:
                n *= sizes.get(g, 1)
            if shape.global_batch % n == 0:
                batch_shards = n
                break
        accum = min(accum, max(shape.global_batch // batch_shards, 1))
    cache_seq_axis = None if kv_div else "model"
    t0 = time.time()
    with use_mesh_context(mesh, profile, multi_pod=multi_pod,
                          cache_seq_axis=(cache_seq_axis
                                          if shape.kind == "decode" else None)):
        if shape.kind == "train":
            sspec = state_spec(arch, opt_cfg)
            state_sh = param_shardings(sspec, mesh, profile,
                                       ensure_model_axis=True)
            state_abs = abstract(sspec)
            step = make_train_step(arch, opt_cfg,
                                   linear_warmup_cosine(3e-4, 100, 10_000),
                                   accum=accum)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            cspec = arch.cache_spec(shape.global_batch, shape.seq_len)
            cache_sh = param_shardings(cspec, mesh, in_prof)
            prefill_step = make_prefill_step(arch, max_len=shape.seq_len)
            jitted = jax.jit(prefill_step,
                             in_shardings=(params_sh, batch_sh),
                             out_shardings=(None, cache_sh))
            lowered = jitted.lower(params_abs, batch_abs)
        else:                                           # decode
            cspec = arch.cache_spec(shape.global_batch, shape.seq_len)
            cache_sh = param_shardings(cspec, mesh, in_prof)
            cache_abs = abstract(cspec)
            step = make_serve_step(arch)
            jitted = jax.jit(step,
                             in_shardings=(params_sh, cache_sh, batch_sh),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, batch_abs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    n_dev = 512 if multi_pod else 256
    res = hlo_mod.analyze(compiled, lowered, n_devices=n_dev)
    mem = hlo_mod.memory_analysis_dict(compiled)
    mesh_spec = MeshSpec(shape=(2, 16, 16) if multi_pod else (16, 16),
                         axes=("pod", "data", "model") if multi_pod
                         else ("data", "model"))
    ecm = from_resources(
        res, mesh_spec, name=f"{arch.name}/{shape.name}",
        model_flops=arch.model_flops(shape), flops_are_global=False)

    peak_bytes = (mem.get("argument_size_in_bytes", 0)
                  + mem.get("output_size_in_bytes", 0)
                  + mem.get("temp_size_in_bytes", 0)
                  - mem.get("alias_size_in_bytes", 0))
    record = {
        "arch": arch.name,
        "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "profile": profile_name,
        "kind": shape.kind,
        "kv_divisible": kv_div,
        "status": "ok",
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "memory": mem,
        "peak_bytes_per_chip": peak_bytes,
        "fits_hbm": bool(peak_bytes < HBM_BYTES),
        "cost": {"flops_per_chip": res.flops,
                 "bytes_per_chip": res.bytes_accessed,
                 "transcendentals": res.transcendentals},
        "collectives": {
            "n_ops": len(res.collectives),
            "out_bytes_by_kind": res.by_kind(),
            "wire_bytes_per_chip": res.wire_bytes_per_chip,
        },
        "ecm": ecm.summary(),
    }
    return record, lowered, compiled


# ---------------------------------------------------------------------------
# composed-prediction table (--predict)
# ---------------------------------------------------------------------------

#: a train step is forward + backward; the backward re-runs each matmul
#: twice (dL/dx and dL/dW), so step time ~= 3x the composed forward
TRAIN_STEP_MULT = 3.0


def composed_step_s(arch_name: str, shape: ShapeSpec, n_chips: int, *,
                    machine: str = "tpu-v5e") -> float:
    """Per-chip composed step time for one cell (ideal weak scaling:
    the whole-model composition divided over the mesh's chips)."""
    from repro.core import compose

    if shape.kind == "decode":
        pred = compose.predict_step(
            arch_name, machine, batch=shape.global_batch,
            seq_len=shape.seq_len, context=shape.seq_len,
            phases=("decode",))
        t = pred.decode_s
    else:
        pred = compose.predict_step(
            arch_name, machine, batch=shape.global_batch,
            seq_len=shape.seq_len, phases=("prefill",))
        t = pred.prefill_s
        if shape.kind == "train":
            t *= TRAIN_STEP_MULT
    return t / n_chips


def predict_table(records, *, machine: str = "tpu-v5e") -> list[dict]:
    """One row per dry-run record comparing the composed whole-model
    prediction against the compiled-HLO three-term model.

    Skipped and errored cells stay in the table with their reason —
    previously they vanished from the run output entirely.  ``best_mesh``
    is the parallelism model's ranked winner at the cell's chip count
    (``repro.core.mesh.rank_meshes``) — what the mesh *should* have
    been, next to what the cell actually ran on.
    """
    from repro.core.compose import DRYRUN_TOLERANCE
    from repro.core.mesh import rank_meshes

    lo, hi = DRYRUN_TOLERANCE
    rows = []
    for rec in records:
        row = {"arch": rec["arch"], "shape": rec["shape"],
               "mesh": rec["mesh"], "status": rec["status"]}
        if rec["status"] != "ok":
            row["reason"] = rec.get("reason") or rec.get("error", "")
            rows.append(row)
            continue
        shape = SHAPES[rec["shape"]]
        pods = 2 if rec["mesh"] == "2x16x16" else 1
        n_chips = 512 if rec["mesh"] == "2x16x16" else 256
        pred = composed_step_s(rec["arch"], shape, n_chips, machine=machine)
        sim = float(rec["ecm"]["t_ecm_s"])
        ratio = pred / sim if sim > 0 else float("inf")
        phase = shape.kind if shape.kind in ("train", "decode") else "prefill"
        best = rank_meshes(
            rec["arch"], n_chips, machine, batch=shape.global_batch,
            seq_len=shape.seq_len,
            context=shape.seq_len if phase == "decode" else None,
            phase=phase, pods=pods, include_blocks=False, top=1)[0]
        row.update(predicted_s=pred, simulated_s=sim, ratio=ratio,
                   agrees=bool(lo <= ratio <= hi),
                   best_mesh=f"{best['mesh']}/{best['profile']}")
        rows.append(row)
    return rows


def format_predict_table(rows) -> str:
    header = (f"{'arch':<24} {'shape':<12} {'mesh':<8} "
              f"{'predicted_s':>12} {'simulated_s':>12} {'ratio':>7}  "
              f"{'ok':<3} best_mesh")
    lines = [header, "-" * len(header)]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"{r['arch']:<24} {r['shape']:<12} {r['mesh']:<8} "
                         f"{r['status'].upper()}: {r.get('reason', '')}")
            continue
        lines.append(
            f"{r['arch']:<24} {r['shape']:<12} {r['mesh']:<8} "
            f"{r['predicted_s']:>12.4g} {r['simulated_s']:>12.4g} "
            f"{r['ratio']:>7.2f}  {'yes' if r['agrees'] else 'NO':<3} "
            f"{r.get('best_mesh', '')}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _result_path(out: str, arch_name: str, shape_name: str, multi_pod: bool
                 ) -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    safe = arch_name.replace("/", "_")
    return os.path.join(out, f"{safe}__{shape_name}__{mesh}.json")


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool, out: str,
             force: bool = False, verbose: bool = True) -> dict:
    os.makedirs(out, exist_ok=True)
    path = _result_path(out, arch_name, shape_name, multi_pod)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, reason = arch.shape_supported(shape)
    if not ok:
        record = {"arch": arch_name, "shape": shape_name,
                  "mesh": "2x16x16" if multi_pod else "16x16",
                  "status": "skipped", "reason": reason}
        if verbose:
            # skipped cells used to vanish from the run output entirely
            # (nothing printed, no summary count) — surface them so a
            # grid survey can't silently under-report its coverage
            print(f"[dryrun] {arch_name} x {shape_name} "
                  f"({record['mesh']}): SKIPPED — {reason}")
    else:
        try:
            record, lowered, compiled = lower_cell(arch, shape,
                                                   multi_pod=multi_pod)
            if verbose:
                print(f"[dryrun] {arch_name} x {shape_name} "
                      f"({record['mesh']}): compile ok, "
                      f"{record['peak_bytes_per_chip']/2**30:.2f} GiB/chip, "
                      f"dominant={record['ecm']['dominant']}")
                print(json.dumps(record["memory"], indent=1))
                print(json.dumps(record["cost"], indent=1))
        # noqa rationale: a dry-run grid survey's whole point is to
        # record arbitrary compile failures as data, not crash on them
        except Exception as e:  # noqa: BLE001
            record = {"arch": arch_name, "shape": shape_name,
                      "mesh": "2x16x16" if multi_pod else "16x16",
                      "status": "error", "error": f"{type(e).__name__}: {e}",
                      "traceback": traceback.format_exc()[-4000:]}
            if verbose:
                print(f"[dryrun] {arch_name} x {shape_name} FAILED: {e}")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell on both meshes")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--predict", action="store_true",
                    help="append a composed-vs-simulated step-time table "
                         "(repro.core.compose) over the run's cells")
    ap.add_argument("--machine", default="tpu-v5e",
                    help="machine for --predict: a registry name/alias or "
                         "a calibrated machine-file path (default: "
                         "tpu-v5e)")
    args = ap.parse_args()

    from repro.core.machine import resolve_machine
    machine = resolve_machine(args.machine)

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                cells.append((a, s, False))
                cells.append((a, s, True))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        pods = [False, True] if args.both_meshes else [args.multi_pod]
        cells = [(args.arch, args.shape, mp) for mp in pods]

    records = []
    for a, s, mp in cells:
        records.append(run_cell(a, s, multi_pod=mp, out=args.out,
                                force=args.force))
    failures = sum(r["status"] == "error" for r in records)
    skipped = sum(r["status"] == "skipped" for r in records)
    if args.predict:
        print(format_predict_table(predict_table(records,
                                                 machine=machine)))
    print(f"[dryrun] done: {len(cells)} cells, {failures} failures, "
          f"{skipped} skipped")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
