"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and smoke tests/benches must keep seeing the single real device.

Mesh semantics (TPU v5e pods):

* single-pod: ``(16, 16)`` over ``("data", "model")`` — 256 chips, both
  axes on ICI (2D torus: one physical ring per mesh dim).
* multi-pod: ``(2, 16, 16)`` over ``("pod", "data", "model")`` — 512 chips;
  the ``pod`` axis rides DCN (pod-to-pod network), everything else ICI.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, *, data: int | None = None,
                   multi_pod: bool = False) -> Mesh:
    """Small mesh over whatever devices exist (tests, examples)."""
    n = jax.device_count()
    data = data or max(n // model, 1)
    if multi_pod:
        assert data % 2 == 0
        return jax.make_mesh((2, data // 2, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
