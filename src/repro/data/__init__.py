"""Data pipeline: deterministic synthetic streams + memmap token files.

Determinism contract (fault tolerance): ``batch(step)`` is a pure function
of ``(seed, step)`` — after a checkpoint-restart the pipeline resumes at the
restored step with bit-identical batches, with no iterator state to persist.
"""
from .pipeline import (
    DataConfig,
    SyntheticLMDataset,
    TokenFileDataset,
    make_global_array,
    shard_batch,
)

__all__ = [
    "DataConfig",
    "SyntheticLMDataset",
    "TokenFileDataset",
    "make_global_array",
    "shard_batch",
]
