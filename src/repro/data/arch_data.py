"""Arch-aware synthetic dataset: fills every input the arch's batch_spec
declares (tokens/labels/mask + stub modality embeddings), deterministically
per (seed, step) — the multimodal counterpart of SyntheticLMDataset.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ArchDef, ShapeSpec
from .pipeline import DataConfig, SyntheticLMDataset, _rng


class ArchSyntheticDataset:
    def __init__(self, arch: ArchDef, shape: ShapeSpec, seed: int = 0):
        self.arch = arch
        self.shape = shape
        self.seed = seed
        self.spec = arch.batch_spec(shape)
        text_len = self.spec["tokens"].shape[1]
        vocab = getattr(arch.cfg, "vocab", 1024)
        self._lm = SyntheticLMDataset(DataConfig(
            global_batch=shape.global_batch, seq_len=text_len,
            vocab=vocab, seed=seed))

    def batch(self, step: int) -> dict[str, np.ndarray]:
        lm = self._lm.batch(step)
        out: dict[str, np.ndarray] = {}
        g = _rng(self.seed ^ 0xA5C3, step)
        for k, spec in self.spec.items():
            if k == "tokens":
                out[k] = lm["tokens"]
            elif k in ("labels", "mask"):
                b, sl = spec.shape
                st = lm[k].shape[1]
                if sl == st:
                    out[k] = lm[k]
                else:                      # prefix positions (VLM): masked out
                    pad = np.zeros((b, sl - st), lm[k].dtype)
                    out[k] = np.concatenate([pad, lm[k]], axis=1)
            else:                          # stub modality embeddings
                out[k] = (g.standard_normal(spec.shape) * 0.02
                          ).astype(np.float32)
        return out
