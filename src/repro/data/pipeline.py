"""Deterministic, shardable data pipeline.

``SyntheticLMDataset`` generates language-modelling batches from a counter-
based PRNG (Philox keyed on ``(seed, step)``): stateless, so checkpoint-
restart needs no data-iterator state, and every data shard can be generated
independently on its host (at scale each host materializes only its
addressable slice via :func:`make_global_array`).

``TokenFileDataset`` is the real-data path: a flat binary token file
(np.uint16/np.int32 memmap) cut into fixed-length windows; window order is a
deterministic permutation of ``(seed, epoch)``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    #: synthetic corpus structure: tokens follow a Markov-ish mixture so the
    #: LM loss actually decreases during the example runs (pure uniform noise
    #: has no learnable signal).
    structure: float = 0.8


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=[seed, step]))


class SyntheticLMDataset:
    """Deterministic synthetic LM batches: ``batch(step) -> dict``.

    Emitted arrays: tokens (B,S) int32, labels (B,S) int32 (next-token
    shifted), mask (B,S) float32.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed "grammar": each token deterministically prefers a successor;
        # generated once from the seed, shared by every batch.
        g = _rng(cfg.seed, 0xFFFF)
        self._succ = g.integers(0, cfg.vocab, size=(cfg.vocab,), dtype=np.int64)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        g = _rng(cfg.seed, step)
        b, s = cfg.global_batch, cfg.seq_len
        noise = g.integers(0, cfg.vocab, size=(b, s + 1), dtype=np.int64)
        use_rule = g.random((b, s + 1)) < cfg.structure
        toks = noise.copy()
        # pair grammar (vectorizable, genuinely learnable): odd positions
        # follow the successor of the *emitted* even token with probability
        # ``structure`` — a first-order dependency a model can pick up.
        n_pairs = (s + 1) // 2
        even = toks[:, 0:2 * n_pairs:2]
        toks[:, 1:2 * n_pairs:2] = np.where(
            use_rule[:, 1:2 * n_pairs:2], self._succ[even],
            noise[:, 1:2 * n_pairs:2])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "mask": np.ones((b, s), np.float32),
        }

    def batches(self, start_step: int = 0):
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


class TokenFileDataset:
    """Fixed-window LM dataset over a flat binary token file (memmap)."""

    def __init__(self, path: str, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self._data = np.memmap(path, dtype=dtype, mode="r")
        self.n_windows = (len(self._data) - 1) // cfg.seq_len
        if self.n_windows < cfg.global_batch:
            raise ValueError(
                f"{path}: only {self.n_windows} windows of {cfg.seq_len} "
                f"tokens; need >= global_batch={cfg.global_batch}")

    def _perm(self, epoch: int) -> np.ndarray:
        return _rng(self.cfg.seed, epoch).permutation(self.n_windows)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        per_epoch = self.n_windows // cfg.global_batch
        epoch, idx = divmod(step, per_epoch)
        perm = self._perm(epoch)
        rows = perm[idx * cfg.global_batch:(idx + 1) * cfg.global_batch]
        s = cfg.seq_len
        out = np.stack([self._data[r * s:r * s + s + 1] for r in rows])
        out = out.astype(np.int32)
        return {
            "tokens": out[:, :-1],
            "labels": out[:, 1:],
            "mask": np.ones((cfg.global_batch, s), np.float32),
        }


# ---------------------------------------------------------------------------
# Sharded materialization
# ---------------------------------------------------------------------------


def make_global_array(host_fn: Callable[[tuple[slice, ...]], np.ndarray],
                      shape: tuple[int, ...], mesh: Mesh, pspec: P,
                      dtype=None):
    """Build a global jax.Array where each device's shard is produced by
    ``host_fn(index)`` — at multi-host scale each process only touches its
    addressable shards (single-host here, but the code path is the same)."""
    sharding = NamedSharding(mesh, pspec)

    def cb(index):
        arr = host_fn(index)
        return arr.astype(dtype) if dtype is not None else arr

    return jax.make_array_from_callback(shape, sharding, cb)


def shard_batch(batch: dict[str, np.ndarray], mesh: Mesh,
                batch_axes) -> dict[str, Any]:
    """Place a host batch onto the mesh, sharded over the batch axes."""
    out = {}
    for k, v in batch.items():
        spec = P(batch_axes, *([None] * (v.ndim - 1))) if v.ndim else P()
        out[k] = make_global_array(lambda idx, v=v: v[idx], v.shape, mesh,
                                   spec, dtype=v.dtype)
    return out
