"""Cache-hierarchy simulator — the "measurement" stand-in.

This container has neither the paper's Haswell-EP testbed nor a TPU, so the
paper's *measured* columns (Table I, Figs. 7-10) are reproduced by a
calibrated simulator instead of `likwid-perfctr` runs.  See DESIGN.md §8.
"""
from .sim import (
    EVAL_COUNTERS,
    SimParams,
    CacheHierarchy,
    HASWELL_CACHES,
    HASWELL_CACHES_COD,
    machine_caches,
    reset_counters,
    scaling_batch,
    simulate_level,
    simulate_levels_batch,
    simulate_lowered,
    simulate_stencil_level,
    simulate_stencil_levels_batch,
    simulate_table,
    simulate_working_set,
    simulate_workloads_batch,
    simulate_scaling,
    stencil_sweep_batch,
    sweep,
    sweep_batch,
)

__all__ = [
    "EVAL_COUNTERS",
    "SimParams",
    "CacheHierarchy",
    "HASWELL_CACHES",
    "HASWELL_CACHES_COD",
    "machine_caches",
    "reset_counters",
    "scaling_batch",
    "simulate_level",
    "simulate_levels_batch",
    "simulate_lowered",
    "simulate_stencil_level",
    "simulate_stencil_levels_batch",
    "simulate_table",
    "simulate_working_set",
    "simulate_workloads_batch",
    "simulate_scaling",
    "stencil_sweep_batch",
    "sweep",
    "sweep_batch",
]
