"""Cache-hierarchy simulator — the "measurement" stand-in.

This container has neither the paper's Haswell-EP testbed nor a TPU, so the
paper's *measured* columns (Table I, Figs. 7-10) are reproduced by a
calibrated simulator instead of `likwid-perfctr` runs.  See DESIGN.md §8.
"""
from .sim import (
    SimParams,
    CacheHierarchy,
    HASWELL_CACHES,
    HASWELL_CACHES_COD,
    simulate_level,
    simulate_working_set,
    simulate_scaling,
    sweep,
)

__all__ = [
    "SimParams",
    "CacheHierarchy",
    "HASWELL_CACHES",
    "HASWELL_CACHES_COD",
    "simulate_level",
    "simulate_working_set",
    "simulate_scaling",
    "sweep",
]
