"""Calibrated memory-hierarchy simulator for streaming kernels.

The ECM model (``repro.core``) is a *light-speed* model: it neglects
latencies, clock-domain crossings and end-of-benchmark eviction effects by
design.  Real measurements (the paper's Table I "Measurement" column) differ
from the light-speed prediction in reproducible, mechanistic ways that the
paper itself identifies:

* §VII-A: an off-core latency penalty ("one clock cycle per load stream and
  cache-level") for kernels with a *low* cycle count per cache line — i.e.
  the penalty is progressively hidden once the per-CL cycle count grows
  (more slack for the out-of-order engine to hide latency in);
* §VII-A: sustained L2 load bandwidth below the advertised 64 B/c
  (a ~0.3 cy/CL penalty per load stream);
* §VII-B: eviction traffic still in flight when the benchmark ends
  ("caches and several store buffers still holding data to be evicted"),
  which makes *measured* runtimes for evicting kernels better than the
  light-speed prediction in L3/memory;
* eviction/load interference on the shared L1<->L2 bus.

This simulator composes the light-speed ECM terms with those four effects.
The effect magnitudes (:class:`SimParams`) are calibrated once against the
paper's published measurements (the same way any timing simulator is
calibrated against hardware) and then frozen; tests pin the simulator to the
paper's measured values within ~12%.

It also provides working-set sweeps (for the Fig. 7-9 style curves, using
LRU-streaming residence: a cyclically streamed working set larger than a
level thrashes it) and multi-core scaling with shared-bandwidth saturation
(Fig. 10).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.ecm import ECMModel
from repro.core.kernel_spec import BENCHMARKS, StreamKernelSpec
from repro.core.machine import HASWELL_EP, HASWELL_MEASURED_BW, MachineModel


@dataclass(frozen=True)
class SimParams:
    """Calibrated non-light-speed effects (see module docstring)."""

    l2_load_penalty: float = 0.3      # cy per load stream (L2-resident)
    l2_evict_interference: float = 0.7  # cy per evict stream (L2-resident)
    offcore_load_penalty: float = 1.0  # cy per load stream per off-core level
    mem_load_penalty: float = 2.0     # cy per load stream (memory-resident)
    #: latency hiding: penalties fade linearly to zero as the light-speed
    #: cy/CL prediction approaches this many cycles (OoO slack).
    hide_scale_l3: float = 40.0
    hide_scale_mem: float = 40.0
    #: async-eviction credit: fraction-style credits for in-flight evictions
    evict_credit_l3: float = 3.2      # cy x (evict share of streams)
    evict_credit_mem_scale: float = 45.0  # hide scale for the mem credit
    frontend_jitter: float = 0.1      # cy, for kernels with >=4 L1 uops


DEFAULT_PARAMS = SimParams()


@dataclass(frozen=True)
class CacheHierarchy:
    """Capacities for working-set residence (inclusive, LRU, streaming)."""

    l1_bytes: int = 32 * 1024
    l2_bytes: int = 256 * 1024
    l3_bytes: int = 35 * 1024 * 1024

    def capacities(self) -> tuple[int, ...]:
        return (self.l1_bytes, self.l2_bytes, self.l3_bytes)


HASWELL_CACHES = CacheHierarchy()
#: Cluster-on-Die mode: the LLC is segmented, 7 x 2.5 MB per affinity domain
HASWELL_CACHES_COD = CacheHierarchy(l3_bytes=35 * 1024 * 1024 // 2)


# ---------------------------------------------------------------------------
# Level-resident simulation (Table I's measurement columns)
# ---------------------------------------------------------------------------


def _level_effects(spec: StreamKernelSpec, pred: tuple[float, ...],
                   p: SimParams) -> list[float]:
    """Per-level additive effects on top of the light-speed prediction."""
    loads = spec.loads_explicit + spec.rfo
    evicts = spec.stores + spec.nt_stores
    share = evicts / max(spec.mem_streams, 1)

    eff = [0.0, 0.0, 0.0, 0.0]
    # L1: front-end jitter only
    if (spec.uop_loads + spec.uop_stores) >= 4:
        eff[0] = p.frontend_jitter
    # L2: sub-spec sustained load bandwidth + eviction interference
    eff[1] = p.l2_load_penalty * loads + p.l2_evict_interference * evicts
    # L3: off-core latency, hidden with growing per-CL cycles; async-evict credit
    h3 = max(0.0, 1.0 - pred[2] / p.hide_scale_l3)
    eff[2] = p.offcore_load_penalty * loads * h3 - p.evict_credit_l3 * share
    # Mem: one more clock-domain crossing (the eviction credit is applied by
    # the caller, which knows the per-CL memory cycles)
    hm = max(0.0, 1.0 - pred[3] / p.hide_scale_mem)
    eff[3] = p.mem_load_penalty * loads * hm
    return eff


def simulate_level(
    name_or_spec: str | StreamKernelSpec,
    level: int,
    *,
    machine: MachineModel = HASWELL_EP,
    sustained_bw: float | None = None,
    params: SimParams = DEFAULT_PARAMS,
    optimized_agu: bool = False,
) -> float:
    """Simulated ("measured") cy/CL for data resident in ``level``
    (0=L1, 1=L2, 2=L3, 3=Mem)."""
    spec = BENCHMARKS[name_or_spec] if isinstance(name_or_spec, str) else name_or_spec
    bw = sustained_bw or HASWELL_MEASURED_BW.get(spec.name, 27e9)
    ecm = spec.ecm(machine, bw, optimized_agu=optimized_agu)
    pred = ecm.predictions()
    eff = _level_effects(spec, pred, params)
    out = pred[level] + eff[level]
    if level == 3 and (spec.stores or spec.nt_stores):
        # async-eviction credit: evictions still in flight at benchmark end
        mem_cy_per_cl = machine.mem_cycles_per_line(bw)
        evict_cy = (spec.stores + spec.nt_stores) * mem_cy_per_cl
        hm = max(0.0, 1.0 - pred[3] / params.evict_credit_mem_scale)
        out -= evict_cy * hm
    return max(out, ecm.t_core)


def simulate_table(names: list[str] | None = None,
                   **kw) -> dict[str, tuple[float, ...]]:
    names = names or list(BENCHMARKS)
    return {n: tuple(simulate_level(n, lv, **kw) for lv in range(4))
            for n in names}


# ---------------------------------------------------------------------------
# Working-set sweeps (Figs. 7-9)
# ---------------------------------------------------------------------------


def _residence_weights(ws_bytes: float, caches: CacheHierarchy
                       ) -> list[float]:
    """Blend weights over residence levels for a streamed working set.

    Pure cyclic streaming with LRU gives a sharp thrash transition at each
    capacity; measurements show a knee.  We model the hit fraction of level
    ``k`` as ``clamp(2*C_k/WS - 1, 0, 1)`` (full hits up to C, none at 2C).
    """
    caps = caches.capacities()
    weights = []
    remaining = 1.0
    for c in caps:
        h = min(1.0, max(0.0, 2.0 * c / ws_bytes - 1.0)) if ws_bytes > 0 else 1.0
        w = remaining * h
        weights.append(w)
        remaining -= w
    weights.append(remaining)          # memory
    return weights


def simulate_working_set(
    name: str,
    ws_bytes: float,
    *,
    machine: MachineModel = HASWELL_EP,
    caches: CacheHierarchy = HASWELL_CACHES_COD,
    params: SimParams = DEFAULT_PARAMS,
    sustained_bw: float | None = None,
) -> float:
    """Simulated cy/CL for a given total working-set size in bytes."""
    w = _residence_weights(ws_bytes, caches)
    lv = [simulate_level(name, i, machine=machine, params=params,
                         sustained_bw=sustained_bw) for i in range(4)]
    return sum(wi * ci for wi, ci in zip(w, lv))


def sweep(name: str, sizes_bytes: list[float], **kw) -> list[tuple[float, float]]:
    """(working_set_bytes, cy/CL) curve — the Fig. 7-9 x/y data."""
    return [(s, simulate_working_set(name, s, **kw)) for s in sizes_bytes]


# ---------------------------------------------------------------------------
# Multi-core scaling (Fig. 10)
# ---------------------------------------------------------------------------


def simulate_scaling(
    name: str,
    n_cores: int,
    *,
    machine: MachineModel = HASWELL_EP,
    domain_bw: float | None = None,
    cores_per_domain: int = 7,
    n_domains: int = 2,
    params: SimParams = DEFAULT_PARAMS,
    fill_domains_first: bool = True,
) -> list[float]:
    """Measured-style scaling curve in updates/s for n = 1..n_cores.

    Each affinity domain saturates at its sustained bandwidth; cores fill
    one domain after the other (CoD) or round-robin (non-CoD, which behaves
    like one big domain with the chip bandwidth).
    """
    spec = BENCHMARKS[name]
    bw = domain_bw or HASWELL_MEASURED_BW[spec.name]
    t_single = simulate_level(name, 3, machine=machine, params=params,
                              sustained_bw=bw)
    upd_per_line = spec.elems_per_line(machine.line_bytes) * spec.updates_per_elem
    p1 = upd_per_line * machine.clock_hz / t_single           # single core
    bytes_per_update = spec.mem_streams * machine.line_bytes / upd_per_line
    p_sat_domain = bw / bytes_per_update

    out = []
    for n in range(1, n_cores + 1):
        if fill_domains_first:
            full, rem = divmod(n, cores_per_domain)
            p = full * min(cores_per_domain * p1, p_sat_domain)
            p += min(rem * p1, p_sat_domain) if rem else 0.0
            p = min(p, n_domains * p_sat_domain)
        else:
            p = min(n * p1, n_domains * p_sat_domain)
        out.append(p)
    return out
