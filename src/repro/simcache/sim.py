"""Calibrated memory-hierarchy simulator for any workload family.

The ECM model (``repro.core``) is a *light-speed* model: it neglects
latencies, clock-domain crossings and end-of-benchmark eviction effects by
design.  Real measurements (the paper's Table I "Measurement" column) differ
from the light-speed prediction in reproducible, mechanistic ways that the
paper itself identifies:

* §VII-A: an off-core latency penalty ("one clock cycle per load stream and
  cache-level") for kernels with a *low* cycle count per cache line — i.e.
  the penalty is progressively hidden once the per-CL cycle count grows
  (more slack for the out-of-order engine to hide latency in);
* §VII-A: sustained L2 load bandwidth below the advertised 64 B/c
  (a ~0.3 cy/CL penalty per load stream);
* §VII-B: eviction traffic still in flight when the benchmark ends
  ("caches and several store buffers still holding data to be evicted"),
  which makes *measured* runtimes for evicting kernels better than the
  light-speed prediction in L3/memory;
* eviction/load interference on the shared L1<->L2 bus.

This simulator composes the light-speed ECM terms with those four effects,
plus a **compute-bound path** for T_OL-dominated kernels (blocked matmul,
flash attention): a long in-core FMA/MXU chain sustains only
``fma_sustained_eff`` of the light-speed issue rate (real GEMMs reach
~90-95% of FMA peak, arXiv:1511.03639); the ``fma_eff_min_cy`` threshold
keeps the short-T_OL streaming/stencil kernels untouched.
The effect magnitudes (:class:`SimParams`) are calibrated once against the
paper's published measurements (the same way any timing simulator is
calibrated against hardware) and then frozen; tests pin the simulator to the
paper's measured values within ~12%.

It also provides working-set sweeps (for the Fig. 7-9 style curves, using
LRU-streaming residence: a cyclically streamed working set larger than a
level thrashes it) and multi-core scaling with shared-bandwidth saturation
(Fig. 10).

**Evaluation path.**  There is exactly one simulation core,
:func:`simulate_lowered`: any workload (stream kernel, stencil, fused
chain, ...) is lowered by the unified engine
(``repro.core.workload.lower_many``) into per-edge line traffic + ECM
times, and the four calibrated effects are applied to that routed record —
no stream-vs-stencil forks, no per-family branches.
:func:`simulate_workloads_batch` is the generic entry point;
:func:`simulate_levels_batch` (streams) and
:func:`simulate_stencil_levels_batch` (stencils) are thin wrappers that
build the workload objects, and the scalar functions
(:func:`simulate_level`, :func:`simulate_working_set`, ...) are views over
the batch path that agree with it bit-for-bit.  ``EVAL_COUNTERS`` tracks
how many Python-level evaluations happen per batch call — the
``benchmarks/run.py --json`` model-eval throughput numbers come from it.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.kernel_spec import BENCHMARKS, StreamKernelSpec
from repro.core.layer_condition import (
    LC_SAFETY,
    STENCILS,
    StencilSpec,
    misses_batch,
    stencil_batch_from_misses,
)
from repro.core import engine
from repro.core.machine import HASWELL_EP, MachineModel
from repro.core.workload import (
    LoweredBatch,
    StencilWorkload,
    StreamWorkload,
    get_machine,
    lower_many,
)

#: batch_array_evals counts vectorized evaluations (one per grid, however
#: large); scalar_points counts individual (kernel, level/size/core) points
#: produced.  Their ratio is the "Python-level calls per point" figure.
#: levels_cache_hits counts evaluations served from the warm levels memo
#: (points served from a hit still count in the other two, so the per-point
#: figures keep their meaning whether or not the cache is on).
EVAL_COUNTERS = {"batch_array_evals": 0, "scalar_points": 0,
                 "levels_cache_hits": 0}


def reset_counters() -> None:
    EVAL_COUNTERS["batch_array_evals"] = 0
    EVAL_COUNTERS["scalar_points"] = 0
    EVAL_COUNTERS["levels_cache_hits"] = 0


@dataclass(frozen=True)
class SimParams:
    """Calibrated non-light-speed effects (see module docstring)."""

    l2_load_penalty: float = 0.3      # cy per load stream (L2-resident)
    l2_evict_interference: float = 0.7  # cy per evict stream (L2-resident)
    offcore_load_penalty: float = 1.0  # cy per load stream per off-core level
    mem_load_penalty: float = 2.0     # cy per load stream (memory-resident)
    #: latency hiding: penalties fade linearly to zero as the light-speed
    #: cy/CL prediction approaches this many cycles (OoO slack).
    hide_scale_l3: float = 40.0
    hide_scale_mem: float = 40.0
    #: async-eviction credit: fraction-style credits for in-flight evictions
    evict_credit_l3: float = 3.2      # cy x (evict share of streams)
    evict_credit_mem_scale: float = 45.0  # hide scale for the mem credit
    frontend_jitter: float = 0.1      # cy, for kernels with >=4 L1 uops
    #: compute-bound path: kernels whose overlapping in-core time is a
    #: long FMA/MXU chain (T_OL >= fma_eff_min_cy) sustain only a fraction
    #: of the light-speed issue rate — loop edges, accumulator spills and
    #: frontend bubbles the OoO window cannot cover (real GEMMs run at
    #: ~90-95% of FMA peak; arXiv:1511.03639's Haswell measurements).
    #: The threshold keeps every Table I / stencil kernel (T_OL <= 6 cy)
    #: untouched.
    fma_sustained_eff: float = 0.92   # sustained / light-speed T_OL
    fma_eff_min_cy: float = 64.0      # only long in-core chains qualify


DEFAULT_PARAMS = SimParams()


@dataclass(frozen=True)
class CacheHierarchy:
    """Capacities for working-set residence (inclusive, LRU, streaming)."""

    l1_bytes: int = 32 * 1024
    l2_bytes: int = 256 * 1024
    l3_bytes: int = 35 * 1024 * 1024

    def capacities(self) -> tuple[int, ...]:
        return (self.l1_bytes, self.l2_bytes, self.l3_bytes)


HASWELL_CACHES = CacheHierarchy()
#: Cluster-on-Die mode: the LLC is segmented, 7 x 2.5 MB per affinity domain
HASWELL_CACHES_COD = CacheHierarchy(l3_bytes=35 * 1024 * 1024 // 2)


def machine_caches(machine: "MachineModel | str") -> CacheHierarchy:
    """Residence capacities of a registry machine (affinity-domain LLC)."""
    m = get_machine(machine)
    caps = m.capacities
    if len(caps) != 3:
        raise ValueError(
            f"machine {m.name!r} has {len(caps)} cache levels; the "
            f"residence blend expects 3 (+Mem)")
    return CacheHierarchy(*caps)


# ---------------------------------------------------------------------------
# The single simulation core: calibrated effects on a lowered record
# ---------------------------------------------------------------------------


def simulate_lowered(lowered: LoweredBatch,
                     params: SimParams = DEFAULT_PARAMS) -> np.ndarray:
    """Simulated ("measured") cy/CL for every batch element x residence
    level: ``(B, L)``.

    Input is the unified engine's :class:`~repro.core.workload.
    LoweredBatch` — light-speed ECM times plus the routed per-edge line
    traffic — so the four calibrated effects apply identically to any
    workload family on any machine; nothing here asks what kind of kernel
    produced the record.
    """
    batch = lowered.batch
    pred = batch.predictions()                              # (B, L)
    n_levels = pred.shape[-1]
    loads = lowered.routed.load_lines                       # (B, E)
    ev0 = lowered.routed.evict_lines[:, 0]                  # L1<->L2 outward
    ev_mem = lowered.routed.evict_lines[:, -1]              # mem-edge outward
    share = ev_mem / np.maximum(lowered.routed.mem_lines(), 1.0)
    p = params

    eff = np.zeros_like(pred)
    # L1: front-end jitter only
    eff[:, 0] = np.where(lowered.l1_uops >= 4, p.frontend_jitter, 0.0)
    for lv in range(1, n_levels):
        lo = loads[:, lv - 1]         # inward lines on the edge feeding lv
        if lv == 1:
            # L2: sub-spec sustained load bandwidth + eviction interference
            eff[:, lv] = (p.l2_load_penalty * lo
                          + p.l2_evict_interference * ev0)
        elif lv < n_levels - 1:
            # off-core caches: latency penalty, hidden with growing per-CL
            # cycles; async-eviction credit
            h = np.maximum(0.0, 1.0 - pred[:, lv] / p.hide_scale_l3)
            eff[:, lv] = (p.offcore_load_penalty * lo * h
                          - p.evict_credit_l3 * share)
        else:
            # Mem: one more clock-domain crossing
            hm = np.maximum(0.0, 1.0 - pred[:, lv] / p.hide_scale_mem)
            eff[:, lv] = p.mem_load_penalty * lo * hm

    out = pred + eff
    # async-eviction credit: evictions still in flight at benchmark end
    hmc = np.maximum(0.0, 1.0 - pred[:, -1] / p.evict_credit_mem_scale)
    out[:, -1] = out[:, -1] - np.where(
        ev_mem > 0, ev_mem * lowered.mem_cy_per_line * hmc, 0.0)
    # compute-bound path: T_OL-dominated kernels (blocked matmul / flash
    # attention) sustain a fraction of the light-speed FMA/MXU rate.
    # Pre-lowered records (RawWorkload: zero routed traffic, zero uops,
    # times in their own units) are pass-throughs — the threshold is in
    # cycles, so it must never touch them.
    reduced = (loads.sum(axis=-1) + lowered.routed.evict_lines.sum(axis=-1)
               + lowered.l1_uops) > 0
    core_lim = np.where(reduced & (batch.t_ol >= p.fma_eff_min_cy),
                        batch.t_ol / max(p.fma_sustained_eff, 1e-9), 0.0)
    out = np.maximum(out, np.maximum(batch.t_core, core_lim)[:, None])
    EVAL_COUNTERS["batch_array_evals"] += 1
    EVAL_COUNTERS["scalar_points"] += out.size
    return out


def simulate_workloads_batch(
    workloads,
    machine: "MachineModel | str" = HASWELL_EP,
    *,
    sustained_bw: "dict | float | None" = None,
    params: SimParams = DEFAULT_PARAMS,
    optimized_agu: bool = False,
) -> tuple[tuple[str, ...], np.ndarray]:
    """Simulated cy/CL table for any workloads on any machine: the generic
    entry point every family-specific wrapper routes through."""
    lowered = lower_many(workloads, machine, sustained_bw=sustained_bw,
                         optimized_agu=optimized_agu)
    return lowered.batch.names, simulate_lowered(lowered, params)


# ---------------------------------------------------------------------------
# Stream wrappers (Table I's measurement columns)
# ---------------------------------------------------------------------------


def _as_spec(name_or_spec) -> StreamKernelSpec:
    """Registry-key-or-spec coercion (specs are hashable non-keys)."""
    spec = BENCHMARKS.get(name_or_spec, name_or_spec)
    if not hasattr(spec, "load_streams"):
        raise KeyError(f"unknown stream kernel {name_or_spec!r}; "
                       f"registered: {sorted(BENCHMARKS)}")
    return spec


#: warm (kernel-set, machine, bandwidths, params) -> (names, table) memo:
#: the request-path sweeps re-evaluate the same levels table thousands of
#: times; a hit skips lowering and simulation entirely.  Keys embed
#: ``engine.cache_token`` so registry/calibration updates invalidate.
_LEVELS_MEMO: dict = {}
_LEVELS_MEMO_MAX = 256


def _stream_bws(names, machine: MachineModel, sustained_bw) -> dict:
    if sustained_bw is None:
        return {n: machine.sustained_bw(n, "_stream", default=27e9)
                for n in names}
    if hasattr(sustained_bw, "items"):          # per-kernel overrides
        base = {n: machine.sustained_bw(n, "_stream", default=27e9)
                for n in names}
        return {**base, **sustained_bw}
    return {n: float(sustained_bw) for n in names}


def simulate_levels_batch(
    names: "list | tuple | None" = None,
    *,
    machine: "MachineModel | str" = HASWELL_EP,
    sustained_bw: "dict[str, float] | float | None" = None,
    params: SimParams = DEFAULT_PARAMS,
    optimized_agu: bool = False,
) -> tuple[tuple[str, ...], np.ndarray]:
    """Simulated ("measured") cy/CL for every kernel x residence level.

    Returns ``(names, table)`` with ``table`` of shape (K, L).  One
    vectorized evaluation regardless of K.  ``names`` entries may be
    registry keys or :class:`StreamKernelSpec` objects.
    """
    m = get_machine(machine)
    specs = [_as_spec(n) for n in (names or BENCHMARKS)]
    names = tuple(s.name for s in specs)
    bws = _stream_bws(names, m, sustained_bw)
    key = None
    if engine.cache_enabled():
        # the machine token covers both registry generation and the
        # machine's calibration fingerprint, so a re-registered machine
        # (or any registry mutation) misses every stale entry
        key = (engine.cache_token(m), tuple(specs),
               tuple(sorted(bws.items())), params, optimized_agu)
        hit = _LEVELS_MEMO.get(key)
        if hit is not None:
            # points are served either way: keep the per-point counter
            # semantics identical to a cold evaluation
            EVAL_COUNTERS["batch_array_evals"] += 1
            EVAL_COUNTERS["scalar_points"] += hit[1].size
            EVAL_COUNTERS["levels_cache_hits"] += 1
            return hit
    out = simulate_workloads_batch(
        [StreamWorkload(s) for s in specs], m, sustained_bw=bws,
        params=params, optimized_agu=optimized_agu)
    if key is not None:
        out[1].flags.writeable = False      # shared across future callers
        if len(_LEVELS_MEMO) >= _LEVELS_MEMO_MAX:
            _LEVELS_MEMO.clear()
        _LEVELS_MEMO[key] = out
    return out


def simulate_level(
    name_or_spec: str | StreamKernelSpec,
    level: int,
    *,
    machine: "MachineModel | str" = HASWELL_EP,
    sustained_bw: float | None = None,
    params: SimParams = DEFAULT_PARAMS,
    optimized_agu: bool = False,
) -> float:
    """Simulated ("measured") cy/CL for data resident in ``level``
    (0=L1, 1=L2, 2=L3, 3=Mem).  Scalar view of the batch path; a
    :class:`StreamKernelSpec` argument is evaluated as-is (it may differ
    from the registry entry of the same name)."""
    _, table = simulate_levels_batch(
        [name_or_spec], machine=machine, sustained_bw=sustained_bw,
        params=params, optimized_agu=optimized_agu)
    return float(table[0, level])


def simulate_table(names: list[str] | None = None,
                   **kw) -> dict[str, tuple[float, ...]]:
    names_t, table = simulate_levels_batch(names, **kw)
    return {n: tuple(float(x) for x in table[i])
            for i, n in enumerate(names_t)}


# ---------------------------------------------------------------------------
# Working-set sweeps (Figs. 7-9)
# ---------------------------------------------------------------------------


def residence_weights_batch(sizes_bytes, caches: CacheHierarchy
                            ) -> np.ndarray:
    """Blend weights over residence levels, vectorized over sizes: (S, 4).

    Pure cyclic streaming with LRU gives a sharp thrash transition at each
    capacity; measurements show a knee.  We model the hit fraction of level
    ``k`` as ``clamp(2*C_k/WS - 1, 0, 1)`` (full hits up to C, none at 2C).
    """
    ws = np.asarray(sizes_bytes, float)
    weights = np.zeros(ws.shape + (4,))
    remaining = np.ones_like(ws)
    for k, c in enumerate(caches.capacities()):
        h = np.where(ws > 0, np.clip(2.0 * c / np.maximum(ws, 1e-30) - 1.0,
                                     0.0, 1.0), 1.0)
        w = remaining * h
        weights[..., k] = w
        remaining = remaining - w
    weights[..., 3] = remaining
    return weights


def _residence_weights(ws_bytes: float, caches: CacheHierarchy
                       ) -> list[float]:
    """Scalar view of :func:`residence_weights_batch`."""
    return [float(x) for x in residence_weights_batch([ws_bytes], caches)[0]]


def sweep_batch(
    names: "list[str] | tuple[str, ...] | None",
    sizes_bytes,
    *,
    machine: "MachineModel | str" = HASWELL_EP,
    caches: CacheHierarchy | None = None,
    params: SimParams = DEFAULT_PARAMS,
    sustained_bw: "dict[str, float] | float | None" = None,
) -> tuple[tuple[str, ...], np.ndarray]:
    """(kernels x sizes) cy/CL surface in one evaluation: (K, S).

    This is the Fig. 7-9 grid: the per-level table is built once (one
    batch call) and the residence blend is a (S,4) x (K,4) -> (K,S)
    matrix product — no per-point Python.  Residence capacities default
    to the machine's own (:func:`machine_caches`).
    """
    if caches is None:
        caches = machine_caches(machine)
    names_t, table = simulate_levels_batch(
        names, machine=machine, sustained_bw=sustained_bw, params=params)
    weights = residence_weights_batch(sizes_bytes, caches)       # (S, 4)
    EVAL_COUNTERS["batch_array_evals"] += 1
    surface = table @ weights.T                                  # (K, S)
    EVAL_COUNTERS["scalar_points"] += surface.size
    return names_t, surface


def simulate_working_set(
    name: str,
    ws_bytes: float,
    *,
    machine: "MachineModel | str" = HASWELL_EP,
    caches: CacheHierarchy | None = None,
    params: SimParams = DEFAULT_PARAMS,
    sustained_bw: float | None = None,
) -> float:
    """Simulated cy/CL for a given total working-set size in bytes."""
    _, surface = sweep_batch([name], [ws_bytes], machine=machine,
                             caches=caches, params=params,
                             sustained_bw=sustained_bw)
    return float(surface[0, 0])


def sweep(name: str, sizes_bytes: list[float], **kw) -> list[tuple[float, float]]:
    """(working_set_bytes, cy/CL) curve — the Fig. 7-9 x/y data.

    One batch evaluation for the whole curve (was: 4 model builds per
    point)."""
    _, surface = sweep_batch([name], sizes_bytes, **kw)
    return list(zip([float(s) for s in sizes_bytes],
                    [float(y) for y in surface[0]]))


# ---------------------------------------------------------------------------
# Multi-core scaling (Fig. 10)
# ---------------------------------------------------------------------------


def scaling_batch(
    names: "list[str] | tuple[str, ...] | None",
    n_cores: int,
    *,
    machine: "MachineModel | str" = HASWELL_EP,
    domain_bw: "dict[str, float] | float | None" = None,
    cores_per_domain: int | None = None,
    n_domains: int | None = None,
    params: SimParams = DEFAULT_PARAMS,
    fill_domains_first: bool = True,
) -> tuple[tuple[str, ...], np.ndarray]:
    """Measured-style scaling surface in updates/s: (K, n_cores).

    Each affinity domain saturates at its sustained bandwidth; cores fill
    one domain after the other (CoD) or round-robin (non-CoD, which behaves
    like one big domain with the chip bandwidth).  Vectorized over kernels
    AND core counts.  Domain topology defaults to the machine's
    (``cores_per_domain`` / ``n_domains``).
    """
    from repro.core.scaling import fill_domains

    m = get_machine(machine)
    if cores_per_domain is None:
        cores_per_domain = m.cores_per_domain or m.cores
    if n_domains is None:
        n_domains = m.n_domains
    specs = [_as_spec(n) for n in (names or BENCHMARKS)]
    names_t = tuple(s.name for s in specs)
    bws = _stream_bws(names_t, m, domain_bw)
    _, table = simulate_levels_batch(specs, machine=m,
                                     sustained_bw=bws, params=params)
    t_single = table[:, -1]                                    # (K,)
    upd = np.array([s.elems_per_line(m.line_bytes) * s.updates_per_elem
                    for s in specs], float)
    mem_streams = np.array([s.mem_streams for s in specs], float)
    bw_arr = np.array([bws[n] for n in names_t], float)

    p1 = upd * m.clock_hz / t_single                           # (K,)
    bytes_per_update = mem_streams * m.line_bytes / upd
    p_sat = bw_arr / bytes_per_update                          # per domain

    EVAL_COUNTERS["batch_array_evals"] += 1
    # the one shared Eq. 2 domain-filling rule (repro.core.scaling) on
    # the *simulated* single-core time — measured-style curves
    p = fill_domains(p1, p_sat, n_cores, cores_per_domain, n_domains,
                     fill_domains_first)
    EVAL_COUNTERS["scalar_points"] += p.size
    return names_t, p


def simulate_scaling(
    name: str,
    n_cores: int,
    *,
    machine: "MachineModel | str" = HASWELL_EP,
    domain_bw: float | None = None,
    cores_per_domain: int | None = None,
    n_domains: int | None = None,
    params: SimParams = DEFAULT_PARAMS,
    fill_domains_first: bool = True,
) -> list[float]:
    """Measured-style scaling curve in updates/s for n = 1..n_cores.

    Scalar view of :func:`scaling_batch`."""
    _, p = scaling_batch([name], n_cores, machine=machine,
                         domain_bw=domain_bw,
                         cores_per_domain=cores_per_domain,
                         n_domains=n_domains, params=params,
                         fill_domains_first=fill_domains_first)
    return [float(x) for x in p[0]]


# ---------------------------------------------------------------------------
# Stencil wrappers (layer-condition-driven traffic, arXiv:1410.5010)
# ---------------------------------------------------------------------------


def _as_stencil(name_or_spec) -> StencilSpec:
    """Registry-key-or-spec coercion (specs are hashable non-keys)."""
    spec = STENCILS.get(name_or_spec, name_or_spec)
    if not hasattr(spec, "row_streams"):
        raise KeyError(f"unknown stencil {name_or_spec!r}; "
                       f"registered: {sorted(STENCILS)}")
    return spec


def simulate_stencil_levels_batch(
    name_or_spec: "str | StencilSpec",
    widths_arr,
    *,
    machine: "MachineModel | str" = HASWELL_EP,
    caches: CacheHierarchy | None = None,
    sustained_bw: float | None = None,
    params: SimParams = DEFAULT_PARAMS,
    safety: float = LC_SAFETY,
    misses: "np.ndarray | None" = None,
) -> np.ndarray:
    """Simulated ("measured") cy/CL for a stencil: ``(B, L)`` over a batch
    of effective inner widths.

    Unlike the streaming kernels, the light-speed transfer terms are not
    constants: the inward load count on every edge comes from the layer
    condition of the cache above it (pass a precomputed ``misses`` table to
    share it with a caller that already built the predicted side).  The
    stencil is lowered by the same engine and simulated by the same
    :func:`simulate_lowered` core as every other workload.  Layer
    conditions and the residence blend both default to the *machine's*
    capacities (:func:`machine_caches`).
    """
    m = get_machine(machine)
    if caches is None:
        caches = machine_caches(m)
    spec = _as_stencil(name_or_spec)
    bw = sustained_bw or m.sustained_bw(spec.name, "_stencil",
                                        default=24.1e9)
    w = StencilWorkload(spec, widths=np.asarray(widths_arr, float),
                        capacities=caches.capacities(), safety=safety,
                        misses=misses)
    _, table = simulate_workloads_batch([w], m, sustained_bw=bw,
                                        params=params)
    return table


def simulate_stencil_level(name_or_spec, level: int, *,
                           widths: tuple[int, ...], **kw) -> float:
    """Scalar view of :func:`simulate_stencil_levels_batch`."""
    table = simulate_stencil_levels_batch(
        name_or_spec, np.asarray([widths], float), **kw)
    return float(table[0, level])


def stencil_sweep_batch(
    name_or_spec: "str | StencilSpec",
    problem_ns,
    *,
    machine: "MachineModel | str" = HASWELL_EP,
    caches: CacheHierarchy | None = None,
    sustained_bw: float | None = None,
    params: SimParams = DEFAULT_PARAMS,
    safety: float = LC_SAFETY,
    n_arrays: int = 2,
) -> dict[str, np.ndarray]:
    """Measured-vs-predicted cy/CL curves over square problem sizes.

    ``problem_ns`` are inner widths N of square 2D (N x N) or cubic 3D
    (N x N x N) problems.  The working set (``n_arrays`` = input + output
    arrays) sets the residence blend; N itself sets the layer conditions —
    both vary along the sweep, which is exactly the 1410.5010 Fig. 6
    structure.  Returns per-N arrays: ``predicted`` / ``measured`` (cy per
    CL of updates), ``ws_bytes``, ``misses`` (B, 3) and ``regime`` (the
    dominant residence level index).  Capacities default to the machine's
    (:func:`machine_caches`).
    """
    m = get_machine(machine)
    if caches is None:
        caches = machine_caches(m)
    spec = _as_stencil(name_or_spec)
    ns = np.asarray(problem_ns, float)
    widths = (ns[:, None] if spec.dim == 2
              else np.stack([ns, ns], axis=-1))
    ws = n_arrays * ns ** spec.dim * spec.elem_bytes
    misses = misses_batch(spec, widths, caches.capacities(), safety=safety)

    bw = sustained_bw or m.sustained_bw(spec.name, "_stencil",
                                        default=24.1e9)
    batch = stencil_batch_from_misses(spec, misses, machine=m,
                                      sustained_bw=bw)
    pred_levels = batch.predictions()                          # (B, 4)
    meas_levels = simulate_stencil_levels_batch(
        spec, widths, machine=m, caches=caches, sustained_bw=bw,
        params=params, safety=safety, misses=misses)
    weights = residence_weights_batch(ws, caches)              # (B, 4)
    EVAL_COUNTERS["batch_array_evals"] += 1
    predicted = np.sum(pred_levels * weights, axis=-1)
    measured = np.sum(meas_levels * weights, axis=-1)
    EVAL_COUNTERS["scalar_points"] += predicted.size + measured.size
    return {
        "n": ns, "ws_bytes": ws, "misses": misses,
        "predicted": predicted, "measured": measured,
        "predicted_levels": pred_levels, "measured_levels": meas_levels,
        "regime": np.argmax(weights, axis=-1),
    }
