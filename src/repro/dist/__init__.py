"""Distribution: logical-axis sharding rules and mesh context."""
from .sharding import (  # noqa: F401
    PROFILES,
    MeshContext,
    ShardingProfile,
    current_context,
    current_mesh,
    logical_to_pspec,
    param_shardings,
    tp_dp,
    tp_fsdp,
    use_mesh_context,
)
