"""Logical-axis sharding: rules, profiles and the active mesh context.

The mesh is an *input*, never baked into model code (the ECM paper's
machine-model-as-input lesson applied to distribution).  Models declare
parameters with *logical* axis names (``repro.models.common.ParamSpec``);
a :class:`ShardingProfile` maps logical names to mesh axes; and
:func:`param_shardings` resolves a whole spec tree into
``NamedSharding``s for one concrete mesh.

Resolution is divisibility-aware: a logical axis whose dimension does not
divide the mesh axes it maps to is left unsharded, because uneven
shardings make GSPMD pad and replicate (observed: 24 q-heads annotated
onto a 16-way axis cost GiBs of padded full-size copies in the
minitron-4b dry-run).  A mesh axis may appear at most once per
``PartitionSpec``; the first (leftmost) logical axis that claims it wins.

:func:`use_mesh_context` installs the active mesh + profile for the
duration of a trace: model code reads it back via :func:`current_context`
(for ``shard_map`` meshes, data axes, decode-cache sequence sharding) and
``repro.models.common.shard_annotate`` picks up the activation rules.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import is_spec, set_activation_rules


# ---------------------------------------------------------------------------
# Profiles
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShardingProfile:
    """Named bundle of logical-axis -> mesh-axis rules.

    ``rules`` governs parameters (and optimizer state, which shares the
    parameter specs); ``activation_rules`` governs the in-graph
    ``with_sharding_constraint`` annotations.  A rule value is a mesh axis
    name, a tuple of mesh axis names, or ``None`` (replicate).
    """

    name: str
    rules: dict[str, Any]
    activation_rules: dict[str, Any] = field(default_factory=dict)


def _batch_axes(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def tp_dp(multi_pod: bool = False) -> ShardingProfile:
    """Tensor parallel over ``model``, data parallel over batch."""
    return ShardingProfile(
        name="tp_dp",
        rules={
            "mlp": "model", "heads": "model", "kv_heads": "model",
            "heads_qk": "model", "experts": "model", "experts_r": None,
            "mamba_inner": "model", "vocab": "model",
            "embed": None, "layers": None, "head_dim": None,
        },
        activation_rules={
            "batch": _batch_axes(multi_pod),
            "mlp": "model", "heads": "model", "kv_heads": "model",
            "mamba_inner": "model", "vocab": "model",
            "embed": None, "seq": None,
        },
    )


def tp_fsdp(multi_pod: bool = False) -> ShardingProfile:
    """TP over ``model`` + FSDP: the embed axis of every weight is sharded
    over ``data`` (gathered per microbatch by GSPMD / the MoE shard_map)."""
    base = tp_dp(multi_pod)
    return ShardingProfile(
        name="tp_fsdp",
        rules={**base.rules, "embed": "data"},
        activation_rules=base.activation_rules,
    )


def moe_ep(multi_pod: bool = False) -> ShardingProfile:
    """Expert parallelism: experts over ``model``, tokens data-sharded,
    expert weights FSDP'd over ``data`` (see ``moe_ffn_shard_map``)."""
    base = tp_dp(multi_pod)
    return ShardingProfile(
        name="moe_ep",
        rules={**base.rules, "experts": "model", "mlp": None,
               "embed": "data"},
        activation_rules=base.activation_rules,
    )


def dp_vocab(multi_pod: bool = False) -> ShardingProfile:
    """Pure data parallel with only the (large) vocab dims model-sharded —
    for small recurrent archs where TP'ing the inner dims doesn't pay."""
    base = tp_dp(multi_pod)
    return ShardingProfile(
        name="dp_vocab",
        rules={**base.rules, "mlp": None, "heads": None, "heads_qk": None,
               "mamba_inner": None, "vocab": "model"},
        activation_rules={**base.activation_rules, "mlp": None,
                          "heads": None, "mamba_inner": None},
    )


# ---------------------------------------------------------------------------
# Profile registry (mirrors MACHINES / workload_registry())
# ---------------------------------------------------------------------------

#: name -> constructor ``(multi_pod: bool = False) -> ShardingProfile``.
#: Kept constructor-valued so the historical ``PROFILES[name](multi_pod)``
#: call shape keeps working; prefer :func:`get_profile` for new code.
PROFILES: dict[str, Any] = {}
_PROFILE_ALIASES: dict[str, str] = {}


def register_profile(profile_or_ctor, *aliases, name: str | None = None):
    """Register a sharding profile by name, mirroring ``register_machine``.

    Accepts either a constructor ``ctor(multi_pod: bool = False) ->
    ShardingProfile`` or a concrete :class:`ShardingProfile` (wrapped in a
    constructor that ignores ``multi_pod``).  Returns the argument so it
    can be used as a decorator.
    """
    if isinstance(profile_or_ctor, ShardingProfile):
        prof = profile_or_ctor
        key = name or prof.name

        def ctor(multi_pod: bool = False, _p=prof) -> ShardingProfile:
            return _p
    else:
        ctor = profile_or_ctor
        key = name or ctor(False).name
    PROFILES[key] = ctor
    for a in aliases:
        _PROFILE_ALIASES[a] = key
    return profile_or_ctor


def get_profile(name_or_profile, *,
                multi_pod: bool = False) -> ShardingProfile:
    """Resolve a profile by registered name (a :class:`ShardingProfile`
    passes through unchanged, mirroring ``get_machine``)."""
    if isinstance(name_or_profile, ShardingProfile):
        return name_or_profile
    key = _PROFILE_ALIASES.get(name_or_profile, name_or_profile)
    try:
        ctor = PROFILES[key]
    except KeyError:
        raise KeyError(
            f"unknown sharding profile {name_or_profile!r}; registered: "
            f"{', '.join(profile_names())}") from None
    return ctor(multi_pod)


def profile_names() -> tuple[str, ...]:
    """Sorted names of all registered sharding profiles."""
    return tuple(sorted(PROFILES))


for _ctor in (tp_dp, tp_fsdp, moe_ep, dp_vocab):
    register_profile(_ctor)
del _ctor


# ---------------------------------------------------------------------------
# Rule resolution
# ---------------------------------------------------------------------------


def _axis_sizes(mesh: Mesh | None) -> dict[str, int]:
    if mesh is None:
        return {}
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _group_size(group: tuple[str, ...], sizes: dict[str, int]) -> int:
    n = 1
    for g in group:
        n *= sizes.get(g, 1)
    return n


def _resolve_one(assignment, dim: int | None, sizes: dict[str, int],
                 taken: set[str]):
    """Resolve one logical-axis assignment against divisibility + dedup.

    Returns the mesh axis (or tuple, or None) actually used.  Tuples keep
    the largest prefix whose mesh-size product divides ``dim`` (matching
    ``models.common.shard_annotate``).
    """
    if assignment is None:
        return None
    group = assignment if isinstance(assignment, tuple) else (assignment,)
    if any(g in taken for g in group):
        return None
    if dim is None or not sizes:
        return assignment
    for k in range(len(group), 0, -1):
        n = _group_size(group[:k], sizes)
        if n and dim % n == 0:
            return group[:k] if k > 1 else group[0]
    return None


def logical_to_pspec(axes, rules: dict[str, Any],
                     dims: tuple[int, ...] | None = None,
                     mesh: Mesh | None = None) -> P:
    """Map logical axis names to a ``PartitionSpec`` via ``rules``.

    ``dims``/``mesh`` enable the divisibility fallback (an indivisible
    logical axis is replicated).  Duplicate mesh axes are deduped, first
    occurrence wins.
    """
    sizes = _axis_sizes(mesh)
    taken: set[str] = set()
    out = []
    for i, a in enumerate(axes):
        assignment = rules.get(a) if a else None
        dim = dims[i] if dims is not None else None
        chosen = _resolve_one(assignment, dim, sizes, taken)
        if chosen is not None:
            grp = chosen if isinstance(chosen, tuple) else (chosen,)
            taken.update(grp)
        out.append(chosen)
    return P(*out)


def _ensure_model(spec, pspec: P, sizes: dict[str, int],
                  min_elems: int) -> P:
    """Force ``model`` onto the largest divisible dim of a big param that
    would otherwise be replicated over ``model`` (keeps per-chip footprint
    bounded even when the profile's preferred axis is indivisible).

    ``layers`` axes (scan stacks) are never chosen: sharding the stack dim
    would shard *different layers* onto different chips."""
    n_model = sizes.get("model", 1)
    if n_model <= 1:
        return pspec
    flat: set[str] = set()
    for e in pspec:
        if e is None:
            continue
        flat.update(e if isinstance(e, tuple) else (e,))
    if "model" in flat:
        return pspec
    if math.prod(spec.shape) < min_elems:
        return pspec
    order = sorted(range(len(spec.shape)), key=lambda i: -spec.shape[i])
    for i in order:
        if spec.axes[i] == "layers":
            continue
        if pspec[i] is not None:
            continue
        if spec.shape[i] % n_model == 0:
            out = list(pspec)
            out[i] = "model"
            return P(*out)
    return pspec


def param_shardings(spec_tree, mesh: Mesh, profile: ShardingProfile, *,
                    ensure_model_axis: bool = False,
                    min_elems: int = 1 << 16):
    """Spec tree -> ``NamedSharding`` tree for one concrete mesh."""
    sizes = _axis_sizes(mesh)

    def one(spec):
        pspec = logical_to_pspec(spec.axes, profile.rules, spec.shape, mesh)
        if ensure_model_axis:
            pspec = _ensure_model(spec, pspec, sizes, min_elems)
        return NamedSharding(mesh, pspec)

    return jax.tree.map(one, spec_tree, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Active mesh context
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshContext:
    """What model code may ask about the ambient distribution."""

    mesh: Mesh | None = None
    profile: ShardingProfile | None = None
    data_axes: tuple[str, ...] = ("data",)
    cache_seq_axis: str | None = None


_NULL_CONTEXT = MeshContext()
_CONTEXT: list[MeshContext] = []


def current_context() -> MeshContext:
    return _CONTEXT[-1] if _CONTEXT else _NULL_CONTEXT


def current_mesh() -> Mesh | None:
    return current_context().mesh


@contextmanager
def use_mesh_context(mesh: Mesh, profile: ShardingProfile | None, *,
                     multi_pod: bool = False,
                     cache_seq_axis: str | None = None):
    """Install ``mesh``/``profile`` as the ambient distribution context.

    Inside the block, ``current_context()`` reports the mesh,
    ``shard_annotate`` applies the profile's activation rules, and plain
    ``PartitionSpec`` sharding constraints resolve against ``mesh``.
    """
    batch = None
    if profile is not None:
        batch = profile.activation_rules.get("batch")
    data_axes = (batch if isinstance(batch, tuple)
                 else (batch,) if batch else _batch_axes(multi_pod))
    ctx = MeshContext(mesh=mesh, profile=profile, data_axes=data_axes,
                      cache_seq_axis=cache_seq_axis)
    _CONTEXT.append(ctx)
    set_activation_rules(profile.activation_rules if profile else None)
    try:
        with mesh:
            yield ctx
    finally:
        _CONTEXT.pop()
        prev = current_context()
        set_activation_rules(prev.profile.activation_rules
                             if prev.profile else None)
