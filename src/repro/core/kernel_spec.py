"""Stream-kernel specifications and automatic ECM model construction.

This module implements the paper's model-construction recipe (§IV-C):

1. count the micro-ops needed to process one cache line of work and push
   them through the machine's port model -> ``T_OL``, ``T_nOL``;
2. count cache-line streams (explicit loads, write-allocate/RFO streams,
   evictions, non-temporal stores) and convert them to per-level transfer
   cycles using the machine's per-level bandwidths;
3. compose everything into an :class:`~repro.core.ecm.ECMModel`.

The seven microbenchmarks of the paper's Table I (plus the two
non-temporal-store variants of §VII-E) ship as :data:`BENCHMARKS`;
:data:`TRIAD_UPDATE`, the fused chain built by :func:`fuse_chain`, ships
separately (it is not a Table I kernel) and is registered in the
workload registry alongside them.

Both builders here (:meth:`StreamKernelSpec.ecm` and
:func:`benchmark_batch`) are thin views of the unified workload engine
(``repro.core.workload``): a spec is wrapped in a ``StreamWorkload`` and
lowered on the target machine — the same single code path that evaluates
stencils and TPU steps, on any machine in the registry.
"""
from __future__ import annotations

from dataclasses import dataclass

from .ecm import ECMModel
from .machine import HASWELL_EP, MachineModel


@dataclass(frozen=True)
class StreamKernelSpec:
    """A steady-state streaming loop kernel, in the paper's Table I terms.

    Stream counts are *cache lines per cache line of work*: e.g. the copy
    kernel ``A[i]=B[i]`` reads one CL (B), write-allocates one CL (A, the
    RFO stream) and evicts one CL (A) per CL of work.

    ``flops_per_elem`` counts floating-point operations per scalar element
    (an FMA counts as two), used for performance conversion.
    """

    name: str
    expr: str
    loads_explicit: int
    rfo: int
    stores: int
    nt_stores: int = 0
    elem_bytes: int = 8            # double precision
    flops_per_elem: int = 0
    updates_per_elem: int = 1      # "MUp/s" work definition (1 elem update)
    # micro-op mix per CL of work, AVX (see machine.PortModel)
    uop_loads: int = 0
    uop_stores: int = 0
    uop_fma: int = 0
    uop_mul: int = 0
    uop_add: int = 0

    # ------------------------------------------------------------------
    # Stream accounting (§IV-C / §VII-E).  Non-temporal stores bypass the
    # L2/L3 *caches* (no write-allocate, no residence) but still traverse
    # the L1<->L2 *interface*: they leave the core through the line-fill
    # buffers at the L1 eviction bandwidth on their way to memory.  So NT
    # streams count on the L1<->L2 edge (outward) and on the L3<->Mem edge,
    # and are absent from the L2<->L3 edge — exactly the accounting that
    # reproduces the paper's striad_nt input {1 || 3 | 4 | 4 | 15.6}.
    # ------------------------------------------------------------------
    @property
    def load_streams(self) -> int:
        """Inward cache lines on every in-cache edge (loads + RFO)."""
        return self.loads_explicit + self.rfo

    @property
    def l1_evict_streams(self) -> int:
        """Outward cache lines on the L1<->L2 interface: write-backs plus
        NT stores draining through the line-fill buffers."""
        return self.stores + self.nt_stores

    @property
    def mem_streams(self) -> int:
        """Cache lines crossing the L3<->Mem edge per CL of work (NT
        stores land here directly from the LFBs)."""
        return self.loads_explicit + self.rfo + self.stores + self.nt_stores

    @property
    def l2_streams(self) -> int:
        """Cache lines crossing the L2<->L3 edge: NT stores bypass the
        deeper cache levels entirely (LFB -> memory, §VII-E)."""
        return self.loads_explicit + self.rfo + self.stores

    def elems_per_line(self, line_bytes: int) -> int:
        return line_bytes // self.elem_bytes

    # ------------------------------------------------------------------
    # §IV-C step 1+2+3: build the ECM model on a machine
    # ------------------------------------------------------------------
    def ecm(
        self,
        machine: MachineModel,
        sustained_bw: float,
        *,
        optimized_agu: bool = False,
    ) -> ECMModel:
        """Scalar view of the unified engine (the §IV-C recipe applied by
        ``workload.lower``; the stream-accounting note above describes the
        inclusive-hierarchy routing it performs)."""
        from .workload import StreamWorkload, workload_ecm

        return workload_ecm(StreamWorkload(self), machine,
                            sustained_bw=sustained_bw,
                            optimized_agu=optimized_agu)


# ---------------------------------------------------------------------------
# The paper's benchmark set (Table I + §VII-E non-temporal variants).
# uop counts are per cache line of work with AVX (32 B) vector registers:
# one 64 B line of doubles = 2 AVX loads or stores per stream.
# ---------------------------------------------------------------------------

BENCHMARKS: dict[str, StreamKernelSpec] = {
    "ddot": StreamKernelSpec(
        name="ddot", expr="s += A[i]*B[i]",
        loads_explicit=2, rfo=0, stores=0,
        flops_per_elem=2,
        uop_loads=4, uop_fma=2,
    ),
    "load": StreamKernelSpec(
        name="load", expr="s += A[i]",
        loads_explicit=1, rfo=0, stores=0,
        flops_per_elem=1,
        uop_loads=2, uop_add=2,
    ),
    "store": StreamKernelSpec(
        name="store", expr="A[i] = s",
        loads_explicit=0, rfo=1, stores=1,
        flops_per_elem=0,
        uop_stores=2,
    ),
    "update": StreamKernelSpec(
        name="update", expr="A[i] = s*A[i]",
        loads_explicit=1, rfo=0, stores=1,
        flops_per_elem=1,
        uop_loads=2, uop_stores=2, uop_mul=2,
    ),
    "copy": StreamKernelSpec(
        name="copy", expr="A[i] = B[i]",
        loads_explicit=1, rfo=1, stores=1,
        flops_per_elem=0,
        uop_loads=2, uop_stores=2,
    ),
    "striad": StreamKernelSpec(
        name="striad", expr="A[i] = B[i] + s*C[i]",
        loads_explicit=2, rfo=1, stores=1,
        flops_per_elem=2,
        uop_loads=4, uop_stores=2, uop_fma=2,
    ),
    "schoenauer": StreamKernelSpec(
        name="schoenauer", expr="A[i] = B[i] + C[i]*D[i]",
        loads_explicit=3, rfo=1, stores=1,
        flops_per_elem=2,
        uop_loads=6, uop_stores=2, uop_fma=2,
    ),
    # §VII-E: non-temporal-store variants (no RFO, stores bypass the caches)
    "striad_nt": StreamKernelSpec(
        name="striad_nt", expr="A[i] = B[i] + s*C[i]  (NT stores)",
        loads_explicit=2, rfo=0, stores=0, nt_stores=1,
        flops_per_elem=2,
        uop_loads=4, uop_stores=2, uop_fma=2,
    ),
    "schoenauer_nt": StreamKernelSpec(
        name="schoenauer_nt", expr="A[i] = B[i] + C[i]*D[i]  (NT stores)",
        loads_explicit=3, rfo=0, stores=0, nt_stores=1,
        flops_per_elem=2,
        uop_loads=6, uop_stores=2, uop_fma=2,
    ),
}


def fuse_chain(name: str, parts: "tuple | list", *, internal: int,
               expr: str = "") -> StreamKernelSpec:
    """Build the spec of a fused pipeline chain (§VII-E logic applied to
    kernel fusion, see ``kernels/stream/ops.triad_update``): uops of all
    stages are summed; ``internal`` intermediate arrays stay resident
    between stages, eliding one store + one load stream (and their uops)
    per fused link.  Returns an ordinary :class:`StreamKernelSpec`.

    RFO accounting per fused link (the write-allocate stream follows the
    arrays, not the stages): the elided intermediate is never allocated,
    so the upstream stage's RFO for it disappears; an in-place downstream
    stage (``rfo == 0``: its store targeted the array it loaded) loses
    that covering load, so its store becomes write-allocating.
    """
    if internal and any(p.nt_stores for p in parts[:-1]):
        raise ValueError(
            f"chain {name!r}: a non-final stage writes non-temporally; an "
            f"NT intermediate cannot stay resident for fusion")
    loads = sum(p.loads_explicit for p in parts) - internal
    stores = sum(p.stores for p in parts) - internal
    rfo = sum(p.rfo for p in parts)
    for up, down in list(zip(parts, parts[1:]))[:internal]:
        if up.rfo:
            rfo -= 1                  # intermediate no longer allocated
        if down.rfo == 0 and down.stores:
            rfo += 1                  # in-place store now write-allocates
    if loads < 0 or stores < 0 or rfo < 0:
        raise ValueError(f"chain {name!r} elides more streams than exist")
    return StreamKernelSpec(
        name=name,
        expr=expr or " -> ".join(p.name for p in parts),
        loads_explicit=loads,
        rfo=rfo,
        stores=stores,
        nt_stores=sum(p.nt_stores for p in parts),
        flops_per_elem=sum(p.flops_per_elem for p in parts),
        uop_loads=sum(p.uop_loads for p in parts) - 2 * internal,
        uop_stores=sum(p.uop_stores for p in parts) - 2 * internal,
        uop_fma=sum(p.uop_fma for p in parts),
        uop_mul=sum(p.uop_mul for p in parts),
        uop_add=sum(p.uop_add for p in parts),
    )


#: The fused triad->update chain of ``kernels/stream/ops.triad_update``:
#: the triad result stays in cache/VMEM instead of round-tripping memory —
#: 3 memory streams instead of 5, the 5/3 speedup the ECM stream count
#: predicts for the memory-bound limit.
TRIAD_UPDATE = fuse_chain(
    "triad_update", (BENCHMARKS["striad"], BENCHMARKS["update"]),
    internal=1, expr="A[i] = t*(B[i] + s*C[i])  (fused, triad result resident)")


def benchmark_batch(names: "list | tuple | None" = None, *,
                    machine: MachineModel | None = None,
                    sustained_bw: dict[str, float] | None = None,
                    optimized_agu: bool = False) -> "ECMBatch":
    """Vectorized §IV-C model construction for a set of benchmarks.

    One call into the unified workload engine
    (:func:`repro.core.workload.lower_many`); agrees with
    :func:`haswell_ecm` / ``StreamKernelSpec.ecm`` exactly.  ``names``
    entries may be registry keys or :class:`StreamKernelSpec` objects
    (custom kernels); bandwidths are looked up by spec name, so a custom
    spec needs a ``sustained_bw`` entry under its name (the simulator
    layer, ``simulate_levels_batch``, supplies defaults).
    """
    from .machine import HASWELL_EP
    from .workload import StreamWorkload, workload_batch

    m = machine or HASWELL_EP
    specs = [n if isinstance(n, StreamKernelSpec) else BENCHMARKS[n]
             for n in (names or BENCHMARKS)]
    if sustained_bw is not None:
        bws = sustained_bw
    else:
        bws = {k: v for k, v in m.measured_bw.items()
               if not k.startswith("_")}
    missing = [s.name for s in specs if s.name not in bws]
    if missing:
        raise KeyError(
            f"no sustained bandwidth for kernel {missing[0]!r}: pass "
            f"sustained_bw={{{missing[0]!r}: <bytes/s>}} for custom specs")
    return workload_batch([StreamWorkload(s) for s in specs], m,
                          sustained_bw=dict(bws),
                          optimized_agu=optimized_agu)


def haswell_ecm(name: str, *, optimized_agu: bool = False,
                machine: MachineModel | None = None,
                sustained_bw: float | None = None) -> ECMModel:
    """Build the ECM model for one of the paper's benchmarks on Haswell-EP,
    using the paper's measured sustained memory-domain bandwidths."""
    spec = BENCHMARKS[name]
    m = machine or HASWELL_EP
    bw = sustained_bw or HASWELL_EP.measured_bw[name]
    return spec.ecm(m, bw, optimized_agu=optimized_agu)


# ---------------------------------------------------------------------------
# Ground truth from the paper, used by tests and the Table I benchmark.
# Predictions: Table I ("ECM Prediction" column); measurements: Table I
# ("Measurement" column).  NT variants from §VII-E prose.
# ---------------------------------------------------------------------------

PAPER_TABLE1_PREDICTIONS: dict[str, tuple[float, ...]] = {
    "ddot": (2, 4, 8, 17.1),
    "load": (2, 2, 4, 8.5),
    "store": (2, 5, 9, 21.5),
    "update": (2, 5, 9, 21.5),
    "copy": (2, 6, 12, 28.8),
    "striad": (3, 8, 16, 37.7),
    "schoenauer": (4, 10, 20, 46.5),
    "striad_nt": (3, 7, 11, 26.6),
    "schoenauer_nt": (4, 9, 15, 35.3),
}

PAPER_TABLE1_MEASUREMENTS: dict[str, tuple[float, ...]] = {
    "ddot": (2.1, 4.7, 9.6, 19.4),
    "load": (2, 2.3, 5, 10.5),
    "store": (2, 6, 8.2, 17.7),
    "update": (2.1, 6.5, 8.3, 17.6),
    "copy": (2.1, 8, 13, 27),
    "striad": (3.1, 10, 17.5, 37),
    "schoenauer": (4.1, 11.9, 21.9, 46.8),
}

#: paper-stated model inputs (§V prose), for regression-testing the builder.
PAPER_TABLE1_INPUTS: dict[str, str] = {
    "ddot": "{1 || 2 | 2 | 4 | 9.1}",
    "load": "{2 || 1 | 1 | 2 | 4.5}",
    "store": "{0 || 2 | 3 | 4 | 12.5}",
    "update": "{2 || 2 | 3 | 4 | 12.5}",
    "copy": "{0 || 2 | 4 | 6 | 16.8}",
    "striad": "{1 || 3 | 5 | 8 | 21.7}",
    "schoenauer": "{1 || 4 | 6 | 10 | 26.5}",
    "striad_nt": "{1 || 3 | 4 | 4 | 15.6}",
    "schoenauer_nt": "{1 || 4 | 5 | 6 | 20.3}",
}
