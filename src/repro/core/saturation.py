"""Chip-level bottleneck and saturation (paper §IV-B, Eq. 2).

Single-core (single-chip) performance scales linearly with the number of
cores until the shared bottleneck — memory bandwidth on the CPU, HBM or
interconnect on the TPU — is hit::

    P(n) = min(n * P_ECM^mem, I * b_S)

with the saturation point ``n_S = ceil(T_ECM^mem / T_L3Mem)``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .ecm import ECMModel


@dataclass(frozen=True)
class ScalingModel:
    """Multicore scaling of one ECM model on one machine."""

    ecm: ECMModel
    #: transfer time over the shared bottleneck edge (cy per unit of work);
    #: on Haswell this is T_L3Mem — the last transfer term by default.
    bottleneck_cycles: float

    @classmethod
    def from_ecm(cls, ecm: ECMModel, bottleneck_level: int = -1) -> "ScalingModel":
        return cls(ecm=ecm, bottleneck_cycles=ecm.transfers[bottleneck_level])

    # ------------------------------------------------------------------
    @property
    def t_single(self) -> float:
        """Single-core in-memory runtime, cy per unit of work."""
        return self.ecm.prediction(len(self.ecm.levels) - 1)

    @property
    def n_saturation(self) -> int:
        """Eq. 2: cores needed to saturate the bottleneck."""
        return math.ceil(self.t_single / self.bottleneck_cycles)

    def performance(self, n_cores: int, work_per_unit: float = 1.0,
                    clock_hz: float | None = None) -> float:
        """P(n) in work units per cycle (or per second with ``clock_hz``)."""
        p_one = work_per_unit / self.t_single
        p_sat = work_per_unit / self.bottleneck_cycles
        p = min(n_cores * p_one, p_sat)
        return p * clock_hz if clock_hz else p

    def curve(self, n_cores: int, work_per_unit: float = 1.0,
              clock_hz: float | None = None) -> list[float]:
        return [self.performance(n, work_per_unit, clock_hz)
                for n in range(1, n_cores + 1)]


def batch_curve(batch, n_cores: int, work_per_unit=1.0,
                clock_hz: float | None = None,
                bottleneck_level: int = -1):
    """Vectorized Eq. 2 scaling surface for an :class:`~repro.core.ecm.
    ECMBatch`: P(n) for every batch element x n = 1..n_cores, shape
    ``B + (n_cores,)`` — one array op instead of a per-(kernel, n) loop."""
    import numpy as np

    t_single = batch.prediction(len(batch.levels) - 1)       # (B,)
    bottleneck = batch.transfers[..., bottleneck_level]       # (B,)
    w = np.asarray(work_per_unit, float)
    p_one = w / t_single
    p_sat = w / bottleneck
    n = np.arange(1, n_cores + 1, dtype=float)
    p = np.minimum(n * p_one[..., None], p_sat[..., None])
    return p * clock_hz if clock_hz else p


def batch_saturation(batch, bottleneck_level: int = -1):
    """Vectorized Eq. 2 saturation points: ``ceil(T_ECM^mem / T_bottleneck)``
    per batch element."""
    import numpy as np

    t_single = batch.prediction(len(batch.levels) - 1)
    bottleneck = batch.transfers[..., bottleneck_level]
    return np.ceil(t_single / bottleneck).astype(int)


def domain_scaling(ecm_domain: ECMModel, n_domains: int,
                   cores_per_domain: int, work_per_unit: float = 1.0,
                   clock_hz: float | None = None) -> list[float]:
    """Cluster-on-Die-style scaling (paper §VII-D): cores fill one affinity
    domain after the other; each domain saturates independently.

    ``ecm_domain`` must be built with the *single-domain* sustained
    bandwidth.  Returns P(n) for n = 1..n_domains*cores_per_domain.
    """
    single = ScalingModel.from_ecm(ecm_domain)
    out = []
    for n in range(1, n_domains * cores_per_domain + 1):
        full, rem = divmod(n, cores_per_domain)
        p = full * single.performance(cores_per_domain, work_per_unit)
        if rem:
            p += single.performance(rem, work_per_unit)
        out.append(p * clock_hz if clock_hz else p)
    return out
