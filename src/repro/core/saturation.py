"""Chip-level bottleneck and saturation (paper §IV-B, Eq. 2).

Single-core (single-chip) performance scales linearly with the number of
cores until the shared bottleneck — memory bandwidth on the CPU, HBM or
interconnect on the TPU — is hit::

    P(n) = min(n * P_ECM^mem, I * b_S)

with the saturation point ``n_S = ceil(T_ECM^mem / T_L3Mem)``.

**Core-bound workloads** (the PR-4 compute-bound families at
cache-resident sizes, or pre-lowered records whose bottleneck term is
zero) never hit the shared bottleneck: they scale linearly to the full
chip, so ``n_S = cores`` and ``P(n) = n * P_ECM`` — dividing by a zero
``bottleneck_cycles`` is guarded everywhere below.

This module is the scalar, single-machine view; the registry-integrated
batched engine (domain topology, DVFS, energy) lives in
:mod:`repro.core.scaling`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .ecm import ECMModel


@dataclass(frozen=True)
class ScalingModel:
    """Multicore scaling of one ECM model on one machine."""

    ecm: ECMModel
    #: transfer time over the shared bottleneck edge (cy per unit of work);
    #: on Haswell this is T_L3Mem — the last transfer term by default.
    bottleneck_cycles: float
    #: cores available on the chip (0 = unknown).  Caps ``n_saturation``
    #: and is the reported saturation point for core-bound workloads
    #: (``bottleneck_cycles == 0``: linear scaling to the full chip).
    cores: int = 0

    @classmethod
    def from_ecm(cls, ecm: ECMModel, bottleneck_level: int = -1,
                 cores: int = 0) -> "ScalingModel":
        return cls(ecm=ecm, bottleneck_cycles=ecm.transfers[bottleneck_level],
                   cores=cores)

    # ------------------------------------------------------------------
    @property
    def t_single(self) -> float:
        """Single-core in-memory runtime, cy per unit of work."""
        return self.ecm.prediction(len(self.ecm.levels) - 1)

    @property
    def core_bound(self) -> bool:
        """No shared-bottleneck term: the workload scales linearly."""
        return self.bottleneck_cycles <= 0.0

    @property
    def n_saturation(self) -> int:
        """Eq. 2: cores needed to saturate the bottleneck.  Core-bound
        workloads report the full chip (``cores``) — they never
        saturate; a known core count also caps the bandwidth-bound
        ceiling (more cores than the chip has cannot help)."""
        if self.core_bound:
            return max(self.cores, 1)
        n = math.ceil(self.t_single / self.bottleneck_cycles)
        return min(n, self.cores) if self.cores else n

    def performance(self, n_cores: int, work_per_unit: float = 1.0,
                    clock_hz: float | None = None) -> float:
        """P(n) in work units per cycle (or per second with ``clock_hz``)."""
        p_one = work_per_unit / self.t_single
        p = (n_cores * p_one if self.core_bound
             else min(n_cores * p_one, work_per_unit / self.bottleneck_cycles))
        return p * clock_hz if clock_hz else p

    def curve(self, n_cores: int, work_per_unit: float = 1.0,
              clock_hz: float | None = None) -> list[float]:
        return [self.performance(n, work_per_unit, clock_hz)
                for n in range(1, n_cores + 1)]


def batch_curve(batch, n_cores: int, work_per_unit=1.0,
                clock_hz: float | None = None,
                bottleneck_level: int = -1):
    """Vectorized Eq. 2 scaling surface for an :class:`~repro.core.ecm.
    ECMBatch`: P(n) for every batch element x n = 1..n_cores, shape
    ``B + (n_cores,)`` — one array op instead of a per-(kernel, n) loop.
    Zero-bottleneck (core-bound) elements scale linearly."""
    import numpy as np

    t_single = batch.prediction(len(batch.levels) - 1)       # (B,)
    bottleneck = batch.transfers[..., bottleneck_level]       # (B,)
    w = np.asarray(work_per_unit, float)
    p_one = w / t_single
    bound = bottleneck > 0
    p_sat = np.where(bound, w / np.where(bound, bottleneck, 1.0), np.inf)
    n = np.arange(1, n_cores + 1, dtype=float)
    p = np.minimum(n * p_one[..., None], p_sat[..., None])
    return p * clock_hz if clock_hz else p


def batch_saturation(batch, bottleneck_level: int = -1, cores: int = 0):
    """Vectorized Eq. 2 saturation points: ``ceil(T_ECM^mem /
    T_bottleneck)`` per batch element.  Elements with a zero bottleneck
    term (core-bound workloads) report ``cores`` — linear scaling to the
    full chip; a non-zero ``cores`` also caps the bandwidth-bound points.
    """
    import numpy as np

    t_single = batch.prediction(len(batch.levels) - 1)
    bottleneck = batch.transfers[..., bottleneck_level]
    bound = bottleneck > 0
    out = np.full(bottleneck.shape, max(cores, 1), dtype=int)
    out[bound] = np.ceil(t_single[bound] / bottleneck[bound]).astype(int)
    if cores:
        out = np.minimum(out, cores)
    return out


def domain_scaling(ecm_domain: ECMModel, n_domains: int,
                   cores_per_domain: int, work_per_unit: float = 1.0,
                   clock_hz: float | None = None) -> list[float]:
    """Cluster-on-Die-style scaling (paper §VII-D): cores fill one affinity
    domain after the other; each domain saturates independently.

    ``ecm_domain`` must be built with the *single-domain* sustained
    bandwidth.  Returns P(n) for n = 1..n_domains*cores_per_domain.
    """
    single = ScalingModel.from_ecm(ecm_domain)
    out = []
    for n in range(1, n_domains * cores_per_domain + 1):
        full, rem = divmod(n, cores_per_domain)
        p = full * single.performance(cores_per_domain, work_per_unit)
        if rem:
            p += single.performance(rem, work_per_unit)
        out.append(p * clock_hz if clock_hz else p)
    return out
