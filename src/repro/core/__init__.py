"""ECM performance-model core (the paper's contribution).

Paper-faithful pieces: :mod:`.ecm` (model + Eq. 1 overlap rule + notation),
:mod:`.machine` (Haswell-EP port/bandwidth model), :mod:`.kernel_spec`
(§IV-C construction recipe + Table I benchmarks), :mod:`.saturation`
(Eq. 2 multicore scaling) and :mod:`.energy` (§III-D energy/EDP analysis).

Beyond the paper's streaming kernels: :mod:`.layer_condition` (stencil
layer-condition analysis, arXiv:1410.5010) with LC-aware ECM construction.

TPU adaptation: :mod:`.hlo` (compiled-HLO resource extraction) and
:mod:`.tpu_ecm` (three-term compute/HBM/ICI ECM for JAX programs).
"""
from .ecm import ECMBatch, ECMModel, parse_prediction
from .kernel_spec import (
    BENCHMARKS,
    PAPER_TABLE1_INPUTS,
    PAPER_TABLE1_MEASUREMENTS,
    PAPER_TABLE1_PREDICTIONS,
    StreamKernelSpec,
    benchmark_batch,
    haswell_ecm,
)
from .layer_condition import (
    HASWELL_CAPACITIES,
    JACOBI2D,
    JACOBI3D,
    LC_SAFETY,
    STENCIL_MEASURED_BW,
    STENCILS,
    LayerCondition,
    StencilSpec,
    misses_batch,
    stencil_block_batch,
    stencil_ecm,
)
from .machine import (
    HASWELL_EP,
    HASWELL_MEASURED_BW,
    TPU_V5E,
    MachineModel,
    PortModel,
    TPUMachineModel,
    TransferLevel,
)
from .saturation import ScalingModel, batch_curve, batch_saturation, domain_scaling

__all__ = [
    "ECMBatch",
    "ECMModel",
    "parse_prediction",
    "BENCHMARKS",
    "PAPER_TABLE1_INPUTS",
    "PAPER_TABLE1_MEASUREMENTS",
    "PAPER_TABLE1_PREDICTIONS",
    "StreamKernelSpec",
    "benchmark_batch",
    "haswell_ecm",
    "HASWELL_CAPACITIES",
    "JACOBI2D",
    "JACOBI3D",
    "LC_SAFETY",
    "STENCIL_MEASURED_BW",
    "STENCILS",
    "LayerCondition",
    "StencilSpec",
    "misses_batch",
    "stencil_block_batch",
    "stencil_ecm",
    "batch_curve",
    "batch_saturation",
    "HASWELL_EP",
    "HASWELL_MEASURED_BW",
    "TPU_V5E",
    "MachineModel",
    "PortModel",
    "TPUMachineModel",
    "TransferLevel",
    "ScalingModel",
    "domain_scaling",
]
