"""ECM performance-model core (the paper's contribution).

Paper-faithful pieces: :mod:`.ecm` (model + Eq. 1 overlap rule + notation),
:mod:`.machine` (machine registry: Haswell-EP and the cross-generation
zoo, with per-machine bandwidth/issue tables and calibration data),
:mod:`.kernel_spec` (§IV-C construction recipe + Table I benchmarks),
:mod:`.saturation` (Eq. 2 multicore scaling) and :mod:`.energy` (§III-D
energy/EDP analysis), both now thin views over :mod:`.scaling` — the
registry-integrated chip engine (domain-aware Eq. 2 saturation, DVFS +
per-machine power calibration, energy/EDP operating points, and the TPU
data-parallel Eq. 2 analogue with ICI collectives as the shared
bottleneck).

Unified construction: :mod:`.workload` — every kernel family reduces to
one canonical record (uop mix + per-level line traffic) and one batched
engine evaluates any workload on any registered machine.

Beyond the paper's streaming kernels: :mod:`.layer_condition` (stencil
layer-condition analysis, arXiv:1410.5010) with LC-aware ECM construction.

TPU adaptation: :mod:`.hlo` (compiled-HLO resource extraction) and
:mod:`.tpu_ecm` (three-term compute/HBM/ICI ECM for JAX programs).

Calibration loop: :mod:`.calibrate` (measure -> least-squares fit ->
versioned machine files with provenance, closing the paper's §IV-A
measurement story) and :mod:`.diskcache` (content-fingerprinted on-disk
persistence of fitted calibrations and tuned-block picks, so warm PR-8
tables survive process restarts).  Machines serialize declaratively via
``machine_to_dict``/``machine_from_dict``; the zoo ships as checked-in
``src/repro/machines/*.json`` files bit-identical to the constants.
"""
from .ecm import ECMBatch, ECMModel, parse_prediction
from .kernel_spec import (
    BENCHMARKS,
    PAPER_TABLE1_INPUTS,
    PAPER_TABLE1_MEASUREMENTS,
    PAPER_TABLE1_PREDICTIONS,
    TRIAD_UPDATE,
    StreamKernelSpec,
    benchmark_batch,
    fuse_chain,
    haswell_ecm,
)
from .engine import (
    LoweredTable,
    cache_disabled,
    eq1_backend,
    eq1_predictions,
    fingerprint,
    lowered_table,
    zoo_sweep,
)
from .layer_condition import (
    JACOBI2D,
    JACOBI3D,
    LC_SAFETY,
    STENCILS,
    LayerCondition,
    StencilSpec,
    misses_batch,
    stencil_block_batch,
    stencil_ecm,
)
from .machine import (
    BROADWELL_EP,
    ChipPower,
    HASWELL_EP,
    MACHINES,
    SANDY_BRIDGE_EP,
    SKYLAKE_SP,
    TPU_V5E,
    TPU_V5E_HIERARCHY,
    MachineModel,
    PortModel,
    TPUMachineModel,
    TransferLevel,
    get_machine,
    load_machine_file,
    machine_from_dict,
    machine_names,
    machine_to_dict,
    register_machine,
    resolve_machine,
    save_machine_file,
)
from .saturation import ScalingModel, batch_curve, batch_saturation, domain_scaling
from .scaling import (
    ChipScaling,
    fill_domains,
    frequency_scale,
    saturation_table,
    scale_workloads,
    scaling_zoo,
    tpu_dp_scaling,
)
from .workload import (
    FLASH_ATTENTION_F32,
    MATMUL_F32,
    WORKLOADS,
    AttentionSpec,
    AttentionWorkload,
    LineTraffic,
    MatmulSpec,
    MatmulWorkload,
    RawWorkload,
    StencilWorkload,
    StreamWorkload,
    UopMix,
    Workload,
    lower,
    lower_many,
    register_workload,
    route_traffic,
    workload_batch,
    workload_ecm,
    workload_registry,
    zoo_predictions,
)

__all__ = [
    "ECMBatch",
    "ECMModel",
    "parse_prediction",
    "BENCHMARKS",
    "PAPER_TABLE1_INPUTS",
    "PAPER_TABLE1_MEASUREMENTS",
    "PAPER_TABLE1_PREDICTIONS",
    "StreamKernelSpec",
    "benchmark_batch",
    "haswell_ecm",
    "JACOBI2D",
    "JACOBI3D",
    "LC_SAFETY",
    "STENCILS",
    "LayerCondition",
    "StencilSpec",
    "misses_batch",
    "stencil_block_batch",
    "stencil_ecm",
    "batch_curve",
    "batch_saturation",
    "BROADWELL_EP",
    "HASWELL_EP",
    "MACHINES",
    "SANDY_BRIDGE_EP",
    "SKYLAKE_SP",
    "TPU_V5E",
    "TPU_V5E_HIERARCHY",
    "TRIAD_UPDATE",
    "MachineModel",
    "PortModel",
    "TPUMachineModel",
    "TransferLevel",
    "get_machine",
    "load_machine_file",
    "machine_from_dict",
    "machine_names",
    "machine_to_dict",
    "register_machine",
    "resolve_machine",
    "save_machine_file",
    "fuse_chain",
    "LoweredTable",
    "cache_disabled",
    "eq1_backend",
    "eq1_predictions",
    "fingerprint",
    "lowered_table",
    "zoo_sweep",
    "ScalingModel",
    "domain_scaling",
    "ChipScaling",
    "ChipPower",
    "fill_domains",
    "frequency_scale",
    "saturation_table",
    "scale_workloads",
    "scaling_zoo",
    "tpu_dp_scaling",
    "WORKLOADS",
    "FLASH_ATTENTION_F32",
    "MATMUL_F32",
    "AttentionSpec",
    "AttentionWorkload",
    "LineTraffic",
    "MatmulSpec",
    "MatmulWorkload",
    "RawWorkload",
    "StencilWorkload",
    "StreamWorkload",
    "UopMix",
    "Workload",
    "lower",
    "lower_many",
    "register_workload",
    "route_traffic",
    "workload_batch",
    "workload_ecm",
    "workload_registry",
    "zoo_predictions",
]

# PR-3 alias shims: resolved lazily so the DeprecationWarning fires in the
# owning submodule only when the name is actually used, not on package import.
_DEPRECATED_ALIASES = {
    "HASWELL_MEASURED_BW": "machine",
    "HASWELL_CAPACITIES": "layer_condition",
    "STENCIL_MEASURED_BW": "layer_condition",
    "PowerModel": "energy",
}


def __getattr__(name: str):
    if name in _DEPRECATED_ALIASES:
        import importlib

        mod = importlib.import_module(
            f".{_DEPRECATED_ALIASES[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
