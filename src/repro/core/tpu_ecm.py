"""ECM model for TPU programs (the paper's model, adapted — DESIGN.md §3/§4).

The unit of work is one compiled step (train / prefill / decode).  The
hierarchy terms become:

* ``T_comp`` — MXU/VPU execution time; this is the paper's ``T_OL`` (compute
  overlaps with DMA on TPU);
* ``T_hbm``  — HBM<->VMEM streaming time, the analogue of the in-cache
  transfer terms;
* ``T_ici``  — inter-chip collective time (ICI within a pod, DCN across
  pods), the analogue of the L3<->Mem term of the slowest shared resource.

Composition (paper Eq. 1 adapted): a fraction of the collective time is not
overlappable with compute (blocking gradient/activation dependencies) — that
fraction plays the role of ``T_nOL``.  We report both the full-overlap
(roofline) bound and the ECM no-overlap bound; the dominant term drives the
§Perf hillclimb.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from .ecm import ECMModel
from .hlo import HLOResources
from .machine import TPU_V5E, TPUMachineModel


@dataclass(frozen=True)
class MeshSpec:
    """Physical interpretation of a mesh for the ICI/DCN term."""

    shape: tuple[int, ...]
    axes: tuple[str, ...]
    #: axes that ride on DCN (pod-to-pod) instead of ICI
    dcn_axes: tuple[str, ...] = ("pod",)

    @property
    def n_chips(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def n_pods(self) -> int:
        n = 1
        for s, a in zip(self.shape, self.axes):
            if a in self.dcn_axes:
                n *= s
        return n


@dataclass(frozen=True)
class TPUStepECM:
    """Three-term ECM model of one compiled step on a TPU mesh.

    All times in seconds per step, *per chip* (resources are divided over
    chips by construction: cost_analysis FLOPs/bytes are per-device program
    totals already when compiled under SPMD; see ``from_resources``).
    """

    name: str
    t_comp: float
    t_hbm: float
    t_ici: float
    t_dcn: float = 0.0
    #: fraction of collective time serialized with compute (ECM T_nOL role).
    #: 1.0 = fully exposed (paper's non-overlapping loads assumption);
    #: tuned down by overlap optimizations (async collectives, FSDP prefetch).
    exposed_ici_fraction: float = 1.0
    exposed_hbm_fraction: float = 1.0
    model_flops: float = 0.0            # useful-work FLOPs (6ND), global
    hlo_flops: float = 0.0              # compiled FLOPs, global
    details: dict = field(default_factory=dict)

    # ------------------------------------------------------------------
    @property
    def t_roofline(self) -> float:
        """Full-overlap (light-speed) bound: max of the three terms."""
        return max(self.t_comp, self.t_hbm, self.t_ici + self.t_dcn)

    @property
    def t_ecm(self) -> float:
        """ECM bound: compute overlaps only the non-exposed transfer part."""
        exposed = (self.exposed_hbm_fraction * self.t_hbm
                   + self.exposed_ici_fraction * (self.t_ici + self.t_dcn))
        hidden_hbm = (1 - self.exposed_hbm_fraction) * self.t_hbm
        hidden_ici = (1 - self.exposed_ici_fraction) * (self.t_ici + self.t_dcn)
        return max(self.t_comp, hidden_hbm, hidden_ici) + exposed

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_comp, "memory": self.t_hbm,
                 "collective": self.t_ici + self.t_dcn}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the ECM-bound step time: how close the
        step is to the compute roofline (MFU-at-lightspeed)."""
        if self.t_ecm <= 0:
            return 0.0
        return self.t_comp / self.t_ecm * self.useful_flops_fraction

    @property
    def useful_flops_fraction(self) -> float:
        if self.hlo_flops <= 0:
            return 1.0
        return min(1.0, self.model_flops / self.hlo_flops)

    # ------------------------------------------------------------------
    def as_ecm_model(self) -> ECMModel:
        """Express as the paper's notation (times in microseconds):
        {T_comp || exposed | T_hbm | T_ici | T_dcn}."""
        us = 1e6
        exposed = 0.0
        return ECMModel(
            t_ol=self.t_comp * us,
            t_nol=exposed,
            transfers=(self.t_hbm * us, self.t_ici * us, self.t_dcn * us),
            levels=("VMEM", "HBM", "ICI", "DCN"),
            unit="us/step",
            name=self.name,
        )

    def as_workload(self):
        """Adapter into the unified workload engine: the step model as a
        pre-lowered :class:`~repro.core.workload.RawWorkload`, so TPU
        steps rank/batch through the exact code path every other family
        uses (``autotune.rank``, ``ECMBatch`` grids).  The
        record keeps its own (VMEM/HBM/ICI/DCN, us/step) hierarchy —
        batch it with other steps, not with cache-line workloads."""
        from .workload import tpu_step_workload

        return tpu_step_workload(self)

    def summary(self) -> dict:
        return {
            "name": self.name,
            "t_comp_s": self.t_comp,
            "t_hbm_s": self.t_hbm,
            "t_ici_s": self.t_ici,
            "t_dcn_s": self.t_dcn,
            "t_roofline_s": self.t_roofline,
            "t_ecm_s": self.t_ecm,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops": self.hlo_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            **{f"detail_{k}": v for k, v in self.details.items()},
        }


def from_resources(
    res: HLOResources,
    mesh: MeshSpec,
    *,
    name: str = "step",
    machine: TPUMachineModel = TPU_V5E,
    model_flops: float = 0.0,
    flops_are_global: bool = True,
    exposed_ici_fraction: float | None = None,
    exposed_hbm_fraction: float | None = None,
    ici_axis_links: int = 1,
    dtype_peak: float | None = None,
) -> TPUStepECM:
    """Build the per-chip three-term model from HLO resources.

    ``flops_are_global``: XLA's SPMD cost analysis reports the per-module
    numbers of the partitioned program — i.e. per device.  When compiling
    with ``--xla_force_host_platform_device_count`` the analysis is of the
    already-partitioned module, so figures are per chip; set
    ``flops_are_global=False`` in that case.  collective wire bytes from
    :class:`HLOResources` are per chip already.

    The exposed-fraction overlap coefficients default to the *machine's
    calibration data* (``TPUMachineModel.exposed_hbm_fraction`` /
    ``exposed_ici_fraction`` — measured by the serial-vs-pipelined kernel
    pair, see :func:`measured_overlap`); pass explicit values to override.
    """
    if exposed_ici_fraction is None:
        exposed_ici_fraction = machine.exposed_ici_fraction
    if exposed_hbm_fraction is None:
        exposed_hbm_fraction = machine.exposed_hbm_fraction
    n = mesh.n_chips
    div = n if flops_are_global else 1
    flops_chip = res.flops / div
    bytes_chip = res.bytes_accessed / div

    t_comp = flops_chip / (dtype_peak or machine.peak_bf16_flops)
    t_hbm = bytes_chip / machine.hbm_bytes_per_s

    # split wire traffic into ICI vs DCN by group size: groups spanning more
    # chips than one pod holds must cross DCN.
    chips_per_pod = n // max(mesh.n_pods, 1)
    ici_bytes = 0.0
    dcn_bytes = 0.0
    for c in res.collectives:
        w = c.wire_bytes_per_chip
        if mesh.n_pods > 1 and c.group_size > chips_per_pod:
            # hierarchical split: intra-pod part on ICI, 1/pod-th on DCN
            dcn_bytes += w / max(c.group_size // chips_per_pod, 1)
            ici_bytes += w
        else:
            ici_bytes += w
    t_ici = ici_bytes / (machine.ici_link_bytes_per_s * ici_axis_links)
    t_dcn = dcn_bytes / machine.dcn_bytes_per_s

    return TPUStepECM(
        name=name,
        t_comp=t_comp,
        t_hbm=t_hbm,
        t_ici=t_ici,
        t_dcn=t_dcn,
        exposed_ici_fraction=exposed_ici_fraction,
        exposed_hbm_fraction=exposed_hbm_fraction,
        model_flops=model_flops,
        hlo_flops=res.flops if flops_are_global else res.flops * n,
        details={
            "chips": n,
            "pods": mesh.n_pods,
            "bytes_chip": bytes_chip,
            "ici_wire_bytes_chip": ici_bytes,
            "dcn_wire_bytes_chip": dcn_bytes,
            "collective_out_bytes": res.collective_bytes,
            "collectives_by_kind": res.by_kind(),
        },
    )


# ---------------------------------------------------------------------------
# Overlap calibration (Eq. 1 inverted)
# ---------------------------------------------------------------------------


def overlap_coefficient(measured_s: float, t_comp_s: float,
                        t_transfer_s: float) -> float:
    """Invert Eq. 1 for the exposed-transfer fraction ``f``.

    The ECM composition is ``T(f) = max(T_comp, (1-f)*T_x) + f*T_x`` with
    ``f`` the fraction of transfer time serialized with compute (the
    ``T_nOL`` role).  Given a measured step time, return the *smallest*
    ``f`` consistent with it: when the kernel is transfer-bound
    (``T_x > T_comp``) any ``f <= 1 - T_comp/T_x`` predicts ``T = T_x``,
    so a measurement at the transfer bound pins only that upper range.
    """
    if t_transfer_s <= 0:
        return 0.0
    return min(1.0, max(0.0, (measured_s - t_comp_s) / t_transfer_s))


def measured_overlap(t_serial_s: float, t_pipelined_s: float,
                     t_transfer_s: float) -> float:
    """Exposed-transfer fraction from a serial/pipelined measurement pair.

    ``t_serial`` is the ``num_stages=1`` runtime (no overlap: compute and
    DMA strictly alternate, the T_nOL + T_data bound); ``t_pipelined`` the
    multi-buffered runtime.  The transfer time hidden by the pipeline is
    their difference, so the *exposed* fraction of the transfer term is
    ``1 - (t_serial - t_pipelined) / T_x`` — this is the calibrated
    ``exposed_hbm_fraction`` for :class:`TPUStepECM`.
    """
    if t_transfer_s <= 0:
        return 0.0
    hidden = max(0.0, t_serial_s - t_pipelined_s)
    return min(1.0, max(0.0, 1.0 - hidden / t_transfer_s))


def with_measured_overlap(step: TPUStepECM, *, t_serial_s: float,
                          t_pipelined_s: float) -> TPUStepECM:
    """Return a copy of ``step`` whose HBM exposure is calibrated from a
    serial vs multi-buffered kernel timing pair (see
    ``repro.kernels.pipeline``)."""
    import dataclasses

    f = measured_overlap(t_serial_s, t_pipelined_s, step.t_hbm)
    return dataclasses.replace(step, exposed_hbm_fraction=f)


def saturation_chips(step: TPUStepECM, bottleneck: str = "collective") -> int:
    """Eq. 2 analogue: chips after which adding more stops helping for a
    fixed global problem (the bottleneck term stops shrinking)."""
    terms = {"compute": step.t_comp, "memory": step.t_hbm,
             "collective": step.t_ici + step.t_dcn}
    b = terms[bottleneck]
    if b <= 0:
        return 1
    return max(1, math.ceil(step.t_ecm / b))
