"""The Execution-Cache-Memory (ECM) analytical performance model.

Implements the model of Hofmann, Eitzinger & Fey (2015), §IV:

* runtime decomposition into overlapping in-core cycles ``T_OL``,
  non-overlapping in-core cycles ``T_nOL`` and per-level transfer times;
* the composition / overlap rule (Eq. 1)::

      T_core = max(T_nOL, T_OL)
      T_ECM  = max(T_nOL + T_data, T_OL)

  where ``T_data`` is the sum of the transfer contributions down to the
  memory level the working set lives in;
* the shorthand notations ``{T_OL || T_nOL | T_L1L2 | T_L2L3 | T_L3Mem}``
  for model inputs and ``{L1 ] L2 ] L3 ] Mem}`` for predictions;
* conversion from cycles to performance (``P = W / T_ECM``).

Times are core cycles per unit of work (one cache-line of work on the CPU,
one VMEM block or one training step on the TPU — the model is agnostic, see
``machine.py``).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, replace

import numpy as np


def _fmt(x: float) -> str:
    """Format a cycle count the way the paper does (1 decimal, trim .0)."""
    r = round(x, 1)
    if abs(r - round(r)) < 1e-9:
        return str(int(round(r)))
    return f"{r:.1f}"


@dataclass(frozen=True)
class ECMModel:
    """An ECM model instance for one kernel on one machine.

    ``transfers[i]`` is the data-transfer time (cycles per unit of work)
    between hierarchy level ``i`` and level ``i+1``; ``levels`` names the
    *prediction* levels, so ``len(levels) == len(transfers) + 1``.
    """

    t_ol: float
    t_nol: float
    transfers: tuple[float, ...]
    levels: tuple[str, ...] = ("L1", "L2", "L3", "Mem")
    unit: str = "cy/CL"
    name: str = ""

    def __post_init__(self) -> None:
        if len(self.levels) != len(self.transfers) + 1:
            raise ValueError(
                f"need len(levels) == len(transfers)+1, got {len(self.levels)} "
                f"levels and {len(self.transfers)} transfers"
            )
        if self.t_ol < 0 or self.t_nol < 0 or any(t < 0 for t in self.transfers):
            raise ValueError("ECM times must be non-negative")

    # ------------------------------------------------------------------
    # Eq. (1)
    # ------------------------------------------------------------------
    @property
    def t_core(self) -> float:
        return max(self.t_nol, self.t_ol)

    def t_data(self, level: int | str) -> float:
        """Cumulative transfer time for data residing in ``level``."""
        idx = self._level_index(level)
        return sum(self.transfers[:idx])

    def prediction(self, level: int | str) -> float:
        """``T_ECM`` for data in ``level`` (Eq. 1)."""
        return max(self.t_nol + self.t_data(level), self.t_ol)

    def predictions(self) -> tuple[float, ...]:
        return tuple(self.prediction(i) for i in range(len(self.levels)))

    def core_bound(self, level: int | str = -1) -> bool:
        """True when ``T_OL`` hides the whole transfer chain down to
        ``level`` (default: the memory level) — the prediction *is* the
        in-core time.  The single home of the core-bound test used by
        the block tuners, benchmarks and docs."""
        return self.prediction(level) <= self.t_ol + 1e-9

    def _level_index(self, level: int | str) -> int:
        if isinstance(level, int):
            if level < 0:
                level += len(self.levels)
            if not 0 <= level < len(self.levels):
                raise IndexError(f"level {level} out of range")
            return level
        try:
            return self.levels.index(level)
        except ValueError:
            raise KeyError(f"unknown level {level!r}; have {self.levels}") from None

    # ------------------------------------------------------------------
    # Shorthand notation (paper §IV-A)
    # ------------------------------------------------------------------
    def notation(self) -> str:
        parts = " | ".join(_fmt(t) for t in self.transfers)
        return f"{{{_fmt(self.t_ol)} || {_fmt(self.t_nol)} | {parts}}}"

    def prediction_notation(self) -> str:
        return "{" + " ] ".join(_fmt(p) for p in self.predictions()) + "}"

    @classmethod
    def parse(cls, s: str, *, levels: tuple[str, ...] | None = None,
              name: str = "") -> "ECMModel":
        """Parse the paper's input shorthand, e.g. ``{1 || 2 | 2 | 4 | 9.1}``.

        Both the ASCII ``||`` and the typographic ``‖`` separator are
        accepted.
        """
        body = s.strip()
        if body.startswith("{") and body.endswith("}"):
            body = body[1:-1]
        body = body.replace("‖", "||")
        if "||" not in body:
            raise ValueError(f"not an ECM input notation: {s!r}")
        ol_part, rest = body.split("||", 1)
        xs = [float(x) for x in rest.split("|")]
        t_nol, transfers = xs[0], tuple(xs[1:])
        lv = levels or tuple(
            ["L1"] + [f"L{i+2}" for i in range(len(transfers) - 1)] + ["Mem"]
        )
        return cls(t_ol=float(ol_part), t_nol=t_nol, transfers=transfers,
                   levels=lv, name=name)

    # ------------------------------------------------------------------
    # Performance conversion (paper §IV-A: P = W / T_ECM)
    # ------------------------------------------------------------------
    def performance(self, work_per_unit: float, level: int | str,
                    clock_hz: float | None = None) -> float:
        """Performance for data in ``level``: work units per cycle, or per
        second if ``clock_hz`` is given."""
        p = work_per_unit / self.prediction(level)
        return p * clock_hz if clock_hz else p

    # ------------------------------------------------------------------
    # Utilities
    # ------------------------------------------------------------------
    def with_penalty(self, penalty_per_level: dict[int, float] | None = None,
                     ) -> "ECMModel":
        """Return a copy with extra per-transfer-level penalty cycles added
        (the paper's empirical off-core latency penalty, §VII-A)."""
        if not penalty_per_level:
            return self
        new = list(self.transfers)
        for i, p in penalty_per_level.items():
            new[i] = new[i] + p
        return replace(self, transfers=tuple(new))

    def scaled(self, factor: float) -> "ECMModel":
        return replace(
            self,
            t_ol=self.t_ol * factor,
            t_nol=self.t_nol * factor,
            transfers=tuple(t * factor for t in self.transfers),
        )

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        nm = f"{self.name}: " if self.name else ""
        return f"{nm}{self.notation()} {self.unit} -> T_ECM = {self.prediction_notation()}"


# ---------------------------------------------------------------------------
# Vectorized batch evaluation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ECMBatch:
    """A batch of ECM models over one shared level hierarchy, evaluated as
    NumPy array ops instead of per-model Python calls.

    All time arrays share an arbitrary leading batch shape ``B`` (kernels,
    kernels x sizes, candidates, ...): ``t_ol``/``t_nol`` are ``B``-shaped
    and ``transfers`` is ``B + (len(levels) - 1,)``.  The scalar
    :class:`ECMModel` API is available per element via :meth:`scalar` —
    the two agree exactly (same Eq. 1, same floats).
    """

    t_ol: np.ndarray
    t_nol: np.ndarray
    transfers: np.ndarray
    levels: tuple[str, ...] = ("L1", "L2", "L3", "Mem")
    names: tuple[str, ...] = ()
    unit: str = "cy/CL"

    def __post_init__(self):
        object.__setattr__(self, "t_ol", np.asarray(self.t_ol, float))
        object.__setattr__(self, "t_nol", np.asarray(self.t_nol, float))
        object.__setattr__(self, "transfers",
                           np.asarray(self.transfers, float))
        if self.transfers.shape[-1] != len(self.levels) - 1:
            raise ValueError(
                f"need transfers.shape[-1] == len(levels)-1, got "
                f"{self.transfers.shape[-1]} vs {len(self.levels)} levels")

    # ------------------------------------------------------------------
    @classmethod
    def from_models(cls, models: "list[ECMModel] | tuple[ECMModel, ...]"
                    ) -> "ECMBatch":
        levels = models[0].levels
        for m in models:
            if m.levels != levels:
                raise ValueError(f"level mismatch: {m.levels} vs {levels}")
        return cls(
            t_ol=np.array([m.t_ol for m in models]),
            t_nol=np.array([m.t_nol for m in models]),
            transfers=np.array([m.transfers for m in models]),
            levels=levels,
            names=tuple(m.name for m in models),
            unit=models[0].unit,
        )

    @property
    def batch_shape(self) -> tuple[int, ...]:
        return self.t_ol.shape

    def __len__(self) -> int:
        return int(np.prod(self.batch_shape)) if self.batch_shape else 1

    # ------------------------------------------------------------------
    # Eq. (1), vectorized
    # ------------------------------------------------------------------
    @property
    def t_core(self) -> np.ndarray:
        return np.maximum(self.t_nol, self.t_ol)

    def t_data(self) -> np.ndarray:
        """Cumulative transfer time per level: ``B + (L,)``, level 0 = 0."""
        zero = np.zeros(self.transfers.shape[:-1] + (1,))
        return np.concatenate(
            [zero, np.cumsum(self.transfers, axis=-1)], axis=-1)

    def predictions(self) -> np.ndarray:
        """``T_ECM`` for every batch element x level: ``B + (L,)``."""
        return eq1_predictions(self.t_ol, self.t_nol, self.transfers)

    def prediction(self, level: int | str) -> np.ndarray:
        idx = (level if isinstance(level, int)
               else self.levels.index(level))
        return self.predictions()[..., idx]

    def core_bound(self, level: int | str = -1) -> np.ndarray:
        """Vectorized :meth:`ECMModel.core_bound`: ``(B,)`` booleans."""
        return self.prediction(level) <= self.t_ol + 1e-9

    def performance(self, work_per_unit, level: int | str,
                    clock_hz: float | None = None) -> np.ndarray:
        p = np.asarray(work_per_unit, float) / self.prediction(level)
        return p * clock_hz if clock_hz else p

    # ------------------------------------------------------------------
    # Utilities
    # ------------------------------------------------------------------
    def scaled(self, factor) -> "ECMBatch":
        f = np.asarray(factor, float)
        return replace(self, t_ol=self.t_ol * f, t_nol=self.t_nol * f,
                       transfers=self.transfers * f[..., None]
                       if f.ndim else self.transfers * f)

    def with_penalty(self, penalty: np.ndarray) -> "ECMBatch":
        """Add per-transfer-edge penalty cycles (broadcast over ``B``)."""
        return replace(self, transfers=self.transfers + penalty)

    def scalar(self, i) -> ECMModel:
        """Thin scalar view of batch element ``i`` (flat index or tuple)."""
        name = ""
        if isinstance(i, int):
            if self.names:
                name = self.names[i]
            if len(self.batch_shape) > 1:       # flat index into B dims
                i = np.unravel_index(i, self.batch_shape)
        return ECMModel(
            t_ol=float(self.t_ol[i]),
            t_nol=float(self.t_nol[i]),
            transfers=tuple(float(x) for x in self.transfers[i]),
            levels=self.levels,
            unit=self.unit,
            name=name,
        )

    def models(self) -> "list[ECMModel]":
        return [self.scalar(i) for i in range(len(self))]


def eq1_predictions(t_ol, t_nol, transfers) -> np.ndarray:
    """Eq. (1) as a standalone array program: ``T_ECM`` per level.

    The single home of the model's arithmetic — :meth:`ECMBatch.predictions`
    and the table-backed fast path in :mod:`repro.core.engine` both call
    this, so "fast" and "reference" cannot drift apart.  Shapes: ``t_ol``
    and ``t_nol`` are ``B``-shaped, ``transfers`` is ``B + (E,)``; the
    result is ``B + (E + 1,)`` with level 0 carrying zero transfer time.
    """
    t_ol = np.asarray(t_ol, float)
    t_nol = np.asarray(t_nol, float)
    transfers = np.asarray(transfers, float)
    zero = np.zeros(transfers.shape[:-1] + (1,))
    t_data = np.concatenate([zero, np.cumsum(transfers, axis=-1)], axis=-1)
    return np.maximum(t_nol[..., None] + t_data, t_ol[..., None])


# ---------------------------------------------------------------------------
# Prediction-notation parsing (for validating against the paper's tables)
# ---------------------------------------------------------------------------

_PRED_SPLIT = re.compile(r"\]")


def parse_prediction(s: str) -> tuple[float, ...]:
    """Parse the paper's prediction shorthand ``{2 ] 4 ] 8 ] 17.1}``."""
    body = s.strip()
    if body.startswith("{") and body.endswith("}"):
        body = body[1:-1]
    return tuple(float(x) for x in _PRED_SPLIT.split(body))
