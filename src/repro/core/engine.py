"""Compiled evaluation layer: the request-path speed pass (ROADMAP item 5).

PRs 6-7 put :class:`~repro.core.ecm.ECMBatch` evaluation inside the serving
engine's admission control, the compose step-predictor and the autotuners —
code that runs per-request and per-step — but every call still paid the
Python-level §IV-C reduction (uops -> core cycles, logical traffic ->
:func:`~repro.core.traffic.route_traffic`, bandwidth-key resolution).  The
paper's point is that Eq. 1/Eq. 2 are cheap closed forms over a handful of
machine constants; this module makes them cheap *here*:

* :class:`LoweredTable` — a precomputed lowered-record table.  Every
  (workload, machine, bandwidth-override, AGU-mode) combination is lowered
  once into packed arrays and served on every later request.  Rows are
  keyed by a structural **fingerprint** of the inputs (exact canonical
  form, compared by equality — never by a lossy hash), so two calls share a
  row iff their inputs are bit-for-bit the same calibration.
* **Invalidation contract** — :func:`~repro.core.workload.register_workload`
  and :func:`~repro.core.machine.register_machine` notify this module
  through registry hook lists; only rows indexed under the re-registered
  name are dropped, everything else stays warm.  Calibration updates are
  published by re-registering the machine (serve's EWMA re-calibration is a
  post-prediction multiplier and touches no lowering input at all).
  Mutating a registered object's arrays/dicts in place is outside the
  contract.
* :func:`eq1_predictions` / :func:`eq1_backend` — Eq. 1 as a pure array
  program.  The numpy form (shared with ``ECMBatch.predictions``, so it is
  the reference by construction) is the default; a ``jax.jit`` mirror is
  available for large fused sweeps.  jax lowers to f32 by default, so the
  jitted backend trades bit-identity for fusion — the ``engine`` bench
  times both and ``docs/ecm-model.md`` records when each wins.
* :func:`zoo_sweep` — the full (workloads x machines x cores x frequency)
  Eq. 2 grid from warm table rows, sub-millisecond once warm.

Everything here is a cache in front of :func:`repro.core.workload.lower`;
correctness is anchored by tests that diff table-backed results bit-for-bit
against cold re-lowering for the whole registry.
"""

from __future__ import annotations

from collections import OrderedDict
from contextlib import contextmanager
from dataclasses import fields, is_dataclass

import numpy as np

from . import machine as _machine_mod
from . import workload as _workload_mod
from .ecm import eq1_predictions
from .machine import MACHINES, MachineModel, get_machine
from .workload import LoweredBatch, concat_lowered, lower, workload_registry

__all__ = [
    "LoweredTable", "PackedZoo", "cache_disabled", "cache_enabled",
    "cache_token", "canonical", "eq1_backend", "eq1_predictions",
    "fingerprint", "invalidate", "lowered_table", "packed_zoo",
    "set_cache_enabled", "zoo_sweep",
]


# ---------------------------------------------------------------------------
# Fingerprints: exact canonical form, interned to small tokens
# ---------------------------------------------------------------------------

_FP_ATTR = "_ecm_fingerprint"
_INTERN: dict = {}


def canonical(obj):
    """Reduce ``obj`` to an exact, hashable canonical form.

    The form is *structural*: two objects share a canonical form iff every
    field (recursively, down to array bytes) is equal — so a fingerprint
    match guarantees the lowered row was produced from bit-identical
    inputs, and a re-registered machine with any changed calibration field
    misses the old rows.  Frozen dataclasses intern their form to a small
    ``("fp", n)`` token, memoized on the instance, which makes repeat
    fingerprinting of registry singletons O(1) — that is what keeps warm
    table lookups off the request path's critical cost.
    """
    if obj is None or type(obj) in (bool, int, float, str, bytes):
        return obj
    if is_dataclass(obj) and not isinstance(obj, type):
        memo = getattr(obj, _FP_ATTR, None)
        if memo is not None:
            return memo
        form = (type(obj).__module__, type(obj).__qualname__) + tuple(
            (f.name, canonical(getattr(obj, f.name))) for f in fields(obj))
        token = ("fp", _INTERN.setdefault(form, len(_INTERN)))
        if obj.__dataclass_params__.frozen:
            try:
                object.__setattr__(obj, _FP_ATTR, token)
            except (AttributeError, TypeError):
                pass
        return token
    if type(obj) is np.ndarray:
        return ("ndarray", obj.shape, str(obj.dtype), obj.tobytes())
    if type(obj) is dict:
        return ("dict",) + tuple(
            (k, canonical(v)) for k, v in sorted(obj.items()))
    if type(obj) in (tuple, list):
        return ("seq",) + tuple(canonical(x) for x in obj)
    if callable(obj):
        return ("callable", getattr(obj, "__module__", ""),
                getattr(obj, "__qualname__", repr(obj)))
    if isinstance(obj, (bool, int, float, str, bytes, np.generic)):
        return ("scalar", type(obj).__name__, obj.item()
                if isinstance(obj, np.generic) else obj)
    return ("repr", type(obj).__qualname__, repr(obj))


def fingerprint(obj):
    """Public alias of :func:`canonical`: the identity a table row is
    keyed under.  Equal fingerprints == bit-identical lowering inputs."""
    return canonical(obj)


# ---------------------------------------------------------------------------
# Generation counter + process-wide cache switch
# ---------------------------------------------------------------------------

_GENERATION = 0
_CACHE_ENABLED = True


def cache_enabled() -> bool:
    """Whether table/levels caching is live (see :func:`cache_disabled`)."""
    return _CACHE_ENABLED


def set_cache_enabled(flag: bool) -> bool:
    """Globally enable/disable the precomputed-table fast paths (cold-path
    benchmarking, paranoia bisection).  Returns the previous setting."""
    global _CACHE_ENABLED
    prev, _CACHE_ENABLED = _CACHE_ENABLED, bool(flag)
    return prev


@contextmanager
def cache_disabled():
    """Force every lowering/levels evaluation inside the block cold."""
    prev = set_cache_enabled(False)
    try:
        yield
    finally:
        set_cache_enabled(prev)


def cache_token(machine: "MachineModel | str | None" = None):
    """Opaque token that changes whenever cached derivations of ``machine``
    (or, with no argument, of anything) may be stale: bumps with every
    registry mutation and with the machine's own fingerprint.  Consumers
    (``simcache``'s levels memo, serve's ``BucketModel``) compare tokens
    instead of re-deriving."""
    if machine is None:
        return (_GENERATION,)
    m = get_machine(machine)
    # prefer the currently registered object under the same name, so a
    # re-registered calibration is picked up even by holders of the old one
    m = MACHINES.get(m.name, m)
    return (_GENERATION, canonical(m))


def _on_registry_change(obj) -> None:
    global _GENERATION
    _GENERATION += 1
    try:
        object.__delattr__(obj, _FP_ATTR)   # drop stale memo, if any
    except AttributeError:
        pass
    name = getattr(obj, "name", None)
    if isinstance(obj, MachineModel):
        _TABLE.invalidate(machine=name)
    else:
        _TABLE.invalidate(workload=name)


_workload_mod._REGISTRY_HOOKS.append(_on_registry_change)
_machine_mod._REGISTRY_HOOKS.append(_on_registry_change)


# ---------------------------------------------------------------------------
# The precomputed lowered-record table
# ---------------------------------------------------------------------------

def _freeze(lowered: LoweredBatch) -> LoweredBatch:
    """Cached rows are shared across callers: make their arrays read-only
    so an accidental in-place edit raises instead of corrupting the
    table."""
    for arr in (lowered.batch.t_ol, lowered.batch.t_nol,
                lowered.batch.transfers, lowered.routed.load_lines,
                lowered.routed.evict_lines, lowered.l1_uops,
                lowered.mem_cy_per_line):
        arr.flags.writeable = False
    return lowered


class LoweredTable:
    """Precomputed (workload x machine) lowered records.

    Rows hold exactly what :func:`repro.core.workload.lower` returns —
    packed uop pressure, routed per-edge line counts, bandwidth keys
    resolved to transfer cycles — keyed by the full input fingerprint
    ``(workload, machine, sustained_bw, optimized_agu)``.  Keying by
    fingerprint rather than by name is load-bearing: the autotuners lower
    many same-named candidates (attention blockings differing only in
    ``block``), and a name key would alias them.  Name-keyed secondary
    indexes exist purely for targeted invalidation; eviction is LRU with a
    bounded row count.
    """

    def __init__(self, max_rows: int = 4096):
        self.max_rows = int(max_rows)
        # key -> (workload_name, machine_name, LoweredBatch)
        self._rows: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._by_workload: dict[str, set] = {}
        self._by_machine: dict[str, set] = {}
        self.stats = {"hits": 0, "misses": 0, "invalidated": 0,
                      "evicted": 0}

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._rows)

    def key_for(self, workload, machine, *, sustained_bw=None,
                optimized_agu: bool = False) -> tuple:
        m = get_machine(machine)
        return (canonical(workload), canonical(m), canonical(sustained_bw),
                bool(optimized_agu))

    def get(self, workload, machine, *, sustained_bw=None,
            optimized_agu: bool = False) -> LoweredBatch:
        """One workload's lowered record — served warm when fingerprints
        match, lowered cold (and installed) otherwise."""
        m = get_machine(machine)
        key = self.key_for(workload, m, sustained_bw=sustained_bw,
                           optimized_agu=optimized_agu)
        row = self._rows.get(key)
        if row is not None:
            self.stats["hits"] += 1
            self._rows.move_to_end(key)
            return row[2]
        self.stats["misses"] += 1
        lowered = _freeze(lower(workload, m, sustained_bw=sustained_bw,
                                optimized_agu=optimized_agu))
        wname = getattr(workload, "name", "?")
        self._rows[key] = (wname, m.name, lowered)
        self._by_workload.setdefault(wname, set()).add(key)
        self._by_machine.setdefault(m.name, set()).add(key)
        while len(self._rows) > self.max_rows:
            old_key, (ow, om, _) = self._rows.popitem(last=False)
            self._by_workload.get(ow, set()).discard(old_key)
            self._by_machine.get(om, set()).discard(old_key)
            self.stats["evicted"] += 1
        return lowered

    def get_many(self, workloads, machine, *, sustained_bw=None,
                 optimized_agu: bool = False) -> LoweredBatch:
        """Table-backed :func:`repro.core.workload.lower_many`: same rows,
        same concatenation (:func:`~repro.core.workload.concat_lowered`),
        bit-identical output."""
        parts = [self.get(w, machine, sustained_bw=sustained_bw,
                          optimized_agu=optimized_agu) for w in workloads]
        return concat_lowered(parts)

    # ------------------------------------------------------------------
    def build(self, workloads=None, machines=None, **kw) -> int:
        """Materialize rows ahead of time: every given workload x machine
        pair (defaults: the full registries).  Returns the row count."""
        ws = list(workloads if workloads is not None
                  else workload_registry().values())
        ms = [get_machine(m) for m in (machines or sorted(MACHINES))]
        for m in ms:
            for w in ws:
                self.get(w, m, **kw)
        return len(self._rows)

    def invalidate(self, *, workload: "str | None" = None,
                   machine: "str | None" = None) -> int:
        """Drop rows: all of them, or only those indexed under a workload
        and/or machine name.  Returns how many were dropped."""
        if workload is None and machine is None:
            n = len(self._rows)
            self._rows.clear()
            self._by_workload.clear()
            self._by_machine.clear()
        else:
            keys: set = set()
            if workload is not None:
                keys |= self._by_workload.pop(workload, set())
            if machine is not None:
                keys |= self._by_machine.pop(machine, set())
            n = 0
            for key in keys:
                row = self._rows.pop(key, None)
                if row is None:
                    continue
                n += 1
                self._by_workload.get(row[0], set()).discard(key)
                self._by_machine.get(row[1], set()).discard(key)
        self.stats["invalidated"] += n
        return n


_TABLE = LoweredTable()


def lowered_table() -> LoweredTable:
    """The process-wide table behind ``lower_many(..., table=None)``."""
    return _TABLE


def invalidate(**kw) -> int:
    """Module-level convenience: ``lowered_table().invalidate(...)``."""
    return _TABLE.invalidate(**kw)


# ---------------------------------------------------------------------------
# Eq. 1 backends: shared numpy reference, optional jax.jit mirror
# ---------------------------------------------------------------------------

_JAX_EQ1 = None


def _jax_eq1():
    global _JAX_EQ1
    if _JAX_EQ1 is None:
        try:
            import jax
            import jax.numpy as jnp
        except ImportError:
            _JAX_EQ1 = False
        else:
            @jax.jit
            def _eq1(t_ol, t_nol, transfers):
                zero = jnp.zeros(transfers.shape[:-1] + (1,),
                                 dtype=transfers.dtype)
                t_data = jnp.concatenate(
                    [zero, jnp.cumsum(transfers, axis=-1)], axis=-1)
                return jnp.maximum(t_nol[..., None] + t_data,
                                   t_ol[..., None])

            _JAX_EQ1 = _eq1
    return _JAX_EQ1 or None


def eq1_backend(name: str = "numpy"):
    """Eq. 1 evaluator by backend name.

    ``"numpy"`` is :func:`repro.core.ecm.eq1_predictions` — the exact
    function ``ECMBatch.predictions`` runs, hence bit-identical by
    construction and the default everywhere.  ``"jax"`` is a ``jax.jit``
    mirror: faster only for very large fused sweeps (see the ``engine``
    bench), numerically f32 under jax's default config, and silently
    unavailable (-> numpy) when jax is absent.
    """
    if name == "jax":
        fn = _jax_eq1()
        if fn is not None:
            return lambda t_ol, t_nol, transfers: np.asarray(
                fn(np.asarray(t_ol), np.asarray(t_nol),
                   np.asarray(transfers)))
    elif name != "numpy":
        raise ValueError(f"unknown Eq. 1 backend {name!r}")
    return eq1_predictions


# ---------------------------------------------------------------------------
# Packed zoo + the full Eq. 2 sweep
# ---------------------------------------------------------------------------

class PackedZoo:
    """One machine's registry workloads as a single warm
    :class:`LoweredBatch` (what Eq. 2 consumes), cached per (machine,
    workloads, bandwidth) fingerprint."""

    __slots__ = ("machine", "names", "lowered", "_scalings")

    def __init__(self, machine: MachineModel, names: tuple,
                 lowered: LoweredBatch):
        self.machine = machine
        self.names = names
        self.lowered = lowered
        self._scalings: dict = {}

    def scaling(self, f_ghz=None):
        """The DVFS-gridded :class:`~repro.core.scaling.ChipScaling` for
        this zoo, memoized per frequency grid — the frequency rescale and
        Eq. 1 re-evaluation it embodies are deterministic in (lowered
        rows, machine, grid), so a warm sweep skips them entirely."""
        from .scaling import scale_workloads
        key = canonical(f_ghz)
        cs = self._scalings.get(key)
        if cs is None:
            cs = scale_workloads(self.lowered, self.machine, f_ghz=f_ghz)
            self._scalings[key] = cs
        return cs


_PACKED: "OrderedDict[tuple, PackedZoo]" = OrderedDict()
_PACKED_MAX = 64


def packed_zoo(machine, workloads=None, *, sustained_bw=None) -> PackedZoo:
    """The concatenated lowered zoo for one machine, memoized so a warm
    sweep skips even the per-row concatenation."""
    m = get_machine(machine)
    ws = list(workloads if workloads is not None
              else workload_registry().values())
    key = (_GENERATION, canonical(m), tuple(canonical(w) for w in ws),
           canonical(sustained_bw))
    hit = _PACKED.get(key) if _CACHE_ENABLED else None
    if hit is not None:
        _PACKED.move_to_end(key)
        return hit
    lowered = _TABLE.get_many(ws, m, sustained_bw=sustained_bw) \
        if _CACHE_ENABLED else concat_lowered(
            [lower(w, m, sustained_bw=sustained_bw) for w in ws])
    zoo = PackedZoo(m, tuple(lowered.batch.names), lowered)
    if _CACHE_ENABLED:
        _PACKED[key] = zoo
        while len(_PACKED) > _PACKED_MAX:
            _PACKED.popitem(last=False)
    return zoo


def zoo_sweep(machines=None, workloads=None, *, n_cores=None,
              f_ghz=None, sustained_bw=None) -> dict:
    """The full Eq. 2 grid: every registered workload x machine x core
    count x frequency step, from warm table rows.

    Returns ``{machine: {"names", "f_ghz", "n_sat_chip", "core_bound",
    "performance"}}`` plus a total point count; ``performance`` is the
    (W, F, N) saturation-capped work rate from
    :meth:`repro.core.scaling.ChipScaling.performance`.  Warm, the whole
    registry sweep is sub-millisecond — the ``engine`` bench gates it.
    """
    ms = [get_machine(m) for m in (machines or sorted(MACHINES))]
    out: dict = {}
    points = 0
    for m in ms:
        zoo = packed_zoo(m, workloads, sustained_bw=sustained_bw)
        cs = zoo.scaling(f_ghz)
        perf = cs.performance(n_cores)
        out[m.name] = {
            "names": zoo.names,
            "f_ghz": cs.f_ghz,
            "n_sat_chip": cs.n_saturation_chip(),
            "core_bound": cs.core_bound(),
            "performance": perf,
        }
        points += int(perf.size)
    return {"machines": out, "points": points}
