"""ECM-guided configuration selection (beyond-paper use of the model).

The paper's workflow is: build the light-speed model from resource counts,
find the dominant term, act on it.  This module automates that loop over
*distribution configs*: for a transformer-like workload it estimates the
three TPU-ECM terms analytically for every candidate (data, model) mesh
factorization and gradient-accumulation depth, rejects configs whose
working set exceeds HBM, and ranks the rest by the ECM-bound step time.

The estimator is deliberately first-order (the same spirit as the paper's
stream counting): weights/activations/collectives are counted from model
dimensions, not from a compile.  `repro.launch.dryrun` remains the ground
truth; the autotuner prunes the candidate set before any compile happens.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from .machine import TPU_V5E, TPUMachineModel


@dataclass(frozen=True)
class WorkloadSpec:
    """First-order description of one training/serving step (global)."""

    n_params: int                      # active parameters
    d_model: int
    n_layers: int
    global_batch: int
    seq_len: int
    kind: str = "train"                # train | prefill | decode
    dtype_bytes: int = 2               # compute dtype
    opt_bytes_per_param: int = 12      # f32 master + 2 f32 moments
    remat_factor: float = 1.33         # fwd recompute in bwd
    #: activation bytes per token per layer in the residual path (empirical
    #: multiple of d_model; ~12 covers qkv/mlp/norm streams of a swiglu block)
    act_streams: float = 12.0

    @property
    def tokens(self) -> int:
        return self.global_batch * (1 if self.kind == "decode"
                                    else self.seq_len)

    @property
    def step_flops(self) -> float:
        mult = 6.0 if self.kind == "train" else 2.0
        return mult * self.n_params * self.tokens


@dataclass(frozen=True)
class CandidateConfig:
    data: int
    model: int
    accum: int = 1

    @property
    def chips(self) -> int:
        return self.data * self.model


@dataclass(frozen=True)
class Estimate:
    config: CandidateConfig
    t_comp: float
    t_hbm: float
    t_coll: float
    hbm_bytes: float
    fits: bool

    @property
    def t_ecm(self) -> float:
        return max(self.t_comp, self.t_hbm) + self.t_coll

    def summary(self) -> dict:
        return {"data": self.config.data, "model": self.config.model,
                "accum": self.config.accum,
                "t_comp_ms": self.t_comp * 1e3, "t_hbm_ms": self.t_hbm * 1e3,
                "t_coll_ms": self.t_coll * 1e3, "t_ecm_ms": self.t_ecm * 1e3,
                "hbm_gib": self.hbm_bytes / 2**30, "fits": self.fits}


def estimate(w: WorkloadSpec, c: CandidateConfig,
             m: TPUMachineModel = TPU_V5E) -> Estimate:
    """Three-term ECM estimate for one candidate (per chip, per step)."""
    chips = c.chips
    # ---- compute ----
    t_comp = w.step_flops * w.remat_factor / (chips * m.peak_bf16_flops)

    # ---- memory: weights + optimizer resident; activations streamed ----
    tokens_chip = w.tokens / c.data
    act_bytes = (tokens_chip * w.n_layers * w.act_streams * w.d_model
                 * w.dtype_bytes / c.model)
    micro = max(c.accum, 1)
    # FSDP/ZeRO semantics: params shard over (model x data); every
    # microbatch gathers + reads the full model-shard of the weights
    weight_stream = (w.n_params * w.dtype_bytes / c.model
                     * (micro if w.kind == "train" else 1))
    hbm_stream = act_bytes * (3.0 if w.kind == "train" else 1.0) \
        + weight_stream
    t_hbm = hbm_stream / m.hbm_bytes_per_s

    # ---- collectives ----
    coll = 0.0
    if w.kind == "train":
        # grad reduce-scatter+all-gather over data: 2 (N-1)/N bytes/param
        n = c.data
        coll += 2 * (n - 1) / max(n, 1) * w.n_params * 4 / (c.model * c.data)
        # FSDP weight all-gather over data, once per microbatch
        coll += (micro * (c.data - 1) / max(c.data, 1)
                 * w.n_params * w.dtype_bytes / c.model)
    if c.model > 1:
        # TP: 2 all-reduces of the residual stream per layer
        n = c.model
        stream = tokens_chip * w.d_model * w.dtype_bytes
        coll += 2 * w.n_layers * 2 * (n - 1) / n * stream / n
    t_coll = coll / (m.ici_link_bytes_per_s * 1)

    # ---- residency ----
    resident = (w.n_params * (w.dtype_bytes + (w.opt_bytes_per_param
                                               if w.kind == "train" else 0))
                / (c.model * c.data))
    live_act = act_bytes / micro + tokens_chip / micro * w.d_model \
        * w.dtype_bytes * w.n_layers / c.model   # remat carries
    fits = resident + live_act < m.hbm_bytes * 0.9
    return Estimate(c, t_comp, t_hbm, t_coll, resident + live_act, fits)


def candidates(n_chips: int, w: WorkloadSpec,
               accums=(1, 2, 4, 8, 16)) -> list[CandidateConfig]:
    out = []
    d = 1
    while d <= n_chips:
        if n_chips % d == 0:
            for a in accums:
                if w.global_batch % (d * a) == 0 or w.kind != "train":
                    out.append(CandidateConfig(data=d, model=n_chips // d,
                                               accum=a))
                    if w.kind != "train":
                        break
        d *= 2
    return out


def rank(w: WorkloadSpec, n_chips: int = 256,
         m: TPUMachineModel = TPU_V5E) -> list[Estimate]:
    """All feasible candidates, best (lowest ECM time) first."""
    ests = [estimate(w, c, m) for c in candidates(n_chips, w)]
    feasible = [e for e in ests if e.fits]
    pool = feasible or ests
    return sorted(pool, key=lambda e: e.t_ecm)


def recommend(w: WorkloadSpec, n_chips: int = 256,
              m: TPUMachineModel = TPU_V5E) -> Estimate:
    return rank(w, n_chips, m)[0]
