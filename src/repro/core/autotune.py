"""ECM-guided configuration selection (beyond-paper use of the model).

The paper's workflow is: build the light-speed model from resource counts,
find the dominant term, act on it.  This module automates that loop
behind **one keyword-driven facade**, :func:`rank`:

* ``rank(workloads, machine)`` — any ``repro.core.workload`` candidates
  (streams, stencils at different blockings, fused chains, pre-lowered
  TPU steps) lowered through the unified engine and argsorted by
  predicted ``T_ECM`` (supports incremental ``prior``/``dirty``
  re-ranking);
* ``rank(workloads, machine, objective="edp"|"energy"|"performance")``
  — chip operating points over the (workload x frequency x cores)
  surface;
* ``rank(spec_or_name, machine, widths=...)`` /
  ``rank(dims, machine, objective="matmul"|"attention")`` — the
  kernel block-size tuners (stencil spatial blocking, blocked-GEMM
  tilings, flash-attention tiles);
* ``rank(config, machine, mesh=n_chips)`` — the **mesh axis**: a joint
  (mesh shape, sharding profile, kernel block sizes) ranking from
  :mod:`repro.core.mesh` for a zoo config at a chip count;
* ``rank(WorkloadSpec(...), n_chips)`` — the first-order analytic
  (data, model, accum) factorization estimate below (the historical
  ``rank`` signature, unchanged).

The historical per-family entry points (``rank_workloads``,
``rank_operating_points``, ``rank_stencil_blocks``,
``rank_matmul_blocks``, ``rank_attention_blocks``) remain importable as
thin deprecated wrappers (module ``__getattr__`` shim) and return
``==``-identical output to the facade.

The first-order estimators are deliberately coarse (the same spirit as
the paper's stream counting): weights/activations/collectives are
counted from model dimensions, not from a compile.
`repro.launch.dryrun` remains the ground truth; the autotuner prunes the
candidate set before any compile happens.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass

import numpy as np

from .machine import TPU_V5E, TPUMachineModel

__all__ = [
    "CandidateConfig",
    "Estimate",
    "WorkloadSpec",
    "attention_block_candidates",
    "candidates",
    "estimate",
    "estimate_batch",
    "matmul_block_candidates",
    "rank",
    "recommend",
    "stencil_block_candidates",
]


@dataclass(frozen=True)
class WorkloadSpec:
    """First-order description of one training/serving step (global)."""

    n_params: int                      # active parameters
    d_model: int
    n_layers: int
    global_batch: int
    seq_len: int
    kind: str = "train"                # train | prefill | decode
    dtype_bytes: int = 2               # compute dtype
    opt_bytes_per_param: int = 12      # f32 master + 2 f32 moments
    remat_factor: float = 1.33         # fwd recompute in bwd
    #: activation bytes per token per layer in the residual path (empirical
    #: multiple of d_model; ~12 covers qkv/mlp/norm streams of a swiglu block)
    act_streams: float = 12.0

    @property
    def tokens(self) -> int:
        return self.global_batch * (1 if self.kind == "decode"
                                    else self.seq_len)

    @property
    def step_flops(self) -> float:
        mult = 6.0 if self.kind == "train" else 2.0
        return mult * self.n_params * self.tokens


@dataclass(frozen=True)
class CandidateConfig:
    data: int
    model: int
    accum: int = 1

    @property
    def chips(self) -> int:
        return self.data * self.model


@dataclass(frozen=True)
class Estimate:
    config: CandidateConfig
    t_comp: float
    t_hbm: float
    t_coll: float
    hbm_bytes: float
    fits: bool

    @property
    def t_ecm(self) -> float:
        return max(self.t_comp, self.t_hbm) + self.t_coll

    def summary(self) -> dict:
        return {"data": self.config.data, "model": self.config.model,
                "accum": self.config.accum,
                "t_comp_ms": self.t_comp * 1e3, "t_hbm_ms": self.t_hbm * 1e3,
                "t_coll_ms": self.t_coll * 1e3, "t_ecm_ms": self.t_ecm * 1e3,
                "hbm_gib": self.hbm_bytes / 2**30, "fits": self.fits}


def estimate_batch(w: WorkloadSpec, configs: "list[CandidateConfig]",
                   m: TPUMachineModel = TPU_V5E) -> dict[str, np.ndarray]:
    """Three-term ECM estimates for ALL candidates in single array ops.

    Returns a dict of (C,)-shaped arrays: ``t_comp``, ``t_hbm``,
    ``t_coll``, ``t_ecm``, ``hbm_bytes``, ``fits``.  This is the
    autotuner's hot path: a mesh scan over thousands of (data, model,
    accum) factorizations costs one NumPy pass, not one Python estimate
    per candidate.
    """
    data = np.array([c.data for c in configs], float)
    model = np.array([c.model for c in configs], float)
    accum = np.array([c.accum for c in configs], float)
    chips = data * model

    # ---- compute ----
    t_comp = w.step_flops * w.remat_factor / (chips * m.peak_bf16_flops)

    # ---- memory: weights + optimizer resident; activations streamed ----
    tokens_chip = w.tokens / data
    act_bytes = (tokens_chip * w.n_layers * w.act_streams * w.d_model
                 * w.dtype_bytes / model)
    micro = np.maximum(accum, 1.0)
    # FSDP/ZeRO semantics: params shard over (model x data); every
    # microbatch gathers + reads the full model-shard of the weights
    weight_stream = (w.n_params * w.dtype_bytes / model
                     * (micro if w.kind == "train" else 1.0))
    hbm_stream = act_bytes * (3.0 if w.kind == "train" else 1.0) \
        + weight_stream
    t_hbm = hbm_stream / m.hbm_bytes_per_s

    # ---- collectives ----
    coll = np.zeros_like(data)
    if w.kind == "train":
        # grad reduce-scatter+all-gather over data: 2 (N-1)/N bytes/param
        coll += (2 * (data - 1) / np.maximum(data, 1) * w.n_params * 4
                 / (model * data))
        # FSDP weight all-gather over data, once per microbatch
        coll += (micro * (data - 1) / np.maximum(data, 1)
                 * w.n_params * w.dtype_bytes / model)
    # TP: 2 all-reduces of the residual stream per layer (only model > 1)
    tp_stream = tokens_chip * w.d_model * w.dtype_bytes
    coll += np.where(
        model > 1,
        2 * w.n_layers * 2 * (model - 1) / model * tp_stream / model,
        0.0)
    t_coll = coll / (m.ici_link_bytes_per_s * 1)

    # ---- residency ----
    resident = (w.n_params * (w.dtype_bytes + (w.opt_bytes_per_param
                                               if w.kind == "train" else 0))
                / (model * data))
    live_act = act_bytes / micro + tokens_chip / micro * w.d_model \
        * w.dtype_bytes * w.n_layers / model   # remat carries
    hbm_bytes = resident + live_act
    fits = hbm_bytes < m.hbm_bytes * 0.9
    t_ecm = np.maximum(t_comp, t_hbm) + t_coll
    return {"t_comp": t_comp, "t_hbm": t_hbm, "t_coll": t_coll,
            "t_ecm": t_ecm, "hbm_bytes": hbm_bytes, "fits": fits}


def estimate(w: WorkloadSpec, c: CandidateConfig,
             m: TPUMachineModel = TPU_V5E) -> Estimate:
    """Three-term ECM estimate for one candidate (per chip, per step).

    Scalar view of :func:`estimate_batch`."""
    b = estimate_batch(w, [c], m)
    return Estimate(c, float(b["t_comp"][0]), float(b["t_hbm"][0]),
                    float(b["t_coll"][0]), float(b["hbm_bytes"][0]),
                    bool(b["fits"][0]))


def candidates(n_chips: int, w: WorkloadSpec,
               accums=(1, 2, 4, 8, 16)) -> list[CandidateConfig]:
    out = []
    d = 1
    while d <= n_chips:
        if n_chips % d == 0:
            for a in accums:
                if w.global_batch % (d * a) == 0 or w.kind != "train":
                    out.append(CandidateConfig(data=d, model=n_chips // d,
                                               accum=a))
                    if w.kind != "train":
                        break
        d *= 2
    return out


def _rank_spec(w: WorkloadSpec, n_chips: int = 256,
               m: TPUMachineModel = TPU_V5E) -> list[Estimate]:
    """All feasible candidates, best (lowest ECM time) first.

    Routed through :func:`estimate_batch`: one vectorized evaluation over
    the whole candidate set, then a NumPy argsort — Estimate objects are
    materialized only for the returned ranking."""
    cands = candidates(n_chips, w)
    if not cands:
        return []
    b = estimate_batch(w, cands, m)
    keep = b["fits"] if bool(b["fits"].any()) else np.ones(len(cands), bool)
    idx = np.flatnonzero(keep)
    order = idx[np.argsort(b["t_ecm"][idx], kind="stable")]
    return [Estimate(cands[i], float(b["t_comp"][i]), float(b["t_hbm"][i]),
                     float(b["t_coll"][i]), float(b["hbm_bytes"][i]),
                     bool(b["fits"][i]))
            for i in order]


def recommend(w: WorkloadSpec, n_chips: int = 256,
              m: TPUMachineModel = TPU_V5E) -> Estimate:
    return _rank_spec(w, n_chips, m)[0]


# ---------------------------------------------------------------------------
# Generic ECM workload ranking (the single code path every family uses)
# ---------------------------------------------------------------------------


def _rank_workloads(workloads, machine=None, *,
                    level: "int | str" = -1,
                    sustained_bw=None,
                    tiebreak=None,
                    prior: "list[dict] | None" = None,
                    dirty=None) -> list[dict]:
    """Rank any workloads on any machine by predicted ``T_ECM``.

    One vectorized lowering through the unified engine
    (``repro.core.workload.lower_many``), one argsort — no per-candidate
    model builds and no family-specific code: candidates may be stream
    kernels, stencils at different blockings, fused chains or pre-lowered
    (``RawWorkload``) records — any mix that lowers to one level
    hierarchy (pre-lowered records keep their own levels, so rank them
    against peers of the same hierarchy).  ``level`` picks the
    residence level the ranking optimizes for (default: the machine's
    memory level, whatever the hierarchy calls it); ``tiebreak`` is an
    optional
    secondary sort key array (ascending), e.g. preferring larger blocks
    among equal predictions.

    Returns dicts ``{"name", "index", "t_ecm", "predictions"}``
    best-first (``index`` is the position in the lowered batch, i.e. the
    candidate order).  ``workloads`` may also be an already-lowered
    :class:`~repro.core.workload.LoweredBatch` (callers that need the
    routed traffic or in-core times anyway avoid lowering twice);
    ``machine``/``sustained_bw`` are ignored then.

    **Incremental re-ranking**: pass a previously returned ranking as
    ``prior`` plus a ``dirty`` set of candidate indices and/or names
    whose inputs changed; only those candidates are re-lowered, the rest
    reuse their prior evaluations, and the same sort runs over the
    merged values — the result is exactly what a full re-rank would
    return (``dirty=None`` means nothing changed: a pure re-sort).
    ``prior`` must rank this same candidate list (same order, length).
    """
    from .machine import HASWELL_EP
    from .workload import lower_many

    if prior is not None:
        if hasattr(workloads, "routed"):
            raise ValueError(
                "incremental re-ranking needs the candidate list (to "
                "re-lower the dirty subset), not a pre-lowered batch")
        return _rerank_workloads(list(workloads), machine, level=level,
                                 sustained_bw=sustained_bw,
                                 tiebreak=tiebreak, prior=prior,
                                 dirty=dirty)
    lowered = (workloads if hasattr(workloads, "routed")
               else lower_many(workloads, machine or HASWELL_EP,
                               sustained_bw=sustained_bw))
    batch = lowered.batch
    t = batch.prediction(level)                               # (C,)
    order = (np.argsort(t, kind="stable") if tiebreak is None
             else np.lexsort((np.asarray(tiebreak), t)))
    preds = batch.predictions()
    return [{"name": batch.names[i] if batch.names else str(i),
             "index": int(i),
             "t_ecm": float(t[i]),
             "predictions": tuple(float(x) for x in preds[i])}
            for i in order]


def _rerank_workloads(ws, machine, *, level, sustained_bw, tiebreak,
                      prior, dirty) -> list[dict]:
    """The incremental arm of :func:`rank_workloads`: merge prior
    evaluations with fresh ones for the dirty subset, then run the exact
    sort of the full path over the merged values.  Float round-trips
    through the prior dicts are exact, so the output is bit-identical to
    a full re-rank whose non-dirty inputs did not change."""
    from .machine import HASWELL_EP
    from .workload import lower_many

    n = len(ws)
    by_index = {r["index"]: r for r in prior}
    if sorted(by_index) != list(range(n)):
        raise ValueError(
            f"prior ranking covers candidate indices "
            f"{sorted(by_index)[:8]}..., expected exactly 0..{n - 1}; "
            f"it must be a ranking of this same candidate list")
    dirty_set = frozenset(dirty if dirty is not None else ())
    todo = [i for i in range(n)
            if i in dirty_set or getattr(ws[i], "name", None) in dirty_set]
    if todo:
        lowered = lower_many([ws[i] for i in todo],
                             machine or HASWELL_EP,
                             sustained_bw=sustained_bw)
        batch = lowered.batch
        t_new = batch.prediction(level)
        preds = batch.predictions()
        for j, i in enumerate(todo):
            by_index[i] = {
                "name": batch.names[j] if batch.names else str(i),
                "index": i,
                "t_ecm": float(t_new[j]),
                "predictions": tuple(float(x) for x in preds[j]),
            }
    t = np.array([by_index[i]["t_ecm"] for i in range(n)], float)
    order = (np.argsort(t, kind="stable") if tiebreak is None
             else np.lexsort((np.asarray(tiebreak), t)))
    return [dict(by_index[int(i)]) for i in order]


def _rank_operating_points(workloads, machine=None, *,
                           objective: str = "edp",
                           total_work_units: float = 1.0,
                           f_ghz=None, sustained_bw=None,
                           n_cores: int | None = None,
                           top: int | None = None) -> list[dict]:
    """Rank chip operating points ``(workload, frequency, cores)`` by a
    performance-, energy- or EDP-objective.

    The chip-level companion of :func:`rank_workloads`: the same one
    lowering through the unified engine (``workloads`` may be any
    family mix or an already-lowered ``LoweredBatch``), then the
    registry scaling engine (:func:`repro.core.scaling.scale_workloads`
    — domain topology, DVFS grid and power coefficients all from the
    machine's calibration) evaluates the full (W x F x N) surface in
    one array pass and argsorts it.  ``objective`` is one of
    ``"performance"`` (minimise runtime), ``"energy"``
    (energy-to-solution) or ``"edp"``; ``top`` truncates the ranking.

    Returns dicts ``{"name", "f_ghz", "n_cores", "objective", "value",
    "runtime_s", "energy_J", "edp_Js"}`` best-first.
    """
    from .machine import HASWELL_EP
    from .scaling import scale_workloads

    cs = scale_workloads(workloads, machine or HASWELL_EP, f_ghz=f_ghz,
                         sustained_bw=sustained_bw)
    return cs.operating_points(total_work_units, objective=objective,
                               n_cores=n_cores, top=top)


# ---------------------------------------------------------------------------
# Stencil spatial-blocking autotuner (layer-condition ECM)
# ---------------------------------------------------------------------------


def stencil_block_candidates(widths: tuple[int, ...],
                             min_block: int = 16) -> list[tuple[int, ...]]:
    """Power-of-two inner-width cappings up to the full problem width.

    Only the innermost (contiguous) dimension is tiled — that is the knob
    that moves the layer condition; outer widths are kept whole."""
    inner = widths[-1]
    blocks, b = [], min_block
    while b < inner:
        blocks.append(widths[:-1] + (b,))
        b *= 2
    blocks.append(tuple(widths))          # no blocking
    return blocks


def _rank_stencil_blocks(spec_or_name, widths: tuple[int, ...],
                         blocks: "list[tuple[int, ...]] | None" = None,
                         *, level: "int | str" = "Mem",
                         machine=None, sustained_bw: float | None = None,
                         capacities: tuple[int, ...] | None = None
                         ) -> list[dict]:
    """Rank spatial blockings of a stencil by predicted ``T_ECM``.

    Same structure as :func:`rank` (the mesh autotuner): one vectorized
    :func:`~repro.core.layer_condition.stencil_block_batch` evaluation
    over every candidate, then an argsort — no per-candidate model
    builds.  ``level`` picks the residence level the ranking optimizes
    for (``"Mem"``: large working sets, where blocking matters).

    Returns dicts ``{"block", "t_ecm", "misses_l1", "speedup_vs_unblocked"}``
    best-first.  Ties on ``t_ecm`` (every block already satisfying the
    binding layer condition) are broken toward the *largest* block: equal
    predicted cycles, but fewer strips and less halo re-reading the
    first-order model does not charge for.
    """
    from .layer_condition import STENCILS, misses_batch
    from .machine import HASWELL_EP, get_machine
    from .workload import StencilWorkload

    spec = STENCILS.get(spec_or_name, spec_or_name)
    if not hasattr(spec, "row_streams"):
        raise KeyError(f"unknown stencil {spec_or_name!r}; "
                       f"registered: {sorted(STENCILS)}")
    m = get_machine(machine or HASWELL_EP)
    caps = capacities or m.capacities
    bw = sustained_bw or m.sustained_bw(spec.name, "_stencil",
                                        default=24.1e9)
    cands = blocks or stencil_block_candidates(widths)
    eff = np.minimum(np.asarray([tuple(b) for b in cands], float),
                     np.asarray(widths, float)[None, :])
    mis = misses_batch(spec, eff, caps)
    point = StencilWorkload(spec, widths=tuple(widths), capacities=caps)
    # one generic ranking pass over blocking candidates + the truly
    # unblocked baseline (appended last, independent of the candidate set)
    ranked = _rank_workloads(
        [point.with_block(b) for b in cands] + [point], m, level=level,
        sustained_bw=bw,
        # primary key t_ecm ascending, secondary key inner block descending
        tiebreak=np.concatenate([-eff[:, -1],
                                 [-float(np.asarray(widths)[-1])]]))
    base = next(r["t_ecm"] for r in ranked if r["index"] == len(cands))
    return [{"block": tuple(int(x) for x in cands[r["index"]]),
             "t_ecm": r["t_ecm"],
             "misses_l1": int(mis[r["index"], 0]),
             "speedup_vs_unblocked": base / r["t_ecm"]}
            for r in ranked if r["index"] < len(cands)]


# ---------------------------------------------------------------------------
# Compute-bound block-size autotuners (blocked matmul + flash attention)
# ---------------------------------------------------------------------------


def _pow2_divisors(dim: int, min_block: int, max_block: int) -> list[int]:
    """Power-of-two tile sizes that divide ``dim`` evenly (the Pallas
    kernels' grid constraint), capped at the dimension itself."""
    out, b = [], min_block
    while b <= min(max_block, dim):
        if dim % b == 0:
            out.append(b)
        b *= 2
    return out or [dim]


def matmul_block_candidates(m: int, n: int, k: int, *,
                            min_block: int = 32,
                            max_block: int = 1024,
                            bk: int | None = None
                            ) -> list[tuple[int, int, int]]:
    """(bm, bn, bk) candidates: power-of-two output tilings that divide
    the problem (the K blocking only sets the accumulator depth — it does
    not move the operand-panel layer conditions, so it is held fixed)."""
    bk = bk or min(k, 512)
    return [(bm, bn, bk)
            for bm in _pow2_divisors(m, min_block, max_block)
            for bn in _pow2_divisors(n, min_block, max_block)]


def _rank_matmul_blocks(dims: tuple[int, int, int],
                        blocks: "list[tuple[int, int, int]] | None" = None,
                        *, level: "int | str" = -1,
                        machine=None, sustained_bw: float | None = None,
                        spec=None) -> list[dict]:
    """Rank blocked-GEMM tilings of ``C[m,n] = A[m,k] @ B[k,n]`` by
    predicted ``T_ECM``.

    Same structure as :func:`rank_stencil_blocks`: one vectorized lowering
    over every candidate through :func:`rank_workloads`, then an argsort.
    Ties (every blocking already core-bound: ``T_OL`` hides the whole
    transfer chain) break toward the *largest* output tile — equal
    predicted cycles but fewer grid steps and less panel re-streaming the
    light-speed model does not charge for.

    Returns dicts ``{"block", "t_ecm", "core_bound", "mem_lines",
    "speedup_vs_min_block"}`` best-first.
    """
    from .machine import HASWELL_EP, get_machine
    from .workload import MATMUL_F32, MatmulWorkload, lower_many

    m_, n_, k_ = dims
    mach = get_machine(machine or HASWELL_EP)
    cands = blocks or matmul_block_candidates(m_, n_, k_)
    base = MatmulWorkload(spec or MATMUL_F32, m=m_, n=n_, k=k_)
    ws = [base.with_block(b) for b in cands]
    lowered = lower_many(ws, mach, sustained_bw=sustained_bw)
    mem_lines = lowered.routed.mem_lines()       # (C,)
    core = lowered.batch.core_bound(level)       # (C,)
    ranked = _rank_workloads(lowered, level=level,
                             tiebreak=[-b[0] * b[1] for b in cands])
    t_by_index = {r["index"]: r["t_ecm"] for r in ranked}
    base_i = min(range(len(cands)), key=lambda i: cands[i][0] * cands[i][1])
    base = t_by_index[base_i]
    return [{"block": tuple(int(x) for x in cands[r["index"]]),
             "t_ecm": r["t_ecm"],
             "core_bound": bool(core[r["index"]]),
             "mem_lines": float(mem_lines[r["index"]]),
             "speedup_vs_min_block": base / r["t_ecm"]}
            for r in ranked]


def attention_block_candidates(sq: int, skv: int, *,
                               min_block: int = 128,
                               max_block: int = 2048
                               ) -> list[tuple[int, int]]:
    """(bq, bkv) candidates: power-of-two tile rows dividing the
    sequence lengths (the Pallas kernel's grid constraint)."""
    return [(bq, bkv)
            for bq in _pow2_divisors(sq, min_block, max_block)
            for bkv in _pow2_divisors(skv, min_block, max_block)]


def _rank_attention_blocks(dims: tuple[int, int, int],
                           blocks: "list[tuple[int, int]] | None" = None,
                           *, level: "int | str" = -1,
                           machine=None, causal: bool = True,
                           sustained_bw: float | None = None,
                           spec=None,
                           prior: "list[dict] | None" = None,
                           dirty=None) -> list[dict]:
    """Rank flash-attention (bq, bkv) tilings by predicted ``T_ECM``.

    ``dims`` is ``(sq, skv, d)``.  Candidates whose working set (q tile,
    KV tiles, score tile, accumulator) overflows the reuse level — the
    innermost cache that can hold it (VMEM on the TPU, L2/L3 on the
    CPUs) — are marked ``fits=False`` and ranked after every fitting
    candidate: the flash strategy's traffic model assumes the tiles stay
    resident through a KV pass.

    Larger ``bq`` cuts the KV re-streaming (``2*Sk/bq`` lines per CL of
    O); larger ``bkv`` cuts the online-softmax rescale uops — the tuner
    trades both against the fit constraint.

    Returns dicts ``{"block", "t_ecm", "fits", "core_bound",
    "tile_bytes"}`` best-first.

    **Incremental re-ranking**: pass a previously returned ranking as
    ``prior`` plus a ``dirty`` set of ``(bq, bkv)`` blocks whose inputs
    changed; only those candidates are re-lowered (fit/tile-size
    arithmetic is always recomputed — it needs no lowering) and the same
    sort runs over the merged values, so the result is exactly a full
    re-rank.  An empty ``dirty`` performs no lowering at all — the case
    serve's EWMA re-calibration hits, since its correction is a
    post-prediction multiplier and no lowering input moved.
    """
    from .machine import HASWELL_EP, get_machine
    from .workload import (COMPUTE_LC_SAFETY, FLASH_ATTENTION_F32,
                           AttentionWorkload, lower_many)

    sq, skv, d = dims
    mach = get_machine(machine or HASWELL_EP)
    sp = spec or FLASH_ATTENTION_F32
    cands = blocks or attention_block_candidates(sq, skv)
    base = AttentionWorkload(sp, sq=sq, skv=skv, d=d, causal=causal)
    ws = [base.with_block(b) for b in cands]
    eb = sp.elem_bytes
    reuse_cap = max(mach.capacities) if mach.capacities else 0
    tile_bytes = [(bq * d + 2 * bkv * d + bq * bkv + bq * d) * eb
                  for bq, bkv in cands]
    fits = [not reuse_cap or tb * COMPUTE_LC_SAFETY <= reuse_cap
            for tb in tile_bytes]
    if prior is None:
        lowered = lower_many(ws, mach, sustained_bw=sustained_bw)
        t = lowered.batch.prediction(level)      # (C,)
        core = lowered.batch.core_bound(level)   # (C,)
    else:
        want = [tuple(int(x) for x in c) for c in cands]
        by_block = {tuple(r["block"]): r for r in prior}
        missing = [b for b in want if b not in by_block]
        if missing:
            raise ValueError(
                f"prior ranking is missing blocks {missing[:4]}; it "
                f"must rank this same candidate set")
        # prior t_ecm values round-trip through float() exactly, so the
        # merged sort keys match a full re-rank bit for bit
        t = np.array([by_block[b]["t_ecm"] for b in want], float)
        core = np.array([by_block[b]["core_bound"] for b in want], bool)
        dirty_set = {tuple(int(x) for x in b) for b in (dirty or ())}
        todo = [i for i, b in enumerate(want) if b in dirty_set]
        if todo:
            sub = lower_many([ws[i] for i in todo], mach,
                             sustained_bw=sustained_bw)
            t[todo] = sub.batch.prediction(level)
            core[todo] = sub.batch.core_bound(level)
    # at equal predictions prefer the larger tiles (less KV streaming /
    # fewer rescale passes than the light-speed tie reflects)
    order = np.lexsort((np.asarray([-bq * bkv for bq, bkv in cands]), t))
    out = [{"block": tuple(int(x) for x in cands[i]),
            "t_ecm": float(t[i]),
            "fits": bool(fits[i]),
            "core_bound": bool(core[i]),
            "tile_bytes": int(tile_bytes[i])}
           for i in order]
    # fit is the primary key: the traffic model assumes resident tiles
    out.sort(key=lambda r: 0 if r["fits"] else 1)
    return out


# ---------------------------------------------------------------------------
# The unified facade
# ---------------------------------------------------------------------------


_OPERATING_POINT_OBJECTIVES = ("edp", "energy", "performance")
_UNSET = object()


def rank(candidates=None, machine=None, *,
         objective: str | None = None,
         mesh=None,
         level=_UNSET,
         sustained_bw: float | None = None,
         tiebreak=None,
         prior: "list[dict] | None" = None,
         dirty=None,
         blocks=None,
         widths: tuple[int, ...] | None = None,
         causal: bool = True,
         spec=None,
         capacities: tuple[int, ...] | None = None,
         total_work_units: float = 1.0,
         f_ghz=None,
         n_cores: int | None = None,
         top: int | None = None,
         n_chips: int = 256,
         **mesh_opts):
    """Rank candidates by the ECM model — the single autotuner entry point.

    Dispatch is keyword-driven; ``candidates``/``machine`` mean whatever
    the selected ranking expects:

    ===========================  ==========================================
    call shape                   ranking
    ===========================  ==========================================
    ``rank(cfg, m, mesh=N)``     joint (mesh shape, sharding profile,
                                 block sizes) for a zoo config at ``N``
                                 chips (:func:`repro.core.mesh.rank_meshes`;
                                 ``mesh`` may also be a dict of its
                                 options, extra keywords pass through)
    ``rank(WorkloadSpec, N)``    first-order (data, model, accum)
                                 factorizations -> ``list[Estimate]``
                                 (the historical ``rank`` signature)
    ``objective="edp" |``        chip operating points over the
    ``"energy"|"performance"``   (workload x frequency x cores) surface
    ``widths=...`` (or           stencil spatial blockings
    ``objective="stencil"``)     (``candidates`` is the spec or name)
    ``objective="matmul"``       blocked-GEMM (bm, bn, bk) tilings
                                 (``candidates`` is ``(m, n, k)``)
    ``objective="attention"``    flash-attention (bq, bkv) tilings
                                 (``candidates`` is ``(sq, skv, d)``;
                                 supports ``prior``/``dirty``)
    default                      any ``repro.core.workload`` candidates by
                                 ``T_ECM`` (supports ``prior``/``dirty``)
    ===========================  ==========================================

    Every arm delegates to the same implementation the historical
    per-family names wrap, so output is ``==``-identical either way.
    """
    if mesh is not None:
        from .mesh import rank_meshes

        # ``mesh`` is either the chip count or a mapping of rank_meshes
        # options (duck-typed, like the pre-lowered ``routed`` protocol)
        opts = dict(mesh) if hasattr(mesh, "keys") else {}
        n = int(opts.pop("n_chips", n_chips if hasattr(mesh, "keys")
                         else mesh))
        opts.update(mesh_opts)
        if top is not None:
            opts.setdefault("top", top)
        if sustained_bw is not None:
            opts.setdefault("sustained_bw", sustained_bw)
        return rank_meshes(candidates, n, machine or "tpu-v5e", **opts)
    if mesh_opts:
        raise TypeError(f"unexpected keyword arguments without mesh=: "
                        f"{sorted(mesh_opts)}")
    if hasattr(candidates, "step_flops"):
        # a WorkloadSpec: the historical ``rank(w, n_chips, m)`` shape,
        # where ``machine`` may carry the chip count positionally
        m_is_machine = hasattr(machine, "hbm_bytes_per_s")
        n = (int(machine) if machine is not None and not m_is_machine
             else n_chips)
        m = machine if m_is_machine else TPU_V5E
        return _rank_spec(candidates, n, m)
    if objective in _OPERATING_POINT_OBJECTIVES:
        return _rank_operating_points(
            candidates, machine, objective=objective,
            total_work_units=total_work_units, f_ghz=f_ghz,
            sustained_bw=sustained_bw, n_cores=n_cores, top=top)
    if objective == "stencil" or widths is not None:
        return _rank_stencil_blocks(
            candidates, widths, blocks,
            level=("Mem" if level is _UNSET else level),
            machine=machine, sustained_bw=sustained_bw,
            capacities=capacities)
    if objective == "matmul":
        return _rank_matmul_blocks(
            candidates, blocks, level=(-1 if level is _UNSET else level),
            machine=machine, sustained_bw=sustained_bw, spec=spec)
    if objective == "attention":
        return _rank_attention_blocks(
            candidates, blocks, level=(-1 if level is _UNSET else level),
            machine=machine, causal=causal, sustained_bw=sustained_bw,
            spec=spec, prior=prior, dirty=dirty)
    if objective is None or objective == "t_ecm":
        return _rank_workloads(
            candidates, machine, level=(-1 if level is _UNSET else level),
            sustained_bw=sustained_bw, tiebreak=tiebreak, prior=prior,
            dirty=dirty)
    raise ValueError(
        f"unknown objective {objective!r}; expected one of "
        f"{_OPERATING_POINT_OBJECTIVES + ('stencil', 'matmul', 'attention', 't_ecm')}")


# ---------------------------------------------------------------------------
# Deprecated per-family names (module __getattr__ shim)
# ---------------------------------------------------------------------------

#: old public name -> (implementation, suggested facade call shape)
_DEPRECATED_RANKERS = {
    "rank_workloads": ("_rank_workloads", "rank(workloads, machine)"),
    "rank_operating_points": (
        "_rank_operating_points",
        'rank(workloads, machine, objective="edp")'),
    "rank_stencil_blocks": (
        "_rank_stencil_blocks", "rank(spec, machine, widths=...)"),
    "rank_matmul_blocks": (
        "_rank_matmul_blocks", 'rank(dims, machine, objective="matmul")'),
    "rank_attention_blocks": (
        "_rank_attention_blocks",
        'rank(dims, machine, objective="attention")'),
}


def __getattr__(name: str):
    entry = _DEPRECATED_RANKERS.get(name)
    if entry is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    impl_name, hint = entry
    impl = globals()[impl_name]

    @functools.wraps(impl)
    def wrapper(*args, **kwargs):
        warnings.warn(
            f"repro.core.autotune.{name} is deprecated and scheduled for "
            f"removal; migrate to repro.core.autotune.{hint}",
            DeprecationWarning, stacklevel=2)
        return impl(*args, **kwargs)

    wrapper.__name__ = name
    wrapper.__qualname__ = name
    return wrapper
