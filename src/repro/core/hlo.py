"""Extract ECM resource terms from compiled XLA artifacts.

The dry-run (``repro.launch.dryrun``) lowers and compiles every
(architecture x input-shape x mesh) cell; this module is the framework's
"performance counter": it pulls

* HLO FLOPs and HLO bytes-accessed from ``compiled.cost_analysis()``;
* collective traffic by parsing the HLO text for ``all-gather`` /
  ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
  ``collective-permute`` ops and summing their operand sizes (cost_analysis
  does not report collective bytes).

On-wire bytes differ from operand bytes per collective kind; we apply the
standard ring-algorithm multipliers so the ICI term reflects actual link
traffic per chip.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g.  f32[16,1024,512]{2,1,0}  or  bf16[8192,49152]
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9a-z]+)?|pred)\[([0-9,]*)\]")

_COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# an HLO instruction line:  %name = TYPE[shape] op-name(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)

_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_REPLICA_GROUPS_ALT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> float:
    """Total bytes of all array shapes in an HLO type string (handles
    tuples by summing members)."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveOp:
    kind: str
    out_bytes: float
    group_size: int
    line: str = ""

    @property
    def wire_bytes_per_chip(self) -> float:
        """Per-chip on-wire bytes for a ring algorithm.

        With output/buffer size B and group size N (per chip contribution):
          all-gather:        each chip sends its shard around: (N-1)/N * B
          reduce-scatter:    same traffic pattern: (N-1)/N * B
          all-reduce:        RS + AG: 2 (N-1)/N * B
          all-to-all:        each chip keeps 1/N: (N-1)/N * B
          collective-permute: B (point-to-point)
        """
        n = max(self.group_size, 1)
        frac = (n - 1) / n
        if self.kind == "all-reduce":
            return 2.0 * frac * self.out_bytes
        if self.kind == "collective-permute":
            return self.out_bytes
        return frac * self.out_bytes


@dataclass
class HLOResources:
    """Aggregated per-program resources (global, all chips)."""

    flops: float = 0.0
    bytes_accessed: float = 0.0
    transcendentals: float = 0.0
    collectives: list[CollectiveOp] = field(default_factory=list)
    collective_out_bytes: dict[str, float] = field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        """Sum of collective operand (output) bytes — the §Roofline input."""
        return sum(c.out_bytes for c in self.collectives)

    @property
    def wire_bytes_per_chip(self) -> float:
        return sum(c.wire_bytes_per_chip for c in self.collectives)

    def by_kind(self) -> dict[str, float]:
        out: dict[str, float] = defaultdict(float)
        for c in self.collectives:
            out[c.kind] += c.out_bytes
        return dict(out)


def _group_size(line: str, default: int) -> int:
    m = _REPLICA_GROUPS_ALT_RE.search(line)
    if m:
        # replica_groups=[G,S] — G groups of size S (iota format)
        return int(m.group(2))
    m = _REPLICA_GROUPS_RE.search(line)
    if m:
        body = m.group(1)
        first = body.split("}", 1)[0].strip("{} ")
        if first:
            return len([x for x in first.split(",") if x.strip() != ""])
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> list[CollectiveOp]:
    """Parse collective ops and their sizes from HLO text.

    Async pairs (``-start``/``-done``) are counted once (on the ``-start``).
    """
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # counted at -start
        m = _INSTR_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(type_str)
        if nbytes <= 0:
            continue
        gs = _group_size(line, n_devices)
        ops.append(CollectiveOp(kind=kind, out_bytes=nbytes, group_size=gs,
                                line=line.strip()[:200]))
    return ops


def analyze(compiled, lowered=None, n_devices: int | None = None) -> HLOResources:
    """Build :class:`HLOResources` from a ``jax`` compiled (and optionally
    lowered) artifact."""
    res = HLOResources()
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
    except (AttributeError, IndexError, NotImplementedError, RuntimeError,
            TypeError, ValueError):
        ca = None  # backend exposes no cost analysis
    if ca:
        res.flops = float(ca.get("flops", 0.0))
        res.bytes_accessed = float(ca.get("bytes accessed", 0.0))
        res.transcendentals = float(ca.get("transcendentals", 0.0))
    if n_devices is None:
        try:
            n_devices = len(compiled.input_shardings[0].device_set)  # best effort
        except (AttributeError, IndexError, TypeError):
            n_devices = 1
    text = None
    for src in (compiled, lowered):
        if src is None:
            continue
        try:
            text = src.as_text()
            break
        except (AttributeError, NotImplementedError, RuntimeError,
                TypeError, ValueError):
            continue
    if text:
        res.collectives = parse_collectives(text, n_devices)
        res.collective_out_bytes = res.by_kind()
    return res


def memory_analysis_dict(compiled) -> dict[str, float]:
    """Best-effort extraction of ``compiled.memory_analysis()`` fields."""
    out: dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
    except (AttributeError, NotImplementedError, RuntimeError, TypeError,
            ValueError):
        return out
    if ma is None:
        return out
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out
