"""On-disk persistence for fitted calibrations and tuned-block picks.

PR-8 made warm model evaluation cheap *within* a process: lowered-record
tables are fingerprint-keyed and invalidated by registry generation bumps.
This module closes the remaining gap — surviving a process restart — with a
small content-addressed JSON cache:

* **Keying.**  Every entry is keyed by ``(machine fingerprint, payload
  key)``.  The machine fingerprint is a *stable* sha256 over the machine's
  full recursive field content (``engine.canonical`` interns frozen
  dataclasses to process-local tokens, so it cannot name files); any
  calibration change — a re-registered ``measured_bw``, a new capacity fit
  — changes the fingerprint and the old entry simply never matches again.
  Payload keys carry the workload side (dims, spec canonical form, block
  candidates), so the composite key is the PR-8 ``(machine fingerprint,
  workload fingerprint)`` pair, made restart-durable.

* **Invalidation.**  Within a process the registry hooks (the PR-8
  generation token) clear the in-memory memo on every
  ``register_machine`` / ``register_workload``, so a published calibration
  update takes effect immediately; across processes the content hash does
  the same job with no token to persist.

* **Safety.**  Values round-trip through ``repr``/``ast.literal_eval`` —
  exact for the plain-Python ranking dicts (floats, ints, bools, tuples)
  that JSON would mangle.  Corrupted files, schema mismatches, and foreign
  fingerprints are **misses**, never crashes: the cache is an accelerator,
  not a source of truth.

The cache is opt-in: set ``REPRO_CACHE_DIR`` (or call
:func:`set_cache_dir`) to enable it.  With no directory configured every
``get`` misses and every ``put`` is a no-op, so cold-path behavior is
bit-identical to a cacheless build.
"""
from __future__ import annotations

import ast
import hashlib
import json
import os
from dataclasses import fields, is_dataclass
from pathlib import Path

from . import machine as _machine_mod
from . import workload as _workload_mod

#: Cache-file schema version; files written by a different schema are
#: treated as misses (and left in place for the version that owns them).
CACHE_SCHEMA = 1

#: Environment variable naming the cache directory (enables the cache).
CACHE_DIR_ENV = "REPRO_CACHE_DIR"

#: Observability counters for tests and the bench suite.
COUNTERS = {"hits": 0, "misses": 0, "puts": 0, "rejected": 0,
            "invalidations": 0}

_state: dict = {"dir": None, "from_env": True}
_MEMO: dict = {}


def reset_counters() -> None:
    for k in COUNTERS:
        COUNTERS[k] = 0


def cache_dir() -> Path | None:
    """The active cache directory, or ``None`` when the cache is disabled."""
    if _state["from_env"]:
        env = os.environ.get(CACHE_DIR_ENV)
        return Path(env) if env else None
    return _state["dir"]


def enabled() -> bool:
    return cache_dir() is not None


def set_cache_dir(path: "str | os.PathLike | None"):
    """Point the cache at ``path`` (``None`` disables it); returns the
    previous setting for :func:`restore_cache_dir`.  Overrides the
    ``REPRO_CACHE_DIR`` environment variable until restored."""
    prev = (_state["dir"], _state["from_env"])
    _state["dir"] = Path(path) if path is not None else None
    _state["from_env"] = False
    _MEMO.clear()
    return prev


def restore_cache_dir(prev) -> None:
    """Undo :func:`set_cache_dir` with its return value."""
    _state["dir"], _state["from_env"] = prev
    _MEMO.clear()


def clear_memo() -> None:
    _MEMO.clear()


# ---------------------------------------------------------------------------
# Stable content hashing (cross-process, unlike engine.canonical)
# ---------------------------------------------------------------------------

def stable_form(obj):
    """Reduce ``obj`` to a deterministic, ``repr``-stable literal form.

    Mirrors ``engine.canonical``'s structural semantics (recursive field
    equality) without its process-local interning, so the same content
    produces the same form — and hence the same cache file name — in every
    process.
    """
    if obj is None or type(obj) in (bool, int, float, str, bytes):
        return obj
    if is_dataclass(obj) and not isinstance(obj, type):
        return (type(obj).__module__, type(obj).__qualname__) + tuple(
            (f.name, stable_form(getattr(obj, f.name))) for f in fields(obj))
    if isinstance(obj, dict):
        return ("dict",) + tuple(
            (k, stable_form(v)) for k, v in sorted(obj.items()))
    if isinstance(obj, (tuple, list)):
        return (type(obj).__name__,) + tuple(stable_form(v) for v in obj)
    if hasattr(obj, "tolist"):                      # numpy array / scalar
        return ("array", stable_form(obj.tolist()))
    raise TypeError(f"no stable cache form for {type(obj)!r}")


def _digest(obj) -> str:
    return hashlib.sha256(repr(stable_form(obj)).encode()).hexdigest()


def machine_fingerprint(machine) -> str:
    """Stable content hash of a machine (name/alias, model, dict or path)."""
    if not isinstance(machine, _machine_mod.MachineModel):
        machine = _machine_mod.get_machine(machine)
    return _digest(machine)


# ---------------------------------------------------------------------------
# Value literalization
# ---------------------------------------------------------------------------

def _pyify(value):
    """Coerce numpy scalars/arrays inside ``value`` to plain literals so the
    stored ``repr`` survives ``ast.literal_eval``."""
    if value is None or type(value) in (bool, int, float, str, bytes):
        return value
    if isinstance(value, dict):
        return {k: _pyify(v) for k, v in value.items()}
    if isinstance(value, tuple):
        return tuple(_pyify(v) for v in value)
    if isinstance(value, list):
        return [_pyify(v) for v in value]
    if hasattr(value, "item") and not hasattr(value, "shape"):
        return value.item()
    if hasattr(value, "tolist"):
        return value.tolist()
    if isinstance(value, (bool, int, float)):       # numpy bool_/int_/float_
        return value
    try:                                            # np.float64 etc.
        return value.item()
    except AttributeError:
        raise TypeError(f"cannot cache a value of type {type(value)!r}")


# ---------------------------------------------------------------------------
# Get / put
# ---------------------------------------------------------------------------

def _entry_path(kind: str, machine_fp: str, key_digest: str) -> Path:
    d = cache_dir()
    assert d is not None
    return d / kind / f"{machine_fp[:16]}-{key_digest[:24]}.json"


def get(kind: str, key, machine=None):
    """Look up a cached value; ``None`` on any miss (including corrupted or
    foreign-schema files — those count in ``COUNTERS['rejected']``)."""
    if not enabled():
        COUNTERS["misses"] += 1
        return None
    fp = machine_fingerprint(machine) if machine is not None else "nomachine"
    kd = _digest((CACHE_SCHEMA, kind, stable_form(key)))
    memo_key = (kind, fp, kd)
    if memo_key in _MEMO:
        COUNTERS["hits"] += 1
        return _MEMO[memo_key]
    path = _entry_path(kind, fp, kd)
    try:
        doc = json.loads(path.read_text())
    except FileNotFoundError:
        COUNTERS["misses"] += 1
        return None
    except (OSError, ValueError):
        COUNTERS["rejected"] += 1
        COUNTERS["misses"] += 1
        return None
    try:
        if (not isinstance(doc, dict)
                or doc.get("schema") != CACHE_SCHEMA
                or doc.get("kind") != kind
                or doc.get("machine_fp") != fp):
            raise ValueError("cache envelope mismatch")
        value = ast.literal_eval(doc["value"])
    except (KeyError, ValueError, SyntaxError, TypeError, MemoryError):
        COUNTERS["rejected"] += 1
        COUNTERS["misses"] += 1
        return None
    _MEMO[memo_key] = value
    COUNTERS["hits"] += 1
    return value


def put(kind: str, key, value, machine=None) -> Path | None:
    """Persist ``value`` under ``(kind, machine, key)``; no-op when the
    cache is disabled.  Returns the file path written."""
    if not enabled():
        return None
    value = _pyify(value)
    fp = machine_fingerprint(machine) if machine is not None else "nomachine"
    kd = _digest((CACHE_SCHEMA, kind, stable_form(key)))
    path = _entry_path(kind, fp, kd)
    path.parent.mkdir(parents=True, exist_ok=True)
    doc = {
        "schema": CACHE_SCHEMA,
        "kind": kind,
        "machine_fp": fp,
        "machine": getattr(machine, "name", machine),
        "key": repr(stable_form(key)),
        "value": repr(value),
    }
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    os.replace(tmp, path)
    _MEMO[(kind, fp, kd)] = value
    COUNTERS["puts"] += 1
    return path


# ---------------------------------------------------------------------------
# Registry invalidation (the PR-8 generation token, in-process)
# ---------------------------------------------------------------------------

def _on_registry_change(_obj) -> None:
    _MEMO.clear()
    COUNTERS["invalidations"] += 1


_machine_mod._REGISTRY_HOOKS.append(_on_registry_change)
_workload_mod._REGISTRY_HOOKS.append(_on_registry_change)
