"""Energy-to-solution and EDP modelling (paper §III-D, Figs. 5/6).

.. deprecated::
    The energy/DVFS analysis is now a registry subsystem: power
    coefficients live on :attr:`repro.core.machine.MachineModel.power`
    (a :class:`~repro.core.machine.ChipPower`), the frequency behaviour
    on the machine's ``f_nominal_ghz`` / ``f_steps_ghz`` /
    ``bw_freq_coupled`` / ``coupling_floor`` calibration fields, and the
    batched engine is :func:`repro.core.scaling.scale_workloads` (energy
    / EDP / operating points for any workload on any machine in one
    call).  This module keeps the original single-model API as thin
    views over that engine — bit-identical to the pre-registry
    implementation (pinned in ``tests/golden_haswell_ecm.json``).

The paper shows, for bandwidth-limited kernels, that (i) race-to-idle is
not efficient, (ii) once memory bandwidth is saturated, adding cores or
clock only costs energy, and (iii) on Haswell the sustained bandwidth is
frequency independent, so the lowest frequency minimises energy.  The
power model is ``P(n, f) = P_idle + n * (p0 + p1 * f + p2 * f**2)``;
energy-to-solution is ``E = P * T`` and ``EDP = P * T^2`` over a
(cores x frequency) grid.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

from .ecm import ECMBatch, ECMModel
from .machine import ChipPower


def __getattr__(name: str):
    # PR-3 alias shim: the coefficients are per-machine calibration now
    # (``MachineModel.power``); the class lives in ``repro.core.machine``
    # and its defaults are the Haswell fit this module always used.
    if name == "PowerModel":
        warnings.warn(
            "PowerModel is deprecated and scheduled for removal; migrate "
            "to repro.core.machine.ChipPower — read a machine's fit via "
            "get_machine(name).power, or refit it from the energy grid "
            "via repro.core.calibrate.calibrate(name)",
            DeprecationWarning, stacklevel=2)
        return ChipPower
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclass(frozen=True)
class FrequencyScaledECM:
    """Frequency behaviour of one ECM model.

    .. deprecated:: use the machine calibration fields
        (``bw_freq_coupled`` / ``coupling_floor`` / ``f_nominal_ghz``)
        with :func:`repro.core.scaling.frequency_scale`, which applies
        the same rule to whole batches.

    In-core and in-cache cycles are frequency-invariant *in cycles* (they
    live in the core clock domain).  The memory term is fixed *in seconds*
    (DRAM clock domain), so in core cycles it scales with f.  On Haswell
    sustained memory bandwidth is frequency-independent
    (``bw_freq_coupled=False``); on Sandy/Ivy Bridge it degrades at low
    frequency (paper Fig. 4), modelled with a coupling floor.
    """

    ecm: ECMModel
    f_nominal_ghz: float
    bw_freq_coupled: bool = False
    coupling_floor: float = 2.0 / 3.0  # SNB/IVB: 1.2GHz gives ~2/3 bandwidth

    def at_frequency(self, f_ghz: float) -> ECMModel:
        import dataclasses

        from .scaling import frequency_scale

        batch = frequency_scale(
            ECMBatch.from_models([self.ecm]), [f_ghz],
            f_nominal_ghz=self.f_nominal_ghz,
            bw_freq_coupled=self.bw_freq_coupled,
            coupling_floor=self.coupling_floor)
        return dataclasses.replace(batch.scalar((0, 0)), name=self.ecm.name)


def energy_grid(
    fecm: FrequencyScaledECM,
    power: ChipPower,
    *,
    n_cores_max: int,
    f_ghz_list: list[float],
    total_work_units: float,
) -> dict[str, list[list[float]]]:
    """Energy-to-solution [J] and EDP [Js] over (frequency x cores).

    Thin view over :class:`repro.core.scaling.ChipScaling` (one-domain
    topology, as the original implementation assumed)."""
    import dataclasses

    import numpy as np

    from .machine import HASWELL_EP
    from .scaling import ChipScaling, frequency_scale

    batch = frequency_scale(
        ECMBatch.from_models([fecm.ecm]), f_ghz_list,
        f_nominal_ghz=fecm.f_nominal_ghz,
        bw_freq_coupled=fecm.bw_freq_coupled,
        coupling_floor=fecm.coupling_floor)
    cs = ChipScaling(
        machine=dataclasses.replace(HASWELL_EP, power=power,
                                    cores=n_cores_max),
        names=(fecm.ecm.name,),
        f_ghz=np.asarray(f_ghz_list, float),
        t_single=batch.predictions()[..., -1],
        bottleneck=batch.transfers[..., -1],
        t_ol=np.asarray([fecm.ecm.t_ol], float),
        cores_per_domain=n_cores_max, n_domains=1)
    g = cs.energy(total_work_units)
    return {k: [[float(x) for x in row] for row in g[k][0]]
            for k in ("energy_J", "edp_Js", "runtime_s")}


def best_config(grid_rows: list[list[float]], f_ghz_list: list[float]
                ) -> tuple[float, int, float]:
    """Return (f_ghz, n_cores, value) minimising a grid."""
    best = (f_ghz_list[0], 1, grid_rows[0][0])
    for fi, row in enumerate(grid_rows):
        for ni, v in enumerate(row):
            if v < best[2]:
                best = (f_ghz_list[fi], ni + 1, v)
    return best
