"""Energy-to-solution and EDP modelling (paper §III-D, Figs. 5/6).

The paper shows, for bandwidth-limited kernels, that (i) race-to-idle is not
efficient, (ii) once memory bandwidth is saturated, adding cores or clock
only costs energy, and (iii) on Haswell the sustained bandwidth is frequency
independent, so the lowest frequency minimises energy.

We reproduce the *structure* of those heat maps analytically: a simple power
model ``P(n, f) = P_idle + n * (p0 + p1 * f + p2 * f**2)`` combined with the
frequency-dependent ECM runtime prediction gives energy-to-solution
``E = P * T`` and ``EDP = P * T^2`` over a (cores x frequency) grid.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .ecm import ECMModel
from .saturation import ScalingModel


@dataclass(frozen=True)
class PowerModel:
    """Chip power as a function of active cores and frequency (GHz).

    Coefficients calibrated against the paper's reference points
    (single-core package power ~40-55 W, Haswell-vs-SNB/IVB energy ratio
    1.12-1.23x, EDP ratio 1.35-1.55x); see EXPERIMENTS.md."""

    idle_watts: float = 25.0
    static_per_core: float = 0.5       # W per active core
    dyn_lin: float = 0.3               # W per core per GHz
    dyn_quad: float = 2.2              # W per core per GHz^2

    def watts(self, n_cores: int, f_ghz: float) -> float:
        return self.idle_watts + n_cores * (
            self.static_per_core + self.dyn_lin * f_ghz + self.dyn_quad * f_ghz**2
        )


@dataclass(frozen=True)
class FrequencyScaledECM:
    """Frequency behaviour of an ECM model.

    In-core and in-cache cycles are frequency-invariant *in cycles* (they
    live in the core clock domain).  The memory term is fixed *in seconds*
    (DRAM clock domain), so in core cycles it scales with f.  On Haswell
    sustained memory bandwidth is frequency-independent
    (``bw_freq_coupled=False``); on Sandy/Ivy Bridge it degrades at low
    frequency (paper Fig. 4), modelled with a coupling floor.
    """

    ecm: ECMModel
    f_nominal_ghz: float
    bw_freq_coupled: bool = False
    coupling_floor: float = 2.0 / 3.0  # SNB/IVB: 1.2GHz gives ~2/3 bandwidth

    def at_frequency(self, f_ghz: float) -> ECMModel:
        scale = f_ghz / self.f_nominal_ghz
        mem_cy = self.ecm.transfers[-1] * scale
        if self.bw_freq_coupled:
            # bandwidth degrades towards the floor as f decreases
            rel = min(1.0, self.coupling_floor + (1 - self.coupling_floor) * scale)
            mem_cy = mem_cy / rel
        transfers = self.ecm.transfers[:-1] + (mem_cy,)
        return ECMModel(t_ol=self.ecm.t_ol, t_nol=self.ecm.t_nol,
                        transfers=transfers, levels=self.ecm.levels,
                        name=self.ecm.name)


def energy_grid(
    fecm: FrequencyScaledECM,
    power: PowerModel,
    *,
    n_cores_max: int,
    f_ghz_list: list[float],
    total_work_units: float,
) -> dict[str, list[list[float]]]:
    """Energy-to-solution [J] and EDP [Js] over (frequency x cores)."""
    energy, edp, runtime = [], [], []
    for f in f_ghz_list:
        ecm_f = fecm.at_frequency(f)
        scal = ScalingModel.from_ecm(ecm_f)
        e_row, d_row, t_row = [], [], []
        for n in range(1, n_cores_max + 1):
            perf_cy = scal.performance(n)                 # work / cycle
            t_s = total_work_units / (perf_cy * f * 1e9)  # seconds
            w = power.watts(n, f)
            e_row.append(w * t_s)
            d_row.append(w * t_s * t_s)
            t_row.append(t_s)
        energy.append(e_row)
        edp.append(d_row)
        runtime.append(t_row)
    return {"energy_J": energy, "edp_Js": edp, "runtime_s": runtime}


def best_config(grid_rows: list[list[float]], f_ghz_list: list[float]
                ) -> tuple[float, int, float]:
    """Return (f_ghz, n_cores, value) minimising a grid."""
    best = (f_ghz_list[0], 1, grid_rows[0][0])
    for fi, row in enumerate(grid_rows):
        for ni, v in enumerate(row):
            if v < best[2]:
                best = (f_ghz_list[fi], ni + 1, v)
    return best
