"""Multi-chip parallelism model: Eq. 2 over the ICI mesh.

The paper's Eq. 2 treats multicore scaling as saturation against a
shared bottleneck: compute divides over the executing units, the
bottleneck transfer time does not, and the saturation point is
``n_S = ceil(T_single / T_bottleneck)``.  :func:`repro.core.scaling.
tpu_dp_scaling` applies that treatment at chip granularity for **data
parallelism** only.  This module generalizes it to the full strategy
space of ``dist/sharding.py`` — tensor, pipeline and expert
parallelism — so one call answers "how does this config scale to N
chips and which mesh is optimal" for the whole config zoo:

* a :class:`MeshPlan` names one point in the strategy space: the
  ``(data, model, pipe, pods)`` mesh factorization, the sharding
  profile (by registry name — :func:`repro.dist.sharding.get_profile`),
  and the microbatch count;
* :func:`plan_collectives` derives the per-strategy collective terms
  **analytically** from the :mod:`repro.core.compose` layer specs (no
  compiled HLO needed): each row-parallel projection back into the
  residual stream costs a TP all-reduce, expert-parallel MoE layers
  cost a dispatch/combine all-to-all pair, vocab-sharded unembeds cost
  a per-token softmax all-reduce, FSDP costs per-microbatch weight
  all-gathers, training costs the gradient all-reduce (or
  reduce-scatter + all-gather under FSDP), and pipeline stages cost a
  boundary collective-permute.  Wire bytes per chip come from
  :class:`repro.core.hlo.CollectiveOp.wire_bytes_per_chip` (the ring
  multipliers);
* :func:`predict_plan` composes the ICI term with the per-chip
  :class:`~repro.core.compose.StepPrediction` via
  :class:`~repro.core.tpu_ecm.TPUStepECM`: the data-invariant
  collectives (gradient sync, FSDP gathers) are the Eq. 2 floor, and
  pipeline parallelism adds the classic bubble fraction
  ``(p - 1) / (m + p - 1)`` over the microbatch count;
* :func:`rank_meshes` ranks every candidate ``(mesh shape, sharding
  profile, kernel block sizes)`` jointly for a config x chip count —
  the block axis rides the ``autotune`` facade and therefore the PR-8
  ``LoweredTable`` warm path;
* :func:`dp_scaling` / :func:`plan_scaling` are the HLO-resources
  path: when compiled collectives *are* available they are used as-is,
  and the pure-DP case reproduces ``tpu_dp_scaling`` bit-identically
  (``tpu_dp_scaling`` now delegates here).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace

from .hlo import CollectiveOp
from .machine import get_machine
from .tpu_ecm import TPUStepECM

__all__ = [
    "MeshPlan",
    "PlanCollectives",
    "TRAIN_STEP_MULT",
    "dp_scaling",
    "plan_candidates",
    "plan_collectives",
    "plan_memory_bytes",
    "plan_scaling",
    "predict_plan",
    "rank_meshes",
]

#: fwd + bwd + update as a multiple of the forward pass (matches
#: ``launch/dryrun.py``'s calibration of composed-vs-simulated steps).
TRAIN_STEP_MULT = 3.0

#: bytes of optimizer state per parameter (f32 master + Adam moments),
#: mirroring ``autotune.WorkloadSpec.opt_bytes_per_param``.
OPT_BYTES_PER_PARAM = 12


def _tpu_chip(machine):
    """Fabric/chip constants (ICI links, DCN, HBM capacity, exposed
    fractions).  Registry ``MachineModel``\\ s don't carry them — fall
    back to the ``TPU_V5E`` chip record, like ``tpu_dp_scaling``."""
    if hasattr(machine, "ici_link_bytes_per_s"):
        return machine
    from .machine import TPU_V5E

    return TPU_V5E


# ---------------------------------------------------------------------------
# The strategy space
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshPlan:
    """One point in the parallelism-strategy space.

    ``data`` x ``model`` x ``pipe`` x ``pods`` chips; ``profile`` is a
    registered sharding-profile name (``dist/sharding.py``);
    ``microbatches`` feeds the pipeline bubble and the FSDP re-gather
    count.  A plain ``MeshPlan(data=n)`` is the pure-DP point that
    reproduces ``tpu_dp_scaling``.
    """

    data: int = 1
    model: int = 1
    pipe: int = 1
    pods: int = 1
    profile: str = "tp_dp"
    microbatches: int = 1

    @property
    def n_chips(self) -> int:
        return self.data * self.model * self.pipe * self.pods

    @property
    def multi_pod(self) -> bool:
        return self.pods > 1

    @property
    def data_total(self) -> int:
        """Extent of the batch split (the ``("pod", "data")`` axes)."""
        return self.data * self.pods

    @property
    def bubble_fraction(self) -> float:
        """Classic GPipe bubble: ``(p - 1) / (m + p - 1)``."""
        if self.pipe <= 1:
            return 0.0
        m = max(self.microbatches, 1)
        return (self.pipe - 1) / (m + self.pipe - 1)

    @property
    def pipeline_scale(self) -> float:
        """Per-chip time multiplier from the bubble: ``(m+p-1)/m``."""
        if self.pipe <= 1:
            return 1.0
        m = max(self.microbatches, 1)
        return (m + self.pipe - 1) / m

    @property
    def label(self) -> str:
        parts = [f"dp{self.data}"]
        if self.model > 1:
            parts.append(f"tp{self.model}")
        if self.pipe > 1:
            parts.append(f"pp{self.pipe}")
        if self.pods > 1:
            parts.insert(0, f"{self.pods}pod")
        return "x".join(parts)


def plan_candidates(n_chips: int, *, profiles=None, pipe_sizes=(1, 2, 4),
                    microbatches: int = 8, max_model: int | None = None,
                    pods: int = 1) -> list[MeshPlan]:
    """Enumerate the power-of-two ``(data, model, pipe)`` factorizations
    of ``n_chips`` crossed with the registered sharding profiles."""
    from repro.dist.sharding import get_profile, profile_names

    profs = tuple(profiles) if profiles is not None else profile_names()
    if n_chips % max(pods, 1):
        raise ValueError(f"pods={pods} does not divide n_chips={n_chips}")

    # At model == 1 the model-axis rules are moot: profiles collapse into
    # FSDP vs non-FSDP classes.  Keep one canonical name per class
    # (prefers tp_dp / tp_fsdp) so rankings don't carry duplicate rows.
    by_class: dict[bool, str] = {}
    for prof in profs:
        fsdp = get_profile(prof).rules.get("embed") == "data"
        if fsdp not in by_class:
            by_class[fsdp] = prof
        if prof in ("tp_dp", "tp_fsdp"):
            by_class[fsdp] = prof
    dp_profs = tuple(by_class[k] for k in sorted(by_class))

    per_pod = n_chips // max(pods, 1)
    out: list[MeshPlan] = []
    for pp in pipe_sizes:
        if pp < 1 or per_pod % pp:
            continue
        rem = per_pod // pp
        mdl = 1
        while mdl <= rem:
            if rem % mdl == 0 and (max_model is None or mdl <= max_model):
                micro = max(microbatches, pp) if pp > 1 else 1
                for prof in (profs if mdl > 1 else dp_profs):
                    out.append(MeshPlan(data=rem // mdl, model=mdl, pipe=pp,
                                        pods=pods, profile=prof,
                                        microbatches=micro))
            mdl *= 2
    return out


# ---------------------------------------------------------------------------
# Analytic per-strategy collective volumes (compose layer specs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PlanCollectives:
    """Per-step collectives of one plan, split by fabric and by Eq. 2
    role: ``floor`` is the subset of ``ici`` whose per-chip volume does
    **not** shrink as the data axis grows (gradient sync, FSDP weight
    gathers) — the shared-bottleneck term of Eq. 2."""

    ici: tuple[CollectiveOp, ...] = ()
    dcn: tuple[CollectiveOp, ...] = ()
    floor: tuple[CollectiveOp, ...] = ()

    @property
    def ici_wire_bytes_per_chip(self) -> float:
        return sum(c.wire_bytes_per_chip for c in self.ici)

    @property
    def dcn_wire_bytes_per_chip(self) -> float:
        return sum(c.wire_bytes_per_chip for c in self.dcn)

    @property
    def floor_bytes(self) -> float:
        """Ring fraction ``(n-1)/n -> 1``: the asymptotic per-chip wire
        bytes of the data-invariant collectives."""
        return sum((2.0 if c.kind == "all-reduce" else 1.0) * c.out_bytes
                   for c in self.floor)


#: matmul-op leaf name -> the profile rule that governs its collective.
#: Leaves listed here are row-parallel projections back into the
#: residual stream (partial sums -> all-reduce when the rule maps to
#: ``model``), except ``expert_*`` (EP all-to-all) and ``unembed``
#: (vocab-sharded softmax reduction).
_TP_GATES = {
    "out": "heads",                 # attn.out / shared.out / enc.out / dec.out
    "out_proj": "mamba_inner",      # mamba.out_proj
    "down_proj": "mamba_inner",     # mlstm.down_proj
    "down": "mlp",                  # mlp.down
    "mlp_down": "mlp",              # shared./enc./dec. mlp_down
    "ff_down": "mlp",               # slstm.ff_down
    "expert_up": "experts",         # MoE dispatch all-to-all
    "expert_down": "experts",       # MoE combine all-to-all
    "unembed": "vocab",             # softmax max+sum reduction
}


def _maps_to_model(rule) -> bool:
    if rule == "model":
        return True
    return isinstance(rule, tuple) and "model" in rule


#: op leaf name -> the profile rule that decides whether the op's
#: *compute* divides over the model axis (Amdahl term of TP: work the
#: profile leaves unsharded is replicated across the model axis).
_COMPUTE_GATES = {
    # attention family
    "qkv": "heads", "self_qkv": "heads", "cross_q": "heads",
    "cross_kv": "heads", "core": "heads", "attn": "heads",
    "self_attn": "heads", "cross_attn": "heads", "out": "heads",
    # dense MLP family
    "up": "mlp", "down": "mlp", "mlp_up": "mlp", "mlp_down": "mlp",
    "ff_up": "mlp", "ff_down": "mlp",
    # MoE experts
    "expert_up": "experts", "expert_down": "experts",
    # recurrent inner dims (Mamba / xLSTM)
    "in_proj": "mamba_inner", "out_proj": "mamba_inner",
    "scan": "mamba_inner", "up_proj": "mamba_inner",
    "down_proj": "mamba_inner", "recurrence": "mamba_inner",
    "gates": "mamba_inner", "conv": "mamba_inner", "gate": "mamba_inner",
    # head
    "unembed": "vocab",
}


def _model_coverage(pred, base: str, rules: dict) -> float:
    """Fraction of the composed per-chip cycles whose op the profile
    shards over ``model`` — the divisible part of the Amdahl split
    across the tensor-parallel axis."""
    ops = pred.phase_ops(base)
    total = sum(o.cycles for o in ops)
    if total <= 0:
        return 0.0
    covered = sum(
        o.cycles for o in ops
        if _maps_to_model(rules.get(_COMPUTE_GATES.get(
            o.name.split(".")[-1], ""))))
    return covered / total


def _matmul_params(mops) -> float:
    """Total parameter count of the matmul ops (expert weights scaled up
    to all ``n_experts`` via the router's output dim)."""
    n_experts = 1.0
    for o in mops:
        if o.kind == "matmul" and o.name.split(".")[-1] == "router":
            n_experts = max(float(o.workload.n), 1.0)
    total = 0.0
    for o in mops:
        if o.kind != "matmul":
            continue
        w = o.workload
        scale = n_experts if o.name.split(".")[-1].startswith("expert") else 1.0
        total += float(w.n) * float(w.k) * o.count * scale
    return total


def _d_model(cfg, mops) -> float:
    d = getattr(cfg, "d_model", None)
    if d:
        return float(d)
    for o in mops:
        if o.kind == "matmul" and o.name.split(".")[-1] in ("out", "down"):
            return float(o.workload.n)
    return 0.0


def plan_collectives(config, plan: MeshPlan, *, batch: int = 8,
                     seq_len: int = 2048, context: int | None = None,
                     phase: str = "train",
                     dtype_bytes: int = 2) -> PlanCollectives:
    """Analytic per-layer collective volumes of ``config`` under ``plan``,
    derived from the :mod:`repro.core.compose` op walk (the no-HLO path).

    ``phase``: ``"train"`` (fwd+bwd activation collectives, gradient
    sync), ``"prefill"`` or ``"decode"`` (inference, forward only).
    Activation volumes are per data-shard: the global token count splits
    over the ``("pod", "data")`` axes.
    """
    from .compose import _resolve_config, model_ops
    from repro.dist.sharding import get_profile

    _, cfg = _resolve_config(config)
    base = "decode" if phase == "decode" else "prefill"
    ctx = context if context is not None else seq_len
    mops = model_ops(cfg, base, batch=batch, seq_len=seq_len, context=ctx)
    prof = get_profile(plan.profile, multi_pod=plan.multi_pod)
    rules = prof.rules
    train = phase == "train"
    act_mult = 2.0 if train else 1.0        # fwd + grad-of-activation
    dt = max(plan.data_total, 1)
    tp = plan.model

    ici: list[CollectiveOp] = []
    dcn: list[CollectiveOp] = []
    floor: list[CollectiveOp] = []

    # -- tensor / expert / vocab parallelism (activation collectives) --
    if tp > 1:
        for o in mops:
            if o.kind != "matmul":
                continue
            gate = _TP_GATES.get(o.name.split(".")[-1])
            if gate is None or not _maps_to_model(rules.get(gate)):
                continue
            w = o.workload
            if gate == "experts":
                # dispatch moves the routed inputs, combine the outputs
                leaf = o.name.split(".")[-1]
                elems = (float(w.m) * float(w.k) if leaf == "expert_up"
                         else o.out_elems)
                nbytes = elems * o.elem_bytes * o.count / dt
                ici.append(CollectiveOp("all-to-all", nbytes * act_mult, tp))
            elif gate == "vocab":
                # shard-wise softmax: per-token max + sum (f32 scalars)
                nbytes = 2.0 * float(w.m) * 4.0 * o.count / dt
                ici.append(CollectiveOp("all-reduce", nbytes * act_mult, tp))
            else:
                nbytes = o.out_elems * o.elem_bytes * o.count / dt
                ici.append(CollectiveOp("all-reduce", nbytes * act_mult, tp))

    # -- gradient sync and FSDP (weight collectives) -------------------
    fsdp = rules.get("embed") == "data"
    params = _matmul_params(mops)
    shard = 4.0 * params / (tp * plan.pipe)     # f32 grads, per model shard
    if train:
        if plan.data > 1:
            if fsdp:
                grads = (CollectiveOp("reduce-scatter", shard, plan.data),
                         CollectiveOp("all-gather", shard, plan.data))
            else:
                grads = (CollectiveOp("all-reduce", shard, plan.data),)
            ici.extend(grads)
            floor.extend(grads)
        if plan.pods > 1:
            dcn.append(CollectiveOp(
                "all-reduce", shard / (plan.data if fsdp else 1), plan.pods))
    if fsdp and plan.data > 1:
        # every microbatch re-gathers the data-sharded weights
        w_bytes = (dtype_bytes * params / (tp * plan.pipe)
                   * max(plan.microbatches, 1))
        gather = CollectiveOp("all-gather", w_bytes, plan.data)
        ici.append(gather)
        floor.append(gather)

    # -- pipeline boundary permutes ------------------------------------
    if plan.pipe > 1:
        tokens = float(batch) if base == "decode" else float(batch * seq_len)
        act_bytes = tokens * _d_model(cfg, mops) * 4.0 / dt
        ici.append(CollectiveOp("collective-permute",
                                act_bytes * act_mult, plan.pipe))

    return PlanCollectives(ici=tuple(ici), dcn=tuple(dcn),
                           floor=tuple(floor))


def plan_memory_bytes(config, plan: MeshPlan, *, phase: str = "train",
                      batch: int = 8, seq_len: int = 2048,
                      context: int | None = None,
                      dtype_bytes: int = 2) -> float:
    """Coarse per-chip HBM footprint of the model state under ``plan``:
    weights plus (training) optimizer state, divided over the axes the
    profile actually shards them on.  Activations/KV are not modeled."""
    from .compose import _resolve_config, model_ops
    from repro.dist.sharding import get_profile

    _, cfg = _resolve_config(config)
    base = "decode" if phase == "decode" else "prefill"
    ctx = context if context is not None else seq_len
    mops = model_ops(cfg, base, batch=batch, seq_len=seq_len, context=ctx)
    prof = get_profile(plan.profile, multi_pod=plan.multi_pod)
    params = _matmul_params(mops)
    per_param = dtype_bytes + (OPT_BYTES_PER_PARAM if phase == "train" else 0)
    denom = plan.model * plan.pipe
    if prof.rules.get("embed") == "data":        # FSDP: sharded over data too
        denom *= max(plan.data_total, 1)
    return params * per_param / denom


# ---------------------------------------------------------------------------
# Eq. 2 composition: per-chip StepPrediction + ICI floor + bubble
# ---------------------------------------------------------------------------


def predict_plan(config, plan: MeshPlan, machine="tpu-v5e", *,
                 batch: int = 8, seq_len: int = 2048,
                 context: int | None = None, phase: str = "train",
                 sustained_bw=None, dtype_bytes: int = 2,
                 step_prediction=None, collectives=None) -> dict:
    """One plan's predicted step: the per-chip composed
    :class:`~repro.core.compose.StepPrediction` (ideal ``1/n`` split,
    scaled by the pipeline bubble) plus the plan's ICI/DCN collective
    terms, composed under the machine's exposed-ICI rule via
    :class:`~repro.core.tpu_ecm.TPUStepECM`.

    ``step_prediction`` / ``collectives`` accept precomputed values so a
    sweep over many plans composes the model once per config.
    """
    from .compose import predict_step

    m = get_machine(machine)
    chip = _tpu_chip(machine)
    base = "decode" if phase == "decode" else "prefill"
    mult = TRAIN_STEP_MULT if phase == "train" else 1.0
    pred = step_prediction
    if pred is None:
        pred = predict_step(config, m, batch=batch, seq_len=seq_len,
                            context=context, phases=(base,),
                            sustained_bw=sustained_bw)
    from repro.dist.sharding import get_profile

    t_single = pred.seconds(base) * mult
    n = plan.n_chips
    rules = get_profile(plan.profile, multi_pod=plan.multi_pod).rules
    # Amdahl over the model axis: only profile-sharded compute divides
    # by ``model``; the rest is replicated across it.
    cov = _model_coverage(pred, base, rules) if plan.model > 1 else 1.0
    eff = cov / plan.model + (1.0 - cov)
    t_chip = (t_single * eff / (plan.data_total * plan.pipe)
              * plan.pipeline_scale)

    colls = collectives
    if colls is None:
        colls = plan_collectives(config, plan, batch=batch, seq_len=seq_len,
                                 context=context, phase=phase,
                                 dtype_bytes=dtype_bytes)
    ici_bw = chip.ici_link_bytes_per_s * chip.ici_links_per_chip
    t_ici = colls.ici_wire_bytes_per_chip / ici_bw
    t_dcn = colls.dcn_wire_bytes_per_chip / chip.dcn_bytes_per_s
    exposed = chip.exposed_ici_fraction
    step = TPUStepECM(name=f"{plan.label}/{plan.profile}", t_comp=t_chip,
                      t_hbm=0.0, t_ici=t_ici, t_dcn=t_dcn,
                      exposed_ici_fraction=exposed,
                      exposed_hbm_fraction=chip.exposed_hbm_fraction)

    # Eq. 2 over ICI: only the data-invariant collectives floor out
    t_floor = colls.floor_bytes / ici_bw
    n_sat = (None if t_floor <= 0 or exposed <= 0
             else max(1, math.ceil(t_single / (exposed * t_floor))))

    hbm = plan_memory_bytes(config, plan, phase=phase, batch=batch,
                            seq_len=seq_len, context=context,
                            dtype_bytes=dtype_bytes)
    t_step = step.t_ecm
    return {
        "mesh": plan.label,
        "profile": plan.profile,
        "data": plan.data, "model": plan.model, "pipe": plan.pipe,
        "pods": plan.pods, "microbatches": plan.microbatches,
        "n_chips": n,
        "t_step_us": t_step * 1e6,
        "t_chip_us": t_chip * 1e6,
        "t_ici_us": t_ici * 1e6,
        "t_dcn_us": t_dcn * 1e6,
        "bubble_fraction": plan.bubble_fraction,
        "model_coverage": cov,
        "t_ici_floor_us": t_floor * 1e6,
        "n_saturation": n_sat,
        "parallel_efficiency": (t_single / (t_step * n)) if t_step > 0 else 0.0,
        "hbm_bytes_per_chip": hbm,
        "fits_hbm": bool(hbm <= getattr(chip, "hbm_bytes", float("inf"))),
    }


def rank_meshes(config, n_chips: int, machine="tpu-v5e", *,
                batch: int = 8, seq_len: int = 2048,
                context: int | None = None, phase: str = "train",
                profiles=None, pipe_sizes=(1, 2, 4), microbatches: int = 8,
                max_model: int | None = None, pods: int = 1,
                include_blocks: bool = True, top: int | None = None,
                sustained_bw=None, dtype_bytes: int = 2) -> list[dict]:
    """Rank every ``(mesh shape, sharding profile, kernel block sizes)``
    candidate jointly for one config x chip count.

    The composed step model is built **once** per config and reused
    across plans; the attention-block axis rides the ``autotune`` facade
    (hence the PR-8 ``LoweredTable``), so a full (config x mesh x
    profile) sweep stays in the warm-path regime.  HBM-overflowing plans
    rank after fitting ones; ties break on the mesh label for
    deterministic golden pins.
    """
    from .compose import _resolve_config, predict_step

    m = get_machine(machine)
    base = "decode" if phase == "decode" else "prefill"
    pred = predict_step(config, m, batch=batch, seq_len=seq_len,
                        context=context, phases=(base,),
                        sustained_bw=sustained_bw)

    block = None
    if include_blocks:
        _, cfg = _resolve_config(config)
        dh = getattr(cfg, "head_dim_", None) or getattr(cfg, "head_dim", None)
        if dh:
            from .autotune import rank as _rank
            sq = 1 if base == "decode" else seq_len
            skv = (context or seq_len) if base == "decode" else seq_len
            ranked = _rank((sq, skv, int(dh)), m, objective="attention",
                           causal=base != "decode")
            block = ranked[0]["block"] if ranked else None

    rows = []
    for plan in plan_candidates(n_chips, profiles=profiles,
                                pipe_sizes=pipe_sizes,
                                microbatches=microbatches,
                                max_model=max_model, pods=pods):
        colls = plan_collectives(config, plan, batch=batch, seq_len=seq_len,
                                 context=context, phase=phase,
                                 dtype_bytes=dtype_bytes)
        row = predict_plan(config, plan, m, batch=batch, seq_len=seq_len,
                           context=context, phase=phase,
                           sustained_bw=sustained_bw, dtype_bytes=dtype_bytes,
                           step_prediction=pred, collectives=colls)
        row["block"] = block
        rows.append(row)
    rows.sort(key=lambda r: (not r["fits_hbm"], r["t_step_us"],
                             r["mesh"], r["profile"]))
    return rows[:top] if top else rows


# ---------------------------------------------------------------------------
# HLO-resources path (compiled collectives) + the bit-identical DP case
# ---------------------------------------------------------------------------


def plan_scaling(resources, plans, *, machine=None,
                 dtype_peak: float | None = None,
                 exposed_ici_fraction: float | None = None) -> dict:
    """Generalized ``tpu_dp_scaling`` over explicit :class:`MeshPlan`\\ s,
    driven by compiled-program resources (the HLO path).

    Compute and HBM divide over ``plan.n_chips`` (scaled by the pipeline
    bubble); the program's collectives are grouped over each plan's data
    axis (their ring wire bytes approach the Eq. 2 floor); saturation is
    ``n_S = ceil(T_single / T_ICI_floor)``.  For pure-DP plans the
    arithmetic — and therefore every returned float — is identical to
    the historical ``tpu_dp_scaling``.
    """
    from .machine import TPU_V5E

    m = machine or TPU_V5E
    peak = dtype_peak or m.peak_bf16_flops
    exposed = (m.exposed_ici_fraction if exposed_ici_fraction is None
               else exposed_ici_fraction)
    colls = list(getattr(resources, "collectives", ()))
    ici_bw = m.ici_link_bytes_per_s * m.ici_links_per_chip

    def t_ici(n: int) -> float:
        return sum(replace(c, group_size=n).wire_bytes_per_chip
                   for c in colls) / ici_bw

    # the floor: ring fraction (n-1)/n -> 1
    floor_bytes = sum((2.0 if c.kind == "all-reduce" else 1.0) * c.out_bytes
                      for c in colls)
    t_floor = floor_bytes / ici_bw

    plans = list(plans)
    mesh, chips, t_comp, t_hbm, t_coll, t_step, bubble = \
        [], [], [], [], [], [], []
    for p in plans:
        n = p.n_chips
        scale = p.pipeline_scale
        step = TPUStepECM(
            t_comp=resources.flops / (n * peak) * scale,
            t_hbm=resources.bytes_accessed / (n * m.hbm_bytes_per_s) * scale,
            t_ici=t_ici(p.data), t_dcn=0.0,
            exposed_ici_fraction=exposed, name=p.label)
        mesh.append(p.label)
        chips.append(int(n))
        bubble.append(p.bubble_fraction)
        t_comp.append(step.t_comp)
        t_hbm.append(step.t_hbm)
        t_coll.append(step.t_ici)
        t_step.append(step.t_ecm)
    t1 = t_step[0] * chips[0]          # single-chip step time equivalent
    # no collectives, or a fully-hidden ICI term (exposed fraction 0):
    # nothing ever saturates — the chip-level core-bound case
    n_sat = (None if t_floor <= 0 or exposed <= 0
             else max(1, math.ceil(t1 / (exposed * t_floor))))
    return {
        "mesh": mesh,
        "chips": chips,
        "t_comp_us": [t * 1e6 for t in t_comp],
        "t_hbm_us": [t * 1e6 for t in t_hbm],
        "t_ici_us": [t * 1e6 for t in t_coll],
        "t_step_us": [t * 1e6 for t in t_step],
        "speedup": [t_step[0] / t for t in t_step],
        "parallel_efficiency": [t_step[0] / (t * n) * chips[0]
                                for n, t in zip(chips, t_step)],
        "bubble_fraction": bubble,
        "t_ici_floor_us": t_floor * 1e6,
        "n_saturation": n_sat,
    }


_DP_KEYS = ("chips", "t_comp_us", "t_hbm_us", "t_ici_us", "t_step_us",
            "speedup", "parallel_efficiency", "t_ici_floor_us",
            "n_saturation")


def dp_scaling(resources, chip_counts=(1, 2, 4, 8, 16, 32, 64, 128, 256), *,
               machine=None, dtype_peak: float | None = None,
               exposed_ici_fraction: float | None = None) -> dict:
    """The pure data-parallel special case of :func:`plan_scaling`, with
    the historical ``tpu_dp_scaling`` return shape (and bit-identical
    values — ``repro.core.scaling.tpu_dp_scaling`` delegates here)."""
    full = plan_scaling(resources,
                        [MeshPlan(data=int(n)) for n in chip_counts],
                        machine=machine, dtype_peak=dtype_peak,
                        exposed_ici_fraction=exposed_ici_fraction)
    return {k: full[k] for k in _DP_KEYS}
