"""Layer-condition analysis and ECM construction for stencil kernels.

The paper validates the ECM model on streaming kernels whose cache-line
traffic is a *constant* per unit of work (Table I).  Stencils break that
assumption: the companion work "Quantifying performance bottlenecks of
stencil computations using the Execution-Cache-Memory model" (Stengel,
Treibig, Hager & Wellein, arXiv:1410.5010, §III) shows that the number of
load streams that miss a given cache level depends on whether that level
can hold the *reuse set* of the stencil — the "layer condition" (LC).

For the 2D 5-point Jacobi ``b[j,i] = c0*a[j,i] + c1*(a[j-1,i] + a[j+1,i]
+ a[j,i-1] + a[j,i+1])`` the kernel touches ``2r+1 = 3`` consecutive rows
of ``a`` per sweep position.  A cache of capacity ``C`` holds them all iff

    (2r+1) * W * elem_bytes  <=  C / safety        (safety = 2)

where ``W`` is the width of the inner (contiguous) loop — the *problem*
width, or the *block* width under spatial blocking.  If the condition
holds, only the leading row of ``a`` misses: 1 load stream per cache line
of work, and with the write-allocate + write-back pair of ``b`` the edge
below carries 3 CLs/CL (24 B/LUP in the reference's units).  If it is
violated, all ``2r+1`` rows miss: 5 CLs/CL (40 B/LUP) — the §III
hand-derived values that ``tests/test_layer_condition.py`` pins.

For the 3D 7-point stencil the hierarchy has two conditions (misses per
CL of work in {1, 3, 5} + the store pair):

* *layer* condition — ``2r+1`` layers fit: only the leading stream misses;
* *row* condition — the ``4r+1`` in-flight rows fit: one row stream per
  layer misses (``2r+1``);
* neither — every distinct row stream misses (``4r+1``).

:func:`stencil_ecm` turns the per-level miss counts into a full
:class:`~repro.core.ecm.ECMModel` exactly the way
``StreamKernelSpec.ecm`` does for streaming kernels (§IV-C recipe: port
model for T_OL/T_nOL, per-level bandwidths for the transfer terms);
:func:`stencil_block_batch` evaluates whole candidate grids (block widths
x problem widths) in one :class:`~repro.core.ecm.ECMBatch`.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

from .ecm import ECMBatch, ECMModel
from .machine import HASWELL_EP, MachineModel

#: Rule-of-thumb safety factor of the LC literature: require the reuse set
#: to fit in *half* the cache (associativity conflicts, other data).
LC_SAFETY = 2.0


@dataclass(frozen=True)
class LayerCondition:
    """One reuse condition: if ``nbytes <= capacity / safety`` then only
    ``misses_if_held`` load streams miss in that cache level."""

    name: str
    nbytes: float
    misses_if_held: int

    def holds(self, capacity_bytes: float, safety: float = LC_SAFETY) -> bool:
        return self.nbytes * safety <= capacity_bytes


@dataclass(frozen=True)
class StencilSpec:
    """A Jacobi-style star stencil of radius ``radius`` in ``dim`` dims.

    The spec plays the role :class:`~repro.core.kernel_spec.StreamKernelSpec`
    plays for streaming kernels, except the stream counts are functions of
    the layer conditions instead of constants.  uop counts are per cache
    line of work (one CL of updates = ``line_bytes/elem_bytes`` LUPs) with
    AVX registers, mirroring Table I's accounting.

    The store side is LC-independent: the output array is streamed, so one
    write-allocate (RFO) and one write-back stream cross every edge.
    """

    name: str
    dim: int                    # 2 or 3
    radius: int = 1
    elem_bytes: int = 8         # double precision
    write_allocate: bool = True
    flops_per_elem: int = 6
    updates_per_elem: int = 1
    # micro-op mix per CL of work (AVX: one 64 B line = 2 vector iterations)
    uop_loads: int = 8
    uop_stores: int = 2
    uop_fma: int = 0
    uop_mul: int = 4
    uop_add: int = 6

    def __post_init__(self) -> None:
        if self.dim not in (2, 3):
            raise ValueError(f"dim must be 2 or 3, got {self.dim}")
        if self.radius < 1:
            raise ValueError("radius must be >= 1")

    # ------------------------------------------------------------------
    # Stream structure
    # ------------------------------------------------------------------
    @property
    def row_streams(self) -> int:
        """Distinct rows of the input touched per sweep position: ``2r+1``
        in 2D, ``4r+1`` in 3D (``2r+1`` rows in the centre layer plus one
        per outer layer)."""
        return (2 * self.radius + 1 if self.dim == 2
                else 4 * self.radius + 1)

    @property
    def rfo_streams(self) -> int:
        return 1 if self.write_allocate else 0

    @property
    def wb_streams(self) -> int:
        return 1

    def conditions(self, widths: tuple[int, ...],
                   block: tuple[int, ...] | None = None
                   ) -> tuple[LayerCondition, ...]:
        """Reuse conditions, strongest (fewest misses) first.

        ``widths`` are the inner problem dimensions, outermost sweep dim
        excluded: ``(W,)`` for 2D arrays of shape (H, W), ``(H, W)`` for 3D
        arrays of shape (D, H, W).  ``block`` optionally caps each width
        (spatial blocking tiles the inner loops, shrinking the reuse set).
        """
        if len(widths) != self.dim - 1:
            raise ValueError(
                f"{self.dim}D stencil needs {self.dim - 1} inner widths, "
                f"got {widths!r}")
        w = [min(x, b) for x, b in zip(widths, block)] if block else \
            list(widths)
        r, eb = self.radius, self.elem_bytes
        if self.dim == 2:
            return (LayerCondition(
                "rows", (2 * r + 1) * w[0] * eb, misses_if_held=1),)
        return (
            LayerCondition(
                "layers", (2 * r + 1) * w[0] * w[1] * eb, misses_if_held=1),
            LayerCondition(
                "rows", (4 * r + 1) * w[1] * eb, misses_if_held=2 * r + 1),
        )

    def load_misses(self, capacity_bytes: float, widths: tuple[int, ...],
                    *, block: tuple[int, ...] | None = None,
                    safety: float = LC_SAFETY) -> int:
        """Input load streams missing a cache of ``capacity_bytes``."""
        for cond in self.conditions(widths, block):
            if cond.holds(capacity_bytes, safety):
                return cond.misses_if_held
        return self.row_streams

    def misses_per_level(self, widths: tuple[int, ...],
                         capacities: tuple[int, ...] | None = None,
                         *, block: tuple[int, ...] | None = None,
                         safety: float = LC_SAFETY) -> tuple[int, ...]:
        """Load-stream misses per cache level (L1, L2, ...): the inward
        load traffic on the edge *below* each level.  Defaults to the
        Haswell-EP capacities; pass ``machine.capacities`` for any other
        registry machine."""
        caps = capacities if capacities is not None else HASWELL_EP.capacities
        return tuple(self.load_misses(c, widths, block=block, safety=safety)
                     for c in caps)

    def elems_per_line(self, line_bytes: int) -> int:
        return line_bytes // self.elem_bytes

    # ------------------------------------------------------------------
    # §IV-C model construction, LC-aware
    # ------------------------------------------------------------------
    def ecm(self, machine: MachineModel, sustained_bw: float, *,
            widths: tuple[int, ...],
            capacities: tuple[int, ...] | None = None,
            block: tuple[int, ...] | None = None,
            safety: float = LC_SAFETY,
            optimized_agu: bool = False) -> ECMModel:
        """Build the ECM model for one (problem size, blocking) point.

        Identical recipe to ``StreamKernelSpec.ecm`` except the inward load
        stream count on each edge comes from the layer condition of the
        cache level above it (evaluated against the machine's capacities
        unless overridden).  Scalar view of
        :func:`stencil_batch_from_misses`."""
        from .workload import StencilWorkload, workload_ecm

        return workload_ecm(
            StencilWorkload(self, widths=tuple(widths), block=block,
                            safety=safety, capacities=capacities),
            machine, sustained_bw=sustained_bw, optimized_agu=optimized_agu)


# ---------------------------------------------------------------------------
# Vectorized evaluation (ECMBatch over candidate grids)
# ---------------------------------------------------------------------------


def misses_batch(spec: StencilSpec, widths_arr: np.ndarray,
                 capacities: tuple[int, ...] | None = None,
                 *, safety: float = LC_SAFETY) -> np.ndarray:
    """Load-miss table for a batch of effective inner widths: ``(B, L)``.

    ``widths_arr`` has shape ``(B, dim-1)`` (or ``(B,)`` for 2D) and holds
    the *effective* widths (problem width already capped by any blocking).
    One set of array comparisons regardless of B — the LC analogue of
    :func:`~repro.core.kernel_spec.benchmark_batch`.
    """
    w = np.asarray(widths_arr, float)
    if w.ndim == 1:
        w = w[:, None]
    if w.shape[-1] != spec.dim - 1:
        raise ValueError(
            f"widths_arr last dim must be {spec.dim - 1}, got {w.shape}")
    r, eb = spec.radius, spec.elem_bytes
    caps = np.asarray(capacities if capacities is not None
                      else HASWELL_EP.capacities, float)     # (L,)
    if spec.dim == 2:
        nbytes = [(2 * r + 1) * w[:, 0] * eb]                # one condition
        held_misses = [1]
    else:
        nbytes = [(2 * r + 1) * w[:, 0] * w[:, 1] * eb,
                  (4 * r + 1) * w[:, 1] * eb]
        held_misses = [1, 2 * r + 1]
    out = np.full((w.shape[0], caps.size), spec.row_streams, float)
    # weakest condition first so stronger ones overwrite
    for nb, m in list(zip(nbytes, held_misses))[::-1]:
        holds = nb[:, None] * safety <= caps[None, :]        # (B, L)
        out = np.where(holds, m, out)
    return out


def stencil_batch_from_misses(
    spec: StencilSpec,
    misses: np.ndarray,
    *,
    machine: MachineModel = HASWELL_EP,
    sustained_bw: float,
    names: tuple[str, ...] = (),
    optimized_agu: bool = False,
) -> ECMBatch:
    """The single light-speed §IV-C construction every stencil path uses.

    ``misses`` is a ``(B, L)`` per-level load-miss table (from
    :func:`misses_batch` or :meth:`StencilSpec.misses_per_level`); the
    store side adds the LC-independent write-allocate + write-back pair.
    :meth:`StencilSpec.ecm`, :func:`stencil_block_batch` and the simulator
    paths in ``repro.simcache`` are all views of the unified engine
    (``repro.core.workload``), so the edge accounting lives in exactly
    one place.
    """
    from .workload import StencilWorkload, lower

    misses = np.atleast_2d(np.asarray(misses, float))
    return lower(
        StencilWorkload(spec, misses=misses,
                        names=names or (spec.name,) * misses.shape[0]),
        machine, sustained_bw=sustained_bw,
        optimized_agu=optimized_agu).batch


def stencil_block_batch(
    spec: StencilSpec,
    widths: tuple[int, ...],
    blocks: "list[tuple[int, ...]] | np.ndarray | list[int]",
    *,
    machine: MachineModel = HASWELL_EP,
    sustained_bw: float,
    capacities: tuple[int, ...] | None = None,
    safety: float = LC_SAFETY,
    optimized_agu: bool = False,
) -> ECMBatch:
    """One :class:`ECMBatch` over spatial-blocking candidates.

    ``blocks`` is a sequence of block-width tuples (ints accepted for 2D).
    Agrees element-for-element with :meth:`StencilSpec.ecm` (both are
    views of :func:`stencil_batch_from_misses`) but builds the whole
    candidate set in a handful of array ops so the autotuner can rank
    thousands of blockings per Python call.
    """
    blk = np.asarray([(b,) if np.ndim(b) == 0 else tuple(b)
                      for b in blocks], float)               # (B, dim-1)
    eff = np.minimum(blk, np.asarray(widths, float)[None, :])
    caps = capacities if capacities is not None else machine.capacities
    misses = misses_batch(spec, eff, caps, safety=safety)    # (B, L)
    return stencil_batch_from_misses(
        spec, misses, machine=machine, sustained_bw=sustained_bw,
        names=tuple(f"{spec.name}@blk{tuple(int(x) for x in b)}"
                    for b in blk),
        optimized_agu=optimized_agu)


# ---------------------------------------------------------------------------
# The stencil registry (the Table-I analogue for this kernel family)
# ---------------------------------------------------------------------------

# 2D 5-point star, r=1: per AVX iteration 4 neighbour loads + 1 centre load
# covered by the neighbour reuse (we count 4), 1 store; 2 iterations per CL.
# flops/LUP: 3 adds (neighbour sums) + 1 add + 2 muls (c0*c + c1*s) = 6.
JACOBI2D = StencilSpec(
    name="jacobi2d", dim=2, radius=1,
    flops_per_elem=6,
    uop_loads=8, uop_stores=2, uop_mul=4, uop_add=6,
)

# 3D 7-point star, r=1: 6 neighbour loads + centre per AVX iteration (the
# centre row covers a[j][i+-1] spatially) -> 6 loads counted, 1 store.
# flops/LUP: 5 adds + 1 add + 2 muls = 8.
JACOBI3D = StencilSpec(
    name="jacobi3d", dim=3, radius=1,
    flops_per_elem=8,
    uop_loads=12, uop_stores=2, uop_mul=4, uop_add=10,
)

STENCILS: dict[str, StencilSpec] = {s.name: s for s in (JACOBI2D, JACOBI3D)}


def __getattr__(name: str):
    # PR-3 alias shims: both tables live on the machine registry now
    # (capacities and measured_bw with the ``_stencil`` family fallback).
    if name == "HASWELL_CAPACITIES":
        warnings.warn(
            "HASWELL_CAPACITIES is deprecated and scheduled for removal; "
            "migrate to get_machine('haswell-ep').capacities (the L3 "
            "entry is the Cluster-on-Die affinity-domain slice)",
            DeprecationWarning, stacklevel=2)
        return HASWELL_EP.capacities
    if name == "STENCIL_MEASURED_BW":
        warnings.warn(
            "STENCIL_MEASURED_BW is deprecated and scheduled for removal; "
            "migrate to get_machine('haswell-ep').measured_bw — e.g. "
            "HASWELL_EP.sustained_bw('jacobi2d', '_stencil') for the "
            "family-fallback lookup",
            DeprecationWarning, stacklevel=2)
        return {k: HASWELL_EP.measured_bw[k]
                for k in ("jacobi2d", "jacobi3d")}
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def stencil_ecm(name_or_spec: "str | StencilSpec", *,
                widths: tuple[int, ...],
                machine: MachineModel = HASWELL_EP,
                sustained_bw: float | None = None,
                capacities: tuple[int, ...] | None = None,
                block: tuple[int, ...] | None = None,
                safety: float = LC_SAFETY,
                optimized_agu: bool = False) -> ECMModel:
    """LC-aware ECM model for a registered (or custom) stencil spec, on
    any machine in the registry (bandwidth/capacities default to the
    machine's calibration data)."""
    spec = (name_or_spec if isinstance(name_or_spec, StencilSpec)
            else STENCILS[name_or_spec])
    bw = sustained_bw or machine.sustained_bw(spec.name, "_stencil",
                                              default=24.1e9)
    return spec.ecm(machine, bw, widths=widths, capacities=capacities,
                    block=block, safety=safety, optimized_agu=optimized_agu)
