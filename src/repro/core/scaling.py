"""Registry-integrated chip scaling and energy: Eq. 2 + §III-D as one
batched engine over the workload/machine registry.

The paper's chip-level results — the Eq. 2 saturation point
``n_S = ceil(T_ECM^mem / T_L3Mem)`` (§IV-B, Fig. 10) and the
energy-to-solution / EDP grids over (cores x frequency) (§III-D,
Figs. 5/6) — were historically computed from one hand-built
:class:`~repro.core.ecm.ECMModel` with Haswell-only constants
(``core.saturation`` / ``core.energy``).  This module promotes both to
first-class registry subsystems:

* :func:`scale_workloads` builds a :class:`ChipScaling` from **any**
  workloads on **any** registered machine — the lowered record supplies
  the light-speed times and the shared-bottleneck (memory-edge) term,
  the machine supplies the domain topology (CoD / SNC:
  ``cores_per_domain`` / ``n_domains``), the per-domain ``measured_bw``
  calibration, the DVFS grid and the :class:`~repro.core.machine.
  ChipPower` coefficients;
* every quantity is **vectorized** over (workloads x frequencies x
  cores) on top of :class:`~repro.core.ecm.ECMBatch` — one array pass
  for a whole (registry x DVFS x chip) surface, and one more machine in
  the outer dict for the cross-zoo tables;
* frequency behaviour follows the old ``FrequencyScaledECM`` rule
  exactly (in-core/in-cache cycles frequency-invariant, the memory term
  fixed in seconds so it scales with ``f`` in cycles, with the SNB/IVB
  bandwidth-coupling floor), but the knobs now come from per-machine
  calibration (``bw_freq_coupled`` / ``coupling_floor`` /
  ``f_steps_ghz``);
* **core-bound workloads** never saturate within the machine: either
  the bottleneck term is zero (cache-resident compute, pre-lowered
  records — no division by a zero transfer term anywhere) or the Eq. 2
  point lies beyond the domain's core count (in-core time dominates).
  They report ``n_S = cores`` and scale linearly to the full chip;
* :func:`tpu_dp_scaling` is the Eq. 2 analogue at chip granularity: the
  ICI collective traffic extracted by :mod:`repro.core.hlo` is the
  shared-bottleneck term of multi-chip data-parallel scaling (compute
  and HBM divide with the fleet, the ring-collective wire bytes
  approach a floor — exactly the role of ``T_L3Mem`` in Eq. 2).

The Haswell numbers of the old modules are reproduced **bit-identically**
through this path (pinned in ``tests/golden_haswell_ecm.json`` via
``tests/test_scaling.py``); ``core.energy`` and the scalar
``core.saturation`` API remain as thin / deprecated views.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .ecm import ECMBatch
from .machine import MACHINES, MachineModel, get_machine

__all__ = [
    "ChipScaling",
    "fill_domains",
    "frequency_scale",
    "scale_model",
    "scale_workloads",
    "saturation_table",
    "scaling_zoo",
    "tpu_dp_scaling",
]


# ---------------------------------------------------------------------------
# Building blocks (shared with repro.simcache and repro.core.energy)
# ---------------------------------------------------------------------------


def frequency_scale(batch: ECMBatch, f_ghz, *, f_nominal_ghz: float,
                    bw_freq_coupled: bool = False,
                    coupling_floor: float = 2.0 / 3.0) -> ECMBatch:
    """Vectorized DVFS view of a batch: appends a frequency axis.

    In-core and in-cache cycle counts live in the core clock domain and
    are frequency-invariant *in cycles*; the memory edge is fixed *in
    seconds* (DRAM clock domain), so in core cycles it scales with
    ``f / f_nominal``.  On bandwidth-coupled machines (SNB/IVB, paper
    Fig. 4) the sustained bandwidth additionally degrades towards
    ``coupling_floor`` as the frequency drops.  Returns an
    :class:`ECMBatch` with batch shape ``B + (F,)``.
    """
    f = np.atleast_1d(np.asarray(f_ghz, float))                  # (F,)
    scale = f / f_nominal_ghz
    mem_cy = batch.transfers[..., -1, None] * scale              # B + (F,)
    if bw_freq_coupled:
        rel = np.minimum(1.0, coupling_floor
                         + (1 - coupling_floor) * scale)
        mem_cy = mem_cy / rel
    shape = mem_cy.shape
    cache = np.broadcast_to(batch.transfers[..., None, :-1],
                            shape + (batch.transfers.shape[-1] - 1,))
    transfers = np.concatenate([cache, mem_cy[..., None]], axis=-1)
    return ECMBatch(
        t_ol=np.broadcast_to(batch.t_ol[..., None], shape).copy(),
        t_nol=np.broadcast_to(batch.t_nol[..., None], shape).copy(),
        transfers=transfers, levels=batch.levels, names=batch.names,
        unit=batch.unit)


def fill_domains(p1, p_sat, n_cores: int, cores_per_domain: int,
                 n_domains: int, fill_domains_first: bool = True
                 ) -> np.ndarray:
    """Domain-aware Eq. 2 performance curves, vectorized over cores.

    ``p1`` (single-core performance) and ``p_sat`` (per-domain
    saturation performance; ``inf`` = no shared bottleneck) are
    broadcast-compatible arrays; the result appends a trailing axis of
    length ``n_cores``.  ``fill_domains_first=True`` is the CoD/SNC
    pinning (cores fill one affinity domain after the other; each
    domain saturates independently); ``False`` spreads cores over one
    big domain with ``n_domains`` times the bandwidth (non-CoD).  This
    is the one shared scaling rule: the light-speed engine here and the
    calibrated simulator (``repro.simcache``) both call it.
    """
    p1 = np.asarray(p1, float)[..., None]
    p_sat = np.asarray(p_sat, float)[..., None]
    n = np.arange(1, n_cores + 1, dtype=float)
    if not fill_domains_first:
        return np.minimum(n * p1, n_domains * p_sat)
    full = np.floor_divide(n, cores_per_domain)
    rem = n - full * cores_per_domain
    p = (full * np.minimum(cores_per_domain * p1, p_sat)
         + np.minimum(rem * p1, p_sat) * (rem > 0))
    return np.minimum(p, n_domains * p_sat)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ChipScaling:
    """Domain-aware multicore scaling + energy of a workload batch on one
    machine, over a DVFS grid — the registry-integrated Eq. 2 / §III-D
    engine.  All arrays are ``(W, F)``-shaped (workloads x frequencies);
    performance/energy surfaces append a core axis ``(W, F, N)``.
    Construct via :func:`scale_workloads`."""

    machine: MachineModel
    names: tuple[str, ...]
    f_ghz: np.ndarray              # (F,)
    t_single: np.ndarray           # (W, F) mem-level cy per unit of work
    bottleneck: np.ndarray         # (W, F) per-domain bottleneck cy/unit
    t_ol: np.ndarray               # (W,) overlapping in-core cycles
    cores_per_domain: int
    n_domains: int

    @property
    def cores(self) -> int:
        return self.cores_per_domain * self.n_domains

    def _memo(self, key, build) -> np.ndarray:
        """Per-instance memo for derived grids.  Every array here is a
        pure function of the frozen fields, so caching is free of staleness
        by construction; results are frozen (read-only) because they are
        shared across callers."""
        grids = self.__dict__.get("_grids")
        if grids is None:
            grids = {}
            object.__setattr__(self, "_grids", grids)
        val = grids.get(key)
        if val is None:
            val = build()
            val.flags.writeable = False
            grids[key] = val
        return val

    def _n_sat_raw(self) -> np.ndarray:
        """(W, F) uncapped Eq. 2 points as floats; ``inf`` where the
        bottleneck term is zero (nothing to saturate)."""
        def build():
            bound = self.bottleneck > 0
            n = np.full(self.bottleneck.shape, np.inf)
            n[bound] = np.ceil(self.t_single[bound]
                               / self.bottleneck[bound])
            return n
        return self._memo("n_sat_raw", build)

    def core_bound(self) -> np.ndarray:
        """(W, F) booleans: the workload cannot saturate the shared
        bottleneck within one affinity domain — either there is no
        bottleneck term at all (cache-resident compute: zero memory
        traffic) or the Eq. 2 point lies beyond the domain's core
        count (in-core time dominates).  Consistent with
        :meth:`performance` by construction: a core-bound workload's
        bandwidth cap is unreachable with the cores this machine has."""
        return self._memo(
            "core_bound",
            lambda: self._n_sat_raw() > self.cores_per_domain)

    def n_saturation(self) -> np.ndarray:
        """(W, F) Eq. 2 per-domain saturation points.  The domain core
        count caps the values: core-bound workloads report the full
        domain (linear scaling to the machine's edge)."""
        return self._memo(
            "n_sat",
            lambda: np.minimum(self._n_sat_raw(),
                               self.cores_per_domain).astype(int))

    def n_saturation_chip(self) -> np.ndarray:
        """(W, F) chip-level saturation under balanced domain pinning:
        ``n_domains`` x the per-domain point (paper Fig. 10: "2 x 4
        cores for the chip"); the full chip for core-bound workloads."""
        return self._memo(
            "n_sat_chip",
            lambda: np.minimum(self.n_saturation() * self.n_domains,
                               self.cores))

    def saturation_summary(self, f_ghz: float | None = None
                           ) -> dict[str, dict]:
        """Per-workload Eq. 2 summary at one frequency (default: the
        machine's nominal clock) — the one extraction behind the
        cross-zoo :func:`saturation_table`, the ``BENCH_scaling``
        artifact and the zoo report."""
        f = self.machine.nominal_ghz if f_ghz is None else f_ghz
        fi = int(np.argmin(np.abs(self.f_ghz - f)))
        n_dom, n_chip = self.n_saturation(), self.n_saturation_chip()
        core = self.core_bound()
        return {
            w: {"n_sat_domain": int(n_dom[i, fi]),
                "n_sat_chip": int(n_chip[i, fi]),
                "core_bound": bool(core[i, fi]),
                "t_single_cy": float(self.t_single[i, fi]),
                "bottleneck_cy": float(self.bottleneck[i, fi])}
            for i, w in enumerate(self.names)
        }

    # ------------------------------------------------------------------
    def _p_sat(self, work_per_unit) -> np.ndarray:
        w = np.broadcast_to(np.asarray(work_per_unit, float),
                            self.bottleneck.shape)
        bound = self.bottleneck > 0
        return np.where(bound,
                        w / np.where(bound, self.bottleneck, 1.0), np.inf)

    def performance(self, n_cores: int | None = None,
                    work_per_unit=1.0, *,
                    fill_domains_first: bool = True) -> np.ndarray:
        """(W, F, N) performance surface in work units per core cycle
        (multiply by ``f * 1e9`` for units/s).  ``work_per_unit``
        broadcasts over ``(W, F)`` (e.g. updates per unit of work)."""
        def build():
            w = np.asarray(work_per_unit, float)
            p1 = w / self.t_single
            return fill_domains(p1, self._p_sat(work_per_unit),
                                n_cores or self.cores,
                                self.cores_per_domain,
                                self.n_domains, fill_domains_first)
        if type(work_per_unit) in (int, float):    # hashable -> memoizable
            return self._memo(("perf", n_cores, float(work_per_unit),
                               fill_domains_first), build)
        return build()

    def energy(self, total_work_units: float, *,
               n_cores: int | None = None,
               fill_domains_first: bool = True) -> dict[str, np.ndarray]:
        """(W, F, N) energy-to-solution [J], EDP [Js], runtime [s] and
        power [W] grids — the Figs. 5/6 surfaces from the machine's
        :class:`~repro.core.machine.ChipPower` calibration."""
        perf = self.performance(n_cores, fill_domains_first=fill_domains_first)
        n_max = perf.shape[-1]
        f = self.f_ghz[None, :, None]
        n = np.arange(1, n_max + 1, dtype=float)[None, None, :]
        t_s = total_work_units / (perf * f * 1e9)
        watts = self.machine.power.watts(n, f) + np.zeros_like(t_s)
        energy = watts * t_s
        return {"energy_J": energy, "edp_Js": energy * t_s,
                "runtime_s": t_s, "watts": watts}

    def operating_points(self, total_work_units: float = 1.0, *,
                         objective: str = "edp",
                         n_cores: int | None = None,
                         fill_domains_first: bool = True,
                         top: int | None = None) -> list[dict]:
        """Rank every (workload, frequency, cores) operating point by an
        objective — ``"performance"`` (min runtime), ``"energy"`` (min
        energy-to-solution) or ``"edp"``.  Returns dicts best-first;
        ``top`` truncates.  The argsort is stable with the grid laid out
        frequency-outer / cores-inner, matching the scan order of the
        old ``energy.best_config``."""
        key = {"performance": "runtime_s", "energy": "energy_J",
               "edp": "edp_Js"}
        if objective not in key:
            raise KeyError(f"unknown objective {objective!r}; "
                           f"pick one of {sorted(key)}")
        grids = self.energy(total_work_units, n_cores=n_cores,
                            fill_domains_first=fill_domains_first)
        obj = grids[key[objective]]                       # (W, F, N)
        flat = obj.reshape(-1)
        order = np.argsort(flat, kind="stable")
        if top is not None:
            order = order[:top]
        out = []
        for i in order:
            wi, fi, ni = np.unravel_index(i, obj.shape)
            out.append({
                "name": (self.names[wi] if self.names else str(int(wi))),
                "f_ghz": float(self.f_ghz[fi]),
                "n_cores": int(ni) + 1,
                "objective": objective,
                "value": float(flat[i]),
                "runtime_s": float(grids["runtime_s"][wi, fi, ni]),
                "energy_J": float(grids["energy_J"][wi, fi, ni]),
                "edp_Js": float(grids["edp_Js"][wi, fi, ni]),
            })
        return out

    def best(self, total_work_units: float = 1.0, *,
             objective: str = "edp", n_cores: int | None = None,
             fill_domains_first: bool = True) -> list[dict]:
        """The energy-optimal (or EDP-/runtime-optimal) ``(n, f)``
        operating point per workload — first minimum in the
        frequency-outer / cores-inner scan order (bit-compatible with
        ``energy.best_config``)."""
        pts = self.operating_points(total_work_units, objective=objective,
                                    n_cores=n_cores,
                                    fill_domains_first=fill_domains_first)
        seen: dict[str, dict] = {}
        for p in pts:
            seen.setdefault(p["name"], p)
        return [seen[n] for n in (self.names or sorted(seen))]


def scale_workloads(workloads, machine: "MachineModel | str" = "haswell-ep",
                    *, f_ghz=None, sustained_bw=None,
                    cores_per_domain: int | None = None,
                    n_domains: int | None = None,
                    optimized_agu: bool = False) -> ChipScaling:
    """Build the chip-scaling engine for any workloads on any machine.

    ``workloads`` is any mix the unified engine can lower (or an
    already-lowered :class:`~repro.core.workload.LoweredBatch`); the
    per-domain sustained bandwidth comes from the machine's
    ``measured_bw`` calibration unless overridden, and the domain
    topology / DVFS grid default to the machine's own.
    """
    from .workload import lower_many

    m = get_machine(machine)
    lowered = (workloads if hasattr(workloads, "routed")
               else lower_many(workloads, m, sustained_bw=sustained_bw,
                               optimized_agu=optimized_agu))
    batch = lowered.batch
    f = np.atleast_1d(np.asarray(
        f_ghz if f_ghz is not None else m.frequency_grid(), float))
    scaled = frequency_scale(batch, f, f_nominal_ghz=m.nominal_ghz,
                             bw_freq_coupled=m.bw_freq_coupled,
                             coupling_floor=m.coupling_floor)
    return ChipScaling(
        machine=m,
        names=batch.names,
        f_ghz=f,
        t_single=scaled.predictions()[..., -1],
        bottleneck=scaled.transfers[..., -1],
        t_ol=np.asarray(batch.t_ol, float),
        cores_per_domain=cores_per_domain
        or (m.cores_per_domain or m.cores),
        n_domains=n_domains or m.n_domains,
    )


def scale_model(config, machine: "MachineModel | str" = "haswell-ep",
                *, phase: str = "decode", batch: int = 1,
                seq_len: int = 4096, context: int | None = None,
                f_ghz=None, cores_per_domain: int | None = None,
                n_domains: int | None = None) -> ChipScaling:
    """Eq. 2 saturation / energy surfaces for a **whole model config**.

    The composition engine (``repro.core.compose``) walks one phase of
    the config into registry workloads and aggregates them into a
    single pre-scaled lowered record whose unit of work is one step;
    this function feeds that record to the same Eq. 2 machinery every
    single-kernel workload uses.  ``t_single`` is the pipelined
    composed step time, the bottleneck term is the step's summed
    memory-edge transfer cycles — so ``n_saturation()``, ``energy()``
    and ``operating_points()`` answer "how many cores / what frequency
    does *this model step* need" directly.
    """
    from .compose import model_lowered

    lowered = model_lowered(config, machine, phase=phase, batch=batch,
                            seq_len=seq_len, context=context)
    return scale_workloads(lowered, machine, f_ghz=f_ghz,
                           cores_per_domain=cores_per_domain,
                           n_domains=n_domains)


# ---------------------------------------------------------------------------
# Cross-zoo views
# ---------------------------------------------------------------------------


def scaling_zoo(workloads=None, machines=None, **kw
                ) -> dict[str, ChipScaling]:
    """One :class:`ChipScaling` per machine for the given workloads
    (default: the full workload registry on every registered machine) —
    the (workloads x machines x cores x frequencies) surface as a
    per-machine dict of batched engines (hierarchies differ across
    machines, so the machine axis stays an outer dict)."""
    from .workload import workload_registry

    ws = list(workloads if workloads is not None
              else workload_registry().values())
    ms = [get_machine(m) for m in (machines or sorted(MACHINES))]
    return {m.name: scale_workloads(ws, m, **kw) for m in ms}


def saturation_table(workloads=None, machines=None) -> dict[str, dict]:
    """The cross-zoo Eq. 2 table: ``{machine: {workload:
    saturation-summary row}}`` at each machine's nominal frequency —
    every registered workload on every registered machine."""
    return {name: cs.saturation_summary()
            for name, cs in scaling_zoo(workloads, machines,
                                        f_ghz=None).items()}


# ---------------------------------------------------------------------------
# TPU Eq. 2 analogue: ICI collectives as the shared bottleneck
# ---------------------------------------------------------------------------


def tpu_dp_scaling(resources, chip_counts=(1, 2, 4, 8, 16, 32, 64, 128,
                                           256), *,
                   machine=None, dtype_peak: float | None = None,
                   exposed_ici_fraction: float | None = None) -> dict:
    """Eq. 2 at chip granularity: data-parallel scaling of one program.

    ``resources`` describes the global program on one chip (an
    :class:`~repro.core.hlo.HLOResources` or anything with ``flops``,
    ``bytes_accessed`` and a ``collectives`` list of
    :class:`~repro.core.hlo.CollectiveOp`).  Spreading it over ``n``
    chips divides the compute and HBM terms by ``n``, but the ring
    collectives' per-chip wire bytes scale with ``(n-1)/n`` — they
    approach a **floor** that plays exactly the role of ``T_L3Mem`` in
    Eq. 2: the shared-bottleneck transfer time that does not shrink
    with more executing units.  The saturation chip count is the Eq. 2
    form ``n_S = ceil(T_single / T_ICI_floor)``.

    Returns per-``n`` arrays (``t_*_us`` in microseconds) plus
    ``n_saturation`` (``None`` when the program has no collectives —
    linear scaling, the chip-level core-bound case).

    Since the multi-chip generalization landed this is the pure-DP
    special case of :mod:`repro.core.mesh`: it delegates to
    :func:`repro.core.mesh.dp_scaling` (bit-identical values through the
    shared plan evaluator; tensor/pipeline/expert parallelism live
    there).
    """
    from .mesh import dp_scaling

    return dp_scaling(resources, chip_counts, machine=machine,
                      dtype_peak=dtype_peak,
                      exposed_ici_fraction=exposed_ici_fraction)
