"""The unified workload protocol and the single ECM construction engine.

The ECM model's whole point (paper §IV) is that *one* composition rule —
``T_ECM = max(T_nOL + T_data, T_OL)`` — covers any kernel on any machine.
This module makes the *construction* side equally uniform: every workload
family (streaming loop, layer-condition stencil, fused pipeline chain, TPU
step) reduces to one **canonical record**,

* a micro-op mix (:class:`UopMix`) that the machine's issue model turns
  into ``T_OL`` / ``T_nOL``, and
* logical per-level line traffic (:class:`LineTraffic`): input-load lines
  missing each cache level, write-allocate (RFO) streams, write-back
  evictions and non-temporal stores — as a function of machine, problem
  size and blocking,

and one batched engine (:func:`lower` / :func:`workload_batch`) evaluates
the full (workload x machine x level x size) grid through
:class:`~repro.core.ecm.ECMBatch` with **no per-family code downstream**:
``repro.simcache`` and ``repro.core.autotune`` consume the lowered record
and never ask what family a workload belongs to.

Hierarchy semantics live in exactly one place, :func:`route_traffic`:
inclusive caches (Haswell-style), a non-inclusive victim LLC
(``machine.victim_l3``, Skylake-SP) and software-managed hierarchies
without write-allocate (``machine.write_allocate=False``, the TPU — every
store becomes the paper's §VII-E non-temporal store) are per-machine
*routing rules* applied to the same logical traffic.

Workload families shipped here:

* :class:`StreamWorkload` — wraps a §IV-C
  :class:`~repro.core.kernel_spec.StreamKernelSpec` (constant traffic);
* :class:`StencilWorkload` — wraps a
  :class:`~repro.core.layer_condition.StencilSpec` bound to problem
  widths / blocking; traffic follows the layer conditions evaluated
  against the *machine's* cache capacities;
* fused pipeline chains — specs built by
  :func:`~repro.core.kernel_spec.fuse_chain` (e.g. ``triad_update``),
  which sums stage uops and elides the intermediate streams that stay
  resident between fused stages; they are ordinary stream workloads here;
* :class:`MatmulWorkload` / :class:`AttentionWorkload` — the
  compute-bound families (cache-blocked GEMM and flash-attention tiles):
  per-level traffic from layer-condition analysis of which operand
  blocks survive each cache, contraction MACs as ``UopMix.dot`` uops so
  a matrix unit (the TPU MXU) can retire them at the systolic rate —
  the first families where ``T_core`` dominates the composition;
* :class:`RawWorkload` — a pre-lowered record (the TPU step model's
  seconds-per-step terms enter the engine through this, see
  :func:`tpu_step_workload`).

``WORKLOADS`` is the registry: every entry evaluates on every machine in
``repro.core.machine.MACHINES`` through the same code path (pinned by
``tests/test_workload.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Protocol, runtime_checkable

import numpy as np

from .ecm import ECMBatch, ECMModel
from .machine import MACHINES, MachineModel, get_machine


# ---------------------------------------------------------------------------
# The canonical record
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class UopMix:
    """Micro-op mix per unit of work, canonical per 32 B vector register on
    a 64 B line (Table I's accounting); the machine's
    ``effective_uop_scale`` adapts it to wider/narrower SIMD.

    ``dot`` counts *contraction* MACs (matmul / attention inner products)
    separately from element-wise ``fma``: on a CPU they are the same FMA
    uops, but a machine with a matrix unit (the TPU's MXU) retires them at
    the systolic-array rate instead of the vector-FMA rate — the uop mix
    carries the distinction so the machine's issue model can route it.
    """

    loads: float = 0.0
    stores: float = 0.0
    fma: float = 0.0
    mul: float = 0.0
    add: float = 0.0
    dot: float = 0.0

    @property
    def l1_uops(self) -> float:
        """Load/store uops hitting the L1 interface (front-end pressure)."""
        return self.loads + self.stores


@dataclass(frozen=True)
class LineTraffic:
    """Logical per-level line traffic for a batch of model points.

    ``loads[b, l]`` — input-load lines per unit of work that *miss* cache
    level ``l`` (innermost first); constant across ``l`` for streaming
    kernels, layer-condition-driven for stencils.  ``rfo`` (write-allocate
    reads), ``evicts`` (write-backs leaving L1) and ``nt`` (non-temporal
    stores) are per-unit-of-work scalars per batch element.  How these
    logical streams map onto hierarchy *edges* is the machine's business —
    see :func:`route_traffic`.
    """

    loads: np.ndarray          # (B, L)
    rfo: np.ndarray            # (B,)
    evicts: np.ndarray         # (B,)
    nt: np.ndarray             # (B,)

    def __post_init__(self):
        object.__setattr__(self, "loads",
                           np.atleast_2d(np.asarray(self.loads, float)))
        b = self.loads.shape[0]
        for name in ("rfo", "evicts", "nt"):
            v = np.broadcast_to(
                np.asarray(getattr(self, name), float), (b,)).copy()
            object.__setattr__(self, name, v)

    @property
    def batch(self) -> int:
        return self.loads.shape[0]


@dataclass(frozen=True)
class RoutedTraffic:
    """Per-edge line counts after hierarchy routing: edge ``e`` connects
    prediction level ``e`` and ``e+1``; the last edge is the memory edge."""

    load_lines: np.ndarray     # (B, E) inward lines per edge
    evict_lines: np.ndarray    # (B, E) outward lines per edge

    def mem_lines(self) -> np.ndarray:
        return self.load_lines[:, -1] + self.evict_lines[:, -1]


def route_traffic(machine: MachineModel, t: LineTraffic) -> RoutedTraffic:
    """Map logical streams onto the machine's hierarchy edges.

    This is the *single* place hierarchy semantics live:

    * inclusive caches — loads + RFO travel inward on every edge down to
      the level holding the data; write-backs travel outward on every
      edge; NT stores leave through the L1 interface (line-fill buffers)
      and land on the memory edge, bypassing the caches in between
      (§VII-E accounting);
    * ``machine.write_allocate=False`` — RFO streams do not exist and
      write-backs *are* NT streams (software-managed hierarchy: Pallas
      whole-block ``out_specs``);
    * ``machine.victim_l3`` — non-inclusive LLC (Skylake-SP): loads
      stream from memory directly into L2, so the LLC edge carries no
      inward lines; instead every line displaced from L2 crosses it
      outward (clean victims + dirty write-backs).
    """
    n_edges = len(machine.levels) + 1
    if t.loads.shape[1] != n_edges:
        raise ValueError(
            f"traffic has {t.loads.shape[1]} miss levels, machine "
            f"{machine.name!r} has {n_edges} (cache levels incl. the one "
            f"feeding the memory edge)")
    rfo, evicts, nt = t.rfo, t.evicts, t.nt
    if not machine.write_allocate:
        rfo = np.zeros_like(rfo)
        nt = nt + evicts
        evicts = np.zeros_like(evicts)
    zeros = np.zeros_like(evicts)
    load_cols, evict_cols = [], []
    for e in range(n_edges):
        inward = t.loads[:, e] + rfo
        if e == 0:
            outward = evicts + nt
        elif e == n_edges - 1:
            outward = evicts + nt
        else:
            outward = evicts
        if machine.victim_l3 and n_edges >= 3 and e == n_edges - 2:
            # victim LLC edge: nothing inward; clean victims (the lines
            # fetched from memory into L2) + dirty write-backs outward.
            outward = t.loads[:, e] + evicts
            inward = zeros
        load_cols.append(inward)
        evict_cols.append(outward)
    return RoutedTraffic(load_lines=np.stack(load_cols, axis=-1),
                         evict_lines=np.stack(evict_cols, axis=-1))


# ---------------------------------------------------------------------------
# The workload protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class Workload(Protocol):
    """Anything that reduces to the canonical record on a given machine."""

    name: str

    def batch_names(self) -> tuple[str, ...]: ...

    def uops(self) -> UopMix: ...

    def traffic(self, machine: MachineModel) -> LineTraffic: ...

    def bw_keys(self) -> tuple[str, ...]: ...

    def work_per_elem(self) -> tuple[int, int]:
        """(flops, updates) per scalar element, for performance
        conversion."""
        ...


@dataclass(frozen=True)
class LoweredBatch:
    """One workload family lowered on one machine: the engine's output and
    the simulator's input.  ``batch`` holds the light-speed ECM models;
    the routed traffic and uop pressure are what the calibrated
    non-light-speed effects in ``repro.simcache`` consume — so *any*
    workload can be simulated without family-specific code.
    """

    batch: ECMBatch
    routed: RoutedTraffic
    l1_uops: np.ndarray            # (B,)
    mem_cy_per_line: np.ndarray    # (B,)

    def __len__(self) -> int:
        return len(self.batch)


def _resolve_bw(workload: Workload, machine: MachineModel,
                sustained_bw) -> float:
    if isinstance(sustained_bw, (int, float)):
        return float(sustained_bw)
    if isinstance(sustained_bw, dict):
        for k in (workload.name, *workload.bw_keys()):
            if k in sustained_bw:
                return float(sustained_bw[k])
    return machine.sustained_bw(*workload.bw_keys())


def lower(workload: Workload, machine: "MachineModel | str", *,
          sustained_bw: "float | dict | None" = None,
          optimized_agu: bool = False) -> LoweredBatch:
    """Reduce one workload on one machine: canonical record -> ECM times.

    The §IV-C recipe, once, for every family: uop mix through the
    machine's issue model -> ``T_OL``/``T_nOL``; logical traffic through
    :func:`route_traffic` -> per-edge lines; per-level bandwidths (and the
    machine's calibrated sustained memory bandwidth) -> transfer cycles.

    Pre-lowered workloads (:class:`RawWorkload`: ``as_batch()``) skip the
    reduction — their times are already calibrated in their own units —
    and enter with zero residual traffic (nothing left for the simulator's
    non-light-speed effects to act on).
    """
    m = get_machine(machine)
    if hasattr(workload, "as_batch"):           # pre-lowered record
        batch = workload.as_batch()
        b = len(batch)
        n_edges = len(batch.levels) - 1
        zeros = np.zeros((b, n_edges))
        return LoweredBatch(batch=batch,
                            routed=RoutedTraffic(zeros, zeros.copy()),
                            l1_uops=np.zeros(b),
                            mem_cy_per_line=np.zeros(b))
    u = workload.uops()
    t_nol, t_ol = m.core_cycles(loads=u.loads, stores=u.stores, fma=u.fma,
                                mul=u.mul, add=u.add, dot=u.dot,
                                optimized_agu=optimized_agu)
    traffic = workload.traffic(m)
    routed = route_traffic(m, traffic)
    bw = _resolve_bw(workload, m, sustained_bw)
    lb = m.line_bytes
    edges = []
    for i, lvl in enumerate(m.levels):
        edges.append(routed.load_lines[:, i] * lb / lvl.load_bpc
                     + routed.evict_lines[:, i] * lb / lvl.evict_bpc)
    mem_cy = m.mem_cycles_per_line(bw)
    edges.append(mem_cy * routed.mem_lines())
    b = traffic.batch
    names = workload.batch_names()
    if len(names) != b:
        names = tuple(f"{workload.name}[{i}]" for i in range(b))
    batch = ECMBatch(
        t_ol=np.full(b, t_ol), t_nol=np.full(b, t_nol),
        transfers=np.stack(edges, axis=-1),
        levels=m.level_names(), names=names, unit="cy/CL")
    return LoweredBatch(batch=batch, routed=routed,
                        l1_uops=np.full(b, float(u.l1_uops)),
                        mem_cy_per_line=np.full(b, mem_cy))


def concat_lowered(parts: "list[LoweredBatch]") -> LoweredBatch:
    """Concatenate per-workload :class:`LoweredBatch` parts (shared level
    hierarchy).  The single home of the batching semantics: both the cold
    path below and the precomputed table in :mod:`repro.core.engine`
    assemble their results here, so the two cannot diverge."""
    if len(parts) == 1:
        return parts[0]
    first = parts[0].batch
    for p in parts[1:]:
        if p.batch.levels != first.levels:
            raise ValueError(
                f"cannot batch workloads over different hierarchies: "
                f"{p.batch.names[0]!r} lowers to levels {p.batch.levels} "
                f"vs {first.names[0]!r} at {first.levels} (pre-lowered "
                f"RawWorkloads keep their own hierarchy; batch them "
                f"separately)")
    batch = ECMBatch(
        t_ol=np.concatenate([p.batch.t_ol for p in parts]),
        t_nol=np.concatenate([p.batch.t_nol for p in parts]),
        transfers=np.concatenate([p.batch.transfers for p in parts]),
        levels=first.levels,
        names=tuple(n for p in parts for n in p.batch.names),
        unit=first.unit)
    routed = RoutedTraffic(
        load_lines=np.concatenate([p.routed.load_lines for p in parts]),
        evict_lines=np.concatenate([p.routed.evict_lines for p in parts]))
    return LoweredBatch(
        batch=batch, routed=routed,
        l1_uops=np.concatenate([p.l1_uops for p in parts]),
        mem_cy_per_line=np.concatenate([p.mem_cy_per_line for p in parts]))


_ENGINE = None


def _engine_mod():
    """Import :mod:`repro.core.engine` lazily (it imports this module)."""
    global _ENGINE
    if _ENGINE is None:
        from repro.core import engine as _ENGINE_module
        _ENGINE = _ENGINE_module
    return _ENGINE


def lower_many(workloads, machine: "MachineModel | str", *,
               sustained_bw: "float | dict | None" = None,
               optimized_agu: bool = False,
               table: "bool | object | None" = None) -> LoweredBatch:
    """Lower several workloads on one machine into one concatenated
    :class:`LoweredBatch` (shared level hierarchy).

    ``table`` selects the lowering source: ``None`` (default) consults the
    process-wide precomputed :class:`repro.core.engine.LoweredTable` when
    engine caching is enabled, ``False`` forces a cold re-lowering, and an
    explicit table instance uses that table.  Rows served from a table are
    bit-identical to the cold path (same :func:`lower`, same concatenation)
    but have read-only arrays, since they are shared across calls.
    """
    ws = list(workloads)
    if table is not False:
        eng = _engine_mod()
        tab = table if table not in (None, True) else eng.lowered_table()
        if tab is not None and (table is not None or eng.cache_enabled()):
            return tab.get_many(ws, machine, sustained_bw=sustained_bw,
                                optimized_agu=optimized_agu)
    parts = [lower(w, machine, sustained_bw=sustained_bw,
                   optimized_agu=optimized_agu) for w in ws]
    return concat_lowered(parts)


def workload_batch(workloads, machine: "MachineModel | str" = "haswell-ep",
                   *, sustained_bw: "float | dict | None" = None,
                   optimized_agu: bool = False) -> ECMBatch:
    """The one model-construction entry point: any workloads, any machine,
    one :class:`ECMBatch`."""
    return lower_many(workloads, machine, sustained_bw=sustained_bw,
                      optimized_agu=optimized_agu).batch


def workload_ecm(workload: Workload, machine: "MachineModel | str", *,
                 sustained_bw: "float | dict | None" = None,
                 optimized_agu: bool = False) -> ECMModel:
    """Scalar view of :func:`workload_batch` (batch element 0)."""
    return lower(workload, machine, sustained_bw=sustained_bw,
                 optimized_agu=optimized_agu).batch.scalar(0)


def zoo_predictions(workloads=None, machines=None) -> dict:
    """The cross-generation prediction grid: ``{machine: {workload:
    (levels, predictions)}}`` for every registered pair — the
    arXiv:1702.07554 structure (same workload inputs, many machines)."""
    ws = list(workloads if workloads is not None
              else workload_registry().values())
    ms = [get_machine(m) for m in (machines or sorted(MACHINES))]
    out: dict = {}
    for m in ms:
        lowered = lower_many(ws, m)
        preds = lowered.batch.predictions()
        out[m.name] = {
            n: (lowered.batch.levels, tuple(float(x) for x in preds[i]))
            for i, n in enumerate(lowered.batch.names)
        }
    return out


# ---------------------------------------------------------------------------
# Stream workloads (constant traffic; §IV-C Table I)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StreamWorkload:
    """A steady-state streaming kernel: traffic is constant per unit of
    work at every level (no reuse)."""

    spec: "object"                 # StreamKernelSpec (duck-typed)

    @property
    def name(self) -> str:
        return self.spec.name

    def batch_names(self) -> tuple[str, ...]:
        return (self.spec.name,)

    def uops(self) -> UopMix:
        s = self.spec
        return UopMix(loads=s.uop_loads, stores=s.uop_stores, fma=s.uop_fma,
                      mul=s.uop_mul, add=s.uop_add)

    def traffic(self, machine: MachineModel) -> LineTraffic:
        s = self.spec
        n_levels = len(machine.levels) + 1
        return LineTraffic(
            loads=np.full((1, n_levels), float(s.loads_explicit)),
            rfo=float(s.rfo), evicts=float(s.stores),
            nt=float(s.nt_stores))

    def bw_keys(self) -> tuple[str, ...]:
        return (self.spec.name, "_stream")

    def work_per_elem(self) -> tuple[int, int]:
        return self.spec.flops_per_elem, self.spec.updates_per_elem


# ---------------------------------------------------------------------------
# Stencil workloads (layer-condition traffic; arXiv:1410.5010)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StencilWorkload:
    """A stencil spec bound to problem widths and optional blocking.

    ``widths`` may be one tuple (scalar point) or a ``(B, dim-1)`` array
    of effective inner widths (a whole sweep / candidate grid evaluated as
    one batch).  The layer conditions are evaluated against the machine's
    own cache capacities unless ``capacities`` overrides them; a
    precomputed ``misses`` table short-circuits the LC analysis (shared
    with callers that already built one).
    """

    spec: "object"                 # StencilSpec (duck-typed)
    widths: "tuple | np.ndarray | None" = None
    block: "tuple | None" = None
    safety: float | None = None
    capacities: "tuple[int, ...] | None" = None
    misses: "np.ndarray | None" = None
    names: tuple = ()

    @property
    def name(self) -> str:
        return self.spec.name

    def batch_names(self) -> tuple[str, ...]:
        if self.names:
            return tuple(self.names)
        b = self._effective_widths_or_none()
        if b is None or b.shape[0] == 1:
            return (self.spec.name,)
        return tuple(f"{self.spec.name}[{i}]" for i in range(b.shape[0]))

    def uops(self) -> UopMix:
        s = self.spec
        return UopMix(loads=s.uop_loads, stores=s.uop_stores, fma=s.uop_fma,
                      mul=s.uop_mul, add=s.uop_add)

    def _effective_widths_or_none(self) -> "np.ndarray | None":
        if self.widths is None:
            return None
        w = np.asarray(self.widths, float)
        if w.ndim == 1:
            w = w[None, :] if w.shape[0] == self.spec.dim - 1 else w[:, None]
        if self.block is not None:
            w = np.minimum(w, np.asarray(self.block, float)[None, :]
                           if np.ndim(self.block) else float(self.block))
        return w

    def traffic(self, machine: MachineModel) -> LineTraffic:
        from .layer_condition import LC_SAFETY, misses_batch

        s = self.spec
        misses = self.misses
        if misses is None:
            w = self._effective_widths_or_none()
            if w is None:
                raise ValueError(
                    f"stencil workload {s.name!r} needs widths (or a "
                    f"precomputed misses table)")
            caps = self.capacities or machine.capacities
            if not caps:
                raise ValueError(
                    f"machine {machine.name!r} declares no cache "
                    f"capacities; cannot evaluate layer conditions")
            misses = misses_batch(
                s, w, tuple(caps),
                safety=self.safety if self.safety is not None else LC_SAFETY)
        misses = np.atleast_2d(np.asarray(misses, float))
        n_levels = len(machine.levels) + 1
        if misses.shape[1] != n_levels:
            raise ValueError(
                f"misses table has {misses.shape[1]} levels, machine "
                f"{machine.name!r} needs {n_levels}")
        return LineTraffic(loads=misses, rfo=float(s.rfo_streams),
                           evicts=float(s.wb_streams), nt=0.0)

    def bw_keys(self) -> tuple[str, ...]:
        return (self.spec.name, "_stencil")

    def work_per_elem(self) -> tuple[int, int]:
        return self.spec.flops_per_elem, self.spec.updates_per_elem

    # convenience for sweeps over candidate blockings
    def with_block(self, block) -> "StencilWorkload":
        return replace(self, block=tuple(int(x) for x in np.atleast_1d(block)))


# ---------------------------------------------------------------------------
# Compute-bound workloads: blocked matmul + flash attention
# ---------------------------------------------------------------------------
#
# These are the first families where T_core (not transfer time) dominates
# the Eq. 1 composition: the overlap rule is exercised from the
# non-saturated side (T_OL hides the whole transfer chain).  Their traffic
# follows the layer-condition approach of arXiv:1410.5010 generalized to
# cache-blocked GEMM: the per-edge line counts depend on which operand
# *panels* survive in each cache level, exactly as the stencil's depend on
# which row neighbourhoods do.  The in-core side follows the per-
# generation throughput analysis of arXiv:1511.03639 (FMA ports on the
# CPUs, the MXU systolic rate on the TPU via ``UopMix.dot``).

#: reuse-set safety factor (same rule of thumb as the stencil layer
#: conditions: a panel only survives if it fits in *half* the cache).
COMPUTE_LC_SAFETY = 2.0


@dataclass(frozen=True)
class MatmulSpec:
    """Register-tile + dtype description of a blocked-GEMM family.

    uop accounting per cache line of C fully computed (Table I's canonical
    32 B-vector-on-64 B-line units): the two C vectors of a line each take
    ``K`` contraction MACs -> ``2K`` ``dot`` uops; the register tile
    (``reg_m_vecs`` vector rows x ``reg_n`` columns of C, the classic
    Haswell 8x6 DGEMM microkernel by default) amortizes the A-broadcast
    and B-vector loads to ``2K * (1/reg_n + 1/reg_m_vecs)`` load uops —
    which is what makes a well-tiled GEMM FMA-bound rather than
    load-bound in the port model (arXiv:1511.03639's Haswell analysis).
    """

    name: str = "matmul"
    elem_bytes: int = 4                 # f32, matching the Pallas kernel
    reg_m_vecs: int = 2                 # register tile: vector rows of C
    reg_n: int = 6                      # register tile: columns of C


@dataclass(frozen=True)
class MatmulWorkload:
    """Cache-blocked GEMM ``C[m,n] = A[m,k] @ B[k,n]`` with tile sizes
    ``bm/bn/bk`` (the Pallas kernel's grid blocking).

    Unit of work: one cache line of C elements fully computed.  Per-level
    line traffic via layer-condition analysis of the blocked loop nest
    (i-blocks outer, j-blocks middle, k innermost-sequential — the
    ``kernels/matmul`` grid order):

    * **A** (``bm x K`` panel, streamed per (i, j) block): if the panel
      survives a level across the j-loop, A is read once per i-row —
      ``K/N`` lines per CL of C; otherwise it is re-read for every
      j-block — ``K/bn`` lines.
    * **B** (whole matrix, streamed per i-block): if all of B fits, it is
      read once — ``K/M`` lines; otherwise re-read per i-block —
      ``K/bm`` lines.
    * **C** is written once (the accumulator tile stays resident across
      the k loop): the LC-independent write-allocate + write-back pair.

    The memory-edge load count ``K/bm + K/bn`` is the classic blocked-GEMM
    traffic law: blocking grows ``bm``/``bn`` until T_core dominates and
    the kernel leaves the bandwidth-bound regime.
    """

    spec: MatmulSpec
    m: int
    n: int
    k: int
    bm: int = 256
    bn: int = 256
    bk: int = 512
    safety: float = COMPUTE_LC_SAFETY

    @property
    def name(self) -> str:
        return self.spec.name

    def batch_names(self) -> tuple[str, ...]:
        return (self.spec.name,)

    def uops(self) -> UopMix:
        s = self.spec
        dot = 2.0 * self.k
        return UopMix(loads=dot * (1.0 / s.reg_n + 1.0 / s.reg_m_vecs),
                      stores=2.0, dot=dot)

    def traffic(self, machine: MachineModel) -> LineTraffic:
        caps = machine.capacities
        n_levels = len(machine.levels) + 1
        if len(caps) != n_levels:
            raise ValueError(
                f"machine {machine.name!r} declares {len(caps)} cache "
                f"capacities; the blocked-matmul layer conditions need "
                f"{n_levels} (one per prediction level short of memory)")
        eb = self.spec.elem_bytes
        bm, bn = min(self.bm, self.m), min(self.bn, self.n)
        a_panel = bm * self.k * eb
        b_full = self.k * self.n * eb
        lines = [
            (self.k / self.n if a_panel * self.safety <= c
             else self.k / bn)
            + (self.k / self.m if b_full * self.safety <= c
               else self.k / bm)
            for c in caps
        ]
        return LineTraffic(loads=np.asarray([lines], float),
                           rfo=1.0, evicts=1.0, nt=0.0)

    def bw_keys(self) -> tuple[str, ...]:
        return (self.spec.name, "_compute")

    def work_per_elem(self) -> tuple[int, int]:
        return 2 * self.k, 1

    def with_block(self, block) -> "MatmulWorkload":
        bm, bn, bk = (int(x) for x in block)
        return replace(self, bm=bm, bn=bn, bk=bk)


@dataclass(frozen=True)
class AttentionSpec:
    """Flash-attention (online-softmax) family description.

    uop accounting per cache line of O, canonical units: the QK^T and PV
    contractions contribute ``4 * Sk_eff`` ``dot`` uops (each O element
    costs ``2 * Sk_eff`` MACs); the softmax rides on the VPU/scalar ports
    — ``exp_mul_uops``/``exp_add_uops`` model the exp() polynomial per
    score, plus the running-max compare and sum.  The online-softmax
    *rescale* (``acc *= alpha`` once per visited KV block) is the uop
    overhead that shrinks with the KV block size — the knob
    ``rank(..., objective="attention")`` trades against VMEM/cache fit.
    """

    name: str = "flash-attention"
    elem_bytes: int = 4                 # f32
    reg_q_vecs: int = 2                 # register tile, as MatmulSpec
    reg_k: int = 6
    exp_mul_uops: float = 4.0           # per score: exp() multiplies
    exp_add_uops: float = 4.0           # per score: exp() adds


@dataclass(frozen=True)
class AttentionWorkload:
    """Flash-attention tiles: ``O[sq,d] = softmax(Q K^T / sqrt(d)) V``
    with q-blocks of ``bq`` rows streaming over KV blocks of ``bkv`` rows
    (the ``kernels/attention`` grid; heads multiply the work, they do not
    change the per-line model).

    Unit of work: one cache line of O elements.  Traffic:

    * **Q** is read once and stays resident through the KV loop — 1 line
      per CL of O;
    * **K, V** stream once per q-block: ``2*Sk_eff/bq`` lines per CL of
      O, unless the whole KV set survives a cache level
      (``2*skv*d*elem_bytes`` fits), where only the cold misses remain —
      ``2*skv/sq`` lines;
    * **O** is written once: the write-allocate + write-back pair
      (running m/l statistics are a ``1/d`` fraction — neglected).

    ``causal=True`` visits only ~half the KV blocks per q row
    (``kv_fraction``), scaling both the contraction uops and the streamed
    KV traffic.
    """

    spec: AttentionSpec
    sq: int = 4096
    skv: int = 4096
    d: int = 128
    bq: int = 512
    bkv: int = 512
    causal: bool = True
    safety: float = COMPUTE_LC_SAFETY

    @property
    def name(self) -> str:
        return self.spec.name

    def batch_names(self) -> tuple[str, ...]:
        return (self.spec.name,)

    def kv_fraction(self) -> float:
        """Fraction of (q, kv) tile pairs the kernel visits under causal
        masking.  The Pallas kernel skips a tile only when its *whole*
        q block lies above the diagonal (``qi*bq + bq - 1 < ki*bkv``),
        so coarsening either tile grows the visited fraction:
        ``0.5 + max(bq, bkv) / (2*skv)`` (exact for power-of-two tilings
        of square problems; 1.0 when one tile spans the sequence)."""
        if not self.causal:
            return 1.0
        return min(1.0, 0.5 + max(self.bq, self.bkv) / (2.0 * self.skv))

    def uops(self) -> UopMix:
        s = self.spec
        sk_eff = self.skv * self.kv_fraction()
        dot = 4.0 * sk_eff                       # QK^T + PV contractions
        score_vecs = 2.0 * sk_eff / self.d       # score vectors per CL of O
        rescale = 2.0 * sk_eff / self.bkv        # acc *= alpha per KV block
        return UopMix(
            loads=dot * (1.0 / s.reg_k + 1.0 / s.reg_q_vecs),
            stores=2.0,
            mul=s.exp_mul_uops * score_vecs + rescale,
            add=(s.exp_add_uops + 2.0) * score_vecs,
            dot=dot)

    def traffic(self, machine: MachineModel) -> LineTraffic:
        caps = machine.capacities
        n_levels = len(machine.levels) + 1
        if len(caps) != n_levels:
            raise ValueError(
                f"machine {machine.name!r} declares {len(caps)} cache "
                f"capacities; the attention KV reuse conditions need "
                f"{n_levels}")
        kv_bytes = 2 * self.skv * self.d * self.spec.elem_bytes
        sk_eff = self.skv * self.kv_fraction()
        lines = [
            1.0 + (2.0 * self.skv / self.sq
                   if kv_bytes * self.safety <= c
                   else 2.0 * sk_eff / self.bq)
            for c in caps
        ]
        return LineTraffic(loads=np.asarray([lines], float),
                           rfo=1.0, evicts=1.0, nt=0.0)

    def bw_keys(self) -> tuple[str, ...]:
        return (self.spec.name, "_compute")

    def work_per_elem(self) -> tuple[int, int]:
        return int(round(4.0 * self.skv * self.kv_fraction())), 1

    def with_block(self, block) -> "AttentionWorkload":
        bq, bkv = (int(x) for x in block)
        return replace(self, bq=bq, bkv=bkv)


#: the shipped compute-bound specs (f32, Haswell-8x6-class register tile)
MATMUL_F32 = MatmulSpec()
FLASH_ATTENTION_F32 = AttentionSpec()


# ---------------------------------------------------------------------------
# Pre-lowered workloads (TPU step model and other direct records)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RawWorkload:
    """A workload already expressed as ECM times (no uop/traffic
    reduction): the adapter that lets pre-lowered models — the TPU
    three-term step model chiefly — ride the same batched engine and
    ranking paths as everything else."""

    name: str
    t_ol: float
    t_nol: float
    transfers: tuple
    levels: tuple
    unit: str = "cy/CL"

    def batch_names(self) -> tuple[str, ...]:
        return (self.name,)

    def as_batch(self) -> ECMBatch:
        return ECMBatch(
            t_ol=np.asarray([self.t_ol], float),
            t_nol=np.asarray([self.t_nol], float),
            transfers=np.asarray([self.transfers], float),
            levels=tuple(self.levels), names=(self.name,), unit=self.unit)


def tpu_step_workload(step) -> RawWorkload:
    """Adapt a :class:`~repro.core.tpu_ecm.TPUStepECM` to the unified
    engine (times in microseconds per step, the ``as_ecm_model`` view)."""
    m = step.as_ecm_model()
    return RawWorkload(name=m.name or "tpu-step", t_ol=m.t_ol,
                       t_nol=m.t_nol, transfers=m.transfers,
                       levels=m.levels, unit=m.unit)


# ---------------------------------------------------------------------------
# The workload registry
# ---------------------------------------------------------------------------

WORKLOADS: "dict[str, Workload]" = {}

#: Registry-change observers, called with the workload just (re)registered;
#: ``repro.core.engine`` appends its lowered-table invalidation hook here.
_REGISTRY_HOOKS: list = []


def register_workload(w: Workload) -> Workload:
    WORKLOADS[w.name] = w
    for hook in _REGISTRY_HOOKS:
        hook(w)
    return w


_REGISTRY_SEEDED = False


def workload_registry() -> "dict[str, Workload]":
    """The shipped families, seeded lazily on first access (avoids import
    cycles with the spec modules): Table I streams (+NT variants), the
    fused triad->update chain, and the two Jacobi stencils bound to
    memory-resident problem sizes.  User entries added via
    :func:`register_workload` coexist with the shipped set.  Every entry
    evaluates on every machine in ``MACHINES`` through
    :func:`workload_batch`."""
    global _REGISTRY_SEEDED
    if not _REGISTRY_SEEDED:
        _REGISTRY_SEEDED = True
        from .kernel_spec import BENCHMARKS, TRIAD_UPDATE
        from .layer_condition import JACOBI2D, JACOBI3D

        for spec in BENCHMARKS.values():
            WORKLOADS.setdefault(spec.name, StreamWorkload(spec))
        WORKLOADS.setdefault(TRIAD_UPDATE.name, StreamWorkload(TRIAD_UPDATE))
        WORKLOADS.setdefault("jacobi2d",
                             StencilWorkload(JACOBI2D, widths=(8192,)))
        WORKLOADS.setdefault("jacobi3d",
                             StencilWorkload(JACOBI3D, widths=(480, 480)))
        # compute-bound families, bound to the kernels' default blockings
        WORKLOADS.setdefault(
            MATMUL_F32.name,
            MatmulWorkload(MATMUL_F32, m=4096, n=4096, k=4096))
        WORKLOADS.setdefault(FLASH_ATTENTION_F32.name,
                             AttentionWorkload(FLASH_ATTENTION_F32))
    return WORKLOADS
