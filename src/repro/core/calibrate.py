"""Calibration runner: measure -> fit -> emit a versioned machine file.

The ECM model's premise (paper §IV-V) is that machine parameters are
*measurable*: the same stream/stencil microbenchmark sweeps that validate
the model are the measurements that fit it, and the fitting procedure
transfers across processor generations (arXiv:1702.07554).  This module
closes that measure->calibrate->predict loop:

1. **Measure.**  A measurement backend runs the microbenchmark suite.  On
   this host the backend is :class:`SimcacheBackend` — the calibrated
   cache/port simulator standing in for ``likwid-bench`` runs on real
   hardware (the container has neither a Haswell nor a TPU); hierarchies
   the simulator cannot sweep (the two-level TPU view) fall back to the
   ECM forward model itself.  A backend is any object with the same four
   methods, so real Pallas-kernel timings plug in unchanged.

2. **Fit.**  Each :class:`MachineModel` calibration field class is fitted
   from its measurement by least squares:

   * ``measured_bw[kernel]`` — the deep-memory sweep plateau is inverted
     through the backend's forward response (monotone in the sustained
     bandwidth, solved by geometric bisection to machine precision: the
     nonlinear least-squares optimum for a scalar parameter).  The pure
     ECM affine form ``t(bw) = a + c/bw`` is fitted alongside and its
     relative deviation from the measurement is recorded as the
     ``model_gap`` — the paper's model-vs-measurement gap (§IV-B, a few
     to ~15 percent).  The gated ``residual`` is the least-squares
     misfit of the fitted response itself.
   * ``capacities[k]`` — the residence knees of the stream sweep: the
     curve crosses the midpoint of two adjacent level plateaus where the
     hit weight ``clamp(2*C/ws - 1, 0, 1)`` is one half, i.e. at
     ``ws = 4C/3``; the layer-condition breaks of the 2D stencil sweep
     (``C = 2 * 3 rows * 8 B * N_break``, Stengel §LC) are detected as
     an independent cross-check and recorded in the provenance.
   * ``ChipPower`` — ordinary least squares of the §III-D form
     ``P(n, f) = idle + n (static + lin f + quad f^2)`` over the
     (cores x DVFS-grid) energy measurements; machines without at least
     three operating frequencies are rank-deficient and keep their
     priors (noted, not guessed).
   * overlap — the serial-vs-pipelined "multi-stage pipeline delta"
     (``tpu_ecm.measured_overlap``) recovers ``exposed_hbm_fraction`` on
     software-managed hierarchies; it lives on ``TPUMachineModel`` so it
     is recorded in the provenance rather than the machine dict.

3. **Snap.**  A fit that lands within ``snap_rtol`` of the registered
   prior *adopts the prior bit-identically* (the raw fit and residual
   stay in the provenance).  Recalibrating a zoo machine therefore emits
   a file whose loaded model reproduces the golden predictions exactly —
   recalibration confirms the constants instead of dithering them.
   Pass ``snap_rtol=0`` to adopt raw fits (the new-machine onboarding
   path, exercised by the synthetic-recovery tests).

4. **Emit.**  :meth:`CalibrationReport.save` writes the fitted machine as
   a versioned machine file with full provenance — per-field raw fits and
   residuals, a sha256 over every measurement, backend name, schema
   version — which ``register_machine``/``--machine`` load uniformly.

Reports are persisted in :mod:`repro.core.diskcache` keyed by the prior
machine's content fingerprint, so a warm rerun performs zero re-fitting
(``CAL_COUNTERS`` makes that assertable).
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import time
from dataclasses import dataclass, field

import numpy as np

from . import diskcache
from .machine import (ChipPower, MachineModel, get_machine, machine_from_dict,
                      machine_to_dict, save_machine_file)
from .workload import lower_many, workload_registry

#: Default snap tolerance: fits within this relative distance of the
#: registered prior adopt the prior bit-identically (see module notes).
SNAP_RTOL = 0.05

#: Validation bound on the worst per-field least-squares misfit; the fits
#: reproduce their measurements essentially exactly, so any drift here
#: means the measurement response or the fitting inversion changed —
#: ``check_bench.CALIBRATE_SPEC`` fails the bench gate beyond this.
MAX_FIT_RESIDUAL = 0.02

#: Observability counters (reset with :func:`reset_counters`): ``fits``
#: counts fitted fields, ``measurements`` backend sweeps, ``cache_hits``
#: reports served from the disk cache without re-fitting.
CAL_COUNTERS = {"fits": 0, "measurements": 0, "cache_hits": 0}

#: Stream kernels the cache/port simulator can measure (its likwid set).
STREAM_KERNELS = ("copy", "ddot", "load", "schoenauer", "schoenauer_nt",
                  "store", "striad", "striad_nt", "update")
STENCIL_KERNELS = ("jacobi2d", "jacobi3d")

_CAL_CACHE_KIND = "calibration"


def reset_counters() -> None:
    for k in CAL_COUNTERS:
        CAL_COUNTERS[k] = 0


# ---------------------------------------------------------------------------
# Fit records
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FieldFit:
    """One fitted calibration field: the raw least-squares value, the
    adopted value (snapped to the prior when close enough), and the model
    residual against the measurement."""

    field: str                 # e.g. "measured_bw[copy]", "capacities[1]"
    group: str                 # bandwidth | capacity | power | overlap
    prior: float
    fitted: float
    adopted: float
    residual: float            # rms relative least-squares misfit (gated)
    n_points: int
    snapped: bool
    model_gap: float = 0.0     # pure-ECM vs measurement deviation (info)
    note: str = ""

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclass(frozen=True)
class CalibrationReport:
    """The outcome of one calibration run (see :func:`calibrate`)."""

    base: str                       # prior machine's registry name
    machine: MachineModel           # the fitted (adopted-values) machine
    fits: tuple                     # tuple[FieldFit, ...]
    measurement_hash: str           # sha256 over every measurement array
    backend: str
    snap_rtol: float
    wall_s: float
    checks: dict = field(default_factory=dict)   # e.g. stencil LC breaks
    from_cache: bool = False

    # ------------------------------------------------------------------
    def residual_max(self, group: str | None = None) -> float:
        vals = [f.residual for f in self.fits
                if group is None or f.group == group]
        return max(vals) if vals else 0.0

    def group_summary(self) -> dict:
        out: dict = {}
        for f in self.fits:
            g = out.setdefault(f.group, {"n": 0, "n_snapped": 0,
                                         "max_residual": 0.0})
            g["n"] += 1
            g["n_snapped"] += bool(f.snapped)
            g["max_residual"] = max(g["max_residual"], f.residual)
        return out

    def provenance(self) -> dict:
        return {
            "calibrated_from": self.base,
            "backend": self.backend,
            "snap_rtol": self.snap_rtol,
            "measurement_hash": self.measurement_hash,
            "residual_max": self.residual_max(),
            "fit_wall_s": self.wall_s,
            "fits": [f.as_dict() for f in self.fits],
            "checks": dict(self.checks),
        }

    def save(self, path) -> "Path":  # noqa: F821 - Path via machine module
        """Write the fitted machine as a versioned machine file."""
        return save_machine_file(self.machine, path,
                                 provenance=self.provenance())

    # ------------------------------------------------------------------
    def to_literal(self) -> dict:
        """Plain-literal form for the disk cache (see ``from_literal``)."""
        return {
            "base": self.base,
            "machine": machine_to_dict(self.machine),
            "fits": [f.as_dict() for f in self.fits],
            "measurement_hash": self.measurement_hash,
            "backend": self.backend,
            "snap_rtol": self.snap_rtol,
            "wall_s": self.wall_s,
            "checks": dict(self.checks),
        }

    @classmethod
    def from_literal(cls, doc: dict, *, from_cache: bool = False):
        return cls(
            base=doc["base"],
            machine=machine_from_dict(doc["machine"]),
            fits=tuple(FieldFit(**f) for f in doc["fits"]),
            measurement_hash=doc["measurement_hash"],
            backend=doc["backend"],
            snap_rtol=doc["snap_rtol"],
            wall_s=doc["wall_s"],
            checks=dict(doc.get("checks") or {}),
            from_cache=from_cache,
        )


# ---------------------------------------------------------------------------
# Measurement backend
# ---------------------------------------------------------------------------


class SimcacheBackend:
    """Measurements from the calibrated cache/port simulator — the host's
    stand-in for likwid-bench / RAPL runs on real hardware.

    Any object with the same four methods is a valid backend; timings from
    executed Pallas kernels plug in here when the hardware exists.
    """

    name = "simcache"

    def __init__(self, machine: "MachineModel | str"):
        self.machine = get_machine(machine)

    # -- stream ---------------------------------------------------------
    def supports_sweeps(self) -> bool:
        """The residence blend models a 3-level cache + Mem hierarchy."""
        return len(self.machine.capacities) == 3

    def stream_sweep(self, kernels, sizes_bytes, *,
                     sustained_bw=None) -> np.ndarray:
        from .. import simcache
        CAL_COUNTERS["measurements"] += 1
        _, vals = simcache.sweep_batch(list(kernels), sizes_bytes,
                                       machine=self.machine,
                                       sustained_bw=sustained_bw)
        return vals

    def stream_levels(self, kernels) -> np.ndarray:
        from .. import simcache
        CAL_COUNTERS["measurements"] += 1
        _, tab = simcache.simulate_levels_batch(list(kernels),
                                                machine=self.machine)
        return tab

    # -- stencil --------------------------------------------------------
    def stencil_sweep(self, name, problem_ns, *,
                      sustained_bw=None) -> np.ndarray:
        from .. import simcache
        CAL_COUNTERS["measurements"] += 1
        out = simcache.stencil_sweep_batch(name, problem_ns,
                                           machine=self.machine,
                                           sustained_bw=sustained_bw)
        return np.asarray(out["measured"], dtype=float)

    # -- power ----------------------------------------------------------
    def power_grid(self, n_cores, f_ghz) -> np.ndarray:
        """Package power draw (watts) for each (frequency, active-core)
        grid point — the RAPL-counter measurement of §III-D."""
        CAL_COUNTERS["measurements"] += 1
        p = self.machine.power
        return np.array([[p.watts(int(n), float(f)) for n in n_cores]
                         for f in f_ghz], dtype=float)

    # -- overlap --------------------------------------------------------
    def pipeline_pair(self) -> tuple:
        """(t_serial, t_pipelined, t_transfer) seconds for a reference
        compute-dominated step: the ``num_stages=1`` vs multi-buffered
        DMA-pipeline timing pair (``repro.kernels.pipeline``)."""
        from .tpu_ecm import TPU_V5E, TPUStepECM
        CAL_COUNTERS["measurements"] += 1
        step = TPUStepECM(name="calibrate-ref", t_comp=2e-3, t_hbm=1e-3,
                          t_ici=0.0,
                          exposed_hbm_fraction=TPU_V5E.exposed_hbm_fraction,
                          exposed_ici_fraction=0.0)
        return step.t_comp + step.t_hbm, step.t_ecm, step.t_hbm


# ---------------------------------------------------------------------------
# Fit primitives
# ---------------------------------------------------------------------------


def _snap(fitted: float, prior: float, snap_rtol: float) -> tuple:
    """(adopted, snapped): adopt the prior when the fit confirms it."""
    if fitted == prior:
        return prior, True
    if prior != 0 and abs(fitted - prior) <= snap_rtol * abs(prior):
        return prior, True
    return fitted, False


def _rms_rel(obs: np.ndarray, pred: np.ndarray) -> float:
    obs = np.asarray(obs, dtype=float)
    pred = np.asarray(pred, dtype=float)
    return float(np.sqrt(np.mean(((obs - pred) / obs) ** 2)))


def _affine_in_inv_bw(machine, workloads, bw_lo=10e9, bw_hi=40e9):
    """Exact ECM mem-level prediction coefficients ``t(bw) = a + c/bw``
    (verified affine: two probes determine the model everywhere)."""
    p_lo = lower_many(workloads, machine, sustained_bw=bw_lo,
                      table=False).batch.prediction(-1)
    p_hi = lower_many(workloads, machine, sustained_bw=bw_hi,
                      table=False).batch.prediction(-1)
    c = (p_lo - p_hi) / (1.0 / bw_lo - 1.0 / bw_hi)
    a = p_lo - c / bw_lo
    return a, c


def _bisect_bw(forward, obs: float, prior: float, *, iters: int = 52):
    """Invert a monotone-decreasing measurement response ``forward(bw)``
    for the sustained bandwidth matching ``obs`` (geometric bisection —
    the exact scalar nonlinear-least-squares solution).  Returns ``None``
    when ``obs`` is outside the bracketing response (unidentifiable)."""
    lo, hi = prior / 16.0, prior * 16.0
    if not (forward(hi) <= obs <= forward(lo)):
        return None
    for _ in range(iters):
        mid = math.sqrt(lo * hi)
        if forward(mid) > obs:
            lo = mid
        else:
            hi = mid
    return math.sqrt(lo * hi)


def _crossings(sizes: np.ndarray, curve: np.ndarray, level: float):
    """Log-interpolated first upward crossing of ``level``, or ``None``."""
    idx = np.nonzero((curve[:-1] < level) & (curve[1:] >= level))[0]
    if not len(idx):
        return None
    i = int(idx[0])
    f = (level - curve[i]) / (curve[i + 1] - curve[i])
    return math.exp(math.log(sizes[i])
                    + f * (math.log(sizes[i + 1]) - math.log(sizes[i])))


# ---------------------------------------------------------------------------
# Field-class fitters
# ---------------------------------------------------------------------------

def _deep_sizes(machine, n: int = 4) -> np.ndarray:
    cap = max(machine.capacities or (32 * 1024 * 1024,))
    return np.geomspace(16.0 * cap, 128.0 * cap, n)


def _fit_stream_bandwidths(machine, backend, snap_rtol, meas, fits):
    """measured_bw[kernel] for every simulator-measurable stream kernel,
    fitted jointly by vectorized geometric bisection."""
    kernels = [k for k in STREAM_KERNELS if k in machine.measured_bw]
    if not kernels or not backend.supports_sweeps():
        return {}
    sizes = _deep_sizes(machine)
    obs = backend.stream_sweep(kernels, sizes)          # (K, S) cy/CL
    meas.append(("stream_sweep", obs))
    obs_mean = obs.mean(axis=1)
    priors = np.array([machine.measured_bw[k] for k in kernels])
    lo, hi = priors / 16.0, priors * 16.0
    for _ in range(52):
        mid = np.sqrt(lo * hi)
        resp = backend.stream_sweep(
            kernels, sizes,
            sustained_bw={k: float(b) for k, b in zip(kernels, mid)})
        too_slow = resp.mean(axis=1) > obs_mean         # bw guess too low
        lo = np.where(too_slow, mid, lo)
        hi = np.where(too_slow, hi, mid)
    fitted = np.sqrt(lo * hi)
    # pure-ECM affine deviation at the adopted bandwidth (= model error)
    reg = workload_registry()
    ws = [reg[k] for k in kernels]
    a, c = _affine_in_inv_bw(machine, ws)
    out = {}
    adopted_all = {}
    for i, k in enumerate(kernels):
        adopted_all[k] = _snap(float(fitted[i]), float(priors[i]),
                               snap_rtol)
    refit = backend.stream_sweep(
        kernels, sizes,
        sustained_bw={k: v[0] for k, v in adopted_all.items()})
    for i, k in enumerate(kernels):
        adopted, snapped = adopted_all[k]
        fits.append(FieldFit(
            field=f"measured_bw[{k}]", group="bandwidth",
            prior=float(priors[i]), fitted=float(fitted[i]),
            adopted=adopted, residual=_rms_rel(obs[i], refit[i]),
            n_points=obs.shape[1], snapped=snapped,
            model_gap=_rms_rel(obs[i], a[i] + c[i] / adopted)))
        CAL_COUNTERS["fits"] += 1
        out[k] = adopted
    return out


def _fit_stencil_bandwidths(machine, backend, snap_rtol, meas, fits):
    out = {}
    if not backend.supports_sweeps():
        return out
    for k in STENCIL_KERNELS:
        if k not in machine.measured_bw:
            continue
        prior = float(machine.measured_bw[k])
        # deep problem sizes: past every layer-condition break
        n_deep = max(machine.capacities) // 24          # > C3/(LC*3*8)
        ns = np.geomspace(n_deep, 4 * n_deep, 3).astype(int)
        obs = backend.stencil_sweep(k, ns)
        meas.append((f"stencil_sweep[{k}]", obs))
        obs_mean = float(obs.mean())

        def forward(bw, _k=k, _ns=ns):
            return float(backend.stencil_sweep(_k, _ns,
                                               sustained_bw=bw).mean())

        fitted = _bisect_bw(forward, obs_mean, prior)
        if fitted is None:
            fits.append(FieldFit(
                field=f"measured_bw[{k}]", group="bandwidth", prior=prior,
                fitted=prior, adopted=prior, residual=0.0,
                n_points=len(ns), snapped=True,
                note="measurement response does not bracket the "
                     "observation; prior retained"))
        else:
            adopted, snapped = _snap(fitted, prior, snap_rtol)
            refit = backend.stencil_sweep(k, ns, sustained_bw=adopted)
            reg = workload_registry()
            a, c = _affine_in_inv_bw(machine, [reg[k]])
            fits.append(FieldFit(
                field=f"measured_bw[{k}]", group="bandwidth", prior=prior,
                fitted=fitted, adopted=adopted,
                residual=_rms_rel(obs, refit), n_points=len(ns),
                snapped=snapped,
                model_gap=_rms_rel(obs, float(a[0] + c[0] / adopted))))
            out[k] = adopted
        CAL_COUNTERS["fits"] += 1
    return out


def _fit_model_forward_bandwidths(machine, backend, snap_rtol, meas, fits):
    """Hierarchies the simulator cannot sweep (the two-level TPU view):
    invert the ECM forward model's deep-memory response directly — the
    affine ``t = a + c/bw`` solved in closed form."""
    out = {}
    reg = workload_registry()
    keys = [k for k in machine.measured_bw if not k.startswith("_")] \
        or ["_default"]
    ref = reg["copy"]
    for k in keys:
        prior = float(machine.sustained_bw(k, default=0.0)
                      or machine.measured_bw.get("_default", 0.0))
        w = reg.get(k, ref)
        obs = lower_many([w], machine, table=False).batch.prediction(-1)
        meas.append((f"model_forward[{k}]", obs))
        a, c = _affine_in_inv_bw(machine, [w],
                                 bw_lo=prior / 2.0, bw_hi=prior * 2.0)
        denom = float(obs[0] - a[0])
        if denom <= 0 or c[0] <= 0:
            fits.append(FieldFit(
                field=f"measured_bw[{k}]", group="bandwidth", prior=prior,
                fitted=prior, adopted=prior, residual=0.0, n_points=1,
                snapped=True, note="core-bound at the memory level; "
                                   "bandwidth unidentifiable"))
        else:
            fitted = float(c[0] / denom)
            adopted, snapped = _snap(fitted, prior, snap_rtol)
            fits.append(FieldFit(
                field=f"measured_bw[{k}]", group="bandwidth", prior=prior,
                fitted=fitted, adopted=adopted,
                residual=_rms_rel(obs, a + c / adopted), n_points=1,
                snapped=snapped, model_gap=0.0,
                note="ECM-forward inversion (no cache-simulator support "
                     "for this hierarchy)"))
            out[k] = adopted
        CAL_COUNTERS["fits"] += 1
    return out


def _fit_family_fallbacks(machine, fitted_bw, snap_rtol, fits):
    """The ``_stream``/``_stencil``/``_compute``/``_default`` family keys:
    refit as the median of their members' adopted values."""
    families = {
        "_stream": [k for k in STREAM_KERNELS if k in fitted_bw],
        "_stencil": [k for k in STENCIL_KERNELS if k in fitted_bw],
    }
    out = {}
    for fam, members in families.items():
        if fam not in machine.measured_bw:
            continue
        prior = float(machine.measured_bw[fam])
        if not members:
            fitted = prior
            note = "no fitted members; prior retained"
        else:
            fitted = float(np.median([fitted_bw[k] for k in members]))
            note = f"median of {len(members)} member fits"
        adopted, snapped = _snap(fitted, prior, snap_rtol)
        fits.append(FieldFit(
            field=f"measured_bw[{fam}]", group="bandwidth", prior=prior,
            fitted=fitted, adopted=adopted, residual=0.0,
            n_points=len(members), snapped=snapped, note=note))
        CAL_COUNTERS["fits"] += 1
        out[fam] = adopted
    for k in machine.measured_bw:
        if k in fitted_bw or k in out or k in ("_stream", "_stencil"):
            continue
        prior = float(machine.measured_bw[k])
        fits.append(FieldFit(
            field=f"measured_bw[{k}]", group="bandwidth", prior=prior,
            fitted=prior, adopted=prior, residual=0.0, n_points=0,
            snapped=True,
            note="no microbenchmark measurement for this kernel class "
                 "(core-bound or unsupported); prior retained"))
        CAL_COUNTERS["fits"] += 1
    return out


def _fit_capacities(machine, backend, snap_rtol, meas, fits, checks):
    """capacities[k] from the residence knees of the stream sweep, with
    the stencil layer-condition breaks as a recorded cross-check."""
    caps = list(machine.capacities)
    if not caps or not backend.supports_sweeps():
        for i, c in enumerate(caps):
            fits.append(FieldFit(
                field=f"capacities[{i}]", group="capacity", prior=float(c),
                fitted=float(c), adopted=float(c), residual=0.0,
                n_points=0, snapped=True,
                note="hierarchy not sweepable; prior retained"))
            CAL_COUNTERS["fits"] += 1
        return caps
    lo = max(1024.0, min(c for c in caps if c) / 16.0)
    hi = 32.0 * max(caps)
    sizes = np.geomspace(lo, hi, 240)
    curve = backend.stream_sweep(["copy"], sizes)[0]
    plateaus = backend.stream_levels(["copy"])[0]       # (L,) per level
    meas.append(("capacity_sweep", curve))
    meas.append(("capacity_plateaus", plateaus))
    adopted_caps = []
    for k, prior_c in enumerate(caps):
        mid = (plateaus[k] + plateaus[k + 1]) / 2.0
        ws = _crossings(sizes, curve, mid)
        if ws is None:
            fits.append(FieldFit(
                field=f"capacities[{k}]", group="capacity",
                prior=float(prior_c), fitted=float(prior_c),
                adopted=float(prior_c), residual=0.0,
                n_points=len(sizes), snapped=True,
                note="no residence knee found (capacity 0 or outside the "
                     "sweep); prior retained"))
            adopted_caps.append(prior_c)
        else:
            # hit weight clamp(2C/ws - 1) is 1/2 at ws = 4C/3
            fitted = 0.75 * ws
            adopted, snapped = _snap(fitted, float(prior_c), snap_rtol)
            adopted = int(round(adopted))
            fits.append(FieldFit(
                field=f"capacities[{k}]", group="capacity",
                prior=float(prior_c), fitted=fitted, adopted=float(adopted),
                residual=abs(fitted - adopted) / max(adopted, 1),
                n_points=len(sizes), snapped=snapped))
            adopted_caps.append(adopted)
        CAL_COUNTERS["fits"] += 1
    # stencil layer-condition cross-check: C = 2 * (2r+1) * 8 B * N_break
    try:
        breaks = _stencil_lc_breaks(machine, backend, adopted_caps, meas)
        checks["stencil_lc_breaks"] = breaks
    except Exception as e:  # noqa: BLE001 - cross-check only; recorded, never fails calibration
        checks["stencil_lc_breaks"] = {"error": f"{type(e).__name__}: {e}"}
    return adopted_caps


def _stencil_lc_breaks(machine, backend, caps, meas) -> dict:
    """Locate the jacobi2d layer-condition breaks in the measured stencil
    sweep; each break at ``N`` implies ``C = 48 N`` (3 rows x 8 B x
    LC-safety 2).  Returned per level as an independent capacity estimate."""
    out = {}
    for k, cap in enumerate(caps):
        if not cap:
            continue
        n_break = cap / 48.0
        ns = np.geomspace(n_break / 3.0, n_break * 3.0, 64).astype(int)
        obs = backend.stencil_sweep("jacobi2d", ns)
        meas.append((f"stencil_lc[{k}]", obs))
        steps = np.diff(obs) / obs[:-1]
        i = int(np.argmax(steps))
        if steps[i] <= 1e-6:
            out[f"L{k + 1}"] = {"detected": False}
            continue
        n_star = math.sqrt(float(ns[i]) * float(ns[i + 1]))
        est = 48.0 * n_star
        out[f"L{k + 1}"] = {
            "detected": True, "n_break": n_star, "capacity_est": est,
            "vs_adopted": est / cap,
        }
    return out


def _fit_power(machine, backend, snap_rtol, meas, fits) -> ChipPower:
    """ChipPower coefficients by OLS over the (cores x frequency) energy
    grid (§III-D).  Needs >= 3 operating frequencies to be full-rank."""
    prior = machine.power
    f_grid = machine.frequency_grid()
    n_grid = list(range(1, machine.cores + 1))
    names = ("idle_watts", "static_per_core", "dyn_lin", "dyn_quad")
    if len(set(f_grid)) < 3 or len(n_grid) < 2:
        for nm in names:
            p = float(getattr(prior, nm))
            fits.append(FieldFit(
                field=f"power.{nm}", group="power", prior=p, fitted=p,
                adopted=p, residual=0.0, n_points=0, snapped=True,
                note="fewer than 3 DVFS points: P(n,f) design matrix is "
                     "rank-deficient; priors retained"))
            CAL_COUNTERS["fits"] += 1
        return prior
    grid = backend.power_grid(n_grid, f_grid)           # (F, N)
    meas.append(("power_grid", grid))
    rows, y = [], []
    for i, f in enumerate(f_grid):
        for j, n in enumerate(n_grid):
            rows.append([1.0, n, n * f, n * f * f])
            y.append(grid[i, j])
    A = np.array(rows)
    yv = np.array(y)
    coef, *_ = np.linalg.lstsq(A, yv, rcond=None)
    resid = _rms_rel(yv, A @ coef)
    kwargs = {}
    for nm, fitted in zip(names, coef):
        p = float(getattr(prior, nm))
        adopted, snapped = _snap(float(fitted), p, snap_rtol)
        fits.append(FieldFit(
            field=f"power.{nm}", group="power", prior=p,
            fitted=float(fitted), adopted=adopted, residual=resid,
            n_points=len(yv), snapped=snapped))
        CAL_COUNTERS["fits"] += 1
        kwargs[nm] = adopted
    return ChipPower(**kwargs)


def _fit_overlap(machine, backend, snap_rtol, meas, fits) -> None:
    """exposed_hbm_fraction from the serial-vs-pipelined delta (software-
    managed hierarchies only; recorded in provenance — the coefficient
    lives on ``TPUMachineModel``, not the hierarchy machine dict)."""
    if machine.write_allocate:
        return                                      # hardware-managed CPU
    from .tpu_ecm import TPU_V5E, measured_overlap
    t_serial, t_pipelined, t_hbm = backend.pipeline_pair()
    meas.append(("pipeline_pair",
                 np.array([t_serial, t_pipelined, t_hbm])))
    prior = float(TPU_V5E.exposed_hbm_fraction)
    fitted = float(measured_overlap(t_serial, t_pipelined, t_hbm))
    adopted, snapped = _snap(fitted, prior, snap_rtol)
    fits.append(FieldFit(
        field="tpu.exposed_hbm_fraction", group="overlap", prior=prior,
        fitted=fitted, adopted=adopted, residual=abs(fitted - prior),
        n_points=2, snapped=snapped,
        note="applies to TPUMachineModel via tpu_ecm.with_measured_overlap"))
    CAL_COUNTERS["fits"] += 1


# ---------------------------------------------------------------------------
# The runner
# ---------------------------------------------------------------------------


def calibrate(machine: "MachineModel | str" = "haswell-ep", *,
              backend=None, snap_rtol: float = SNAP_RTOL,
              use_cache: bool = True) -> CalibrationReport:
    """Run the full measure->fit cycle against ``machine``'s prior.

    Returns a :class:`CalibrationReport`; ``report.save(path)`` emits the
    versioned machine file.  With the disk cache enabled
    (:mod:`repro.core.diskcache`), a repeat run with the same prior,
    backend, and tolerance is served from disk with **zero re-fitting**
    (``report.from_cache`` is set and ``CAL_COUNTERS['fits']`` does not
    move).
    """
    prior_m = get_machine(machine)
    backend = backend or SimcacheBackend(prior_m)
    cache_key = ("report", backend.name, float(snap_rtol))
    if use_cache:
        hit = diskcache.get(_CAL_CACHE_KIND, cache_key, machine=prior_m)
        if hit is not None:
            CAL_COUNTERS["cache_hits"] += 1
            return CalibrationReport.from_literal(hit, from_cache=True)

    t0 = time.perf_counter()
    fits: list = []
    meas: list = []
    checks: dict = {}
    if backend.supports_sweeps():
        fitted_bw = _fit_stream_bandwidths(prior_m, backend, snap_rtol,
                                           meas, fits)
        fitted_bw.update(_fit_stencil_bandwidths(prior_m, backend,
                                                 snap_rtol, meas, fits))
    else:
        fitted_bw = _fit_model_forward_bandwidths(prior_m, backend,
                                                  snap_rtol, meas, fits)
    fitted_bw.update(
        _fit_family_fallbacks(prior_m, fitted_bw, snap_rtol, fits))
    caps = _fit_capacities(prior_m, backend, snap_rtol, meas, fits, checks)
    power = _fit_power(prior_m, backend, snap_rtol, meas, fits)
    _fit_overlap(prior_m, backend, snap_rtol, meas, fits)

    bw = dict(prior_m.measured_bw)
    bw.update(fitted_bw)
    fitted_m = dataclasses.replace(
        prior_m, measured_bw=bw, capacities=tuple(int(c) for c in caps),
        power=power)
    wall = time.perf_counter() - t0
    h = hashlib.sha256()
    for label, arr in meas:
        h.update(label.encode())
        h.update(repr(np.asarray(arr).tolist()).encode())
    report = CalibrationReport(
        base=prior_m.name, machine=fitted_m, fits=tuple(fits),
        measurement_hash=h.hexdigest(), backend=backend.name,
        snap_rtol=snap_rtol, wall_s=wall, checks=checks)
    if use_cache:
        diskcache.put(_CAL_CACHE_KIND, cache_key, report.to_literal(),
                      machine=prior_m)
    return report


def format_report(report: CalibrationReport) -> str:
    """Human-readable fit table for the launch CLI."""
    lines = [
        f"calibration of {report.base!r} "
        f"(backend={report.backend}, snap_rtol={report.snap_rtol:g}"
        + (", cached" if report.from_cache else "") + ")",
        f"{'field':34s} {'prior':>12s} {'fitted':>12s} "
        f"{'adopted':>12s} {'resid':>7s} {'gap':>6s}  snap",
    ]
    for f in report.fits:
        lines.append(
            f"{f.field:34s} {f.prior:12.5g} {f.fitted:12.5g} "
            f"{f.adopted:12.5g} {f.residual:7.4f} {f.model_gap:6.3f}  "
            f"{'yes' if f.snapped else 'NO'}"
            + (f"  ({f.note})" if f.note else ""))
    lines.append(
        f"max residual {report.residual_max():.3f}; "
        f"{sum(1 for f in report.fits if f.snapped)}/{len(report.fits)} "
        f"fields snapped to prior; wall {report.wall_s:.2f}s; "
        f"measurements sha256 {report.measurement_hash[:16]}")
    return "\n".join(lines)
