"""Machine models for the ECM performance model.

A :class:`MachineModel` captures everything the ECM model needs to know about
a processor: clock, unit-of-work granularity (cache line / VMEM block), the
per-level transfer bandwidths of the memory hierarchy, and an in-core issue
model (ports for the CPU, MXU/VPU/DMA occupancy for the TPU).

Two concrete machines ship with the library:

* ``HASWELL_EP`` — the paper's testbed (Xeon E5-2695 v3, Table II), used to
  reproduce the paper's Table I / Figs. 7-12 numbers exactly.
* ``TPU_V5E`` — the adaptation target for the JAX/Pallas framework.  The
  hierarchy becomes VREG <- VMEM <- HBM <- ICI <- DCN and the port model is
  replaced by MXU/VPU issue throughput.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass


# ---------------------------------------------------------------------------
# Generic building blocks
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TransferLevel:
    """One edge of the memory hierarchy (e.g. the L1<->L2 data path).

    Bandwidths are in bytes per core cycle.  ``load_bpc`` is the bandwidth
    towards the core, ``evict_bpc`` the bandwidth away from the core (the two
    differ on Haswell: 64 B/c L2->L1 but 32 B/c L1->L2 eviction).
    """

    name: str
    load_bpc: float
    evict_bpc: float

    def load_cycles(self, n_lines: float, line_bytes: int) -> float:
        return n_lines * line_bytes / self.load_bpc

    def evict_cycles(self, n_lines: float, line_bytes: int) -> float:
        return n_lines * line_bytes / self.evict_bpc


@dataclass(frozen=True)
class PortModel:
    """Simplified Haswell-style issue/port model (paper §III-A, §V).

    Only throughput is modelled (the ECM model is a light-speed model:
    hazards, dependencies and latencies are neglected by design).  Resource
    classes and their port counts:

    * ``n_load_ports``  — AVX loads (ports 2/3)
    * ``n_store_ports`` — AVX store-data (port 4)
    * ``n_full_agu``    — full AGUs supporting base+index+offset (ports 2/3)
    * ``n_simple_agu``  — the Haswell port-7 simple AGU; usable for streaming
      kernels only with the LEA pre-computation trick (§VII-C), enabled via
      ``optimized_agu=True``
    * ``n_fma`` / ``n_mul`` (ports 0/1) and ``n_add`` (port 1 only)
    """

    n_load_ports: int = 2
    n_store_ports: int = 1
    n_full_agu: int = 2
    n_simple_agu: int = 1
    n_fma: int = 2
    n_mul: int = 2
    n_add: int = 1
    retire_width: int = 4

    def core_cycles(
        self,
        *,
        loads: int = 0,
        stores: int = 0,
        fma: int = 0,
        mul: int = 0,
        add: int = 0,
        optimized_agu: bool = False,
    ) -> tuple[float, float]:
        """Return ``(t_nol, t_ol)`` in cycles for one unit of work.

        ``t_nol`` — cycles in which loads/stores retire; by the ECM model's
        assumption (i) these do not overlap with any transfer in the
        hierarchy.  ``t_ol`` — everything else (arithmetic), which does.
        """
        agus = self.n_full_agu + (self.n_simple_agu if optimized_agu else 0)
        t_nol = max(
            math.ceil(loads / self.n_load_ports) if loads else 0,
            math.ceil(stores / self.n_store_ports) if stores else 0,
            math.ceil((loads + stores) / agus) if (loads + stores) else 0,
        )
        t_ol = max(
            math.ceil(fma / self.n_fma) if fma else 0,
            math.ceil(mul / self.n_mul) if mul else 0,
            math.ceil(add / self.n_add) if add else 0,
        )
        return float(t_nol), float(t_ol)


# ---------------------------------------------------------------------------
# Machine model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MachineModel:
    """Everything the ECM model needs to know about one processor."""

    name: str
    clock_hz: float
    line_bytes: int                      # unit-of-work transfer granule
    simd_bytes: int                      # register width for load/store ops
    levels: tuple[TransferLevel, ...]    # in-cache hierarchy edges, inner->outer
    mem_level_name: str                  # name of the final (measured-bw) edge
    ports: PortModel
    cores: int = 1
    # peak compute, for roofline-style cross-checks
    flops_per_cycle_dp: float = 16.0
    flops_per_cycle_sp: float = 32.0
    # empirical off-core latency penalty (paper §VII-A): cycles per load
    # stream per cache level beyond L2, for kernels with low cy/CL counts
    offcore_penalty_cy: float = 1.0

    # ------------------------------------------------------------------
    def mem_cycles_per_line(self, sustained_bw_bytes_per_s: float) -> float:
        """Convert a measured sustained memory bandwidth into cy/CL
        (paper §IV-A: other clock domains are converted into core cycles)."""
        return self.line_bytes * self.clock_hz / sustained_bw_bytes_per_s

    def level_names(self) -> tuple[str, ...]:
        """Prediction-level names, innermost first (e.g. L1, L2, L3, Mem)."""
        names = ["L1"]
        for lvl in self.levels:
            names.append(lvl.name.split("<->")[-1].split("->")[-1])
        names.append(self.mem_level_name)
        return tuple(names)

    def with_cores(self, n: int) -> "MachineModel":
        return dataclasses.replace(self, cores=n)


# ---------------------------------------------------------------------------
# The paper's testbed: Xeon E5-2695 v3 (Haswell-EP), Table II
# ---------------------------------------------------------------------------

HASWELL_EP = MachineModel(
    name="haswell-ep-2695v3",
    clock_hz=2.3e9,
    line_bytes=64,
    simd_bytes=32,                       # AVX
    levels=(
        # register<-L1 is captured by the port model, not a TransferLevel.
        TransferLevel("L1<->L2", load_bpc=64.0, evict_bpc=32.0),
        TransferLevel("L2<->L3", load_bpc=32.0, evict_bpc=32.0),
    ),
    mem_level_name="Mem",
    ports=PortModel(),
    cores=14,
    flops_per_cycle_dp=16.0,
    flops_per_cycle_sp=32.0,
)

#: Sustained single-memory-domain (CoD) bandwidths measured in the paper, in
#: bytes/s, keyed by benchmark.  These are *calibration inputs* of the model
#: (the paper measures them with likwid-bench); they are not predictions.
HASWELL_MEASURED_BW = {
    "ddot": 32.4e9,
    "load": 32.4e9,          # footnote 2: identical to ddot
    "store": 23.6e9,
    "update": 23.6e9,        # "almost identical to that of the store kernel"
    "copy": 26.3e9,
    "striad": 27.1e9,
    "schoenauer": 27.8e9,
    "striad_nt": 28.3e9,
    "schoenauer_nt": 29.0e9,
}

#: Non-CoD sustained chip bandwidths (both memory controllers, Fig. 10/11).
#: The paper gives CoD ~= 1.08x non-CoD for most kernels; we use the chip
#: bandwidth ~= 52.3 GB/s stream-triad figure scaled per kernel class.
HASWELL_CHIP_BW_NONCOD = {k: 1.85 * v for k, v in HASWELL_MEASURED_BW.items()}


# ---------------------------------------------------------------------------
# Adaptation target: TPU v5e
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TPUMachineModel:
    """TPU machine constants for the TPU-ECM model (per chip).

    The TPU hierarchy is software-managed: VREG <- VMEM <- HBM, with ICI
    links between chips inside a pod and DCN between pods.  There is no
    write-allocate: Pallas ``out_specs`` / XLA output buffers stream whole
    blocks (the "non-temporal store" of the paper is the default, see
    DESIGN.md §3).
    """

    name: str = "tpu-v5e"
    clock_hz: float = 0.94e9
    peak_bf16_flops: float = 197e12          # per chip
    peak_f32_flops: float = 49.25e12
    hbm_bytes_per_s: float = 819e9           # per chip
    hbm_bytes: int = 16 * 1024**3
    vmem_bytes: int = 128 * 1024**2
    ici_link_bytes_per_s: float = 50e9       # per link per direction
    ici_links_per_chip: int = 4              # 2D torus: +/-x, +/-y
    dcn_bytes_per_s: float = 25e9            # per host, pod-to-pod
    # MXU shape: 128x128 systolic; VPU: 8x128 lanes
    mxu_dim: int = 128
    vpu_lanes: int = 8 * 128
    # energy model (approximate public figures, used for the Fig. 5/6
    # analogue only — relative structure matters, not absolute joules)
    pj_per_flop: float = 0.35
    pj_per_hbm_byte: float = 15.0
    pj_per_ici_byte: float = 30.0
    idle_watts: float = 70.0
    peak_watts: float = 220.0

    # ------------------------------------------------------------------
    def compute_seconds(self, flops: float, dtype_peak: float | None = None) -> float:
        return flops / (dtype_peak or self.peak_bf16_flops)

    def hbm_seconds(self, nbytes: float) -> float:
        return nbytes / self.hbm_bytes_per_s

    def ici_seconds(self, nbytes: float, links: int | None = None) -> float:
        links = links or self.ici_links_per_chip
        return nbytes / (self.ici_link_bytes_per_s * links)

    def hbm_bytes_per_cycle(self) -> float:
        return self.hbm_bytes_per_s / self.clock_hz     # ~871 B/cy

    def mxu_flops_per_cycle_bf16(self) -> float:
        return self.peak_bf16_flops / self.clock_hz


TPU_V5E = TPUMachineModel()
