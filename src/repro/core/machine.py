"""Machine models and the machine registry for the ECM performance model.

A :class:`MachineModel` captures everything the ECM model needs to know about
a processor: clock, unit-of-work granularity (cache line / VMEM block), the
per-level transfer bandwidths of the memory hierarchy, per-level cache
capacities (for layer-condition / residence analysis), an in-core issue
model (ports for the CPU, VPU occupancy for the TPU), and the machine's
*calibration data* — the measured sustained memory bandwidths that the
paper (§IV-A) feeds the model as inputs, keyed by kernel class.

Machines are **declarative**: a new generation is a single ``MachineModel``
literal (bandwidth/issue tables + calibration dict) registered with
:func:`register_machine`; no per-machine code exists anywhere downstream —
the unified workload engine (``repro.core.workload``) evaluates any
registered workload on any registered machine.

The shipped zoo (see ``docs/machines.md``):

* ``haswell-ep`` — the paper's testbed (Xeon E5-2695 v3, Table II); every
  Table I / Figs. 7-12 number is pinned bit-identical against it.
* ``sandy-bridge-ep`` — Xeon E5-2680: half-width (16 B) L1 data paths, no
  FMA, 32 B/cy L2 bandwidth (arXiv:1702.07554 generation study).
* ``broadwell-ep`` — Xeon E5-2699 v4: Haswell-like hierarchy, DDR4-2400.
* ``skylake-sp`` — Xeon Gold 6148: AVX-512, 1 MiB private L2 and a
  **non-inclusive victim L3** — loads stream from memory directly into L2
  and the L2<->L3 edge carries victim/write-back traffic only, so the
  per-level traffic of the same workload genuinely differs from the
  inclusive-L3 machines (arXiv:1702.07554 / the SKX follow-up).
* ``tpu-v5e`` — hierarchy view of the TPU adaptation target: VREG <- VMEM
  <- HBM, software-managed (no write-allocate: stores are non-temporal by
  construction, the §VII-E observation as a machine property).

``TPU_V5E`` (a :class:`TPUMachineModel`) additionally carries the
three-term step-model constants (MXU/ICI/DCN) used by ``core.tpu_ecm``.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import warnings
from dataclasses import dataclass, field
from pathlib import Path


# ---------------------------------------------------------------------------
# Generic building blocks
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TransferLevel:
    """One edge of the memory hierarchy (e.g. the L1<->L2 data path).

    Bandwidths are in bytes per core cycle.  ``load_bpc`` is the bandwidth
    towards the core, ``evict_bpc`` the bandwidth away from the core (the two
    differ on Haswell: 64 B/c L2->L1 but 32 B/c L1->L2 eviction).
    """

    name: str
    load_bpc: float
    evict_bpc: float

    def load_cycles(self, n_lines: float, line_bytes: int) -> float:
        return n_lines * line_bytes / self.load_bpc

    def evict_cycles(self, n_lines: float, line_bytes: int) -> float:
        return n_lines * line_bytes / self.evict_bpc


@dataclass(frozen=True)
class ChipPower:
    """Chip power as a function of active cores and frequency (GHz):
    ``P(n, f) = idle + n * (static + lin * f + quad * f**2)`` (§III-D).

    This is per-machine *calibration data*, carried on
    :attr:`MachineModel.power` the same way ``measured_bw`` carries the
    sustained-bandwidth inputs.  The defaults are the Haswell-EP
    calibration (single-core package power ~40-55 W, Haswell-vs-SNB/IVB
    energy ratio 1.12-1.23x, EDP ratio 1.35-1.55x).
    """

    idle_watts: float = 25.0
    static_per_core: float = 0.5       # W per active core
    dyn_lin: float = 0.3               # W per core per GHz
    dyn_quad: float = 2.2              # W per core per GHz^2

    def watts(self, n_cores, f_ghz):
        """Power draw; accepts scalars or broadcastable NumPy arrays."""
        return self.idle_watts + n_cores * (
            self.static_per_core + self.dyn_lin * f_ghz
            + self.dyn_quad * f_ghz**2
        )


@dataclass(frozen=True)
class PortModel:
    """Simplified Haswell-style issue/port model (paper §III-A, §V).

    Only throughput is modelled (the ECM model is a light-speed model:
    hazards, dependencies and latencies are neglected by design).  Resource
    classes and their port counts:

    * ``n_load_ports``  — AVX loads (ports 2/3)
    * ``n_store_ports`` — AVX store-data (port 4)
    * ``n_full_agu``    — full AGUs supporting base+index+offset (ports 2/3)
    * ``n_simple_agu``  — the Haswell port-7 simple AGU; usable for streaming
      kernels only with the LEA pre-computation trick (§VII-C), enabled via
      ``optimized_agu=True``
    * ``n_fma`` / ``n_mul`` (ports 0/1) and ``n_add`` (port 1 only).  A
      machine without FMA units (``n_fma=0``, e.g. Sandy Bridge) executes
      each FMA as a separate multiply and add uop.  Contraction MACs
      (``dot`` uops, the matmul/attention inner products) are ordinary
      FMAs on a CPU — only machines with a matrix unit treat them
      differently (see :class:`VPUIssueModel`).
    * ``load_issue_cycles`` / ``store_issue_cycles`` — cycles one
      full-width vector op occupies its port (2.0 on Sandy Bridge: 16 B
      data paths moving 32 B AVX registers).
    """

    n_load_ports: int = 2
    n_store_ports: int = 1
    n_full_agu: int = 2
    n_simple_agu: int = 1
    n_fma: int = 2
    n_mul: int = 2
    n_add: int = 1
    retire_width: int = 4
    load_issue_cycles: float = 1.0
    store_issue_cycles: float = 1.0

    def core_cycles(
        self,
        *,
        loads: float = 0,
        stores: float = 0,
        fma: float = 0,
        mul: float = 0,
        add: float = 0,
        dot: float = 0,
        optimized_agu: bool = False,
    ) -> tuple[float, float]:
        """Return ``(t_nol, t_ol)`` in cycles for one unit of work.

        ``t_nol`` — cycles in which loads/stores retire; by the ECM model's
        assumption (i) these do not overlap with any transfer in the
        hierarchy.  ``t_ol`` — everything else (arithmetic), which does.
        """
        fma = fma + dot                         # contraction MACs = FMAs
        if not self.n_fma:                      # no FMA units: mul + add uops
            mul = mul + fma
            add = add + fma
            fma = 0
        agus = self.n_full_agu + (self.n_simple_agu if optimized_agu else 0)
        lc = loads * self.load_issue_cycles
        sc = stores * self.store_issue_cycles
        t_nol = max(
            math.ceil(lc / self.n_load_ports) if loads else 0,
            math.ceil(sc / self.n_store_ports) if stores else 0,
            math.ceil((lc + sc) / agus) if (loads + stores) else 0,
        )
        t_ol = max(
            math.ceil(fma / self.n_fma) if fma else 0,
            math.ceil(mul / self.n_mul) if mul else 0,
            math.ceil(add / self.n_add) if add else 0,
        )
        return float(t_nol), float(t_ol)


@dataclass(frozen=True)
class VPUIssueModel:
    """TPU in-core issue model: a ``lanes_per_cycle``-wide vector unit.

    All vector arithmetic overlaps with DMA (``t_ol``); there is no
    non-overlapping load/store retirement phase — data movement is the
    explicit DMA modelled by the transfer edges, so ``t_nol = 0``.  Duck-
    types :meth:`PortModel.core_cycles`.

    ``mxu_vectors_per_cycle`` is the matrix-unit throughput for
    contraction MACs (``dot`` uops) in canonical uops per cycle; ``0``
    means no matrix unit and ``dot`` executes on the VPU like any other
    FMA.  When set, the MXU systolic throughput *replaces* the FMA port
    model for matmul-class workloads while element-wise mul/add/fma stay
    on the VPU — compute time is the max of the two pipes (they issue
    concurrently).
    """

    vectors_per_cycle: float = 8.0      # 8 x 128-lane VPU sub-units
    mxu_vectors_per_cycle: float = 0.0  # 0 = no matrix unit

    def core_cycles(self, *, loads: float = 0, stores: float = 0,
                    fma: float = 0, mul: float = 0, add: float = 0,
                    dot: float = 0, optimized_agu: bool = False
                    ) -> tuple[float, float]:
        if dot and not self.mxu_vectors_per_cycle:
            fma = fma + dot             # no MXU: contractions run on the VPU
            dot = 0.0
        vec_ops = max(fma + mul + add, 0.0 if dot else 1.0)
        t_ol = vec_ops / self.vectors_per_cycle
        if dot:
            t_ol = max(t_ol, dot / self.mxu_vectors_per_cycle)
        return 0.0, t_ol


# ---------------------------------------------------------------------------
# Machine model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MachineModel:
    """Everything the ECM model needs to know about one processor."""

    name: str
    clock_hz: float
    line_bytes: int                      # unit-of-work transfer granule
    simd_bytes: int                      # register width for load/store ops
    levels: tuple[TransferLevel, ...]    # in-cache hierarchy edges, inner->outer
    mem_level_name: str                  # name of the final (measured-bw) edge
    ports: PortModel | VPUIssueModel
    cores: int = 1
    # peak compute, for roofline-style cross-checks
    flops_per_cycle_dp: float = 16.0
    flops_per_cycle_sp: float = 32.0
    # empirical off-core latency penalty (paper §VII-A): cycles per load
    # stream per cache level beyond L2, for kernels with low cy/CL counts
    offcore_penalty_cy: float = 1.0
    # ---- hierarchy / traffic semantics --------------------------------
    #: capacity in bytes of cache level i (innermost first; one entry per
    #: prediction level short of the memory level).  For machines with a
    #: segmented LLC (CoD / SNC) this is the per-affinity-domain slice,
    #: matching the per-domain ``measured_bw`` calibration.
    capacities: tuple[int, ...] = ()
    #: non-inclusive victim LLC (Skylake-SP): loads stream from memory
    #: directly into L2; the LLC edge carries victim + write-back traffic
    #: only.  Consumed by ``workload.route_traffic`` — the single place
    #: hierarchy semantics turn logical streams into per-edge lines.
    victim_l3: bool = False
    #: hardware write-allocate on store miss.  ``False`` for software-
    #: managed hierarchies (TPU): RFO streams vanish and write-backs become
    #: non-temporal streams (whole-block ``out_specs`` writes, §VII-E).
    write_allocate: bool = True
    first_level_name: str = "L1"
    # ---- calibration data ---------------------------------------------
    #: measured sustained memory-domain bandwidths in bytes/s, keyed by
    #: kernel name, with ``_stream`` / ``_stencil`` / ``_default`` family
    #: fallbacks.  These are *calibration inputs* of the model (the paper
    #: measures them with likwid-bench); they are not predictions.
    measured_bw: dict = field(default_factory=dict)
    #: explicit uop scale; 0.0 = auto (``line_bytes / simd_bytes / 2``,
    #: i.e. workload uop counts are canonical per 32 B vector on a 64 B
    #: line and shrink on wider SIMD).
    uop_scale: float = 0.0
    # ---- multi-core topology ------------------------------------------
    cores_per_domain: int = 0            # 0 = all cores in one domain
    n_domains: int = 1
    # ---- chip-level calibration: DVFS grid + power model (§III-D) -----
    #: power coefficients for the energy/EDP analysis; per-machine
    #: calibration like ``measured_bw`` (defaults: the Haswell fit).
    power: ChipPower = ChipPower()
    #: nominal core frequency in GHz; 0.0 = derive from ``clock_hz``.
    f_nominal_ghz: float = 0.0
    #: DVFS operating frequencies in GHz for the energy grids;
    #: () = fixed-frequency part (just the nominal clock).
    f_steps_ghz: tuple = ()
    #: sustained memory bandwidth degrades at low core frequency
    #: (paper Fig. 4: true on SNB/IVB, false on Haswell — the Uncore
    #: clock decouples from the core clock there).
    bw_freq_coupled: bool = False
    #: bandwidth floor for coupled machines: 1.2 GHz gives ~2/3 bandwidth
    coupling_floor: float = 2.0 / 3.0

    # ------------------------------------------------------------------
    def mem_cycles_per_line(self, sustained_bw_bytes_per_s: float) -> float:
        """Convert a measured sustained memory bandwidth into cy/CL
        (paper §IV-A: other clock domains are converted into core cycles)."""
        return self.line_bytes * self.clock_hz / sustained_bw_bytes_per_s

    def level_names(self) -> tuple[str, ...]:
        """Prediction-level names, innermost first (e.g. L1, L2, L3, Mem)."""
        names = [self.first_level_name]
        for lvl in self.levels:
            names.append(lvl.name.split("<->")[-1].split("->")[-1])
        names.append(self.mem_level_name)
        return tuple(names)

    def with_cores(self, n: int) -> "MachineModel":
        return dataclasses.replace(self, cores=n)

    @property
    def nominal_ghz(self) -> float:
        """Nominal core frequency in GHz (the ECM models' clock domain)."""
        return self.f_nominal_ghz or self.clock_hz / 1e9

    def frequency_grid(self) -> tuple[float, ...]:
        """DVFS operating points for the energy/EDP grids; machines
        without a calibrated grid run at the nominal clock only."""
        return self.f_steps_ghz or (self.nominal_ghz,)

    # ------------------------------------------------------------------
    # Calibration lookup + in-core issue (the two machine-specific hooks
    # of the unified workload engine)
    # ------------------------------------------------------------------
    def sustained_bw(self, *keys: str, default: float | None = None) -> float:
        """Walk a calibration-key chain (kernel name, then family fallback,
        then ``_default``) through :attr:`measured_bw`."""
        for k in (*keys, "_default"):
            if k in self.measured_bw:
                return self.measured_bw[k]
        if default is not None:
            return default
        raise KeyError(
            f"no sustained-bandwidth calibration for {keys!r} on machine "
            f"{self.name!r}: add an entry to measured_bw or pass "
            f"sustained_bw explicitly")

    @property
    def effective_uop_scale(self) -> float:
        """Workload uop counts are canonical per cache line with 32 B SIMD
        (Table I's accounting); wider registers need fewer uops."""
        if self.uop_scale:
            return self.uop_scale
        return self.line_bytes / self.simd_bytes / 2.0

    def core_cycles(self, *, loads: float = 0, stores: float = 0,
                    fma: float = 0, mul: float = 0, add: float = 0,
                    dot: float = 0, optimized_agu: bool = False
                    ) -> tuple[float, float]:
        """SIMD-width-scaled in-core times; the unified engine's entry to
        the machine's issue model."""
        s = self.effective_uop_scale
        return self.ports.core_cycles(
            loads=loads * s, stores=stores * s, fma=fma * s, mul=mul * s,
            add=add * s, dot=dot * s, optimized_agu=optimized_agu)


# ---------------------------------------------------------------------------
# Machine registry
# ---------------------------------------------------------------------------

MACHINES: dict[str, MachineModel] = {}
_ALIASES: dict[str, str] = {}

#: Registry-change observers, called with the machine just (re)registered.
#: ``repro.core.engine`` appends its invalidation hook here at import time
#: (a hook list instead of a direct call keeps this module engine-free).
_REGISTRY_HOOKS: list = []


def register_machine(machine: "MachineModel | dict | str | os.PathLike",
                     *aliases: str) -> MachineModel:
    """Register a machine (and optional aliases) for name-based lookup.

    ``machine`` may be a :class:`MachineModel`, a declarative dict (see
    :func:`machine_from_dict`), or the path of a versioned machine file
    (see :func:`load_machine_file`) — all three register identically, so a
    freshly calibrated on-disk file is a first-class zoo citizen.

    Re-registering a name is the supported way to publish a calibration
    update (new ``measured_bw`` / capacities / power fit): observers in
    ``_REGISTRY_HOOKS`` — the lowered-record table in
    :mod:`repro.core.engine` — are notified so rows lowered against the
    replaced calibration are rebuilt on next access.  Mutating a registered
    machine's ``measured_bw`` dict in place is outside that contract.
    """
    if isinstance(machine, dict):
        machine = machine_from_dict(machine)
    elif isinstance(machine, (str, os.PathLike)):
        machine = load_machine_file(machine)
    MACHINES[machine.name] = machine
    for a in aliases:
        _ALIASES[a] = machine.name
    for hook in _REGISTRY_HOOKS:
        hook(machine)
    return machine


def get_machine(name_or_model: "str | MachineModel") -> MachineModel:
    """Resolve a machine by registry name/alias; models pass through."""
    if isinstance(name_or_model, MachineModel):
        return name_or_model
    key = _ALIASES.get(name_or_model, name_or_model)
    try:
        return MACHINES[key]
    except KeyError:
        raise KeyError(
            f"unknown machine {name_or_model!r}; registered: "
            f"{sorted(MACHINES)}") from None


def machine_names() -> tuple[str, ...]:
    return tuple(sorted(MACHINES))


# ---------------------------------------------------------------------------
# Declarative serialization: machine dicts and versioned machine files
# ---------------------------------------------------------------------------
# A machine is data, so it round-trips losslessly through a plain dict (and
# hence JSON): ``machine_from_dict(machine_to_dict(m)) == m`` bit-identically
# for every zoo machine (golden-pinned in tests).  The on-disk *machine file*
# wraps the dict in a versioned envelope with optional calibration
# provenance (fit residuals, measurement hashes — see ``core.calibrate``):
#
#     {"schema": 1, "kind": "ecm-machine",
#      "machine": {...machine_to_dict...},
#      "provenance": {...}}            # optional
#
# The checked-in zoo lives as such files under ``src/repro/machines/`` —
# bit-identical to the registered constants and regenerable with
# ``tools/write_machine_files.py``.

#: Version of the machine-file schema; files written with a *newer* schema
#: than the running code understands are rejected, not guessed at.
MACHINE_SCHEMA_VERSION = 1

#: Tag <-> class for the in-core issue-model union in serialized machines.
_PORT_KINDS = {"ports": PortModel, "vpu": VPUIssueModel}


def machine_to_dict(machine: MachineModel) -> dict:
    """Serialize a :class:`MachineModel` to a JSON-compatible dict.

    The dict is purely declarative — nested issue/power models become
    tagged sub-dicts, tuples become lists under JSON — and is the exact
    inverse of :func:`machine_from_dict`.
    """
    d = dataclasses.asdict(machine)
    d["levels"] = [dict(lv) for lv in d["levels"]]
    kind = next(k for k, cls in _PORT_KINDS.items()
                if type(machine.ports) is cls)
    d["ports"] = {"kind": kind, **d["ports"]}
    d["capacities"] = list(d["capacities"])
    d["f_steps_ghz"] = list(d["f_steps_ghz"])
    d["measured_bw"] = dict(d["measured_bw"])
    return d


def machine_from_dict(data: dict) -> MachineModel:
    """Rebuild a :class:`MachineModel` from :func:`machine_to_dict` output.

    Accepts either the bare machine dict or a full machine-file document
    (``{"schema": ..., "machine": {...}}``).  Unknown fields and unknown
    schema versions raise ``ValueError`` — a file from a newer version of
    the code is rejected cleanly rather than silently misread.
    """
    if not isinstance(data, dict):
        raise TypeError(f"machine_from_dict wants a dict, got {type(data)!r}")
    d = dict(data)
    if isinstance(d.get("machine"), dict):            # full file document
        schema = d.get("schema")
        if schema != MACHINE_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported machine-file schema {schema!r} (this code "
                f"understands schema {MACHINE_SCHEMA_VERSION})")
        d = dict(d["machine"])
    d.pop("schema", None)
    known = {f.name for f in dataclasses.fields(MachineModel)}
    unknown = sorted(set(d) - known)
    if unknown:
        raise ValueError(
            f"unknown MachineModel fields in machine dict: {unknown}")
    ports = dict(d["ports"])
    kind = ports.pop("kind", "ports")
    try:
        port_cls = _PORT_KINDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown issue-model kind {kind!r}; "
            f"expected one of {sorted(_PORT_KINDS)}") from None
    d["ports"] = port_cls(**ports)
    d["levels"] = tuple(TransferLevel(**dict(lv)) for lv in d["levels"])
    if "capacities" in d:
        d["capacities"] = tuple(int(c) for c in d["capacities"])
    if "f_steps_ghz" in d:
        d["f_steps_ghz"] = tuple(float(f) for f in d["f_steps_ghz"])
    if "power" in d:
        d["power"] = ChipPower(**dict(d["power"]))
    if "measured_bw" in d:
        d["measured_bw"] = dict(d["measured_bw"])
    return MachineModel(**d)


def save_machine_file(machine: MachineModel, path: "str | os.PathLike",
                      *, provenance: dict | None = None) -> Path:
    """Write ``machine`` as a versioned machine file (see module notes).

    ``provenance`` is stored verbatim next to the machine dict — the
    calibration runner records fit residuals, measurement hashes, and the
    backend there so a loaded file carries its own audit trail.
    """
    doc = {
        "schema": MACHINE_SCHEMA_VERSION,
        "kind": "ecm-machine",
        "machine": machine_to_dict(machine),
    }
    if provenance is not None:
        doc["provenance"] = dict(provenance)
    path = Path(path)
    path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    return path


def load_machine_file(path: "str | os.PathLike",
                      *, with_provenance: bool = False):
    """Load a versioned machine file; returns the :class:`MachineModel`
    (or ``(model, provenance)`` with ``with_provenance=True``)."""
    raw = json.loads(Path(path).read_text())
    if not isinstance(raw, dict) or not isinstance(raw.get("machine"), dict):
        raise ValueError(
            f"{os.fspath(path)!r} is not a machine file: expected a JSON "
            "object with a 'machine' member (see save_machine_file)")
    model = machine_from_dict(raw)
    if with_provenance:
        return model, dict(raw.get("provenance") or {})
    return model


def resolve_machine(spec: "str | os.PathLike | dict | MachineModel",
                    *, register: bool = True) -> MachineModel:
    """Uniform machine resolution for CLI/launch entry points.

    ``spec`` may be a registry name or alias, the path of a machine file,
    a machine dict, or a model.  File/dict specs are registered by default
    (``register=True``) so downstream name-based lookups — bench payload
    labels, serving engines — see the freshly loaded machine.
    """
    if isinstance(spec, MachineModel):
        return spec
    if isinstance(spec, dict):
        machine = machine_from_dict(spec)
    elif isinstance(spec, (str, os.PathLike)):
        name = os.fspath(spec)
        if name in MACHINES or name in _ALIASES:
            return get_machine(name)
        if name.endswith(".json") or os.path.sep in name or os.path.exists(name):
            machine = load_machine_file(name)
        else:
            return get_machine(name)     # raises the registry KeyError
    else:
        raise TypeError(f"cannot resolve a machine from {type(spec)!r}")
    return register_machine(machine) if register else machine


def zoo_machine_file(name: str) -> Path:
    """Path of the checked-in machine file for a zoo machine name/alias."""
    name = _ALIASES.get(name, name)
    return Path(__file__).resolve().parent.parent / "machines" / f"{name}.json"


# ---------------------------------------------------------------------------
# The paper's testbed: Xeon E5-2695 v3 (Haswell-EP), Table II
# ---------------------------------------------------------------------------

#: Sustained single-memory-domain (CoD) bandwidths measured in the paper, in
#: bytes/s, keyed by benchmark (§IV-A calibration inputs, measured with
#: likwid-bench).  ``_stream`` / ``_stencil`` are the family fallbacks for
#: custom specs; ``triad_update`` is the fused chain (striad-class streams).
_HASWELL_BW = {
    "ddot": 32.4e9,
    "load": 32.4e9,          # footnote 2: identical to ddot
    "store": 23.6e9,
    "update": 23.6e9,        # "almost identical to that of the store kernel"
    "copy": 26.3e9,
    "striad": 27.1e9,
    "schoenauer": 27.8e9,
    "striad_nt": 28.3e9,
    "schoenauer_nt": 29.0e9,
    "triad_update": 27.1e9,
    "jacobi2d": 24.1e9,
    "jacobi3d": 24.1e9,
    # compute-bound kernels: the memory-edge streams are almost entirely
    # loads (panel re-reads), so the sustained bandwidth is load-dominated;
    # the value barely matters because T_core dominates the composition.
    "matmul": 30.0e9,
    "flash-attention": 30.0e9,
    "_stream": 27e9,
    "_stencil": 24.1e9,
    "_compute": 30.0e9,
}


def _scaled_bw(table: dict, factor: float) -> dict:
    """Declarative calibration helper: scale a per-kernel-class bandwidth
    table by a machine-to-machine sustained-bandwidth ratio."""
    return {k: v * factor for k, v in table.items()}


HASWELL_EP = register_machine(MachineModel(
    name="haswell-ep",
    clock_hz=2.3e9,
    line_bytes=64,
    simd_bytes=32,                       # AVX
    levels=(
        # register<-L1 is captured by the port model, not a TransferLevel.
        TransferLevel("L1<->L2", load_bpc=64.0, evict_bpc=32.0),
        TransferLevel("L2<->L3", load_bpc=32.0, evict_bpc=32.0),
    ),
    mem_level_name="Mem",
    ports=PortModel(),
    cores=14,
    flops_per_cycle_dp=16.0,
    flops_per_cycle_sp=32.0,
    # Table II capacities; the L3 entry is the Cluster-on-Die affinity-
    # domain slice (7 x 2.5 MB), matching the CoD measured_bw calibration.
    capacities=(32 * 1024, 256 * 1024, 35 * 1024 * 1024 // 2),
    measured_bw=dict(_HASWELL_BW),
    cores_per_domain=7,
    n_domains=2,
    # §III-D calibration: the ChipPower defaults *are* the Haswell fit;
    # sustained bandwidth is frequency-independent on Haswell (Fig. 4)
    power=ChipPower(),
    f_steps_ghz=(1.2, 1.6, 2.0, 2.3, 2.7, 3.0),
    bw_freq_coupled=False,
), "haswell", "haswell-ep-2695v3", "hsw")

def _haswell_table1_bw() -> dict:
    """The paper's Table I stream calibrations, as the pre-registry
    ``HASWELL_MEASURED_BW`` constant exposed them (streams only: no family
    fallbacks, no stencil/compute entries)."""
    return {
        k: v for k, v in HASWELL_EP.measured_bw.items()
        if not k.startswith("_")
        and k not in ("triad_update", "jacobi2d", "jacobi3d",
                      "matmul", "flash-attention")
    }


#: Non-CoD sustained chip bandwidths (both memory controllers, Fig. 10/11).
#: The paper gives CoD ~= 1.08x non-CoD for most kernels; we use the chip
#: bandwidth ~= 52.3 GB/s stream-triad figure scaled per kernel class.
HASWELL_CHIP_BW_NONCOD = {k: 1.85 * v for k, v in _haswell_table1_bw().items()}


def __getattr__(name: str):
    # PR-3 alias shim: the calibration table lives on the machine now.
    if name == "HASWELL_MEASURED_BW":
        warnings.warn(
            "HASWELL_MEASURED_BW is deprecated and scheduled for removal; "
            "migrate to get_machine('haswell-ep').measured_bw (the same "
            "Table I calibration, plus family fallbacks) — or load/refit "
            "it via repro.core.calibrate.calibrate('haswell-ep')",
            DeprecationWarning, stacklevel=2)
        return _haswell_table1_bw()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ---------------------------------------------------------------------------
# The generation zoo (arXiv:1702.07554 study; first-order calibration)
# ---------------------------------------------------------------------------

SANDY_BRIDGE_EP = register_machine(MachineModel(
    name="sandy-bridge-ep",
    clock_hz=2.7e9,
    line_bytes=64,
    simd_bytes=32,                       # AVX, but 16 B L1 data paths
    levels=(
        TransferLevel("L1<->L2", load_bpc=32.0, evict_bpc=32.0),
        TransferLevel("L2<->L3", load_bpc=32.0, evict_bpc=32.0),
    ),
    mem_level_name="Mem",
    # no FMA; both L1 ports move 16 B/cy, so one 32 B AVX op holds its
    # port for two cycles
    ports=PortModel(n_fma=0, n_simple_agu=0,
                    load_issue_cycles=2.0, store_issue_cycles=2.0),
    cores=8,
    flops_per_cycle_dp=8.0,
    flops_per_cycle_sp=16.0,
    capacities=(32 * 1024, 256 * 1024, 20 * 1024 * 1024),
    # single memory domain, DDR3-1600: ~1.35x the Haswell CoD domain
    measured_bw=_scaled_bw(_HASWELL_BW, 1.35),
    cores_per_domain=8,
    n_domains=1,
    # 32 nm part: higher leakage + steeper dynamic power than Haswell,
    # and the Uncore rides the core clock, so sustained bandwidth
    # degrades at low frequency (paper Fig. 4)
    power=ChipPower(idle_watts=32.0, static_per_core=0.8,
                    dyn_lin=0.5, dyn_quad=2.8),
    f_steps_ghz=(1.2, 1.6, 2.0, 2.3, 2.7),
    bw_freq_coupled=True,
), "sandy-bridge", "snb")

BROADWELL_EP = register_machine(MachineModel(
    name="broadwell-ep",
    clock_hz=2.2e9,
    line_bytes=64,
    simd_bytes=32,                       # AVX2, Haswell-like core
    levels=(
        TransferLevel("L1<->L2", load_bpc=64.0, evict_bpc=32.0),
        TransferLevel("L2<->L3", load_bpc=32.0, evict_bpc=32.0),
    ),
    mem_level_name="Mem",
    ports=PortModel(),
    cores=22,
    flops_per_cycle_dp=16.0,
    flops_per_cycle_sp=32.0,
    # 55 MB L3, CoD slice of 11 x 2.5 MB
    capacities=(32 * 1024, 256 * 1024, 55 * 1024 * 1024 // 2),
    # DDR4-2400 vs Haswell's 2133: ~1.12x per domain
    measured_bw=_scaled_bw(_HASWELL_BW, 1.12),
    cores_per_domain=11,
    n_domains=2,
    # 14 nm shrink of the Haswell core: slightly lower static/dynamic
    # power, same decoupled-Uncore bandwidth behaviour
    power=ChipPower(idle_watts=22.0, static_per_core=0.5,
                    dyn_lin=0.3, dyn_quad=2.0),
    f_steps_ghz=(1.2, 1.6, 2.0, 2.2),
    bw_freq_coupled=False,
), "broadwell", "bdw")

SKYLAKE_SP = register_machine(MachineModel(
    name="skylake-sp",
    clock_hz=2.4e9,
    line_bytes=64,
    simd_bytes=64,                       # AVX-512: one 64 B line per uop
    levels=(
        TransferLevel("L1<->L2", load_bpc=64.0, evict_bpc=64.0),
        # victim L3: measured sustained L2<->L3 bandwidth ~16 B/cy/direction
        TransferLevel("L2<->L3", load_bpc=16.0, evict_bpc=16.0),
    ),
    mem_level_name="Mem",
    ports=PortModel(n_fma=2, n_mul=2, n_add=2),
    cores=20,
    flops_per_cycle_dp=32.0,
    flops_per_cycle_sp=64.0,
    # 32 KiB L1, 1 MiB private L2, 1.375 MB/core non-inclusive L3
    # (SNC-2 slice of 10 cores)
    capacities=(32 * 1024, 1024 * 1024, int(13.75 * 1024 * 1024)),
    victim_l3=True,
    # DDR4-2666 6ch split over two SNC domains: ~1.85x the Haswell domain
    measured_bw=_scaled_bw(_HASWELL_BW, 1.85),
    cores_per_domain=10,
    n_domains=2,
    # AVX-512 pipes raise both static and dynamic per-core power; the
    # mesh Uncore clocks independently of the cores
    power=ChipPower(idle_watts=30.0, static_per_core=0.6,
                    dyn_lin=0.4, dyn_quad=2.4),
    f_steps_ghz=(1.2, 1.6, 2.0, 2.4),
    bw_freq_coupled=False,
), "skylake", "skx")


# ---------------------------------------------------------------------------
# Adaptation target: TPU v5e
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TPUMachineModel:
    """TPU machine constants for the TPU-ECM model (per chip).

    The TPU hierarchy is software-managed: VREG <- VMEM <- HBM, with ICI
    links between chips inside a pod and DCN between pods.  There is no
    write-allocate: Pallas ``out_specs`` / XLA output buffers stream whole
    blocks (the "non-temporal store" of the paper is the default, see
    DESIGN.md §3).
    """

    name: str = "tpu-v5e"
    clock_hz: float = 0.94e9
    peak_bf16_flops: float = 197e12          # per chip
    peak_f32_flops: float = 49.25e12
    hbm_bytes_per_s: float = 819e9           # per chip
    hbm_bytes: int = 16 * 1024**3
    vmem_bytes: int = 128 * 1024**2
    ici_link_bytes_per_s: float = 50e9       # per link per direction
    ici_links_per_chip: int = 4              # 2D torus: +/-x, +/-y
    dcn_bytes_per_s: float = 25e9            # per host, pod-to-pod
    # MXU shape: 128x128 systolic; VPU: 8x128 lanes
    mxu_dim: int = 128
    vpu_lanes: int = 8 * 128
    # energy model (approximate public figures, used for the Fig. 5/6
    # analogue only — relative structure matters, not absolute joules)
    pj_per_flop: float = 0.35
    pj_per_hbm_byte: float = 15.0
    pj_per_ici_byte: float = 30.0
    idle_watts: float = 70.0
    peak_watts: float = 220.0
    # ---- calibration data (the ECM overlap coefficients) --------------
    #: fraction of collective / HBM transfer time serialized with compute
    #: (the ``T_nOL`` role in Eq. 1).  These are *per-machine calibration*
    #: values: ``exposed_hbm_fraction`` is measured by the serial-vs-
    #: pipelined kernel pair (``tpu_ecm.measured_overlap``); the defaults
    #: reproduce the pre-calibration model (collectives fully exposed,
    #: HBM fully overlapped by the multi-buffered DMA pipeline).
    exposed_ici_fraction: float = 1.0
    exposed_hbm_fraction: float = 0.0

    # ------------------------------------------------------------------
    def compute_seconds(self, flops: float, dtype_peak: float | None = None) -> float:
        return flops / (dtype_peak or self.peak_bf16_flops)

    def hbm_seconds(self, nbytes: float) -> float:
        return nbytes / self.hbm_bytes_per_s

    def ici_seconds(self, nbytes: float, links: int | None = None) -> float:
        links = links or self.ici_links_per_chip
        return nbytes / (self.ici_link_bytes_per_s * links)

    def hbm_bytes_per_cycle(self) -> float:
        return self.hbm_bytes_per_s / self.clock_hz     # ~871 B/cy

    def mxu_flops_per_cycle_bf16(self) -> float:
        return self.peak_bf16_flops / self.clock_hz


TPU_V5E = TPUMachineModel()

#: Hierarchy view of the TPU for the unified workload engine: one VMEM
#: block row of 128 f32 lanes is the unit of work; VREG<->VMEM moves one
#: 8x128 vector per cycle; the memory edge is HBM at the sustained rate.
#: ``write_allocate=False`` encodes the Pallas whole-block-write semantics
#: (every store is the paper's §VII-E non-temporal store).
TPU_V5E_HIERARCHY = register_machine(MachineModel(
    name="tpu-v5e",
    clock_hz=TPU_V5E.clock_hz,
    line_bytes=128 * 4,                  # one f32 row of 128 lanes
    simd_bytes=128 * 4,
    levels=(
        TransferLevel("VREG<->VMEM", load_bpc=8 * 128 * 4.0,
                      evict_bpc=8 * 128 * 4.0),
    ),
    mem_level_name="HBM",
    first_level_name="VREG",
    # VPU for element-wise work; contraction MACs (``dot`` uops) run on
    # the 128x128 MXU instead of the FMA/VPU pipe.  The rate is calibrated
    # so a matmul workload's T_OL equals flops / peak_f32 at this clock:
    # one unit of work (a 128-lane f32 row of C) counts 2K canonical dot
    # uops for 2*128*K flops, hence peak/clock/128 canonical uops/cycle.
    ports=VPUIssueModel(
        vectors_per_cycle=8.0,
        mxu_vectors_per_cycle=TPU_V5E.peak_f32_flops / TPU_V5E.clock_hz
        / 128.0),
    cores=1,
    # registers hold nothing across iterations; VMEM is the reuse level
    capacities=(0, TPU_V5E.vmem_bytes),
    write_allocate=False,
    measured_bw={"_default": TPU_V5E.hbm_bytes_per_s},
    uop_scale=1.0,                       # uop counts used as-is (VPU ops)
    # fixed-frequency part: the energy grid degenerates to one column.
    # ChipPower calibrated to the public idle/peak envelope (70/220 W
    # at 0.94 GHz with one "core" = the whole chip's compute complex).
    power=ChipPower(idle_watts=TPU_V5E.idle_watts, static_per_core=20.0,
                    dyn_lin=30.0, dyn_quad=115.0),
), "tpu", "v5e")
