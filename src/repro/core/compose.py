"""Whole-model ECM composition: step-time prediction for a model config.

The paper's Eq. 1 predicts one kernel; a model step is a *sequence* of
kernels.  This module walks a model's ops (a ``LayerSpec`` adapter over
the ``repro.configs`` architecture definitions), maps every op onto a
registry workload —

* projections / MLP / MoE experts  -> :class:`~repro.core.workload.MatmulWorkload`
* prefill / decode attention       -> :class:`~repro.core.workload.AttentionWorkload`
* norms / residuals / elementwise  -> :class:`~repro.core.workload.StreamWorkload`
  (Table I specs at f32 element width, so the sustained-bandwidth
  calibration keys keep resolving)

— lowers the whole op list through the unified ``workload`` engine in one
batch, and composes the per-op Eq. 1 results into a
:class:`StepPrediction` under the machine's overlap rule:

* **CPU (cache-based hierarchy)**: kernels run back to back; per-op
  ``T_ECM = max(T_nOL + T_data, T_OL)`` terms *sum* (the paper's
  single-core non-overlap assumption applied across kernels).
* **tpu-v5e (software-managed hierarchy)**: the multi-buffered DMA
  pipeline overlaps one op's HBM streams with its neighbours' compute,
  calibrated by ``TPU_V5E.exposed_hbm_fraction`` — at the measured 0.0
  the composition is Eq. 1 applied to the *summed* terms,
  ``max(sum T_OL, sum (T_nOL + T_data))``.

Both rules are the two ends of one blend (:func:`compose_cycles`):
``alpha * serial + (1 - alpha) * pipelined`` with ``alpha =
overlap_alpha(machine)``.

Everything here is first-order by design (the GQA KV stream is counted
per query head; chunked SSM scans are modeled as their per-token state
contractions) — the point is that any config in the zoo becomes one
composed prediction through the existing registry, not a new modeling
effort.  ``scale_model`` (``repro.core.scaling``), the dry-run
``--predict`` table and the serving engine's composition-backed
``BucketModel`` all consume these records.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from .kernel_spec import BENCHMARKS
from .machine import TPU_V5E, MachineModel, get_machine
from .ecm import ECMBatch
from .workload import (
    FLASH_ATTENTION_F32,
    MATMUL_F32,
    AttentionWorkload,
    LoweredBatch,
    MatmulWorkload,
    RoutedTraffic,
    StreamWorkload,
    lower_many,
)

PHASES = ("prefill", "decode")

#: Table I stream specs reused at activation (f32) width: the spec *names*
#: stay registered so the per-machine sustained-bandwidth calibration
#: resolves; only the element width changes (uop counts are per cache
#: line, so they are unaffected).
_NORM_SPEC = replace(BENCHMARKS["update"], elem_bytes=4)      # x = f(x)
_RESID_SPEC = replace(BENCHMARKS["striad"], elem_bytes=4)     # y = x + a*r
_GATHER_SPEC = replace(BENCHMARKS["copy"], elem_bytes=4)      # table lookup

#: composed-vs-three-term-model agreement band on the dry-run path
#: (ratio composed/simulated step time); calibrated against the tpu-v5e
#: zoo — the two paths share traffic inputs but differ in the in-core
#: model (uop issue vs peak-FLOPs roofline), so the band is generous.
DRYRUN_TOLERANCE = (0.2, 5.0)


def overlap_alpha(machine: "MachineModel | str") -> float:
    """Cross-op serialization coefficient of the machine's overlap rule.

    1.0 on cache-based CPUs (write-allocate hierarchies: kernels run
    serially, per-op Eq. 1 times sum); the calibrated
    ``exposed_hbm_fraction`` on the software-managed TPU hierarchy
    (0.0 = the DMA pipeline fully overlaps transfers across ops).
    """
    m = get_machine(machine)
    if m.write_allocate:
        return 1.0
    return float(TPU_V5E.exposed_hbm_fraction)


def compose_cycles(t_ol, t_rest, serial, alpha: float) -> float:
    """The Eq. 1 overlap rule across ops.

    ``serial`` sums per-op ``max(T_nOL + T_data, T_OL)``; ``pipelined``
    applies Eq. 1 once to the summed terms.  ``alpha`` blends the two
    (see :func:`overlap_alpha`).
    """
    t_ol = np.asarray(t_ol, float)
    t_rest = np.asarray(t_rest, float)
    serial = np.asarray(serial, float)
    pipelined = max(float(t_ol.sum()), float(t_rest.sum()))
    return alpha * float(serial.sum()) + (1.0 - alpha) * pipelined


# ---------------------------------------------------------------------------
# Op records
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OpSpec:
    """One model op bound to a registry workload.

    ``units`` are machine-dependent (cache lines of the op's output), so
    the spec carries the machine-independent ``out_elems`` /
    ``elem_bytes`` instead; ``count`` is the number of identical
    instances per step (layers x heads x batch folded in).
    """

    name: str                      # e.g. "attn.qkv"
    layer: str                     # breakdown group ("block", "head", ...)
    phase: str                     # prefill | decode
    kind: str                      # matmul | attention | stream
    workload: object               # the registry workload to lower
    out_elems: float               # output elements per instance
    elem_bytes: int
    count: float = 1.0

    def units(self, line_bytes: int) -> float:
        """Cache-line units of work per instance on this machine."""
        return self.out_elems * self.elem_bytes / line_bytes

    @property
    def flops(self) -> float:
        """Useful FLOPs across all instances (workload accounting)."""
        per_elem = self.workload.work_per_elem()[0]
        return float(per_elem) * self.out_elems * self.count


@dataclass(frozen=True)
class OpPrediction:
    """One composed op: the lowered Eq. 1 terms scaled to step totals."""

    name: str
    layer: str
    phase: str
    kind: str
    count: float
    units: float                   # cache lines per instance
    cy_per_unit: float             # per-unit T_ECM (== workload_batch)
    t_ol_cy: float                 # step-total overlapping cycles
    t_rest_cy: float               # step-total T_nOL + T_data cycles
    cycles: float                  # step-total serial Eq. 1 cycles
    flops: float
    hbm_bytes: float               # step-total memory-edge traffic

    def as_dict(self) -> dict:
        return {
            "op": self.name, "layer": self.layer, "phase": self.phase,
            "kind": self.kind, "count": self.count,
            "cy_per_unit": self.cy_per_unit, "cycles": self.cycles,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
        }


@dataclass(frozen=True)
class StepPrediction:
    """A whole-model step prediction, decomposable per op / layer / phase.

    ``ops`` carry both phases; the per-phase totals re-apply the
    machine's overlap rule (``alpha``), so *the breakdown always sums to
    the total under that rule* — the invariant the tests pin.
    """

    name: str
    machine: str
    clock_hz: float
    alpha: float
    ops: tuple

    # -- composition --------------------------------------------------
    def phase_ops(self, phase: str | None = None) -> tuple:
        if phase is None:
            return self.ops
        return tuple(o for o in self.ops if o.phase == phase)

    def cycles(self, phase: str | None = None) -> float:
        ops = self.phase_ops(phase)
        if not ops:
            return 0.0
        return compose_cycles([o.t_ol_cy for o in ops],
                              [o.t_rest_cy for o in ops],
                              [o.cycles for o in ops], self.alpha)

    def seconds(self, phase: str | None = None) -> float:
        return self.cycles(phase) / self.clock_hz

    @property
    def prefill_s(self) -> float:
        return self.seconds("prefill")

    @property
    def decode_s(self) -> float:
        return self.seconds("decode")

    # -- breakdowns ---------------------------------------------------
    def per_op(self, phase: str | None = None) -> list[dict]:
        return [o.as_dict() for o in sorted(self.phase_ops(phase),
                                            key=lambda o: -o.cycles)]

    def per_layer(self, phase: str | None = None) -> dict[str, float]:
        out: dict[str, float] = {}
        for o in self.phase_ops(phase):
            out[o.layer] = out.get(o.layer, 0.0) + o.cycles
        return out

    def flops(self, phase: str | None = None) -> float:
        return sum(o.flops for o in self.phase_ops(phase))

    def hbm_bytes(self, phase: str | None = None) -> float:
        return sum(o.hbm_bytes for o in self.phase_ops(phase))

    def dominant_op(self, phase: str | None = None) -> str:
        ops = self.phase_ops(phase)
        return max(ops, key=lambda o: o.cycles).name if ops else ""

    def summary(self) -> dict:
        out = {"name": self.name, "machine": self.machine,
               "alpha": self.alpha, "n_ops": len(self.ops)}
        for ph in PHASES:
            if not self.phase_ops(ph):
                continue
            out[ph] = {
                "cycles": self.cycles(ph),
                "seconds": self.seconds(ph),
                "flops": self.flops(ph),
                "hbm_bytes": self.hbm_bytes(ph),
                "dominant_op": self.dominant_op(ph),
            }
        return out


# ---------------------------------------------------------------------------
# Op constructors
# ---------------------------------------------------------------------------


def matmul_op(name: str, layer: str, phase: str, *, m: int, n: int, k: int,
              count: float = 1.0, spec=MATMUL_F32) -> OpSpec:
    w = MatmulWorkload(spec, m=max(int(m), 1), n=max(int(n), 1),
                       k=max(int(k), 1))
    return OpSpec(name=name, layer=layer, phase=phase, kind="matmul",
                  workload=w, out_elems=float(m) * float(n),
                  elem_bytes=spec.elem_bytes, count=float(count))


def attention_op(name: str, layer: str, phase: str, *, sq: int, skv: int,
                 d: int, count: float, causal: bool,
                 bq: int | None = None, bkv: int | None = None,
                 out_tokens: int | None = None,
                 spec=FLASH_ATTENTION_F32) -> OpSpec:
    """One attention instance per (batch element x head); ``out_tokens``
    overrides the output row count when the workload is evaluated at a
    bucketed ``sq`` (the serving path)."""
    bq = min(bq or 512, sq)
    bkv = min(bkv or 512, skv)
    w = AttentionWorkload(spec, sq=int(sq), skv=int(skv), d=int(d),
                          bq=int(bq), bkv=int(bkv), causal=causal)
    rows = sq if out_tokens is None else out_tokens
    return OpSpec(name=name, layer=layer, phase=phase, kind="attention",
                  workload=w, out_elems=float(rows) * float(d),
                  elem_bytes=spec.elem_bytes, count=float(count))


def stream_op(name: str, layer: str, phase: str, *, elems: float,
              count: float = 1.0, spec=_NORM_SPEC) -> OpSpec:
    return OpSpec(name=name, layer=layer, phase=phase, kind="stream",
                  workload=StreamWorkload(spec), out_elems=float(elems),
                  elem_bytes=spec.elem_bytes, count=float(count))


# ---------------------------------------------------------------------------
# LayerSpec adapters: config dataclass -> op walk
# ---------------------------------------------------------------------------


def _attn_dims(phase: str, seq_len: int, context: int) -> tuple[int, int, bool]:
    """(sq, skv, causal) for decoder self-attention in this phase."""
    if phase == "decode":
        return 1, context, False
    return seq_len, seq_len, True


def _lm_ops(cfg, phase: str, *, batch: int, seq_len: int, context: int
            ) -> list[OpSpec]:
    """Dense / GQA / MoE / VLM decoder stack (``LMConfig``-shaped)."""
    d, nh, dh = cfg.d_model, cfg.n_heads, cfg.head_dim_
    kvh = cfg.n_kv_heads
    n_layers = cfg.n_layers
    tokens = batch if phase == "decode" else batch * seq_len
    sq, skv, causal = _attn_dims(phase, seq_len, context)
    ops = [
        stream_op("embed.lookup", "embed", phase, elems=tokens * d,
                  spec=_GATHER_SPEC),
        stream_op("block.norm", "block", phase, elems=tokens * d,
                  count=2 * n_layers),
        stream_op("block.residual", "block", phase, elems=tokens * d,
                  count=2 * n_layers, spec=_RESID_SPEC),
        matmul_op("attn.qkv", "block", phase, m=tokens,
                  n=(nh + 2 * kvh) * dh, k=d, count=n_layers),
        attention_op("attn.core", "block", phase, sq=sq, skv=skv, d=dh,
                     count=batch * nh * n_layers, causal=causal),
        matmul_op("attn.out", "block", phase, m=tokens, n=d, k=nh * dh,
                  count=n_layers),
    ]
    moe = getattr(cfg, "moe", None)
    if moe is not None:
        ops += [
            matmul_op("moe.router", "block", phase, m=tokens,
                      n=moe.n_experts, k=d, count=n_layers),
            matmul_op("moe.expert_up", "block", phase,
                      m=tokens * moe.top_k, n=2 * moe.d_ff, k=d,
                      count=n_layers),
            matmul_op("moe.expert_down", "block", phase,
                      m=tokens * moe.top_k, n=d, k=moe.d_ff,
                      count=n_layers),
        ]
    else:
        ops += [
            matmul_op("mlp.up", "block", phase, m=tokens, n=2 * cfg.d_ff,
                      k=d, count=n_layers),
            matmul_op("mlp.down", "block", phase, m=tokens, n=d,
                      k=cfg.d_ff, count=n_layers),
        ]
    ops += [
        stream_op("head.norm", "head", phase, elems=tokens * d),
        matmul_op("head.unembed", "head", phase, m=tokens,
                  n=cfg.vocab_padded, k=d),
    ]
    return ops


def _zamba2_ops(cfg, phase: str, *, batch: int, seq_len: int, context: int
                ) -> list[OpSpec]:
    """Mamba2 backbone + shared attention blocks (Zamba2)."""
    d = cfg.d_model
    mc = cfg.mamba_cfg
    di, ds = mc.d_inner, mc.d_state
    n_layers, n_shared = cfg.n_layers, cfg.n_shared
    nh, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    tokens = batch if phase == "decode" else batch * seq_len
    sq, skv, causal = _attn_dims(phase, seq_len, context)
    proj_out = 2 * di + 2 * mc.n_groups * ds + mc.n_heads
    return [
        stream_op("embed.lookup", "embed", phase, elems=tokens * d,
                  spec=_GATHER_SPEC),
        stream_op("mamba.norm", "mamba", phase, elems=tokens * d,
                  count=n_layers),
        stream_op("mamba.residual", "mamba", phase, elems=tokens * d,
                  count=n_layers, spec=_RESID_SPEC),
        matmul_op("mamba.in_proj", "mamba", phase, m=tokens, n=proj_out,
                  k=d, count=n_layers),
        stream_op("mamba.conv", "mamba", phase, elems=tokens * mc.conv_dim,
                  count=n_layers),
        # chunked SSM scan as its per-token state contractions (B·x in,
        # C·h out): two d_state-deep GEMVs per channel per token
        matmul_op("mamba.scan", "mamba", phase, m=tokens, n=di, k=ds,
                  count=2 * n_layers),
        stream_op("mamba.gate", "mamba", phase, elems=tokens * di,
                  count=n_layers),
        matmul_op("mamba.out_proj", "mamba", phase, m=tokens, n=d, k=di,
                  count=n_layers),
        # shared transformer block (input: concat of stream + skip -> 2d)
        stream_op("shared.norm", "shared", phase, elems=tokens * 2 * d,
                  count=2 * n_shared),
        stream_op("shared.residual", "shared", phase, elems=tokens * d,
                  count=2 * n_shared, spec=_RESID_SPEC),
        matmul_op("shared.qkv", "shared", phase, m=tokens,
                  n=(nh + 2 * kvh) * dh, k=2 * d, count=n_shared),
        attention_op("shared.attn", "shared", phase, sq=sq, skv=skv, d=dh,
                     count=batch * nh * n_shared, causal=causal),
        matmul_op("shared.out", "shared", phase, m=tokens, n=d, k=nh * dh,
                  count=n_shared),
        matmul_op("shared.mlp_up", "shared", phase, m=tokens, n=2 * cfg.d_ff,
                  k=d, count=n_shared),
        matmul_op("shared.mlp_down", "shared", phase, m=tokens, n=d,
                  k=cfg.d_ff, count=n_shared),
        stream_op("head.norm", "head", phase, elems=tokens * d),
        matmul_op("head.unembed", "head", phase, m=tokens,
                  n=cfg.vocab_padded, k=d),
    ]


def _xlstm_ops(cfg, phase: str, *, batch: int, seq_len: int, context: int
               ) -> list[OpSpec]:
    """mLSTM / sLSTM block stack (xLSTM)."""
    d = cfg.d_model
    bc = cfg.block_cfg
    di, dh = bc.d_inner, bc.head_dim
    n_s = sum(1 for i in cfg.slstm_at if i < cfg.n_layers)
    n_m = cfg.n_layers - n_s
    tokens = batch if phase == "decode" else batch * seq_len
    ops = [
        stream_op("embed.lookup", "embed", phase, elems=tokens * d,
                  spec=_GATHER_SPEC),
        stream_op("block.norm", "block", phase, elems=tokens * d,
                  count=2 * cfg.n_layers),
        stream_op("block.residual", "block", phase, elems=tokens * d,
                  count=2 * cfg.n_layers, spec=_RESID_SPEC),
    ]
    if n_m:
        ops += [
            matmul_op("mlstm.up_proj", "mlstm", phase, m=tokens, n=2 * di,
                      k=d, count=n_m),
            matmul_op("mlstm.qkv", "mlstm", phase, m=tokens, n=3 * di, k=d,
                      count=n_m),
            # matrix-memory update/readout: head_dim-deep contraction per
            # channel per token (C += v k^T; h = C q)
            matmul_op("mlstm.recurrence", "mlstm", phase, m=tokens, n=di,
                      k=dh, count=2 * n_m),
            matmul_op("mlstm.down_proj", "mlstm", phase, m=tokens, n=d,
                      k=di, count=n_m),
        ]
    if n_s:
        ops += [
            matmul_op("slstm.gates", "slstm", phase, m=tokens, n=4 * d, k=d,
                      count=n_s),
            stream_op("slstm.recurrence", "slstm", phase, elems=tokens * d,
                      count=n_s),
            matmul_op("slstm.ff_up", "slstm", phase, m=tokens,
                      n=2 * bc.d_ff_s, k=d, count=n_s),
            matmul_op("slstm.ff_down", "slstm", phase, m=tokens, n=d,
                      k=bc.d_ff_s, count=n_s),
        ]
    ops += [
        stream_op("head.norm", "head", phase, elems=tokens * d),
        matmul_op("head.unembed", "head", phase, m=tokens,
                  n=cfg.vocab_padded, k=d),
    ]
    return ops


def _whisper_ops(cfg, phase: str, *, batch: int, seq_len: int, context: int
                 ) -> list[OpSpec]:
    """Whisper encoder-decoder: the encoder runs in prefill only; decode
    replays cached cross-attention KV over the encoded frames."""
    d, nh, dh = cfg.d_model, cfg.n_heads, cfg.head_dim_
    n_layers = cfg.n_layers
    tokens = batch if phase == "decode" else batch * seq_len
    enc_tokens = batch * seq_len
    sq, skv, causal = _attn_dims(phase, seq_len, context)
    ops: list[OpSpec] = []
    if phase == "prefill":
        ops += [
            matmul_op("enc.qkv", "encoder", phase, m=enc_tokens, n=3 * d,
                      k=d, count=n_layers),
            attention_op("enc.attn", "encoder", phase, sq=seq_len,
                         skv=seq_len, d=dh, count=batch * nh * n_layers,
                         causal=False),
            matmul_op("enc.out", "encoder", phase, m=enc_tokens, n=d,
                      k=d, count=n_layers),
            matmul_op("enc.mlp_up", "encoder", phase, m=enc_tokens,
                      n=cfg.d_ff, k=d, count=n_layers),
            matmul_op("enc.mlp_down", "encoder", phase, m=enc_tokens, n=d,
                      k=cfg.d_ff, count=n_layers),
            stream_op("enc.norm", "encoder", phase, elems=enc_tokens * d,
                      count=2 * n_layers),
            # cross-attention KV of the encoded frames, computed once
            matmul_op("dec.cross_kv", "decoder", phase, m=enc_tokens,
                      n=2 * d, k=d, count=n_layers),
        ]
    ops += [
        stream_op("dec.norm", "decoder", phase, elems=tokens * d,
                  count=3 * n_layers),
        stream_op("dec.residual", "decoder", phase, elems=tokens * d,
                  count=3 * n_layers, spec=_RESID_SPEC),
        matmul_op("dec.self_qkv", "decoder", phase, m=tokens, n=3 * d,
                  k=d, count=n_layers),
        attention_op("dec.self_attn", "decoder", phase, sq=sq, skv=skv,
                     d=dh, count=batch * nh * n_layers, causal=causal),
        matmul_op("dec.cross_q", "decoder", phase, m=tokens, n=d, k=d,
                  count=n_layers),
        attention_op("dec.cross_attn", "decoder", phase,
                     sq=1 if phase == "decode" else seq_len,
                     skv=context, d=dh, count=batch * nh * n_layers,
                     causal=False),
        matmul_op("dec.out", "decoder", phase, m=tokens, n=d, k=d,
                  count=2 * n_layers),
        matmul_op("dec.mlp_up", "decoder", phase, m=tokens, n=cfg.d_ff,
                  k=d, count=n_layers),
        matmul_op("dec.mlp_down", "decoder", phase, m=tokens, n=d,
                  k=cfg.d_ff, count=n_layers),
        stream_op("head.norm", "head", phase, elems=tokens * d),
        matmul_op("head.unembed", "head", phase, m=tokens,
                  n=cfg.vocab_padded, k=d),
    ]
    return ops


def model_ops(cfg, phase: str, *, batch: int = 1, seq_len: int = 4096,
              context: int | None = None) -> list[OpSpec]:
    """The ``LayerSpec`` adapter: walk one phase of a model config into
    bound op records.  Dispatch is structural (field signatures), so any
    config dataclass with the right fields composes — not just the
    shipped zoo."""
    if phase not in PHASES:
        raise ValueError(f"unknown phase {phase!r}; expected one of {PHASES}")
    context = context or seq_len
    kw = dict(batch=batch, seq_len=seq_len, context=context)
    if hasattr(cfg, "shared_every"):            # Zamba2 hybrid
        ops = _zamba2_ops(cfg, phase, **kw)
    elif hasattr(cfg, "slstm_at"):              # xLSTM
        ops = _xlstm_ops(cfg, phase, **kw)
    elif hasattr(cfg, "max_frames"):            # Whisper enc-dec
        ops = _whisper_ops(cfg, phase, **kw)
    elif hasattr(cfg, "n_kv_heads"):            # dense / GQA / MoE / VLM LM
        ops = _lm_ops(cfg, phase, **kw)
    else:
        raise TypeError(
            f"no LayerSpec adapter for config type {type(cfg).__name__}: "
            f"expected LM / Zamba2 / xLSTM / Whisper field signature")
    return [o for o in ops if o.count > 0 and o.out_elems > 0]


# ---------------------------------------------------------------------------
# Composition
# ---------------------------------------------------------------------------


def _resolve_config(config):
    """(name, cfg) from an arch name, an ArchDef, or a raw config."""
    if isinstance(config, str):
        from repro.configs import get_arch

        arch = get_arch(config)
        return arch.name, arch.cfg
    cfg = getattr(config, "cfg", None)
    if cfg is not None and hasattr(config, "spec_fn"):   # ArchDef
        return config.name, cfg
    return getattr(config, "name", type(config).__name__), config


def compose_ops(ops, machine: "MachineModel | str", *, name: str = "model",
                sustained_bw=None) -> StepPrediction:
    """Lower bound ops in one batch and compose a :class:`StepPrediction`.

    Per-op results are *bit-identical* to lowering the op's workload
    alone through ``workload_batch`` (same engine call); composition
    only scales by (count x units) and applies the overlap rule.
    """
    m = get_machine(machine)
    ops = list(ops)
    if not ops:
        raise ValueError("compose_ops: empty op list")
    lowered = lower_many([o.workload for o in ops], m,
                         sustained_bw=sustained_bw)
    batch = lowered.batch
    pred = batch.predictions()[:, -1]                       # serial T_ECM
    t_rest = batch.t_nol + batch.transfers.sum(axis=-1)
    mem_lines = lowered.routed.mem_lines()
    records = []
    for i, o in enumerate(ops):
        units = o.units(m.line_bytes)
        scale = o.count * units
        records.append(OpPrediction(
            name=o.name, layer=o.layer, phase=o.phase, kind=o.kind,
            count=o.count, units=units,
            cy_per_unit=float(pred[i]),
            t_ol_cy=float(batch.t_ol[i]) * scale,
            t_rest_cy=float(t_rest[i]) * scale,
            cycles=float(pred[i]) * scale,
            flops=o.flops,
            hbm_bytes=float(mem_lines[i]) * m.line_bytes * scale,
        ))
    return StepPrediction(name=name, machine=m.name, clock_hz=m.clock_hz,
                          alpha=overlap_alpha(m), ops=tuple(records))


def predict_step(config, machine: "MachineModel | str" = "tpu-v5e", *,
                 batch: int = 1, seq_len: int = 4096,
                 context: int | None = None,
                 phases=PHASES, sustained_bw=None) -> StepPrediction:
    """Compose the whole-model step prediction for a config on a machine.

    ``config`` is an arch name from ``repro.configs``, an ``ArchDef``,
    or a raw model config dataclass.  The returned record carries both
    a prefill step (``batch x seq_len`` tokens) and a decode step (one
    token per sequence at ``context``), each decomposable per op and
    per layer group.
    """
    name, cfg = _resolve_config(config)
    context = context or seq_len
    ops: list[OpSpec] = []
    for ph in phases:
        ops += model_ops(cfg, ph, batch=batch, seq_len=seq_len,
                         context=context)
    return compose_ops(ops, machine, name=name, sustained_bw=sustained_bw)


def model_lowered(config, machine: "MachineModel | str", *,
                  phase: str = "decode", batch: int = 1,
                  seq_len: int = 4096, context: int | None = None,
                  sustained_bw=None) -> LoweredBatch:
    """One phase of a config aggregated into a single pre-scaled
    :class:`LoweredBatch` element (unit: one whole step) — the adapter
    that feeds the Eq. 2 chip-scaling engine (``scaling.scale_model``).

    The aggregate's Eq. 1 prediction is the pipelined composition
    ``max(sum T_OL, sum (T_nOL + T_data))``; its memory-edge transfer
    term is the shared-bottleneck input Eq. 2 saturates on.
    """
    name, cfg = _resolve_config(config)
    m = get_machine(machine)
    ops = model_ops(cfg, phase, batch=batch, seq_len=seq_len,
                    context=context)
    lowered = lower_many([o.workload for o in ops], m,
                         sustained_bw=sustained_bw)
    scales = np.array([o.count * o.units(m.line_bytes) for o in ops])
    w = scales[:, None]
    batch_agg = ECMBatch(
        t_ol=np.array([float((lowered.batch.t_ol * scales).sum())]),
        t_nol=np.array([float((lowered.batch.t_nol * scales).sum())]),
        transfers=(lowered.batch.transfers * w).sum(axis=0, keepdims=True),
        levels=lowered.batch.levels,
        names=(f"{name}/{phase}",),
        unit="cy/step")
    routed = RoutedTraffic(
        load_lines=(lowered.routed.load_lines * w).sum(axis=0,
                                                       keepdims=True),
        evict_lines=(lowered.routed.evict_lines * w).sum(axis=0,
                                                         keepdims=True))
    return LoweredBatch(
        batch=batch_agg, routed=routed,
        l1_uops=np.array([float((lowered.l1_uops * scales).sum())]),
        mem_cy_per_line=lowered.mem_cy_per_line[:1].copy())
