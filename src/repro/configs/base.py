"""Architecture definitions: the uniform API every assigned arch implements.

An :class:`ArchDef` binds a model family's functions (spec / loss / prefill /
decode / cache-spec) to one concrete configuration, and knows how to build
its inputs for each assigned input shape — as numpy arrays (smoke tests,
examples) or as ``ParamSpec`` trees (the dry-run's ShapeDtypeStruct
stand-ins, which double as the source of input shardings).

Input shapes (assigned, global):

=============  ========  ============  =======================
shape          seq_len   global_batch  lowers
=============  ========  ============  =======================
train_4k       4,096     256           ``train_step``
prefill_32k    32,768    32            ``prefill_step``
decode_32k     32,768    128           ``serve_step`` (1 token)
long_500k      524,288   1             ``serve_step`` (1 token)
=============  ========  ============  =======================

``long_500k`` requires sub-quadratic sequence mixing and is skipped (with a
recorded reason) for pure full-attention architectures, per the brief.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ParamSpec, abstract, count_params, is_spec


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# ArchDef
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchDef:
    """One selectable architecture (``--arch <name>``)."""

    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    cfg: Any                       # model config dataclass
    spec_fn: Callable
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    cache_spec_fn: Callable
    profile: str = "tp_dp"         # sharding profile (repro.dist.sharding)
    sub_quadratic: bool = False    # may run long_500k
    has_decoder: bool = True       # encoder-only archs skip decode shapes
    source: str = ""               # provenance note ([arXiv/hf; tier])
    #: extra per-shape batch entries: name -> fn(shape, cfg) -> ParamSpec
    extra_inputs: dict = field(default_factory=dict)
    #: full override of batch_spec: fn(shape, cfg) -> dict[str, ParamSpec]
    batch_spec_fn: Callable | None = None
    #: gradient-accumulation microbatches for train_4k (memory-term knob:
    #: global batch preserved, per-device live activations divided)
    train_accum: int = 1
    #: Adam moment storage for the production config (f32 | bf16 | int8);
    #: the HBM-footprint knob for the very large archs
    moment_dtype: str = "f32"

    # -- parameters ----------------------------------------------------
    def param_spec(self):
        return self.spec_fn(self.cfg)

    @property
    def n_params(self) -> int:
        return count_params(self.param_spec())

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: experts scaled by top_k/n_experts)."""
        spec = self.param_spec()
        moe = getattr(self.cfg, "moe", None)
        if moe is None:
            return count_params(spec)
        total = 0
        flat, _ = jax.tree_util.tree_flatten_with_path(
            spec, is_leaf=is_spec)
        for path, s in flat:
            n = int(math.prod(s.shape))
            if "experts" in s.axes:     # expert-parallel weights
                n = int(n * moe.top_k / moe.n_experts)
            total += n
        return total

    # -- model fns -----------------------------------------------------
    def loss(self, params, batch):
        return self.loss_fn(params, self.cfg, batch)

    def prefill(self, params, batch, *, max_len: int | None = None):
        return self.prefill_fn(params, self.cfg, batch, max_len=max_len)

    def decode(self, params, cache, batch):
        return self.decode_fn(params, self.cfg, cache, batch)

    def cache_spec(self, batch_size: int, max_len: int):
        return self.cache_spec_fn(self.cfg, batch_size, max_len)

    # -- shape policy ----------------------------------------------------
    def shape_supported(self, shape: ShapeSpec) -> tuple[bool, str]:
        if shape.kind == "decode" and not self.has_decoder:
            return False, "encoder-only: no decode step"
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False, "full-attention arch: long_500k needs sub-quadratic mixing"
        return True, ""

    def cells(self) -> list[tuple[ShapeSpec, bool, str]]:
        return [(s, *self.shape_supported(s)) for s in SHAPES.values()]

    # -- inputs ----------------------------------------------------------
    def batch_spec(self, shape: ShapeSpec) -> dict:
        """ParamSpec tree of the step's *data* inputs (not params/cache)."""
        if self.batch_spec_fn is not None:
            return self.batch_spec_fn(shape, self.cfg)
        b = shape.global_batch
        s = shape.seq_len if shape.kind != "decode" else 1
        text_s = self._text_len(shape, s)
        out = {
            "tokens": ParamSpec((b, text_s), ("batch", None), init="zeros",
                                dtype=jnp.int32),
        }
        if shape.kind == "train":
            out["labels"] = ParamSpec((b, self._label_len(shape, text_s)),
                                      ("batch", None), init="zeros",
                                      dtype=jnp.int32)
            out["mask"] = ParamSpec((b, self._label_len(shape, text_s)),
                                    ("batch", None), init="ones",
                                    dtype=jnp.float32)
        for k, fn in self.extra_inputs.items():
            spec = fn(shape, self.cfg)
            if spec is not None:
                out[k] = spec
        return out

    def _text_len(self, shape: ShapeSpec, s: int) -> int:
        """Token-stream length (VLM archs reserve prefix positions)."""
        prefix = getattr(self.cfg, "image_prefix", 0)
        if shape.kind == "decode":
            return 1
        return max(s - prefix, 1)

    def _label_len(self, shape: ShapeSpec, text_s: int) -> int:
        prefix = getattr(self.cfg, "image_prefix", 0)
        return text_s + prefix

    def abstract_batch(self, shape: ShapeSpec):
        return abstract(self.batch_spec(shape))

    def make_batch(self, shape: ShapeSpec, seed: int = 0) -> dict:
        """Concrete numpy batch for this shape (smoke/example scale only)."""
        g = np.random.Generator(np.random.Philox(key=[seed, 7]))
        out = {}
        for k, spec in self.batch_spec(shape).items():
            if spec.dtype == jnp.int32:
                vocab = getattr(self.cfg, "vocab", 1024)
                out[k] = g.integers(0, vocab, size=spec.shape).astype(np.int32)
            elif spec.init == "ones":
                out[k] = np.ones(spec.shape, np.float32)
            else:
                out[k] = g.standard_normal(spec.shape).astype(np.float32) * 0.02
        return out

    # -- useful-work accounting (§Roofline) -------------------------------
    def model_flops(self, shape: ShapeSpec) -> float:
        """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference fwd), N = active."""
        n = self.n_active_params
        if shape.kind == "train":
            return 6.0 * n * shape.tokens_per_step
        if shape.kind == "prefill":
            return 2.0 * n * shape.tokens_per_step
        return 2.0 * n * shape.global_batch          # decode: 1 token/seq
