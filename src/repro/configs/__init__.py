"""Architecture registry: one module per assigned architecture.

``get_arch(name)`` returns the full-size :class:`~repro.configs.base.ArchDef`
(dry-run scale); ``get_arch(name, smoke=True)`` the reduced same-family
config used by CPU smoke tests and examples.
"""
from __future__ import annotations

import importlib

from .base import SHAPES, ArchDef, ShapeSpec

_MODULES = {
    "zamba2-1.2b": "zamba2_1_2b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen1.5-110b": "qwen1_5_110b",
    "minitron-4b": "minitron_4b",
    "glm4-9b": "glm4_9b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "xlstm-125m": "xlstm_125m",
    "pixtral-12b": "pixtral_12b",
    "whisper-base": "whisper_base",
}

ARCH_NAMES = tuple(_MODULES)


def get_arch(name: str, *, smoke: bool = False) -> ArchDef:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.smoke() if smoke else mod.full()


def all_archs(*, smoke: bool = False) -> dict[str, ArchDef]:
    return {n: get_arch(n, smoke=smoke) for n in ARCH_NAMES}


__all__ = ["SHAPES", "ArchDef", "ShapeSpec", "ARCH_NAMES", "get_arch",
           "all_archs"]
