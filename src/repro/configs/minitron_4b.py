"""minitron-4b: width/depth-pruned Nemotron, 256k vocabulary
[arXiv:2407.14679; hf].  The 256k vocab makes the embedding/logits the
sharding-critical tensors (vocab-parallel unembed + embedding)."""
from repro.models.lm import LMConfig
from ._lm_family import lm_arch

SOURCE = "[arXiv:2407.14679; hf]"


def full():
    cfg = LMConfig(
        name="minitron-4b",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=9216, vocab=256000,
        attn_impl="chunked", remat="full",
    )
    return lm_arch("minitron-4b", cfg, source=SOURCE, train_accum=4)


def smoke():
    cfg = LMConfig(
        name="minitron-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=2048,            # keep the fat-vocab character
        attn_impl="dense", vocab_pad_multiple=64,
    )
    return lm_arch("minitron-4b", cfg, source=SOURCE)
