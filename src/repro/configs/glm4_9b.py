"""glm4-9b: dense transformer, 2 KV heads (extreme GQA), partial RoPE
[hf:THUDM/glm-4-9b; hf]."""
from repro.models.lm import LMConfig
from ._lm_family import lm_arch

SOURCE = "[hf:THUDM/glm-4-9b; hf]"


def full():
    cfg = LMConfig(
        name="glm4-9b",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab=151552, rope_fraction=0.5,
        attn_impl="chunked", remat="full",
    )
    return lm_arch("glm4-9b", cfg, profile="tp_fsdp", source=SOURCE,
                   train_accum=8)


def smoke():
    cfg = LMConfig(
        name="glm4-smoke",
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab=512, rope_fraction=0.5,
        attn_impl="dense", vocab_pad_multiple=64,
    )
    return lm_arch("glm4-9b", cfg, profile="tp_fsdp", source=SOURCE)
