"""zamba2-1.2b: Mamba2 backbone + shared attention block (hybrid)
[arXiv:2411.15242; hf].  Sub-quadratic — runs the long_500k cell."""
from repro.models import zamba2
from .base import ArchDef

SOURCE = "[arXiv:2411.15242; hf]"


def _arch(cfg, train_accum: int = 1) -> ArchDef:
    return ArchDef(
        name="zamba2-1.2b",
        family="hybrid",
        cfg=cfg,
        spec_fn=zamba2.zamba2_spec,
        loss_fn=zamba2.loss_fn,
        prefill_fn=zamba2.prefill,
        decode_fn=zamba2.decode_step,
        cache_spec_fn=zamba2.cache_spec,
        profile="tp_dp",
        sub_quadratic=True,
        source=SOURCE,
        train_accum=train_accum,
    )


def full():
    return _arch(zamba2.Zamba2Config(
        name="zamba2-1.2b",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32000, d_state=64,
        shared_every=6, attn_impl="chunked", remat="full",
    ), train_accum=4)


def smoke():
    return _arch(zamba2.Zamba2Config(
        name="zamba2-smoke",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab=512, d_state=16,
        shared_every=2, lora_rank=8, mamba_head_dim=32, mamba_chunk=16,
        attn_impl="dense", vocab_pad_multiple=64,
    ))
