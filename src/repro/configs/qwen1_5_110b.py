"""qwen1.5-110b: dense GQA transformer with QKV bias
[hf:Qwen/Qwen1.5-0.5B family scaled per assignment; hf]."""
from repro.models.lm import LMConfig
from ._lm_family import lm_arch

SOURCE = "[hf:Qwen/Qwen1.5-110B; hf]"


def full():
    cfg = LMConfig(
        name="qwen1.5-110b",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=49152, vocab=152064, qkv_bias=True,
        attn_impl="chunked", remat="full",
    )
    return lm_arch("qwen1.5-110b", cfg, profile="tp_fsdp", source=SOURCE,
                   train_accum=16)


def smoke():
    cfg = LMConfig(
        name="qwen1.5-smoke",
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=384, vocab=512, qkv_bias=True,
        attn_impl="dense", vocab_pad_multiple=64,
    )
    return lm_arch("qwen1.5-110b", cfg, profile="tp_fsdp", source=SOURCE)
