"""Shared constructor for dense/MoE decoder-only LM architectures."""
from __future__ import annotations

from repro.models import lm
from .base import ArchDef


def lm_arch(name: str, cfg: lm.LMConfig, *, family: str = "dense",
            profile: str = "tp_dp", source: str = "",
            extra_inputs: dict | None = None,
            batch_spec_fn=None, train_accum: int = 1,
            moment_dtype: str = "f32") -> ArchDef:
    return ArchDef(
        name=name,
        family=family,
        cfg=cfg,
        spec_fn=lm.lm_spec,
        loss_fn=lm.loss_fn,
        prefill_fn=lm.prefill,
        decode_fn=lm.decode_step,
        cache_spec_fn=lm.cache_spec,
        profile=profile,
        sub_quadratic=False,
        source=source,
        extra_inputs=extra_inputs or {},
        batch_spec_fn=batch_spec_fn,
        train_accum=train_accum,
        moment_dtype=moment_dtype,
    )
