"""qwen3-moe-235b-a22b: 94-layer 128-expert top-8 MoE
[hf:Qwen/Qwen3-235B-A22B family; hf].  The EP+FSDP+TP stress case."""
from repro.models.lm import LMConfig
from repro.models.moe import MoEConfig
from ._lm_family import lm_arch

SOURCE = "[hf:Qwen/Qwen3-235B-A22B; hf]"


def full():
    cfg = LMConfig(
        name="qwen3-moe-235b-a22b",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, vocab=151936,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff=1536, impl="shard_map"),
        attn_impl="chunked", remat="full",
    )
    return lm_arch("qwen3-moe-235b-a22b", cfg, family="moe",
                   profile="moe_ep", source=SOURCE, train_accum=16,
                   moment_dtype="bf16")


def smoke():
    cfg = LMConfig(
        name="qwen3-moe-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=64, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=64),
        attn_impl="dense", vocab_pad_multiple=64,
    )
    return lm_arch("qwen3-moe-235b-a22b", cfg, family="moe",
                   profile="moe_ep", source=SOURCE)
