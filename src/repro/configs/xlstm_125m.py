"""xlstm-125m: sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

The assigned config has ``d_ff = 0``: feed-forward capacity lives inside
the blocks (see ``repro.models.xlstm_lm``).  Sub-quadratic: runs long_500k.
"""
from repro.models import xlstm_lm
from .base import ArchDef

SOURCE = "[arXiv:2405.04517; unverified]"


def _arch(cfg, train_accum: int = 1) -> ArchDef:
    return ArchDef(
        name="xlstm-125m",
        family="ssm",
        cfg=cfg,
        spec_fn=xlstm_lm.xlstm_lm_spec,
        loss_fn=xlstm_lm.loss_fn,
        prefill_fn=xlstm_lm.prefill,
        decode_fn=xlstm_lm.decode_step,
        cache_spec_fn=xlstm_lm.cache_spec,
        profile="dp_vocab",
        sub_quadratic=True,
        source=SOURCE,
        train_accum=train_accum,
    )


def full():
    return _arch(xlstm_lm.XLSTMLMConfig(
        name="xlstm-125m",
        n_layers=12, d_model=768, n_heads=4, vocab=50304,
        slstm_at=(3, 7), remat="full",
    ), train_accum=4)


def smoke():
    return _arch(xlstm_lm.XLSTMLMConfig(
        name="xlstm-smoke",
        n_layers=3, d_model=64, n_heads=2, vocab=512,
        slstm_at=(1,), chunk=16, vocab_pad_multiple=64,
    ))
