"""granite-moe-1b-a400m: 32-expert top-8 MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.models.lm import LMConfig
from repro.models.moe import MoEConfig
from ._lm_family import lm_arch

SOURCE = "[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"


def full():
    cfg = LMConfig(
        name="granite-moe-1b-a400m",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=512, vocab=49155,
        moe=MoEConfig(n_experts=32, top_k=8, d_ff=512, impl="shard_map"),
        attn_impl="chunked", remat="full",
    )
    return lm_arch("granite-moe-1b-a400m", cfg, family="moe",
                   profile="moe_ep", source=SOURCE, train_accum=2)


def smoke():
    cfg = LMConfig(
        name="granite-moe-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=64),
        attn_impl="dense", vocab_pad_multiple=64,
    )
    return lm_arch("granite-moe-1b-a400m", cfg, family="moe",
                   profile="moe_ep", source=SOURCE)
