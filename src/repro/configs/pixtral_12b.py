"""pixtral-12b: mistral-nemo decoder backbone + stub patch-embedding
frontend [hf:mistralai/Pixtral-12B-2409; unverified].

Per the brief the vision tower is a STUB: ``input_specs()`` supplies
precomputed patch embeddings (B, 256, d_model) which the LM prepends to
the token stream (``LMConfig.image_prefix``)."""
import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.lm import LMConfig
from ._lm_family import lm_arch
from .base import ShapeSpec

SOURCE = "[hf:mistralai/Pixtral-12B-2409; unverified]"


def _patches(shape: ShapeSpec, cfg: LMConfig):
    if shape.kind == "decode":
        return None                     # patches live in the prefill cache
    return ParamSpec((shape.global_batch, cfg.image_prefix, cfg.d_model),
                     ("batch", None, "embed"), dtype=jnp.bfloat16)


def full():
    cfg = LMConfig(
        name="pixtral-12b",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=131072, image_prefix=256,
        attn_impl="chunked", remat="full",
    )
    return lm_arch("pixtral-12b", cfg, family="vlm", profile="tp_fsdp",
                   source=SOURCE, extra_inputs={"patch_embeds": _patches},
                   train_accum=8)


def smoke():
    cfg = LMConfig(
        name="pixtral-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, image_prefix=8,
        attn_impl="dense", vocab_pad_multiple=64,
    )
    return lm_arch("pixtral-12b", cfg, family="vlm", profile="tp_fsdp",
                   source=SOURCE, extra_inputs={"patch_embeds": _patches})
