"""internlm2-1.8b: dense GQA transformer [arXiv:2403.17297; hf]."""
from repro.models.lm import LMConfig
from ._lm_family import lm_arch

SOURCE = "[arXiv:2403.17297; hf]"


def full():
    cfg = LMConfig(
        name="internlm2-1.8b",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab=92544,
        attn_impl="chunked", remat="full",
    )
    return lm_arch("internlm2-1.8b", cfg, source=SOURCE, train_accum=2)


def smoke():
    cfg = LMConfig(
        name="internlm2-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512,
        attn_impl="dense", vocab_pad_multiple=64,
    )
    return lm_arch("internlm2-1.8b", cfg, source=SOURCE)
