"""whisper-base: encoder-decoder with stub conv/mel frontend
[arXiv:2212.04356; unverified].

Shape interpretation for the enc-dec family (DESIGN.md §6):

* ``train_4k``    — encode seq_len frames, teacher-force seq_len tokens.
* ``prefill_32k`` — encode seq_len frames, prefill a 256-token prompt.
* ``decode_32k``  — one decoder token; self-KV cache of seq_len, cross-KV
  over seq_len encoder frames (computed at prefill).
* ``long_500k``   — skipped: the decoder is full attention.
"""
import jax.numpy as jnp

from repro.models import whisper
from repro.models.common import ParamSpec
from .base import ArchDef, ShapeSpec

SOURCE = "[arXiv:2212.04356; unverified]"

PROMPT_LEN = 256


def _prompt_len(shape: ShapeSpec) -> int:
    """Decoder prompt for prefill: 256 at assigned scale, shrunk for the
    smoke shapes so it stays within max_text."""
    return min(PROMPT_LEN, max(shape.seq_len // 128, 8))


def _batch_spec(shape: ShapeSpec, cfg: whisper.WhisperConfig) -> dict:
    b = shape.global_batch
    out: dict = {}
    if shape.kind == "train":
        s = shape.seq_len
        out["frames"] = ParamSpec((b, s, cfg.d_model), ("batch", None, "embed"),
                                  dtype=jnp.bfloat16)
        out["tokens"] = ParamSpec((b, s), ("batch", None), init="zeros",
                                  dtype=jnp.int32)
        out["labels"] = ParamSpec((b, s), ("batch", None), init="zeros",
                                  dtype=jnp.int32)
        out["mask"] = ParamSpec((b, s), ("batch", None), init="ones",
                                dtype=jnp.float32)
    elif shape.kind == "prefill":
        out["frames"] = ParamSpec((b, shape.seq_len, cfg.d_model),
                                  ("batch", None, "embed"), dtype=jnp.bfloat16)
        out["tokens"] = ParamSpec((b, _prompt_len(shape)), ("batch", None),
                                  init="zeros", dtype=jnp.int32)
    else:                                   # decode: one token
        out["tokens"] = ParamSpec((b, 1), ("batch", None), init="zeros",
                                  dtype=jnp.int32)
    return out


def _arch(cfg) -> ArchDef:
    return ArchDef(
        name="whisper-base",
        family="audio",
        cfg=cfg,
        spec_fn=whisper.whisper_spec,
        loss_fn=whisper.loss_fn,
        prefill_fn=whisper.prefill,
        decode_fn=whisper.decode_step,
        cache_spec_fn=whisper.cache_spec,
        profile="tp_dp",
        sub_quadratic=False,
        source=SOURCE,
        batch_spec_fn=_batch_spec,
    )


def full():
    return _arch(whisper.WhisperConfig(
        name="whisper-base",
        n_layers=6, d_model=512, n_heads=8, d_ff=2048, vocab=51865,
        attn_impl="chunked", remat="full",
    ))


def smoke():
    return _arch(whisper.WhisperConfig(
        name="whisper-smoke",
        n_layers=2, d_model=64, n_heads=2, d_ff=128, vocab=512,
        max_frames=64, max_text=64,
        attn_impl="dense", vocab_pad_multiple=64,
    ))
