#!/usr/bin/env python
"""Regenerate the checked-in zoo machine files under ``src/repro/machines/``.

The Python constants in ``repro.core.machine`` remain the source of truth;
this script serializes them as versioned machine files so the declarative
path (``register_machine(path)``, ``--machine <file>``) is exercised by the
same data the registry ships.  A golden test asserts the files load
bit-identical to the registered constants — rerun this script after editing
a zoo machine and commit the result.

Usage::

    PYTHONPATH=src python tools/write_machine_files.py
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.machine import (  # noqa: E402
    MACHINES, _ALIASES, machine_names, save_machine_file, zoo_machine_file)


def main() -> int:
    out_dir = zoo_machine_file("haswell-ep").parent
    out_dir.mkdir(parents=True, exist_ok=True)
    for name in machine_names():
        aliases = sorted(a for a, t in _ALIASES.items() if t == name)
        path = save_machine_file(
            MACHINES[name], zoo_machine_file(name),
            provenance={
                "source": "repro.core.machine registry constants",
                "generated_by": "tools/write_machine_files.py",
                "aliases": aliases,
            })
        print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
