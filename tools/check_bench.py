#!/usr/bin/env python3
"""BENCH artifact check: stdlib JSON-schema validation *and* the bench-
regression gate for the perf-trajectory files emitted by
``benchmarks/run.py --json``.

    python tools/check_bench.py [files...]      # default: BENCH_*.json
    python tools/check_bench.py NEW.json --compare BASELINE.json [--rtol R]
    python tools/check_bench.py FILES... --floor engine.warm_eval.points_per_s=14e6

Every artifact shares one envelope (``schema`` version, ``suite``,
``machine``) plus a per-suite payload; this checker pins the field names
and types that downstream trajectory tooling relies on, so a refactor
that silently drops or renames a field fails CI instead of producing
holes in the perf history.  Legacy ``schema: 1`` files (no envelope) are
accepted — the suite is inferred from their distinctive payload keys.
An *unrecognized* suite name is always a hard failure (exit 1), so a
typo'd or not-yet-registered suite cannot pass the gate silently.
Suites: stream, stencil, compute, scaling (Eq. 2 saturation + energy/EDP
grids + TPU DP scaling), tpu, serve (fault-injected serving runs — the
spec *pins zero lost requests per fault class*, so a request that
vanishes without a terminal state fails validation, not just the
compare), compose (whole-model composed step predictions — the spec pins
per-config prefill/decode entries and the config x machine zoo, and
requires decode <= prefill at the bench's equal-context shape), engine
(request-path engine — lowered-table shape, the deterministic zoo T_ECM
checksum, warm/cold eval sections and the re-rank ``identical`` pin),
mesh (multi-chip parallelism autotuner — golden-pinned joint
(mesh x profile x block) winners per config x chip count, the
``tpu_dp_scaling`` bit-identity flag through ``mesh.dp_scaling``, and
the warm mesh-sweep throughput gated via ``--floor``), calibrate (the
calibration loop — the spec *pins the max relative fit residual at
``MAX_CALIBRATE_RESIDUAL``* as a validation failure, requires zero warm
re-fits/re-measurements against the disk cache, and type-checks the
machine-file round-trip identity flags).

``--compare`` is the CI regression gate: it diffs a freshly generated
artifact against the committed baseline, failing when any *deterministic*
value (model predictions, ranked blockings, traffic counts, bit-equality
flags) drifts beyond ``--rtol`` or disappears.  Wall-clock-derived fields
(``wall``/``*_s``/``per_s``/throughput ratios/measured overlap fractions)
are volatile by nature and excluded — the gate guards the *model*, not
the runner's machine of the day.  ``--floor suite.path=value`` is the
opt-in complement for exactly those fields: an absolute throughput lower
bound (repeatable; a floor whose suite matches no checked artifact is an
error, not a skip).

Exit code 0 when clean, 1 with a per-finding report otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

SUITES = ("stream", "stencil", "compute", "scaling", "tpu", "serve",
          "compose", "engine", "mesh", "calibrate")

#: minimal spec language: {key: type | (type, predicate) | dict (nested) |
#: [element_spec] (non-empty list) | callable(value) -> error or None}
NUM = (int, float)


def _positive(x):
    return None if x > 0 else f"expected > 0, got {x!r}"


def _fraction(x):
    return None if 0.0 <= x <= 1.0 else f"expected in [0, 1], got {x!r}"


STREAM_SPEC = {
    "pipeline": {
        "kernels": dict,
        "fused_triad_update": {
            "fused_s": (NUM, _positive),
            "unfused_s": (NUM, _positive),
            "speedup": NUM,
            "predicted_stream_ratio": NUM,
        },
        "overlap": {
            "kernel": str,
            "t_serial_s": NUM,
            "t_pipelined_s": NUM,
            "exposed_hbm_fraction": (NUM, _fraction),
        },
    },
    "model_eval": {
        "batch_points": (int, _positive),
        "batch_wall_s": (NUM, _positive),
        "batch_points_per_s": (NUM, _positive),
        "batch_array_evals": (int, _positive),
        "python_calls_per_point_batch": NUM,
        "scalar_points_per_s": (NUM, _positive),
        "throughput_ratio": (NUM, _positive),
        "per_point_call_reduction": (NUM, _positive),
        "cold_wall_s": (NUM, _positive),
        "cold_points_per_s": (NUM, _positive),
        "warm_iters": (int, _positive),
        "warm_points": (int, _positive),
        "warm_wall_s": (NUM, _positive),
        "warm_points_per_s": (NUM, _positive),
        "warm_throughput_ratio": (NUM, _positive),
    },
    "autotune": {
        "n_candidates": (int, _positive),
        "batch_rank_wall_s": (NUM, _positive),
        "best_config": dict,
    },
}

STENCIL_SPEC = {
    "sweep": [{
        "n": (int, _positive),
        "ws_kib": (NUM, _positive),
        "regime": str,
        "lc_misses": list,
        "predicted_cy_per_cl": (NUM, _positive),
        "measured_cy_per_cl": (NUM, _positive),
        "model_error": NUM,
    }],
    "blocking": {
        "n": (int, _positive),
        "ranked": [{
            "block": list,
            "t_ecm": (NUM, _positive),
            "misses_l1": (int, _positive),
            "speedup_vs_unblocked": (NUM, _positive),
        }],
        "best": dict,
    },
    "kernels": {
        "shape": list,
        "stages": dict,
    },
}

TPU_SPEC = {
    "pipeline": {"kernels": dict},
    "zoo": dict,
}

_ECM_DETAIL = {
    "levels": list,
    "input_notation": str,
    "predictions": list,
    "t_ol": (NUM, _positive),
    "t_nol": NUM,
    "core_bound": bool,
}

COMPUTE_SPEC = {
    "matmul": {
        "dims": list,
        "ecm": _ECM_DETAIL,
        "blocking": {
            "ranked": [{
                "block": list,
                "t_ecm": (NUM, _positive),
                "core_bound": bool,
                "mem_lines": (NUM, _positive),
                "speedup_vs_min_block": (NUM, _positive),
            }],
            "best": dict,
        },
    },
    "attention": {
        "dims": list,
        "causal": bool,
        "ecm": _ECM_DETAIL,
        "blocking": {
            "ranked": [{
                "block": list,
                "t_ecm": (NUM, _positive),
                "fits": bool,
                "core_bound": bool,
                "tile_bytes": (int, _positive),
            }],
            "best": dict,
        },
    },
    "kernels": {
        "matmul": {
            "shape": list, "block": list, "max_abs_err": NUM,
            "matches_ref": bool, "wall_s": (NUM, _positive),
        },
        "attention": {
            "shape": list, "block": list, "max_abs_err": NUM,
            "matches_ref": bool, "wall_s": (NUM, _positive),
        },
    },
}

def _int_or_none(x):
    if x is None or (isinstance(x, int) and not isinstance(x, bool)):
        return None
    return f"expected int or null, got {x!r}"


def _saturation_workloads(v):
    """Per-workload Eq. 2 entries: every value carries the saturation
    points, the core-bound flag and the two cycle terms."""
    if not isinstance(v, dict) or not v:
        return "expected non-empty object of per-workload entries"
    for name, d in v.items():
        if not isinstance(d, dict):
            return f"[{name}]: expected object"
        for k, typ in (("n_sat_domain", int), ("n_sat_chip", int),
                       ("core_bound", bool), ("t_single_cy", float),
                       ("bottleneck_cy", float)):
            val = d.get(k)
            if not isinstance(val, typ) or (typ is not bool
                                            and isinstance(val, bool)):
                return f"[{name}].{k}: expected {typ.__name__}, got " \
                       f"{type(val).__name__}"
    return None


_BEST_POINT = {
    "f_ghz": (NUM, _positive),
    "n_cores": (int, _positive),
    "energy_J": (NUM, _positive),
    "edp_Js": (NUM, _positive),
}

SCALING_SPEC = {
    "saturation": {
        "workloads": _saturation_workloads,
        "cores_per_domain": (int, _positive),
        "n_domains": (int, _positive),
    },
    "energy": {
        "workload": str,
        "f_ghz": [NUM],
        "n_cores": (int, _positive),
        "grid_energy_J": [list],
        "grid_edp_Js": [list],
        "best_energy": _BEST_POINT,
        "best_edp": _BEST_POINT,
    },
    "operating_points": [{
        "name": str,
        "f_ghz": (NUM, _positive),
        "n_cores": (int, _positive),
        "objective": str,
        "value": (NUM, _positive),
        "runtime_s": (NUM, _positive),
        "energy_J": (NUM, _positive),
        "edp_Js": (NUM, _positive),
    }],
    "tpu_dp": {
        "chips": [(int, _positive)],
        "t_comp_us": [NUM],
        "t_hbm_us": [NUM],
        "t_ici_us": [NUM],
        "t_step_us": [(NUM, _positive)],
        "speedup": [(NUM, _positive)],
        "parallel_efficiency": [(NUM, _positive)],
        "t_ici_floor_us": (NUM, _positive),
        "n_saturation": _int_or_none,
    },
}

def _zero_lost(x):
    return None if x == 0 else f"lost requests must be 0, got {x!r}"


def _num_or_none(x):
    if x is None or (isinstance(x, NUM) and not isinstance(x, bool)):
        return None
    return f"expected number or null, got {x!r}"


#: one fault class's run summary — a request without a terminal state
#: ("lost") is a validation failure, not merely a regression
_SERVE_CLASS = {
    "requests": (int, _positive),
    "completed": (int, _positive),
    "lost": (int, _zero_lost),
    "terminal": dict,
    "tokens": (int, _positive),
    "steps": (int, _positive),
    "makespan": (NUM, _positive),
    "tok_rate": (NUM, _positive),
    "latency_p50": _num_or_none,
    "latency_p99": _num_or_none,
    "deadline_hits": int,
    "step_pred_measured": {
        "mean_ratio": (NUM, _positive),
        "max_ratio": (NUM, _positive),
    },
    "recovery": {"requeued": int, "retried": int, "recovered": int},
    "degrade_max_level": int,
    "events": dict,
    "n_devices_final": (int, _positive),
    "blocks": dict,
}

SERVE_SPEC = {
    "trace": {
        "n_requests": (int, _positive),
        "mean_interarrival_ms": (NUM, _positive),
        "seed": int,
    },
    "classes": {
        "none": _SERVE_CLASS,
        "device_loss": _SERVE_CLASS,
        "slow_step": _SERVE_CLASS,
        "kv_corruption": _SERVE_CLASS,
    },
}

def _compose_phase(name: str, ph: str, p) -> str | None:
    if not isinstance(p, dict):
        return f"[{name}].{ph}: expected object"
    for k in ("predicted_cy", "measured_cy", "flops", "hbm_bytes"):
        val = p.get(k)
        if not isinstance(val, NUM) or isinstance(val, bool) or val <= 0:
            return f"[{name}].{ph}.{k}: expected positive number, got " \
                   f"{val!r}"
    if (not isinstance(p.get("model_error"), NUM)
            or isinstance(p.get("model_error"), bool)):
        return f"[{name}].{ph}.model_error: expected number"
    if not isinstance(p.get("dominant_op"), str) or not p["dominant_op"]:
        return f"[{name}].{ph}.dominant_op: expected non-empty string"
    return None


def _compose_models(v):
    """Per-config composed entries: both phases present, every cycle /
    traffic field finite-positive, and decode <= prefill at the bench's
    equal-context shape (the invariant the test suite pins)."""
    if not isinstance(v, dict) or not v:
        return "expected non-empty object of per-config entries"
    for name, d in v.items():
        if not isinstance(d, dict):
            return f"[{name}]: expected object"
        n_ops = d.get("n_ops")
        if not isinstance(n_ops, int) or isinstance(n_ops, bool) \
                or n_ops <= 0:
            return f"[{name}].n_ops: expected positive int"
        for ph in ("prefill", "decode"):
            err = _compose_phase(name, ph, d.get(ph))
            if err:
                return err
        if d["decode"]["predicted_cy"] > d["prefill"]["predicted_cy"]:
            return f"[{name}]: decode predicted_cy exceeds prefill at " \
                   f"equal context"
    return None


def _compose_zoo(v):
    if not isinstance(v, dict) or not v:
        return "expected non-empty object keyed by machine"
    for m, models in v.items():
        if not isinstance(models, dict) or not models:
            return f"[{m}]: expected non-empty object keyed by config"
        for name, d in models.items():
            for k in ("prefill_cy", "decode_cy"):
                val = d.get(k) if isinstance(d, dict) else None
                if not isinstance(val, NUM) or isinstance(val, bool) \
                        or val <= 0:
                    return f"[{m}][{name}].{k}: expected positive number"
    return None


COMPOSE_SPEC = {
    "shape": {
        "batch": (int, _positive),
        "seq_len": (int, _positive),
        "context": (int, _positive),
    },
    "models": _compose_models,
    "zoo": _compose_zoo,
    "throughput": {
        "n_compositions": (int, _positive),
        "compose_wall_s": (NUM, _positive),
        "compositions_per_s": (NUM, _positive),
    },
}

ENGINE_SPEC = {
    "table": {
        "n_workloads": (int, _positive),
        "n_machines": (int, _positive),
        "rows": (int, _positive),
        "zoo_t_ecm_mem_total_cy": (NUM, _positive),
    },
    "cold_lower": {
        "rows": (int, _positive),
        "wall_s": (NUM, _positive),
        "rows_per_s": (NUM, _positive),
    },
    "warm_eval": {
        "points": (int, _positive),
        "iters": (int, _positive),
        "wall_s": (NUM, _positive),
        "points_per_s": (NUM, _positive),
    },
    "zoo_sweep": {
        "points": (int, _positive),
        "machines": (int, _positive),
        "iters": (int, _positive),
        "wall_s": (NUM, _positive),
        "sweeps_per_s": (NUM, _positive),
    },
    "rerank": {
        "n_candidates": (int, _positive),
        "n_dirty": (int, _positive),
        "full_wall_s": (NUM, _positive),
        "incremental_wall_s": (NUM, _positive),
        "speedup": (NUM, _positive),
        "identical": bool,
    },
    "zoo": dict,
}

def _mesh_winner(ctx: str, w) -> str | None:
    if not isinstance(w, dict):
        return f"{ctx}: expected winner object"
    for k in ("mesh", "profile"):
        if not isinstance(w.get(k), str) or not w[k]:
            return f"{ctx}.{k}: expected non-empty string"
    for k in ("data", "model", "pipe", "microbatches"):
        val = w.get(k)
        if not isinstance(val, int) or isinstance(val, bool) or val <= 0:
            return f"{ctx}.{k}: expected positive int, got {val!r}"
    for k in ("t_step_us", "t_ici_us"):
        val = w.get(k)
        if not isinstance(val, NUM) or isinstance(val, bool) or val < 0:
            return f"{ctx}.{k}: expected non-negative number, got {val!r}"
    bf = w.get("bubble_fraction")
    if not isinstance(bf, NUM) or isinstance(bf, bool) \
            or not 0.0 <= bf <= 1.0:
        return f"{ctx}.bubble_fraction: expected fraction in [0, 1]"
    if _int_or_none(w.get("n_saturation")):
        return f"{ctx}.n_saturation: expected int or null"
    if not isinstance(w.get("fits_hbm"), bool):
        return f"{ctx}.fits_hbm: expected bool"
    if "block" in w and not (isinstance(w["block"], list) and w["block"]):
        return f"{ctx}.block: expected non-empty array when present"
    return None


def _mesh_rankings(v):
    """Per-config golden pins: config -> chip count -> winner + plan
    count.  Every cell must carry a fully-typed winner row — a field
    dropped by a ``rank_meshes`` refactor fails validation here before
    the compare gate ever sees it."""
    if not isinstance(v, dict) or not v:
        return "expected non-empty object keyed by config"
    for cfg, by_n in v.items():
        if not isinstance(by_n, dict) or not by_n:
            return f"[{cfg}]: expected non-empty object keyed by chip count"
        for n, cell in by_n.items():
            if not (isinstance(n, str) and n.isdigit() and int(n) > 0):
                return f"[{cfg}][{n!r}]: chip-count key must be a " \
                       f"positive integer string"
            if not isinstance(cell, dict):
                return f"[{cfg}][{n}]: expected object"
            n_plans = cell.get("n_plans")
            if not isinstance(n_plans, int) or isinstance(n_plans, bool) \
                    or n_plans <= 0:
                return f"[{cfg}][{n}].n_plans: expected positive int"
            err = _mesh_winner(f"[{cfg}][{n}].winner", cell.get("winner"))
            if err:
                return err
    return None


MESH_SPEC = {
    "rankings": _mesh_rankings,
    "dp_scaling": {
        "bit_identical": bool,
        "chips": [(int, _positive)],
        "n_saturation": _int_or_none,
        "t_ici_floor_us": (NUM, _positive),
    },
    "sweep": {
        "configs": (int, _positive),
        "chip_counts": [(int, _positive)],
        "plans": (int, _positive),
        "wall_s": (NUM, _positive),
        "plans_per_s": (NUM, _positive),
    },
}

#: validation ceiling on the worst per-field calibration fit residual —
#: mirrors ``repro.core.calibrate.MAX_FIT_RESIDUAL`` (this checker is
#: stdlib-only, so the bound is pinned here rather than imported; the
#: test suite asserts the two stay equal).  The fits invert the
#: measurement backend's own forward response, so any residual beyond
#: this means the fitting inversion or the measurements changed.
MAX_CALIBRATE_RESIDUAL = 0.02


def _nonneg(x):
    return None if x >= 0 else f"expected >= 0, got {x!r}"


def _zero_refits(x):
    return None if x == 0 else \
        f"warm run against the disk cache must not re-fit/re-measure, " \
        f"got {x!r}"


def _residual_bound(x):
    return None if 0.0 <= x <= MAX_CALIBRATE_RESIDUAL else \
        f"fit residual {x!r} exceeds the calibration gate " \
        f"{MAX_CALIBRATE_RESIDUAL}"


def _calibrate_groups(v):
    """Per-field-class fit summaries; every group's worst residual is
    held to the same ``MAX_CALIBRATE_RESIDUAL`` gate as the overall max."""
    if not isinstance(v, dict) or not v:
        return "expected non-empty object keyed by field group"
    for g, s in v.items():
        if not isinstance(s, dict):
            return f"[{g}]: expected object"
        for k in ("n", "n_snapped"):
            val = s.get(k)
            if not isinstance(val, int) or isinstance(val, bool) or val < 0:
                return f"[{g}].{k}: expected non-negative int"
        r = s.get("max_residual")
        if not isinstance(r, NUM) or isinstance(r, bool):
            return f"[{g}].max_residual: expected number"
        err = _residual_bound(r)
        if err:
            return f"[{g}].max_residual: {err}"
    return None


CALIBRATE_SPEC = {
    "fit": {
        "base": str,
        "backend": str,
        "snap_rtol": (NUM, _fraction),
        "n_fields": (int, _positive),
        "n_snapped": (int, _nonneg),
        "residual_max": (NUM, _residual_bound),
        "model_gap_max": (NUM, _nonneg),
        "groups": _calibrate_groups,
        "measurement_hash": str,
        "fit_wall_s": (NUM, _nonneg),
    },
    "roundtrip": {
        "schema": (int, _positive),
        "reload_equal": bool,
        "machine_equal_prior": bool,
        "dict_equal_prior": bool,
        "zoo_files": (int, _positive),
        "zoo_files_match_registry": bool,
    },
    "cache": {
        "cold_wall_s": (NUM, _positive),
        "cold_fits": (int, _positive),
        "warm_wall_s": (NUM, _positive),
        "speedup": (NUM, _positive),
        "warm_fits": (int, _zero_refits),
        "warm_measurements": (int, _zero_refits),
        "warm_from_cache": bool,
        "warm_identical": bool,
    },
}

SPECS = {"stream": STREAM_SPEC, "stencil": STENCIL_SPEC,
         "compute": COMPUTE_SPEC, "scaling": SCALING_SPEC,
         "tpu": TPU_SPEC, "serve": SERVE_SPEC, "compose": COMPOSE_SPEC,
         "engine": ENGINE_SPEC, "mesh": MESH_SPEC,
         "calibrate": CALIBRATE_SPEC}

#: distinctive payload keys for suite inference on legacy (schema 1)
#: files; "rankings" must precede "sweep" (mesh payloads carry both),
#: "warm_eval" must precede "zoo" (engine payloads carry both) and
#: "models" must precede "zoo" — compose payloads carry both
SUITE_HINTS = (("model_eval", "stream"), ("rankings", "mesh"),
               ("roundtrip", "calibrate"), ("sweep", "stencil"),
               ("matmul", "compute"), ("tpu_dp", "scaling"),
               ("classes", "serve"), ("warm_eval", "engine"),
               ("models", "compose"), ("zoo", "tpu"))


def check_value(path: str, value, spec, problems: list[str]) -> None:
    if isinstance(spec, dict):
        if not isinstance(value, dict):
            problems.append(f"{path}: expected object, got "
                            f"{type(value).__name__}")
            return
        for k, sub in spec.items():
            if k not in value:
                problems.append(f"{path}.{k}: missing")
                continue
            check_value(f"{path}.{k}", value[k], sub, problems)
    elif isinstance(spec, list):
        if not isinstance(value, list) or not value:
            problems.append(f"{path}: expected non-empty array")
            return
        for i, item in enumerate(value):
            check_value(f"{path}[{i}]", item, spec[0], problems)
    elif (isinstance(spec, tuple) and len(spec) == 2
          and not isinstance(spec[1], type) and callable(spec[1])):
        typ, pred = spec
        if not isinstance(value, typ) or isinstance(value, bool):
            problems.append(f"{path}: expected {typ}, got "
                            f"{type(value).__name__}")
            return
        err = pred(value)
        if err:
            problems.append(f"{path}: {err}")
    elif not isinstance(spec, type) and callable(spec):
        err = spec(value)
        if err:
            problems.append(f"{path}: {err}")
    else:
        if not isinstance(value, spec) or (spec is not bool
                                           and isinstance(value, bool)):
            problems.append(f"{path}: expected {spec}, got "
                            f"{type(value).__name__}")


def check_file(path: Path) -> list[str]:
    problems: list[str] = []
    rel = path.name
    try:
        payload = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError) as e:
        return [f"{rel}: unreadable JSON ({e})"]
    if not isinstance(payload, dict):
        return [f"{rel}: top level must be an object"]

    schema = payload.get("schema")
    if not isinstance(schema, int) or schema < 1:
        problems.append(f"{rel}.schema: missing or not a positive int")
        schema = 1

    suite = payload.get("suite")
    if suite is not None and suite not in SUITES:
        # an unrecognized suite name is a hard error, never a skip: a
        # typo'd or unregistered suite must not slide through the gate
        problems.append(f"{rel}.suite: unrecognized suite {suite!r} "
                        f"(known: {', '.join(SUITES)})")
        return problems
    if suite is None:
        suite = next((s for k, s in SUITE_HINTS if k in payload), None)
        if schema >= 2:
            problems.append(f"{rel}.suite: missing (required for schema "
                            f">= 2)")
    if schema >= 2 and not isinstance(payload.get("machine"), str):
        problems.append(f"{rel}.machine: missing or not a string")

    if suite is None:
        problems.append(f"{rel}: cannot determine suite; keys = "
                        f"{sorted(payload)[:8]}")
        return problems
    check_value(rel, payload, SPECS[suite], problems)
    return problems


# ---------------------------------------------------------------------------
# The bench-regression gate (--compare): deterministic values only
# ---------------------------------------------------------------------------

#: path segments whose values depend on the runner's wall clock / machine
#: rather than on the model: never compared across runs.
VOLATILE_PARTS = ("wall", "per_s", "throughput", "reduction", "exposed",
                  "err")
#: exact key names that are wall-clock-derived even though similarly named
#: fields elsewhere are deterministic (``speedup_vs_unblocked`` is a model
#: ratio; the fused-pipeline ``speedup`` is measured).
VOLATILE_KEYS = frozenset({"speedup"})


def _is_volatile(key: str) -> bool:
    k = key.lower()
    return (k in VOLATILE_KEYS or k.endswith("_s")
            or any(p in k for p in VOLATILE_PARTS))


def _rel_close(a: float, b: float, rtol: float) -> bool:
    return abs(a - b) <= rtol * max(abs(a), abs(b), 1e-300)


def compare_values(path: str, new, base, rtol: float,
                   problems: list[str]) -> None:
    """Recursive diff of the deterministic (model-derived) leaves."""
    if isinstance(base, dict):
        if not isinstance(new, dict):
            problems.append(f"{path}: object became {type(new).__name__}")
            return
        for k in sorted(set(base) | set(new)):
            if _is_volatile(k):
                continue
            sub = f"{path}.{k}"
            if k not in new:
                problems.append(f"{sub}: missing from new artifact")
            elif k not in base:
                problems.append(f"{sub}: not in baseline (schema drift — "
                                f"regenerate the committed baseline)")
            else:
                compare_values(sub, new[k], base[k], rtol, problems)
    elif isinstance(base, list):
        if not isinstance(new, list):
            problems.append(f"{path}: array became {type(new).__name__}")
            return
        if len(new) != len(base):
            problems.append(f"{path}: length {len(base)} -> {len(new)}")
            return
        for i, (nv, bv) in enumerate(zip(new, base)):
            compare_values(f"{path}[{i}]", nv, bv, rtol, problems)
    elif isinstance(base, bool) or isinstance(new, bool):
        if new != base:
            problems.append(f"{path}: {base} -> {new}")
    elif isinstance(base, (int, float)) and isinstance(new, (int, float)):
        if not _rel_close(float(new), float(base), rtol):
            drift = (float(new) - float(base)) / max(abs(float(base)), 1e-300)
            problems.append(f"{path}: {base} -> {new} "
                            f"({drift:+.2%} > rtol {rtol:.2%})")
    elif new != base:
        problems.append(f"{path}: {base!r} -> {new!r}")


def compare_files(new_path: Path, base_path: Path, rtol: float) -> list[str]:
    problems: list[str] = []
    try:
        new = json.loads(new_path.read_text(encoding="utf-8"))
        base = json.loads(base_path.read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError) as e:
        return [f"compare: unreadable JSON ({e})"]
    if (isinstance(new, dict) and isinstance(base, dict)
            and new.get("suite") != base.get("suite")):
        return [f"compare: suite mismatch — new {new.get('suite')!r} vs "
                f"baseline {base.get('suite')!r}; comparing artifacts of "
                f"different suites is meaningless"]
    compare_values(new_path.name, new, base, rtol, problems)
    return problems


def check_floors(files: list[Path], floors: list[str]) -> list[str]:
    """Opt-in throughput floors: ``--floor suite.dotted.path=value``.

    Volatile (wall-clock) fields are excluded from ``--compare`` by
    design; a floor is the one sanctioned way to gate them — an absolute
    lower bound the runner must clear, not a diff against a baseline.
    Every floor must match at least one artifact of its suite, so a
    typo'd suite or path fails the gate instead of passing silently.
    """
    problems: list[str] = []
    by_suite: dict[str, list[tuple[Path, dict]]] = {}
    for f in files:
        try:
            payload = json.loads(f.read_text(encoding="utf-8"))
        except (OSError, json.JSONDecodeError):
            continue                    # already reported by check_file
        if not isinstance(payload, dict):
            continue
        suite = payload.get("suite")
        if suite is None:
            suite = next((s for k, s in SUITE_HINTS if k in payload), None)
        if suite:
            by_suite.setdefault(suite, []).append((f, payload))

    for spec in floors:
        lhs, sep, rhs = spec.partition("=")
        parts = lhs.split(".")
        try:
            floor = float(rhs)
        except ValueError:
            floor = None
        if not sep or floor is None or len(parts) < 2:
            problems.append(f"--floor {spec!r}: expected "
                            f"suite.dotted.path=number")
            continue
        suite, path = parts[0], parts[1:]
        matched = by_suite.get(suite, [])
        if not matched:
            # name the floor *and* the missing suite explicitly: with
            # several --floor flags the gate must say which one matched
            # nothing, and against which artifact set
            present = ", ".join(sorted(by_suite)) or "none"
            hint = (f" ({suite!r} is not a known suite; expected one of "
                    f"{', '.join(SUITES)})" if suite not in SUITES else "")
            problems.append(
                f"--floor {spec!r}: no artifact for suite {suite!r} among "
                f"the {len(files)} checked file(s) — suites present: "
                f"{present}{hint}")
            continue
        for f, payload in matched:
            cur = payload
            for seg in path:
                cur = cur.get(seg) if isinstance(cur, dict) else None
            if not isinstance(cur, (int, float)) or isinstance(cur, bool):
                problems.append(f"{f.name}: --floor {spec}: "
                                f"{'.'.join(path)} is not a number "
                                f"({cur!r})")
            elif cur < floor:
                problems.append(f"{f.name}: {'.'.join(path)} = {cur:g} "
                                f"below floor {floor:g}")
    return problems


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(
        description="BENCH artifact schema check + regression gate")
    ap.add_argument("files", nargs="*",
                    help="artifacts to validate (default: BENCH_*.json)")
    ap.add_argument("--compare", metavar="BASELINE", default=None,
                    help="regression gate: diff the single given artifact "
                         "against this baseline (deterministic fields only)")
    ap.add_argument("--rtol", type=float, default=0.05,
                    help="relative drift tolerance for --compare "
                         "(default: 0.05)")
    ap.add_argument("--floor", action="append", default=[],
                    metavar="SUITE.PATH=VALUE",
                    help="opt-in throughput floor, e.g. "
                         "engine.warm_eval.points_per_s=14000000; fails "
                         "if any matching artifact's value is below VALUE "
                         "(repeatable; errors if no artifact of SUITE is "
                         "among the checked files)")
    args = ap.parse_args(argv)

    if args.files:
        files = [Path(a).resolve() for a in args.files]
    else:
        files = sorted(ROOT.glob("BENCH_*.json"))
    if not files:
        print("check_bench: no BENCH_*.json artifacts found", file=sys.stderr)
        return 1
    if args.compare and len(files) != 1:
        print("check_bench: --compare takes exactly one artifact to diff",
              file=sys.stderr)
        return 1
    baseline = Path(args.compare).resolve() if args.compare else None
    missing = [f for f in files if not f.exists()]
    if baseline is not None and not baseline.exists():
        missing.append(baseline)
    if missing:
        for f in missing:
            print(f"missing file: {f}", file=sys.stderr)
        return 1
    problems: list[str] = []
    for f in files:
        problems += check_file(f)
    if baseline is not None:
        problems += check_file(baseline)
        problems += compare_files(files[0], baseline, args.rtol)
    if args.floor:
        problems += check_floors(files, args.floor)
    if problems:
        print("\n".join(problems), file=sys.stderr)
        print(f"\ncheck_bench: {len(problems)} problem(s) in "
              f"{len(files)} file(s)", file=sys.stderr)
        return 1
    what = (f"{files[0].name} vs baseline {baseline.name} "
            f"(rtol {args.rtol:.2%})" if baseline is not None
            else f"{len(files)} artifact(s)")
    print(f"check_bench: {what} clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
