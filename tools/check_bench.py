#!/usr/bin/env python3
"""BENCH artifact check: stdlib JSON-schema validation for the
perf-trajectory files emitted by ``benchmarks/run.py --json``.

    python tools/check_bench.py [files...]      # default: BENCH_*.json

Every artifact shares one envelope (``schema`` version, ``suite``,
``machine``) plus a per-suite payload; this checker pins the field names
and types that downstream trajectory tooling relies on, so a refactor
that silently drops or renames a field fails CI instead of producing
holes in the perf history.  Legacy ``schema: 1`` files (no envelope) are
accepted — the suite is inferred from their distinctive payload keys.

Exit code 0 when clean, 1 with a per-finding report otherwise.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

SUITES = ("stream", "stencil", "tpu")

#: minimal spec language: {key: type | (type, predicate) | dict (nested) |
#: [element_spec] (non-empty list) | callable(value) -> error or None}
NUM = (int, float)


def _positive(x):
    return None if x > 0 else f"expected > 0, got {x!r}"


def _fraction(x):
    return None if 0.0 <= x <= 1.0 else f"expected in [0, 1], got {x!r}"


STREAM_SPEC = {
    "pipeline": {
        "kernels": dict,
        "fused_triad_update": {
            "fused_s": (NUM, _positive),
            "unfused_s": (NUM, _positive),
            "speedup": NUM,
            "predicted_stream_ratio": NUM,
        },
        "overlap": {
            "kernel": str,
            "t_serial_s": NUM,
            "t_pipelined_s": NUM,
            "exposed_hbm_fraction": (NUM, _fraction),
        },
    },
    "model_eval": {
        "batch_points": (int, _positive),
        "batch_wall_s": (NUM, _positive),
        "batch_points_per_s": (NUM, _positive),
        "batch_array_evals": (int, _positive),
        "python_calls_per_point_batch": NUM,
        "scalar_points_per_s": (NUM, _positive),
        "throughput_ratio": (NUM, _positive),
        "per_point_call_reduction": (NUM, _positive),
    },
    "autotune": {
        "n_candidates": (int, _positive),
        "batch_rank_wall_s": (NUM, _positive),
        "best_config": dict,
    },
}

STENCIL_SPEC = {
    "sweep": [{
        "n": (int, _positive),
        "ws_kib": (NUM, _positive),
        "regime": str,
        "lc_misses": list,
        "predicted_cy_per_cl": (NUM, _positive),
        "measured_cy_per_cl": (NUM, _positive),
        "model_error": NUM,
    }],
    "blocking": {
        "n": (int, _positive),
        "ranked": [{
            "block": list,
            "t_ecm": (NUM, _positive),
            "misses_l1": (int, _positive),
            "speedup_vs_unblocked": (NUM, _positive),
        }],
        "best": dict,
    },
    "kernels": {
        "shape": list,
        "stages": dict,
    },
}

TPU_SPEC = {
    "pipeline": {"kernels": dict},
    "zoo": dict,
}

SPECS = {"stream": STREAM_SPEC, "stencil": STENCIL_SPEC, "tpu": TPU_SPEC}

#: distinctive payload keys for suite inference on legacy (schema 1) files
SUITE_HINTS = (("model_eval", "stream"), ("sweep", "stencil"),
               ("zoo", "tpu"))


def check_value(path: str, value, spec, problems: list[str]) -> None:
    if isinstance(spec, dict):
        if not isinstance(value, dict):
            problems.append(f"{path}: expected object, got "
                            f"{type(value).__name__}")
            return
        for k, sub in spec.items():
            if k not in value:
                problems.append(f"{path}.{k}: missing")
                continue
            check_value(f"{path}.{k}", value[k], sub, problems)
    elif isinstance(spec, list):
        if not isinstance(value, list) or not value:
            problems.append(f"{path}: expected non-empty array")
            return
        for i, item in enumerate(value):
            check_value(f"{path}[{i}]", item, spec[0], problems)
    elif (isinstance(spec, tuple) and len(spec) == 2
          and not isinstance(spec[1], type) and callable(spec[1])):
        typ, pred = spec
        if not isinstance(value, typ) or isinstance(value, bool):
            problems.append(f"{path}: expected {typ}, got "
                            f"{type(value).__name__}")
            return
        err = pred(value)
        if err:
            problems.append(f"{path}: {err}")
    else:
        if not isinstance(value, spec) or (spec is not bool
                                           and isinstance(value, bool)):
            problems.append(f"{path}: expected {spec}, got "
                            f"{type(value).__name__}")


def check_file(path: Path) -> list[str]:
    problems: list[str] = []
    rel = path.name
    try:
        payload = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError) as e:
        return [f"{rel}: unreadable JSON ({e})"]
    if not isinstance(payload, dict):
        return [f"{rel}: top level must be an object"]

    schema = payload.get("schema")
    if not isinstance(schema, int) or schema < 1:
        problems.append(f"{rel}.schema: missing or not a positive int")
        schema = 1

    suite = payload.get("suite")
    if suite is None:
        suite = next((s for k, s in SUITE_HINTS if k in payload), None)
        if schema >= 2:
            problems.append(f"{rel}.suite: missing (required for schema "
                            f">= 2)")
    elif suite not in SUITES:
        problems.append(f"{rel}.suite: unknown suite {suite!r}")
        suite = None
    if schema >= 2 and not isinstance(payload.get("machine"), str):
        problems.append(f"{rel}.machine: missing or not a string")

    if suite is None:
        problems.append(f"{rel}: cannot determine suite; keys = "
                        f"{sorted(payload)[:8]}")
        return problems
    check_value(rel, payload, SPECS[suite], problems)
    return problems


def main(argv: list[str]) -> int:
    if argv:
        files = [Path(a).resolve() for a in argv]
    else:
        files = sorted(ROOT.glob("BENCH_*.json"))
    if not files:
        print("check_bench: no BENCH_*.json artifacts found", file=sys.stderr)
        return 1
    missing = [f for f in files if not f.exists()]
    if missing:
        for f in missing:
            print(f"missing file: {f}", file=sys.stderr)
        return 1
    problems: list[str] = []
    for f in files:
        problems += check_file(f)
    if problems:
        print("\n".join(problems), file=sys.stderr)
        print(f"\ncheck_bench: {len(problems)} problem(s) in "
              f"{len(files)} file(s)", file=sys.stderr)
        return 1
    print(f"check_bench: {len(files)} artifact(s) clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
