#!/usr/bin/env python3
"""Docs build check: lightweight markdown lint + dead-link check.

Stdlib-only so it runs identically in CI and in this container:

    python tools/check_docs.py [files...]       # default: README.md docs/*.md

Checks, per file:

* **lint** — balanced code fences; no trailing whitespace; ATX headings
  start at column 0 and have a space after the hashes; exactly one H1;
* **links** — every relative markdown link/image target resolves on disk
  (anchors like ``#section`` are checked against the target file's
  headings; bare in-page anchors against the current file); external
  ``http(s)``/``mailto`` links are not fetched (no network in CI).

Exit code 0 when clean, 1 with a per-finding report otherwise.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"!?\[(?:[^\]\[]|\[[^\]]*\])*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^(#{1,6})(.*)$")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug (good enough for our headings)."""
    s = re.sub(r"[`*_]", "", heading.strip().lower())
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def heading_slugs(path: Path) -> set[str]:
    slugs: set[str] = set()
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        m = HEADING_RE.match(line)
        if m and not in_fence:
            slugs.add(slugify(m.group(2)))
    return slugs


def check_file(path: Path) -> list[str]:
    problems: list[str] = []
    rel = path.relative_to(ROOT)
    text = path.read_text(encoding="utf-8")
    lines = text.splitlines()

    # ---- lint ----
    fence_opens = 0
    in_fence = False
    h1s = 0
    for i, line in enumerate(lines, 1):
        stripped = line.rstrip("\n")
        if stripped != stripped.rstrip():
            problems.append(f"{rel}:{i}: trailing whitespace")
        if line.lstrip().startswith("```"):
            fence_opens += 1
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if m:
            if m.group(2) and not m.group(2).startswith(" "):
                problems.append(f"{rel}:{i}: heading missing space after '#'")
            if len(m.group(1)) == 1:
                h1s += 1
        elif re.match(r"^\s+#{1,6}\s", line):
            problems.append(f"{rel}:{i}: indented heading")
    if fence_opens % 2:
        problems.append(f"{rel}: unbalanced code fences")
    if h1s != 1:
        problems.append(f"{rel}: expected exactly one H1, found {h1s}")

    # ---- links ----
    in_fence = False
    for i, line in enumerate(lines, 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if target.startswith("#"):
                if slugify(target[1:]) not in heading_slugs(path):
                    problems.append(
                        f"{rel}:{i}: dead in-page anchor {target!r}")
                continue
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            dest = (path.parent / target).resolve()
            if not dest.exists():
                problems.append(f"{rel}:{i}: dead link {m.group(1)!r}")
                continue
            if frag and dest.suffix == ".md":
                if slugify(frag) not in heading_slugs(dest):
                    problems.append(
                        f"{rel}:{i}: dead anchor {m.group(1)!r}")
    return problems


def main(argv: list[str]) -> int:
    if argv:
        files = [Path(a).resolve() for a in argv]
    else:
        files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    missing = [f for f in files if not f.exists()]
    if missing:
        for f in missing:
            print(f"missing file: {f}", file=sys.stderr)
        return 1
    problems: list[str] = []
    for f in files:
        problems += check_file(f)
    if problems:
        print("\n".join(problems), file=sys.stderr)
        print(f"\ncheck_docs: {len(problems)} problem(s) in "
              f"{len(files)} file(s)", file=sys.stderr)
        return 1
    print(f"check_docs: {len(files)} file(s) clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
