"""ECM explorer: what-if analysis with the analytical model.

Answers the paper's §IV questions for any kernel/machine combination from
the command line — which level bottlenecks, where the multicore saturation
point sits, what non-temporal stores would buy, and what an SMT/AVX-512
style machine change would do.

Run:  PYTHONPATH=src python examples/ecm_explorer.py --kernel striad
      PYTHONPATH=src python examples/ecm_explorer.py --kernel schoenauer \
          --optimized-agu --bw 30e9
"""
import argparse
import dataclasses

from repro.core import BENCHMARKS, HASWELL_EP
from repro.core.saturation import ScalingModel
from repro.simcache import simulate_level


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", default="striad", choices=sorted(BENCHMARKS))
    ap.add_argument("--bw", type=float, default=None,
                    help="sustained memory-domain bandwidth [B/s]")
    ap.add_argument("--optimized-agu", action="store_true")
    ap.add_argument("--clock-ghz", type=float, default=2.3)
    args = ap.parse_args()

    spec = BENCHMARKS[args.kernel]
    machine = dataclasses.replace(HASWELL_EP, clock_hz=args.clock_ghz * 1e9)
    bw = args.bw or HASWELL_EP.measured_bw[args.kernel]
    ecm = spec.ecm(machine, bw, optimized_agu=args.optimized_agu)

    print(f"kernel    : {spec.name}   ({spec.expr})")
    print(f"streams   : {spec.loads_explicit} load + {spec.rfo} RFO + "
          f"{spec.stores} store + {spec.nt_stores} NT")
    print(f"ECM input : {ecm.notation()} cy/CL")
    print(f"prediction: {ecm.prediction_notation()} cy/CL")
    for lv, name in enumerate(ecm.levels):
        pred = ecm.prediction(lv)
        sim = simulate_level(spec, lv, machine=machine, sustained_bw=bw,
                             optimized_agu=args.optimized_agu)
        mups = spec.elems_per_line(64) * machine.clock_hz / pred / 1e6
        print(f"  {name:4s}: model {pred:6.1f} cy/CL  sim {sim:6.1f} cy/CL "
              f"  -> {mups:8.0f} MUp/s/core")
    sat = ScalingModel.from_ecm(ecm)
    print(f"saturation: {sat.n_saturation} cores per memory domain (Eq. 2)")
    if spec.stores and not args.optimized_agu:
        nt = BENCHMARKS.get(f"{spec.name}_nt")
        if nt:
            bw_nt = HASWELL_EP.measured_bw[nt.name]
            e_nt = nt.ecm(machine, bw_nt)
            x = ecm.prediction(3) / e_nt.prediction(3)
            print(f"non-temporal stores would give {x:.2f}x in memory "
                  f"(roofline alone says "
                  f"{spec.mem_streams/(nt.mem_streams):.2f}x)")


if __name__ == "__main__":
    main()
