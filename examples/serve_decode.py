"""Batched serving example: prefill a prompt batch, decode with KV/state
caches, for any architecture family (dense KV cache, Mamba2 SSM state,
xLSTM matrix memory, Whisper cross-attention cache).

Run:  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-1.2b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_arch
from repro.configs.base import ShapeSpec
from repro.models.common import materialize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    arch = get_arch(args.arch, smoke=True)
    if not arch.has_decoder:
        raise SystemExit(f"{arch.name} has no decoder")
    params = materialize(arch.param_spec(), jax.random.key(0))
    shape = ShapeSpec("serve", seq_len=args.prompt_len,
                      global_batch=args.batch, kind="prefill")
    batch = {k: jnp.asarray(v) for k, v in arch.make_batch(shape).items()}
    max_len = args.prompt_len + args.gen + 8

    prefill = jax.jit(lambda p, b: arch.prefill(p, b, max_len=max_len))
    decode = jax.jit(arch.decode)

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    print(f"[prefill] batch={args.batch} len={args.prompt_len} "
          f"in {time.perf_counter()-t0:.2f}s "
          f"(cache leaves: {len(jax.tree.leaves(cache))})")

    tok = jnp.argmax(logits[:, -1, : arch.cfg.vocab], -1)[:, None]
    outs = [np.asarray(tok[:, 0])]
    t0 = time.perf_counter()
    for _ in range(args.gen):
        logits, cache = decode(params, cache,
                               {"tokens": tok.astype(jnp.int32)})
        tok = jnp.argmax(logits[:, -1, : arch.cfg.vocab], -1)[:, None]
        outs.append(np.asarray(tok[:, 0]))
    jax.block_until_ready(logits)
    dt = (time.perf_counter() - t0) / args.gen
    print(f"[decode]  {args.gen} tokens at {dt*1e3:.1f} ms/token (greedy)")
    print(f"[tokens]  {np.stack(outs, 1).tolist()}")


if __name__ == "__main__":
    main()
