"""Quickstart: the ECM model in two minutes.

1. Paper mode — build the ECM model for a streaming kernel on Haswell-EP
   from first principles and compare with the paper's Table I.
2. Stencil mode — layer-condition-aware ECM for the 2D Jacobi: the model
   inputs change with problem width, and spatial blocking is ranked by
   predicted T_ECM (see docs/ecm-model.md).
3. Compute mode — the in-core limit: blocked matmul hits the FMA peak on
   Haswell and the MXU rate on the TPU; the ECM autotuner picks the
   block sizes the Pallas kernel runs with.
4. TPU mode — jit a small training step, pull FLOPs/bytes/collectives out
   of the compiled artifact and build the three-term TPU-ECM model that
   drives the framework's §Roofline analysis.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

# --- 1. paper mode ---------------------------------------------------------
from repro.core import haswell_ecm, PAPER_TABLE1_PREDICTIONS
from repro.core.saturation import ScalingModel

print("== ECM on Haswell-EP (paper Table I) ==")
for name in ("ddot", "striad", "schoenauer"):
    ecm = haswell_ecm(name)
    sat = ScalingModel.from_ecm(ecm)
    print(f"{name:12s} input {ecm.notation():28s} -> prediction "
          f"{ecm.prediction_notation()}  (paper: "
          f"{PAPER_TABLE1_PREDICTIONS[name]}), saturates at "
          f"{sat.n_saturation} cores/domain (Eq. 2)")

# --- 2. stencil mode (layer conditions, arXiv:1410.5010) -------------------
from repro.core import JACOBI2D, stencil_ecm
from repro.core.autotune import rank

print("\n== Layer-condition ECM: 2D 5-point Jacobi ==")
for n in (512, 8192):
    ecm = stencil_ecm("jacobi2d", widths=(n,))
    print(f"N={n:<6d} L1/L2/L3 misses {JACOBI2D.misses_per_level((n,))} "
          f"input {ecm.notation():26s} -> {ecm.prediction_notation()}")
best = rank("jacobi2d", widths=(8192,))[0]
print(f"autotuned blocking at N=8192: block {best['block']} "
      f"({best['speedup_vs_unblocked']:.2f}x predicted vs unblocked)")

# --- 3. compute mode (the in-core limit) -----------------------------------
from repro.core import workload_ecm, workload_registry

print("\n== Compute-bound ECM: blocked matmul (T_OL dominates) ==")
mm = workload_registry()["matmul"]
for machine in ("haswell-ep", "tpu-v5e"):
    ecm = workload_ecm(mm, machine)
    bound = "core" if ecm.core_bound() else "transfer"
    print(f"{machine:12s} {ecm.notation():34s} -> "
          f"{ecm.prediction_notation()}  ({bound}-bound)")
best = rank((4096, 4096, 4096), objective="matmul")[0]
print(f"autotuned tiling: bm x bn = {best['block'][0]}x{best['block'][1]} "
      f"(core-bound: {best['core_bound']}, "
      f"{best['mem_lines']:.0f} mem lines/CL)")

# --- 4. TPU mode -----------------------------------------------------------
from repro.configs import get_arch
from repro.configs.base import ShapeSpec
from repro.core import hlo
from repro.core.tpu_ecm import MeshSpec, from_resources
from repro.optim import AdamWConfig
from repro.train.steps import init_state, make_train_step

print("\n== TPU-ECM of a compiled train step (smoke config) ==")
arch = get_arch("internlm2-1.8b", smoke=True)
opt = AdamWConfig()
state = init_state(arch, jax.random.key(0), opt)
shape = ShapeSpec("demo", seq_len=32, global_batch=4, kind="train")
batch = {k: jnp.asarray(v) for k, v in arch.make_batch(shape).items()}

lowered = jax.jit(make_train_step(arch, opt)).lower(state, batch)
compiled = lowered.compile()
res = hlo.analyze(compiled, lowered, n_devices=1)
ecm = from_resources(res, MeshSpec(shape=(1,), axes=("data",)),
                     name=f"{arch.name}-smoke/train",
                     model_flops=arch.model_flops(shape),
                     flops_are_global=False)
print(f"FLOPs/chip {res.flops:.3e}, bytes/chip {res.bytes_accessed:.3e}")
print(f"T_comp {ecm.t_comp*1e6:.1f} us | T_hbm {ecm.t_hbm*1e6:.1f} us | "
      f"T_ici {ecm.t_ici*1e6:.1f} us -> dominant: {ecm.dominant}")
print(f"paper notation: {ecm.as_ecm_model()}")

# the step still runs for real:
state2, metrics = jax.jit(make_train_step(arch, opt))(state, batch)
print(f"one real step: loss = {float(metrics['loss']):.3f}")


# --- 4. One model, many machines -------------------------------------------
from repro.core import get_machine, zoo_predictions

print("\n== Cross-generation zoo: striad on every registered machine ==")
for mach, rows in zoo_predictions().items():
    levels, preds = rows["striad"]
    notes = []
    m = get_machine(mach)
    if m.victim_l3:
        notes.append("victim L3")
    if not m.write_allocate:
        notes.append("no write-allocate")
    tag = f"  ({', '.join(notes)})" if notes else ""
    print(f"  {mach:>16}: " + " ] ".join(
        f"{lv}={p:.1f}" for lv, p in zip(levels, preds)) + tag)
