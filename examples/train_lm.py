"""End-to-end training driver example (CPU scale).

Trains a reduced-config LM for a few hundred steps through the full
production stack — sharded state on a host mesh, deterministic synthetic
pipeline, AdamW + cosine schedule, atomic checkpoints, straggler watchdog —
then kills the process state and restarts from the latest checkpoint to
demonstrate fault tolerance.

Run:  PYTHONPATH=src python examples/train_lm.py [--arch xlstm-125m]
      [--steps 300]
"""
import argparse
import shutil

from repro.configs import ARCH_NAMES, get_arch
from repro.configs.base import ShapeSpec
from repro.data.arch_data import ArchSyntheticDataset
from repro.dist.sharding import get_profile
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig
from repro.optim.schedule import linear_warmup_cosine
from repro.train.driver import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="results/example_ckpt")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    arch = get_arch(args.arch, smoke=True)
    mesh = make_host_mesh(model=1)
    profile = get_profile(arch.profile)
    shape = ShapeSpec("train", seq_len=args.seq, global_batch=args.batch,
                      kind="train")
    data = ArchSyntheticDataset(arch, shape, seed=0)
    opt = AdamWConfig()
    sched = linear_warmup_cosine(3e-3, 20, args.steps)

    def trainer(total_steps):
        return Trainer(arch, data, mesh, profile, opt, sched, TrainerConfig(
            total_steps=total_steps, ckpt_dir=args.ckpt_dir,
            ckpt_interval=50, log_interval=25))

    # phase 1: train to ~60% and "crash"
    crash_at = args.steps * 6 // 10
    t1 = trainer(crash_at)
    out1 = t1.run()
    print(f"[phase 1] step {crash_at}: loss "
          f"{out1['losses'][0]:.3f} -> {out1['final_loss']:.3f}")
    print("[phase 1] simulated crash; process state dropped")

    # phase 2: fresh Trainer restores the latest checkpoint and finishes
    t2 = trainer(args.steps)
    out2 = t2.run()
    resumed_from = args.steps - len(out2["losses"])
    print(f"[phase 2] restored from step {resumed_from}, "
          f"finished at {args.steps}: loss {out2['final_loss']:.3f}")
    assert out2["final_loss"] < out1["losses"][0], "loss should improve"
    print("[ok] end-to-end train + checkpoint-restart complete")


if __name__ == "__main__":
    main()
