"""Request-path engine speed: the precompiled lowering table, the warm
vectorized Eq. 1/Eq. 2 evaluation path, and incremental re-ranking.

    PYTHONPATH=src python -m benchmarks.run --suite engine
    PYTHONPATH=src python -m benchmarks.run --json --suite engine

Four measurements, all over the same registry (every workload x every
machine):

* **cold lowering** — first-touch cost of lowering the full zoo with the
  table bypassed (``lower_many(..., table=False)`` under
  ``engine.cache_disabled()``): what every request paid before the table.
* **warm eval** — the steady-state request path: full working-set +
  scaling surfaces from warm table rows and memoized level curves
  (fixed rep count, so the point total is deterministic).
* **zoo sweep** — the whole Eq. 2 grid (workload x machine x cores x
  frequency) from packed warm rows; the engine floor gates its rate.
* **re-rank** — full attention-block re-rank vs the incremental path
  (``prior`` + small dirty set); the two rankings must be *identical*,
  which the artifact records as a deterministic boolean.

The deterministic anchor is ``table.zoo_t_ecm_mem_total_cy``: the summed
memory-level ``T_ECM`` over every (workload, machine) row, computed
through the table.  Any fast-path drift from the reference lowering moves
this checksum and fails the regression gate.
"""
from __future__ import annotations

import time

from .util import fmt, table

#: fixed rep counts — keep the deterministic point totals stable
WARM_EVAL_ITERS = 5
ZOO_SWEEP_ITERS = 20
RERANK_DIMS = (4096, 4096, 128)
RERANK_DIRTY = ((128, 128), (256, 256))


def table_payload() -> dict:
    """Build the full-registry lowered table; deterministic checksum."""
    from repro.core import MACHINES, workload_registry
    from repro.core.engine import lowered_table

    tab = lowered_table()
    tab.build()
    total = 0.0
    for m in sorted(MACHINES):
        for w in workload_registry().values():
            total += float(tab.get(w, MACHINES[m]).batch.prediction(-1)[0])
    return {
        "n_workloads": len(workload_registry()),
        "n_machines": len(MACHINES),
        "rows": len(tab),
        "zoo_t_ecm_mem_total_cy": total,
    }


def cold_lower_payload() -> dict:
    """First-touch lowering cost for the whole zoo, table bypassed."""
    from repro.core import MACHINES, workload_registry
    from repro.core.engine import cache_disabled
    from repro.core.workload import lower_many

    ws = list(workload_registry().values())
    with cache_disabled():
        t0 = time.perf_counter()
        rows = 0
        for m in sorted(MACHINES):
            lowered = lower_many(ws, MACHINES[m], table=False)
            rows += len(lowered)
        dt = time.perf_counter() - t0
    return {"rows": rows, "wall_s": dt, "rows_per_s": rows / dt}


def warm_eval_payload(machine: str = "haswell-ep",
                      n_sizes: int = 2000, n_cores: int = 64) -> dict:
    """Steady-state eval rate: warm table rows + memoized level curves."""
    import numpy as np

    from repro.core import BENCHMARKS
    from repro.simcache import scaling_batch, sweep_batch

    names = tuple(BENCHMARKS)
    sizes = list(np.geomspace(16 * 1024, 256 * 1024 * 1024, n_sizes))
    # warm-up pass: populate the lowered table and the level-curve memo
    sweep_batch(names, sizes, machine=machine)
    scaling_batch(names, n_cores, machine=machine)

    t0 = time.perf_counter()
    points = 0
    for _ in range(WARM_EVAL_ITERS):
        _, surface = sweep_batch(names, sizes, machine=machine)
        _, scaling = scaling_batch(names, n_cores, machine=machine)
        points += int(surface.size + scaling.size)
    dt = time.perf_counter() - t0
    return {"points": points, "iters": WARM_EVAL_ITERS,
            "wall_s": dt, "points_per_s": points / dt}


def zoo_sweep_payload() -> dict:
    """Whole-registry Eq. 2 grid rate from packed warm rows."""
    from repro.core import MACHINES
    from repro.core.engine import zoo_sweep

    first = zoo_sweep()          # warm-up: packs every machine's zoo
    t0 = time.perf_counter()
    for _ in range(ZOO_SWEEP_ITERS):
        out = zoo_sweep()
    dt = time.perf_counter() - t0
    assert out["points"] == first["points"]
    return {
        "points": out["points"],
        "machines": len(MACHINES),
        "iters": ZOO_SWEEP_ITERS,
        "wall_s": dt,
        "sweeps_per_s": ZOO_SWEEP_ITERS / dt,
    }


def rerank_payload() -> dict:
    """Full vs incremental attention-block re-rank; must be identical."""
    from repro.core.autotune import rank
    from repro.core.engine import cache_disabled

    dims = RERANK_DIMS
    with cache_disabled():            # full path pays real re-lowering
        t0 = time.perf_counter()
        full = rank(dims, objective="attention")
        dt_full = time.perf_counter() - t0

    prior = rank(dims, objective="attention")
    t0 = time.perf_counter()
    inc = rank(dims, objective="attention", prior=prior, dirty=RERANK_DIRTY)
    dt_inc = time.perf_counter() - t0
    return {
        "n_candidates": len(full),
        "n_dirty": len(RERANK_DIRTY),
        "full_wall_s": dt_full,
        "incremental_wall_s": dt_inc,
        "speedup": dt_full / dt_inc,
        "identical": inc == full,
    }


def engine_payload(machine: str = "haswell-ep") -> dict:
    return {
        "table": table_payload(),
        "cold_lower": cold_lower_payload(),
        "warm_eval": warm_eval_payload(machine=machine),
        "zoo_sweep": zoo_sweep_payload(),
        "rerank": rerank_payload(),
    }


def run(machine: str | None = None) -> str:
    p = engine_payload(machine=machine or "haswell-ep")
    tab, cold, warm = p["table"], p["cold_lower"], p["warm_eval"]
    zoo, rr = p["zoo_sweep"], p["rerank"]
    rows = [
        ["lowered table", f"{tab['rows']} rows",
         f"{tab['n_workloads']} workloads x {tab['n_machines']} machines"],
        ["cold lowering", f"{fmt(cold['rows_per_s'], 0)} rows/s",
         f"{cold['rows']} rows in {cold['wall_s'] * 1e3:.1f} ms"],
        ["warm eval", f"{warm['points_per_s'] / 1e6:.1f} M points/s",
         f"{warm['points']} points, {warm['iters']} reps"],
        ["zoo sweep", f"{fmt(zoo['sweeps_per_s'], 0)} sweeps/s",
         f"{zoo['points']} Eq. 2 points x {zoo['machines']} machines, "
         f"{1e6 * zoo['wall_s'] / zoo['iters']:.0f} us/sweep"],
        ["re-rank", f"{rr['speedup']:.1f}x incremental",
         f"{rr['n_candidates']} blocks, {rr['n_dirty']} dirty, "
         f"identical: {rr['identical']}"],
    ]
    out = [table(["stage", "rate", "detail"], rows)]
    out.append(f"\nzoo T_ECM(mem) checksum: "
               f"{tab['zoo_t_ecm_mem_total_cy']:.3f} cy "
               f"(regression-gated; any fast-path drift moves it)")
    return "\n".join(out)
